"""The CPU-optimized native-jnp artifact variants must agree exactly with
the Pallas-kernel graphs and the oracles (same math, different lowering —
backend kernel selection must never change semantics)."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

SHAPES = [(64, 5), (64, 21), (128, 128), (64, 896)]
SEEDS = [0, 1]


def draw(b, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, d)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=b).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32)
    return w, x, y


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("seed", SEEDS)
def test_distance_fast_matches_pallas_and_ref(shape, seed):
    b, d = shape
    w, x, y = draw(b, d, seed)
    xi2, invc = jnp.float32(0.7), jnp.float32(0.5)
    (fast,) = model.distance_fast_graph(w, x, y, xi2, invc)
    (pallas,) = model.distance_graph(w, x, y, xi2, invc)
    want = ref.ref_distance(w, x, y, xi2, invc)
    np.testing.assert_allclose(fast, want, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(fast, pallas, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("seed", SEEDS)
def test_predict_fast_matches_pallas(shape, seed):
    b, d = shape
    w, x, _ = draw(b, d, seed)
    (fast,) = model.predict_fast_graph(w, x)
    (pallas,) = model.predict_graph(w, x)
    np.testing.assert_allclose(fast, pallas, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("seed", SEEDS)
def test_update_fast_matches_update(seed):
    b, d = 64, 21
    w, x, y = draw(b, d, seed)
    args = (
        jnp.asarray(w),
        jnp.float32(1.0),
        jnp.float32(0.5),
        jnp.asarray(x),
        jnp.asarray(y),
        jnp.ones(b, jnp.float32),
        jnp.float32(0.5),
        jnp.float32(0.5),
    )
    slow = model.update_graph(*args)
    fast = model.update_fast_graph(*args)
    for a, b_ in zip(slow, fast):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5, atol=1e-5)
