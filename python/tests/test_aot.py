"""AOT lowering round-trip: every entry point lowers to parseable HLO text
with the expected parameter count, and the manifest is well-formed."""

import os
import subprocess
import sys

import pytest

from compile import aot


@pytest.mark.parametrize("d", [2, 21, 128])
def test_entry_points_lower(d):
    for name, fn, ex in aot.entry_specs(64, d, 8):
        text = aot.lower_one(fn, ex)
        assert text.startswith("HloModule"), name
        assert f"f32[{d}" in text or d == 1, name
        # lowered with return_tuple=True -> root is a tuple
        assert "tuple(" in text or ") tuple" in text, name


def test_pad_dim_rule():
    assert aot.pad_dim(2) == 2
    assert aot.pad_dim(128) == 128
    assert aot.pad_dim(129) == 256
    assert aot.pad_dim(300) == 384
    assert aot.pad_dim(784) == 896


def test_cli_writes_manifest(tmp_path):
    out = tmp_path / "arts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--dims", "2"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) >= 5  # predict x2, distance, update, merge x2
    for line in manifest:
        entry, b, d, fname = line.split()
        assert (out / fname).exists()
        assert entry in {
            "distance", "predict", "update", "merge",
            "distancef", "predictf", "updatef",
        }
        assert int(b) > 0 and int(d) == 2
