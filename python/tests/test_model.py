"""L2 graph semantics vs explicit-space references.

The key assertions:
  * update_graph over a block == the pure-Python Algorithm 1 (both slack
    conventions), including padded/masked rows;
  * merge_graph returns a ball that *encloses* the old ball and every
    buffered point — verified by materializing the augmented space
    explicitly (original D dims + one slack dim per point + one dim for
    the old center's aggregated slack mass), independently of the Gram
    derivation the graph uses;
  * merge_graph is near-optimal vs brute-force random search on tiny
    instances;
  * Algorithm-2 with L=1 merge degenerates to Algorithm-1-like updates.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

SEEDS = [0, 1, 2, 3]


def draw_stream(n, d, seed):
    rng = np.random.default_rng(seed)
    mu = rng.normal(size=d)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    x += (y[:, None] * mu[None, :]).astype(np.float32)
    return x, y


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("slack_mode", ["paper", "consistent"])
@pytest.mark.parametrize("c", [0.5, 1.0, 10.0])
def test_update_graph_matches_pure_python(seed, slack_mode, c):
    d = 21
    x, y = draw_stream(65, d, seed)
    invc = 1.0 / c
    s2 = 1.0 if slack_mode == "paper" else invc
    w0 = y[0] * x[0]
    valid = np.ones(64, np.float32)
    w1, r1, xi1, m, upd, _ = model.update_graph(
        jnp.asarray(w0),
        jnp.float32(0.0),
        jnp.float32(s2),
        jnp.asarray(x[1:]),
        jnp.asarray(y[1:]),
        jnp.asarray(valid),
        jnp.float32(invc),
        jnp.float32(s2),
    )
    wr, rr, xir, mr = ref.ref_streamsvm(x, y, c, slack_mode=slack_mode)
    np.testing.assert_allclose(np.asarray(w1), wr, rtol=1e-4, atol=1e-4)
    assert abs(float(r1) - rr) < 1e-4 * max(1.0, rr)
    assert abs(float(xi1) - xir) < 1e-4 * max(1.0, xir)
    assert int(m) + 1 == mr


@pytest.mark.parametrize("seed", SEEDS)
def test_update_graph_padding_is_inert(seed):
    d = 5
    x, y = draw_stream(33, d, seed)
    w0 = y[0] * x[0]
    args = dict(invc=jnp.float32(0.5), s2=jnp.float32(0.5))
    # unpadded
    w1, r1, xi1, m1, _, _ = model.update_graph(
        jnp.asarray(w0), jnp.float32(0.0), jnp.float32(0.5),
        jnp.asarray(x[1:]), jnp.asarray(y[1:]),
        jnp.ones(32, jnp.float32), **args,
    )
    # padded to 64 rows with garbage that MUST be ignored
    rng = np.random.default_rng(99)
    xp = np.vstack([x[1:], rng.normal(size=(31, d)).astype(np.float32) * 100])
    yp = np.concatenate([y[1:], np.ones(31, np.float32)])
    vp = np.concatenate([np.ones(32, np.float32), np.zeros(31, np.float32)])
    w2, r2, xi2_, m2, _, _ = model.update_graph(
        jnp.asarray(w0), jnp.float32(0.0), jnp.float32(0.5),
        jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(vp), **args,
    )
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-5, atol=1e-6)
    assert float(r1) == pytest.approx(float(r2), rel=1e-5)
    assert float(m1) == float(m2)


def explicit_augmented(w, xi2, xs, ys, s2):
    """Materialize c0 and p_i in an explicit (D + L + 1)-dim space."""
    L, d = xs.shape
    c0 = np.concatenate([w, np.zeros(L), [np.sqrt(xi2)]])
    pts = []
    for i in range(L):
        e = np.zeros(L)
        e[i] = np.sqrt(s2)
        pts.append(np.concatenate([ys[i] * xs[i], e, [0.0]]))
    return c0, np.array(pts)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("lval", [2, 5, 16])
def test_merge_graph_encloses_ball_and_points(seed, lval):
    d = 21
    xs, ys = draw_stream(lval, d, seed)
    rng = np.random.default_rng(seed + 100)
    w = rng.normal(size=d).astype(np.float32)
    r0, xi2, s2 = 2.0, 0.7, 0.5
    w1, r1, xi1, mu = model.merge_graph(
        jnp.asarray(w), jnp.float32(r0), jnp.float32(xi2),
        jnp.asarray(xs), jnp.asarray(ys), jnp.ones(lval, jnp.float32),
        jnp.float32(s2),
    )
    w1, r1, xi1, mu = map(np.asarray, (w1, r1, xi1, mu))
    # independent check in the explicit space
    c0, pts = explicit_augmented(w, xi2, xs, ys, s2)
    c1 = (1.0 - mu.sum()) * c0 + mu @ pts
    tol = 1e-3 * max(1.0, r1)
    assert np.linalg.norm(c1 - c0) + r0 <= float(r1) + tol  # old ball enclosed
    for p in pts:
        assert np.linalg.norm(c1 - p) <= float(r1) + tol  # every point enclosed
    # the graph's explicit-part and slack-mass bookkeeping agree
    np.testing.assert_allclose(w1, c1[:d], rtol=1e-4, atol=1e-4)
    assert float(xi1) == pytest.approx(float(np.sum(c1[d:] ** 2)), rel=1e-3, abs=1e-5)


@pytest.mark.parametrize("seed", SEEDS)
def test_merge_graph_near_optimal(seed):
    lval, d = 5, 3
    xs, ys = draw_stream(lval, d, seed)
    rng = np.random.default_rng(seed)
    w = rng.normal(size=d).astype(np.float32)
    r0, xi2, s2 = 1.0, 0.5, 0.5
    _, r1, _, _ = model.merge_graph(
        jnp.asarray(w), jnp.float32(r0), jnp.float32(xi2),
        jnp.asarray(xs), jnp.asarray(ys), jnp.ones(lval, jnp.float32),
        jnp.float32(s2),
    )
    _, brute = ref.ref_merge_bruteforce(w, r0, xi2, xs, ys, s2)
    # Badoiu-Clarkson with 128 iterations should be within ~10% of the
    # (itself approximate) brute-force optimum, and never below it by
    # more than float tolerance.
    assert float(r1) <= brute * 1.10 + 1e-4


@pytest.mark.parametrize("seed", SEEDS)
def test_merge_graph_masks_padding(seed):
    lval, d = 8, 5
    xs, ys = draw_stream(lval, d, seed)
    rng = np.random.default_rng(seed + 7)
    w = rng.normal(size=d).astype(np.float32)
    base = dict(r=jnp.float32(1.0), xi2=jnp.float32(0.5))
    w1, r1, x1, _ = model.merge_graph(
        jnp.asarray(w), base["r"], base["xi2"], jnp.asarray(xs), jnp.asarray(ys),
        jnp.ones(lval, jnp.float32), jnp.float32(0.5),
    )
    # pad with huge garbage rows marked invalid
    pad = np.full((8, d), 1e3, np.float32)
    xp = np.vstack([xs, pad])
    yp = np.concatenate([ys, np.ones(8, np.float32)])
    vp = np.concatenate([np.ones(lval, np.float32), np.zeros(8, np.float32)])
    w2, r2, x2, mu2 = model.merge_graph(
        jnp.asarray(w), base["r"], base["xi2"], jnp.asarray(xp), jnp.asarray(yp),
        jnp.asarray(vp), jnp.float32(0.5),
    )
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-4, atol=1e-4)
    assert float(r1) == pytest.approx(float(r2), rel=1e-4)
    assert np.all(np.asarray(mu2)[lval:] == 0.0)


def test_merge_noop_when_ball_already_encloses():
    """If every buffered point is already inside, the merge can keep c = c0
    (mu = 0) and must return r' >= r0 but not much larger."""
    d, lval = 3, 4
    rng = np.random.default_rng(0)
    w = rng.normal(size=d).astype(np.float32)
    xs = np.tile(w, (lval, 1)).astype(np.float32)  # p_i explicit part == w
    ys = np.ones(lval, np.float32)
    r0 = 10.0
    _, r1, _, _ = model.merge_graph(
        jnp.asarray(w), jnp.float32(r0), jnp.float32(0.25),
        jnp.asarray(xs), jnp.asarray(ys), jnp.ones(lval, jnp.float32),
        jnp.float32(0.25),
    )
    assert float(r1) >= r0 - 1e-5
    assert float(r1) <= r0 * 1.01


def test_streamsvm_reference_runs():
    x, y = draw_stream(129, 5, 0)
    w, r, xi2, m = model.streamsvm_reference(jnp.asarray(x), jnp.asarray(y), 1.0)
    assert np.isfinite(float(r)) and float(r) > 0
    assert 1 <= int(m) <= 129
