"""L1 Pallas kernels vs pure-jnp oracles.

hypothesis is unavailable in this offline image; the sweep below is the
explicit equivalent of the hypothesis strategies we would have used:
a grid of (B, D) tile-edge cases (D < tile, D == tile, D > tile and
multi-tile, B single/multi tile) crossed with seeded random draws and
scalar parameters.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels.distance import block_distance, block_sqdist
from compile.kernels.gram import signed_gram
from compile.kernels.predict import block_scores
from compile.kernels import ref

SHAPES = [
    (64, 2),
    (64, 3),
    (64, 5),
    (64, 21),
    (64, 22),
    (128, 64),
    (64, 128),
    (128, 256),
    (256, 384),
    (64, 896),
]
SEEDS = [0, 1, 2]


def draw(b, d, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, d), scale=scale).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=b).astype(np.float32)
    w = rng.normal(size=d, scale=scale).astype(np.float32)
    return w, x, y


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("seed", SEEDS)
def test_distance_matches_ref(shape, seed):
    b, d = shape
    w, x, y = draw(b, d, seed)
    xi2 = jnp.float32(0.5 + seed)
    invc = jnp.float32(1.0 / (1.0 + seed))
    got = block_distance(w, x, y, xi2, invc)
    want = ref.ref_distance(w, x, y, xi2, invc)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("seed", SEEDS)
def test_sqdist_matches_ref(shape, seed):
    b, d = shape
    w, x, y = draw(b, d, seed, scale=3.0)
    xi2 = jnp.float32(2.0)
    invc = jnp.float32(0.1)
    got = block_sqdist(w, x, y, xi2, invc)
    want = ref.ref_sqdist(w, x, y, xi2, invc)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-4)


@pytest.mark.parametrize("shape", [(16, 2), (16, 21), (64, 128), (128, 256), (16, 896)])
@pytest.mark.parametrize("seed", SEEDS)
def test_gram_matches_ref(shape, seed):
    b, d = shape
    w, x, y = draw(b, d, seed)
    got = signed_gram(x, y, block_b=min(64, b))
    want = ref.ref_signed_gram(x, y)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("seed", SEEDS)
def test_predict_matches_ref(shape, seed):
    b, d = shape
    w, x, _ = draw(b, d, seed)
    got = block_scores(w, x)
    want = ref.ref_scores(w, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_gram_symmetry_and_psd_shift():
    """Signed gram is symmetric; adding the slack diagonal keeps it PSD."""
    w, x, y = draw(32, 22, 7)
    g = np.asarray(signed_gram(x, y, block_b=32))
    np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-5)
    eig = np.linalg.eigvalsh(g + np.eye(32, dtype=np.float32))
    assert eig.min() > -1e-3


def test_distance_zero_padding_rows():
    """Zero rows (the batcher's padding) give d^2 = ||w||^2 + xi2 + invc."""
    w, x, y = draw(64, 21, 3)
    x[32:] = 0.0
    y[32:] = 0.0
    d2 = np.asarray(block_sqdist(w, x, y, jnp.float32(1.0), jnp.float32(0.5)))
    want = float(w @ w) + 1.5
    np.testing.assert_allclose(d2[32:], want, rtol=1e-5)
