"""Kept for the Makefile contract: the real kernel tests live in
test_kernels.py (kernels), test_model.py (graphs), test_aot.py (lowering)."""

from compile.kernels import ref
import numpy as np


def test_ref_streamsvm_radius_monotone():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(200, 5))
    y = rng.choice([-1.0, 1.0], size=200)
    w, r, xi2, m = ref.ref_streamsvm(x, y, 1.0)
    assert r > 0 and xi2 > 0 and 1 <= m <= 200
