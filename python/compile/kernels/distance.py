"""Layer-1 Pallas kernel: batched StreamSVM distance evaluation.

The hot-spot of StreamSVM (Rai, Daumé III, Venkatasubramanian, IJCAI'09)
is line 5 of Algorithm 1: for each streamed example compute the distance
of its augmented-space image to the current MEB center,

    d_b = sqrt( ||w - y_b x_b||^2 + xi2 + 1/C )

Over a block of B examples this expands to

    d2_b = ||w||^2 - 2 y_b <x_b, w> + ||x_b||^2 + xi2 + 1/C

whose dominant term is the matvec X @ w — MXU work on TPU. The kernel
tiles over (B, D) with BlockSpec so the HBM->VMEM schedule is explicit:
grid = (B/bb, D/bd), the D axis is the innermost (sequential) grid
dimension and partial sums accumulate into the output block, which is
revisited for every D tile (its index_map ignores the D coordinate).

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; on a real TPU the same BlockSpec structure lowers natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _distance_kernel(s_ref, w_ref, x_ref, y_ref, out_ref):
    """One (bb, bd) tile of the blocked distance computation.

    s_ref   : (2,)  f32 — [xi2, 1/C], broadcast to every tile
    w_ref   : (bd,) f32 — current center slice for this D tile
    x_ref   : (bb, bd) f32 — example block
    y_ref   : (bb,) f32 — labels in {-1, +1}
    out_ref : (bb,) f32 — accumulates d^2 across D tiles
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, s_ref[0] + s_ref[1], out_ref.dtype)

    x = x_ref[...]
    w = w_ref[...]
    y = y_ref[...]
    xw = x @ w  # (bb,) — the MXU matvec
    out_ref[...] += jnp.sum(x * x, axis=1) - 2.0 * y * xw + jnp.sum(w * w)


def block_sqdist(w, x, y, xi2, invc, *, block_b=64, block_d=128):
    """d^2 for a block: ||w - y_b x_b||^2 + xi2 + invc, shape (B,).

    Shapes must tile exactly: B % bb == 0 and D % bd == 0 (the AOT buckets
    guarantee this; the Rust batcher zero-pads and masks).
    Zero-padded rows yield d^2 = ||w||^2 + xi2 + invc, masked out upstream.
    """
    b, d = x.shape
    bb = min(block_b, b)
    bd = min(block_d, d)
    assert b % bb == 0 and d % bd == 0, (x.shape, bb, bd)
    s = jnp.stack([xi2.astype(jnp.float32), invc.astype(jnp.float32)])
    grid = (b // bb, d // bd)
    return pl.pallas_call(
        _distance_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i, j: (0,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bb, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(s, w, x, y)


@functools.partial(jax.jit, static_argnames=("block_b", "block_d"))
def block_distance(w, x, y, xi2, invc, *, block_b=64, block_d=128):
    """d for a block (sqrt of block_sqdist); clamped at 0 for safety."""
    d2 = block_sqdist(w, x, y, xi2, invc, block_b=block_b, block_d=block_d)
    return jnp.sqrt(jnp.maximum(d2, 0.0))
