"""Layer-1 Pallas kernel: batched linear scoring for the serving path.

After training, the Rust coordinator's prediction service batches requests
and scores them with one PJRT call: scores = X @ w (the sign is taken by
the caller, which also wants the raw margin for metrics). Tiled exactly
like the distance kernel: grid = (B/bb, D/bd), D innermost, accumulate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _predict_kernel(w_ref, x_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    out_ref[...] += x_ref[...] @ w_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b", "block_d"))
def block_scores(w, x, *, block_b=64, block_d=128):
    """scores_b = <x_b, w>, shape (B,). B % bb == 0, D % bd == 0."""
    b, d = x.shape
    bb = min(block_b, b)
    bd = min(block_d, d)
    assert b % bb == 0 and d % bd == 0, (x.shape, bb, bd)
    grid = (b // bb, d // bd)
    return pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bb, bd), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(w, x)
