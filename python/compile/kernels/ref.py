"""Pure-jnp / pure-Python oracles for every Pallas kernel and L2 graph.

These are the CORE correctness signal: pytest asserts the Pallas kernels
and the lowered graphs agree with these to float32 tolerance, over a
sweep of shapes and seeds (see python/tests/).
"""

import jax.numpy as jnp
import numpy as np


def ref_sqdist(w, x, y, xi2, invc):
    """d^2_b = ||w - y_b x_b||^2 + xi2 + invc (dense, no tiling)."""
    diff = w[None, :] - y[:, None] * x
    return jnp.sum(diff * diff, axis=1) + xi2 + invc


def ref_distance(w, x, y, xi2, invc):
    return jnp.sqrt(jnp.maximum(ref_sqdist(w, x, y, xi2, invc), 0.0))


def ref_signed_gram(x, y):
    return (y[:, None] * y[None, :]) * (x @ x.T)


def ref_scores(w, x):
    return x @ w


def ref_streamsvm(xs, ys, c, *, slack_mode="consistent", w0=None):
    """Pure-Python/NumPy Algorithm 1 (StreamSVM), the L2 scan oracle.

    slack_mode:
      "paper"      — verbatim pseudocode: xi2 init 1, update adds beta^2.
      "consistent" — slack coordinate C^{-1/2}e_n carried exactly: xi2
                     init 1/C, update adds beta^2/C. Identical when C=1.
    Returns (w, R, xi2, m) after one pass.
    """
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    invc = 1.0 / c
    s2 = 1.0 if slack_mode == "paper" else invc
    w = ys[0] * xs[0] if w0 is None else np.array(w0, dtype=np.float64)
    r = 0.0
    xi2 = s2
    m = 1
    for x, y in zip(xs[1:], ys[1:]):
        diff = w - y * x
        d = np.sqrt(diff @ diff + xi2 + invc)
        if d >= r:
            beta = 0.5 * (1.0 - r / d)
            w = w + beta * (y * x - w)
            r = r + 0.5 * (d - r)
            xi2 = xi2 * (1.0 - beta) ** 2 + beta**2 * s2
            m += 1
    return w, r, xi2, m


def ref_merge_gram(w, xi2, xs, ys, s2):
    """Gram of v_i = p_i - c0 in augmented space.

    <p_i, p_j> = y_i y_j <x_i, x_j> + [i==j] s2   (fresh orthogonal slacks)
    <c0,  p_i> = y_i <w, x_i>                     (c0 slack ⟂ fresh slacks)
    <c0,  c0 > = ||w||^2 + xi2
    """
    w = np.asarray(w, dtype=np.float64)
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    pp = (ys[:, None] * ys[None, :]) * (xs @ xs.T) + s2 * np.eye(len(ys))
    cp = ys * (xs @ w)
    cc = w @ w + xi2
    return pp - cp[:, None] - cp[None, :] + cc


def merge_objective(mu, g, r0):
    """max( ||V mu|| + r0, max_i ||V mu - v_i|| ) from the Gram g."""
    q = g @ mu
    mgm = float(mu @ q)
    ball = np.sqrt(max(mgm, 0.0)) + r0
    pts = np.sqrt(np.maximum(mgm - 2.0 * q + np.diag(g), 0.0))
    return max(ball, float(pts.max()))


def ref_merge_bruteforce(w, r, xi2, xs, ys, s2, n_draws=4000, seed=0):
    """Brute-force reference for the lookahead merge: random search over
    convex coefficients mu for the center c = c0 + sum_i mu_i (p_i - c0).
    Used only by tests on tiny instances to sanity-check near-optimality."""
    rng = np.random.default_rng(seed)
    ys = np.asarray(ys, dtype=np.float64)
    L = len(ys)
    g = ref_merge_gram(w, xi2, xs, ys, s2)
    best_mu = np.zeros(L)
    best = merge_objective(best_mu, g, r)
    for _ in range(n_draws):
        mu = rng.dirichlet(np.ones(L + 1))[:L] * rng.uniform(0.0, 1.2)
        v = merge_objective(mu, g, r)
        if v < best:
            best, best_mu = v, mu.copy()
    return best_mu, best
