"""Layer-1 Pallas kernel: signed Gram matrix for the lookahead MEB solve.

Algorithm 2 of the paper buffers up to L non-enclosed points and merges
(ball ∪ buffer) into one ball. The merge operates entirely on augmented
inner products, whose data-dependent part is the *signed Gram matrix*

    G_ij = y_i y_j <x_i, x_j>

(the mutually-orthogonal slack coordinates contribute a diagonal constant
added outside the kernel). Tiled as a classic (i, j, k) matmul: grid =
(B/bb, B/bb, D/bd), K-axis innermost, output tile revisited across K.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(xa_ref, xb_ref, ya_ref, yb_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    xa = xa_ref[...]  # (bb, bd)
    xb = xb_ref[...]  # (bb, bd)
    sign = ya_ref[...][:, None] * yb_ref[...][None, :]
    out_ref[...] += sign * (xa @ xb.T)


@functools.partial(jax.jit, static_argnames=("block_b", "block_d"))
def signed_gram(x, y, *, block_b=64, block_d=128):
    """G_ij = y_i y_j <x_i, x_j>, shape (B, B). B % bb == 0, D % bd == 0."""
    b, d = x.shape
    bb = min(block_b, b)
    bd = min(block_d, d)
    assert b % bb == 0 and d % bd == 0, (x.shape, bb, bd)
    grid = (b // bb, b // bb, d // bd)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bb, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((bb,), lambda i, j, k: (i,)),
            pl.BlockSpec((bb,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bb, bb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, b), jnp.float32),
        interpret=True,
    )(x, x, y, y)
