"""Layer-2 JAX graphs for StreamSVM (IJCAI'09), calling the L1 Pallas kernels.

Four AOT entry points, each lowered to HLO text by aot.py and executed from
the Rust coordinator via PJRT (Python never runs at request time):

  distance_graph  — block distance d_b to the current MEB center (L1 kernel).
  predict_graph   — batched linear scores for the serving path (L1 kernel).
  update_graph    — exact Algorithm-1 semantics over a block: an L1
                    prefilter distance pass + a lax.scan that applies the
                    sequential center/radius/slack updates. Because the
                    ball only ever grows, a point enclosed by the ball at
                    block entry stays enclosed forever — the scan re-checks
                    d >= R per step, so in-block orderings are exact.
  merge_graph     — Algorithm-2 lookahead merge: minimum enclosing ball of
                    (current ball ∪ L buffered points), solved in the
                    coefficient space of the augmented-feature Gram matrix
                    (L1 gram + scores kernels) with a fixed-iteration
                    Badoiu-Clarkson farthest-point loop. The returned
                    radius is the exact max-distance at the final center,
                    so enclosure holds unconditionally.

Slack-coordinate bookkeeping: the augmented map is phi(z_n) = [y_n x_n ;
C^{-1/2} e_n]. The paper's pseudocode initializes xi^2 = 1 and adds
beta^2 per update (an implicit unit-slack convention); carrying the
C^{-1/2} coordinate exactly gives init 1/C and increments beta^2/C. Both
are supported through the runtime scalar `s2` (paper: s2=1, consistent:
s2=1/C); the two coincide at C=1. See DESIGN.md §3.
"""

import jax
import jax.numpy as jnp

from .kernels.distance import block_distance
from .kernels.gram import signed_gram
from .kernels.predict import block_scores

_EPS = 1e-12


def distance_graph(w, x, y, xi2, invc):
    """d_b = sqrt(||w - y_b x_b||^2 + xi2 + invc) for a (B, D) block."""
    return (block_distance(w, x, y, xi2, invc),)


def predict_graph(w, x):
    """Raw margins <x_b, w> for a (B, D) block (sign taken by the caller)."""
    return (block_scores(w, x),)


def update_graph(w, r, xi2, x, y, valid, invc, s2):
    """One-pass Algorithm-1 updates over a block, exactly.

    Inputs:  w[D], r[], xi2[], x[B,D], y[B], valid[B] (1.0 = real row,
             0.0 = padding), invc[] = 1/C, s2[] = slack self-norm.
    Returns: (w', r', xi2', m_added[], upd_mask[B], d0[B]) where d0 is the
             L1-kernel distance of every row to the *entry* ball (used by
             the Rust coordinator for filter statistics).
    """
    d0 = block_distance(w, x, y, xi2, invc)

    def step(carry, inp):
        wc, rc, xc = carry
        xb, yb, vb = inp
        diff = wc - yb * xb
        d = jnp.sqrt(jnp.maximum(diff @ diff + xc + invc, _EPS))
        upd = (vb > 0.5) & (d >= rc)
        beta = 0.5 * (1.0 - rc / d)
        w2 = wc + beta * (yb * xb - wc)
        r2 = rc + 0.5 * (d - rc)
        x2 = xc * (1.0 - beta) ** 2 + beta**2 * s2
        uf = upd.astype(jnp.float32)
        carry2 = (
            jnp.where(upd, w2, wc),
            jnp.where(upd, r2, rc),
            jnp.where(upd, x2, xc),
        )
        return carry2, uf

    (w1, r1, xi1), upd_mask = jax.lax.scan(step, (w, r, xi2), (x, y, valid))
    return w1, r1, xi1, jnp.sum(upd_mask), upd_mask, d0


def _merge_gram(w, xi2, xs, ys, s2):
    """Gram of v_i = p_i - c0 in augmented space, via the L1 kernels.

    <p_i,p_j> = y_i y_j <x_i,x_j> + [i==j] s2 ; <c0,p_i> = y_i <w,x_i> ;
    <c0,c0>   = ||w||^2 + xi2.
    """
    L = ys.shape[0]
    pp = signed_gram(xs, ys) + s2 * jnp.eye(L, dtype=jnp.float32)
    cp = ys * block_scores(w, xs)
    cc = w @ w + xi2
    return pp - cp[:, None] - cp[None, :] + cc


def merge_graph(w, r, xi2, xs, ys, valid, s2, *, n_iters=128):
    """Algorithm-2 merge: MEB of (ball(w, r, xi2) ∪ buffered points).

    Center parametrized as c = c0 + V mu with V = [p_i - c0]; all norms
    come from the Gram G = V^T V. Badoiu-Clarkson: repeatedly step the
    center 1/(t+2) of the way toward the farthest entity (a buffered point,
    or the far pole of the old ball). Invalid (padding) rows are masked out
    of the farthest-point selection and never receive weight.

    Note there is no `invc` input: in the consistent slack convention the
    point self-norm `s2` carries the 1/C term, so the merge geometry is
    fully determined by (w, r, xi2, s2) — an `invc` argument would be dead
    and MLIR lowering would prune it from the HLO signature.

    Returns (w', r', xi2', mu[L]).
    """
    g = _merge_gram(w, xi2, xs, ys, s2)
    gdiag = jnp.diag(g)
    L = ys.shape[0]
    vmask = valid > 0.5

    def dists(mu):
        q = g @ mu
        mgm = jnp.maximum(mu @ q, 0.0)
        dball = jnp.sqrt(mgm) + r
        dpts = jnp.sqrt(jnp.maximum(mgm - 2.0 * q + gdiag, 0.0))
        dpts = jnp.where(vmask, dpts, -1.0)
        return mgm, dball, dpts

    def body(t, mu):
        mgm, dball, dpts = dists(mu)
        i = jnp.argmax(dpts)
        step = 1.0 / (t.astype(jnp.float32) + 2.0)
        to_pt = mu + step * (jax.nn.one_hot(i, L, dtype=jnp.float32) - mu)
        # far pole of the old ball: q_mu = -mu * r / ||V mu||
        scale = jnp.where(mgm > _EPS, r * jax.lax.rsqrt(jnp.maximum(mgm, _EPS)), 0.0)
        to_ball = mu * (1.0 - step) - step * scale * mu
        ball_farther = dball > dpts[i]
        stay = ball_farther & (mgm <= _EPS)
        mu2 = jnp.where(ball_farther, to_ball, to_pt)
        return jnp.where(stay, mu, mu2)

    mu = jax.lax.fori_loop(0, n_iters, body, jnp.zeros((L,), jnp.float32))
    _, dball, dpts = dists(mu)
    r1 = jnp.maximum(dball, jnp.max(dpts))  # exact radius at final center
    tot = jnp.sum(mu)
    w1 = (1.0 - tot) * w + (mu * ys) @ xs
    xi1 = (1.0 - tot) ** 2 * xi2 + jnp.sum(mu * mu) * s2
    return w1, r1, xi1, mu


# ---------------------------------------------------------------------------
# CPU-optimized "fast" variants: identical math lowered through native jnp
# ops instead of the interpret-mode Pallas kernels. On the CPU PJRT backend
# the interpret-lowered grid (a sequence of dynamic-slice steps) compiles to
# loops that XLA cannot fuse into one GEMV; the jnp form lowers to a single
# dot. The coordinator selects the backend-appropriate artifact at runtime
# (kernel selection, not a semantic change); the Pallas kernels remain the
# TPU-structured path and both are pytest-checked against the same oracle.
# ---------------------------------------------------------------------------


def _fast_sqdist(w, x, y, xi2, invc):
    xw = x @ w
    return (w @ w) - 2.0 * y * xw + jnp.sum(x * x, axis=1) + xi2 + invc


def distance_fast_graph(w, x, y, xi2, invc):
    return (jnp.sqrt(jnp.maximum(_fast_sqdist(w, x, y, xi2, invc), 0.0)),)


def predict_fast_graph(w, x):
    return (x @ w,)


def update_fast_graph(w, r, xi2, x, y, valid, invc, s2):
    """update_graph with the prefilter distance in native jnp."""
    d0 = jnp.sqrt(jnp.maximum(_fast_sqdist(w, x, y, xi2, invc), 0.0))

    def step(carry, inp):
        wc, rc, xc = carry
        xb, yb, vb = inp
        diff = wc - yb * xb
        d = jnp.sqrt(jnp.maximum(diff @ diff + xc + invc, _EPS))
        upd = (vb > 0.5) & (d >= rc)
        beta = 0.5 * (1.0 - rc / d)
        w2 = wc + beta * (yb * xb - wc)
        r2 = rc + 0.5 * (d - rc)
        x2 = xc * (1.0 - beta) ** 2 + beta**2 * s2
        uf = upd.astype(jnp.float32)
        carry2 = (
            jnp.where(upd, w2, wc),
            jnp.where(upd, r2, rc),
            jnp.where(upd, x2, xc),
        )
        return carry2, uf

    (w1, r1, xi1), upd_mask = jax.lax.scan(step, (w, r, xi2), (x, y, valid))
    return w1, r1, xi1, jnp.sum(upd_mask), upd_mask, d0


def streamsvm_reference(xs, ys, c, *, slack_mode="consistent"):
    """Full-pass Algorithm 1 as a single jit-able scan (testing/validation
    convenience; the production path is Rust driving update_graph blocks)."""
    invc = jnp.float32(1.0 / c)
    s2 = jnp.float32(1.0 if slack_mode == "paper" else 1.0 / c)
    w0 = ys[0] * xs[0]
    valid = jnp.ones(ys.shape[0] - 1, jnp.float32)
    w1, r1, xi1, m, _, _ = update_graph(
        w0, jnp.float32(0.0), s2, xs[1:], ys[1:], valid, invc, s2
    )
    return w1, r1, xi1, m + 1.0
