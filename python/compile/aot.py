"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT lowered.compiler_ir('hlo').serialize()) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids, so text round-trips cleanly. Lowered with
return_tuple=True; the Rust side unwraps the tuple.

Usage:  python -m compile.aot --out-dir ../artifacts [--dims 2,3,...]

Emits one HLO file per (entry point, shape bucket) plus a plain-text
manifest (`entry b d file` rows — no JSON so the Rust side needs no serde):

  distance_{B}x{D}.hlo.txt   in: w[D] x[B,D] y[B] xi2[] invc[]      out: d[B]
  predict_{B}x{D}.hlo.txt    in: w[D] x[B,D]                        out: s[B]
  update_{B}x{D}.hlo.txt     in: w[D] r[] xi2[] x[B,D] y[B] v[B] invc[] s2[]
                             out: w'[D] r'[] xi2'[] m[] upd[B] d0[B]
  merge_{L}x{D}.hlo.txt      in: w[D] r[] xi2[] xs[L,D] ys[L] v[L] s2[]
                             out: w'[D] r'[] xi2'[] mu[L]

Shape buckets: B (block) and L (lookahead buffer) fixed per artifact; the
feature dim D is used exactly when D <= 128 and padded to a multiple of
128 above that (the Pallas tiles are (64, min(D,128))). The Rust batcher
zero-pads rows/columns and masks with `valid`.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Feature dims of the paper's eight datasets (Table 1): Synthetic A/B/C,
# Waveform, IJCNN, w3a, MNIST pairs.
DEFAULT_DIMS = [2, 3, 5, 21, 22, 300, 784]
TRAIN_BLOCK = 256
PREDICT_BLOCKS = [64, 256]
MERGE_LS = [16, 128]


def pad_dim(d: int) -> int:
    """Feature-dim padding rule (mirrored by the Rust batcher)."""
    if d <= 128:
        return d
    return ((d + 127) // 128) * 128


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _s():
    return jax.ShapeDtypeStruct((), jnp.float32)


def _v(n):
    return jax.ShapeDtypeStruct((n,), jnp.float32)


def _m(b, d):
    return jax.ShapeDtypeStruct((b, d), jnp.float32)


def entry_specs(b, d, l):
    """(name, fn, example_args) for every artifact at this bucket.

    The `*f` entries are the CPU-optimized native-jnp variants of the same
    math (backend kernel selection — see model.py); the unsuffixed entries
    embed the Pallas kernels.
    """
    dist_args = (_v(d), _m(b, d), _v(b), _s(), _s())
    upd_args = (_v(d), _s(), _s(), _m(b, d), _v(b), _v(b), _s(), _s())
    return [
        (f"distance_{b}x{d}", model.distance_graph, dist_args),
        (f"predict_{b}x{d}", model.predict_graph, (_v(d), _m(b, d))),
        (f"update_{b}x{d}", model.update_graph, upd_args),
        (
            f"merge_{l}x{d}",
            functools.partial(model.merge_graph, n_iters=128),
            (_v(d), _s(), _s(), _m(l, d), _v(l), _v(l), _s()),
        ),
        (f"distancef_{b}x{d}", model.distance_fast_graph, dist_args),
        (f"predictf_{b}x{d}", model.predict_fast_graph, (_v(d), _m(b, d))),
        (f"updatef_{b}x{d}", model.update_fast_graph, upd_args),
    ]


def lower_one(fn, args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--dims",
        default=",".join(str(d) for d in DEFAULT_DIMS),
        help="comma-separated raw feature dims (padded per pad_dim)",
    )
    ap.add_argument("--train-block", type=int, default=TRAIN_BLOCK)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    dims = sorted({pad_dim(int(t)) for t in args.dims.split(",") if t})
    manifest = []
    seen = set()
    for d in dims:
        specs = []
        for pb in PREDICT_BLOCKS:
            e = entry_specs(pb, d, MERGE_LS[0])
            specs.extend([e[1], e[5]])  # predict + predictf
        # train blocks: the compiled default plus a 4x block for the
        # call-overhead-amortization ablation (benches/throughput.rs)
        for tb in [args.train_block, args.train_block * 4]:
            e = entry_specs(tb, d, MERGE_LS[0])
            specs.extend([e[0], e[2], e[4], e[6]])  # distance/update ×2 variants
        base = entry_specs(args.train_block, d, MERGE_LS[0])
        specs.append(base[3])  # merge L=16
        specs.append(entry_specs(args.train_block, d, MERGE_LS[1])[3])  # merge L=128
        for name, fn, ex in specs:
            if name in seen:
                continue
            seen.add(name)
            text = lower_one(fn, ex)
            fname = f"{name}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            entry, shape = name.rsplit("_", 1)
            b, dd = shape.split("x")
            manifest.append(f"{entry} {b} {dd} {fname}")
            print(f"  wrote {fname} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts in {args.out_dir}")


if __name__ == "__main__":
    main()
