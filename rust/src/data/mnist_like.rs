//! Simulated MNIST digit pairs (0vs1 and 8vs9), 28×28 = 784 dims.
//!
//! Real MNIST is unavailable offline. This generator rasterizes stroke
//! templates per digit (circles, lines) onto a 28×28 grid with random
//! translation, scale, stroke thickness, intensity and pixel noise, and is
//! tuned so that the two Table-1 regimes are preserved:
//!
//! * **0 vs 1** — disc vs bar: near-perfectly linearly separable (~99.5%).
//! * **8 vs 9** — both contain a top loop; they differ only in the lower
//!   half (loop vs stem), and the jitter ranges overlap enough that
//!   linear accuracy lands in the mid-90s, with aggressive single-pass
//!   learners visibly below batch — the paper's hard pair.
//!
//! Sizes follow Table 1: 12,665/2,115 for 0vs1 and 11,800/1,983 for 8vs9.

use super::{Dataset, Example};
use crate::rng::Pcg32;

const SIDE: usize = 28;
const DIM: usize = SIDE * SIDE;

/// Geometry of one rendered digit. The ranges are deliberately wide:
/// real MNIST has large intra-class style variance, which is what keeps
/// streamed points escaping the current MEB (hundreds of core vectors on
/// the real data). A too-clean generator saturates the ball after a
/// dozen updates and collapses every MEB-based learner.
struct Jitter {
    dx: f64,
    dy: f64,
    scale: f64,
    thick: f64,
    gain: f64,
    /// Independent per-stroke style factors (aspect, slant, length).
    sa: f64,
    sb: f64,
    shear: f64,
}

impl Jitter {
    fn draw(rng: &mut Pcg32) -> Self {
        Jitter {
            dx: rng.range(-1.0, 1.0),
            dy: rng.range(-1.0, 1.0),
            scale: rng.range(0.92, 1.08),
            thick: rng.range(1.4, 1.8),
            gain: rng.range(0.8, 1.0),
            sa: rng.range(0.95, 1.1),
            sb: rng.range(0.95, 1.1),
            shear: rng.range(-0.05, 0.05),
        }
    }
}

/// Additive intensity of a ring (ellipse outline) at pixel (px, py).
fn ring(px: f64, py: f64, cx: f64, cy: f64, rx: f64, ry: f64, thick: f64) -> f64 {
    let nx = (px - cx) / rx;
    let ny = (py - cy) / ry;
    let r = (nx * nx + ny * ny).sqrt();
    let dist = (r - 1.0) * rx.min(ry); // approx distance to the outline
    (-0.5 * (dist / thick) * (dist / thick)).exp()
}

/// Additive intensity of a line segment from (x0,y0) to (x1,y1).
fn segment(px: f64, py: f64, x0: f64, y0: f64, x1: f64, y1: f64, thick: f64) -> f64 {
    let vx = x1 - x0;
    let vy = y1 - y0;
    let len2 = vx * vx + vy * vy;
    let t = if len2 > 0.0 { ((px - x0) * vx + (py - y0) * vy) / len2 } else { 0.0 };
    let t = t.clamp(0.0, 1.0);
    let dx = px - (x0 + t * vx);
    let dy = py - (y0 + t * vy);
    let dist = (dx * dx + dy * dy).sqrt();
    (-0.5 * (dist / thick) * (dist / thick)).exp()
}

fn render<F: Fn(f64, f64, &Jitter) -> f64>(rng: &mut Pcg32, f: F) -> Vec<f32> {
    let j = Jitter::draw(rng);
    let mut img = vec![0.0f32; DIM];
    for row in 0..SIDE {
        for col in 0..SIDE {
            // shear: columns slide with the row index (italic styles)
            let px = col as f64 + j.shear * (row as f64 - 13.5);
            let py = row as f64;
            // Background stays exactly 0 like real MNIST (a uniform noise
            // floor would swamp the ink-mass asymmetry that makes the
            // unbiased linear classifier work); strokes get multiplicative
            // noise, plus rare salt specks.
            let mut v = f(px, py, &j) * j.gain;
            if v > 0.05 {
                v *= 1.0 + rng.normal() * 0.15;
            } else if rng.bernoulli(0.01) {
                v += rng.range(0.1, 0.5);
            }
            img[row * SIDE + col] = (v.clamp(0.0, 1.0)) as f32;
        }
    }
    img
}

fn digit0(rng: &mut Pcg32) -> Vec<f32> {
    render(rng, |px, py, j| {
        ring(
            px,
            py,
            13.5 + j.dx,
            13.5 + j.dy,
            6.0 * j.scale * j.sa,
            9.0 * j.scale * j.sb,
            j.thick,
        )
    })
}

fn digit1(rng: &mut Pcg32) -> Vec<f32> {
    render(rng, |px, py, j| {
        let x = 13.5 + j.dx;
        segment(
            px,
            py,
            x,
            5.0 + j.dy,
            x,
            (5.0 + 18.0 * j.sb).min(25.0) + j.dy,
            j.thick,
        ) + 0.8
            * segment(px, py, x - 3.5 * j.scale * j.sa, 8.5 + j.dy, x, 5.0 + j.dy, j.thick)
    })
}

fn digit8(rng: &mut Pcg32) -> Vec<f32> {
    render(rng, |px, py, j| {
        let cx = 13.5 + j.dx;
        ring(px, py, cx, 8.0 + j.dy, 3.9 * j.scale * j.sa, 3.6 * j.scale, j.thick)
            + ring(px, py, cx, 18.0 + j.dy, 5.2 * j.scale * j.sb, 5.0 * j.scale, j.thick)
    })
}

fn digit9(rng: &mut Pcg32) -> Vec<f32> {
    render(rng, |px, py, j| {
        let cx = 13.5 + j.dx;
        // Top loop shared with 8; lower half is a thin stem descending
        // vertically at the loop's right tangent. Two asymmetries carry
        // the unbiased linear signal, as on real MNIST: the lower-half
        // ink mass (full ring vs thin stem) and the overall ink gain
        // (real 9s carry ~15% less ink than 8s).
        ring(px, py, cx - 1.0, 11.0 + j.dy, 5.6 * j.scale * j.sa, 5.2 * j.scale, j.thick)
            + segment(
                px,
                py,
                cx + 7.0 * j.scale * j.sa,
                10.0 + j.dy,
                cx + 6.8 * j.scale * j.sa,
                (10.0 + 12.0 * j.sb).min(24.0) + j.dy,
                j.thick * 0.8,
            )
    })
}

fn build_pair(
    name: &str,
    seed: u64,
    stream: u64,
    n_train: usize,
    n_test: usize,
    pos: fn(&mut Pcg32) -> Vec<f32>,
    neg: fn(&mut Pcg32) -> Vec<f32>,
    confusion: f64,
) -> Dataset {
    let mut rng = Pcg32::new(seed, stream);
    let gen = |n: usize, rng: &mut Pcg32| {
        (0..n)
            .map(|_| {
                let y = rng.label(0.5);
                // `confusion`: fraction of genuinely ambiguous writings —
                // a 9 whose stem curls half-way into a loop, an 8 with an
                // open bottom. Rendered as a pixel-space *blend* of the
                // two glyphs (ambiguity in the real pair is continuous,
                // not a label flip): this creates the Bayes overlap that
                // batch solvers absorb in the slack while one-pass
                // learners pay for.
                let x = if rng.bernoulli(confusion) {
                    let u = rng.range(0.35, 0.65) as f32;
                    let (a, b) = (pos(rng), neg(rng));
                    let mix: Vec<f32> = a
                        .iter()
                        .zip(&b)
                        .map(|(&pa, &pb)| (u * pa + (1.0 - u) * pb).clamp(0.0, 1.0))
                        .collect();
                    mix
                } else if y > 0.0 {
                    pos(rng)
                } else {
                    neg(rng)
                };
                Example::new(x, y)
            })
            .collect::<Vec<_>>()
    };
    let train = gen(n_train, &mut rng);
    let test = gen(n_test, &mut rng);
    Dataset::new(name, DIM, train, test)
}

/// MNIST-like 0 vs 1 (+1 = digit 0), 12,665 / 2,115 — the easy pair.
pub fn mnist01(seed: u64) -> Dataset {
    build_pair("mnist01", seed, 0x01, 12_665, 2_115, digit0, digit1, 0.002)
}

/// MNIST-like 8 vs 9 (+1 = digit 8), 11,800 / 1,983 — the hard pair.
pub fn mnist89(seed: u64) -> Dataset {
    build_pair("mnist89", seed, 0x89, 11_800, 1_983, digit8, digit9, 0.10)
}

/// Small variants for fast unit/integration tests.
pub fn mnist89_small(seed: u64, n_train: usize, n_test: usize) -> Dataset {
    build_pair("mnist89s", seed, 0x89, n_train, n_test, digit8, digit9, 0.10)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_table1() {
        let d01 = mnist01(1);
        assert_eq!((d01.dim, d01.train.len(), d01.test.len()), (784, 12_665, 2_115));
        let d89 = mnist89_small(1, 500, 100);
        assert_eq!((d89.dim, d89.train.len()), (784, 500));
    }

    #[test]
    fn pixels_are_normalized() {
        let ds = mnist89_small(2, 50, 10);
        for e in &ds.train {
            assert!(e.x.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn zero_vs_one_mass_differs() {
        // Digit 1 concentrates mass in the central columns; digit 0 does
        // not. A trivial center-column detector must already separate
        // them well — the easy-pair premise.
        let mut rng = Pcg32::seeded(3);
        let center_mass = |img: &[f32]| -> f64 {
            let mut c = 0.0;
            for row in 8..20 {
                for col in 12..16 {
                    c += img[row * SIDE + col] as f64;
                }
            }
            c
        };
        let mut ok = 0;
        for _ in 0..100 {
            if center_mass(&digit1(&mut rng)) > center_mass(&digit0(&mut rng)) {
                ok += 1;
            }
        }
        assert!(ok >= 95, "center-mass separation {ok}/100");
    }

    #[test]
    fn eight_vs_nine_overlap_in_top_half() {
        // 8 and 9 share the top loop: top-half images should be far more
        // similar across classes than bottom halves — the hard-pair premise.
        let mut rng = Pcg32::seeded(4);
        let half_mass = |img: &[f32], top: bool| -> f64 {
            let rows = if top { 0..14 } else { 14..28 };
            rows.flat_map(|r| (0..SIDE).map(move |c| (r, c)))
                .map(|(r, c)| img[r * SIDE + c] as f64)
                .sum()
        };
        let mut top_gap = 0.0;
        let mut bot_gap = 0.0;
        for _ in 0..50 {
            let e8 = digit8(&mut rng);
            let e9 = digit9(&mut rng);
            top_gap += (half_mass(&e8, true) - half_mass(&e9, true)).abs();
            bot_gap += (half_mass(&e8, false) - half_mass(&e9, false)).abs();
        }
        assert!(bot_gap > top_gap, "bottom {bot_gap} vs top {top_gap}");
    }
}
