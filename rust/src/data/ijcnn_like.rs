//! Simulated IJCNN-2001-like dataset: 22 dims, 35,000/91,701, ~9.7%
//! positives.
//!
//! The real IJCNN task (engine misfire detection from time-series-derived
//! features) is unavailable offline. The regime that matters for Table 1:
//! heavy class imbalance (majority rate ≈ 90.3%), a mildly *nonlinear*
//! positive region so that a good linear batch solver only just beats the
//! majority class (paper: 91.64), while order-sensitive single-pass
//! learners land anywhere between 64 and 89. We simulate with an AR(1)
//! latent process (temporal correlation — it *is* a stream) whose
//! positives fire when a quadratic radius condition holds.

use super::{Dataset, Example};
use crate::rng::Pcg32;

const DIM: usize = 22;

fn gen_split(rng: &mut Pcg32, n: usize) -> Vec<Example> {
    let mut state = vec![0.0f64; DIM];
    for s in state.iter_mut() {
        *s = rng.normal();
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // AR(1) evolution: consecutive stream examples are correlated.
        for s in state.iter_mut() {
            *s = 0.6 * *s + 0.8 * rng.normal();
        }
        // Positive region: a shifted shell in the first few coordinates,
        // plus a linear tilt so a linear model captures *part* of it.
        let r2: f64 = state[..4].iter().map(|v| v * v).sum();
        let tilt: f64 = state[..8].iter().sum::<f64>() / 8.0;
        let score = 0.8 * (r2 - 5.2) + 1.4 * tilt + 0.35 * rng.normal();
        let y = if score > 2.1 { 1.0 } else { -1.0 };
        // Physical-sensor scaling: the real IJCNN features are bounded
        // (LIBSVM-scaled) measurements with a non-zero mean. Both
        // properties matter: the offset lets an *unbiased* linear model
        // (the paper's setting) express the 90%-negative majority class,
        // and the bounded range keeps the rare positives from being
        // geometric norm-outliers that would hijack any MEB-based
        // learner (they are not outliers in the real data either).
        let x: Vec<f32> = state.iter().map(|&v| (1.5 + 1.2 * (v * 0.5).tanh()) as f32).collect();
        out.push(Example::new(x, y));
    }
    out
}

/// IJCNN-like: 35,000 train / 91,701 test, ≈9–10% positives.
pub fn ijcnn_like(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 0x13C);
    let train = gen_split(&mut rng, 35_000);
    let test = gen_split(&mut rng, 91_701);
    Dataset::new("ijcnn", DIM, train, test)
}

/// Reduced-size variant for tests.
pub fn ijcnn_small(seed: u64, n_train: usize, n_test: usize) -> Dataset {
    let mut rng = Pcg32::new(seed, 0x13C);
    let train = gen_split(&mut rng, n_train);
    let test = gen_split(&mut rng, n_test);
    Dataset::new("ijcnn_s", DIM, train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imbalance_matches_regime() {
        let ds = ijcnn_small(1, 20_000, 1000);
        let rate = ds.positive_rate();
        assert!((0.06..0.14).contains(&rate), "positive rate {rate}");
    }

    #[test]
    fn temporal_correlation_exists() {
        // Adjacent examples share the AR(1) state: feature-0 lag-1
        // autocorrelation should be clearly positive.
        let ds = ijcnn_small(2, 5000, 10);
        let xs: Vec<f64> = ds.train.iter().map(|e| e.x[0] as f64).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 = xs.iter().map(|v| (v - mean) * (v - mean)).sum();
        let cov: f64 = xs.windows(2).map(|w| (w[0] - mean) * (w[1] - mean)).sum();
        let rho = cov / var;
        assert!(rho > 0.3, "lag-1 autocorrelation {rho}");
    }

    #[test]
    fn full_sizes() {
        // Just the arithmetic, not the full allocation: sizes come from
        // Table 1.
        let ds = ijcnn_small(3, 350, 917);
        assert_eq!(ds.dim, 22);
        assert_eq!(ds.train.len(), 350);
        assert_eq!(ds.test.len(), 917);
    }
}
