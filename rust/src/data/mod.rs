//! Dataset substrates for the paper's evaluation (Table 1).
//!
//! The paper evaluates on Synthetic A/B/C, Waveform, two MNIST digit
//! pairs, IJCNN and w3a. Synthetic A/B/C and Waveform are generators by
//! definition and are regenerated faithfully; MNIST/IJCNN/w3a are not
//! available in this offline environment, so `mnist_like` / `ijcnn_like` /
//! `w3a_like` build structured simulated equivalents that preserve the
//! dimensionality, class balance and difficulty regime (see DESIGN.md §2).
//! Real data in LIBSVM format can be substituted via [`libsvm_format`].
//!
//! Features come in two physical representations behind one [`Features`]
//! value: dense `Vec<f32>` (the generators) and [`SparseVec`] index/value
//! pairs (LIBSVM streams, where w3a-like data is ~4% dense). The hot
//! paths consume borrowed [`FeaturesView`]s so per-example work is
//! O(nnz) for sparse rows instead of O(D).

use std::borrow::Cow;

pub mod chunked;
pub mod hashing;
pub mod ijcnn_like;
pub mod libsvm_format;
pub mod mnist_like;
pub mod registry;
pub mod synthetic;
pub mod w3a_like;
pub mod waveform;

/// A sparse vector as parallel `idx`/`val` arrays. Indices are 0-based,
/// strictly increasing, and `val` entries are the non-zero coordinates
/// (zeros are permitted but wasteful).
#[derive(Clone, Debug, PartialEq)]
pub struct SparseVec {
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl SparseVec {
    /// Build from parallel arrays; panics if the arrays disagree in
    /// length or `idx` is not strictly increasing.
    pub fn new(idx: Vec<u32>, val: Vec<f32>) -> Self {
        assert_eq!(idx.len(), val.len(), "idx/val length mismatch");
        assert!(
            idx.windows(2).all(|w| w[0] < w[1]),
            "sparse indices must be strictly increasing"
        );
        SparseVec { idx, val }
    }

    /// Number of stored (index, value) pairs.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// The non-zero coordinates of a dense slice.
    pub fn from_dense(x: &[f32]) -> Self {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &v) in x.iter().enumerate() {
            if v != 0.0 {
                idx.push(i as u32);
                val.push(v);
            }
        }
        SparseVec { idx, val }
    }

    /// Materialize as a dense vector of length `dim`.
    pub fn to_dense(&self, dim: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; dim];
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
        out
    }

    /// Coordinate `i` (0 if unstored), by binary search.
    pub fn get(&self, i: usize) -> f32 {
        match self.idx.binary_search(&(i as u32)) {
            Ok(p) => self.val[p],
            Err(_) => 0.0,
        }
    }
}

/// Feature storage: dense or sparse. Both carry their logical dimension.
#[derive(Clone, Debug, PartialEq)]
pub enum Features {
    Dense(Vec<f32>),
    Sparse { dim: usize, v: SparseVec },
}

/// A borrowed, `Copy` view of one example's features — what the O(nnz)
/// kernels in [`crate::linalg`] and the ball update consume.
#[derive(Clone, Copy, Debug)]
pub enum FeaturesView<'a> {
    Dense(&'a [f32]),
    Sparse { dim: usize, idx: &'a [u32], val: &'a [f32] },
}

impl Features {
    /// A sparse feature vector of logical dimension `dim`. Panics if an
    /// index is out of range (indices must be < `dim`).
    pub fn sparse(dim: usize, idx: Vec<u32>, val: Vec<f32>) -> Self {
        let v = SparseVec::new(idx, val);
        assert!(
            v.idx.last().map(|&i| (i as usize) < dim).unwrap_or(true),
            "sparse index out of range for dim {dim}"
        );
        Features::Sparse { dim, v }
    }

    /// Logical dimension.
    pub fn len(&self) -> usize {
        match self {
            Features::Dense(x) => x.len(),
            Features::Sparse { dim, .. } => *dim,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored coordinates (= `len()` for dense).
    pub fn nnz(&self) -> usize {
        match self {
            Features::Dense(x) => x.len(),
            Features::Sparse { v, .. } => v.nnz(),
        }
    }

    /// Borrowed view for the O(nnz) kernels.
    pub fn view(&self) -> FeaturesView<'_> {
        match self {
            Features::Dense(x) => FeaturesView::Dense(x),
            Features::Sparse { dim, v } => {
                FeaturesView::Sparse { dim: *dim, idx: &v.idx, val: &v.val }
            }
        }
    }

    /// Dense coordinates: borrowed for dense storage, materialized for
    /// sparse. The escape hatch for consumers that genuinely need a
    /// contiguous slice (baselines, JSON encoding, PJRT blocks).
    pub fn dense(&self) -> Cow<'_, [f32]> {
        match self {
            Features::Dense(x) => Cow::Borrowed(x.as_slice()),
            Features::Sparse { dim, v } => Cow::Owned(v.to_dense(*dim)),
        }
    }

    /// The dense slice; panics on sparse storage (generator/test paths
    /// that construct dense examples by hand).
    pub fn as_slice(&self) -> &[f32] {
        match self {
            Features::Dense(x) => x,
            Features::Sparse { .. } => panic!("as_slice() on sparse features"),
        }
    }

    /// Convert to the sparse representation (drops explicit zeros).
    pub fn to_sparse(&self) -> Features {
        match self {
            Features::Dense(x) => {
                Features::Sparse { dim: x.len(), v: SparseVec::from_dense(x) }
            }
            s => s.clone(),
        }
    }

    /// Every stored value finite?
    pub fn is_finite(&self) -> bool {
        match self {
            Features::Dense(x) => x.iter().all(|v| v.is_finite()),
            Features::Sparse { v, .. } => v.val.iter().all(|v| v.is_finite()),
        }
    }

    /// Coordinate `i` (0-filled for sparse gaps).
    pub fn get(&self, i: usize) -> f32 {
        match self {
            Features::Dense(x) => x[i],
            Features::Sparse { v, .. } => v.get(i),
        }
    }

    /// Iterate stored non-zero coordinates as `(index, value)`.
    pub fn iter_nonzero(&self) -> Box<dyn Iterator<Item = (usize, f32)> + '_> {
        match self {
            Features::Dense(x) => Box::new(
                x.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(i, &v)| (i, v)),
            ),
            Features::Sparse { v, .. } => Box::new(
                v.idx.iter().zip(&v.val).map(|(&i, &v)| (i as usize, v)),
            ),
        }
    }
}

impl From<Vec<f32>> for Features {
    fn from(x: Vec<f32>) -> Self {
        Features::Dense(x)
    }
}

impl std::ops::Index<usize> for Features {
    type Output = f32;

    fn index(&self, i: usize) -> &f32 {
        match self {
            Features::Dense(x) => &x[i],
            Features::Sparse { v, .. } => match v.idx.binary_search(&(i as u32)) {
                Ok(p) => &v.val[p],
                Err(_) => &0.0,
            },
        }
    }
}

impl FeaturesView<'_> {
    /// Logical dimension.
    pub fn dim(&self) -> usize {
        match self {
            FeaturesView::Dense(x) => x.len(),
            FeaturesView::Sparse { dim, .. } => *dim,
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            FeaturesView::Dense(x) => x.len(),
            FeaturesView::Sparse { idx, .. } => idx.len(),
        }
    }

    /// `||x||²` — O(nnz).
    pub fn norm2(&self) -> f64 {
        match self {
            FeaturesView::Dense(x) => crate::linalg::norm2(x),
            FeaturesView::Sparse { val, .. } => crate::linalg::norm2(val),
        }
    }

    /// `<x, z>` between two views of the same logical dimension —
    /// O(nnz) for mixed pairs, O(nnz_x + nnz_z) merge-join for two
    /// sparse views (the Algorithm-2 merge-Gram kernel).
    pub fn dot_view(&self, other: &FeaturesView<'_>) -> f64 {
        debug_assert_eq!(self.dim(), other.dim());
        match (self, other) {
            (FeaturesView::Dense(a), FeaturesView::Dense(b)) => crate::linalg::dot(a, b),
            (FeaturesView::Dense(a), FeaturesView::Sparse { idx, val, .. }) => {
                crate::linalg::sparse_dot(a, idx, val)
            }
            (FeaturesView::Sparse { idx, val, .. }, FeaturesView::Dense(b)) => {
                crate::linalg::sparse_dot(b, idx, val)
            }
            (
                FeaturesView::Sparse { idx: ia, val: va, .. },
                FeaturesView::Sparse { idx: ib, val: vb, .. },
            ) => crate::linalg::sparse_sparse_dot(ia, va, ib, vb),
        }
    }

    /// `<w, x>` against a dense `w` of the same logical dimension —
    /// O(nnz).
    pub fn dot(&self, w: &[f32]) -> f64 {
        match self {
            FeaturesView::Dense(x) => crate::linalg::dot(w, x),
            FeaturesView::Sparse { dim, idx, val } => {
                debug_assert_eq!(w.len(), *dim);
                crate::linalg::sparse_dot(w, idx, val)
            }
        }
    }

    /// `a += s * x` — O(nnz) scatter for sparse `x`.
    pub fn axpy_into(&self, a: &mut [f32], s: f32) {
        match self {
            FeaturesView::Dense(x) => crate::linalg::axpy(a, s, x),
            FeaturesView::Sparse { dim, idx, val } => {
                debug_assert_eq!(a.len(), *dim);
                crate::linalg::sparse_axpy(a, s, idx, val)
            }
        }
    }

    /// Scatter into `out[..dim]` (used by the block batcher; `out` may
    /// be wider than `dim` for padded layouts). Overwrites only stored
    /// coordinates for sparse views, so `out` must be pre-zeroed.
    pub fn write_into(&self, out: &mut [f32]) {
        match self {
            FeaturesView::Dense(x) => out[..x.len()].copy_from_slice(x),
            FeaturesView::Sparse { idx, val, .. } => {
                for (&i, &v) in idx.iter().zip(*val) {
                    out[i as usize] = v;
                }
            }
        }
    }

    /// Materialize a dense copy.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim()];
        self.write_into(&mut out);
        out
    }

    /// An owned copy that *preserves the physical representation*:
    /// sparse views stay sparse (unlike [`Self::to_dense`]). This is
    /// what lets the Algorithm-2 lookahead buffer hold survivors without
    /// densifying them.
    pub fn to_features(&self) -> Features {
        match self {
            FeaturesView::Dense(x) => Features::Dense(x.to_vec()),
            FeaturesView::Sparse { dim, idx, val } => Features::Sparse {
                dim: *dim,
                v: SparseVec { idx: idx.to_vec(), val: val.to_vec() },
            },
        }
    }

    pub fn is_finite(&self) -> bool {
        match self {
            FeaturesView::Dense(x) => x.iter().all(|v| v.is_finite()),
            FeaturesView::Sparse { val, .. } => val.iter().all(|v| v.is_finite()),
        }
    }
}

/// One labeled example: features (dense or sparse) and a ±1 label.
#[derive(Clone, Debug, PartialEq)]
pub struct Example {
    pub x: Features,
    pub y: f32,
}

impl Example {
    pub fn new(x: impl Into<Features>, y: f32) -> Self {
        debug_assert!(y == 1.0 || y == -1.0, "labels must be ±1, got {y}");
        Example { x: x.into(), y }
    }

    /// A sparse example of logical dimension `dim`.
    pub fn sparse(dim: usize, idx: Vec<u32>, val: Vec<f32>, y: f32) -> Self {
        Example::new(Features::sparse(dim, idx, val), y)
    }

    pub fn dim(&self) -> usize {
        self.x.len()
    }
}

/// A train/test split with metadata.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub dim: usize,
    pub train: Vec<Example>,
    pub test: Vec<Example>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, dim: usize, train: Vec<Example>, test: Vec<Example>) -> Self {
        let ds = Dataset { name: name.into(), dim, train, test };
        debug_assert!(ds.train.iter().chain(ds.test.iter()).all(|e| e.dim() == ds.dim));
        ds
    }

    /// Fraction of positive labels in the training split.
    pub fn positive_rate(&self) -> f64 {
        let pos = self.train.iter().filter(|e| e.y > 0.0).count();
        pos as f64 / self.train.len().max(1) as f64
    }

    /// Convert every example to the sparse representation in place (the
    /// CLI `--sparse` toggle; dense datasets then exercise the O(nnz)
    /// hot path).
    pub fn sparsify(&mut self) {
        for e in self.train.iter_mut().chain(self.test.iter_mut()) {
            e.x = e.x.to_sparse();
        }
    }

    /// Mean stored-nonzero fraction of the training split.
    pub fn density(&self) -> f64 {
        if self.train.is_empty() || self.dim == 0 {
            return 0.0;
        }
        let nnz: usize = self.train.iter().map(|e| e.x.iter_nonzero().count()).sum();
        nnz as f64 / (self.train.len() * self.dim) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_dim() {
        let e = Example::new(vec![1.0, 2.0], 1.0);
        assert_eq!(e.dim(), 2);
    }

    #[test]
    fn positive_rate() {
        let mk = |y| Example::new(vec![0.0], y);
        let ds = Dataset::new("t", 1, vec![mk(1.0), mk(-1.0), mk(-1.0), mk(-1.0)], vec![]);
        assert_eq!(ds.positive_rate(), 0.25);
    }

    #[test]
    fn sparse_roundtrip_and_access() {
        let e = Example::sparse(5, vec![1, 4], vec![2.0, -3.0], -1.0);
        assert_eq!(e.dim(), 5);
        assert_eq!(e.x.nnz(), 2);
        assert_eq!(e.x.dense().as_ref(), &[0.0, 2.0, 0.0, 0.0, -3.0]);
        assert_eq!(e.x[1], 2.0);
        assert_eq!(e.x[2], 0.0);
        assert_eq!(e.x.get(4), -3.0);
        let nz: Vec<(usize, f32)> = e.x.iter_nonzero().collect();
        assert_eq!(nz, vec![(1, 2.0), (4, -3.0)]);
    }

    #[test]
    fn dense_sparse_conversion() {
        let d = Features::Dense(vec![0.0, 1.5, 0.0, -2.0]);
        let s = d.to_sparse();
        assert_eq!(s.len(), 4);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.dense().as_ref(), d.dense().as_ref());
        // sparse → sparse is a no-op
        assert_eq!(s.to_sparse(), s);
    }

    #[test]
    fn view_kernels_match_dense() {
        let s = Features::sparse(6, vec![0, 3, 5], vec![1.0, -2.0, 0.5]);
        let w = [0.5f32, 1.0, 1.0, 2.0, 1.0, 4.0];
        let dense = s.dense();
        assert_eq!(s.view().dot(&w), crate::linalg::dot(&w, &dense));
        assert_eq!(s.view().norm2(), crate::linalg::norm2(&dense));
        let mut a = vec![1.0f32; 6];
        s.view().axpy_into(&mut a, 2.0);
        assert_eq!(a, vec![3.0, 1.0, 1.0, -3.0, 1.0, 2.0]);
    }

    #[test]
    fn dot_view_all_representation_pairs() {
        let a = Features::sparse(6, vec![0, 3, 5], vec![1.0, -2.0, 0.5]);
        let b = Features::sparse(6, vec![1, 3, 4], vec![2.0, 3.0, 1.0]);
        let (ad, bd) = (a.dense().into_owned(), b.dense().into_owned());
        let want = crate::linalg::dot(&ad, &bd);
        let dv = |x: FeaturesView, y: FeaturesView| x.dot_view(&y);
        assert_eq!(dv(a.view(), b.view()), want);
        assert_eq!(dv(FeaturesView::Dense(&ad), b.view()), want);
        assert_eq!(dv(a.view(), FeaturesView::Dense(&bd)), want);
        assert_eq!(dv(FeaturesView::Dense(&ad), FeaturesView::Dense(&bd)), want);
    }

    #[test]
    fn to_features_preserves_representation() {
        let s = Features::sparse(5, vec![1, 4], vec![2.0, -3.0]);
        let owned = s.view().to_features();
        assert_eq!(owned, s);
        assert!(matches!(owned, Features::Sparse { .. }));
        let d = Features::Dense(vec![1.0, 0.0]);
        let owned = d.view().to_features();
        assert_eq!(owned, d);
        assert!(matches!(owned, Features::Dense(_)));
    }

    #[test]
    fn finiteness_checks() {
        assert!(Features::Dense(vec![1.0, 2.0]).is_finite());
        assert!(!Features::Dense(vec![1.0, f32::NAN]).is_finite());
        assert!(!Features::sparse(3, vec![1], vec![f32::INFINITY]).is_finite());
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn unsorted_sparse_rejected() {
        SparseVec::new(vec![3, 1], vec![1.0, 2.0]);
    }

    #[test]
    fn sparsify_dataset() {
        let mut ds = Dataset::new(
            "t",
            3,
            vec![Example::new(vec![1.0, 0.0, 2.0], 1.0)],
            vec![Example::new(vec![0.0, 0.0, 0.0], -1.0)],
        );
        ds.sparsify();
        assert_eq!(ds.train[0].x.nnz(), 2);
        assert_eq!(ds.test[0].x.nnz(), 0);
        assert_eq!(ds.train[0].dim(), 3);
        assert!((ds.density() - 2.0 / 3.0).abs() < 1e-12);
    }
}
