//! Dataset substrates for the paper's evaluation (Table 1).
//!
//! The paper evaluates on Synthetic A/B/C, Waveform, two MNIST digit
//! pairs, IJCNN and w3a. Synthetic A/B/C and Waveform are generators by
//! definition and are regenerated faithfully; MNIST/IJCNN/w3a are not
//! available in this offline environment, so `mnist_like` / `ijcnn_like` /
//! `w3a_like` build structured simulated equivalents that preserve the
//! dimensionality, class balance and difficulty regime (see DESIGN.md §2).
//! Real data in LIBSVM format can be substituted via [`libsvm_format`].

pub mod ijcnn_like;
pub mod libsvm_format;
pub mod mnist_like;
pub mod registry;
pub mod synthetic;
pub mod w3a_like;
pub mod waveform;

/// One labeled example: a dense feature vector and a ±1 label.
#[derive(Clone, Debug, PartialEq)]
pub struct Example {
    pub x: Vec<f32>,
    pub y: f32,
}

impl Example {
    pub fn new(x: Vec<f32>, y: f32) -> Self {
        debug_assert!(y == 1.0 || y == -1.0, "labels must be ±1, got {y}");
        Example { x, y }
    }

    pub fn dim(&self) -> usize {
        self.x.len()
    }
}

/// A train/test split with metadata.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub dim: usize,
    pub train: Vec<Example>,
    pub test: Vec<Example>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, dim: usize, train: Vec<Example>, test: Vec<Example>) -> Self {
        let ds = Dataset { name: name.into(), dim, train, test };
        debug_assert!(ds.train.iter().chain(ds.test.iter()).all(|e| e.dim() == ds.dim));
        ds
    }

    /// Fraction of positive labels in the training split.
    pub fn positive_rate(&self) -> f64 {
        let pos = self.train.iter().filter(|e| e.y > 0.0).count();
        pos as f64 / self.train.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_dim() {
        let e = Example::new(vec![1.0, 2.0], 1.0);
        assert_eq!(e.dim(), 2);
    }

    #[test]
    fn positive_rate() {
        let mk = |y| Example::new(vec![0.0], y);
        let ds = Dataset::new("t", 1, vec![mk(1.0), mk(-1.0), mk(-1.0), mk(-1.0)], vec![]);
        assert_eq!(ds.positive_rate(), 0.25);
    }
}
