//! LIBSVM sparse-format parser, so real MNIST/IJCNN/w3a files can replace
//! the simulated generators without code changes.
//!
//! Format: one example per line, `label idx:val idx:val ...` with 1-based
//! indices. Labels are mapped to ±1 (`0`/`-1` → −1, anything positive →
//! +1, two-class multi-label files can be filtered with [`parse_pair`]).

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use super::{Dataset, Example};
use crate::error::{Error, Result};

/// Parse one LIBSVM line into `(raw_label, sparse pairs)`.
fn parse_line(line: &str, lineno: usize) -> Result<Option<(f64, Vec<(usize, f32)>)>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut it = line.split_whitespace();
    let label: f64 = it
        .next()
        .unwrap()
        .parse()
        .map_err(|e| Error::data(format!("line {lineno}: bad label ({e})")))?;
    let mut pairs = Vec::new();
    for tok in it {
        let (i, v) = tok
            .split_once(':')
            .ok_or_else(|| Error::data(format!("line {lineno}: token `{tok}` lacks `:`")))?;
        let idx: usize = i
            .parse()
            .map_err(|e| Error::data(format!("line {lineno}: bad index ({e})")))?;
        if idx == 0 {
            return Err(Error::data(format!("line {lineno}: LIBSVM indices are 1-based")));
        }
        let val: f32 = v
            .parse()
            .map_err(|e| Error::data(format!("line {lineno}: bad value ({e})")))?;
        pairs.push((idx - 1, val));
    }
    Ok(Some((label, pairs)))
}

/// Read all examples from a LIBSVM reader; densifies to the max index
/// (or `force_dim` if larger).
pub fn read_examples<R: Read>(r: R, force_dim: Option<usize>) -> Result<Vec<Example>> {
    let reader = BufReader::new(r);
    let mut rows: Vec<(f64, Vec<(usize, f32)>)> = Vec::new();
    let mut max_dim = force_dim.unwrap_or(0);
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if let Some((label, pairs)) = parse_line(&line, lineno + 1)? {
            if let Some(&(idx, _)) = pairs.iter().max_by_key(|&&(i, _)| i) {
                max_dim = max_dim.max(idx + 1);
            }
            rows.push((label, pairs));
        }
    }
    Ok(rows
        .into_iter()
        .map(|(label, pairs)| {
            let mut x = vec![0.0f32; max_dim];
            for (i, v) in pairs {
                x[i] = v;
            }
            Example::new(x, if label > 0.0 { 1.0 } else { -1.0 })
        })
        .collect())
}

/// Load a train/test pair of LIBSVM files as a [`Dataset`].
pub fn load_files(
    name: &str,
    train_path: &Path,
    test_path: &Path,
    force_dim: Option<usize>,
) -> Result<Dataset> {
    let train = read_examples(std::fs::File::open(train_path)?, force_dim)?;
    let dim = force_dim
        .unwrap_or_else(|| train.iter().map(|e| e.dim()).max().unwrap_or(0));
    let mut train = train;
    pad_to(&mut train, dim);
    let mut test = read_examples(std::fs::File::open(test_path)?, Some(dim))?;
    pad_to(&mut test, dim);
    Ok(Dataset::new(name, dim, train, test))
}

/// For multi-class files: keep labels `a` (→ +1) and `b` (→ −1) only.
pub fn parse_pair<R: Read>(r: R, a: f64, b: f64, force_dim: Option<usize>) -> Result<Vec<Example>> {
    let reader = BufReader::new(r);
    let mut rows = Vec::new();
    let mut max_dim = force_dim.unwrap_or(0);
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if let Some((label, pairs)) = parse_line(&line, lineno + 1)? {
            if label != a && label != b {
                continue;
            }
            if let Some(&(idx, _)) = pairs.iter().max_by_key(|&&(i, _)| i) {
                max_dim = max_dim.max(idx + 1);
            }
            rows.push((label, pairs));
        }
    }
    Ok(rows
        .into_iter()
        .map(|(label, pairs)| {
            let mut x = vec![0.0f32; max_dim];
            for (i, v) in pairs {
                x[i] = v;
            }
            Example::new(x, if label == a { 1.0 } else { -1.0 })
        })
        .collect())
}

fn pad_to(examples: &mut [Example], dim: usize) {
    for e in examples.iter_mut() {
        if e.x.len() < dim {
            e.x.resize(dim, 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n\n# comment\n+1 1:1.0\n";
        let ex = read_examples(text.as_bytes(), None).unwrap();
        assert_eq!(ex.len(), 3);
        assert_eq!(ex[0].x, vec![0.5, 0.0, 1.5]);
        assert_eq!(ex[0].y, 1.0);
        assert_eq!(ex[1].x, vec![0.0, 2.0, 0.0]);
        assert_eq!(ex[1].y, -1.0);
    }

    #[test]
    fn zero_label_is_negative() {
        let ex = read_examples("0 1:1\n".as_bytes(), None).unwrap();
        assert_eq!(ex[0].y, -1.0);
    }

    #[test]
    fn force_dim_pads() {
        let ex = read_examples("+1 1:1\n".as_bytes(), Some(5)).unwrap();
        assert_eq!(ex[0].x.len(), 5);
    }

    #[test]
    fn rejects_malformed() {
        assert!(read_examples("+1 nocolon\n".as_bytes(), None).is_err());
        assert!(read_examples("+1 0:1\n".as_bytes(), None).is_err());
        assert!(read_examples("notanumber 1:1\n".as_bytes(), None).is_err());
    }

    #[test]
    fn pair_filter() {
        let text = "8 1:1\n9 2:1\n3 3:1\n8 1:2\n";
        let ex = parse_pair(text.as_bytes(), 8.0, 9.0, None).unwrap();
        assert_eq!(ex.len(), 3);
        assert_eq!(ex[0].y, 1.0);
        assert_eq!(ex[1].y, -1.0);
    }
}
