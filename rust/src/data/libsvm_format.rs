//! LIBSVM sparse-format parser, so real MNIST/IJCNN/w3a files can replace
//! the simulated generators without code changes.
//!
//! Format: one example per line, `label idx:val idx:val ...` with 1-based
//! indices. Labels are mapped to ±1 (`0`/`-1` → −1, anything positive →
//! +1, two-class multi-label files can be filtered with [`parse_pair`]).
//!
//! Examples load as *sparse* [`Example`]s — the dimension is tracked on
//! the [`Dataset`] (and on each `Features::Sparse`), not by densifying
//! rows, so a w3a-like stream at ~4% density trains at O(nnz) per
//! example. Ingestion is strict about two classes of poison:
//!
//! * **Non-finite values** (`nan`, `inf` — which `f32::parse` happily
//!   accepts) are rejected at parse time for both labels and features: a
//!   single NaN distance would otherwise silently corrupt the ball
//!   (`d < r` is false for NaN, so the update path would blend NaN into
//!   `w` forever).
//! * **Out-of-range test indices**: a test-set row with a feature index
//!   beyond the training dimension is rejected with [`Error::Data`]
//!   instead of silently widening the dataset past its declared `dim`
//!   (which used to blow up later inside a `linalg` length assert).

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use super::{chunked, Dataset, Example};
use crate::error::{Error, Result};

/// Parse one LIBSVM line into `(raw_label, sorted sparse pairs)`.
/// Indices are converted to 0-based; duplicate and non-finite entries
/// are rejected.
fn parse_line(line: &str, lineno: usize) -> Result<Option<(f64, Vec<(u32, f32)>)>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut it = line.split_whitespace();
    let label: f64 = it
        .next()
        .unwrap()
        .parse()
        .map_err(|e| Error::data(format!("line {lineno}: bad label ({e})")))?;
    if !label.is_finite() {
        return Err(Error::data(format!("line {lineno}: non-finite label `{label}`")));
    }
    let mut pairs: Vec<(u32, f32)> = Vec::new();
    for tok in it {
        let (i, v) = tok
            .split_once(':')
            .ok_or_else(|| Error::data(format!("line {lineno}: token `{tok}` lacks `:`")))?;
        let idx: u32 = i
            .parse()
            .map_err(|e| Error::data(format!("line {lineno}: bad index ({e})")))?;
        if idx == 0 {
            return Err(Error::data(format!("line {lineno}: LIBSVM indices are 1-based")));
        }
        let val: f32 = v
            .parse()
            .map_err(|e| Error::data(format!("line {lineno}: bad value ({e})")))?;
        if !val.is_finite() {
            return Err(Error::data(format!(
                "line {lineno}: non-finite value `{v}` at index {idx}"
            )));
        }
        pairs.push((idx - 1, val));
    }
    // LIBSVM files are conventionally sorted, but don't rely on it.
    pairs.sort_unstable_by_key(|&(i, _)| i);
    if pairs.windows(2).any(|w| w[0].0 == w[1].0) {
        return Err(Error::data(format!("line {lineno}: duplicate feature index")));
    }
    Ok(Some((label, pairs)))
}

fn to_example(label: f64, pairs: Vec<(u32, f32)>, dim: usize) -> Example {
    let (idx, val): (Vec<u32>, Vec<f32>) = pairs.into_iter().unzip();
    Example::sparse(dim, idx, val, if label > 0.0 { 1.0 } else { -1.0 })
}

/// Read raw `(label, pairs)` rows plus the observed dimension, through
/// the chunked byte-level parser ([`chunked::read_rows`]).
fn read_rows<R: Read>(r: R) -> Result<(Vec<(f64, Vec<(u32, f32)>)>, usize)> {
    chunked::read_rows(r)
}

/// The legacy per-line strict reader, kept as the reference the parity
/// tests compare the chunked path against (identical rows, identical
/// accept/reject decisions on every fixture).
pub fn read_rows_lines<R: Read>(r: R) -> Result<(Vec<(f64, Vec<(u32, f32)>)>, usize)> {
    let reader = BufReader::new(r);
    let mut rows = Vec::new();
    let mut max_dim = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if let Some((label, pairs)) = parse_line(&line, lineno + 1)? {
            if let Some(&(idx, _)) = pairs.last() {
                max_dim = max_dim.max(idx as usize + 1);
            }
            rows.push((label, pairs));
        }
    }
    Ok((rows, max_dim))
}

/// Tolerant chunked read of a training split whose dimension is
/// discovered from the data: malformed or poisoned rows are skipped
/// whole and counted (returned, and bumped unconditionally on
/// [`crate::obs::telemetry::PARSE_SKIPPED`] — the same contract as
/// [`crate::coordinator::stream::FileStream`]), instead of one stray
/// `qid:3` field aborting a multi-gigabyte load.
pub fn read_examples_tolerant<R: Read>(
    r: R,
    force_dim: Option<usize>,
) -> Result<(Vec<Example>, usize)> {
    let mut cr = chunked::ChunkReader::new(r, chunked::DEFAULT_CHUNK_BYTES);
    let mut rows: Vec<(f64, Vec<(u32, f32)>)> = Vec::new();
    let mut max_dim = 0usize;
    let mut skipped = 0usize;
    while let Some(chunk) = cr.next_chunk()? {
        for line in chunked::lines(&chunk) {
            match chunked::parse_raw_tolerant(line) {
                chunked::RawRow::Ok(label, pairs) => {
                    if let Some(&(idx, _)) = pairs.last() {
                        max_dim = max_dim.max(idx as usize + 1);
                    }
                    rows.push((label, pairs));
                }
                chunked::RawRow::Blank => {}
                chunked::RawRow::Bad => {
                    skipped += 1;
                    crate::obs::telemetry::PARSE_SKIPPED.inc();
                }
            }
        }
    }
    let dim = max_dim.max(force_dim.unwrap_or(0));
    Ok((rows.into_iter().map(|(l, p)| to_example(l, p, dim)).collect(), skipped))
}

/// Read all examples from a LIBSVM reader as sparse examples. The
/// logical dimension is the max observed index (or `force_dim` if
/// larger — a floor, matching the old densifying behaviour).
pub fn read_examples<R: Read>(r: R, force_dim: Option<usize>) -> Result<Vec<Example>> {
    let (rows, max_dim) = read_rows(r)?;
    let dim = max_dim.max(force_dim.unwrap_or(0));
    Ok(rows.into_iter().map(|(l, p)| to_example(l, p, dim)).collect())
}

/// Read examples with a *hard* dimension: any row with a feature index
/// `>= dim` is rejected with [`Error::Data`]. This is the test-split
/// loader — test rows must fit the training dimension, not widen it.
pub fn read_examples_strict<R: Read>(r: R, dim: usize) -> Result<Vec<Example>> {
    let (rows, max_dim) = read_rows(r)?;
    if max_dim > dim {
        return Err(Error::data(format!(
            "row has feature index {max_dim} beyond the declared dimension {dim} \
             (test split wider than its training split?)"
        )));
    }
    Ok(rows.into_iter().map(|(l, p)| to_example(l, p, dim)).collect())
}

/// Load a train/test pair of LIBSVM files as a [`Dataset`] of sparse
/// examples. The dataset dimension is `force_dim` (if given) or the
/// max index of the *training* split; test rows beyond it are rejected.
///
/// The *training* split is tolerant, matching [`FileStream`]'s contract
/// (`crate::coordinator::stream`): malformed rows are skipped whole,
/// counted on `pallas_parse_skipped_total`, and warned about — they
/// used to abort the load, which for a large real-world file with one
/// stray `qid` field meant no training at all. The *test* split stays
/// strict: a malformed or out-of-dimension test row silently dropped
/// would change the reported accuracy denominator.
pub fn load_files(
    name: &str,
    train_path: &Path,
    test_path: &Path,
    force_dim: Option<usize>,
) -> Result<Dataset> {
    let (train, skipped) = read_examples_tolerant(std::fs::File::open(train_path)?, force_dim)?;
    if skipped > 0 {
        crate::obs_warn!(
            "data",
            "{name}: skipped {skipped} malformed row(s) in {}",
            train_path.display()
        );
    }
    let dim = train.iter().map(|e| e.dim()).max().unwrap_or(force_dim.unwrap_or(0));
    let test = read_examples_strict(std::fs::File::open(test_path)?, dim)?;
    Ok(Dataset::new(name, dim, train, test))
}

/// For multi-class files: keep labels `a` (→ +1) and `b` (→ −1) only.
/// The dimension is computed over the *kept* rows (plus the `force_dim`
/// floor) — indices that only appear in discarded classes must not
/// widen the pair dataset, or two splits of the same file could load
/// with mismatched dimensions.
pub fn parse_pair<R: Read>(r: R, a: f64, b: f64, force_dim: Option<usize>) -> Result<Vec<Example>> {
    let (rows, _) = read_rows(r)?;
    let rows: Vec<(f64, Vec<(u32, f32)>)> = rows
        .into_iter()
        .filter(|(label, _)| *label == a || *label == b)
        .collect();
    let max_dim = rows
        .iter()
        .filter_map(|(_, pairs)| pairs.last().map(|&(i, _)| i as usize + 1))
        .max()
        .unwrap_or(0);
    let dim = max_dim.max(force_dim.unwrap_or(0));
    Ok(rows
        .into_iter()
        .map(|(label, pairs)| {
            let (idx, val): (Vec<u32>, Vec<f32>) = pairs.into_iter().unzip();
            Example::sparse(dim, idx, val, if label == a { 1.0 } else { -1.0 })
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file_as_sparse() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n\n# comment\n+1 1:1.0\n";
        let ex = read_examples(text.as_bytes(), None).unwrap();
        assert_eq!(ex.len(), 3);
        assert_eq!(ex[0].dim(), 3);
        assert_eq!(ex[0].x.nnz(), 2);
        assert_eq!(ex[0].x.dense().as_ref(), &[0.5, 0.0, 1.5]);
        assert_eq!(ex[0].y, 1.0);
        assert_eq!(ex[1].x.dense().as_ref(), &[0.0, 2.0, 0.0]);
        assert_eq!(ex[1].y, -1.0);
    }

    #[test]
    fn zero_label_is_negative() {
        let ex = read_examples("0 1:1\n".as_bytes(), None).unwrap();
        assert_eq!(ex[0].y, -1.0);
    }

    #[test]
    fn force_dim_is_a_floor() {
        let ex = read_examples("+1 1:1\n".as_bytes(), Some(5)).unwrap();
        assert_eq!(ex[0].dim(), 5);
        // ... and observed indices can still exceed it
        let ex = read_examples("+1 9:1\n".as_bytes(), Some(5)).unwrap();
        assert_eq!(ex[0].dim(), 9);
    }

    #[test]
    fn unsorted_indices_are_sorted() {
        let ex = read_examples("+1 3:3 1:1\n".as_bytes(), None).unwrap();
        assert_eq!(ex[0].x.dense().as_ref(), &[1.0, 0.0, 3.0]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(read_examples("+1 nocolon\n".as_bytes(), None).is_err());
        assert!(read_examples("+1 0:1\n".as_bytes(), None).is_err());
        assert!(read_examples("notanumber 1:1\n".as_bytes(), None).is_err());
        assert!(read_examples("+1 2:1 2:3\n".as_bytes(), None).is_err());
    }

    #[test]
    fn rejects_non_finite_values_and_labels() {
        // f32/f64::parse accept these spellings; ingestion must not
        for bad in ["+1 1:nan\n", "+1 1:inf\n", "+1 1:-inf\n", "+1 1:NaN\n", "nan 1:1\n", "inf 1:1\n"] {
            let err = read_examples(bad.as_bytes(), None).unwrap_err();
            assert!(
                matches!(err, Error::Data(_)),
                "`{}` should be rejected as data error, got {err}",
                bad.trim()
            );
            assert!(err.to_string().contains("non-finite"), "{err}");
        }
        // overflow to inf is also rejected
        assert!(read_examples("+1 1:4e40\n".as_bytes(), None).is_err());
    }

    #[test]
    fn strict_reader_rejects_wide_rows() {
        let err = read_examples_strict("+1 7:1\n".as_bytes(), 4).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("beyond the declared dimension 4"), "{err}");
        let ok = read_examples_strict("+1 4:1\n".as_bytes(), 4).unwrap();
        assert_eq!(ok[0].dim(), 4);
    }

    #[test]
    fn test_split_wider_than_train_is_rejected() {
        // Regression: a test row with an index beyond the train dim used
        // to silently widen the dataset past Dataset::dim, and eval then
        // died on the length assert inside linalg::dot.
        let dir = std::env::temp_dir().join(format!("ssvm_libsvm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (train_p, test_p) = (dir.join("a.train"), dir.join("a.test"));
        std::fs::write(&train_p, "+1 1:1 3:1\n-1 2:1\n").unwrap();
        std::fs::write(&test_p, "+1 10:1\n").unwrap();
        let err = load_files("t", &train_p, &test_p, None).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("beyond the declared dimension"), "{err}");

        // in-range test rows load fine, at the train dimension
        std::fs::write(&test_p, "+1 2:1\n").unwrap();
        let ds = load_files("t", &train_p, &test_p, None).unwrap();
        assert_eq!(ds.dim, 3);
        assert!(ds.train.iter().chain(ds.test.iter()).all(|e| e.dim() == 3));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn chunked_strict_reader_matches_line_reader() {
        // identical rows and identical accept/reject decisions
        let text = "+1 1:0.5 3:1.5\n# c\n\n-1 2:2 4:2.5E1\n+1 3:3 1:1\n0 1:1e-3";
        assert_eq!(read_rows(text.as_bytes()).unwrap(), read_rows_lines(text.as_bytes()).unwrap());
        for bad in [
            "+1 nocolon\n",
            "+1 0:1\n",
            "notanumber 1:1\n",
            "+1 2:1 2:3\n",
            "+1 1:nan\n",
            "nan 1:1\n",
            "+1 1:4e40\n",
        ] {
            assert!(read_rows(bad.as_bytes()).is_err(), "chunked must reject `{}`", bad.trim());
            assert!(read_rows_lines(bad.as_bytes()).is_err(), "legacy must reject `{}`", bad.trim());
        }
    }

    #[test]
    fn tolerant_train_loader_skips_and_counts() {
        let text = "+1 1:0.5\nnot-a-label 1:1\n+1 qid:3 1:0.5\n-1 2:2.0\n";
        let before = crate::obs::telemetry::PARSE_SKIPPED.get();
        let (ex, skipped) = read_examples_tolerant(text.as_bytes(), None).unwrap();
        assert_eq!(ex.len(), 2);
        assert_eq!(skipped, 2);
        assert!(crate::obs::telemetry::PARSE_SKIPPED.get() >= before + 2);
        assert_eq!(ex[0].x.dense().as_ref(), &[0.5, 0.0]);
        assert_eq!(ex[1].x.dense().as_ref(), &[0.0, 2.0]);
        assert_eq!(ex[1].y, -1.0);
    }

    #[test]
    fn load_files_tolerates_bad_train_rows_but_keeps_test_strict() {
        let dir = std::env::temp_dir().join(format!("ssvm_tol_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (train_p, test_p) = (dir.join("b.train"), dir.join("b.test"));
        // one malformed train row: load must succeed without it
        std::fs::write(&train_p, "+1 1:1 3:1\ngarbage row\n-1 2:1\n").unwrap();
        std::fs::write(&test_p, "+1 2:1\n").unwrap();
        let ds = load_files("t", &train_p, &test_p, None).unwrap();
        assert_eq!(ds.train.len(), 2);
        assert_eq!(ds.dim, 3);
        // a malformed *test* row still aborts the load
        std::fs::write(&test_p, "+1 0:1\n").unwrap();
        assert!(load_files("t", &train_p, &test_p, None).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pair_filter() {
        let text = "8 1:1\n9 2:1\n3 3:1\n8 1:2\n";
        let ex = parse_pair(text.as_bytes(), 8.0, 9.0, None).unwrap();
        assert_eq!(ex.len(), 3);
        assert_eq!(ex[0].y, 1.0);
        assert_eq!(ex[1].y, -1.0);
        // the dimension covers kept rows only: the filtered label-3 row's
        // index 3 must not widen the pair dataset
        assert!(ex.iter().all(|e| e.dim() == 2));
    }
}
