//! Chunked byte-level LIBSVM parsing — the zero-allocation ingest path.
//!
//! The per-line loaders (`BufRead::lines` / `read_line`) pay, per row: a
//! `String` allocation, UTF-8 validation, `split_whitespace` iterator
//! setup, and `str::parse` on every token. At millions of rows/sec that
//! bookkeeping dominates the actual number crunching. This module reads
//! the file in fixed-size buffers instead, splits on newline boundaries
//! ([`ChunkReader`] carries the partial last line over to the next
//! fill), and parses label/index/value straight from the bytes — the
//! only per-row heap allocations are the `idx`/`val` vectors that become
//! the [`SparseVec`](super::SparseVec) itself.
//!
//! Number parsing is **bit-exact** with `str::parse`: the common
//! `[+-]digits[.digits]` spelling takes Clinger's fast path (an integer
//! mantissa and a power of ten that are both exactly representable make
//! one IEEE multiply/divide correctly rounded — the same fast path
//! inside the stdlib's own float parser), and everything else
//! (exponents, `inf`/`nan` spellings, huge mantissas) falls back to
//! `str::parse` on the token slice, with zero intermediate copies either
//! way. The parity tests in `rust/tests/parallel_ingest.rs` pin
//! chunked == per-line on every `data/` fixture.
//!
//! Two row-parse entry points mirror the two ingestion philosophies:
//!
//! * [`parse_row_tolerant`] — the [`FileStream`](crate::coordinator::stream::FileStream)
//!   semantics: malformed/poisoned rows are skipped whole (and counted
//!   by the caller + [`crate::obs::telemetry::PARSE_SKIPPED`]),
//!   out-of-range indices are dropped, duplicates dedup. One bad row
//!   must never truncate a long stream.
//! * [`parse_row_strict`] — the [`libsvm_format`](super::libsvm_format)
//!   loader semantics: malformed tokens, 0-based indices, duplicates and
//!   non-finite numbers are hard [`Error::Data`]s naming the line.

use std::io::Read;

use super::Example;
use crate::error::{Error, Result};

/// Default chunk size: large enough that per-chunk overhead (one
/// channel send, one `Vec` allocation) is noise, small enough that a
/// handful in flight keep cache pressure and queue memory bounded.
pub const DEFAULT_CHUNK_BYTES: usize = 256 * 1024;

// ---- chunked reading --------------------------------------------------

/// Reads fixed-size buffers from an underlying `Read` and yields them
/// re-split on newline boundaries: every chunk ends on a `\n` (except
/// possibly the last, if the file lacks a trailing newline), so a chunk
/// can be parsed — or shipped to a worker thread — independently.
pub struct ChunkReader<R: Read> {
    inner: R,
    /// Partial last line of the previous fill, prepended to the next.
    carry: Vec<u8>,
    chunk_bytes: usize,
    bytes_read: u64,
    done: bool,
}

impl<R: Read> ChunkReader<R> {
    pub fn new(inner: R, chunk_bytes: usize) -> Self {
        ChunkReader {
            inner,
            carry: Vec::new(),
            chunk_bytes: chunk_bytes.max(1),
            bytes_read: 0,
            done: false,
        }
    }

    /// Total bytes consumed from the underlying reader so far.
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read
    }

    #[inline]
    fn record(&self, chunk_len: usize) {
        if crate::obs::telemetry::telemetry_on() {
            crate::obs::telemetry::INGEST_CHUNKS.inc();
            crate::obs::telemetry::INGEST_BYTES.add(chunk_len as u64);
        }
    }

    /// The next newline-aligned chunk, `Ok(None)` at EOF. A line longer
    /// than the chunk size is not an error: the buffer grows until its
    /// newline arrives (the chunk size is a target, not a cap).
    pub fn next_chunk(&mut self) -> Result<Option<Vec<u8>>> {
        if self.done {
            return Ok(None);
        }
        let mut buf = std::mem::take(&mut self.carry);
        loop {
            let start = buf.len();
            buf.resize(start + self.chunk_bytes, 0);
            let n = read_full(&mut self.inner, &mut buf[start..])?;
            buf.truncate(start + n);
            self.bytes_read += n as u64;
            if n == 0 {
                self.done = true;
                if buf.is_empty() {
                    return Ok(None);
                }
                self.record(buf.len());
                return Ok(Some(buf));
            }
            // Split after the last newline; carry the partial tail.
            match buf.iter().rposition(|&b| b == b'\n') {
                Some(nl) => {
                    self.carry = buf[nl + 1..].to_vec();
                    buf.truncate(nl + 1);
                    self.record(buf.len());
                    return Ok(Some(buf));
                }
                // No newline in the whole buffer (one very long line):
                // keep filling until one shows up or EOF.
                None => continue,
            }
        }
    }
}

/// `Read::read` until `buf` is full or EOF (plain `read` may return
/// short counts well before EOF, e.g. on pipes).
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => break,
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(filled)
}

/// Iterate the lines of a newline-aligned chunk (without the `\n`).
/// `split` yields one empty tail slice after a trailing `\n`, and empty
/// interior slices are blank rows — both are non-data, so drop empties.
pub fn lines(chunk: &[u8]) -> impl Iterator<Item = &[u8]> {
    chunk.split(|&b| b == b'\n').filter(|l| !l.is_empty())
}

// ---- byte-level number parsing ---------------------------------------

/// ASCII whitespace inside a row (space/tab/CR — `\n` never appears,
/// chunks are split on it).
#[inline]
fn is_space(b: u8) -> bool {
    b == b' ' || b == b'\t' || b == b'\r'
}

#[inline]
fn trim(mut s: &[u8]) -> &[u8] {
    while let [f, rest @ ..] = s {
        if is_space(*f) {
            s = rest;
        } else {
            break;
        }
    }
    while let [rest @ .., l] = s {
        if is_space(*l) {
            s = rest;
        } else {
            break;
        }
    }
    s
}

/// Split the decimal grammar `[+-]? digits [. digits]?` into
/// `(negative, mantissa, frac_len)`; `None` if the token has any other
/// shape (exponents, inf/nan, stray bytes → caller falls back to
/// `str::parse`). The mantissa is capped so it stays exact in u64.
#[inline]
fn split_decimal(s: &[u8]) -> Option<(bool, u64, u32)> {
    let (neg, digits) = match s {
        [b'-', rest @ ..] => (true, rest),
        [b'+', rest @ ..] => (false, rest),
        _ => (false, s),
    };
    if digits.is_empty() {
        return None;
    }
    let mut m: u64 = 0;
    let mut frac_len: u32 = 0;
    let mut seen_dot = false;
    let mut seen_digit = false;
    for &b in digits {
        match b {
            b'0'..=b'9' => {
                seen_digit = true;
                // 19 digits always fit; a 20th could overflow → fallback
                if m >= u64::MAX / 16 {
                    return None;
                }
                m = m * 10 + (b - b'0') as u64;
                if seen_dot {
                    frac_len += 1;
                }
            }
            b'.' if !seen_dot => seen_dot = true,
            _ => return None,
        }
    }
    if !seen_digit {
        return None;
    }
    Some((neg, m, frac_len))
}

/// Parse an f32, bit-exact with `str::parse::<f32>`. Clinger fast path:
/// with `m <= 2^24` and `frac_len <= 10` both `m` and `10^frac_len` are
/// exact in f32, so the single IEEE divide is correctly rounded — the
/// same result the stdlib's correctly-rounding parser produces.
#[inline]
pub fn parse_f32(s: &[u8]) -> Option<f32> {
    const POW10: [f32; 11] = [1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10];
    if let Some((neg, m, frac)) = split_decimal(s) {
        if m <= (1u64 << 24) && frac <= 10 {
            let v = m as f32 / POW10[frac as usize];
            return Some(if neg { -v } else { v });
        }
    }
    std::str::from_utf8(s).ok()?.parse().ok()
}

/// Parse an f64 (labels), bit-exact with `str::parse::<f64>` by the
/// same argument at f64 width (`m <= 2^53`, `10^frac <= 10^22`).
#[inline]
pub fn parse_f64(s: &[u8]) -> Option<f64> {
    if let Some((neg, m, frac)) = split_decimal(s) {
        if m <= (1u64 << 53) && frac <= 22 {
            let v = m as f64 / pow10_f64(frac);
            return Some(if neg { -v } else { v });
        }
    }
    std::str::from_utf8(s).ok()?.parse().ok()
}

#[inline]
fn pow10_f64(e: u32) -> f64 {
    // 10^0..10^22 are all exactly representable in f64.
    const POW10: [f64; 23] = [
        1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10, 1e11, 1e12, 1e13, 1e14, 1e15,
        1e16, 1e17, 1e18, 1e19, 1e20, 1e21, 1e22,
    ];
    POW10[e as usize]
}

/// Parse a u64 index token (`+` prefix allowed, like `str::parse`).
#[inline]
pub fn parse_index(s: &[u8]) -> Option<u64> {
    let digits = match s {
        [b'+', rest @ ..] => rest,
        _ => s,
    };
    if digits.is_empty() {
        return None;
    }
    let mut v: u64 = 0;
    for &b in digits {
        if !b.is_ascii_digit() {
            return None;
        }
        v = v.checked_mul(10)?.checked_add((b - b'0') as u64)?;
    }
    Some(v)
}

/// Iterate whitespace-separated tokens of a line.
#[inline]
fn tokens(line: &[u8]) -> impl Iterator<Item = &[u8]> {
    line.split(|&b| is_space(b)).filter(|t| !t.is_empty())
}

// ---- row parsing ------------------------------------------------------

/// Outcome of a tolerant row parse.
pub enum Row {
    /// A parsed example.
    Ok(Example),
    /// Blank line or `#` comment — not a data row, not a skip.
    Blank,
    /// Malformed or poisoned (non-finite) — skip and count.
    Bad,
}

/// Tolerant byte-level row parse with [`crate::coordinator::stream::FileStream`]
/// semantics: labels map to ±1, out-of-range indices (0 or > `dim`) are
/// dropped, duplicate indices dedup after an unstable sort, any
/// malformed token or non-finite number poisons the whole row to
/// [`Row::Bad`].
pub fn parse_row_tolerant(line: &[u8], dim: usize) -> Row {
    let t = trim(line);
    if t.is_empty() || t[0] == b'#' {
        return Row::Blank;
    }
    let mut it = tokens(t);
    let label = match it.next().and_then(parse_f64) {
        Some(l) if l.is_finite() => l,
        _ => return Row::Bad,
    };
    let mut idx: Vec<u32> = Vec::new();
    let mut val: Vec<f32> = Vec::new();
    let mut sorted = true;
    for tok in it {
        let Some(colon) = tok.iter().position(|&b| b == b':') else {
            return Row::Bad;
        };
        let Some(i) = parse_index(&tok[..colon]) else {
            return Row::Bad;
        };
        if i == 0 || i > dim as u64 {
            continue; // out-of-range: drop the pair, keep the row
        }
        let Some(v) = parse_f32(&tok[colon + 1..]) else {
            return Row::Bad;
        };
        if !v.is_finite() {
            return Row::Bad;
        }
        let i = (i - 1) as u32;
        if let Some(&last) = idx.last() {
            sorted &= last < i;
        }
        idx.push(i);
        val.push(v);
    }
    if !sorted {
        // Rare path (LIBSVM files are conventionally sorted): fold to
        // pairs, sort, dedup — allocation only happens here.
        let mut pairs: Vec<(u32, f32)> = idx.into_iter().zip(val).collect();
        pairs.sort_unstable_by_key(|&(i, _)| i);
        pairs.dedup_by_key(|&mut (i, _)| i);
        let (i2, v2): (Vec<u32>, Vec<f32>) = pairs.into_iter().unzip();
        idx = i2;
        val = v2;
    }
    Row::Ok(Example::sparse(dim, idx, val, if label > 0.0 { 1.0 } else { -1.0 }))
}

/// Outcome of a tolerant *raw* row parse (dimension not yet known).
pub enum RawRow {
    /// `(label, sorted deduped pairs)` — 0-based indices.
    Ok(f64, Vec<(u32, f32)>),
    /// Blank line or `#` comment.
    Blank,
    /// Malformed or poisoned — skip and count.
    Bad,
}

/// Tolerant raw row parse for loaders that discover the dimension from
/// the data ([`super::libsvm_format::load_files`]' training split):
/// there is no index range to enforce yet, but otherwise the semantics
/// are [`parse_row_tolerant`]'s — malformed tokens and non-finite
/// numbers poison the whole row, duplicates dedup after a sort.
pub fn parse_raw_tolerant(line: &[u8]) -> RawRow {
    let t = trim(line);
    if t.is_empty() || t[0] == b'#' {
        return RawRow::Blank;
    }
    let mut it = tokens(t);
    let label = match it.next().and_then(parse_f64) {
        Some(l) if l.is_finite() => l,
        _ => return RawRow::Bad,
    };
    let mut pairs: Vec<(u32, f32)> = Vec::new();
    for tok in it {
        let Some(colon) = tok.iter().position(|&b| b == b':') else {
            return RawRow::Bad;
        };
        let Some(i) = parse_index(&tok[..colon]) else {
            return RawRow::Bad;
        };
        if i == 0 || i > u32::MAX as u64 {
            return RawRow::Bad;
        }
        let Some(v) = parse_f32(&tok[colon + 1..]) else {
            return RawRow::Bad;
        };
        if !v.is_finite() {
            return RawRow::Bad;
        }
        pairs.push((i as u32 - 1, v));
    }
    pairs.sort_unstable_by_key(|&(i, _)| i);
    pairs.dedup_by_key(|&mut (i, _)| i);
    RawRow::Ok(label, pairs)
}

/// Strict byte-level row parse with [`super::libsvm_format`] loader
/// semantics: returns the raw `(label, sorted pairs)` (dimension is
/// resolved by the caller), `Ok(None)` for blanks/comments, and a
/// line-numbered [`Error::Data`] for anything malformed.
pub fn parse_row_strict(line: &[u8], lineno: usize) -> Result<Option<(f64, Vec<(u32, f32)>)>> {
    let t = trim(line);
    if t.is_empty() || t[0] == b'#' {
        return Ok(None);
    }
    let mut it = tokens(t);
    let label_tok = it.next().expect("trimmed non-empty line has a token");
    let label = parse_f64(label_tok).ok_or_else(|| {
        Error::data(format!(
            "line {lineno}: bad label (`{}`)",
            String::from_utf8_lossy(label_tok)
        ))
    })?;
    if !label.is_finite() {
        return Err(Error::data(format!("line {lineno}: non-finite label `{label}`")));
    }
    let mut pairs: Vec<(u32, f32)> = Vec::new();
    for tok in it {
        let colon = tok.iter().position(|&b| b == b':').ok_or_else(|| {
            Error::data(format!(
                "line {lineno}: token `{}` lacks `:`",
                String::from_utf8_lossy(tok)
            ))
        })?;
        let idx = parse_index(&tok[..colon])
            .filter(|&i| i <= u32::MAX as u64)
            .ok_or_else(|| {
                Error::data(format!(
                    "line {lineno}: bad index (`{}`)",
                    String::from_utf8_lossy(&tok[..colon])
                ))
            })?;
        if idx == 0 {
            return Err(Error::data(format!("line {lineno}: LIBSVM indices are 1-based")));
        }
        let v = &tok[colon + 1..];
        let val = parse_f32(v).ok_or_else(|| {
            Error::data(format!(
                "line {lineno}: bad value (`{}`)",
                String::from_utf8_lossy(v)
            ))
        })?;
        if !val.is_finite() {
            return Err(Error::data(format!(
                "line {lineno}: non-finite value `{}` at index {idx}",
                String::from_utf8_lossy(v)
            )));
        }
        pairs.push((idx as u32 - 1, val));
    }
    // LIBSVM files are conventionally sorted, but don't rely on it.
    pairs.sort_unstable_by_key(|&(i, _)| i);
    if pairs.windows(2).any(|w| w[0].0 == w[1].0) {
        return Err(Error::data(format!("line {lineno}: duplicate feature index")));
    }
    Ok(Some((label, pairs)))
}

/// Strict chunked read of every `(label, pairs)` row plus the max
/// observed dimension — the byte-level engine behind the
/// [`super::libsvm_format`] loaders. Line numbers in errors match the
/// per-line readers exactly (blank lines count, the empty slice after a
/// chunk's trailing `\n` does not).
pub fn read_rows<R: Read>(r: R) -> Result<(Vec<(f64, Vec<(u32, f32)>)>, usize)> {
    let mut cr = ChunkReader::new(r, DEFAULT_CHUNK_BYTES);
    let mut rows = Vec::new();
    let mut max_dim = 0usize;
    let mut lineno = 0usize;
    while let Some(chunk) = cr.next_chunk()? {
        let parts: Vec<&[u8]> = chunk.split(|&b| b == b'\n').collect();
        // A chunk ending in '\n' (every chunk but possibly the last)
        // contributes an empty tail slice that is an artifact of the
        // split, not a line.
        let n_lines = parts.len() - usize::from(chunk.last() == Some(&b'\n'));
        for line in &parts[..n_lines] {
            lineno += 1;
            if let Some((label, pairs)) = parse_row_strict(line, lineno)? {
                if let Some(&(idx, _)) = pairs.last() {
                    max_dim = max_dim.max(idx as usize + 1);
                }
                rows.push((label, pairs));
            }
        }
    }
    Ok((rows, max_dim))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn chunk_reader_aligns_on_newlines() {
        let text = "aaaa\nbb\ncccccc\ndd"; // no trailing newline
        for chunk_bytes in 1..=text.len() + 2 {
            let mut cr = ChunkReader::new(text.as_bytes(), chunk_bytes);
            let mut chunks = Vec::new();
            while let Some(c) = cr.next_chunk().unwrap() {
                chunks.push(c);
            }
            // every chunk except the last ends on a newline
            for c in &chunks[..chunks.len() - 1] {
                assert_eq!(*c.last().unwrap(), b'\n', "chunk_bytes={chunk_bytes}");
            }
            // and concatenation reproduces the input exactly
            let cat: Vec<u8> = chunks.concat();
            assert_eq!(cat, text.as_bytes(), "chunk_bytes={chunk_bytes}");
            assert_eq!(cr.bytes_read(), text.len() as u64);
        }
    }

    #[test]
    fn chunk_reader_survives_lines_longer_than_chunk() {
        let long = format!("{}\nshort\n", "x".repeat(10_000));
        let mut cr = ChunkReader::new(long.as_bytes(), 64);
        let mut cat = Vec::new();
        while let Some(c) = cr.next_chunk().unwrap() {
            cat.extend_from_slice(&c);
        }
        assert_eq!(cat, long.as_bytes());
    }

    #[test]
    fn chunk_reader_empty_input() {
        let mut cr = ChunkReader::new(&b""[..], 8);
        assert!(cr.next_chunk().unwrap().is_none());
        assert!(cr.next_chunk().unwrap().is_none());
    }

    #[test]
    fn byte_float_parse_is_bit_exact_with_std() {
        // deterministic random decimal spellings, both widths
        let mut rng = Pcg32::seeded(0xF1_0A7);
        for _ in 0..20_000 {
            let m = rng.below(1_000_000_000) as u64;
            let frac = rng.below(9);
            let neg = rng.below(2) == 1;
            let digits = format!("{m}");
            let s = if frac == 0 || frac >= digits.len() {
                format!("{}{digits}", if neg { "-" } else { "" })
            } else {
                let (a, b) = digits.split_at(digits.len() - frac);
                format!("{}{a}.{b}", if neg { "-" } else { "" })
            };
            assert_eq!(
                parse_f32(s.as_bytes()),
                s.parse::<f32>().ok(),
                "f32 mismatch on `{s}`"
            );
            assert_eq!(
                parse_f64(s.as_bytes()),
                s.parse::<f64>().ok(),
                "f64 mismatch on `{s}`"
            );
        }
        // display-roundtrip spellings (what gen-data writes)
        let mut rng = Pcg32::seeded(0xF2_0A7);
        for _ in 0..20_000 {
            let v = (rng.uniform() * 2.0 - 1.0) as f32;
            let s = format!("{v}");
            assert_eq!(parse_f32(s.as_bytes()), Some(v), "roundtrip `{s}`");
        }
        // fallback spellings: exponents, specials, signs, dots
        for s in [
            "1e-3", "2.5E4", "-1e10", "inf", "-inf", "nan", "NaN", "+0.5", "-0.0", "3.", ".5",
            "4e40", "0.000000059604645", "16777217", "16777216", "9007199254740993",
        ] {
            // bit-compare (NaN != NaN under ==)
            match (parse_f32(s.as_bytes()), s.parse::<f32>().ok()) {
                (Some(a), Some(b)) => assert_eq!(a.to_bits(), b.to_bits(), "f32 `{s}`"),
                (a, b) => assert_eq!(a.is_some(), b.is_some(), "f32 `{s}`"),
            }
            match (parse_f64(s.as_bytes()), s.parse::<f64>().ok()) {
                (Some(a), Some(b)) => assert_eq!(a.to_bits(), b.to_bits(), "f64 `{s}`"),
                (a, b) => assert_eq!(a.is_some(), b.is_some(), "f64 `{s}`"),
            }
        }
        // garbage rejects like std
        for s in ["", "-", "+", ".", "1.2.3", "1,5", "0x10", "a1", "1a"] {
            assert_eq!(parse_f32(s.as_bytes()).is_some(), s.parse::<f32>().is_ok(), "`{s}`");
        }
    }

    #[test]
    fn index_parse_matches_std() {
        for s in ["0", "1", "42", "4294967295", "+7", "18446744073709551615"] {
            assert_eq!(parse_index(s.as_bytes()), s.parse::<u64>().ok(), "`{s}`");
        }
        for s in ["", "-1", "1.5", "a", "18446744073709551616"] {
            assert_eq!(parse_index(s.as_bytes()), s.parse::<u64>().ok(), "`{s}`");
        }
    }

    #[test]
    fn tolerant_row_semantics() {
        // good row
        let Row::Ok(e) = parse_row_tolerant(b"+1 1:0.5 3:1.5", 3) else {
            panic!("good row must parse")
        };
        assert_eq!(e.x.dense().as_ref(), &[0.5, 0.0, 1.5]);
        assert_eq!(e.y, 1.0);
        // blanks and comments
        assert!(matches!(parse_row_tolerant(b"", 3), Row::Blank));
        assert!(matches!(parse_row_tolerant(b"  \t", 3), Row::Blank));
        assert!(matches!(parse_row_tolerant(b"# comment", 3), Row::Blank));
        // out-of-range dropped, row kept
        let Row::Ok(e) = parse_row_tolerant(b"+1 99:1.0 1:2.0", 2) else {
            panic!()
        };
        assert_eq!(e.x.dense().as_ref(), &[2.0, 0.0]);
        // malformed/poisoned → Bad
        for bad in [
            &b"+1 qid:3 1:0.5"[..],
            b"not-a-label 1:1",
            b"+1 1:bad",
            b"+1 1:nan",
            b"nan 1:1",
            b"+1 1:inf",
        ] {
            assert!(matches!(parse_row_tolerant(bad, 3), Row::Bad));
        }
        // unsorted input sorts, duplicates dedup
        let Row::Ok(e) = parse_row_tolerant(b"-1 3:3 1:1 3:9", 3) else {
            panic!()
        };
        assert_eq!(e.x.iter_nonzero().map(|(i, _)| i).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(e.y, -1.0);
    }

    #[test]
    fn strict_row_matches_line_parser_semantics() {
        assert!(parse_row_strict(b"", 1).unwrap().is_none());
        assert!(parse_row_strict(b"# c", 1).unwrap().is_none());
        let (l, p) = parse_row_strict(b"+1 3:3 1:1", 1).unwrap().unwrap();
        assert_eq!(l, 1.0);
        assert_eq!(p, vec![(0, 1.0), (2, 3.0)]);
        for bad in [
            &b"+1 nocolon"[..],
            b"+1 0:1",
            b"notanumber 1:1",
            b"+1 2:1 2:3",
            b"+1 1:nan",
            b"nan 1:1",
            b"+1 1:4e40",
        ] {
            assert!(parse_row_strict(bad, 7).is_err(), "{}", String::from_utf8_lossy(bad));
        }
        // errors carry the line number
        let err = parse_row_strict(b"+1 0:1", 41).unwrap_err();
        assert!(err.to_string().contains("line 41"), "{err}");
    }

    #[test]
    fn chunked_read_rows_spans_chunk_boundaries() {
        // many rows, forced through tiny chunks so rows straddle fills
        let mut text = String::new();
        for i in 1..200u32 {
            text.push_str(&format!("+1 {i}:0.5 {}:1.25\n", i + 1));
        }
        let (rows, max_dim) = read_rows(text.as_bytes()).unwrap();
        assert_eq!(rows.len(), 199);
        assert_eq!(max_dim, 200);
        // and the chunk iterator sees exactly the same rows at any size
        let mut cr = ChunkReader::new(text.as_bytes(), 37);
        let mut n = 0;
        while let Some(c) = cr.next_chunk().unwrap() {
            for line in lines(&c) {
                assert!(matches!(parse_row_tolerant(line, 200), Row::Ok(_)));
                n += 1;
            }
        }
        assert_eq!(n, 199);
    }
}
