//! Simulated w3a-like dataset: 300 binary bag-of-words features,
//! 44,837 train / 4,912 test, ≈3% positives, ~12 active features per row.
//!
//! w3a (web page categorization) is unavailable offline. The Table-1
//! regime: sparse binary high-dim input with severe class skew, where the
//! positive class is identified by a handful of indicator words that also
//! occur (more rarely) in the background. Batch ℓ₂-SVM reaches ~98%,
//! LASVM ~97, while unnormalized single-pass gradient methods (Pegasos
//! k=1: 57.4!) collapse — driven by the skew, which this generator
//! preserves.

use super::{Dataset, Example};
use crate::rng::Pcg32;

const DIM: usize = 300;
const POS_RATE: f64 = 0.03;
/// Words 0..24 are positive indicators.
const N_INDIC: usize = 25;

fn gen_row(rng: &mut Pcg32, y: f32) -> Vec<f32> {
    let mut x = vec![0.0f32; DIM];
    // Background words: Zipf-ish — word w fires with prob ~ 3.5/(w+10),
    // giving ≈12 active words per document in expectation.
    let mut active = 0usize;
    for w in 0..DIM {
        let p = (3.5 / (w as f64 + 10.0)).min(0.30);
        if rng.bernoulli(p) {
            x[w] = 1.0;
            active += 1;
        }
        if active > 24 {
            break;
        }
    }
    if y > 0.0 {
        // Positive docs contain 2–5 indicator words.
        let k = 2 + rng.below(4);
        for _ in 0..k {
            x[rng.below(N_INDIC)] = 1.0;
        }
    } else if rng.bernoulli(0.08) {
        // Indicators appear occasionally in the background too.
        x[rng.below(N_INDIC)] = 1.0;
    }
    x
}

fn gen_split(rng: &mut Pcg32, n: usize) -> Vec<Example> {
    (0..n)
        .map(|_| {
            let y = rng.label(POS_RATE);
            Example::new(gen_row(rng, y), y)
        })
        .collect()
}

/// w3a-like: 44,837 / 4,912, ≈3% positives.
pub fn w3a_like(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 0x3A);
    let train = gen_split(&mut rng, 44_837);
    let test = gen_split(&mut rng, 4_912);
    Dataset::new("w3a", DIM, train, test)
}

/// Reduced-size variant for tests.
pub fn w3a_small(seed: u64, n_train: usize, n_test: usize) -> Dataset {
    let mut rng = Pcg32::new(seed, 0x3A);
    let train = gen_split(&mut rng, n_train);
    let test = gen_split(&mut rng, n_test);
    Dataset::new("w3a_s", DIM, train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_and_sparsity() {
        let ds = w3a_small(1, 10_000, 100);
        let rate = ds.positive_rate();
        assert!((0.02..0.05).contains(&rate), "positive rate {rate}");
        let avg_active: f64 = ds
            .train
            .iter()
            .map(|e| e.x.as_slice().iter().filter(|&&v| v > 0.0).count() as f64)
            .sum::<f64>()
            / ds.train.len() as f64;
        assert!((6.0..20.0).contains(&avg_active), "avg active {avg_active}");
    }

    #[test]
    fn binary_features() {
        let ds = w3a_small(2, 200, 10);
        for e in &ds.train {
            assert!(e.x.as_slice().iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }

    #[test]
    fn indicators_discriminate() {
        let ds = w3a_small(3, 20_000, 10);
        let mass = |y: f32| -> f64 {
            let sel: Vec<_> = ds.train.iter().filter(|e| e.y == y).collect();
            sel.iter()
                .map(|e| e.x.as_slice()[..N_INDIC].iter().sum::<f32>() as f64)
                .sum::<f64>()
                / sel.len() as f64
        };
        assert!(mass(1.0) > mass(-1.0) + 1.0);
    }
}
