//! Name-based dataset registry shared by the CLI, examples and benches.
//!
//! `load_dataset("mnist89", seed)` returns the Table-1 sized dataset;
//! `load_dataset_sized` scales the split down for fast tests. Real LIBSVM
//! files can be injected with `file:<name>:<train>:<test>` specs.

use std::path::Path;

use super::{
    ijcnn_like, libsvm_format, mnist_like, synthetic, w3a_like, waveform, Dataset,
};
use crate::error::{Error, Result};

/// All built-in Table-1 dataset names, in the paper's row order.
pub const TABLE1_NAMES: [&str; 8] = [
    "synthA", "synthB", "synthC", "waveform", "mnist01", "mnist89", "ijcnn", "w3a",
];

/// Load a dataset by registry name at the paper's full size.
pub fn load_dataset(name: &str, seed: u64) -> Result<Dataset> {
    if let Some(rest) = name.strip_prefix("file:") {
        let parts: Vec<&str> = rest.split(':').collect();
        if parts.len() != 3 {
            return Err(Error::config(format!(
                "file spec must be file:<name>:<train>:<test>, got `{name}`"
            )));
        }
        return libsvm_format::load_files(parts[0], Path::new(parts[1]), Path::new(parts[2]), None);
    }
    match name {
        "synthA" => Ok(synthetic::synth_a(seed)),
        "synthB" => Ok(synthetic::synth_b(seed)),
        "synthC" => Ok(synthetic::synth_c(seed)),
        "waveform" => Ok(waveform::waveform(seed)),
        "mnist01" => Ok(mnist_like::mnist01(seed)),
        "mnist89" => Ok(mnist_like::mnist89(seed)),
        "ijcnn" => Ok(ijcnn_like::ijcnn_like(seed)),
        "w3a" => Ok(w3a_like::w3a_like(seed)),
        other => Err(Error::data(format!("unknown dataset `{other}`"))),
    }
}

/// Load a size-reduced variant (for tests and smoke runs): `frac` scales
/// the train split, test capped at 1000.
pub fn load_dataset_sized(name: &str, seed: u64, frac: f64) -> Result<Dataset> {
    let mut ds = load_dataset(name, seed)?;
    let n_train = ((ds.train.len() as f64 * frac) as usize).max(16);
    ds.train.truncate(n_train);
    ds.test.truncate(1000);
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_resolve_small() {
        for name in TABLE1_NAMES {
            let ds = load_dataset_sized(name, 7, 0.01).unwrap();
            assert!(!ds.train.is_empty(), "{name}");
            assert!(!ds.test.is_empty(), "{name}");
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(load_dataset("nope", 1).is_err());
        assert!(load_dataset("file:bad", 1).is_err());
    }
}
