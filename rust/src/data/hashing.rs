//! Signed feature hashing (Weinberger et al., "Feature Hashing for
//! Large Scale Multitask Learning") — the front-end that folds an
//! unbounded-vocabulary sparse stream into a fixed dimension `D`, so the
//! single-pass MEB center stays constant-size as the paper's streaming
//! model demands.
//!
//! Each input index `i` maps to a bucket `h(i) ∈ [0, D)` and a sign
//! `σ(i) ∈ {±1}`; the hashed vector accumulates `σ(i)·v` into bucket
//! `h(i)`. Both functions derive from one seeded 64-bit mix (splitmix64
//! over pure integer arithmetic), so the mapping is deterministic across
//! platforms and reproducible from `(seed, D)` alone — which is why the
//! `.meb` codec records exactly that pair in provenance and refuses to
//! resume or merge across mismatched hash spaces.

use super::{Dataset, Example, Features, SparseVec};
use crate::svm::HashSpec;

/// splitmix64 finalizer (Steele et al.) — full-avalanche integer mix.
#[inline]
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A seeded signed feature hasher: `h: u32 → [0, D)`, `σ: u32 → ±1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FeatureHasher {
    dim: usize,
    seed: u64,
}

impl FeatureHasher {
    pub fn new(dim: usize, seed: u64) -> Self {
        assert!(dim >= 1, "hash dimension must be >= 1");
        FeatureHasher { dim, seed }
    }

    /// Build from the spec the `.meb` codec stores in provenance.
    pub fn from_spec(spec: HashSpec) -> Self {
        Self::new(spec.dim, spec.seed)
    }

    /// The spec this hasher realizes.
    pub fn spec(&self) -> HashSpec {
        HashSpec { dim: self.dim, seed: self.seed }
    }

    /// Output dimension `D`.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// `(h(i), σ(i))` for one input index. The bucket comes from the low
    /// bits of the mix (via modulo), the sign from the top bit, so the
    /// two are effectively independent.
    #[inline]
    pub fn bucket(&self, i: u32) -> (u32, f32) {
        let m = splitmix64(self.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let b = (m % self.dim as u64) as u32;
        let s = if m >> 63 == 1 { -1.0 } else { 1.0 };
        (b, s)
    }

    /// Hash a stream of `(index, value)` coordinates (indices may be
    /// *arbitrary* u32 — this is the point: wire payloads and
    /// unbounded-vocabulary streams need no range check) into a sparse
    /// dim-`D` vector. Colliding buckets accumulate; output indices are
    /// strictly increasing.
    fn hash_iter(&self, coords: impl Iterator<Item = (u32, f32)>) -> Features {
        let mut pairs: Vec<(u32, f32)> = coords
            .map(|(i, v)| {
                let (b, s) = self.bucket(i);
                (b, s * v)
            })
            .collect();
        // Stable sort: colliding buckets accumulate in input order, so
        // the float sum is bit-reproducible across platforms/releases.
        pairs.sort_by_key(|&(b, _)| b);
        let mut out_idx: Vec<u32> = Vec::with_capacity(pairs.len());
        let mut out_val: Vec<f32> = Vec::with_capacity(pairs.len());
        for (b, v) in pairs {
            match out_idx.last() {
                Some(&last) if last == b => *out_val.last_mut().unwrap() += v,
                _ => {
                    out_idx.push(b);
                    out_val.push(v);
                }
            }
        }
        Features::Sparse { dim: self.dim, v: SparseVec { idx: out_idx, val: out_val } }
    }

    /// [`Self::hash_iter`] over parallel `idx`/`val` arrays (the wire
    /// payload shape).
    pub fn hash_pairs(&self, idx: &[u32], val: &[f32]) -> Features {
        assert_eq!(idx.len(), val.len(), "idx/val length mismatch");
        self.hash_iter(idx.iter().zip(val).map(|(&i, &v)| (i, v)))
    }

    /// Hash any feature vector (dense or sparse) into the dim-`D` space.
    pub fn hash_features(&self, x: &Features) -> Features {
        self.hash_iter(x.iter_nonzero().map(|(i, v)| (i as u32, v)))
    }

    /// Hash one labeled example.
    pub fn hash_example(&self, e: &Example) -> Example {
        Example { x: self.hash_features(&e.x), y: e.y }
    }

    /// Hash a whole dataset (both splits) into the dim-`D` space — the
    /// CLI front-end for training and evaluating in one hash space.
    pub fn hash_dataset(&self, ds: &Dataset) -> Dataset {
        Dataset {
            name: ds.name.clone(),
            dim: self.dim,
            train: ds.train.iter().map(|e| self.hash_example(e)).collect(),
            test: ds.test.iter().map(|e| self.hash_example(e)).collect(),
        }
    }
}

/// Adapter that hashes every example of an inner stream on the fly —
/// wraps any `Iterator<Item = Example>` (VecStream, FileStream, ...) so
/// the pipeline consumes a fixed-dimension stream without materializing
/// the hashed dataset.
pub struct HashedStream<S> {
    inner: S,
    hasher: FeatureHasher,
}

impl<S: Iterator<Item = Example>> HashedStream<S> {
    pub fn new(inner: S, hasher: FeatureHasher) -> Self {
        HashedStream { inner, hasher }
    }
}

impl<S: Iterator<Item = Example>> Iterator for HashedStream<S> {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        self.inner.next().map(|e| self.hasher.hash_example(&e))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.inner.size_hint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_in_range_and_signed() {
        let h = FeatureHasher::new(64, 7);
        for i in 0..10_000u32 {
            let (b, s) = h.bucket(i);
            assert!((b as usize) < 64);
            assert!(s == 1.0 || s == -1.0);
        }
    }

    #[test]
    fn signs_are_roughly_balanced() {
        let h = FeatureHasher::new(1 << 20, 42);
        let neg = (0..20_000u32).filter(|&i| h.bucket(i).1 < 0.0).count();
        assert!((8_000..12_000).contains(&neg), "neg = {neg}");
    }

    #[test]
    fn deterministic_across_instances_and_seed_sensitive() {
        let a = FeatureHasher::new(4096, 1);
        let b = FeatureHasher::new(4096, 1);
        let c = FeatureHasher::new(4096, 2);
        let idx: Vec<u32> = (0..50).map(|i| i * 977).collect();
        let val: Vec<f32> = (0..50).map(|i| i as f32 + 0.5).collect();
        assert_eq!(a.hash_pairs(&idx, &val), b.hash_pairs(&idx, &val));
        assert_ne!(a.hash_pairs(&idx, &val), c.hash_pairs(&idx, &val));
    }

    #[test]
    fn collisions_accumulate_and_indices_sorted() {
        // D = 1: everything lands in bucket 0 with signs ±1.
        let h = FeatureHasher::new(1, 3);
        let hashed = h.hash_pairs(&[5, 9, 1000], &[1.0, 2.0, 4.0]);
        assert_eq!(hashed.len(), 1);
        assert_eq!(hashed.nnz(), 1);
        let expect: f32 = [5u32, 9, 1000]
            .iter()
            .zip([1.0f32, 2.0, 4.0])
            .map(|(&i, v)| h.bucket(i).1 * v)
            .sum();
        assert_eq!(hashed.get(0), expect);
        // general case: strictly increasing output indices
        let h = FeatureHasher::new(32, 3);
        let idx: Vec<u32> = (0..200).collect();
        let val = vec![1.0f32; 200];
        if let Features::Sparse { v, .. } = h.hash_pairs(&idx, &val) {
            assert!(v.idx.windows(2).all(|w| w[0] < w[1]));
        } else {
            panic!("hashed output must be sparse");
        }
    }

    #[test]
    fn dense_and_sparse_inputs_hash_identically() {
        let h = FeatureHasher::new(16, 9);
        let dense = Features::Dense(vec![0.0, 1.5, 0.0, -2.0, 0.25]);
        let sparse = dense.to_sparse();
        assert_eq!(h.hash_features(&dense), h.hash_features(&sparse));
    }

    #[test]
    fn hashed_stream_maps_examples() {
        let h = FeatureHasher::new(8, 11);
        let exs = vec![
            Example::sparse(100, vec![3, 97], vec![1.0, -1.0], 1.0),
            Example::new(vec![0.0; 100], -1.0),
        ];
        let out: Vec<Example> = HashedStream::new(exs.clone().into_iter(), h).collect();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].dim(), 8);
        assert_eq!(out[0], h.hash_example(&exs[0]));
        assert_eq!(out[1].y, -1.0);
        assert_eq!(out[1].x.nnz(), 0);
    }

    #[test]
    fn hash_dataset_rewrites_both_splits() {
        let h = FeatureHasher::new(4, 5);
        let ds = Dataset::new(
            "t",
            10,
            vec![Example::sparse(10, vec![9], vec![2.0], 1.0)],
            vec![Example::sparse(10, vec![0], vec![1.0], -1.0)],
        );
        let hd = h.hash_dataset(&ds);
        assert_eq!(hd.dim, 4);
        assert_eq!(hd.train[0].dim(), 4);
        assert_eq!(hd.test[0].dim(), 4);
        assert_eq!(hd.name, "t");
    }
}
