//! The CART Waveform generator (Breiman et al. 1984), binarized.
//!
//! Waveform is itself a synthetic benchmark: 21 attributes, three classes,
//! each class a random convex combination `u·h_a + (1-u)·h_b` of two of
//! three triangular base waves, plus N(0,1) noise per attribute. The paper
//! uses a binary version with 4000 train / 1000 test; we binarize as
//! class 1 vs class 2 (the classic two-of-three-waves split), which lands
//! linear batch accuracy in the high 80s — the paper's regime.

use super::{Dataset, Example};
use crate::rng::Pcg32;

const DIM: usize = 21;

/// Triangular base wave `h(i) = max(6 - |i - c|, 0)` for i in 1..=21.
fn base_wave(center: f64) -> [f64; DIM] {
    let mut h = [0.0; DIM];
    for (i, v) in h.iter_mut().enumerate() {
        let t = 6.0 - ((i + 1) as f64 - center).abs();
        *v = t.max(0.0);
    }
    h
}

/// One waveform example for 3-class waveform: class in {0,1,2}.
fn wave_example(rng: &mut Pcg32, class: usize) -> Vec<f32> {
    let h1 = base_wave(7.0);
    let h2 = base_wave(15.0);
    let h3 = base_wave(11.0);
    let (a, b) = match class {
        0 => (&h1, &h2),
        1 => (&h1, &h3),
        _ => (&h2, &h3),
    };
    let u = rng.uniform();
    (0..DIM)
        .map(|i| (u * a[i] + (1.0 - u) * b[i] + rng.normal()) as f32)
        .collect()
}

/// Binary waveform: class 1 (+1) vs class 2 (−1); 4000 train, 1000 test.
pub fn waveform(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 0x3A7E);
    let gen = |n: usize, rng: &mut Pcg32| {
        (0..n)
            .map(|_| {
                let y = rng.label(0.5);
                let class = if y > 0.0 { 1 } else { 2 };
                Example::new(wave_example(rng, class), y)
            })
            .collect::<Vec<_>>()
    };
    let train = gen(4000, &mut rng);
    let test = gen(1000, &mut rng);
    Dataset::new("waveform", DIM, train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let ds = waveform(3);
        assert_eq!(ds.dim, 21);
        assert_eq!(ds.train.len(), 4000);
        assert_eq!(ds.test.len(), 1000);
        assert!((ds.positive_rate() - 0.5).abs() < 0.05);
    }

    #[test]
    fn base_waves_are_triangles() {
        let h1 = base_wave(7.0);
        assert_eq!(h1[6], 6.0); // peak at attribute 7 (index 6)
        assert_eq!(h1[0], 0.0);
        assert_eq!(h1[20], 0.0);
        let h3 = base_wave(11.0);
        assert_eq!(h3[10], 6.0);
    }

    #[test]
    fn classes_differ_in_mean_profile() {
        let ds = waveform(5);
        let mean_of = |y: f32| -> Vec<f64> {
            let sel: Vec<_> = ds.train.iter().filter(|e| e.y == y).collect();
            let mut m = vec![0.0; DIM];
            for e in &sel {
                for (mi, &xi) in m.iter_mut().zip(e.x.as_slice().iter()) {
                    *mi += xi as f64;
                }
            }
            m.iter().map(|v| v / sel.len() as f64).collect()
        };
        let mp = mean_of(1.0);
        let mn = mean_of(-1.0);
        // class 1 mixes h1+h3 (mass at attr 7), class 2 mixes h2+h3
        // (mass at attr 15): the profiles must differ at the poles.
        assert!(mp[6] > mn[6] + 1.0, "attr7: {} vs {}", mp[6], mn[6]);
        assert!(mn[14] > mp[14] + 1.0, "attr15: {} vs {}", mn[14], mp[14]);
    }
}
