//! Synthetic A / B / C: the paper's own generators.
//!
//! Table 1 describes them as "normally distributed clusters ... of about
//! 85% separability" with dims 2 / 3 / 5 and 20,000 train / 200 test.
//! The three reported accuracy spreads differ sharply (A: everything
//! ≈96%, B: everything ≈66%, C: batch 93 but single-pass baselines
//! 55–77), so we tune the three constructions to land in those regimes:
//!
//! * **A (2-d)** — two well-separated isotropic Gaussians: easy for every
//!   method.
//! * **B (3-d)** — heavily overlapping Gaussians: Bayes-limited around
//!   two-thirds accuracy for every method.
//! * **C (5-d)** — separable mean shift confined to one direction, with
//!   large-variance distractor directions and a small label flip: linear
//!   batch solvers reach the low 90s, while aggressive single-pass
//!   updates get dragged by the distractor variance.

use super::{Dataset, Example};
use crate::rng::Pcg32;

fn gaussian_pair(
    rng: &mut Pcg32,
    n: usize,
    mean: &[f64],
    sds: &[f64],
    flip: f64,
) -> Vec<Example> {
    let d = mean.len();
    (0..n)
        .map(|_| {
            let mut y = rng.label(0.5);
            let x: Vec<f32> = (0..d)
                .map(|j| (rng.normal() * sds[j] + y as f64 * mean[j]) as f32)
                .collect();
            if rng.bernoulli(flip) {
                y = -y;
            }
            Example::new(x, y)
        })
        .collect()
}

/// Synthetic A: 2-d, 20k/200, ≈96% linearly attainable.
pub fn synth_a(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 0xA);
    let mean = [1.25, 1.25];
    let sds = [1.0, 1.0];
    let train = gaussian_pair(&mut rng, 20_000, &mean, &sds, 0.0);
    let test = gaussian_pair(&mut rng, 200, &mean, &sds, 0.0);
    Dataset::new("synthA", 2, train, test)
}

/// Synthetic B: 3-d, 20k/200, Bayes-limited ≈66%.
pub fn synth_b(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 0xB);
    let mean = [0.25, 0.25, 0.25];
    let sds = [1.0, 1.0, 1.0];
    let train = gaussian_pair(&mut rng, 20_000, &mean, &sds, 0.0);
    let test = gaussian_pair(&mut rng, 200, &mean, &sds, 0.0);
    Dataset::new("synthB", 3, train, test)
}

/// Synthetic C: 5-d, 20k/200 — separable along one axis, noisy elsewhere.
pub fn synth_c(seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 0xC);
    let mean = [1.6, 0.0, 0.0, 0.0, 0.0];
    let sds = [1.0, 2.4, 2.4, 2.4, 2.4];
    let train = gaussian_pair(&mut rng, 20_000, &mean, &sds, 0.03);
    let test = gaussian_pair(&mut rng, 200, &mean, &sds, 0.03);
    Dataset::new("synthC", 5, train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_balance() {
        for (ds, d) in [(synth_a(1), 2), (synth_b(1), 3), (synth_c(1), 5)] {
            assert_eq!(ds.train.len(), 20_000);
            assert_eq!(ds.test.len(), 200);
            assert_eq!(ds.dim, d);
            let rate = ds.positive_rate();
            assert!((rate - 0.5).abs() < 0.03, "{}: rate={rate}", ds.name);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a1 = synth_a(9);
        let a2 = synth_a(9);
        assert_eq!(a1.train[17], a2.train[17]);
        let a3 = synth_a(10);
        assert_ne!(a1.train[17], a3.train[17]);
    }

    #[test]
    fn a_is_easier_than_b() {
        // The oracle direction (all-ones mean) classifies A far better
        // than B — the regimes of Table 1 depend on this gap.
        let acc = |ds: &Dataset, mean: &[f64]| {
            let ok = ds
                .test
                .iter()
                .filter(|e| {
                    let s: f64 = e.x.as_slice().iter().zip(mean).map(|(&xi, &m)| xi as f64 * m).sum();
                    (s > 0.0) == (e.y > 0.0)
                })
                .count();
            ok as f64 / ds.test.len() as f64
        };
        let a = synth_a(2);
        let b = synth_b(2);
        assert!(acc(&a, &[1.0, 1.0]) > 0.92);
        let accb = acc(&b, &[1.0, 1.0, 1.0]);
        assert!(accb > 0.55 && accb < 0.78, "b oracle acc={accb}");
    }
}
