//! Minimal CLI argument parsing (`--key value` and `--key=value`),
//! shared by `main.rs` and unit-tested here (no clap in the offline
//! image).

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed command line: a subcommand plus `--key value` pairs.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub cmd: String,
    kv: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn from_iter<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut it = args.into_iter();
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut kv = HashMap::new();
        while let Some(k) = it.next() {
            let body = k
                .strip_prefix("--")
                .ok_or_else(|| Error::config(format!("expected --flag, got `{k}`")))?;
            let (key, v) = match body.split_once('=') {
                // --key=value (value may be empty: `--tag=`)
                Some((key, v)) => (key.to_string(), v.to_string()),
                // --key value
                None => {
                    let v = it
                        .next()
                        .ok_or_else(|| Error::config(format!("--{body} needs a value")))?;
                    (body.to_string(), v)
                }
            };
            if key.is_empty() {
                return Err(Error::config(format!("empty flag name in `{k}`")));
            }
            kv.insert(key, v);
        }
        Ok(Args { cmd, kv })
    }

    /// Parse the process arguments.
    pub fn parse() -> Result<Self> {
        Self::from_iter(std::env::args().skip(1))
    }

    /// Typed lookup with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.kv.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("bad value for --{key}: `{v}`"))),
        }
    }

    /// String lookup with default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.into())
    }

    /// Was the flag given explicitly?
    pub fn has(&self, key: &str) -> bool {
        self.kv.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Result<Args> {
        Args::from_iter(parts.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_subcommand_and_pairs() {
        let a = parse(&["train", "--dataset", "mnist89", "--c", "0.5"]).unwrap();
        assert_eq!(a.cmd, "train");
        assert_eq!(a.str("dataset", "x"), "mnist89");
        assert_eq!(a.get("c", 1.0).unwrap(), 0.5);
        assert_eq!(a.get("lookahead", 7usize).unwrap(), 7);
        assert!(a.has("c") && !a.has("lookahead"));
    }

    #[test]
    fn empty_is_help() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.cmd, "help");
    }

    #[test]
    fn rejects_bare_token() {
        assert!(parse(&["train", "dataset"]).is_err());
    }

    #[test]
    fn rejects_missing_value() {
        assert!(parse(&["train", "--dataset"]).is_err());
    }

    #[test]
    fn rejects_bad_typed_value() {
        let a = parse(&["train", "--c", "abc"]).unwrap();
        assert!(a.get("c", 1.0).is_err());
    }

    #[test]
    fn parses_equals_form() {
        let a = parse(&["train", "--dataset=mnist89", "--c=0.5"]).unwrap();
        assert_eq!(a.cmd, "train");
        assert_eq!(a.str("dataset", "x"), "mnist89");
        assert_eq!(a.get("c", 1.0).unwrap(), 0.5);
    }

    #[test]
    fn mixes_equals_and_space_forms() {
        let a = parse(&["merge", "--inputs=a.meb,b.meb", "--out", "m.meb", "--frac=0.25"]).unwrap();
        assert_eq!(a.str("inputs", ""), "a.meb,b.meb");
        assert_eq!(a.str("out", ""), "m.meb");
        assert_eq!(a.get("frac", 1.0).unwrap(), 0.25);
    }

    #[test]
    fn equals_value_may_be_empty_and_may_contain_equals() {
        let a = parse(&["train", "--tag=", "--spec=k=v"]).unwrap();
        assert_eq!(a.str("tag", "default"), "");
        assert!(a.has("tag"));
        // only the first '=' splits
        assert_eq!(a.str("spec", ""), "k=v");
    }

    #[test]
    fn parses_variant_flag() {
        use crate::svm::learner::Variant;
        let a = parse(&["serve", "--variant", "kernelized"]).unwrap();
        assert_eq!(a.get("variant", Variant::Ball).unwrap(), Variant::Kernelized);
        // default when absent
        let a = parse(&["serve"]).unwrap();
        assert_eq!(a.get("variant", Variant::Ball).unwrap(), Variant::Ball);
        // every canonical name round-trips through FromStr
        for v in Variant::ALL {
            let a = parse(&["train", &format!("--variant={}", v.name())]).unwrap();
            assert_eq!(a.get("variant", Variant::Ball).unwrap(), v);
        }
        // unknown names surface as a config error naming the flag
        let a = parse(&["train", "--variant", "quantum"]).unwrap();
        let err = a.get("variant", Variant::Ball).unwrap_err();
        assert!(err.to_string().contains("--variant"), "{err}");
    }

    #[test]
    fn equals_form_error_paths() {
        // empty flag name
        assert!(parse(&["train", "--=5"]).is_err());
        // bare `--` still needs a value for its (empty) key → rejected
        assert!(parse(&["train", "--"]).is_err());
        // equals form never consumes the next token
        let a = parse(&["train", "--c=1", "orphan"]);
        assert!(a.is_err(), "bare token after --k=v must still be rejected");
        // typed parse failure on equals form
        let a = parse(&["train", "--c=abc"]).unwrap();
        assert!(a.get("c", 1.0).is_err());
    }
}
