//! Minimal `--key value` CLI argument parsing, shared by `main.rs` and
//! unit-tested here (no clap in the offline image).

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed command line: a subcommand plus `--key value` pairs.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub cmd: String,
    kv: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn from_iter<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut it = args.into_iter();
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut kv = HashMap::new();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .ok_or_else(|| Error::config(format!("expected --flag, got `{k}`")))?
                .to_string();
            let v = it
                .next()
                .ok_or_else(|| Error::config(format!("--{key} needs a value")))?;
            kv.insert(key, v);
        }
        Ok(Args { cmd, kv })
    }

    /// Parse the process arguments.
    pub fn parse() -> Result<Self> {
        Self::from_iter(std::env::args().skip(1))
    }

    /// Typed lookup with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.kv.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("bad value for --{key}: `{v}`"))),
        }
    }

    /// String lookup with default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.into())
    }

    /// Was the flag given explicitly?
    pub fn has(&self, key: &str) -> bool {
        self.kv.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Result<Args> {
        Args::from_iter(parts.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_subcommand_and_pairs() {
        let a = parse(&["train", "--dataset", "mnist89", "--c", "0.5"]).unwrap();
        assert_eq!(a.cmd, "train");
        assert_eq!(a.str("dataset", "x"), "mnist89");
        assert_eq!(a.get("c", 1.0).unwrap(), 0.5);
        assert_eq!(a.get("lookahead", 7usize).unwrap(), 7);
        assert!(a.has("c") && !a.has("lookahead"));
    }

    #[test]
    fn empty_is_help() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.cmd, "help");
    }

    #[test]
    fn rejects_bare_token() {
        assert!(parse(&["train", "dataset"]).is_err());
    }

    #[test]
    fn rejects_missing_value() {
        assert!(parse(&["train", "--dataset"]).is_err());
    }

    #[test]
    fn rejects_bad_typed_value() {
        let a = parse(&["train", "--c", "abc"]).unwrap();
        assert!(a.get("c", 1.0).is_err());
    }
}
