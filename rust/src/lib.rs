//! # StreamSVM — one-pass streaming ℓ₂-SVMs via minimum enclosing balls
//!
//! A production-shaped reproduction of *Rai, Daumé III, Venkatasubramanian:
//! "Streamed Learning: One-Pass SVMs", IJCAI 2009*, built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time)** — the distance / Gram / predict hot-spots
//!   are Pallas kernels embedded in JAX graphs, AOT-lowered to HLO text
//!   (`python/compile/`, `make artifacts`).
//! * **Layer 3 (this crate)** — the streaming coordinator: stream sources,
//!   shape-bucketed batching with backpressure, a block-filter training
//!   pipeline, a batched prediction service, all the paper's algorithms
//!   (Algorithm 1, Algorithm 2 with lookahead, kernelized, multiball) as
//!   pure-Rust reference implementations, every baseline from the
//!   evaluation (Perceptron, Pegasos, LASVM, CVM, batch ℓ₂-SVM), the
//!   dataset substrates, and the experiment harnesses for Table 1 and
//!   Figures 2–4.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary loads the HLO artifacts via PJRT (`xla` crate, behind the
//! `pjrt` feature) and is self-contained. Without the feature (the
//! offline default) every PJRT call site falls back to the pure-Rust
//! reference paths.
//!
//! The **serving layer** ([`server`]) puts the learner behind a network:
//! a dependency-free HTTP/1.1 server (`std::net` only) with `/predict`,
//! `/predict_batch`, `/train`, `/snapshot` and `/stats` endpoints. A
//! background trainer consumes `/train` traffic one-pass style and
//! republishes an immutable model snapshot every k examples through a
//! hot-swap cell, so requests never observe a torn model; bounded
//! admission queues shed overload with explicit 429s; and a built-in
//! load generator ([`server::loadgen`]) measures throughput, latency
//! quantiles and shed rate into `BENCH_serve.json`.
//!
//! The **sparse path**: features live behind [`data::Features`] (dense
//! `Vec<f32>` or `idx`/`val` pairs) with a borrowed [`data::FeaturesView`]
//! consumed by the hot paths. The ball center is stored lazily scaled
//! (`w = σ·v` with a cached `‖w‖²`), so the per-example distance test and
//! the Algorithm-1 update both cost O(nnz) instead of O(D) — LIBSVM
//! streams (w3a is ~4% dense) never densify, and the server accepts
//! sparse `{"idx":[...],"val":[...]}` payloads. Algorithm 2 buffers
//! survivors in their arriving representation and solves the merge Gram
//! with merge-join sparse dots (O(L²·nnz)), and a seeded signed feature
//! hasher ([`data::hashing`]) folds unbounded-vocabulary streams into a
//! fixed dimension `D` — on the CLI (`--hash-dim`), in the pipeline (a
//! [`data::hashing::HashedStream`] adapter), and on the server's ingest
//! path, with the `(seed, D)` pair recorded in `.meb` provenance so
//! resume/merge refuse mismatched hash spaces.
//!
//! The **sketch layer** ([`sketch`]) turns the tiny ball state into
//! durable, composable model files: [`sketch::MebSketch`] is a
//! versioned, checksummed binary encoding of ball + stream provenance;
//! [`sketch::merge_sketches`] folds N shard sketches through an
//! order-robust merge-and-reduce tree (the sharded coordinator trains
//! through it); [`sketch::Checkpointer`] gives the pipeline periodic
//! snapshots with *exact* resume — a run interrupted at example `k` and
//! resumed from its sketch finishes with bit-identical weights. The CLI
//! exposes all of it as `snapshot`, `resume` and `merge` subcommands.
//!
//! The **observability layer** ([`obs`]) gives the running system eyes
//! with zero dependencies: a lock-cheap leveled tracing core
//! (`PALLAS_LOG`-filtered stderr + a bounded in-process ring served by
//! `GET /trace`), training-dynamics telemetry from every variant and the
//! sketch layer (radius/‖w‖ trajectory, per-window violation rate,
//! merge cadence, core-set size — all behind a one-atomic-load disabled
//! fast path), Prometheus text exposition on `GET /metrics`, and a
//! `train --trace-out` JSONL stream for offline plotting.
//!
//! Quickstart (see also `examples/quickstart.rs`):
//!
//! ```no_run
//! use streamsvm::data::registry::load_dataset;
//! use streamsvm::svm::streamsvm::StreamSvm;
//! use streamsvm::svm::TrainOptions;
//! use streamsvm::eval::accuracy;
//!
//! let ds = load_dataset("synthA", 42).unwrap();
//! let opts = TrainOptions::default();
//! let model = StreamSvm::fit(ds.train.iter(), ds.dim, &opts);
//! println!("test acc = {:.3}", accuracy(&model, &ds.test));
//! ```

pub mod baselines;
pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod exp;
pub mod fuzz;
pub mod linalg;
pub mod obs;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod sketch;
pub mod svm;

pub use error::{Error, Result};
