//! Every baseline from the paper's evaluation (§5), implemented from
//! scratch:
//!
//! * [`perceptron`] — Rosenblatt's perceptron, single pass.
//! * [`pegasos`] — single-sweep Pegasos with block size `k` (paper runs
//!   k = 1 and k = 20).
//! * [`lasvm`] — LASVM-style online dual SVM with active revisits
//!   (linear kernel; see module docs for the faithful-simplification
//!   note).
//! * [`cvm`] — the Core Vector Machine: batch (1+ε) MEB via core sets,
//!   one pass over the data per core vector (the Figure-2 comparator).
//! * [`batch_l2svm`] — exact batch ℓ₂-SVM by dual coordinate descent:
//!   the in-memory, multi-pass "libSVM (batch)" stand-in of Table 1.

pub mod batch_l2svm;
pub mod cvm;
pub mod lasvm;
pub mod pegasos;
pub mod perceptron;
