//! LASVM-style online SVM (Bordes et al. 2005) — Table 1 baseline.
//!
//! LASVM interleaves PROCESS (insert the new example with a dual
//! coordinate step) and REPROCESS (revisit stored support vectors,
//! growing or shrinking their coefficients, removing those driven to
//! zero). For the paper's linear-kernel experiments we maintain the
//! primal image `w = Σ αᵢ yᵢ xᵢ` so each dual step costs O(D).
//!
//! Faithful simplification (documented in DESIGN.md): the original
//! selects τ-violating *pairs*; with the unbiased hinge dual (no equality
//! constraint) single-coordinate Newton steps optimize the same dual, so
//! PROCESS = a clipped Newton step on the new α, REPROCESS = the same on
//! the currently most-violating stored SV. One pass, `reprocess` revisits
//! per example.

use crate::data::Example;
use crate::eval::Classifier;
use crate::linalg;

/// LASVM configuration.
#[derive(Clone, Copy, Debug)]
pub struct LasvmOptions {
    /// Box constraint `0 ≤ α ≤ C`.
    pub c: f64,
    /// REPROCESS steps after each PROCESS.
    pub reprocess: usize,
    /// Drop SVs whose α falls below this.
    pub sv_eps: f64,
    /// Cap on SVs scanned per REPROCESS violation search (round-robin
    /// window). Keeps the pass O(N·cap·D) on large noisy streams where
    /// the SV set grows into the thousands.
    pub scan_cap: usize,
}

impl Default for LasvmOptions {
    fn default() -> Self {
        LasvmOptions { c: 1.0, reprocess: 2, sv_eps: 1e-8, scan_cap: 256 }
    }
}

/// One stored support vector.
#[derive(Clone, Debug)]
struct Sv {
    x: Vec<f32>,
    y: f32,
    alpha: f64,
    /// cached ||x||² (Newton denominator)
    xnorm2: f64,
}

/// Online LASVM model (linear kernel).
#[derive(Clone, Debug)]
pub struct Lasvm {
    pub w: Vec<f32>,
    svs: Vec<Sv>,
    opts: LasvmOptions,
    seen: usize,
    /// Round-robin cursor for the capped REPROCESS scan.
    scan_pos: usize,
}

impl Lasvm {
    pub fn new(dim: usize, opts: LasvmOptions) -> Self {
        Lasvm { w: vec![0.0; dim], svs: Vec::new(), opts, seen: 0, scan_pos: 0 }
    }

    /// Clipped Newton step on the dual coordinate of `sv`; updates `w`.
    fn coordinate_step(w: &mut [f32], sv: &mut Sv, c: f64) -> f64 {
        // dual gradient: 1 - y w·x ; Hessian: ||x||²
        let g = 1.0 - sv.y as f64 * linalg::dot(w, &sv.x);
        if sv.xnorm2 <= 0.0 {
            return 0.0;
        }
        let new_alpha = (sv.alpha + g / sv.xnorm2).clamp(0.0, c);
        let delta = new_alpha - sv.alpha;
        if delta != 0.0 {
            linalg::axpy(w, (delta * sv.y as f64) as f32, &sv.x);
            sv.alpha = new_alpha;
        }
        delta
    }

    /// PROCESS: insert a new example with one dual step.
    fn process(&mut self, x: &[f32], y: f32) {
        let mut sv = Sv { x: x.to_vec(), y, alpha: 0.0, xnorm2: linalg::norm2(x) };
        Self::coordinate_step(&mut self.w, &mut sv, self.opts.c);
        if sv.alpha > self.opts.sv_eps {
            self.svs.push(sv);
        }
    }

    /// REPROCESS: revisit the most-violating stored SV.
    fn reprocess(&mut self) {
        if self.svs.is_empty() {
            return;
        }
        // most-violating within a round-robin window of at most scan_cap
        let n = self.svs.len();
        let window = n.min(self.opts.scan_cap.max(1));
        let start = if n > window { self.scan_pos % n } else { 0 };
        self.scan_pos = self.scan_pos.wrapping_add(window);
        let mut best = 0usize;
        let mut best_v = 0.0f64;
        for k in 0..window {
            let i = (start + k) % n;
            let sv = &self.svs[i];
            let g = 1.0 - sv.y as f64 * linalg::dot(&self.w, &sv.x);
            // violation if g > 0 with alpha < C, or g < 0 with alpha > 0
            let v = if g > 0.0 {
                if sv.alpha < self.opts.c { g } else { 0.0 }
            } else if sv.alpha > 0.0 {
                -g
            } else {
                0.0
            };
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        if best_v <= 1e-12 {
            return;
        }
        let c = self.opts.c;
        Self::coordinate_step(&mut self.w, &mut self.svs[best], c);
        if self.svs[best].alpha <= self.opts.sv_eps {
            self.svs.swap_remove(best);
        }
    }

    /// Stream one example: PROCESS + `reprocess` REPROCESS steps.
    pub fn observe(&mut self, x: &[f32], y: f32) {
        self.seen += 1;
        self.process(x, y);
        for _ in 0..self.opts.reprocess {
            self.reprocess();
        }
    }

    /// Single-pass training.
    pub fn fit<'a, I: IntoIterator<Item = &'a Example>>(
        stream: I,
        dim: usize,
        opts: &LasvmOptions,
    ) -> Self {
        let mut m = Lasvm::new(dim, *opts);
        for e in stream {
            m.observe(&e.x.dense(), e.y);
        }
        m
    }

    pub fn num_support(&self) -> usize {
        self.svs.len()
    }

    pub fn examples_seen(&self) -> usize {
        self.seen
    }
}

impl Classifier for Lasvm {
    fn score(&self, x: &[f32]) -> f64 {
        linalg::dot(&self.w, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::accuracy;
    use crate::prop::gen;
    use crate::rng::Pcg32;

    fn toy(n: usize, d: usize, sep: f64, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, sep);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    #[test]
    fn learns_separable() {
        let exs = toy(3000, 8, 1.2, 1);
        let m = Lasvm::fit(exs.iter(), 8, &LasvmOptions::default());
        assert!(accuracy(&m, &exs) > 0.9);
        assert!(m.num_support() > 0);
    }

    #[test]
    fn alphas_respect_box() {
        let exs = toy(500, 4, 0.3, 2);
        let opts = LasvmOptions { c: 0.5, ..Default::default() };
        let m = Lasvm::fit(exs.iter(), 4, &opts);
        for sv in &m.svs {
            assert!(sv.alpha >= 0.0 && sv.alpha <= 0.5 + 1e-12);
        }
    }

    #[test]
    fn w_is_sum_of_alpha_y_x() {
        let exs = toy(200, 3, 0.8, 3);
        let m = Lasvm::fit(exs.iter(), 3, &LasvmOptions::default());
        // Reconstruct w from the SV expansion; REPROCESS removals zero
        // their contribution exactly, so the identity is tight.
        let mut w = vec![0.0f32; 3];
        for sv in &m.svs {
            crate::linalg::axpy(&mut w, (sv.alpha * sv.y as f64) as f32, &sv.x);
        }
        for (a, b) in w.iter().zip(&m.w) {
            assert!((a - b).abs() < 2e-3, "{w:?} vs {:?}", m.w);
        }
    }

    #[test]
    fn beats_perceptron_on_noisy_data() {
        // The Table-1 regime: LASVM ≥ perceptron nearly everywhere.
        let exs = toy(4000, 10, 0.5, 4);
        let l = accuracy(&Lasvm::fit(exs.iter(), 10, &LasvmOptions::default()), &exs);
        let p = accuracy(&crate::baselines::perceptron::Perceptron::fit(exs.iter(), 10), &exs);
        assert!(l + 0.03 >= p, "lasvm {l} vs perceptron {p}");
    }
}
