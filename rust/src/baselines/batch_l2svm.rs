//! Batch ℓ₂-SVM by dual coordinate descent — the "libSVM (batch)"
//! absolute benchmark of Table 1.
//!
//! Primal: `min ||w||² + C Σ ξᵢ²` s.t. `yᵢ w·xᵢ ≥ 1 − ξᵢ`. The dual is
//! box-free: `max Σαᵢ − ¼ αᵀQα` with `Q = [yᵢyⱼ xᵢ·xⱼ + δᵢⱼ/C]`, `α ≥ 0`.
//! With `w̃ = Σ αᵢ yᵢ xᵢ` (so the primal optimum is `w = w̃/2`, an
//! irrelevant scale for classification), the coordinate gradient is
//! `∂ᵢ = 1 − ½(yᵢ w̃·xᵢ + αᵢ/C)` and the Newton step divides by
//! `½(||xᵢ||² + 1/C)`. All data in memory, multiple epochs until the
//! maximum KKT violation drops below tolerance — batch mode by design.

use crate::data::Example;
use crate::eval::Classifier;
use crate::linalg;
use crate::rng::Pcg32;

/// Batch solver configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatchL2SvmOptions {
    pub c: f64,
    /// Stop when the max projected-gradient violation falls below this.
    pub tol: f64,
    pub max_epochs: usize,
    /// Shuffle coordinate order each epoch (seeded).
    pub seed: u64,
}

impl Default for BatchL2SvmOptions {
    fn default() -> Self {
        BatchL2SvmOptions { c: 1.0, tol: 1e-4, max_epochs: 200, seed: 0 }
    }
}

/// A converged batch ℓ₂-SVM model.
#[derive(Clone, Debug)]
pub struct BatchL2Svm {
    pub w: Vec<f32>,
    pub alpha: Vec<f64>,
    epochs_run: usize,
    final_violation: f64,
}

impl BatchL2Svm {
    pub fn fit(examples: &[Example], dim: usize, opts: &BatchL2SvmOptions) -> Self {
        let n = examples.len();
        let invc = 1.0 / opts.c;
        let mut alpha = vec![0.0f64; n];
        let mut w = vec![0.0f32; dim];
        let xnorm2: Vec<f64> = examples.iter().map(|e| e.x.view().norm2()).collect();
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Pcg32::seeded(opts.seed);
        let mut epochs_run = 0;
        let mut max_viol = f64::INFINITY;

        for _epoch in 0..opts.max_epochs {
            epochs_run += 1;
            rng.shuffle(&mut order);
            max_viol = 0.0f64;
            for &i in &order {
                let e = &examples[i];
                let g = 1.0 - 0.5 * (e.y as f64 * e.x.view().dot(&w) + alpha[i] * invc);
                // projected-gradient violation
                let viol = if alpha[i] > 0.0 { g.abs() } else { g.max(0.0) };
                if viol > max_viol {
                    max_viol = viol;
                }
                let h = 0.5 * (xnorm2[i] + invc);
                if h <= 0.0 {
                    continue;
                }
                let new_a = (alpha[i] + g / h).max(0.0);
                let delta = new_a - alpha[i];
                if delta != 0.0 {
                    e.x.view().axpy_into(&mut w, (delta * e.y as f64) as f32);
                    alpha[i] = new_a;
                }
            }
            if max_viol < opts.tol {
                break;
            }
        }
        BatchL2Svm { w, alpha, epochs_run, final_violation: max_viol }
    }

    pub fn epochs_run(&self) -> usize {
        self.epochs_run
    }

    pub fn final_violation(&self) -> f64 {
        self.final_violation
    }

    pub fn num_support(&self) -> usize {
        self.alpha.iter().filter(|&&a| a > 1e-9).count()
    }

    /// Dual objective `Σα − ¼(||w̃||² + Σα²/C)` (for optimality tests).
    pub fn dual_objective(&self, invc: f64) -> f64 {
        let a2: f64 = self.alpha.iter().map(|a| a * a).sum();
        self.alpha.iter().sum::<f64>() - 0.25 * (linalg::norm2(&self.w) + a2 * invc)
    }
}

impl Classifier for BatchL2Svm {
    fn score(&self, x: &[f32]) -> f64 {
        linalg::dot(&self.w, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::accuracy;
    use crate::prop::{check_default, gen};
    use crate::rng::Pcg32;

    fn toy(n: usize, d: usize, sep: f64, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, sep);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    #[test]
    fn solves_separable_to_high_accuracy() {
        let exs = toy(1000, 6, 1.5, 1);
        let m = BatchL2Svm::fit(&exs, 6, &BatchL2SvmOptions::default());
        assert!(accuracy(&m, &exs) > 0.97, "acc {}", accuracy(&m, &exs));
    }

    #[test]
    fn kkt_satisfied_at_convergence() {
        let exs = toy(300, 4, 1.0, 2);
        let opts = BatchL2SvmOptions { tol: 1e-6, max_epochs: 2000, ..Default::default() };
        let m = BatchL2Svm::fit(&exs, 4, &opts);
        assert!(m.final_violation() < 1e-6, "viol {}", m.final_violation());
        // KKT: alpha_i > 0 => y_i w·x_i + alpha_i/C == 2 (stationarity)
        for (i, e) in exs.iter().enumerate() {
            if m.alpha[i] > 1e-6 {
                let lhs = e.y as f64 * e.x.view().dot(&m.w) + m.alpha[i];
                assert!((lhs - 2.0).abs() < 1e-3, "KKT violated: {lhs}");
            }
        }
    }

    #[test]
    fn coordinate_steps_never_decrease_dual() {
        // Run two budgets; the longer run must have >= dual objective.
        check_default("dual-monotone", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 60, d, 1.0, 0.5);
            let exs: Vec<Example> =
                xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
            let short = BatchL2Svm::fit(
                &exs,
                d,
                &BatchL2SvmOptions { max_epochs: 2, tol: 0.0, ..Default::default() },
            );
            let long = BatchL2Svm::fit(
                &exs,
                d,
                &BatchL2SvmOptions { max_epochs: 40, tol: 0.0, ..Default::default() },
            );
            if long.dual_objective(1.0) + 1e-9 < short.dual_objective(1.0) {
                return Err(format!(
                    "dual decreased: {} -> {}",
                    short.dual_objective(1.0),
                    long.dual_objective(1.0)
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn alphas_nonnegative() {
        let exs = toy(200, 3, 0.2, 3);
        let m = BatchL2Svm::fit(&exs, 3, &BatchL2SvmOptions::default());
        assert!(m.alpha.iter().all(|&a| a >= 0.0));
        assert!(m.num_support() > 0);
    }
}
