//! Rosenblatt's perceptron (single pass) — Table 1 baseline.

use crate::data::Example;
use crate::eval::Classifier;
use crate::linalg;

/// A perceptron model trained by mistake-driven updates.
#[derive(Clone, Debug)]
pub struct Perceptron {
    pub w: Vec<f32>,
    mistakes: usize,
    seen: usize,
}

impl Perceptron {
    pub fn new(dim: usize) -> Self {
        Perceptron { w: vec![0.0; dim], mistakes: 0, seen: 0 }
    }

    /// One example: update on mistake (including on-the-margin zeros).
    pub fn observe(&mut self, x: &[f32], y: f32) -> bool {
        self.seen += 1;
        let s = linalg::dot(&self.w, x);
        if s * y as f64 <= 0.0 {
            linalg::axpy(&mut self.w, y, x);
            self.mistakes += 1;
            true
        } else {
            false
        }
    }

    /// Single-pass training.
    pub fn fit<'a, I: IntoIterator<Item = &'a Example>>(stream: I, dim: usize) -> Self {
        let mut m = Perceptron::new(dim);
        for e in stream {
            m.observe(&e.x.dense(), e.y);
        }
        m
    }

    /// Number of updates — contrast with StreamSVM's core-set size (the
    /// paper notes StreamSVM updates far less).
    pub fn num_mistakes(&self) -> usize {
        self.mistakes
    }

    pub fn examples_seen(&self) -> usize {
        self.seen
    }
}

impl Classifier for Perceptron {
    fn score(&self, x: &[f32]) -> f64 {
        linalg::dot(&self.w, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::accuracy;
    use crate::prop::gen;
    use crate::rng::Pcg32;

    #[test]
    fn learns_separable() {
        let mut rng = Pcg32::seeded(1);
        let (xs, ys) = gen::labeled_points(&mut rng, 2000, 8, 1.0, 1.5);
        let exs: Vec<Example> =
            xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
        let m = Perceptron::fit(exs.iter(), 8);
        assert!(accuracy(&m, &exs) > 0.9);
        assert!(m.num_mistakes() > 0);
    }

    #[test]
    fn no_update_on_correct_side() {
        let mut p = Perceptron::new(2);
        p.observe(&[1.0, 0.0], 1.0); // first example always a "mistake" (w=0)
        assert_eq!(p.num_mistakes(), 1);
        assert!(!p.observe(&[2.0, 0.0], 1.0));
        assert_eq!(p.w, vec![1.0, 0.0]);
    }

    #[test]
    fn mistake_bound_on_margin_data() {
        // Novikoff: mistakes <= (R/gamma)^2; just sanity-check it's far
        // below N on comfortably separable data.
        let mut rng = Pcg32::seeded(2);
        let (xs, ys) = gen::labeled_points(&mut rng, 5000, 4, 0.5, 2.0);
        let exs: Vec<Example> =
            xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
        let m = Perceptron::fit(exs.iter(), 4);
        assert!(m.num_mistakes() < 500, "mistakes {}", m.num_mistakes());
    }
}
