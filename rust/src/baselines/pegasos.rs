//! Pegasos (Shalev-Shwartz et al. 2007), single-sweep variant with block
//! size `k` — Table 1 baseline (paper runs k = 1 and k = 20).
//!
//! One sweep: the stream is consumed in consecutive blocks of `k`; at
//! step `t` the subgradient of the regularized hinge loss over the block
//! drives `w ← (1 − η_t λ) w + (η_t/k) Σ_{margin violators} y x`, followed
//! by projection onto the `1/√λ` ball. `λ` defaults to `1/(C·N)` which
//! matches the SVM regularization trade-off.

use crate::data::Example;
use crate::eval::Classifier;
use crate::linalg;

/// Pegasos configuration.
#[derive(Clone, Copy, Debug)]
pub struct PegasosOptions {
    /// Block size `k` for subgradient estimates.
    pub k: usize,
    /// Regularization λ; `None` → `1/(C·N)` with C=1 once N is known.
    pub lambda: Option<f64>,
}

impl Default for PegasosOptions {
    fn default() -> Self {
        PegasosOptions { k: 1, lambda: None }
    }
}

/// A single-sweep Pegasos model.
#[derive(Clone, Debug)]
pub struct Pegasos {
    pub w: Vec<f32>,
    steps: usize,
}

impl Pegasos {
    /// Single sweep over `examples` (order = stream order).
    pub fn fit(examples: &[Example], dim: usize, opts: &PegasosOptions) -> Self {
        let n = examples.len().max(1);
        let lambda = opts.lambda.unwrap_or(1.0 / n as f64);
        let k = opts.k.max(1);
        let mut w = vec![0.0f32; dim];
        let inv_sqrt_lambda = 1.0 / lambda.sqrt();
        let mut seen = 0usize;
        let mut t = 0usize;
        for block in examples.chunks(k) {
            t += 1;
            seen += block.len();
            // Step size on the *example* clock, not the block clock:
            // with eta = 1/(lambda * block_index) a k-sized block takes
            // k-times-larger steps than k=1 at the same stream position
            // and thrashes against the projection cap; the example clock
            // makes k=20 a smoothed version of k=1 (the paper's intent:
            // "akin to using a lookahead of 20").
            let eta = 1.0 / (lambda * seen as f64);
            // subgradient over the block's margin violators
            let mut grad = vec![0.0f32; dim];
            let mut viol = 0usize;
            for e in block {
                if (e.y as f64) * e.x.view().dot(&w) < 1.0 {
                    e.x.view().axpy_into(&mut grad, e.y);
                    viol += 1;
                }
            }
            let _ = viol;
            linalg::scale(&mut w, (1.0 - eta * lambda) as f32);
            if !block.is_empty() {
                linalg::axpy(&mut w, (eta / block.len() as f64) as f32, &grad);
            }
            // projection step: ||w|| <= 1/sqrt(lambda)
            let norm = linalg::norm2(&w).sqrt();
            if norm > inv_sqrt_lambda {
                linalg::scale(&mut w, (inv_sqrt_lambda / norm) as f32);
            }
        }
        Pegasos { w, steps: t }
    }

    pub fn num_steps(&self) -> usize {
        self.steps
    }
}

impl Classifier for Pegasos {
    fn score(&self, x: &[f32]) -> f64 {
        linalg::dot(&self.w, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::accuracy;
    use crate::prop::gen;
    use crate::rng::Pcg32;

    fn toy(n: usize, d: usize, sep: f64, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, sep);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    #[test]
    fn learns_separable_k20() {
        let exs = toy(4000, 6, 1.5, 1);
        let m = Pegasos::fit(&exs, 6, &PegasosOptions { k: 20, lambda: None });
        assert!(accuracy(&m, &exs) > 0.85);
        assert_eq!(m.num_steps(), 200);
    }

    #[test]
    fn k1_noisier_than_k20() {
        // On harder data, k=20 should not be (much) worse than k=1 —
        // Table 1 consistently shows k=20 >> k=1.
        let exs = toy(4000, 10, 0.6, 2);
        let a1 = accuracy(&Pegasos::fit(&exs, 10, &PegasosOptions { k: 1, lambda: None }), &exs);
        let a20 = accuracy(&Pegasos::fit(&exs, 10, &PegasosOptions { k: 20, lambda: None }), &exs);
        assert!(a20 + 0.02 >= a1, "k20 {a20} vs k1 {a1}");
    }

    #[test]
    fn projection_bounds_norm() {
        let exs = toy(500, 4, 1.0, 3);
        let lambda = 0.01;
        let m = Pegasos::fit(&exs, 4, &PegasosOptions { k: 1, lambda: Some(lambda) });
        assert!(crate::linalg::norm2(&m.w).sqrt() <= 1.0 / lambda.sqrt() + 1e-6);
    }

    #[test]
    fn empty_and_single() {
        let m = Pegasos::fit(&[], 3, &PegasosOptions::default());
        assert_eq!(m.w, vec![0.0; 3]);
        let one = vec![Example::new(vec![1.0, 0.0, 0.0], 1.0)];
        let m1 = Pegasos::fit(&one, 3, &PegasosOptions::default());
        assert!(m1.score(&[1.0, 0.0, 0.0]) > 0.0);
    }
}
