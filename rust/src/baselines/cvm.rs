//! Core Vector Machine (Tsang et al. 2005) — the batch MEB comparator of
//! Figure 2.
//!
//! CVM solves the same augmented-space MEB as StreamSVM but in *batch*
//! mode with core sets: repeatedly (a) scan the full dataset for the
//! point farthest from the current center — **one pass over the data per
//! core vector** — (b) stop if everything is within `(1+ε)R`, else (c)
//! add the farthest point to the core set and re-solve the MEB over the
//! core set (warm-started Badoiu-Clarkson). Figure 2 asks how many such
//! passes are needed to match one StreamSVM pass; [`Cvm::fit_tracked`]
//! snapshots the weight vector after every pass for exactly that plot.

use crate::data::Example;
use crate::eval::Classifier;
use crate::linalg;
use crate::svm::TrainOptions;

/// CVM configuration.
#[derive(Clone, Copy, Debug)]
pub struct CvmOptions {
    pub train: TrainOptions,
    /// (1+ε) approximation target.
    pub eps: f64,
    /// Hard cap on data passes (= core vectors added + 1).
    pub max_passes: usize,
    /// Badoiu-Clarkson refinement iterations per core-set re-solve.
    pub solve_iters: usize,
}

impl Default for CvmOptions {
    fn default() -> Self {
        CvmOptions {
            train: TrainOptions::default(),
            eps: 1e-3,
            max_passes: 100,
            solve_iters: 60,
        }
    }
}

/// Snapshot of the model after one full data pass.
#[derive(Clone, Debug)]
pub struct PassSnapshot {
    pub pass: usize,
    pub w: Vec<f32>,
    pub r: f64,
    pub coreset: usize,
}

/// A trained CVM model.
#[derive(Clone, Debug)]
pub struct Cvm {
    pub w: Vec<f32>,
    pub r: f64,
    pub xi2: f64,
    coreset: Vec<usize>,
    alpha: Vec<f64>,
    passes: usize,
    converged: bool,
}

impl Cvm {
    pub fn fit(examples: &[Example], dim: usize, opts: &CvmOptions) -> Self {
        Self::fit_tracked(examples, dim, opts, |_| {})
    }

    /// Train, invoking `on_pass` with a snapshot after every data pass.
    pub fn fit_tracked<F: FnMut(&PassSnapshot)>(
        examples: &[Example],
        dim: usize,
        opts: &CvmOptions,
        mut on_pass: F,
    ) -> Self {
        assert!(!examples.is_empty());
        let s2 = opts.train.s2();
        let mut coreset: Vec<usize> = vec![0];
        let mut alpha: Vec<f64> = vec![1.0];
        let mut w: Vec<f32> = vec![0.0; dim];
        linalg::blend_into(&mut w, &examples[0].x.dense(), examples[0].y, 1.0);
        let mut a2 = 1.0f64; // Σ α²
        let mut r = 0.0f64;
        let mut passes = 0usize;
        let mut converged = false;

        // d²(center, example i) with coefficient a_i (0 if not in core set)
        let sqdist = |w: &[f32], a2: f64, ai: f64, e: &Example| -> f64 {
            linalg::sqdist_scaled(w, &e.x.dense(), e.y) + s2 * (a2 - 2.0 * ai + 1.0)
        };

        while passes < opts.max_passes {
            passes += 1;
            // ---- one full pass: farthest point from the current center
            let mut far_i = 0usize;
            let mut far_d2 = f64::NEG_INFINITY;
            for (i, e) in examples.iter().enumerate() {
                let ai = coreset
                    .iter()
                    .position(|&c| c == i)
                    .map(|k| alpha[k])
                    .unwrap_or(0.0);
                let d2 = sqdist(&w, a2, ai, e);
                if d2 > far_d2 {
                    far_d2 = d2;
                    far_i = i;
                }
            }
            let far_d = far_d2.max(0.0).sqrt();
            on_pass(&PassSnapshot { pass: passes, w: w.clone(), r, coreset: coreset.len() });
            if far_d <= r * (1.0 + opts.eps) {
                converged = true;
                break;
            }
            // ---- grow the core set
            if !coreset.contains(&far_i) {
                coreset.push(far_i);
                alpha.push(0.0);
            }
            // warm insert: blend toward the new point like a stream update
            let d = far_d.max(1e-12);
            let beta = if r > 0.0 { 0.5 * (1.0 - r / d) } else { 0.5 };
            let last = alpha.len() - 1;
            for a in alpha.iter_mut() {
                *a *= 1.0 - beta;
            }
            alpha[last] += beta;
            linalg::scale(&mut w, (1.0 - beta) as f32);
            linalg::axpy(
                &mut w,
                (beta * examples[far_i].y as f64) as f32,
                &examples[far_i].x,
            );
            a2 = alpha.iter().map(|a| a * a).sum();

            // ---- re-solve MEB over the core set (warm-started BC).
            // The inner solve must be much tighter than the outer (1+ε)
            // test, or the inflated radius terminates the outer loop
            // prematurely (the real CVM solves the inner QP exactly);
            // scale the iteration budget with the core-set size.
            let inner_iters = opts.solve_iters.max(25 * coreset.len());
            for t in 0..inner_iters {
                let (mut fi, mut fd2) = (0usize, f64::NEG_INFINITY);
                for (k, &i) in coreset.iter().enumerate() {
                    let d2 = sqdist(&w, a2, alpha[k], &examples[i]);
                    if d2 > fd2 {
                        fd2 = d2;
                        fi = k;
                    }
                }
                let eta = 1.0 / (t as f64 + 2.0);
                for a in alpha.iter_mut() {
                    *a *= 1.0 - eta;
                }
                alpha[fi] += eta;
                linalg::scale(&mut w, (1.0 - eta) as f32);
                let e = &examples[coreset[fi]];
                e.x.view().axpy_into(&mut w, (eta * e.y as f64) as f32);
                a2 = alpha.iter().map(|a| a * a).sum();
            }
            // radius = max over core set at the refined center
            r = coreset
                .iter()
                .enumerate()
                .map(|(k, &i)| sqdist(&w, a2, alpha[k], &examples[i]))
                .fold(0.0f64, f64::max)
                .sqrt();
        }

        Cvm { w, r, xi2: s2 * a2, coreset, alpha, passes, converged }
    }

    pub fn passes(&self) -> usize {
        self.passes
    }

    /// Core-set convex coefficients (center = Σ αₖ φ̃(z_{coreset[k]})).
    pub fn alphas(&self) -> &[f64] {
        &self.alpha
    }

    /// Training indices of the core vectors.
    pub fn coreset_indices(&self) -> &[usize] {
        &self.coreset
    }

    pub fn coreset_size(&self) -> usize {
        self.coreset.len()
    }

    pub fn converged(&self) -> bool {
        self.converged
    }
}

impl Classifier for Cvm {
    fn score(&self, x: &[f32]) -> f64 {
        linalg::dot(&self.w, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::accuracy;
    use crate::prop::gen;
    use crate::rng::Pcg32;

    fn toy(n: usize, d: usize, sep: f64, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, sep);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    #[test]
    fn converges_and_encloses_everything() {
        let exs = toy(400, 5, 1.0, 1);
        let opts = CvmOptions { max_passes: 400, eps: 0.05, ..Default::default() };
        let m = Cvm::fit(&exs, 5, &opts);
        assert!(m.converged(), "no convergence in {} passes", m.passes());
        // every point within (1+eps+slack) R
        let s2 = opts.train.s2();
        let a2 = m.alpha.iter().map(|a| a * a).sum::<f64>();
        for (i, e) in exs.iter().enumerate() {
            let ai = m
                .coreset
                .iter()
                .position(|&c| c == i)
                .map(|k| m.alpha[k])
                .unwrap_or(0.0);
            let d2 = crate::linalg::sqdist_scaled(&m.w, &e.x.dense(), e.y) + s2 * (a2 - 2.0 * ai + 1.0);
            assert!(
                d2.sqrt() <= m.r * (1.0 + opts.eps) + 1e-6,
                "point {i}: {} > {}",
                d2.sqrt(),
                m.r * (1.0 + opts.eps)
            );
        }
    }

    #[test]
    fn coreset_much_smaller_than_data() {
        let exs = toy(2000, 4, 1.0, 2);
        let m = Cvm::fit(&exs, 4, &CvmOptions { max_passes: 300, eps: 0.05, ..Default::default() });
        assert!(m.coreset_size() < 200, "coreset {}", m.coreset_size());
    }

    #[test]
    fn tracked_passes_monotone_and_complete() {
        let exs = toy(300, 3, 0.8, 3);
        let mut snaps = Vec::new();
        let m = Cvm::fit_tracked(
            &exs,
            3,
            &CvmOptions { max_passes: 50, ..Default::default() },
            |s| snaps.push(s.clone()),
        );
        assert_eq!(snaps.len(), m.passes());
        for (k, s) in snaps.iter().enumerate() {
            assert_eq!(s.pass, k + 1);
        }
        // core set never shrinks
        for w in snaps.windows(2) {
            assert!(w[1].coreset >= w[0].coreset);
        }
    }

    #[test]
    fn accuracy_grows_with_passes() {
        let exs = toy(1500, 6, 1.2, 4);
        let mut acc_by_pass = Vec::new();
        let _ = Cvm::fit_tracked(
            &exs,
            6,
            &CvmOptions { max_passes: 40, eps: 1e-4, ..Default::default() },
            |s| {
                let probe = ProbeW(&s.w);
                acc_by_pass.push(accuracy(&probe, &exs));
            },
        );
        let early = acc_by_pass[1.min(acc_by_pass.len() - 1)];
        let late = *acc_by_pass.last().unwrap();
        assert!(late >= early - 0.02, "early {early} late {late}");
        assert!(late > 0.85, "late acc {late}");
    }

    struct ProbeW<'a>(&'a [f32]);
    impl Classifier for ProbeW<'_> {
        fn score(&self, x: &[f32]) -> f64 {
            crate::linalg::dot(self.0, x)
        }
    }
}
