//! The self-profiling perf-regression harness behind `streamsvm
//! profile`.
//!
//! Runs a *standardized* synthetic workload — deterministic sparse
//! libsvm text, fixed seed — through the real example lifecycle, one
//! phase at a time, each timed with its own wall-clock accumulator and
//! wrapped in a tree span (so `--profile-out` renders the same run in
//! Perfetto):
//!
//! | phase       | what runs                                              |
//! |-------------|--------------------------------------------------------|
//! | `parse`     | libsvm text → [`FileStream`] tolerant parser           |
//! | `hash`      | signed feature hashing of every parsed row             |
//! | `update`    | Algorithm-1 [`StreamSvm`] one-pass fit                 |
//! | `distance`  | snapshot scoring of every row against the trained ball |
//! | `merge`     | Algorithm-2 [`LookaheadSvm`] fit + final flush         |
//! | `republish` | [`ModelCell`] epoch publishes at the serve cadence     |
//!
//! The six accumulators are measured *inside* one outer total-wall
//! timer with nothing else in between, so their sum is within a few
//! percent of the total — `BENCH_obs.json` records both and the
//! acceptance test pins the ratio at ≥ 90%. A second section times a
//! full one-pass fit for each of the five variants (rows/sec each).
//!
//! Regression gating: [`gate_against`] compares a fresh report to a
//! committed baseline (`benches/baselines/BENCH_obs.json`) with a
//! warn-then-fail tolerance, which is what the CI perf-regression job
//! runs. Thresholds are deliberately loose — shared runners are noisy
//! — but a real hot-path regression (2-3×) fails loudly.

use std::time::{Duration, Instant};

use crate::coordinator::stream::{FileStream, LineStream};
use crate::data::hashing::FeatureHasher;
use crate::data::Example;
use crate::rng::Pcg32;
use crate::server::cell::ModelCell;
use crate::svm::ellipsoid::EllipsoidSvm;
use crate::svm::learner::AnyLearner;
use crate::svm::kernelfn::Kernel;
use crate::svm::kernelized::KernelStreamSvm;
use crate::svm::lookahead::LookaheadSvm;
use crate::svm::multiball::{MergePolicy, MultiBallSvm};
use crate::svm::streamsvm::StreamSvm;
use crate::svm::TrainOptions;

/// The canonical phase names, in lifecycle order.
pub const PHASES: [&str; 6] = ["parse", "hash", "distance", "update", "merge", "republish"];

/// The five variant names, in registry order.
pub const VARIANTS: [&str; 5] =
    ["streamsvm", "lookahead", "kernelized", "ellipsoid", "multiball"];

/// Workload shape. [`Default`] is the *standardized* workload the
/// committed baseline and the CI job both use; changing it invalidates
/// `benches/baselines/BENCH_obs.json`.
#[derive(Clone, Copy, Debug)]
pub struct ProfileConfig {
    pub rows: usize,
    /// Input (pre-hash) dimension.
    pub dim: usize,
    /// Non-zeros per row.
    pub nnz: usize,
    /// Hashed dimension for the `hash` phase.
    pub hash_dim: usize,
    pub seed: u64,
    /// Lookahead `L` for the `merge` phase.
    pub lookahead: usize,
    /// Publish cadence for the `republish` phase.
    pub republish_every: usize,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        ProfileConfig {
            rows: 20_000,
            dim: 1 << 14,
            nnz: 16,
            hash_dim: 4096,
            seed: 42,
            lookahead: 32,
            republish_every: 64,
        }
    }
}

/// One phase's wall time.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    pub parse: Duration,
    pub hash: Duration,
    pub distance: Duration,
    pub update: Duration,
    pub merge: Duration,
    pub republish: Duration,
}

impl PhaseTimes {
    pub fn get(&self, phase: &str) -> Duration {
        match phase {
            "parse" => self.parse,
            "hash" => self.hash,
            "distance" => self.distance,
            "update" => self.update,
            "merge" => self.merge,
            "republish" => self.republish,
            _ => Duration::ZERO,
        }
    }

    pub fn sum(&self) -> Duration {
        self.parse + self.hash + self.distance + self.update + self.merge + self.republish
    }
}

/// The `profile` run's result: what `BENCH_obs.json` serializes.
#[derive(Clone, Debug)]
pub struct ProfileReport {
    pub cfg: ProfileConfig,
    pub total: Duration,
    pub phases: PhaseTimes,
    /// End-to-end throughput of the phased section.
    pub rows_per_s: f64,
    /// `(variant name, one-pass fit rows/sec)` for all five variants.
    pub variants: Vec<(&'static str, f64)>,
    /// Tolerant-parse throughput (MB/s) of the legacy per-line reader,
    /// measured outside the phased section like the variant sweep.
    pub ingest_line_mb_s: f64,
    /// Same text through the chunked byte-level reader ([`FileStream`]'s
    /// engine since the chunked-ingest refactor).
    pub ingest_chunked_mb_s: f64,
}

/// Deterministic sparse libsvm text: `rows` lines of `nnz` ascending
/// 1-based indices in `[1, dim]` with values in `[-1, 1)` and a
/// halfspace-plus-noise ±1 label.
pub fn gen_libsvm_text(cfg: &ProfileConfig) -> String {
    let mut rng = Pcg32::seeded(cfg.seed);
    let mut out = String::with_capacity(cfg.rows * cfg.nnz * 12);
    for _ in 0..cfg.rows {
        let mut idx: Vec<u32> = (0..cfg.nnz)
            .map(|_| 1 + rng.below(cfg.dim) as u32)
            .collect();
        idx.sort_unstable();
        idx.dedup();
        let mut acc = 0.0f64;
        let mut line = String::new();
        for &i in &idx {
            let v = rng.range(-1.0, 1.0) as f32;
            // Labels correlate with a fixed deterministic halfspace so
            // the learners see a non-degenerate margin structure.
            let w = if i % 3 == 0 { 1.0 } else { -0.5 };
            acc += w * v as f64;
            line.push_str(&format!(" {i}:{v}"));
        }
        let noisy = rng.uniform() < 0.15;
        let label = if (acc >= 0.0) != noisy { 1 } else { -1 };
        out.push_str(&format!("{label}{line}\n"));
    }
    out
}

fn timed<T>(acc: &mut Duration, name: &'static str, f: impl FnOnce() -> T) -> T {
    let _sp = crate::obs::span("profile", name);
    let t = Instant::now();
    let out = f();
    *acc += t.elapsed();
    out
}

/// Run the standardized workload. Single-threaded and allocation-light
/// between phases, so `phases.sum()` tracks `total` closely.
pub fn run_profile(cfg: &ProfileConfig) -> ProfileReport {
    let text = gen_libsvm_text(cfg);
    let mut ph = PhaseTimes::default();
    let opts = TrainOptions::default();
    let hasher = FeatureHasher::new(cfg.hash_dim, cfg.seed);

    let t_total = Instant::now();

    // parse: the real tolerant libsvm parser, fed from memory.
    let parsed: Vec<Example> = timed(&mut ph.parse, "parse", || {
        FileStream::from_reader(text.as_bytes(), cfg.dim).collect()
    });

    // hash: fold every row into the serving dimension.
    let hashed: Vec<Example> = timed(&mut ph.hash, "hash", || {
        parsed.iter().map(|e| hasher.hash_example(e)).collect()
    });

    // update: Algorithm-1 one-pass fit over the hashed stream.
    let model: AnyLearner = timed(&mut ph.update, "update", || {
        let mut m = StreamSvm::new(cfg.hash_dim, opts);
        for e in &hashed {
            m.observe_view(e.x.view(), e.y);
        }
        m
    })
    .into();

    // distance: score every row against the trained ball via the same
    // snapshot path `/predict` serves from.
    let cell = ModelCell::new(&model, "profile");
    let snap = cell.load();
    let checksum = timed(&mut ph.distance, "distance", || {
        let mut acc = 0.0f64;
        for e in &hashed {
            acc += snap.score_view(e.x.view());
        }
        acc
    });

    // merge: Algorithm-2 lookahead fit (buffered solves + final flush).
    timed(&mut ph.merge, "merge", || {
        let mut la = LookaheadSvm::new(cfg.hash_dim, opts.with_lookahead(cfg.lookahead));
        for e in &hashed {
            la.observe_view(e.x.view(), e.y);
        }
        la.finish();
    });

    // republish: epoch publishes at the serve cadence.
    timed(&mut ph.republish, "republish", || {
        for _ in 0..(cfg.rows / cfg.republish_every).max(1) {
            cell.publish(&model, "profile");
        }
    });

    let total = t_total.elapsed();
    let rows = parsed.len().max(1);
    std::hint::black_box(checksum);

    // Per-variant one-pass throughput (outside the phased section; the
    // phase sum is compared against `total`, not against these). Every
    // variant runs through the same [`AnyLearner`] observe/finish
    // surface the pipeline and server use, so the numbers include the
    // enum dispatch the production path pays. The label strings are the
    // *legacy* report keys (`variants.streamsvm` …) pinned by the
    // committed `BENCH_obs.json` baseline and the CI bench-diff gate —
    // they intentionally differ from [`crate::svm::learner::Variant`]
    // names (`ball` …).
    let mut variants = Vec::with_capacity(VARIANTS.len());
    {
        let _sp = crate::obs::span("profile", "variants");
        let learners: [(&'static str, AnyLearner); 5] = [
            ("streamsvm", StreamSvm::new(cfg.hash_dim, opts).into()),
            (
                "lookahead",
                LookaheadSvm::new(cfg.hash_dim, opts.with_lookahead(cfg.lookahead)).into(),
            ),
            ("kernelized", KernelStreamSvm::new(Kernel::Linear, opts).into()),
            ("ellipsoid", EllipsoidSvm::new(cfg.hash_dim, opts).into()),
            ("multiball", MultiBallSvm::new(cfg.hash_dim, 4, MergePolicy::NearestBall, opts).into()),
        ];
        for (name, mut m) in learners {
            let _sp = crate::obs::span("profile", name);
            let t = Instant::now();
            for e in &hashed {
                m.observe_view(e.x.view(), e.y);
            }
            m.finish();
            variants.push((name, rows as f64 / t.elapsed().as_secs_f64().max(1e-9)));
        }
    }

    // Chunked vs per-line ingest throughput over the same text —
    // outside the phased section (like the variant sweep) so the
    // phase-sum-tracks-total invariant is untouched. `benches/ingest.rs`
    // measures this at scale; these keys track it on the standardized
    // workload.
    let mb = text.len() as f64 / (1024.0 * 1024.0);
    let (ingest_line_mb_s, ingest_chunked_mb_s) = {
        let _sp = crate::obs::span("profile", "ingest");
        let t = Instant::now();
        let n_line = LineStream::from_reader(text.as_bytes(), cfg.dim).count();
        let line_s = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let n_chunked = FileStream::from_reader(text.as_bytes(), cfg.dim).count();
        let chunked_s = t.elapsed().as_secs_f64();
        debug_assert_eq!(n_line, n_chunked);
        std::hint::black_box((n_line, n_chunked));
        (mb / line_s.max(1e-9), mb / chunked_s.max(1e-9))
    };

    ProfileReport {
        cfg: *cfg,
        total,
        phases: ph,
        rows_per_s: rows as f64 / total.as_secs_f64().max(1e-9),
        variants,
        ingest_line_mb_s,
        ingest_chunked_mb_s,
    }
}

impl ProfileReport {
    /// The `BENCH_obs.json` document.
    pub fn to_json(&self) -> String {
        use crate::obs::prom::fmt_f64_json as f;
        let mut s = String::with_capacity(512);
        s.push_str("{\n");
        s.push_str(&format!("  \"rows\": {},\n", self.cfg.rows));
        s.push_str(&format!("  \"dim\": {},\n", self.cfg.dim));
        s.push_str(&format!("  \"nnz\": {},\n", self.cfg.nnz));
        s.push_str(&format!("  \"hash_dim\": {},\n", self.cfg.hash_dim));
        s.push_str(&format!("  \"seed\": {},\n", self.cfg.seed));
        s.push_str(&format!("  \"lookahead\": {},\n", self.cfg.lookahead));
        s.push_str(&format!("  \"total_s\": {},\n", f(self.total.as_secs_f64())));
        s.push_str(&format!("  \"phase_sum_s\": {},\n", f(self.phases.sum().as_secs_f64())));
        s.push_str(&format!("  \"rows_per_s\": {},\n", f(self.rows_per_s)));
        s.push_str("  \"phases\": {");
        for (i, p) in PHASES.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{p}\": {}", f(self.phases.get(p).as_secs_f64())));
        }
        s.push_str("},\n  \"variants\": {");
        for (i, (name, rps)) in self.variants.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{name}\": {}", f(*rps)));
        }
        s.push_str("},\n  \"ingest\": {");
        s.push_str(&format!(
            "\"line_mb_s\": {}, \"chunked_mb_s\": {}",
            f(self.ingest_line_mb_s),
            f(self.ingest_chunked_mb_s)
        ));
        s.push_str("}\n}\n");
        s
    }

    /// Prometheus exposition of the same numbers (passes
    /// [`crate::obs::prom::check_exposition`]); the CI job diffs
    /// `metrics-check --sum pallas_profile_rows_per_second` output
    /// against the committed baseline.
    pub fn to_prom(&self) -> String {
        let mut w = crate::obs::prom::PromWriter::new();
        w.header(
            "pallas_profile_rows_per_second",
            "End-to-end rows/sec of the standardized profile workload.",
            "gauge",
        );
        w.sample("pallas_profile_rows_per_second", &[], self.rows_per_s);
        w.header(
            "pallas_profile_phase_seconds",
            "Wall seconds per lifecycle phase of the profile workload.",
            "gauge",
        );
        for p in PHASES {
            w.sample(
                "pallas_profile_phase_seconds",
                &[("phase", p)],
                self.phases.get(p).as_secs_f64(),
            );
        }
        w.header(
            "pallas_profile_variant_rows_per_second",
            "One-pass fit rows/sec per SVM variant on the profile workload.",
            "gauge",
        );
        for &(name, rps) in &self.variants {
            w.sample("pallas_profile_variant_rows_per_second", &[("variant", name)], rps);
        }
        w.finish()
    }
}

/// Outcome of a baseline comparison.
#[derive(Clone, Debug, PartialEq)]
pub enum Gate {
    Ok,
    /// Regressed past the warn threshold: `(key, current, baseline)`.
    Warn(Vec<(String, f64, f64)>),
    /// Regressed past the fail threshold.
    Fail(Vec<(String, f64, f64)>),
}

/// Compare higher-is-better keys of a fresh JSON report against a
/// baseline JSON document. A key regresses when
/// `current < baseline * (1 - frac)`; keys missing from either side
/// are ignored (a new key cannot fail old baselines). Dot-paths
/// (`"variants.streamsvm"`) reach nested objects.
pub fn gate_against(
    current: &str,
    baseline: &str,
    keys: &[&str],
    warn_frac: f64,
    fail_frac: f64,
) -> Result<Gate, String> {
    let cur = crate::server::json::Json::parse(current).map_err(|e| format!("current: {e}"))?;
    let base = crate::server::json::Json::parse(baseline).map_err(|e| format!("baseline: {e}"))?;
    let lookup = |doc: &crate::server::json::Json, path: &str| -> Option<f64> {
        let mut node = doc.clone();
        for part in path.split('.') {
            node = node.get(part)?.clone();
        }
        node.as_f64()
    };
    let mut warns = Vec::new();
    let mut fails = Vec::new();
    for key in keys {
        let (Some(c), Some(b)) = (lookup(&cur, key), lookup(&base, key)) else {
            continue;
        };
        if !c.is_finite() || !b.is_finite() || b <= 0.0 {
            continue;
        }
        if c < b * (1.0 - fail_frac) {
            fails.push((key.to_string(), c, b));
        } else if c < b * (1.0 - warn_frac) {
            warns.push((key.to_string(), c, b));
        }
    }
    Ok(if !fails.is_empty() {
        Gate::Fail(fails)
    } else if !warns.is_empty() {
        Gate::Warn(warns)
    } else {
        Gate::Ok
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ProfileConfig {
        ProfileConfig { rows: 400, dim: 256, nnz: 8, hash_dim: 64, ..Default::default() }
    }

    #[test]
    fn workload_is_deterministic_and_parseable() {
        let cfg = tiny();
        let a = gen_libsvm_text(&cfg);
        let b = gen_libsvm_text(&cfg);
        assert_eq!(a, b, "generator must be seed-deterministic");
        let rows: Vec<Example> = FileStream::from_reader(a.as_bytes(), cfg.dim).collect();
        assert_eq!(rows.len(), cfg.rows);
        assert!(rows.iter().all(|e| e.y == 1.0 || e.y == -1.0));
    }

    #[test]
    fn phase_sum_tracks_total_and_all_phases_run() {
        let r = run_profile(&tiny());
        assert_eq!(r.variants.len(), 5);
        for p in PHASES {
            assert!(r.phases.get(p) > Duration::ZERO, "phase {p} never ran");
        }
        let ratio = r.phases.sum().as_secs_f64() / r.total.as_secs_f64();
        assert!(ratio <= 1.0 + 1e-9, "phases cannot exceed total, got {ratio}");
        assert!(ratio >= 0.90, "phase sum only {:.1}% of total", ratio * 100.0);
        assert!(r.rows_per_s > 0.0);
        assert!(r.ingest_line_mb_s > 0.0 && r.ingest_chunked_mb_s > 0.0);
    }

    #[test]
    fn report_json_and_prom_are_well_formed() {
        let r = run_profile(&tiny());
        let j = crate::server::json::Json::parse(&r.to_json()).expect("valid JSON");
        assert_eq!(j.get("rows").and_then(|v| v.as_f64()), Some(400.0));
        let phases = j.get("phases").unwrap();
        assert!(phases.get("merge").and_then(|v| v.as_f64()).is_some());
        let variants = j.get("variants").unwrap();
        assert!(variants.get("ellipsoid").and_then(|v| v.as_f64()).is_some());
        let ingest = j.get("ingest").unwrap();
        assert!(ingest.get("chunked_mb_s").and_then(|v| v.as_f64()).is_some());
        let prom = r.to_prom();
        let fams = crate::obs::prom::check_exposition(&prom).expect("valid exposition");
        assert_eq!(fams, 3);
        assert_eq!(
            crate::obs::prom::sum_metric(&prom, "pallas_profile_rows_per_second"),
            Some(r.rows_per_s)
        );
    }

    #[test]
    fn gate_warns_then_fails() {
        let base = r#"{"rows_per_s": 1000.0, "variants": {"streamsvm": 500.0}}"#;
        let keys = ["rows_per_s", "variants.streamsvm", "missing_key"];
        let ok = r#"{"rows_per_s": 950.0, "variants": {"streamsvm": 490.0}}"#;
        assert_eq!(gate_against(ok, base, &keys, 0.3, 0.6).unwrap(), Gate::Ok);
        let warn = r#"{"rows_per_s": 600.0, "variants": {"streamsvm": 490.0}}"#;
        match gate_against(warn, base, &keys, 0.3, 0.6).unwrap() {
            Gate::Warn(w) => assert_eq!(w[0].0, "rows_per_s"),
            g => panic!("expected warn, got {g:?}"),
        }
        let fail = r#"{"rows_per_s": 950.0, "variants": {"streamsvm": 100.0}}"#;
        match gate_against(fail, base, &keys, 0.3, 0.6).unwrap() {
            Gate::Fail(f) => assert_eq!(f[0].0, "variants.streamsvm"),
            g => panic!("expected fail, got {g:?}"),
        }
        assert!(gate_against("nope", base, &keys, 0.3, 0.6).is_err());
    }
}
