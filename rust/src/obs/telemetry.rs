//! Training-dynamics counters and gauges: the paper-level signals.
//!
//! Every SVM variant and the sketch layer report here — violation rate
//! per window, radius `R` and `‖w‖` trajectory, σ re-fold count,
//! lookahead buffer occupancy, merge count/duration, kernel core-set
//! size, checkpoint/codec bytes and durations. `GET /metrics` and
//! `train --trace-out` both read these statics; nothing else is shared
//! between the learner and the exposition layer.
//!
//! The hot-path contract: instrumented sites check [`telemetry_on`]
//! (one relaxed `AtomicBool` load) before touching anything else, so a
//! disabled recorder adds a single predictable branch per example —
//! the sparse bench must stay within 3% of the uninstrumented build.
//! Telemetry defaults to *off*; `serve` and `train` switch it on.
//!
//! Counters are monotonic `u64`s; gauges are `f64` bit-cast into an
//! `AtomicU64`. Both are registered by hand in [`counters`]/[`gauges`]
//! — a conscious trade: no linkme-style distributed registries, the
//! list *is* the inventory the README documents.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static TELEMETRY: AtomicBool = AtomicBool::new(false);

/// The gate every instrumented hot-path site checks first.
#[inline]
pub fn telemetry_on() -> bool {
    TELEMETRY.load(Ordering::Relaxed)
}

/// Enable/disable training telemetry process-wide. `serve()` and the
/// `train` CLI enable it; the library default is off.
pub fn set_telemetry(on: bool) {
    TELEMETRY.store(on, Ordering::Relaxed);
}

/// A monotonic counter with Prometheus metadata.
pub struct Counter {
    pub name: &'static str,
    pub help: &'static str,
    v: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Counter { name, help, v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    /// Tests and `--trace-out` runs reset to get per-run numbers.
    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// An `f64` gauge (bits in an `AtomicU64`) with Prometheus metadata.
pub struct Gauge {
    pub name: &'static str,
    pub help: &'static str,
    bits: AtomicU64,
}

impl Gauge {
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Gauge { name, help, bits: AtomicU64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    pub fn reset(&self) {
        self.set(0.0);
    }
}

// ---- the registry ----------------------------------------------------

/// Examples offered to a learner's observe path.
pub static EXAMPLES: Counter = Counter::new(
    "pallas_train_examples_total",
    "Examples offered to the streaming learner.",
);
/// Examples that violated the ball (forced an update / buffered).
pub static UPDATES: Counter = Counter::new(
    "pallas_train_updates_total",
    "Examples outside the ball that forced an update.",
);
/// Algorithm-2 lookahead merges performed.
pub static MERGES: Counter = Counter::new(
    "pallas_train_merges_total",
    "Lookahead buffer merges (Badoiu-Clarkson solves).",
);
/// Total nanoseconds inside merge solves.
pub static MERGE_NS: Counter = Counter::new(
    "pallas_train_merge_ns_total",
    "Cumulative nanoseconds spent in lookahead merge solves.",
);
/// σ re-folds (lazy-scale renormalizations) across all ball states.
pub static SIGMA_FOLDS: Counter = Counter::new(
    "pallas_train_sigma_folds_total",
    "Lazy-scale renormalizations of the ball center (sigma re-folds).",
);
/// `.meb` sketch encodes.
pub static SKETCH_ENCODES: Counter = Counter::new(
    "pallas_sketch_encodes_total",
    "MebSketch binary encodes.",
);
/// Bytes produced by sketch encodes.
pub static SKETCH_BYTES: Counter = Counter::new(
    "pallas_sketch_encoded_bytes_total",
    "Cumulative bytes produced by MebSketch encodes.",
);
/// Nanoseconds spent writing sketches to disk (tmp + rename).
pub static SKETCH_WRITE_NS: Counter = Counter::new(
    "pallas_sketch_write_ns_total",
    "Cumulative nanoseconds writing sketch files (atomic tmp+rename).",
);
/// Checkpoint saves performed by the [`crate::sketch::Checkpointer`].
pub static CHECKPOINT_SAVES: Counter = Counter::new(
    "pallas_checkpoint_saves_total",
    "Periodic checkpoint saves.",
);
/// Events evicted from the recorder ring (oldest-first truncation).
/// Bumped unconditionally at the drop site — it *is* the visibility
/// for a silently-truncating buffer, so it cannot hide behind the
/// telemetry gate.
pub static OBS_EVENTS_DROPPED: Counter = Counter::new(
    "pallas_obs_events_dropped_total",
    "Events dropped from the bounded /trace ring buffer.",
);
/// Rows skipped by the tolerant parsers (malformed tokens, non-finite
/// numbers). Bumped unconditionally at the skip sites, like
/// [`OBS_EVENTS_DROPPED`] — it *is* the visibility for silently dropped
/// training data, so it cannot hide behind the telemetry gate.
pub static PARSE_SKIPPED: Counter = Counter::new(
    "pallas_parse_skipped_total",
    "Rows skipped by the tolerant LIBSVM parsers (malformed/non-finite).",
);
/// Newline-aligned chunks dispatched by the chunked ingest path.
pub static INGEST_CHUNKS: Counter = Counter::new(
    "pallas_ingest_chunks_total",
    "Newline-aligned chunks read by the chunked ingest path.",
);
/// Bytes consumed by the chunked ingest path.
pub static INGEST_BYTES: Counter = Counter::new(
    "pallas_ingest_bytes_total",
    "Bytes consumed by the chunked ingest path.",
);
/// Rows parsed and dispatched by the parallel ingest driver.
pub static INGEST_ROWS: Counter = Counter::new(
    "pallas_ingest_rows_total",
    "Rows parsed by the parallel ingest driver.",
);

/// Current ball radius `R` (max over balls for multiball).
pub static RADIUS: Gauge = Gauge::new(
    "pallas_train_radius",
    "Current enclosing-ball radius R.",
);
/// Current `‖w‖` of the (lazily scaled) center.
pub static WNORM: Gauge = Gauge::new(
    "pallas_train_wnorm",
    "Current norm of the ball-center weight vector.",
);
/// Violation rate over the last completed window (see [`WINDOW`]).
pub static VIOLATION_RATE: Gauge = Gauge::new(
    "pallas_train_violation_rate",
    "Fraction of examples violating the ball over the last window.",
);
/// Lookahead buffer occupancy (Algorithm 2).
pub static LOOKAHEAD_BUFFERED: Gauge = Gauge::new(
    "pallas_train_lookahead_buffered",
    "Examples currently buffered by the lookahead learner.",
);
/// Kernel core-set size M.
pub static CORESET: Gauge = Gauge::new(
    "pallas_train_coreset_size",
    "Kernelized core-set size M (support points held).",
);
/// Number of balls held by the multiball learner.
pub static BALLS: Gauge = Gauge::new(
    "pallas_train_balls",
    "Balls held by the multiball learner.",
);

/// Every registered counter, in exposition order.
pub fn counters() -> [&'static Counter; 14] {
    [
        &EXAMPLES,
        &UPDATES,
        &MERGES,
        &MERGE_NS,
        &SIGMA_FOLDS,
        &SKETCH_ENCODES,
        &SKETCH_BYTES,
        &SKETCH_WRITE_NS,
        &CHECKPOINT_SAVES,
        &OBS_EVENTS_DROPPED,
        &PARSE_SKIPPED,
        &INGEST_CHUNKS,
        &INGEST_BYTES,
        &INGEST_ROWS,
    ]
}

/// Every registered gauge, in exposition order.
pub fn gauges() -> [&'static Gauge; 6] {
    [&RADIUS, &WNORM, &VIOLATION_RATE, &LOOKAHEAD_BUFFERED, &CORESET, &BALLS]
}

/// Zero all registered counters and gauges (per-run baselines for
/// `--trace-out` and tests).
pub fn reset_all() {
    for c in counters() {
        c.reset();
    }
    for g in gauges() {
        g.reset();
    }
    WINDOW_SEEN.store(0, Ordering::Relaxed);
    WINDOW_VIOL.store(0, Ordering::Relaxed);
}

// ---- per-window violation rate ---------------------------------------

/// Window length (examples) over which [`VIOLATION_RATE`] is computed.
pub const WINDOW: u64 = 1024;

static WINDOW_SEEN: AtomicU64 = AtomicU64::new(0);
static WINDOW_VIOL: AtomicU64 = AtomicU64::new(0);

/// The per-example telemetry tap every variant's observe path calls
/// (only when [`telemetry_on`]): counts the example, counts the
/// violation, and folds the violation rate gauge once per [`WINDOW`].
#[inline]
pub fn record_example(violated: bool) {
    EXAMPLES.inc();
    if violated {
        UPDATES.inc();
        WINDOW_VIOL.fetch_add(1, Ordering::Relaxed);
    }
    let n = WINDOW_SEEN.fetch_add(1, Ordering::Relaxed) + 1;
    if n % WINDOW == 0 {
        let v = WINDOW_VIOL.swap(0, Ordering::Relaxed);
        VIOLATION_RATE.set(v as f64 / WINDOW as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_work_without_the_recorder() {
        // Counters are plain atomics: they function (and stay cheap)
        // regardless of recorder/telemetry gates.
        let c = Counter::new("pallas_test_total", "test");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.reset();
        assert_eq!(c.get(), 0);

        let g = Gauge::new("pallas_test_gauge", "test");
        g.set(-2.5);
        assert_eq!(g.get(), -2.5);
        g.set(f64::INFINITY);
        assert!(g.get().is_infinite());
    }

    #[test]
    fn registry_names_are_unique_and_prefixed() {
        let mut names: Vec<&str> = counters().iter().map(|c| c.name).collect();
        names.extend(gauges().iter().map(|g| g.name));
        for n in &names {
            assert!(n.starts_with("pallas_"), "{n} lacks the pallas_ prefix");
        }
        let total = names.len();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), total, "duplicate metric name in registry");
    }

    #[test]
    fn window_folds_violation_rate() {
        let _g = crate::obs::recorder::test_lock();
        reset_all();
        // 25% violations over exactly one window.
        for i in 0..WINDOW {
            record_example(i % 4 == 0);
        }
        assert_eq!(EXAMPLES.get(), WINDOW);
        assert_eq!(UPDATES.get(), WINDOW / 4);
        assert!((VIOLATION_RATE.get() - 0.25).abs() < 1e-12);
        reset_all();
    }
}
