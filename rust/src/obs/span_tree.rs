//! Span *trees*: structured, parented timing records with bounded
//! per-trace buffers and explicit drop accounting.
//!
//! [`recorder::span`](crate::obs::recorder::span) gives flat wall-clock
//! timers; this module upgrades them into a tree. A **trace** is one
//! bounded buffer of [`SpanRecord`]s sharing a 128-bit trace id (W3C
//! `traceparent`-compatible). Threads participate through a
//! thread-local *current-span stack*: while a thread is bound to a
//! trace, every `recorder::span` it opens becomes a node whose parent
//! is the span enclosing it on that thread (or the trace root).
//!
//! Design constraints, in priority order:
//!
//! 1. **Disabled is one relaxed load.** [`enter`] checks the
//!    [`tracing_on`] gate first; with tracing off there is no
//!    thread-local access, no clock read, no allocation.
//! 2. **Bounded everything.** Each trace holds at most its `cap` spans
//!    — excess spans are counted in [`TraceBuf::dropped`], never
//!    silently lost. The retained-trace ring ([`retain`]/[`find`]) is
//!    itself bounded at [`RETAIN_CAP`].
//! 3. **Two binding modes.** Request threads bind explicitly
//!    ([`Trace::bind`], RAII-scoped); profiling runs install a
//!    process-wide fallback ([`set_profile_trace`]) that worker
//!    threads pick up lazily, so `train --profile-out` sees spans from
//!    the pipeline and trainer threads it never touches directly.
//!
//! Timestamps are [`recorder::now_us`](crate::obs::recorder::now_us)
//! microseconds (monotonic, process-relative) so span-tree times line
//! up with the event ring served at `GET /trace`.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::recorder::{now_us, Value};

/// Global tracing gate, independent of the recorder's level gate and
/// the telemetry gate. Off by default; `serve()` and profiling runs
/// switch it on.
static TRACING: AtomicBool = AtomicBool::new(false);

/// The single relaxed load the disabled path pays.
#[inline]
pub fn tracing_on() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Enable/disable span-tree tracing process-wide.
pub fn set_tracing(on: bool) {
    TRACING.store(on, Ordering::Relaxed);
}

/// Span buffer bound for request-scoped traces: a request touches a
/// handful of spans, so this is generous while keeping a hostile
/// `traceparent` sender from growing memory.
pub const REQUEST_SPAN_CAP: usize = 256;

/// Span buffer bound for whole-run profiling traces.
pub const PROFILE_SPAN_CAP: usize = 8192;

/// Retained traces served by `GET /debug/trace/<id>`.
pub const RETAIN_CAP: usize = 128;

/// One closed span: a node in a trace's tree.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Span id, unique within the process (never 0).
    pub id: u64,
    /// Parent span id; the trace root has parent 0.
    pub parent: u64,
    /// Subsystem tag (`"server"`, `"svm"`, `"profile"`, ...).
    pub target: &'static str,
    pub name: &'static str,
    /// Monotonic µs (recorder epoch) at span open.
    pub start_us: u64,
    pub dur_us: u64,
    /// Small per-process thread index (not the OS tid).
    pub thread: u64,
    pub fields: Vec<(&'static str, Value)>,
}

impl SpanRecord {
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(128);
        s.push_str("{\"id\":");
        s.push_str(&self.id.to_string());
        s.push_str(",\"parent\":");
        s.push_str(&self.parent.to_string());
        s.push_str(",\"target\":");
        s.push_str(&crate::obs::prom::json_string(self.target));
        s.push_str(",\"name\":");
        s.push_str(&crate::obs::prom::json_string(self.name));
        s.push_str(",\"start_us\":");
        s.push_str(&self.start_us.to_string());
        s.push_str(",\"dur_us\":");
        s.push_str(&self.dur_us.to_string());
        s.push_str(",\"thread\":");
        s.push_str(&self.thread.to_string());
        if !self.fields.is_empty() {
            s.push_str(",\"fields\":{");
            for (i, (k, v)) in self.fields.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                s.push_str(&crate::obs::prom::json_string(k));
                s.push(':');
                s.push_str(&v.to_json());
            }
            s.push('}');
        }
        s.push('}');
        s
    }
}

/// The bounded span store inside a trace.
#[derive(Debug)]
pub struct TraceBuf {
    pub spans: Vec<SpanRecord>,
    /// Spans discarded because the buffer hit its cap. Never silent.
    pub dropped: u64,
    cap: usize,
}

impl TraceBuf {
    fn push(&mut self, rec: SpanRecord) {
        if self.spans.len() >= self.cap {
            self.dropped += 1;
        } else {
            self.spans.push(rec);
        }
    }
}

/// Shared trace state: id + root span id + the bounded buffer.
#[derive(Debug)]
pub struct TraceShared {
    id: u128,
    root: u64,
    buf: Mutex<TraceBuf>,
}

impl TraceShared {
    pub fn id(&self) -> u128 {
        self.id
    }

    /// The pre-allocated root span id (children parent to it even
    /// before the root record itself is pushed at finish time).
    pub fn root_span(&self) -> u64 {
        self.root
    }

    /// `(span count, dropped count)` right now.
    pub fn len_dropped(&self) -> (usize, u64) {
        let b = self.buf.lock().unwrap();
        (b.spans.len(), b.dropped)
    }

    /// Duration of the root span, if it has been recorded.
    pub fn root_dur_us(&self) -> Option<u64> {
        let b = self.buf.lock().unwrap();
        b.spans.iter().find(|s| s.id == self.root).map(|s| s.dur_us)
    }

    /// Snapshot the spans (for export / rendering).
    pub fn snapshot(&self) -> (Vec<SpanRecord>, u64) {
        let b = self.buf.lock().unwrap();
        (b.spans.clone(), b.dropped)
    }

    /// The `/debug/trace/<id>` payload.
    pub fn to_json(&self) -> String {
        let (spans, dropped) = self.snapshot();
        let mut s = String::with_capacity(256 + spans.len() * 96);
        s.push_str("{\"trace_id\":\"");
        s.push_str(&fmt_trace_id(self.id));
        s.push_str("\",\"root\":");
        s.push_str(&self.root.to_string());
        s.push_str(",\"dropped\":");
        s.push_str(&dropped.to_string());
        if let Some(root) = spans.iter().find(|r| r.id == self.root) {
            s.push_str(",\"root_dur_us\":");
            s.push_str(&root.dur_us.to_string());
        }
        s.push_str(",\"spans\":[");
        for (i, r) in spans.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&r.to_json());
        }
        s.push_str("]}");
        s
    }
}

/// A handle on a live trace. Clones share the same buffer — a clone
/// can ride the training queue to the trainer thread and keep
/// appending spans after the HTTP response has been written.
#[derive(Clone, Debug)]
pub struct Trace(Arc<TraceShared>);

impl Trace {
    /// Start a trace with the given 128-bit id (from a `traceparent`
    /// header, or [`gen_trace_id`]) and span-buffer bound.
    pub fn start(id: u128, cap: usize) -> Trace {
        Trace(Arc::new(TraceShared {
            id,
            root: next_span_id(),
            buf: Mutex::new(TraceBuf { spans: Vec::new(), dropped: 0, cap }),
        }))
    }

    pub fn id(&self) -> u128 {
        self.0.id
    }

    pub fn root_span(&self) -> u64 {
        self.0.root
    }

    pub fn shared(&self) -> &Arc<TraceShared> {
        &self.0
    }

    /// Bind the current thread to this trace: until the guard drops,
    /// every `recorder::span` on this thread records into the tree,
    /// parented under the innermost open span (or the root). Nested
    /// binds restore the previous binding on drop.
    pub fn bind(&self) -> BindGuard {
        let prev = CURRENT.with(|c| {
            c.borrow_mut().replace(ThreadCtx {
                trace: Arc::clone(&self.0),
                stack: Vec::new(),
                profile_gen: None,
            })
        });
        BindGuard { prev }
    }

    /// Record the root span (named + timed by the caller, since the
    /// request's wall clock starts before the trace object exists).
    pub fn finish_root(
        &self,
        target: &'static str,
        name: &'static str,
        start_us: u64,
        dur_us: u64,
        fields: Vec<(&'static str, Value)>,
    ) {
        self.0.buf.lock().unwrap().push(SpanRecord {
            id: self.0.root,
            parent: 0,
            target,
            name,
            start_us,
            dur_us,
            thread: thread_index(),
            fields,
        });
    }
}

// ---- id generation ---------------------------------------------------

static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);

fn next_span_id() -> u64 {
    NEXT_SPAN.fetch_add(1, Ordering::Relaxed)
}

/// splitmix64: a well-mixed 64-bit permutation (public-domain constants).
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Generate a fresh, non-zero 128-bit trace id. Uniqueness comes from
/// a process-wide counter mixed with the monotonic clock; no OS RNG.
pub fn gen_trace_id() -> u128 {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    let hi = splitmix64(n ^ 0x5053_414c_4c41_5321); // "PALLAS!"-ish salt
    let lo = splitmix64(n.wrapping_add(now_us()).rotate_left(17));
    let id = ((hi as u128) << 64) | lo as u128;
    if id == 0 {
        1
    } else {
        id
    }
}

/// 32 lowercase hex chars, the W3C trace-id wire form.
pub fn fmt_trace_id(id: u128) -> String {
    format!("{id:032x}")
}

/// Parse a 32-hex-char trace id; zero is invalid per W3C.
pub fn parse_trace_id(s: &str) -> Option<u128> {
    if s.len() != 32 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    let id = u128::from_str_radix(s, 16).ok()?;
    if id == 0 {
        None
    } else {
        Some(id)
    }
}

/// Small per-process thread index (1, 2, ...) — stable for a thread's
/// lifetime and compact enough for Chrome trace `tid`s.
pub fn thread_index() -> u64 {
    static NEXT_TID: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

// ---- thread binding --------------------------------------------------

struct ThreadCtx {
    trace: Arc<TraceShared>,
    /// Open span ids, innermost last; empty means "parent to root".
    stack: Vec<u64>,
    /// `Some(gen)` when this binding was picked up lazily from the
    /// profile fallback; invalidated when the generation moves on.
    profile_gen: Option<u64>,
}

thread_local! {
    static CURRENT: RefCell<Option<ThreadCtx>> = const { RefCell::new(None) };
}

/// Restores the previous thread binding on drop (see [`Trace::bind`]).
pub struct BindGuard {
    prev: Option<ThreadCtx>,
}

impl Drop for BindGuard {
    fn drop(&mut self) {
        CURRENT.with(|c| {
            *c.borrow_mut() = self.prev.take();
        });
    }
}

/// The trace the current thread is bound to, if any (used by `/train`
/// to ship the request's trace across the queue to the trainer).
pub fn current_trace() -> Option<Trace> {
    CURRENT.with(|c| c.borrow().as_ref().map(|ctx| Trace(Arc::clone(&ctx.trace))))
}

// ---- profile fallback ------------------------------------------------

static PROFILE: Mutex<Option<Arc<TraceShared>>> = Mutex::new(None);
static PROFILE_GEN: AtomicU64 = AtomicU64::new(0);

/// Install (or clear) the process-wide profiling trace. Threads with
/// no explicit binding lazily attach to it on their next span; bumping
/// the generation detaches them once it is cleared or replaced.
pub fn set_profile_trace(t: Option<&Trace>) {
    *PROFILE.lock().unwrap() = t.map(|t| Arc::clone(&t.0));
    PROFILE_GEN.fetch_add(1, Ordering::Relaxed);
}

// ---- span recording (recorder::Span integration) ---------------------

/// A live tree-span handle held inside [`recorder::Span`]. Closing it
/// ([`exit`]) records the [`SpanRecord`].
pub struct TreeSpan {
    trace: Arc<TraceShared>,
    id: u64,
    parent: u64,
    start_us: u64,
}

/// Open a tree span on the current thread, if tracing is on *and* the
/// thread is bound (explicitly or via the profile fallback). One
/// relaxed load when tracing is off.
pub fn enter(_target: &'static str, _name: &'static str) -> Option<TreeSpan> {
    if !tracing_on() {
        return None;
    }
    CURRENT.with(|c| {
        let mut cur = c.borrow_mut();
        // Lazily (re)attach to the profile trace when unbound or when
        // holding a stale profile generation.
        let gen = PROFILE_GEN.load(Ordering::Relaxed);
        let stale = matches!(&*cur, Some(ctx) if ctx.profile_gen.is_some_and(|g| g != gen));
        if cur.is_none() || stale {
            *cur = PROFILE.lock().unwrap().as_ref().map(|arc| ThreadCtx {
                trace: Arc::clone(arc),
                stack: Vec::new(),
                profile_gen: Some(gen),
            });
        }
        let ctx = cur.as_mut()?;
        let id = next_span_id();
        let parent = *ctx.stack.last().unwrap_or(&ctx.trace.root);
        ctx.stack.push(id);
        Some(TreeSpan { trace: Arc::clone(&ctx.trace), id, parent, start_us: now_us() })
    })
}

/// Close a tree span: pop it off the thread stack and record it.
pub fn exit(
    span: TreeSpan,
    target: &'static str,
    name: &'static str,
    dur_us: u64,
    fields: Vec<(&'static str, Value)>,
) {
    CURRENT.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            // RAII drop order makes this LIFO; be defensive anyway so a
            // leaked span cannot poison the stack for its siblings.
            if let Some(i) = ctx.stack.iter().rposition(|&id| id == span.id) {
                ctx.stack.truncate(i);
            }
        }
    });
    span.trace.buf.lock().unwrap().push(SpanRecord {
        id: span.id,
        parent: span.parent,
        target,
        name,
        start_us: span.start_us,
        dur_us,
        thread: thread_index(),
        fields,
    });
}

// ---- retained traces (tail sampling) ---------------------------------

static RETAINED: Mutex<VecDeque<Arc<TraceShared>>> = Mutex::new(VecDeque::new());

/// Retain a finished trace for `GET /debug/trace/<id>`, evicting the
/// oldest beyond [`RETAIN_CAP`].
pub fn retain(t: &Trace) {
    let mut ring = RETAINED.lock().unwrap();
    if ring.len() >= RETAIN_CAP {
        ring.pop_front();
    }
    ring.push_back(Arc::clone(&t.0));
}

/// Look up a retained trace by id.
pub fn find(id: u128) -> Option<Arc<TraceShared>> {
    RETAINED.lock().unwrap().iter().find(|t| t.id == id).map(Arc::clone)
}

/// `(id, span count, root duration)` for every retained trace, oldest
/// first — the `GET /debug/trace` listing.
pub fn retained_summaries() -> Vec<(u128, usize, Option<u64>)> {
    RETAINED
        .lock()
        .unwrap()
        .iter()
        .map(|t| {
            let b = t.buf.lock().unwrap();
            let root = b.spans.iter().find(|s| s.id == t.root).map(|s| s.dur_us);
            (t.id, b.spans.len(), root)
        })
        .collect()
}

/// Drop all retained traces (tests).
pub fn clear_retained() {
    RETAINED.lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_ids_roundtrip_and_reject_garbage() {
        let id = gen_trace_id();
        let s = fmt_trace_id(id);
        assert_eq!(s.len(), 32);
        assert_eq!(parse_trace_id(&s), Some(id));
        assert_eq!(parse_trace_id(&"0".repeat(32)), None, "zero id is invalid");
        assert_eq!(parse_trace_id("abc"), None);
        assert_eq!(parse_trace_id(&"g".repeat(32)), None);
        assert_ne!(gen_trace_id(), gen_trace_id());
    }

    #[test]
    fn bound_thread_builds_a_parented_tree() {
        let _g = crate::obs::recorder::test_lock();
        set_tracing(true);
        let t = Trace::start(gen_trace_id(), 64);
        {
            let _b = t.bind();
            {
                let outer = enter("test", "outer").expect("bound + on");
                {
                    let inner = enter("test", "inner").unwrap();
                    assert_eq!(inner.parent, outer.id);
                    exit(inner, "test", "inner", 1, vec![]);
                }
                let outer_id = outer.id;
                assert_eq!(outer.parent, t.root_span());
                exit(outer, "test", "outer", 2, vec![("k", Value::U64(9))]);
                // After exiting, a new span parents back to the root.
                let next = enter("test", "next").unwrap();
                assert_eq!(next.parent, t.root_span());
                assert_ne!(next.parent, outer_id);
                exit(next, "test", "next", 0, vec![]);
            }
        }
        t.finish_root("test", "req", 0, 10, vec![]);
        let (spans, dropped) = t.shared().snapshot();
        assert_eq!(dropped, 0);
        assert_eq!(spans.len(), 4);
        set_tracing(false);
        // Unbound + off: enter is None.
        assert!(enter("test", "x").is_none());
    }

    #[test]
    fn span_cap_drops_are_counted() {
        let _g = crate::obs::recorder::test_lock();
        set_tracing(true);
        let t = Trace::start(gen_trace_id(), 4);
        {
            let _b = t.bind();
            for _ in 0..10 {
                let s = enter("test", "s").unwrap();
                exit(s, "test", "s", 0, vec![]);
            }
        }
        let (spans, dropped) = t.shared().snapshot();
        assert_eq!(spans.len(), 4);
        assert_eq!(dropped, 6);
        set_tracing(false);
    }

    #[test]
    fn profile_fallback_attaches_and_detaches_worker_threads() {
        let _g = crate::obs::recorder::test_lock();
        set_tracing(true);
        let t = Trace::start(gen_trace_id(), 64);
        set_profile_trace(Some(&t));
        let root = t.root_span();
        std::thread::spawn(move || {
            let s = enter("test", "worker").expect("profile fallback binds");
            assert_eq!(s.parent, root);
            exit(s, "test", "worker", 3, vec![]);
        })
        .join()
        .unwrap();
        set_profile_trace(None);
        // This thread never bound explicitly; after the generation
        // bump it must not attach to the dead profile trace.
        assert!(enter("test", "after").is_none());
        let (spans, _) = t.shared().snapshot();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "worker");
        set_tracing(false);
    }

    #[test]
    fn retained_ring_is_bounded_and_searchable() {
        let _g = crate::obs::recorder::test_lock();
        clear_retained();
        let mut ids = Vec::new();
        for _ in 0..(RETAIN_CAP + 5) {
            let t = Trace::start(gen_trace_id(), 8);
            t.finish_root("test", "r", 0, 1, vec![]);
            retain(&t);
            ids.push(t.id());
        }
        assert_eq!(retained_summaries().len(), RETAIN_CAP);
        assert!(find(ids[0]).is_none(), "oldest evicted");
        let last = find(*ids.last().unwrap()).expect("newest retained");
        assert_eq!(last.root_dur_us(), Some(1));
        clear_retained();
    }

    #[test]
    fn trace_json_is_parseable_and_carries_drop_count() {
        let _g = crate::obs::recorder::test_lock();
        set_tracing(true);
        let t = Trace::start(gen_trace_id(), 1);
        {
            let _b = t.bind();
            for _ in 0..3 {
                let s = enter("test", "s").unwrap();
                exit(s, "test", "s", 0, vec![("n", Value::U64(1))]);
            }
        }
        set_tracing(false);
        let j = crate::server::json::Json::parse(&t.shared().to_json()).expect("valid JSON");
        assert_eq!(
            j.get("trace_id").and_then(|v| v.as_str()),
            Some(fmt_trace_id(t.id()).as_str())
        );
        assert_eq!(j.get("dropped").and_then(|v| v.as_f64()), Some(2.0));
        let spans = j.get("spans").and_then(|v| v.as_array()).unwrap();
        assert_eq!(spans.len(), 1);
    }
}
