//! Zero-dependency observability: tracing, training telemetry, exposition.
//!
//! The paper's central claims are *dynamic* — polylog work per example, a
//! radius that grows monotonically and stabilizes, O(N/L) merges — and this
//! module is how the running system surfaces them, live, without pulling in
//! a single external crate:
//!
//! * [`recorder`] — the lock-cheap global tracing core: leveled structured
//!   [`Event`]s, monotonic-clock [`Span`]s, a bounded ring buffer of recent
//!   events for in-process scraping (`GET /trace`), and a stderr sink
//!   filtered by `PALLAS_LOG=off|error|warn|info|debug|trace`. Every emit
//!   site is gated by one relaxed atomic load; when nothing listens, the
//!   format machinery never runs.
//! * [`telemetry`] — training-dynamics counters/gauges shared by all five
//!   SVM variants and the sketch layer: per-window violation rate, radius
//!   `R` and `‖w‖` trajectory, σ re-fold count, lookahead buffer occupancy,
//!   merge count/duration, kernel core-set size, checkpoint/codec bytes.
//!   All sit behind a separate single-atomic-load gate ([`telemetry_on`])
//!   so the streaming hot path stays O(nnz) with telemetry disabled.
//! * [`prom`] — Prometheus text exposition (format 0.0.4) rendering for
//!   `GET /metrics`, plus a strict line-grammar checker used by tests and
//!   the `metrics-check` CLI subcommand.
//! * [`trace`] — `train --trace-out trace.jsonl`: a sampling JSONL writer
//!   ([`trace::TraceWriter`]) and a stream adapter ([`trace::TracedStream`])
//!   that snapshot the telemetry gauges every k examples for offline
//!   plotting, ending with a `"final"` line carrying the trained radius.
//!
//! The fleet/gossip and drift-detection roadmap items consume these same
//! signals; this module is their substrate.

pub mod prom;
pub mod recorder;
pub mod telemetry;
pub mod trace;

pub use recorder::{
    configure, emit, enabled, init_cli, recent_events, ring_len, span, Event, Level, Span, Value,
};
pub use telemetry::{set_telemetry, telemetry_on};
