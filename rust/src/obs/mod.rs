//! Zero-dependency observability: tracing, training telemetry, exposition.
//!
//! The paper's central claims are *dynamic* — polylog work per example, a
//! radius that grows monotonically and stabilizes, O(N/L) merges — and this
//! module is how the running system surfaces them, live, without pulling in
//! a single external crate:
//!
//! * [`recorder`] — the lock-cheap global tracing core: leveled structured
//!   [`Event`]s, monotonic-clock [`Span`]s, a bounded ring buffer of recent
//!   events for in-process scraping (`GET /trace`), and a stderr sink
//!   filtered by `PALLAS_LOG=off|error|warn|info|debug|trace`. Every emit
//!   site is gated by one relaxed atomic load; when nothing listens, the
//!   format machinery never runs.
//! * [`telemetry`] — training-dynamics counters/gauges shared by all five
//!   SVM variants and the sketch layer: per-window violation rate, radius
//!   `R` and `‖w‖` trajectory, σ re-fold count, lookahead buffer occupancy,
//!   merge count/duration, kernel core-set size, checkpoint/codec bytes.
//!   All sit behind a separate single-atomic-load gate ([`telemetry_on`])
//!   so the streaming hot path stays O(nnz) with telemetry disabled.
//! * [`prom`] — Prometheus text exposition (format 0.0.4) rendering for
//!   `GET /metrics`, plus a strict line-grammar checker used by tests and
//!   the `metrics-check` CLI subcommand.
//! * [`trace`] — `train --trace-out trace.jsonl`: a sampling JSONL writer
//!   ([`trace::TraceWriter`]) and a stream adapter ([`trace::TracedStream`])
//!   that snapshot the telemetry gauges every k examples for offline
//!   plotting, ending with a `"final"` line carrying the trained radius.
//! * [`span_tree`] — structured span *trees*: parented timing records with
//!   W3C-`traceparent`-compatible 128-bit trace ids, a thread-local
//!   current-span stack, bounded per-trace buffers with explicit drop
//!   accounting, and a bounded ring of retained traces served at
//!   `GET /debug/trace/<id>`. Gated by one relaxed load ([`tracing_on`]).
//! * [`chrome_trace`] — renders a span tree as Chrome Trace Event JSON
//!   (Perfetto / `chrome://tracing`), plus the strict well-formedness +
//!   per-thread-nesting checker the tests enforce on every export.
//! * [`profiler`] — the `profile` CLI subcommand's standardized synthetic
//!   workload: per-phase wall-time breakdown (parse → hash → distance →
//!   update → merge → republish) and rows/sec across all five variants,
//!   emitted as `BENCH_obs.json` and gated against a committed baseline.
//!
//! The fleet/gossip and drift-detection roadmap items consume these same
//! signals; this module is their substrate.

pub mod chrome_trace;
pub mod profiler;
pub mod prom;
pub mod recorder;
pub mod span_tree;
pub mod telemetry;
pub mod trace;

pub use recorder::{
    configure, emit, enabled, init_cli, recent_events, ring_len, span, Event, Level, Span, Value,
};
pub use span_tree::{set_tracing, tracing_on, Trace};
pub use telemetry::{set_telemetry, telemetry_on};
