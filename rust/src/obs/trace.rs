//! `train --trace-out`: sampled training-dynamics snapshots as JSONL.
//!
//! A [`TraceWriter`] snapshots the global telemetry gauges/counters
//! every `every` examples and appends one JSON object per line — the
//! offline-plottable record of the paper's dynamic claims (radius
//! trajectory, violation-rate decay, merge cadence). [`TracedStream`]
//! is the iterator adapter that ticks the writer as examples flow by,
//! so any stream source (file, synthetic, hashed) can be traced without
//! the training loop knowing.
//!
//! The last line is `{"final":true,...}` and carries the trained
//! model's radius — the acceptance check is that it matches the radius
//! the in-memory model reports.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::obs::prom::fmt_f64_json;
use crate::obs::telemetry;

/// Sampling JSONL writer over the global telemetry state.
pub struct TraceWriter {
    out: BufWriter<File>,
    path: PathBuf,
    /// Snapshot cadence in examples (`>= 1`).
    every: u64,
    /// Examples ticked so far.
    seen: u64,
    /// Snapshot lines written.
    lines: u64,
}

impl TraceWriter {
    /// Create/truncate `path`; snapshot every `every` examples
    /// (clamped to ≥ 1).
    pub fn create(path: &Path, every: u64) -> Result<TraceWriter> {
        let f = File::create(path).map_err(|e| {
            Error::Pipeline(format!("cannot create trace file {}: {e}", path.display()))
        })?;
        Ok(TraceWriter {
            out: BufWriter::new(f),
            path: path.to_path_buf(),
            every: every.max(1),
            seen: 0,
            lines: 0,
        })
    }

    /// Count one example; writes a snapshot line at the cadence.
    pub fn tick(&mut self) {
        self.seen += 1;
        if self.seen % self.every == 0 {
            self.write_snapshot();
        }
    }

    /// Append one snapshot line from the live telemetry state.
    pub fn write_snapshot(&mut self) {
        let line = format!(
            concat!(
                "{{\"example\":{},\"radius\":{},\"wnorm\":{},",
                "\"violation_rate\":{},\"examples_total\":{},\"updates_total\":{},",
                "\"merges\":{},\"lookahead_buffered\":{},\"coreset\":{},",
                "\"sigma_folds\":{},\"sketch_bytes\":{}}}"
            ),
            self.seen,
            fmt_f64_json(telemetry::RADIUS.get()),
            fmt_f64_json(telemetry::WNORM.get()),
            fmt_f64_json(telemetry::VIOLATION_RATE.get()),
            telemetry::EXAMPLES.get(),
            telemetry::UPDATES.get(),
            telemetry::MERGES.get(),
            fmt_f64_json(telemetry::LOOKAHEAD_BUFFERED.get()),
            fmt_f64_json(telemetry::CORESET.get()),
            telemetry::SIGMA_FOLDS.get(),
            telemetry::SKETCH_BYTES.get(),
        );
        let _ = writeln!(self.out, "{line}");
        self.lines += 1;
    }

    /// Examples ticked so far.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Snapshot lines written so far (excludes the final line).
    pub fn lines(&self) -> u64 {
        self.lines
    }

    /// Write the terminal `{"final":true,...}` line carrying the trained
    /// model's radius and merge count, then flush and close.
    pub fn finish(mut self, final_radius: f64, merges: u64) -> Result<PathBuf> {
        let line = format!(
            "{{\"final\":true,\"example\":{},\"radius\":{},\"merges\":{}}}",
            self.seen,
            fmt_f64_json(final_radius),
            merges,
        );
        writeln!(self.out, "{line}")
            .and_then(|_| self.out.flush())
            .map_err(|e| {
                Error::Pipeline(format!("writing trace file {}: {e}", self.path.display()))
            })?;
        Ok(self.path)
    }
}

/// Iterator adapter: passes items through, ticking a shared
/// [`TraceWriter`]. The writer is `Arc<Mutex<..>>` so the caller keeps a
/// handle to `finish()` after the training loop consumed the stream.
pub struct TracedStream<I> {
    inner: I,
    writer: Arc<Mutex<TraceWriter>>,
}

impl<I> TracedStream<I> {
    pub fn new(inner: I, writer: Arc<Mutex<TraceWriter>>) -> Self {
        TracedStream { inner, writer }
    }
}

impl<I: Iterator> Iterator for TracedStream<I> {
    type Item = I::Item;

    fn next(&mut self) -> Option<I::Item> {
        let item = self.inner.next();
        if item.is_some() {
            self.writer.lock().unwrap_or_else(|e| e.into_inner()).tick();
        }
        item
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.inner.size_hint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::json::Json;

    #[test]
    fn traced_stream_samples_and_finishes() {
        let _g = crate::obs::recorder::test_lock();
        telemetry::reset_all();
        telemetry::RADIUS.set(1.5);
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ssvm_trace_{}.jsonl", std::process::id()));
        let w = Arc::new(Mutex::new(TraceWriter::create(&path, 10).unwrap()));

        let items: Vec<u32> = (0..35).collect();
        let seen: Vec<u32> = TracedStream::new(items.into_iter(), w.clone()).collect();
        assert_eq!(seen.len(), 35);

        let writer = Arc::try_unwrap(w).ok().expect("sole owner").into_inner().unwrap();
        assert_eq!(writer.seen(), 35);
        assert_eq!(writer.lines(), 3); // at 10, 20, 30
        writer.finish(2.25, 4).unwrap();

        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 4);
        for l in &lines {
            Json::parse(l).unwrap_or_else(|e| panic!("unparseable trace line {l:?}: {e}"));
        }
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("example").and_then(|v| v.as_f64()), Some(10.0));
        assert_eq!(first.get("radius").and_then(|v| v.as_f64()), Some(1.5));
        let last = Json::parse(lines[3]).unwrap();
        assert_eq!(last.get("final").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(last.get("radius").and_then(|v| v.as_f64()), Some(2.25));
        assert_eq!(last.get("merges").and_then(|v| v.as_f64()), Some(4.0));
        std::fs::remove_file(&path).ok();
        telemetry::reset_all();
    }
}
