//! The global tracing core: leveled structured events, spans, ring buffer.
//!
//! Design constraints (in priority order):
//!
//! 1. **Disabled is near-free.** Every `obs_*!` macro expands to a single
//!    relaxed load of a combined gate byte before any argument is
//!    evaluated or any string formatted. The streaming hot path calls
//!    this millions of times per second; the acceptance bar is < 3%
//!    regression on the sparse bench with the recorder off.
//! 2. **No dependencies.** `std::sync::atomic` + one `Mutex` around the
//!    ring buffer (taken only when an event is actually retained).
//! 3. **Two independent sinks.** stderr (human, filtered by
//!    `PALLAS_LOG`) and the in-process ring buffer (machine, served by
//!    `GET /trace`). Either can be off; the gate is the max of the two.
//!
//! Timestamps are monotonic microseconds since the first recorder touch
//! (process-relative, never wall clock — spans must not go backwards).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Severity, ordered: a sink at level `L` accepts events with
/// `level <= L`. The discriminants are the gate encoding.
#[repr(u8)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    /// Parse a `PALLAS_LOG` word. `Ok(None)` means "off".
    pub fn parse(s: &str) -> Result<Option<Level>, ()> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" | "0" => Ok(None),
            "error" => Ok(Some(Level::Error)),
            "warn" | "warning" => Ok(Some(Level::Warn)),
            "info" => Ok(Some(Level::Info)),
            "debug" => Ok(Some(Level::Debug)),
            "trace" => Ok(Some(Level::Trace)),
            _ => Err(()),
        }
    }
}

fn level_from_u8(v: u8) -> Option<Level> {
    match v {
        1 => Some(Level::Error),
        2 => Some(Level::Warn),
        3 => Some(Level::Info),
        4 => Some(Level::Debug),
        5 => Some(Level::Trace),
        _ => None,
    }
}

/// A structured field value. `From` impls cover the types emit sites use
/// so `obs_info!("t"; n = 3, p = path_str, "msg")` just works.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    U64(u64),
    I64(i64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    /// Render as a JSON value (for `/trace`).
    pub fn to_json(&self) -> String {
        match self {
            Value::U64(v) => v.to_string(),
            Value::I64(v) => v.to_string(),
            Value::F64(v) => crate::obs::prom::fmt_f64_json(*v),
            Value::Bool(v) => v.to_string(),
            Value::Str(s) => crate::obs::prom::json_string(s),
        }
    }

    /// Render for the stderr `k=v` tail.
    fn to_display(&self) -> String {
        match self {
            Value::Str(s) => s.clone(),
            other => other.to_json(),
        }
    }
}

impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::U64(v)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::U64(v as u64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::U64(v as u64)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::I64(v)
    }
}
impl From<i32> for Value {
    fn from(v: i32) -> Self {
        Value::I64(v as i64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::F64(v)
    }
}
impl From<f32> for Value {
    fn from(v: f32) -> Self {
        Value::F64(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}

/// One retained event: what `GET /trace` serves and stderr prints.
#[derive(Clone, Debug)]
pub struct Event {
    /// Monotonic µs since recorder start.
    pub ts_us: u64,
    pub level: Level,
    /// Subsystem tag (`"server"`, `"svm"`, `"sketch"`, `"cli"`, ...).
    pub target: &'static str,
    pub msg: String,
    pub fields: Vec<(&'static str, Value)>,
    /// For span-close events: the span's duration in µs.
    pub span_us: Option<u64>,
}

impl Event {
    /// One JSON object, e.g.
    /// `{"ts_us":12,"level":"info","target":"server","msg":"up","fields":{"port":80}}`.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(96);
        s.push_str("{\"ts_us\":");
        s.push_str(&self.ts_us.to_string());
        s.push_str(",\"level\":\"");
        s.push_str(self.level.name());
        s.push_str("\",\"target\":");
        s.push_str(&crate::obs::prom::json_string(self.target));
        s.push_str(",\"msg\":");
        s.push_str(&crate::obs::prom::json_string(&self.msg));
        if let Some(us) = self.span_us {
            s.push_str(",\"span_us\":");
            s.push_str(&us.to_string());
        }
        if !self.fields.is_empty() {
            s.push_str(",\"fields\":{");
            for (i, (k, v)) in self.fields.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                s.push_str(&crate::obs::prom::json_string(k));
                s.push(':');
                s.push_str(&v.to_json());
            }
            s.push('}');
        }
        s.push('}');
        s
    }
}

// Gate encoding: 0 = everything off, 1..=5 = max accepted level,
// UNINIT = not yet configured (forces the slow init path once).
const UNINIT: u8 = 0xff;

/// Combined gate: `max(stderr level, ring level)`. The only atomic the
/// disabled fast path touches.
static GATE: AtomicU8 = AtomicU8::new(UNINIT);
static STDERR_LEVEL: AtomicU8 = AtomicU8::new(0);
static RING_LEVEL: AtomicU8 = AtomicU8::new(0);

/// Events retained for `GET /trace`. Oldest are dropped beyond
/// [`RING_CAP`].
static RING: Mutex<VecDeque<Event>> = Mutex::new(VecDeque::new());

/// Ring capacity: enough for a useful tail, bounded so a hot trace level
/// cannot grow memory.
pub const RING_CAP: usize = 1024;

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// µs since the recorder was first touched.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

#[cold]
fn init_from_env() {
    // Library/test default: stderr at `warn` (quiet unless something is
    // wrong), ring at `info` so /trace has content once a server runs.
    let stderr = match std::env::var("PALLAS_LOG") {
        Ok(s) => match Level::parse(&s) {
            Ok(l) => l,
            Err(()) => {
                eprintln!("warning: unrecognized PALLAS_LOG={s:?}, using \"warn\"");
                Some(Level::Warn)
            }
        },
        Err(_) => Some(Level::Warn),
    };
    apply(stderr, Some(Level::Info));
}

fn apply(stderr: Option<Level>, ring: Option<Level>) {
    let s = stderr.map_or(0, |l| l as u8);
    let r = ring.map_or(0, |l| l as u8);
    STDERR_LEVEL.store(s, Ordering::Relaxed);
    RING_LEVEL.store(r, Ordering::Relaxed);
    GATE.store(s.max(r), Ordering::Relaxed);
}

/// Explicitly set both sink levels (`None` = sink off). Tests and the
/// CLI use this; anything not configured falls back to `PALLAS_LOG` on
/// first use.
pub fn configure(stderr: Option<Level>, ring: Option<Level>) {
    epoch();
    apply(stderr, ring);
}

/// CLI entry: like the env default but stderr floors at `info`, so
/// `streamsvm train`/`serve` narrate progress unless PALLAS_LOG says
/// otherwise.
pub fn init_cli() {
    epoch();
    let stderr = match std::env::var("PALLAS_LOG") {
        Ok(s) => match Level::parse(&s) {
            Ok(l) => l,
            Err(()) => {
                eprintln!("warning: unrecognized PALLAS_LOG={s:?}, using \"info\"");
                Some(Level::Info)
            }
        },
        Err(_) => Some(Level::Info),
    };
    apply(stderr, Some(Level::Info));
}

#[cold]
fn enabled_slow(level: Level) -> bool {
    init_from_env();
    level as u8 <= GATE.load(Ordering::Relaxed)
}

/// The hot-path gate: one relaxed load (plus a one-time lazy env init).
/// `false` means no sink wants this level and the caller must skip all
/// formatting work.
#[inline]
pub fn enabled(level: Level) -> bool {
    let g = GATE.load(Ordering::Relaxed);
    if g == UNINIT {
        return enabled_slow(level);
    }
    level as u8 <= g
}

/// Deliver an event to whichever sinks accept its level. Call through
/// the `obs_*!` macros, which pre-check [`enabled`].
pub fn emit(
    level: Level,
    target: &'static str,
    msg: String,
    fields: Vec<(&'static str, Value)>,
    span_us: Option<u64>,
) {
    let ev = Event { ts_us: now_us(), level, target, msg, fields, span_us };
    if level as u8 <= STDERR_LEVEL.load(Ordering::Relaxed) {
        let mut line = format!(
            "[{:>9.3}s {:5} {}] {}",
            ev.ts_us as f64 / 1e6,
            level.name(),
            target,
            ev.msg
        );
        for (k, v) in &ev.fields {
            line.push(' ');
            line.push_str(k);
            line.push('=');
            line.push_str(&v.to_display());
        }
        if let Some(us) = ev.span_us {
            line.push_str(&format!(" span_us={us}"));
        }
        eprintln!("{line}");
    }
    if level as u8 <= RING_LEVEL.load(Ordering::Relaxed) {
        let mut ring = RING.lock().unwrap();
        if ring.len() == RING_CAP {
            ring.pop_front();
            // Truncation is never silent: the counter feeds
            // `pallas_obs_events_dropped_total` and the /trace payload.
            crate::obs::telemetry::OBS_EVENTS_DROPPED.inc();
        }
        ring.push_back(ev);
    }
}

/// Snapshot the ring buffer, oldest first (what `GET /trace` serves).
pub fn recent_events() -> Vec<Event> {
    RING.lock().unwrap().iter().cloned().collect()
}

/// Current ring occupancy.
pub fn ring_len() -> usize {
    RING.lock().unwrap().len()
}

/// Drop all retained events (tests).
pub fn clear_ring() {
    RING.lock().unwrap().clear();
}

/// A monotonic-clock span: measures from construction to drop, then
/// emits a `Debug` event carrying `span_us` and/or records a node in
/// the current thread's span tree (see [`crate::obs::span_tree`]).
/// Inert (no clock read, no emission) when `Debug` is not enabled and
/// no trace is bound at construction time.
pub struct Span {
    start: Option<Instant>,
    target: &'static str,
    name: &'static str,
    fields: Vec<(&'static str, Value)>,
    tree: Option<crate::obs::span_tree::TreeSpan>,
}

impl Span {
    /// Attach a structured field to the close event. No-op when the
    /// span is inert.
    pub fn field(mut self, k: &'static str, v: impl Into<Value>) -> Self {
        if self.start.is_some() {
            self.fields.push((k, v.into()));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            let us = start.elapsed().as_micros() as u64;
            let fields = std::mem::take(&mut self.fields);
            let debug = enabled(Level::Debug);
            if let Some(tree) = self.tree.take() {
                // Clone the fields only when both sinks want them.
                if debug {
                    crate::obs::span_tree::exit(tree, self.target, self.name, us, fields.clone());
                    emit(Level::Debug, self.target, self.name.to_string(), fields, Some(us));
                } else {
                    crate::obs::span_tree::exit(tree, self.target, self.name, us, fields);
                }
            } else if debug {
                emit(Level::Debug, self.target, self.name.to_string(), fields, Some(us));
            }
        }
    }
}

/// Open a span (see [`Span`]). Usage: `let _sp = span("svm",
/// "merge").field("l", len);` — the close event fires when `_sp` drops.
/// Participates in the current thread's span tree when one is bound
/// (one relaxed gate load otherwise).
pub fn span(target: &'static str, name: &'static str) -> Span {
    let tree = crate::obs::span_tree::enter(target, name);
    let start = if tree.is_some() || enabled(Level::Debug) { Some(Instant::now()) } else { None };
    Span { start, target, name, fields: Vec::new(), tree }
}

/// Current sink levels `(stderr, ring)`, for tests and `/trace` headers.
pub fn sink_levels() -> (Option<Level>, Option<Level>) {
    if GATE.load(Ordering::Relaxed) == UNINIT {
        init_from_env();
    }
    (
        level_from_u8(STDERR_LEVEL.load(Ordering::Relaxed)),
        level_from_u8(RING_LEVEL.load(Ordering::Relaxed)),
    )
}

/// Core leveled-event macro. Two shapes:
/// `obs_log!(level, "target", "fmt {}", x)` and
/// `obs_log!(level, "target"; k = v, k2 = v2; "fmt {}", x)`.
/// Arguments after the gate are not evaluated when the level is off.
#[macro_export]
macro_rules! obs_log {
    ($lvl:expr, $target:expr; $($k:ident = $v:expr),+ ; $($fmt:tt)+) => {
        if $crate::obs::enabled($lvl) {
            $crate::obs::emit(
                $lvl,
                $target,
                ::std::format!($($fmt)+),
                ::std::vec![$((::std::stringify!($k), $crate::obs::Value::from($v))),+],
                ::std::option::Option::None,
            );
        }
    };
    ($lvl:expr, $target:expr, $($fmt:tt)+) => {
        if $crate::obs::enabled($lvl) {
            $crate::obs::emit(
                $lvl,
                $target,
                ::std::format!($($fmt)+),
                ::std::vec::Vec::new(),
                ::std::option::Option::None,
            );
        }
    };
}

/// `obs_error!("target", "fmt", ..)` or `obs_error!("target"; k = v; "fmt")`.
#[macro_export]
macro_rules! obs_error {
    ($target:expr; $($rest:tt)+) => { $crate::obs_log!($crate::obs::Level::Error, $target; $($rest)+) };
    ($target:expr, $($rest:tt)+) => { $crate::obs_log!($crate::obs::Level::Error, $target, $($rest)+) };
}

/// See [`obs_error!`].
#[macro_export]
macro_rules! obs_warn {
    ($target:expr; $($rest:tt)+) => { $crate::obs_log!($crate::obs::Level::Warn, $target; $($rest)+) };
    ($target:expr, $($rest:tt)+) => { $crate::obs_log!($crate::obs::Level::Warn, $target, $($rest)+) };
}

/// See [`obs_error!`].
#[macro_export]
macro_rules! obs_info {
    ($target:expr; $($rest:tt)+) => { $crate::obs_log!($crate::obs::Level::Info, $target; $($rest)+) };
    ($target:expr, $($rest:tt)+) => { $crate::obs_log!($crate::obs::Level::Info, $target, $($rest)+) };
}

/// See [`obs_error!`].
#[macro_export]
macro_rules! obs_debug {
    ($target:expr; $($rest:tt)+) => { $crate::obs_log!($crate::obs::Level::Debug, $target; $($rest)+) };
    ($target:expr, $($rest:tt)+) => { $crate::obs_log!($crate::obs::Level::Debug, $target, $($rest)+) };
}

/// See [`obs_error!`].
#[macro_export]
macro_rules! obs_trace {
    ($target:expr; $($rest:tt)+) => { $crate::obs_log!($crate::obs::Level::Trace, $target; $($rest)+) };
    ($target:expr, $($rest:tt)+) => { $crate::obs_log!($crate::obs::Level::Trace, $target, $($rest)+) };
}

/// Recorder/telemetry state is global; every test that reconfigures it
/// runs under this lock so parallel test threads cannot interleave.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static TEST_LOCK: Mutex<()> = Mutex::new(());
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        test_lock()
    }

    #[test]
    fn level_parsing_and_ordering() {
        assert_eq!(Level::parse("warn"), Ok(Some(Level::Warn)));
        assert_eq!(Level::parse("TRACE"), Ok(Some(Level::Trace)));
        assert_eq!(Level::parse("off"), Ok(None));
        assert!(Level::parse("loud").is_err());
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn disabled_recorder_retains_nothing() {
        let _g = lock();
        configure(None, None);
        clear_ring();
        obs_info!("test", "should vanish {}", 42);
        obs_error!("test"; n = 7usize; "even errors, with sinks off");
        assert_eq!(ring_len(), 0);
        assert!(!enabled(Level::Error));
        configure(Some(Level::Warn), Some(Level::Info));
    }

    #[test]
    fn ring_retains_and_bounds_events() {
        let _g = lock();
        configure(None, Some(Level::Info));
        clear_ring();
        for i in 0..(RING_CAP + 10) {
            obs_info!("test"; i = i; "ring fill");
        }
        // Debug is above the ring level: not retained.
        obs_debug!("test", "too detailed");
        let evs = recent_events();
        assert_eq!(evs.len(), RING_CAP);
        // Oldest were dropped: the first retained event is i = 10.
        assert_eq!(evs[0].fields[0], ("i", Value::U64(10)));
        assert!(evs.iter().all(|e| e.level == Level::Info));
        configure(Some(Level::Warn), Some(Level::Info));
        clear_ring();
    }

    #[test]
    fn span_measures_and_carries_fields() {
        let _g = lock();
        configure(None, Some(Level::Debug));
        clear_ring();
        {
            let _sp = span("test", "work").field("shard", 3u64);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let evs = recent_events();
        let ev = evs.iter().find(|e| e.msg == "work").expect("span close event");
        assert!(ev.span_us.unwrap() >= 1_000, "span_us = {:?}", ev.span_us);
        assert_eq!(ev.fields[0], ("shard", Value::U64(3)));
        configure(Some(Level::Warn), Some(Level::Info));
        clear_ring();
    }

    #[test]
    fn event_json_is_parseable() {
        let ev = Event {
            ts_us: 12,
            level: Level::Warn,
            target: "server",
            msg: "he said \"hi\"\n".into(),
            fields: vec![("n", Value::U64(3)), ("r", Value::F64(1.5)), ("p", Value::Str("a/b".into()))],
            span_us: Some(99),
        };
        let j = crate::server::json::Json::parse(&ev.to_json()).expect("valid JSON");
        assert_eq!(j.get("level").and_then(|v| v.as_str()), Some("warn"));
        assert_eq!(j.get("span_us").and_then(|v| v.as_f64()), Some(99.0));
        let f = j.get("fields").unwrap();
        assert_eq!(f.get("r").and_then(|v| v.as_f64()), Some(1.5));
    }
}
