//! Prometheus text exposition (format 0.0.4) — rendering and a strict
//! line-grammar checker.
//!
//! [`PromWriter`] renders `# HELP`/`# TYPE` headers and sample lines;
//! [`render_registry`] dumps every registered telemetry counter/gauge;
//! [`render_latency_histogram`] maps the coordinator's log₂-bucketed
//! [`LatencyHistogram`] onto a Prometheus histogram (cumulative `le`
//! buckets in seconds, `+Inf`, `_sum`, `_count`).
//!
//! [`check_exposition`] is the other direction: a hand-rolled validator
//! for the exact grammar Prometheus scrapes — run over the `/metrics`
//! body in `serve_http.rs` and by the `metrics-check` CLI subcommand so
//! CI fails the moment the endpoint emits a malformed line.

use crate::coordinator::metrics::LatencyHistogram;
use crate::obs::telemetry;

/// Format an `f64` the way Prometheus expects: `+Inf`/`-Inf`/`NaN`
/// spelled exactly, integers without a fraction, everything else via
/// Rust's shortest round-trip `{}`.
pub fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".into()
    } else if v == f64::INFINITY {
        "+Inf".into()
    } else if v == f64::NEG_INFINITY {
        "-Inf".into()
    } else {
        format!("{v}")
    }
}

/// Format an `f64` as a *JSON* number: non-finite values become `null`
/// (JSON has no Inf/NaN). Shared by the `/trace` dump and the JSONL
/// trace writer.
pub fn fmt_f64_json(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

/// JSON-escape and quote a string.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Escape a label *value* per the exposition format (`\\`, `\"`, `\n`).
fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Incremental exposition-body builder.
#[derive(Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Emit the `# HELP` + `# TYPE` pair for a metric family. `kind` is
    /// `counter`, `gauge` or `histogram`.
    pub fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        // HELP text runs to end of line; strip anything that would fork it.
        self.out.push_str(&help.replace('\\', "\\\\").replace('\n', "\\n"));
        self.out.push('\n');
        self.out.push_str("# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    /// Emit one sample line: `name{labels} value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&escape_label(v));
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&fmt_f64(value));
        self.out.push('\n');
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Render every registered telemetry counter and gauge.
pub fn render_registry(w: &mut PromWriter) {
    for c in telemetry::counters() {
        w.header(c.name, c.help, "counter");
        w.sample(c.name, &[], c.get() as f64);
    }
    for g in telemetry::gauges() {
        w.header(g.name, g.help, "gauge");
        w.sample(g.name, &[], g.get());
    }
}

/// Render a [`LatencyHistogram`] as a Prometheus histogram in seconds.
///
/// Bucket `i` of the source holds samples in `[2^i µs, 2^(i+1) µs)`, so
/// the cumulative `le` edges are `2^(i+1)` µs converted to seconds; the
/// mandatory `+Inf` bucket equals `_count`. Empty trailing buckets above
/// the last non-empty one are elided (the first four edges are always
/// kept so the family never renders bucket-less).
pub fn render_latency_histogram(
    w: &mut PromWriter,
    name: &str,
    help: &str,
    labels: &[(&str, &str)],
    h: &LatencyHistogram,
) {
    w.header(name, help, "histogram");
    render_histogram_samples(w, name, labels, h);
}

/// The sample lines of [`render_latency_histogram`] without the
/// `# HELP`/`# TYPE` header — for families with several label sets
/// (e.g. one latency histogram per endpoint), where the header must be
/// emitted exactly once.
pub fn render_histogram_samples(
    w: &mut PromWriter,
    name: &str,
    labels: &[(&str, &str)],
    h: &LatencyHistogram,
) {
    let counts = h.bucket_counts();
    let last = counts.iter().rposition(|&c| c > 0).map_or(3, |i| i.max(3));
    let mut cum = 0u64;
    let bucket_name = format!("{name}_bucket");
    for (i, &c) in counts.iter().enumerate().take(last + 1) {
        cum += c;
        let le = fmt_f64(LatencyHistogram::bucket_edge_us(i) as f64 * 1e-6);
        let mut ls: Vec<(&str, &str)> = labels.to_vec();
        ls.push(("le", &le));
        w.sample(&bucket_name, &ls, cum as f64);
    }
    let mut ls: Vec<(&str, &str)> = labels.to_vec();
    ls.push(("le", "+Inf"));
    w.sample(&bucket_name, &ls, h.count() as f64);
    w.sample(&format!("{name}_sum"), labels, h.sum_ns() as f64 * 1e-9);
    w.sample(&format!("{name}_count"), labels, h.count() as f64);
}

// ---- the strict grammar checker --------------------------------------

fn is_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn is_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn is_sample_value(s: &str) -> bool {
    matches!(s, "+Inf" | "-Inf" | "Inf" | "NaN") || s.parse::<f64>().is_ok()
}

/// Parse `name{l="v",...}` from a sample line; returns
/// `(name, rest-after-labels)` or an error description.
fn parse_name_and_labels(line: &str) -> Result<(&str, &str), String> {
    let name_end = line
        .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_' || c == ':'))
        .unwrap_or(line.len());
    let name = &line[..name_end];
    if !is_metric_name(name) {
        return Err(format!("invalid metric name {name:?}"));
    }
    let rest = &line[name_end..];
    if !rest.starts_with('{') {
        return Ok((name, rest));
    }
    // label block: l="v" (,l="v")* }
    let mut chars = rest[1..].char_indices().peekable();
    loop {
        // label name
        let start = match chars.peek() {
            Some(&(i, _)) => i,
            None => return Err("unterminated label block".into()),
        };
        let mut end = start;
        while let Some(&(i, c)) = chars.peek() {
            if c.is_ascii_alphanumeric() || c == '_' {
                end = i + c.len_utf8();
                chars.next();
            } else {
                break;
            }
        }
        let lname = &rest[1 + start..1 + end];
        if !is_label_name(lname) {
            return Err(format!("invalid label name {lname:?}"));
        }
        match chars.next() {
            Some((_, '=')) => {}
            other => return Err(format!("expected '=' after label {lname:?}, got {other:?}")),
        }
        match chars.next() {
            Some((_, '"')) => {}
            other => return Err(format!("expected '\"' opening label value, got {other:?}")),
        }
        // label value with escapes
        loop {
            match chars.next() {
                None => return Err("unterminated label value".into()),
                Some((_, '\\')) => match chars.next() {
                    Some((_, '\\' | '"' | 'n')) => {}
                    other => return Err(format!("bad escape in label value: {other:?}")),
                },
                Some((_, '"')) => break,
                Some(_) => {}
            }
        }
        match chars.next() {
            Some((_, ',')) => continue,
            Some((i, '}')) => {
                return Ok((name, &rest[1 + i + 1..]));
            }
            other => return Err(format!("expected ',' or '}}' after label value, got {other:?}")),
        }
    }
}

/// For histogram children (`x_bucket`, `x_sum`, `x_count`), the declared
/// family is `x`.
fn family_of(name: &str) -> &str {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            return base;
        }
    }
    name
}

/// Validate a full `/metrics` body against the text exposition format
/// (0.0.4), strictly:
///
/// * only `# HELP <name> <text>` / `# TYPE <name> <kind>` comments;
/// * `TYPE` at most once per family, and *before* any of its samples;
/// * metric/label names match the spec charset; label values use only
///   the `\\`, `\"`, `\n` escapes;
/// * sample values parse as Prometheus floats (`+Inf`, `NaN`, ...),
///   optional integer timestamp;
/// * no duplicate `(name, labels)` sample line;
/// * body ends with a newline.
///
/// Returns `Ok(families)` — the number of `# TYPE`d families — so
/// callers can assert non-triviality.
pub fn check_exposition(body: &str) -> Result<usize, String> {
    if !body.is_empty() && !body.ends_with('\n') {
        return Err("body does not end with a newline".into());
    }
    let mut typed: Vec<(String, String)> = Vec::new(); // (family, kind)
    let mut seen_samples: Vec<String> = Vec::new();
    for (lineno, line) in body.lines().enumerate() {
        let n = lineno + 1;
        let fail = |msg: String| Err(format!("line {n}: {msg} — {line:?}"));
        if line.is_empty() {
            continue;
        }
        if let Some(comment) = line.strip_prefix('#') {
            let comment = comment.strip_prefix(' ').unwrap_or(comment);
            if let Some(rest) = comment.strip_prefix("HELP ") {
                let (name, _help) = match rest.split_once(' ') {
                    Some(p) => p,
                    None => (rest, ""),
                };
                if !is_metric_name(name) {
                    return fail(format!("HELP names invalid metric {name:?}"));
                }
            } else if let Some(rest) = comment.strip_prefix("TYPE ") {
                let (name, kind) = match rest.split_once(' ') {
                    Some(p) => p,
                    None => return fail("TYPE line missing kind".into()),
                };
                if !is_metric_name(name) {
                    return fail(format!("TYPE names invalid metric {name:?}"));
                }
                if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                    return fail(format!("unknown TYPE kind {kind:?}"));
                }
                if typed.iter().any(|(f, _)| f == name) {
                    return fail(format!("duplicate TYPE for family {name:?}"));
                }
                typed.push((name.to_string(), kind.to_string()));
            } else {
                return fail("only HELP/TYPE comments are allowed".into());
            }
            continue;
        }
        // sample line
        let (name, rest) = match parse_name_and_labels(line) {
            Ok(p) => p,
            Err(e) => return fail(e),
        };
        let rest = rest.trim_start();
        let mut parts = rest.split_whitespace();
        let value = match parts.next() {
            Some(v) => v,
            None => return fail("sample line missing value".into()),
        };
        if !is_sample_value(value) {
            return fail(format!("invalid sample value {value:?}"));
        }
        if let Some(ts) = parts.next() {
            if ts.parse::<i64>().is_err() {
                return fail(format!("invalid timestamp {ts:?}"));
            }
        }
        if parts.next().is_some() {
            return fail("trailing tokens after timestamp".into());
        }
        let fam = family_of(name);
        match typed.iter().find(|(f, _)| f == fam || f == name) {
            Some(_) => {}
            None => {
                return fail(format!("sample for {name:?} before its TYPE declaration"));
            }
        }
        let key = {
            let end = line.len() - rest.len();
            line[..end].trim_end().to_string()
        };
        if seen_samples.contains(&key) {
            return fail(format!("duplicate sample {key:?}"));
        }
        seen_samples.push(key);
    }
    Ok(typed.len())
}

/// Sum every sample of `metric` (all label sets) in an exposition body.
/// `None` if the metric never appears. Backs the `metrics-check --sum`
/// CLI used by the CI smoke to assert counters moved.
pub fn sum_metric(body: &str, metric: &str) -> Option<f64> {
    let mut total = 0.0;
    let mut seen = false;
    for line in body.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        if let Ok((name, rest)) = parse_name_and_labels(line) {
            if name == metric {
                if let Some(v) = rest.split_whitespace().next() {
                    if let Ok(f) = v.parse::<f64>() {
                        total += f;
                        seen = true;
                    }
                }
            }
        }
    }
    seen.then_some(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn writer_emits_valid_exposition() {
        let mut w = PromWriter::new();
        w.header("pallas_requests_total", "Requests by endpoint.", "counter");
        w.sample("pallas_requests_total", &[("endpoint", "predict")], 42.0);
        w.sample("pallas_requests_total", &[("endpoint", "train")], 7.0);
        w.header("pallas_train_radius", "Radius.", "gauge");
        w.sample("pallas_train_radius", &[], 1.25);
        let body = w.finish();
        assert!(body.contains("pallas_requests_total{endpoint=\"predict\"} 42\n"));
        assert_eq!(check_exposition(&body), Ok(2));
        assert_eq!(sum_metric(&body, "pallas_requests_total"), Some(49.0));
        assert_eq!(sum_metric(&body, "pallas_absent"), None);
    }

    #[test]
    fn histogram_rendering_is_cumulative_and_valid() {
        let mut h = LatencyHistogram::default();
        for us in [3u64, 3, 5, 100, 5000] {
            h.record(Duration::from_micros(us));
        }
        let mut w = PromWriter::new();
        render_latency_histogram(&mut w, "pallas_latency_seconds", "lat", &[("endpoint", "predict")], &h);
        let body = w.finish();
        assert_eq!(check_exposition(&body), Ok(1));
        // +Inf bucket must equal _count
        assert!(body.contains("le=\"+Inf\"} 5\n"), "{body}");
        assert!(body.contains("pallas_latency_seconds_count{endpoint=\"predict\"} 5\n"));
        // cumulative: [2,4)µs holds 2 samples → le="4e-6"-ish edge carries 2
        let lines: Vec<&str> = body.lines().filter(|l| l.contains("_bucket")).collect();
        let mut prev = -1.0;
        for l in lines {
            let v: f64 = l.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "non-cumulative bucket line: {l}");
            prev = v;
        }
    }

    #[test]
    fn registry_renders_clean() {
        let mut w = PromWriter::new();
        render_registry(&mut w);
        let body = w.finish();
        let fams = check_exposition(&body).expect("registry body valid");
        assert!(fams >= 15, "expected the full registry, got {fams} families");
        assert!(body.contains("pallas_train_radius"));
        assert!(body.contains("pallas_train_violation_rate"));
        assert!(body.contains("pallas_train_merges_total"));
    }

    #[test]
    fn checker_rejects_malformed_bodies() {
        // missing trailing newline
        assert!(check_exposition("# TYPE a counter\na 1").is_err());
        // sample before TYPE
        assert!(check_exposition("a 1\n# TYPE a counter\n").is_err());
        // bad metric name
        assert!(check_exposition("# TYPE 9a counter\n9a 1\n").is_err());
        // bad value
        assert!(check_exposition("# TYPE a counter\na one\n").is_err());
        // bad label grammar
        assert!(check_exposition("# TYPE a counter\na{x=\"unterminated} 1\n").is_err());
        // unknown escape in label value
        assert!(check_exposition("# TYPE a counter\na{x=\"bad\\t\"} 1\n").is_err());
        // duplicate sample
        assert!(check_exposition("# TYPE a counter\na 1\na 2\n").is_err());
        // duplicate TYPE
        assert!(check_exposition("# TYPE a counter\n# TYPE a counter\na 1\n").is_err());
        // free-form comment
        assert!(check_exposition("# hello\n").is_err());
        // valid: histogram children under one family, label sets distinct
        let ok = "# TYPE h histogram\nh_bucket{le=\"0.1\"} 1\nh_bucket{le=\"+Inf\"} 2\nh_sum 0.5\nh_count 2\n";
        assert_eq!(check_exposition(ok), Ok(1));
        // valid: timestamps and escapes
        let ok2 = "# HELP m says \"hi\"\n# TYPE m gauge\nm{p=\"a\\\\b\\\"c\\n\"} -1.5e3 1700000000\n";
        assert_eq!(check_exposition(ok2), Ok(1));
        // NaN/Inf values are legal
        let ok3 = "# TYPE g gauge\ng NaN\ng{k=\"v\"} +Inf\n";
        assert_eq!(check_exposition(ok3), Ok(1));
    }

    #[test]
    fn prom_float_formatting() {
        assert_eq!(fmt_f64(1.0), "1");
        assert_eq!(fmt_f64(1.5), "1.5");
        assert_eq!(fmt_f64(f64::INFINITY), "+Inf");
        assert_eq!(fmt_f64(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_f64(f64::NAN), "NaN");
        assert_eq!(fmt_f64_json(f64::NAN), "null");
        assert_eq!(json_string("a\"b\\c\n"), r#""a\"b\\c\n""#);
    }
}
