//! Chrome-trace-event JSON export for span trees.
//!
//! Renders a [`span_tree`](crate::obs::span_tree) trace as the Trace
//! Event Format consumed by Perfetto (<https://ui.perfetto.dev>) and
//! `chrome://tracing`: a `{"traceEvents":[...]}` object of complete
//! (`"ph":"X"`) events with microsecond `ts`/`dur`, one track per
//! recorded thread index.
//!
//! Span timestamps are truncated to whole microseconds independently
//! at open and close, so a child's recorded interval can overhang its
//! parent's by a microsecond or two. The exporter clamps every span
//! into its parent's interval (walking the recorded parent links), so
//! the emitted timeline is properly nested by construction —
//! [`check_chrome_trace`] verifies exactly that property and is what
//! the test suite runs against every export.

use std::collections::HashMap;

use super::span_tree::{SpanRecord, Trace};

/// Render spans as a Chrome trace JSON document. `pid` is arbitrary
/// (the viewer groups tracks under it); we use 1.
pub fn render_spans(spans: &[SpanRecord]) -> String {
    // Clamp children into their parents so µs truncation cannot make a
    // child overhang. Memoized walk over the parent links.
    let by_id: HashMap<u64, &SpanRecord> = spans.iter().map(|s| (s.id, s)).collect();
    let mut bounds: HashMap<u64, (u64, u64)> = HashMap::new();
    fn clamped(
        id: u64,
        by_id: &HashMap<u64, &SpanRecord>,
        bounds: &mut HashMap<u64, (u64, u64)>,
        depth: usize,
    ) -> Option<(u64, u64)> {
        if let Some(&b) = bounds.get(&id) {
            return Some(b);
        }
        // Parent links are acyclic by construction (ids are allocated
        // monotonically and parents precede children), but a depth
        // fuse keeps a corrupted buffer from recursing forever.
        if depth > 256 {
            return None;
        }
        let s = by_id.get(&id)?;
        let (mut lo, mut hi) = (s.start_us, s.start_us.saturating_add(s.dur_us));
        if let Some((plo, phi)) = clamped(s.parent, by_id, bounds, depth + 1) {
            lo = lo.clamp(plo, phi);
            hi = hi.clamp(lo, phi);
        }
        bounds.insert(id, (lo, hi));
        Some((lo, hi))
    }

    let mut out = String::with_capacity(128 + spans.len() * 128);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    for s in spans {
        let Some((lo, hi)) = clamped(s.id, &by_id, &mut bounds, 0) else {
            continue;
        };
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str("{\"name\":");
        out.push_str(&crate::obs::prom::json_string(s.name));
        out.push_str(",\"cat\":");
        out.push_str(&crate::obs::prom::json_string(s.target));
        out.push_str(",\"ph\":\"X\",\"ts\":");
        out.push_str(&lo.to_string());
        out.push_str(",\"dur\":");
        out.push_str(&(hi - lo).to_string());
        out.push_str(",\"pid\":1,\"tid\":");
        out.push_str(&s.thread.to_string());
        out.push_str(",\"args\":{\"id\":");
        out.push_str(&s.id.to_string());
        out.push_str(",\"parent\":");
        out.push_str(&s.parent.to_string());
        for (k, v) in &s.fields {
            out.push(',');
            out.push_str(&crate::obs::prom::json_string(k));
            out.push(':');
            out.push_str(&v.to_json());
        }
        out.push_str("}}");
    }
    out.push_str("]}");
    out
}

/// Render a whole trace (root + children).
pub fn render(trace: &Trace) -> String {
    let (spans, dropped) = trace.shared().snapshot();
    if dropped > 0 {
        crate::obs_warn!("obs"; dropped = dropped; "chrome trace export is incomplete");
    }
    render_spans(&spans)
}

/// Validate a Chrome trace JSON document: the shape the viewers need,
/// plus the per-track nesting invariant (any two complete events on
/// one `(pid, tid)` track are either disjoint or one contains the
/// other). Returns the event count.
pub fn check_chrome_trace(json: &str) -> Result<usize, String> {
    let doc = crate::server::json::Json::parse(json).map_err(|e| format!("bad JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_array())
        .ok_or("missing traceEvents array")?;
    // (pid, tid) -> [(ts, end)]
    let mut tracks: HashMap<(u64, u64), Vec<(u64, u64)>> = HashMap::new();
    for (i, ev) in events.iter().enumerate() {
        if ev.get("name").and_then(|v| v.as_str()).is_none() {
            return Err(format!("event {i}: missing name"));
        }
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        if ph != "X" {
            return Err(format!("event {i}: unsupported ph {ph:?}"));
        }
        let num = |k: &str| {
            ev.get(k)
                .and_then(|v| v.as_f64())
                .filter(|v| v.is_finite() && *v >= 0.0)
                .map(|v| v as u64)
        };
        let ts = num("ts").ok_or_else(|| format!("event {i}: bad ts"))?;
        let dur = num("dur").ok_or_else(|| format!("event {i}: bad dur"))?;
        let pid = num("pid").ok_or_else(|| format!("event {i}: bad pid"))?;
        let tid = num("tid").ok_or_else(|| format!("event {i}: bad tid"))?;
        tracks.entry((pid, tid)).or_default().push((ts, ts + dur));
    }
    for ((pid, tid), mut ivals) in tracks {
        // Sort by start, longest first on ties, then sweep a stack of
        // open intervals: each new interval must nest inside (or fall
        // after) everything still open.
        ivals.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
        let mut open: Vec<u64> = Vec::new();
        for (ts, end) in ivals {
            while matches!(open.last(), Some(&e) if e <= ts) {
                open.pop();
            }
            if let Some(&e) = open.last() {
                if end > e {
                    return Err(format!(
                        "track pid={pid} tid={tid}: event [{ts},{end}) overlaps [..,{e}) \
                         without nesting"
                    ));
                }
            }
            open.push(end);
        }
    }
    Ok(events.len())
}

/// Render and write a trace to `path`.
pub fn write_file(trace: &Trace, path: &str) -> std::io::Result<()> {
    std::fs::write(path, render(trace))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::Value;
    use crate::obs::span_tree::{self, gen_trace_id};

    fn rec(id: u64, parent: u64, start: u64, dur: u64, thread: u64) -> SpanRecord {
        SpanRecord {
            id,
            parent,
            target: "test",
            name: "s",
            start_us: start,
            dur_us: dur,
            thread,
            fields: vec![],
        }
    }

    #[test]
    fn export_is_valid_and_nested() {
        let spans = vec![
            rec(1, 0, 0, 100, 1),
            rec(2, 1, 10, 30, 1),
            rec(3, 2, 15, 10, 1),
            rec(4, 1, 50, 40, 1),
            rec(5, 0, 20, 60, 2),
        ];
        let json = render_spans(&spans);
        assert_eq!(check_chrome_trace(&json).expect("valid"), 5);
    }

    #[test]
    fn truncation_overhang_is_clamped_into_the_parent() {
        // Child recorded as [95, 105) under a parent ending at 100 —
        // the µs-truncation overhang the exporter must clamp away.
        let spans = vec![rec(1, 0, 0, 100, 1), rec(2, 1, 95, 10, 1)];
        let json = render_spans(&spans);
        assert_eq!(check_chrome_trace(&json).expect("clamped"), 2);
        assert!(json.contains("\"ts\":95,\"dur\":5"), "clamped child in {json}");
    }

    #[test]
    fn checker_rejects_overlap_and_garbage() {
        // Two same-track events that overlap without nesting.
        let bad = r#"{"traceEvents":[
            {"name":"a","ph":"X","ts":0,"dur":50,"pid":1,"tid":1},
            {"name":"b","ph":"X","ts":25,"dur":50,"pid":1,"tid":1}]}"#;
        assert!(check_chrome_trace(bad).is_err());
        // Same shape on different tracks is fine.
        let ok = r#"{"traceEvents":[
            {"name":"a","ph":"X","ts":0,"dur":50,"pid":1,"tid":1},
            {"name":"b","ph":"X","ts":25,"dur":50,"pid":1,"tid":2}]}"#;
        assert_eq!(check_chrome_trace(ok).unwrap(), 2);
        assert!(check_chrome_trace("not json").is_err());
        assert!(check_chrome_trace("{\"traceEvents\":3}").is_err());
        let no_ts = r#"{"traceEvents":[{"name":"a","ph":"X","dur":1,"pid":1,"tid":1}]}"#;
        assert!(check_chrome_trace(no_ts).is_err());
    }

    #[test]
    fn live_trace_exports_clean() {
        let _g = crate::obs::recorder::test_lock();
        span_tree::set_tracing(true);
        let t = span_tree::Trace::start(gen_trace_id(), 64);
        {
            let _b = t.bind();
            let outer = span_tree::enter("test", "outer").unwrap();
            let inner = span_tree::enter("test", "inner").unwrap();
            span_tree::exit(inner, "test", "inner", 2, vec![("rows", Value::U64(5))]);
            span_tree::exit(outer, "test", "outer", 4, vec![]);
        }
        span_tree::set_tracing(false);
        t.finish_root("test", "run", 0, 1000, vec![]);
        let json = render(&t);
        assert_eq!(check_chrome_trace(&json).expect("valid"), 3);
        assert!(json.contains("\"rows\":5"));
    }
}
