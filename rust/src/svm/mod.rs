//! The paper's algorithms: StreamSVM (Algorithm 1), the lookahead variant
//! (Algorithm 2), the kernelized variant, the multiball extension and the
//! diagonal-metric ellipsoid prototype (§6.2), plus the MEB machinery
//! they share.
//!
//! Every variant's observe path is O(nnz) in the example's stored
//! coordinates, and all expose the same `observe_view`/`try_observe`
//! surface (validated via [`validate_example`]); the cross-variant
//! conformance suite (`rust/tests/variant_conformance.rs`) pins the
//! shared invariants — radius monotonicity, convex-coefficient laws, and
//! that the linear-kernelized and isotropic-ellipsoid variants reproduce
//! [`ball::BallState`]'s `(w, R, ξ²)` on identical streams.

use crate::data::FeaturesView;
use crate::error::{Error, Result};

pub mod ball;
pub mod ellipsoid;
pub mod kernelfn;
pub mod kernelized;
pub mod learner;
pub mod lookahead;
pub mod meb;
pub mod multiball;
pub mod streamsvm;

/// Slack-coordinate bookkeeping convention (see DESIGN.md §3).
///
/// The augmented map is `φ̃(z_n) = [y_n x_n ; C^{-1/2} e_n]`. The paper's
/// pseudocode initializes `ξ² = 1` and adds `β²` per update — an implicit
/// *unit*-slack convention; carrying the `C^{-1/2}` coordinate exactly
/// gives init `1/C` and increments `β²/C`. The two coincide at `C = 1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlackMode {
    /// Verbatim paper pseudocode (Algorithm 1 lines 3 and 9).
    Paper,
    /// Exact `C^{-1/2}` slack-coordinate geometry.
    Consistent,
}

/// Validate one untrusted example against a learner of dimension `dim`:
/// wrong dimension is [`Error::Config`], non-finite features or a
/// non-±1 label are [`Error::Data`]. Shared by every learner's
/// `try_observe` so the rejection rules (and messages) cannot drift
/// between algorithms.
pub fn validate_example(x: FeaturesView<'_>, y: f32, dim: usize) -> Result<()> {
    if x.dim() != dim {
        return Err(Error::config(format!(
            "example has dimension {} but the model expects {dim}",
            x.dim()
        )));
    }
    if !x.is_finite() {
        return Err(Error::data("example has non-finite feature values"));
    }
    if y != 1.0 && y != -1.0 {
        return Err(Error::data(format!("label must be ±1, got {y}")));
    }
    Ok(())
}

/// The feature-hashing front-end a model was trained behind: inputs are
/// folded into `dim` buckets by the seeded signed hasher
/// ([`crate::data::hashing::FeatureHasher`]). Two models (or a
/// checkpoint and its resume stream) live in the same geometry only if
/// `(dim, seed)` match exactly, so the pair rides in [`TrainOptions`]
/// and is serialized into `.meb` provenance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HashSpec {
    /// Hashed feature dimension `D`.
    pub dim: usize,
    /// Hash seed (determines both bucket and sign functions).
    pub seed: u64,
}

/// Shared training options for all StreamSVM variants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainOptions {
    /// Misclassification cost `C` of the ℓ₂-SVM.
    pub c: f64,
    /// Slack bookkeeping convention.
    pub slack_mode: SlackMode,
    /// Lookahead buffer size `L` for Algorithm 2 (`1` = Algorithm 1).
    pub lookahead: usize,
    /// Badoiu-Clarkson iterations for the lookahead merge solve.
    pub merge_iters: usize,
    /// Feature-hashing front-end, if the stream was hashed on ingest.
    pub hash: Option<HashSpec>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            c: 1.0,
            slack_mode: SlackMode::Consistent,
            lookahead: 1,
            merge_iters: 128,
            hash: None,
        }
    }
}

impl TrainOptions {
    pub fn with_c(mut self, c: f64) -> Self {
        self.c = c;
        self
    }

    pub fn with_lookahead(mut self, l: usize) -> Self {
        self.lookahead = l;
        self
    }

    pub fn with_slack_mode(mut self, m: SlackMode) -> Self {
        self.slack_mode = m;
        self
    }

    pub fn with_hash(mut self, h: Option<HashSpec>) -> Self {
        self.hash = h;
        self
    }

    /// `1/C`, the constant term inside every distance computation.
    pub fn invc(&self) -> f64 {
        1.0 / self.c
    }

    /// Slack self-norm `s² = ||slack part of φ̃(z)||²` under the chosen
    /// convention.
    pub fn s2(&self) -> f64 {
        match self.slack_mode {
            SlackMode::Paper => 1.0,
            SlackMode::Consistent => 1.0 / self.c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s2_conventions() {
        let p = TrainOptions::default().with_c(4.0).with_slack_mode(SlackMode::Paper);
        assert_eq!(p.s2(), 1.0);
        assert_eq!(p.invc(), 0.25);
        let c = p.with_slack_mode(SlackMode::Consistent);
        assert_eq!(c.s2(), 0.25);
    }

    #[test]
    fn conventions_coincide_at_c1() {
        let p = TrainOptions::default().with_slack_mode(SlackMode::Paper);
        let c = TrainOptions::default().with_slack_mode(SlackMode::Consistent);
        assert_eq!(p.s2(), c.s2());
    }
}
