//! The variant seam: one trait for the five streaming learners, plus an
//! enum for static dispatch at layer boundaries.
//!
//! Every production layer (server, sketch codec, pipeline, sharded
//! coordinator, CLI) used to name `StreamSvm` concretely, so only
//! Algorithm 1 could serve traffic or checkpoint. [`StreamLearner`]
//! captures the surface they actually need — observe, score, provenance
//! — with the shared input-validation guard as a default method, and
//! [`AnyLearner`] packages the five implementations behind one concrete
//! type *without* virtual dispatch: every method is an inlined `match`,
//! so the per-example hot path costs a predictable branch, not a vtable
//! load (the sparse-bench speedup gates hold through this seam).

use std::fmt;
use std::str::FromStr;

use crate::data::FeaturesView;
use crate::error::{Error, Result};
use crate::eval::Classifier;
use crate::svm::ball::BallState;
use crate::svm::ellipsoid::EllipsoidSvm;
use crate::svm::kernelfn::Kernel;
use crate::svm::kernelized::KernelStreamSvm;
use crate::svm::lookahead::LookaheadSvm;
use crate::svm::multiball::{MergePolicy, MultiBallSvm};
use crate::svm::streamsvm::StreamSvm;
use crate::svm::{validate_example, TrainOptions};

/// Default ball budget when a multiball learner is constructed through
/// [`AnyLearner::new`] (CLI / server paths that only pick a variant).
pub const DEFAULT_MAX_BALLS: usize = 8;

/// Which of the paper's algorithm family a learner implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Algorithm 1: single ball, immediate updates.
    Ball,
    /// Algorithm 2: lookahead buffer merged in batches.
    Lookahead,
    /// §4.2: kernelized MEB over a coreset of support points.
    Kernelized,
    /// §6.2: diagonal-metric (ellipsoid) generalization.
    Ellipsoid,
    /// §4.3: bounded set of balls with merge policies.
    Multiball,
}

impl Variant {
    /// All variants, in tag order.
    pub const ALL: [Variant; 5] = [
        Variant::Ball,
        Variant::Lookahead,
        Variant::Kernelized,
        Variant::Ellipsoid,
        Variant::Multiball,
    ];

    /// The canonical CLI / provenance name.
    pub fn name(self) -> &'static str {
        match self {
            Variant::Ball => "ball",
            Variant::Lookahead => "lookahead",
            Variant::Kernelized => "kernelized",
            Variant::Ellipsoid => "ellipsoid",
            Variant::Multiball => "multiball",
        }
    }

    /// The `.meb` wire tag (v4 provenance byte). Stable: new variants
    /// append, existing values never change.
    pub fn tag(self) -> u8 {
        match self {
            Variant::Ball => 0,
            Variant::Lookahead => 1,
            Variant::Kernelized => 2,
            Variant::Ellipsoid => 3,
            Variant::Multiball => 4,
        }
    }

    /// Decode a `.meb` wire tag.
    pub fn from_tag(t: u8) -> Result<Variant> {
        Variant::ALL
            .into_iter()
            .find(|v| v.tag() == t)
            .ok_or_else(|| Error::sketch(format!("unknown variant tag {t}")))
    }
}

impl fmt::Display for Variant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Variant {
    type Err = Error;

    fn from_str(s: &str) -> Result<Variant> {
        Variant::ALL.into_iter().find(|v| v.name() == s).ok_or_else(|| {
            Error::config(format!(
                "unknown variant `{s}` (expected ball|lookahead|kernelized|ellipsoid|multiball)"
            ))
        })
    }
}

/// The surface every streaming MEB/SVM variant exposes to the stack.
///
/// `try_observe` is the validated entry point the server / pipeline /
/// CLI layers call; its default body holds the guard logic that used to
/// be hand-copied into every variant (dimension check → `Error::Config`,
/// non-finite features and non-±1 labels → `Error::Data`, rejected
/// examples consume no stream position). `observe_view` is the
/// pre-validated hot path; each variant additionally skips (never
/// panics on) non-finite inputs there so raw streams degrade gracefully.
pub trait StreamLearner: Classifier {
    /// Which algorithm this learner implements (snapshot provenance).
    fn variant(&self) -> Variant;

    /// Expected feature dimension. Kernelized learners pin this lazily
    /// from the first example; see [`KernelStreamSvm`].
    fn dim(&self) -> usize;

    /// The shared hyperparameters.
    fn options(&self) -> &TrainOptions;

    /// Feed one pre-validated example. Returns `true` when the example
    /// changed (or was buffered into) the model, `false` when it was
    /// already enclosed or skipped.
    fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool;

    /// Validate then observe: the layer-boundary entry point. Rejected
    /// examples consume no stream position.
    fn try_observe(&mut self, x: FeaturesView<'_>, y: f32) -> Result<bool> {
        validate_example(x, y, StreamLearner::dim(self))?;
        Ok(self.observe_view(x, y))
    }

    /// Decision value for one example (same contract as
    /// [`Classifier::score_view`]; provided so generic layers need only
    /// this trait in scope).
    fn score_view(&self, x: FeaturesView<'_>) -> f64 {
        Classifier::score_view(self, x)
    }

    /// Current enclosing radius (0 before the first example; for
    /// multiball, the largest live ball).
    fn radius(&self) -> f64;

    /// Current slack mass ξ² (the σ² floor before the first example).
    fn xi2(&self) -> f64;

    /// Examples consumed from the stream (including enclosed/skipped).
    fn examples_seen(&self) -> usize;

    /// Points absorbed into the model (coreset / center mass).
    fn num_support(&self) -> usize;

    /// Finalize any deferred state (flush lookahead buffers, fold
    /// multiball covers). Idempotent; a no-op for most variants.
    fn finish(&mut self) {}

    /// A single-ball summary of the current model, when one exists:
    /// this is what the sharded coordinator's merge tree aggregates, so
    /// cross-shard merging stays agnostic to the per-shard learner.
    /// `None` when the model cannot be summarized by one ball (a
    /// non-linear kernelized learner, or an empty model).
    fn summary_ball(&self) -> Option<BallState>;
}

/// One of the five learners, statically dispatched. Every method is an
/// inlined `match` over the variants — no `dyn`, no allocation — so the
/// layers can hold "some learner" without taxing the per-example path.
#[derive(Clone, Debug)]
pub enum AnyLearner {
    Ball(StreamSvm),
    Lookahead(LookaheadSvm),
    Kernelized(KernelStreamSvm),
    Ellipsoid(EllipsoidSvm),
    Multiball(MultiBallSvm),
}

macro_rules! dispatch {
    ($self:expr, $m:pat => $body:expr) => {
        match $self {
            AnyLearner::Ball($m) => $body,
            AnyLearner::Lookahead($m) => $body,
            AnyLearner::Kernelized($m) => $body,
            AnyLearner::Ellipsoid($m) => $body,
            AnyLearner::Multiball($m) => $body,
        }
    };
}

impl AnyLearner {
    /// Construct a fresh learner of `variant` with default shape knobs
    /// (linear kernel, [`DEFAULT_MAX_BALLS`] / nearest-ball policy).
    pub fn new(variant: Variant, dim: usize, opts: TrainOptions) -> AnyLearner {
        AnyLearner::with_kernel(variant, dim, opts, Kernel::Linear)
    }

    /// [`AnyLearner::new`] with an explicit kernel for the kernelized
    /// variant (ignored by the linear variants).
    pub fn with_kernel(
        variant: Variant,
        dim: usize,
        opts: TrainOptions,
        kernel: Kernel,
    ) -> AnyLearner {
        match variant {
            Variant::Ball => AnyLearner::Ball(StreamSvm::new(dim, opts)),
            Variant::Lookahead => {
                let opts =
                    if opts.lookahead > 1 { opts } else { opts.with_lookahead(8) };
                AnyLearner::Lookahead(LookaheadSvm::new(dim, opts))
            }
            Variant::Kernelized => {
                AnyLearner::Kernelized(KernelStreamSvm::with_dim(kernel, dim, opts))
            }
            Variant::Ellipsoid => AnyLearner::Ellipsoid(EllipsoidSvm::new(dim, opts)),
            Variant::Multiball => AnyLearner::Multiball(MultiBallSvm::new(
                dim,
                DEFAULT_MAX_BALLS,
                MergePolicy::NearestBall,
                opts,
            )),
        }
    }

    /// Which algorithm this learner implements.
    #[inline]
    pub fn variant(&self) -> Variant {
        dispatch!(self, m => StreamLearner::variant(m))
    }

    /// Expected feature dimension (0 for an unpinned kernelized model).
    #[inline]
    pub fn dim(&self) -> usize {
        dispatch!(self, m => StreamLearner::dim(m))
    }

    /// The shared hyperparameters.
    #[inline]
    pub fn options(&self) -> &TrainOptions {
        dispatch!(self, m => StreamLearner::options(m))
    }

    /// Feed one pre-validated example; see [`StreamLearner::observe_view`].
    #[inline]
    pub fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        dispatch!(self, m => StreamLearner::observe_view(m, x, y))
    }

    /// Validate then observe; see [`StreamLearner::try_observe`]. Each
    /// variant's own override applies (kernelized pins its dimension
    /// from the first example).
    #[inline]
    pub fn try_observe(&mut self, x: FeaturesView<'_>, y: f32) -> Result<bool> {
        dispatch!(self, m => StreamLearner::try_observe(m, x, y))
    }

    /// Decision value for one example (dense slice).
    #[inline]
    pub fn score(&self, x: &[f32]) -> f64 {
        dispatch!(self, m => Classifier::score(m, x))
    }

    /// Decision value for one example — O(nnz) for sparse views.
    #[inline]
    pub fn score_view(&self, x: FeaturesView<'_>) -> f64 {
        dispatch!(self, m => Classifier::score_view(m, x))
    }

    /// Current enclosing radius.
    #[inline]
    pub fn radius(&self) -> f64 {
        dispatch!(self, m => StreamLearner::radius(m))
    }

    /// Current slack mass ξ².
    #[inline]
    pub fn xi2(&self) -> f64 {
        dispatch!(self, m => StreamLearner::xi2(m))
    }

    /// Examples consumed from the stream.
    #[inline]
    pub fn examples_seen(&self) -> usize {
        dispatch!(self, m => StreamLearner::examples_seen(m))
    }

    /// Points absorbed into the model.
    #[inline]
    pub fn num_support(&self) -> usize {
        dispatch!(self, m => StreamLearner::num_support(m))
    }

    /// Finalize deferred state; see [`StreamLearner::finish`].
    pub fn finish(&mut self) {
        dispatch!(self, m => StreamLearner::finish(m))
    }

    /// A single-ball summary, when one exists.
    pub fn summary_ball(&self) -> Option<BallState> {
        dispatch!(self, m => StreamLearner::summary_ball(m))
    }

    /// Dense primal weights, when the model has them (`None` for a
    /// non-linear kernelized learner).
    pub fn weights(&self) -> Option<Vec<f32>> {
        match self {
            AnyLearner::Ball(m) => Some(m.weights()),
            AnyLearner::Lookahead(m) => Some(m.weights()),
            AnyLearner::Kernelized(m) => m.linear_weights(),
            AnyLearner::Ellipsoid(m) => Some(m.weights()),
            AnyLearner::Multiball(m) => {
                Some(m.merged_ball().map(|b| b.weights()).unwrap_or_default())
            }
        }
    }

    /// Train a fresh learner over a stream (validation skipped: the
    /// stream is trusted, mirroring the per-variant `fit` helpers).
    pub fn fit<'a, I>(stream: I, variant: Variant, dim: usize, opts: TrainOptions) -> AnyLearner
    where
        I: IntoIterator<Item = &'a crate::data::Example>,
    {
        let mut m = AnyLearner::new(variant, dim, opts);
        for e in stream {
            m.observe_view(e.x.view(), e.y);
        }
        m.finish();
        m
    }
}

impl StreamLearner for AnyLearner {
    fn variant(&self) -> Variant {
        AnyLearner::variant(self)
    }
    fn dim(&self) -> usize {
        AnyLearner::dim(self)
    }
    fn options(&self) -> &TrainOptions {
        AnyLearner::options(self)
    }
    #[inline]
    fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        AnyLearner::observe_view(self, x, y)
    }
    #[inline]
    fn try_observe(&mut self, x: FeaturesView<'_>, y: f32) -> Result<bool> {
        AnyLearner::try_observe(self, x, y)
    }
    fn radius(&self) -> f64 {
        AnyLearner::radius(self)
    }
    fn xi2(&self) -> f64 {
        AnyLearner::xi2(self)
    }
    fn examples_seen(&self) -> usize {
        AnyLearner::examples_seen(self)
    }
    fn num_support(&self) -> usize {
        AnyLearner::num_support(self)
    }
    fn finish(&mut self) {
        AnyLearner::finish(self)
    }
    fn summary_ball(&self) -> Option<BallState> {
        AnyLearner::summary_ball(self)
    }
}

impl Classifier for AnyLearner {
    #[inline]
    fn score(&self, x: &[f32]) -> f64 {
        AnyLearner::score(self, x)
    }
    #[inline]
    fn score_view(&self, x: FeaturesView<'_>) -> f64 {
        AnyLearner::score_view(self, x)
    }
}

impl From<StreamSvm> for AnyLearner {
    fn from(m: StreamSvm) -> AnyLearner {
        AnyLearner::Ball(m)
    }
}
impl From<LookaheadSvm> for AnyLearner {
    fn from(m: LookaheadSvm) -> AnyLearner {
        AnyLearner::Lookahead(m)
    }
}
impl From<KernelStreamSvm> for AnyLearner {
    fn from(m: KernelStreamSvm) -> AnyLearner {
        AnyLearner::Kernelized(m)
    }
}
impl From<EllipsoidSvm> for AnyLearner {
    fn from(m: EllipsoidSvm) -> AnyLearner {
        AnyLearner::Ellipsoid(m)
    }
}
impl From<MultiBallSvm> for AnyLearner {
    fn from(m: MultiBallSvm) -> AnyLearner {
        AnyLearner::Multiball(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Example;
    use crate::prop::gen;
    use crate::rng::Pcg32;

    fn toy(n: usize, d: usize, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, 0.8);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    #[test]
    fn variant_names_tags_roundtrip() {
        for v in Variant::ALL {
            assert_eq!(v.name().parse::<Variant>().unwrap(), v);
            assert_eq!(Variant::from_tag(v.tag()).unwrap(), v);
            assert_eq!(format!("{v}"), v.name());
        }
        assert!(matches!("blurred".parse::<Variant>(), Err(Error::Config(_))));
        assert!(matches!(Variant::from_tag(200), Err(Error::Sketch(_))));
    }

    #[test]
    fn any_learner_matches_concrete_per_variant() {
        let exs = toy(150, 4, 7);
        let opts = TrainOptions::default();
        let probe = [0.4f32, -0.2, 0.9, 0.1];
        for v in Variant::ALL {
            let mut any = AnyLearner::new(v, 4, opts);
            for e in &exs {
                any.try_observe(e.x.view(), e.y).unwrap();
            }
            assert_eq!(any.variant(), v);
            assert_eq!(any.examples_seen(), exs.len());
            // the concrete twin, driven through its own surface
            let score = match v {
                Variant::Ball => {
                    let mut m = StreamSvm::new(4, opts);
                    for e in &exs {
                        m.observe_view(e.x.view(), e.y);
                    }
                    assert_eq!(any.radius().to_bits(), m.radius().to_bits());
                    Classifier::score(&m, &probe)
                }
                Variant::Lookahead => {
                    let mut m = LookaheadSvm::new(4, opts.with_lookahead(8));
                    for e in &exs {
                        m.observe_view(e.x.view(), e.y);
                    }
                    assert_eq!(any.radius().to_bits(), m.radius().to_bits());
                    Classifier::score(&m, &probe)
                }
                Variant::Kernelized => {
                    let mut m = KernelStreamSvm::with_dim(Kernel::Linear, 4, opts);
                    for e in &exs {
                        m.observe_view(e.x.view(), e.y);
                    }
                    assert_eq!(any.radius().to_bits(), m.radius().to_bits());
                    Classifier::score(&m, &probe)
                }
                Variant::Ellipsoid => {
                    let mut m = EllipsoidSvm::new(4, opts);
                    for e in &exs {
                        m.observe_view(e.x.view(), e.y);
                    }
                    assert_eq!(any.radius().to_bits(), m.radius().to_bits());
                    Classifier::score(&m, &probe)
                }
                Variant::Multiball => {
                    let mut m = MultiBallSvm::new(
                        4,
                        DEFAULT_MAX_BALLS,
                        MergePolicy::NearestBall,
                        opts,
                    );
                    for e in &exs {
                        m.observe_view(e.x.view(), e.y);
                    }
                    Classifier::score(&m, &probe)
                }
            };
            assert_eq!(
                any.score(&probe).to_bits(),
                score.to_bits(),
                "score diverged for {v}"
            );
        }
    }

    #[test]
    fn default_try_observe_rejection_contract() {
        let opts = TrainOptions::default();
        for v in Variant::ALL {
            let mut m = AnyLearner::new(v, 3, opts);
            // dimension mismatch → Config, and no stream position consumed
            let err = m
                .try_observe(crate::data::FeaturesView::Dense(&[1.0, 2.0]), 1.0)
                .unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{v}: {err}");
            // non-finite features / bad labels → Data
            let err = m
                .try_observe(crate::data::FeaturesView::Dense(&[1.0, f32::NAN, 0.0]), 1.0)
                .unwrap_err();
            assert!(matches!(err, Error::Data(_)), "{v}: {err}");
            let err = m
                .try_observe(crate::data::FeaturesView::Dense(&[1.0, 2.0, 3.0]), 0.5)
                .unwrap_err();
            assert!(matches!(err, Error::Data(_)), "{v}: {err}");
            assert_eq!(m.examples_seen(), 0, "{v} consumed a rejected example");
        }
    }

    #[test]
    fn kernelized_try_observe_pins_dim_from_first_example() {
        let opts = TrainOptions::default();
        let mut m: AnyLearner = KernelStreamSvm::new(Kernel::Linear, opts).into();
        assert_eq!(m.dim(), 0);
        m.try_observe(crate::data::FeaturesView::Dense(&[1.0, 2.0]), 1.0).unwrap();
        assert_eq!(m.dim(), 2);
        let err =
            m.try_observe(crate::data::FeaturesView::Dense(&[1.0, 2.0, 3.0]), 1.0).unwrap_err();
        assert!(matches!(err, Error::Config(_)));
    }

    #[test]
    fn summary_ball_exists_for_linear_variants() {
        let exs = toy(80, 3, 11);
        let opts = TrainOptions::default();
        for v in Variant::ALL {
            let mut m = AnyLearner::fit(exs.iter(), v, 3, opts);
            m.finish();
            let b = m.summary_ball().expect("linear variant has a summary ball");
            assert!(b.r.is_finite() && b.r >= 0.0, "{v}");
            assert_eq!(b.dim(), 3, "{v}");
        }
        // a non-linear kernelized model has no primal summary
        let mut rbf: AnyLearner =
            KernelStreamSvm::with_dim(Kernel::Rbf { gamma: 0.5 }, 3, opts).into();
        for e in &exs {
            rbf.observe_view(e.x.view(), e.y);
        }
        assert!(rbf.summary_ball().is_none());
        assert!(rbf.weights().is_none());
    }
}
