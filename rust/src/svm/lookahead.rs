//! Algorithm 2: StreamSVM with lookahead L.
//!
//! Buffers up to `L` points that fall outside the current ball; when the
//! buffer fills, merges (ball ∪ buffer) into a single ball via the MEB
//! solve of [`crate::svm::meb::solve_merge`] (the paper solves a small
//! QP; we use the equivalent Badoiu-Clarkson coefficient solve, whose
//! enclosure is guaranteed by construction). `L = 1` short-circuits to
//! the closed-form Algorithm-1 update, exactly as the paper notes.

use crate::data::{Example, Features, FeaturesView};
use crate::eval::Classifier;
use crate::svm::ball::BallState;
use crate::svm::learner::{StreamLearner, Variant};
use crate::svm::meb::solve_merge_into;
use crate::svm::streamsvm::StreamSvm;
use crate::svm::TrainOptions;

/// A StreamSVM-with-lookahead model (Algorithm 2).
#[derive(Clone, Debug)]
pub struct LookaheadSvm {
    ball: Option<BallState>,
    /// Buffered survivors in their arriving representation — sparse rows
    /// stay sparse, so the merge solve is O(L²·nnz), not O(L²·D).
    buf_x: Vec<Features>,
    buf_y: Vec<f32>,
    opts: TrainOptions,
    dim: usize,
    seen: usize,
    merges: usize,
}

impl LookaheadSvm {
    pub fn new(dim: usize, opts: TrainOptions) -> Self {
        assert!(opts.lookahead >= 1, "lookahead must be >= 1");
        LookaheadSvm {
            ball: None,
            buf_x: Vec::with_capacity(opts.lookahead),
            buf_y: Vec::with_capacity(opts.lookahead),
            opts,
            dim,
            seen: 0,
        merges: 0,
        }
    }

    /// Rebuild a learner mid-stream from checkpointed state: `ball` as
    /// it stood at the buffer-empty stream position `seen` (the only
    /// positions the sketch checkpointer snapshots), with `merges` QP
    /// solves already performed. Continuing the stream from `seen`
    /// reproduces an uninterrupted run exactly — including the paper's
    /// O(N/L) merge count, which a zeroed counter used to misreport.
    pub fn from_ball(
        dim: usize,
        opts: TrainOptions,
        ball: BallState,
        seen: usize,
        merges: usize,
    ) -> Self {
        assert!(opts.lookahead >= 1, "lookahead must be >= 1");
        LookaheadSvm {
            ball: Some(ball),
            buf_x: Vec::with_capacity(opts.lookahead),
            buf_y: Vec::with_capacity(opts.lookahead),
            opts,
            dim,
            seen,
            merges,
        }
    }

    /// Stream one example (Algorithm 2 lines 3–9). Returns `true` when
    /// the example seeded the ball, was absorbed, or was buffered.
    pub fn observe(&mut self, x: &[f32], y: f32) -> bool {
        self.observe_view(FeaturesView::Dense(x), y)
    }

    /// [`Self::observe`] for a dense-or-sparse feature view: the
    /// enclosure test is O(nnz), and buffered survivors keep their
    /// representation (no densify) for the sparse merge solve.
    pub fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        debug_assert_eq!(x.dim(), self.dim);
        self.seen += 1;
        let Some(ball) = &mut self.ball else {
            if !x.is_finite() {
                // keep NaN out of a fresh ball's center
                debug_assert!(false, "non-finite features in LookaheadSvm::observe");
                return false;
            }
            self.ball = Some(BallState::init_view(x, y, &self.opts));
            if crate::obs::telemetry_on() {
                crate::obs::telemetry::record_example(true);
            }
            return true;
        };
        let d = ball.distance_view(x, y, &self.opts);
        if !d.is_finite() {
            // Same skip-and-surface path as BallState::try_update_view: a
            // NaN/Inf example must not reach the buffer — one poisoned
            // survivor would NaN the merge Gram and the merged center
            // forever (and get persisted into snapshots).
            debug_assert!(false, "non-finite distance in LookaheadSvm::observe (d = {d})");
            return false;
        }
        if d < ball.r {
            if crate::obs::telemetry_on() {
                crate::obs::telemetry::record_example(false);
            }
            return false; // enclosed: discard
        }
        if self.opts.lookahead == 1 {
            // L = 1 degenerates to the closed-form Algorithm-1 update.
            let updated = ball.try_update_view(x, y, &self.opts);
            if crate::obs::telemetry_on() {
                crate::obs::telemetry::record_example(updated);
                crate::obs::telemetry::RADIUS.set(ball.r);
                crate::obs::telemetry::WNORM.set(ball.wnorm());
            }
            return updated;
        }
        self.buf_x.push(x.to_features());
        self.buf_y.push(y);
        if crate::obs::telemetry_on() {
            // An escaped (buffered) point is Algorithm 2's violation event.
            crate::obs::telemetry::record_example(true);
            crate::obs::telemetry::LOOKAHEAD_BUFFERED.set(self.buf_x.len() as f64);
        }
        if self.buf_x.len() == self.opts.lookahead {
            self.flush();
        }
        true
    }

    /// Merge any buffered points into the ball (Algorithm 2 lines 12–14;
    /// called automatically when the buffer fills and by [`Self::finish`]).
    pub fn flush(&mut self) {
        if self.buf_x.is_empty() {
            return;
        }
        let ball = self.ball.as_mut().expect("buffer implies an initialized ball");
        let views: Vec<FeaturesView> = self.buf_x.iter().map(|f| f.view()).collect();
        let telemetry = crate::obs::telemetry_on();
        let t0 = if telemetry { Some(std::time::Instant::now()) } else { None };
        {
            // Span-tree node for the Algorithm-2 merge (the hot-loop
            // phase `train --profile-out` and `/debug/trace` surface).
            let _span = crate::obs::span("svm", "merge").field("buffered", self.buf_x.len());
            solve_merge_into(ball, &views, &self.buf_y, &self.opts);
        }
        if let Some(t0) = t0 {
            crate::obs::telemetry::MERGES.inc();
            crate::obs::telemetry::MERGE_NS.add(t0.elapsed().as_nanos() as u64);
            crate::obs::telemetry::LOOKAHEAD_BUFFERED.set(0.0);
            crate::obs::telemetry::RADIUS.set(ball.r);
            crate::obs::telemetry::WNORM.set(ball.wnorm());
            crate::obs_trace!(
                "svm";
                buffered = self.buf_x.len(),
                radius = ball.r;
                "merged lookahead buffer"
            );
        }
        self.buf_x.clear();
        self.buf_y.clear();
        self.merges += 1;
    }

    /// End-of-stream: flush the partial buffer. Idempotent.
    pub fn finish(&mut self) {
        self.flush();
    }

    /// The equivalent Algorithm-1 view of the current state (ball +
    /// stream position) as a [`StreamSvm`] — the shape sketches, the
    /// serving layer and the CLI consume. Callers should [`Self::finish`]
    /// first; a non-empty buffer is not part of the ball.
    pub fn to_stream_svm(&self) -> StreamSvm {
        debug_assert!(self.buf_x.is_empty(), "to_stream_svm with buffered survivors");
        let mut out = StreamSvm::new(self.dim, self.opts);
        if let Some(b) = &self.ball {
            out.set_ball(b.clone(), self.seen);
        }
        out
    }

    /// One-pass training over a slice/iterator.
    pub fn fit<'a, I: IntoIterator<Item = &'a Example>>(
        stream: I,
        dim: usize,
        opts: &TrainOptions,
    ) -> Self {
        let mut model = LookaheadSvm::new(dim, *opts);
        for e in stream {
            model.observe_view(e.x.view(), e.y);
        }
        model.finish();
        model
    }

    pub fn weights(&self) -> Vec<f32> {
        self.ball.as_ref().map(|b| b.weights()).unwrap_or_default()
    }

    pub fn radius(&self) -> f64 {
        self.ball.as_ref().map(|b| b.r).unwrap_or(0.0)
    }

    /// Upper bound on SV count (M in Algorithm 2).
    pub fn num_support(&self) -> usize {
        self.ball.as_ref().map(|b| b.m).unwrap_or(0) + self.buf_x.len()
    }

    /// Number of QP/merge solves performed (the paper's O(N/L) bound).
    pub fn num_merges(&self) -> usize {
        self.merges
    }

    pub fn examples_seen(&self) -> usize {
        self.seen
    }

    pub fn ball(&self) -> Option<&BallState> {
        self.ball.as_ref()
    }

    /// Number of points currently buffered (for tests / introspection).
    pub fn buffered(&self) -> usize {
        self.buf_x.len()
    }
}

impl Classifier for LookaheadSvm {
    fn score(&self, x: &[f32]) -> f64 {
        match &self.ball {
            Some(b) => b.score(x),
            None => 0.0,
        }
    }

    fn score_view(&self, x: crate::data::FeaturesView<'_>) -> f64 {
        match &self.ball {
            Some(b) => b.score_view(x),
            None => 0.0,
        }
    }
}

/// Validated observation (`try_observe`) comes from the trait's default
/// body — the guard logic lives once, in [`crate::svm::learner`].
impl StreamLearner for LookaheadSvm {
    fn variant(&self) -> Variant {
        Variant::Lookahead
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn options(&self) -> &TrainOptions {
        &self.opts
    }

    #[inline]
    fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        LookaheadSvm::observe_view(self, x, y)
    }

    fn radius(&self) -> f64 {
        LookaheadSvm::radius(self)
    }

    fn xi2(&self) -> f64 {
        self.ball.as_ref().map(|b| b.xi2).unwrap_or_else(|| self.opts.s2())
    }

    fn examples_seen(&self) -> usize {
        self.seen
    }

    fn num_support(&self) -> usize {
        LookaheadSvm::num_support(self)
    }

    /// Flush the partial lookahead buffer.
    fn finish(&mut self) {
        LookaheadSvm::finish(self)
    }

    /// The current ball; buffered-but-unmerged survivors are not part of
    /// it, so call [`StreamLearner::finish`] first for a complete summary.
    fn summary_ball(&self) -> Option<BallState> {
        self.ball.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check_default, gen};
    use crate::rng::Pcg32;
    use crate::svm::streamsvm::StreamSvm;

    fn stream(n: usize, d: usize, sep: f64, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, sep);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    #[test]
    fn l1_equals_algorithm1_exactly() {
        check_default("algo2-l1-equals-algo1", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 64, d, 1.0, 0.3);
            let opts = TrainOptions::default().with_lookahead(1);
            let mut a1 = StreamSvm::new(d, opts);
            let mut a2 = LookaheadSvm::new(d, opts);
            for (x, y) in xs.iter().zip(&ys) {
                a1.observe(x, *y);
                a2.observe(x, *y);
            }
            a2.finish();
            if a1.weights() != a2.weights() || a1.radius() != a2.radius() {
                return Err("L=1 diverged from Algorithm 1".into());
            }
            Ok(())
        });
    }

    #[test]
    fn buffer_flushes_at_l() {
        // Adversarial stream where every point escapes the ball: the
        // buffer must flush exactly every L points.
        let opts = TrainOptions::default().with_lookahead(4);
        let mut m = LookaheadSvm::new(1, opts);
        for i in 0..13 {
            // exponentially growing points always escape
            m.observe(&[2.0f32.powi(i)], 1.0);
        }
        assert!(m.buffered() < 4);
        assert!(m.num_merges() >= 2, "merges = {}", m.num_merges());
        m.finish();
        assert_eq!(m.buffered(), 0);
    }

    #[test]
    fn nan_features_never_reach_the_buffer() {
        // Regression: a NaN feature's distance is NaN, `d < r` is false,
        // and L > 1 used to buffer the poisoned survivor — the next
        // flush then wrote NaN into (w, R, ξ²) forever. The guarded path
        // skips it (debug builds assert with an explicit message).
        let mk = || {
            let mut m = LookaheadSvm::new(1, TrainOptions::default().with_lookahead(4));
            m.observe(&[1.0], 1.0);
            m.observe(&[4.0], 1.0);
            m
        };
        if cfg!(debug_assertions) {
            let r = std::panic::catch_unwind(|| {
                let mut m = mk();
                m.observe(&[f32::NAN], 1.0);
            });
            let payload = r.expect_err("debug build should assert");
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            assert!(msg.contains("non-finite"), "unexpected panic: {msg}");
        } else {
            let mut m = mk();
            let buffered = m.buffered();
            m.observe(&[f32::NAN], 1.0);
            assert_eq!(m.buffered(), buffered, "NaN example reached the buffer");
            m.observe(&[8.0], 1.0);
            m.finish();
            assert!(m.radius().is_finite());
            assert!(m.weights()[0].is_finite(), "NaN poisoned the merged center");
            // a NaN first example must not seed the ball either
            let mut m = LookaheadSvm::new(1, TrainOptions::default().with_lookahead(4));
            m.observe(&[f32::NAN], 1.0);
            assert!(m.ball().is_none());
        }
        // the validated entry point surfaces the defect as an error
        let mut m = mk();
        let err = m.try_observe(crate::data::FeaturesView::Dense(&[f32::NAN]), 1.0).unwrap_err();
        assert!(matches!(err, crate::error::Error::Data(_)), "{err}");
        let err = m.try_observe(crate::data::FeaturesView::Dense(&[1.0, 2.0]), 1.0).unwrap_err();
        assert!(matches!(err, crate::error::Error::Config(_)), "{err}");
    }

    #[test]
    fn finish_is_idempotent() {
        let train = stream(200, 3, 0.5, 1);
        let mut m = LookaheadSvm::new(3, TrainOptions::default().with_lookahead(8));
        for e in &train {
            m.observe_view(e.x.view(), e.y);
        }
        m.finish();
        let w = m.weights().to_vec();
        let r = m.radius();
        m.finish();
        assert_eq!(m.weights(), w.as_slice());
        assert_eq!(m.radius(), r);
    }

    #[test]
    fn radius_monotone_across_merges() {
        check_default("algo2-radius-monotone", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 96, d, 1.5, 0.3);
            let opts = TrainOptions::default().with_lookahead(1 + rng.below(10));
            let mut m = LookaheadSvm::new(d, opts);
            let mut prev = 0.0;
            for (x, y) in xs.iter().zip(&ys) {
                m.observe(x, *y);
                let r = m.radius();
                if r < prev - 1e-9 {
                    return Err(format!("radius shrank {prev} -> {r}"));
                }
                prev = r;
            }
            m.finish();
            if m.radius() < prev - 1e-9 {
                return Err("finish shrank the radius".into());
            }
            Ok(())
        });
    }

    #[test]
    fn merge_count_bounded_by_n_over_l() {
        let train = stream(1000, 5, 0.5, 2);
        for l in [2usize, 5, 10, 50] {
            let m = LookaheadSvm::fit(train.iter(), 5, &TrainOptions::default().with_lookahead(l));
            assert!(
                m.num_merges() <= train.len() / l + 1,
                "L={l}: merges {} > N/L",
                m.num_merges()
            );
        }
    }
}
