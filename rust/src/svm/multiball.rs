//! The multiple-balls extension (paper §4.3, general case of lookahead).
//!
//! Maintains up to `L` balls simultaneously, `L(D+1)` floats of state,
//! still one pass. Arriving points already enclosed by *any* ball are
//! discarded; otherwise a policy decides how the L+1 entities (L balls +
//! point) collapse back to at most L. At end-of-stream the surviving
//! balls are merged pairwise into the final MEB, whose center is the SVM
//! weight vector.
//!
//! Ball–ball merging uses the closed-form two-ball MEB: for centers
//! distance `t` apart, the enclosing ball has radius `(r₁+r₂+t)/2` and
//! center on the segment (or the larger ball if it already contains the
//! other). Slack masses of distinct balls live on disjoint stream indices
//! and are orthogonal, so `t² = ||w₁−w₂||² + ξ₁² + ξ₂²`.

use crate::data::{Example, FeaturesView};
use crate::eval::Classifier;
use crate::svm::ball::BallState;
use crate::svm::learner::{StreamLearner, Variant};
use crate::svm::TrainOptions;

/// How to collapse L+1 entities back to L when a new point escapes all
/// balls (ablation surface for the paper's open question in §6.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergePolicy {
    /// Update the nearest ball with the Algorithm-1 closed form.
    NearestBall,
    /// Open a new zero-radius ball; if that exceeds L, first merge the
    /// two closest balls.
    NewBallMergeClosest,
}

/// Multi-ball StreamSVM.
#[derive(Clone, Debug)]
pub struct MultiBallSvm {
    balls: Vec<BallState>,
    max_balls: usize,
    policy: MergePolicy,
    opts: TrainOptions,
    dim: usize,
    seen: usize,
    /// Cached final merged ball (invalidated on observe).
    merged: Option<BallState>,
}

/// Augmented-space distance between two ball centers (no
/// materialization of either weight vector).
fn center_dist(a: &BallState, b: &BallState) -> f64 {
    (a.center_diff_norm2(b) + a.xi2 + b.xi2).sqrt()
}

/// Closed-form MEB of two balls; also returns the blend weight λ
/// (center = (1−λ)·c_a + λ·c_b; λ·t = r − r_a exactly, which is the
/// enclosure proof). Public so the sketch merge tree (and its
/// lifted-space enclosure tests) can reuse the exact geometry.
pub fn merge_two_lambda(a: &BallState, b: &BallState) -> (BallState, f64) {
    let t = center_dist(a, b);
    // containment cases
    if t + b.r <= a.r {
        let mut out = a.clone();
        out.m += b.m;
        return (out, 0.0);
    }
    if t + a.r <= b.r {
        let mut out = b.clone();
        out.m += a.m;
        return (out, 1.0);
    }
    let r = 0.5 * (a.r + b.r + t);
    // center at distance (r - a.r) from a toward b
    let lam = if t > 0.0 { (r - a.r) / t } else { 0.5 };
    let (wa, wb) = (a.weights(), b.weights());
    let w: Vec<f32> = wa
        .iter()
        .zip(&wb)
        .map(|(&x, &y)| ((1.0 - lam) * x as f64 + lam * y as f64) as f32)
        .collect();
    let xi2 = (1.0 - lam) * (1.0 - lam) * a.xi2 + lam * lam * b.xi2;
    (BallState::from_parts(w, r, xi2, a.m + b.m), lam)
}

/// Closed-form MEB of two balls.
pub fn merge_two(a: &BallState, b: &BallState) -> BallState {
    merge_two_lambda(a, b).0
}

impl MultiBallSvm {
    pub fn new(dim: usize, max_balls: usize, policy: MergePolicy, opts: TrainOptions) -> Self {
        assert!(max_balls >= 1);
        MultiBallSvm {
            balls: Vec::with_capacity(max_balls),
            max_balls,
            policy,
            opts,
            dim,
            seen: 0,
            merged: None,
        }
    }

    /// Stream one example. Returns `true` when it seeded/updated a ball,
    /// `false` when it was already enclosed (or skipped as non-finite).
    pub fn observe(&mut self, x: &[f32], y: f32) -> bool {
        self.observe_view(FeaturesView::Dense(x), y)
    }

    /// [`Self::observe`] for a dense-or-sparse feature view — every
    /// enclosure test and the nearest-ball update are O(nnz).
    ///
    /// Non-finite distances (NaN features smuggled past the ingestion
    /// guards) take the same skip-and-surface path as
    /// [`BallState::try_update_view`]: the example is dropped, never
    /// indexed into the ball list. Before this guard, a NaN gap could
    /// never beat the `f64::INFINITY` sentinel, so `NearestBall` panicked
    /// at `self.balls[usize::MAX]`.
    pub fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        debug_assert_eq!(x.dim(), self.dim);
        self.seen += 1;
        self.merged = None;
        // enclosed by any ball?
        let mut nearest = usize::MAX;
        let mut nearest_gap = f64::INFINITY;
        let mut non_finite = false;
        for (i, b) in self.balls.iter().enumerate() {
            let d = b.distance_view(x, y, &self.opts);
            if !d.is_finite() {
                non_finite = true;
                continue;
            }
            if d < b.r {
                self.tap_telemetry(false);
                return false; // discard
            }
            let gap = d - b.r;
            if gap < nearest_gap {
                nearest_gap = gap;
                nearest = i;
            }
        }
        if non_finite && nearest == usize::MAX {
            // Every distance was non-finite: skip the example rather than
            // index self.balls[usize::MAX] or seed a poisoned new ball.
            debug_assert!(false, "non-finite distances in MultiBallSvm::observe");
            return false;
        }
        match self.policy {
            MergePolicy::NearestBall if !self.balls.is_empty() => {
                let updated = self.balls[nearest].try_update_view(x, y, &self.opts);
                self.tap_telemetry(updated);
                updated
            }
            _ => {
                if !x.is_finite() {
                    // No existing ball screened the example (the list may
                    // be empty): keep NaN out of a fresh ball's center.
                    debug_assert!(false, "non-finite features in MultiBallSvm::observe");
                    return false;
                }
                self.balls.push(BallState::init_view(x, y, &self.opts));
                if self.balls.len() > self.max_balls {
                    // Span-tree node for the rare collapse event — the
                    // O(balls² · D) step worth seeing on a timeline.
                    let _span =
                        crate::obs::span("svm", "ball_collapse").field("balls", self.balls.len());
                    while self.balls.len() > self.max_balls {
                        self.collapse_closest_pair();
                    }
                }
                self.tap_telemetry(true);
                true
            }
        }
    }

    /// Training-dynamics tap: one relaxed load when telemetry is off.
    /// Reports the ball count and the largest live radius.
    #[inline]
    fn tap_telemetry(&self, updated: bool) {
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::record_example(updated);
            crate::obs::telemetry::BALLS.set(self.balls.len() as f64);
            let max_r = self.balls.iter().map(|b| b.r).fold(0.0f64, f64::max);
            crate::obs::telemetry::RADIUS.set(max_r);
        }
    }

    fn collapse_closest_pair(&mut self) {
        if self.balls.len() < 2 {
            return;
        }
        let (mut bi, mut bj, mut best) = (0usize, 1usize, f64::INFINITY);
        for i in 0..self.balls.len() {
            for j in (i + 1)..self.balls.len() {
                // cost = radius of the merged ball
                let t = center_dist(&self.balls[i], &self.balls[j]);
                let cost = 0.5 * (self.balls[i].r + self.balls[j].r + t);
                if cost < best {
                    best = cost;
                    bi = i;
                    bj = j;
                }
            }
        }
        let b = self.balls.swap_remove(bj);
        let a = std::mem::replace(&mut self.balls[bi], BallState::zero(self.dim, &self.opts));
        self.balls[bi] = merge_two(&a, &b);
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::MERGES.inc();
        }
    }

    /// Final single ball (merging all survivors); cached.
    pub fn final_ball(&mut self) -> Option<&BallState> {
        if self.merged.is_none() {
            let mut it = self.balls.iter();
            let first = it.next()?.clone();
            let merged = it.fold(first, |acc, b| merge_two(&acc, b));
            self.merged = Some(merged);
        }
        self.merged.as_ref()
    }

    pub fn fit<'a, I: IntoIterator<Item = &'a Example>>(
        stream: I,
        dim: usize,
        max_balls: usize,
        policy: MergePolicy,
        opts: &TrainOptions,
    ) -> Self {
        let mut m = MultiBallSvm::new(dim, max_balls, policy, *opts);
        for e in stream {
            m.observe_view(e.x.view(), e.y);
        }
        m.final_ball();
        m
    }

    pub fn num_balls(&self) -> usize {
        self.balls.len()
    }

    pub fn examples_seen(&self) -> usize {
        self.seen
    }

    pub fn num_support(&self) -> usize {
        self.balls.iter().map(|b| b.m).sum()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The live balls, in creation order.
    pub fn balls(&self) -> &[BallState] {
        &self.balls
    }

    /// The ball budget L.
    pub fn max_balls(&self) -> usize {
        self.max_balls
    }

    pub fn policy(&self) -> MergePolicy {
        self.policy
    }

    /// The cached final merged ball, if [`Self::final_ball`] has run
    /// since the last observation.
    pub fn merged_cached(&self) -> Option<&BallState> {
        self.merged.as_ref()
    }

    /// The fold of all live balls into one, without caching (the `&self`
    /// twin of [`Self::final_ball`], for summary/serialization paths).
    pub fn merged_ball(&self) -> Option<BallState> {
        if let Some(m) = &self.merged {
            return Some(m.clone());
        }
        let mut it = self.balls.iter();
        let first = it.next()?.clone();
        Some(it.fold(first, |acc, b| merge_two(&acc, b)))
    }

    /// Rebuild from exact serialized state (the `.meb` v4 decode path).
    /// The ball list (and the merge cache, when it was serialized) is
    /// bit-copied, so a restored model scores and continues training
    /// identically to the one that was encoded.
    pub fn from_parts(
        dim: usize,
        max_balls: usize,
        policy: MergePolicy,
        opts: TrainOptions,
        balls: Vec<BallState>,
        merged: Option<BallState>,
        seen: usize,
    ) -> Self {
        assert!(max_balls >= 1);
        assert!(balls.len() <= max_balls, "more balls than the budget L");
        MultiBallSvm { balls, max_balls, policy, opts, dim, seen, merged }
    }
}

/// Validated observation (`try_observe`) comes from the trait's default
/// body — the guard logic lives once, in [`crate::svm::learner`].
impl StreamLearner for MultiBallSvm {
    fn variant(&self) -> Variant {
        Variant::Multiball
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn options(&self) -> &TrainOptions {
        &self.opts
    }

    #[inline]
    fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        MultiBallSvm::observe_view(self, x, y)
    }

    /// The merged radius when finalized, else the largest live radius
    /// (the same quantity the telemetry gauge reports mid-stream).
    fn radius(&self) -> f64 {
        if let Some(m) = &self.merged {
            return m.r;
        }
        self.balls.iter().map(|b| b.r).fold(0.0f64, f64::max)
    }

    /// The merged slack mass when finalized, else the sum over live
    /// balls (their slacks live on disjoint stream indices).
    fn xi2(&self) -> f64 {
        if let Some(m) = &self.merged {
            return m.xi2;
        }
        self.balls.iter().map(|b| b.xi2).sum()
    }

    fn examples_seen(&self) -> usize {
        self.seen
    }

    fn num_support(&self) -> usize {
        MultiBallSvm::num_support(self)
    }

    /// Materialize (and cache) the final merged ball.
    fn finish(&mut self) {
        self.final_ball();
    }

    fn summary_ball(&self) -> Option<BallState> {
        self.merged_ball()
    }
}

impl Classifier for MultiBallSvm {
    /// Scores with the merged final ball if available, else the max-margin
    /// vote over live balls.
    fn score(&self, x: &[f32]) -> f64 {
        if let Some(m) = &self.merged {
            return m.score(x);
        }
        self.balls
            .iter()
            .map(|b| b.score(x))
            .max_by(|a, b| a.abs().partial_cmp(&b.abs()).unwrap())
            .unwrap_or(0.0)
    }

    fn score_view(&self, x: crate::data::FeaturesView<'_>) -> f64 {
        if let Some(m) = &self.merged {
            return m.score_view(x);
        }
        self.balls
            .iter()
            .map(|b| b.score_view(x))
            .max_by(|a, b| a.abs().partial_cmp(&b.abs()).unwrap())
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::prop::{check_default, gen};

    #[test]
    fn merge_two_encloses_both() {
        // Verified in an explicit space with one extra dimension per
        // ball's slack mass: a = [w_a; √ξ²_a; 0], b = [w_b; 0; √ξ²_b],
        // merged center m = (1−λ)a + λb. Enclosure: ||m−a|| + r_a ≤ r_m
        // and ||m−b|| + r_b ≤ r_m.
        check_default("two-ball-merge-enclosure", |rng, _| {
            let d = gen::dim(rng);
            let mk = |rng: &mut crate::rng::Pcg32| {
                BallState::from_parts(
                    (0..d).map(|_| rng.normal() as f32 * 2.0).collect(),
                    rng.uniform() * 3.0,
                    rng.uniform(),
                    1,
                )
            };
            let a = mk(rng);
            let b = mk(rng);
            let (m, lam) = merge_two_lambda(&a, &b);
            let lift = |ball: &BallState, sa: f64, sb: f64| -> Vec<f64> {
                let mut v: Vec<f64> = ball.weights().iter().map(|&x| x as f64).collect();
                v.push(sa);
                v.push(sb);
                v
            };
            let ea = lift(&a, a.xi2.sqrt(), 0.0);
            let eb = lift(&b, 0.0, b.xi2.sqrt());
            let em: Vec<f64> = ea
                .iter()
                .zip(&eb)
                .map(|(x, y)| (1.0 - lam) * x + lam * y)
                .collect();
            // merged slack bookkeeping must match the explicit lift
            let slack2 = em[d] * em[d] + em[d + 1] * em[d + 1];
            if (slack2 - m.xi2).abs() > 1e-6 * slack2.max(1.0) {
                return Err(format!("xi2 mismatch: {slack2} vs {}", m.xi2));
            }
            let dist = |p: &[f64], q: &[f64]| -> f64 {
                p.iter().zip(q).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
            };
            for (e, ball) in [(&ea, &a), (&eb, &b)] {
                if dist(&em, e) + ball.r > m.r + 1e-6 {
                    return Err(format!(
                        "ball sticks out: {} + {} > {}",
                        dist(&em, e),
                        ball.r,
                        m.r
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn merge_two_containment_shortcut() {
        let big = BallState::from_parts(vec![0.0, 0.0], 10.0, 0.0, 5);
        let small = BallState::from_parts(vec![1.0, 0.0], 1.0, 0.0, 2);
        let m = merge_two(&big, &small);
        assert_eq!(m.r, 10.0);
        assert_eq!(m.weights(), vec![0.0, 0.0]);
        assert_eq!(m.m, 7);
    }

    #[test]
    fn ball_count_bounded() {
        check_default("multiball-count-bound", |rng, _| {
            let d = gen::dim(rng);
            let l = 1 + rng.below(6);
            let (xs, ys) = gen::labeled_points(rng, 80, d, 1.5, 0.3);
            let mut m = MultiBallSvm::new(d, l, MergePolicy::NewBallMergeClosest, TrainOptions::default());
            for (x, y) in xs.iter().zip(&ys) {
                m.observe(x, *y);
                if m.num_balls() > l {
                    return Err(format!("{} balls > L={l}", m.num_balls()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn nan_features_never_panic_nearest_ball() {
        // Regression: with MergePolicy::NearestBall a NaN feature made
        // every gap NaN, the INFINITY sentinel never lost, and observe
        // panicked at `self.balls[usize::MAX]`. The guarded path skips
        // the example (debug builds assert with an explicit message).
        let mk = || {
            let mut m = MultiBallSvm::new(2, 3, MergePolicy::NearestBall, TrainOptions::default());
            m.observe(&[1.0, 0.0], 1.0);
            m.observe(&[0.0, 1.0], -1.0);
            m
        };
        if cfg!(debug_assertions) {
            let r = std::panic::catch_unwind(|| {
                let mut m = mk();
                m.observe(&[f32::NAN, 0.0], 1.0);
            });
            let payload = r.expect_err("debug build should assert");
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            assert!(
                msg.contains("non-finite"),
                "expected the explicit non-finite assert, got: {msg}"
            );
        } else {
            let mut m = mk();
            let balls_before = m.num_balls();
            m.observe(&[f32::NAN, 0.0], 1.0);
            assert_eq!(m.num_balls(), balls_before);
            let fb = m.final_ball().unwrap();
            assert!(fb.weights().iter().all(|w| w.is_finite()), "NaN poisoned a ball");
        }
        // the validated entry point surfaces the defect as an error
        let mut m = mk();
        let err = m
            .try_observe(FeaturesView::Dense(&[f32::NAN, 0.0]), 1.0)
            .unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        // NewBallMergeClosest must not seed a poisoned ball either
        if !cfg!(debug_assertions) {
            let mut m =
                MultiBallSvm::new(2, 3, MergePolicy::NewBallMergeClosest, TrainOptions::default());
            m.observe(&[f32::NAN, 0.0], 1.0);
            assert_eq!(m.num_balls(), 0);
        }
    }

    #[test]
    fn sparse_observe_matches_dense() {
        check_default("multiball-sparse-dense", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 60, d, 1.5, 0.3);
            for policy in [MergePolicy::NearestBall, MergePolicy::NewBallMergeClosest] {
                let opts = TrainOptions::default();
                let mut dense = MultiBallSvm::new(d, 3, policy, opts);
                let mut sparse = MultiBallSvm::new(d, 3, policy, opts);
                for (x, y) in xs.iter().zip(&ys) {
                    dense.observe(x, *y);
                    let f = crate::data::Features::Dense(x.clone()).to_sparse();
                    sparse.observe_view(f.view(), *y);
                }
                if dense.num_balls() != sparse.num_balls()
                    || dense.num_support() != sparse.num_support()
                {
                    return Err(format!(
                        "{policy:?}: diverged (balls {} vs {}, supports {} vs {})",
                        dense.num_balls(),
                        sparse.num_balls(),
                        dense.num_support(),
                        sparse.num_support()
                    ));
                }
                let (fd, fs) = (dense.final_ball().unwrap(), sparse.final_ball().unwrap());
                if (fd.r - fs.r).abs() > 1e-6 * fd.r.max(1.0) {
                    return Err(format!("{policy:?}: R diverged {} vs {}", fd.r, fs.r));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn l1_nearest_policy_equals_algorithm1() {
        check_default("multiball-l1-equals-algo1", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 48, d, 1.0, 0.3);
            let opts = TrainOptions::default();
            let mut a1 = crate::svm::streamsvm::StreamSvm::new(d, opts);
            let mut mb = MultiBallSvm::new(d, 1, MergePolicy::NearestBall, opts);
            for (x, y) in xs.iter().zip(&ys) {
                a1.observe(x, *y);
                mb.observe(x, *y);
            }
            let fb = mb.final_ball().unwrap();
            if fb.weights() != a1.weights() {
                return Err("L=1 multiball diverged from Algorithm 1".into());
            }
            Ok(())
        });
    }

    #[test]
    fn final_ball_radius_dominates_live_balls() {
        // The pairwise merge encloses by construction (λt = r − r₁; see
        // merge_two_encloses_both for the explicit-space proof); here we
        // check the fold: the final radius dominates every live radius
        // and never exceeds the sum of all radii + pairwise distances
        // (a crude but slack-convention-independent upper bound).
        check_default("multiball-final-radius", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 60, d, 2.0, 0.4);
            let mut m = MultiBallSvm::new(d, 4, MergePolicy::NewBallMergeClosest, TrainOptions::default());
            for (x, y) in xs.iter().zip(&ys) {
                m.observe(x, *y);
            }
            let balls = m.balls.clone();
            let fb = m.final_ball().unwrap().clone();
            let max_r = balls.iter().map(|b| b.r).fold(0.0f64, f64::max);
            if fb.r + 1e-9 < max_r {
                return Err(format!("final r {} < max live r {max_r}", fb.r));
            }
            let mut bound = balls.iter().map(|b| b.r).sum::<f64>();
            for i in 0..balls.len() {
                for j in (i + 1)..balls.len() {
                    bound += center_dist(&balls[i], &balls[j]);
                }
            }
            if fb.r > bound + 1e-6 {
                return Err(format!("final r {} exceeds crude bound {bound}", fb.r));
            }
            Ok(())
        });
    }
}
