//! The streaming ball state and the closed-form Algorithm-1 update.
//!
//! The MEB center in the augmented space `φ̃(z) = [y x ; C^{-1/2} e]`
//! splits into an explicit part `w ∈ R^D` (the SVM weight vector) and an
//! implicit slack mass `ξ²` (the squared norm of the center's component
//! in the mutually-orthogonal slack subspace — never materialized because
//! one pass touches each `e_n` at most once).

use crate::linalg;
use crate::svm::TrainOptions;

/// Streaming MEB / StreamSVM state: `(w, R, ξ², M)`.
#[derive(Clone, Debug, PartialEq)]
pub struct BallState {
    /// Explicit center part = SVM weight vector.
    pub w: Vec<f32>,
    /// Ball radius.
    pub r: f64,
    /// Slack mass of the center.
    pub xi2: f64,
    /// Number of core-set points absorbed (= SV count upper bound).
    pub m: usize,
}

impl BallState {
    /// Initialize from the first streamed example (Algorithm 1 line 3).
    pub fn init(x: &[f32], y: f32, opts: &TrainOptions) -> Self {
        let mut w = vec![0.0f32; x.len()];
        linalg::blend_into(&mut w, x, y, 1.0);
        BallState { w, r: 0.0, xi2: opts.s2(), m: 1 }
    }

    /// A zero-radius ball at the origin (used by pipeline warm starts).
    pub fn zero(dim: usize, opts: &TrainOptions) -> Self {
        BallState { w: vec![0.0; dim], r: 0.0, xi2: opts.s2(), m: 0 }
    }

    /// Distance of `φ̃((x, y))` to the center (Algorithm 1 line 5):
    /// `d = sqrt(||w - y x||² + ξ² + 1/C)`.
    pub fn distance(&self, x: &[f32], y: f32, opts: &TrainOptions) -> f64 {
        (linalg::sqdist_scaled(&self.w, x, y) + self.xi2 + opts.invc()).sqrt()
    }

    /// Algorithm 1 lines 5–10: absorb `(x, y)` if it falls outside the
    /// current ball. Returns `true` if an update happened.
    pub fn try_update(&mut self, x: &[f32], y: f32, opts: &TrainOptions) -> bool {
        let d = self.distance(x, y, opts);
        if d < self.r {
            return false;
        }
        let beta = 0.5 * (1.0 - self.r / d);
        linalg::blend_into(&mut self.w, x, y, beta as f32);
        self.r += 0.5 * (d - self.r);
        let omb = 1.0 - beta;
        self.xi2 = self.xi2 * omb * omb + beta * beta * opts.s2();
        self.m += 1;
        true
    }

    /// `||c||²` in the augmented space.
    pub fn center_norm2(&self) -> f64 {
        linalg::norm2(&self.w) + self.xi2
    }

    pub fn dim(&self) -> usize {
        self.w.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check_default, gen};
    use crate::svm::SlackMode;

    fn opts() -> TrainOptions {
        TrainOptions::default()
    }

    #[test]
    fn init_state() {
        let b = BallState::init(&[2.0, -1.0], -1.0, &opts());
        assert_eq!(b.w, vec![-2.0, 1.0]);
        assert_eq!(b.r, 0.0);
        assert_eq!(b.xi2, 1.0); // consistent mode at C=1 → 1/C = 1
        assert_eq!(b.m, 1);
    }

    #[test]
    fn first_update_moves_halfway() {
        // From a zero-radius ball, beta = 1/2: center lands midway, radius
        // at half the distance.
        let o = opts();
        let mut b = BallState::init(&[0.0, 0.0], 1.0, &o);
        let d0 = b.distance(&[2.0, 0.0], 1.0, &o);
        assert!(b.try_update(&[2.0, 0.0], 1.0, &o));
        assert_eq!(b.w, vec![1.0, 0.0]);
        assert!((b.r - 0.5 * d0).abs() < 1e-12);
        assert_eq!(b.m, 2);
    }

    #[test]
    fn enclosed_point_is_discarded() {
        let o = opts();
        let mut b = BallState::init(&[0.0], 1.0, &o);
        b.try_update(&[10.0], 1.0, &o);
        let r_before = b.r;
        // A point between the two: must be enclosed after the first grow.
        assert!(!b.try_update(&[5.0], 1.0, &o));
        assert_eq!(b.r, r_before);
        assert_eq!(b.m, 2);
    }

    #[test]
    fn radius_never_shrinks_property() {
        check_default("ball-radius-monotone", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 64, d, 2.0, 0.5);
            let o = TrainOptions::default().with_c(0.5 + rng.uniform() * 4.0);
            let mut b = BallState::init(&xs[0], ys[0], &o);
            let mut prev = 0.0;
            for (x, y) in xs[1..].iter().zip(&ys[1..]) {
                b.try_update(x, *y, &o);
                if b.r < prev - 1e-9 {
                    return Err(format!("radius shrank: {prev} -> {}", b.r));
                }
                prev = b.r;
            }
            Ok(())
        });
    }

    #[test]
    fn old_ball_always_enclosed_property() {
        // After an update, the new ball must contain the old ball:
        // ||c' - c|| + r <= r' (within float tolerance). This is the
        // invariant that makes the coordinator's block filter exact.
        check_default("ball-grows", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 48, d, 1.5, 0.3);
            let o = TrainOptions::default();
            let mut b = BallState::init(&xs[0], ys[0], &o);
            for (x, y) in xs[1..].iter().zip(&ys[1..]) {
                let before = b.clone();
                if b.try_update(x, *y, &o) {
                    // ||c' - c||² in augmented space: explicit diff plus
                    // slack-mass displacement. With beta the blend weight,
                    // slack displacement² = beta²(ξ²_old + s²).
                    let mut diff2 = 0.0f64;
                    for i in 0..b.w.len() {
                        let dd = b.w[i] as f64 - before.w[i] as f64;
                        diff2 += dd * dd;
                    }
                    // recover beta from the radius update: r' = r + (d-r)/2
                    // and beta = (1 - r/d)/2 → d = 2 r' - r ... use defs:
                    let dist = 2.0 * b.r - before.r;
                    let beta = 0.5 * (1.0 - before.r / dist);
                    let slack_disp2 = beta * beta * (before.xi2 + o.s2());
                    let move_len = (diff2 + slack_disp2).sqrt();
                    if move_len + before.r > b.r + 1e-6 {
                        return Err(format!(
                            "old ball sticks out: move {move_len} + r {} > r' {}",
                            before.r, b.r
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn paper_mode_xi2_init() {
        let o = TrainOptions::default().with_c(10.0).with_slack_mode(SlackMode::Paper);
        let b = BallState::init(&[1.0], 1.0, &o);
        assert_eq!(b.xi2, 1.0);
        let oc = o.with_slack_mode(SlackMode::Consistent);
        let bc = BallState::init(&[1.0], 1.0, &oc);
        assert!((bc.xi2 - 0.1).abs() < 1e-12);
    }
}
