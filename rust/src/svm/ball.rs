//! The streaming ball state and the closed-form Algorithm-1 update.
//!
//! The MEB center in the augmented space `φ̃(z) = [y x ; C^{-1/2} e]`
//! splits into an explicit part `w ∈ R^D` (the SVM weight vector) and an
//! implicit slack mass `ξ²` (the squared norm of the center's component
//! in the mutually-orthogonal slack subspace — never materialized because
//! one pass touches each `e_n` at most once).
//!
//! # Lazily-scaled center
//!
//! The center is stored factored as `w = σ·v` with a cached `‖w‖²`.
//! Algorithm 1's blend `w ← (1−β)w + βyx` then costs one scalar multiply
//! (`σ ← (1−β)σ`) plus a scatter-add into `v`, and the line-5 distance
//! uses the expansion `‖w − yx‖² = ‖w‖² − 2y⟨w,x⟩ + ‖x‖²` — so both the
//! reject test and the update are O(nnz) in the example's stored
//! coordinates, not O(D). `σ` only shrinks (by at most ×½ per update);
//! when it drifts below [`SIGMA_FOLD`] it is folded back into `v` (an
//! amortized-O(D/updates) renormalization that also refreshes the cached
//! norm).

use crate::data::FeaturesView;
use crate::linalg;
use crate::svm::TrainOptions;

/// Fold `σ` into `v` once `|σ|` drops below this (β ≤ ½ ⇒ at least ~20
/// updates between folds). Keeps `v` within comfortable f32 range: with
/// `|σ| ≥ 1e-6`, `|v| ≤ 1e6·|w|`. Shared with the diagonal-metric
/// [`crate::svm::ellipsoid::EllipsoidSvm`], whose isotropic mode must
/// replay this exact schedule to stay bit-identical to the ball.
pub(crate) const SIGMA_FOLD: f64 = 1e-6;

/// Also renormalize every this many updates regardless of `σ`: the
/// incremental `‖w‖²` recurrence tracks the ideal center while `v`
/// rounds to f32 per scatter-add, so on very long streams (where β→0
/// and `σ` may never cross [`SIGMA_FOLD`]) the cache would otherwise
/// random-walk away from the stored center. Amortized cost O(D/2²⁰)
/// per update — noise. The schedule depends only on `m`, so resume
/// from a sketch replays it deterministically. Shared with the
/// ellipsoid variant like [`SIGMA_FOLD`].
pub(crate) const RENORM_EVERY: usize = 1 << 20;

/// Streaming MEB / StreamSVM state: `(w, R, ξ², M)` with `w = σ·v`.
#[derive(Clone, Debug, PartialEq)]
pub struct BallState {
    /// Unscaled center direction; the true center is `w = σ·v`.
    v: Vec<f32>,
    /// Lazy scale on `v`.
    sigma: f64,
    /// Cached `‖w‖²` (f64, maintained incrementally).
    wnorm2: f64,
    /// Ball radius.
    pub r: f64,
    /// Slack mass of the center.
    pub xi2: f64,
    /// Number of core-set points absorbed (= SV count upper bound).
    pub m: usize,
}

impl BallState {
    /// Initialize from the first streamed example (Algorithm 1 line 3):
    /// `w = y x`, stored as `σ = y`, `v = x`.
    pub fn init(x: &[f32], y: f32, opts: &TrainOptions) -> Self {
        Self::init_view(FeaturesView::Dense(x), y, opts)
    }

    /// [`Self::init`] for a dense-or-sparse feature view.
    pub fn init_view(x: FeaturesView<'_>, y: f32, opts: &TrainOptions) -> Self {
        debug_assert!(y == 1.0 || y == -1.0, "labels must be ±1, got {y}");
        let wnorm2 = x.norm2();
        BallState {
            v: x.to_dense(),
            sigma: y as f64,
            wnorm2,
            r: 0.0,
            xi2: opts.s2(),
            m: 1,
        }
    }

    /// A zero-radius ball at the origin (used by pipeline warm starts).
    pub fn zero(dim: usize, opts: &TrainOptions) -> Self {
        BallState { v: vec![0.0; dim], sigma: 1.0, wnorm2: 0.0, r: 0.0, xi2: opts.s2(), m: 0 }
    }

    /// Build from an explicit dense center (merges, device write-backs,
    /// legacy sketches): `σ = 1`, cached norm computed once.
    pub fn from_parts(w: Vec<f32>, r: f64, xi2: f64, m: usize) -> Self {
        let wnorm2 = linalg::norm2(&w);
        BallState { v: w, sigma: 1.0, wnorm2, r, xi2, m }
    }

    /// Rebuild the exact factored state (the sketch codec's decode path;
    /// round-tripping `(v, σ, ‖w‖²)` bit-exactly is what keeps
    /// checkpoint/resume bit-identical).
    pub fn from_scaled(v: Vec<f32>, sigma: f64, wnorm2: f64, r: f64, xi2: f64, m: usize) -> Self {
        BallState { v, sigma, wnorm2, r, xi2, m }
    }

    /// The lazy scale `σ` (codec / diagnostics).
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// The unscaled direction `v` (codec / diagnostics).
    pub fn direction(&self) -> &[f32] {
        &self.v
    }

    /// Cached `‖w‖²`.
    pub fn wnorm2(&self) -> f64 {
        self.wnorm2
    }

    /// `‖w‖` from the cached squared norm (telemetry, diagnostics).
    pub fn wnorm(&self) -> f64 {
        self.wnorm2.max(0.0).sqrt()
    }

    /// Materialize the weight vector `w = σ·v`.
    pub fn weights(&self) -> Vec<f32> {
        self.v.iter().map(|&vi| (vi as f64 * self.sigma) as f32).collect()
    }

    /// Write `w = σ·v` into `out` (must be exactly `dim()` long) without
    /// allocating — the pipeline's padded-scratch refresh.
    pub fn write_weights(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.v.len());
        for (o, &vi) in out.iter_mut().zip(&self.v) {
            *o = (vi as f64 * self.sigma) as f32;
        }
    }

    /// Raw margin `⟨w, x⟩ = σ·⟨v, x⟩` — no materialization.
    pub fn score(&self, x: &[f32]) -> f64 {
        self.sigma * linalg::dot(&self.v, x)
    }

    /// [`Self::score`] for a feature view — O(nnz).
    pub fn score_view(&self, x: FeaturesView<'_>) -> f64 {
        self.sigma * x.dot(&self.v)
    }

    /// Distance of `φ̃((x, y))` to the center (Algorithm 1 line 5):
    /// `d = sqrt(||w - y x||² + ξ² + 1/C)`.
    pub fn distance(&self, x: &[f32], y: f32, opts: &TrainOptions) -> f64 {
        self.distance_view(FeaturesView::Dense(x), y, opts)
    }

    /// [`Self::distance`] for a feature view — O(nnz) via the expansion
    /// with the cached `‖w‖²`.
    pub fn distance_view(&self, x: FeaturesView<'_>, y: f32, opts: &TrainOptions) -> f64 {
        let (wx, xn2) = self.dots(x);
        let feat2 = (self.wnorm2 - 2.0 * y as f64 * wx + xn2).max(0.0);
        (feat2 + self.xi2 + opts.invc()).sqrt()
    }

    /// `(⟨w,x⟩, ‖x‖²)` — the two O(nnz) reductions everything above is
    /// assembled from.
    fn dots(&self, x: FeaturesView<'_>) -> (f64, f64) {
        debug_assert_eq!(x.dim(), self.v.len());
        match x {
            FeaturesView::Dense(xs) => {
                (self.sigma * linalg::dot(&self.v, xs), linalg::norm2(xs))
            }
            FeaturesView::Sparse { idx, val, .. } => (
                self.sigma * linalg::sparse_dot(&self.v, idx, val),
                linalg::norm2(val),
            ),
        }
    }

    /// Algorithm 1 lines 5–10: absorb `(x, y)` if it falls outside the
    /// current ball. Returns `true` if an update happened.
    pub fn try_update(&mut self, x: &[f32], y: f32, opts: &TrainOptions) -> bool {
        self.try_update_view(FeaturesView::Dense(x), y, opts)
    }

    /// [`Self::try_update`] for a feature view — O(nnz): one scalar
    /// multiply on `σ`, a scatter-add into `v`, and closed-form `‖w‖²` /
    /// `ξ²` / `R` refreshes.
    pub fn try_update_view(&mut self, x: FeaturesView<'_>, y: f32, opts: &TrainOptions) -> bool {
        let (wx, xn2) = self.dots(x);
        let feat2 = (self.wnorm2 - 2.0 * y as f64 * wx + xn2).max(0.0);
        let d = (feat2 + self.xi2 + opts.invc()).sqrt();
        if !d.is_finite() {
            // A non-finite distance (NaN features smuggled past the
            // ingestion guards, or inf overflow) must not poison the
            // center: `d < r` is false for NaN, so without this guard
            // the blend below would write NaN into w forever.
            debug_assert!(false, "non-finite distance in try_update (d = {d})");
            return false;
        }
        if d < self.r {
            return false;
        }
        let beta = 0.5 * (1.0 - self.r / d);
        let omb = 1.0 - beta;
        self.sigma *= omb;
        // w' = (1-β)w + βyx  ⇔  v += (βy/σ')x with σ' already scaled.
        x.axpy_into(&mut self.v, (beta * y as f64 / self.sigma) as f32);
        self.wnorm2 = (omb * omb * self.wnorm2
            + 2.0 * omb * beta * y as f64 * wx
            + beta * beta * xn2)
            .max(0.0);
        self.r += 0.5 * (d - self.r);
        self.xi2 = self.xi2 * omb * omb + beta * beta * opts.s2();
        self.m += 1;
        if self.sigma.abs() < SIGMA_FOLD || self.m % RENORM_EVERY == 0 {
            self.renormalize();
        }
        true
    }

    /// Rebuild the ball in place as a merge result: the new explicit
    /// center is `w' = keep·w + Σ coefs[i]·xs[i]` — one scalar multiply
    /// on `σ` plus sparse scatter-adds into `v`, so the Algorithm-2
    /// flush costs O(Σ nnz) instead of O(L·D). The caller supplies the
    /// closed-form `‖w'‖²` (computable in O(L²) from the merge Gram),
    /// or `None` when that expression suffered heavy cancellation — then
    /// the norm is recomputed exactly from the stored center (O(D), the
    /// precision the pre-factored code always paid).
    pub fn merge_into(
        &mut self,
        keep: f64,
        xs: &[FeaturesView<'_>],
        coefs: &[f64],
        wnorm2: Option<f64>,
        r: f64,
        xi2: f64,
        absorbed: usize,
    ) {
        debug_assert_eq!(xs.len(), coefs.len());
        self.sigma *= keep;
        if self.sigma.abs() < SIGMA_FOLD {
            // Fold before the scatter-adds so `coef/σ` stays bounded.
            // `keep == 0` lands here too and zeroes `v` exactly.
            for vi in self.v.iter_mut() {
                *vi = (*vi as f64 * self.sigma) as f32;
            }
            self.sigma = 1.0;
            if crate::obs::telemetry_on() {
                crate::obs::telemetry::SIGMA_FOLDS.inc();
            }
        }
        for (x, &c) in xs.iter().zip(coefs) {
            x.axpy_into(&mut self.v, (c / self.sigma) as f32);
        }
        self.r = r;
        self.xi2 = xi2;
        let crossed = (self.m / RENORM_EVERY) != ((self.m + absorbed) / RENORM_EVERY);
        self.m += absorbed;
        match wnorm2 {
            Some(w2) if !crossed => self.wnorm2 = w2.max(0.0),
            // Re-anchor from the stored center: on the amortized schedule
            // (same `m`-boundary rule as the per-example update, so it is
            // deterministic under resume), or whenever the caller flagged
            // the closed form as cancellation-damaged.
            _ => self.renormalize(),
        }
    }

    /// Fold `σ` into `v` and refresh the cached norm (amortized; see the
    /// module docs).
    fn renormalize(&mut self) {
        // Cold by construction, so the span probe (one relaxed load when
        // tracing is off) costs nothing relative to the O(D) fold.
        let _span = crate::obs::span("svm", "sigma_fold").field("dim", self.v.len());
        for vi in self.v.iter_mut() {
            *vi = (*vi as f64 * self.sigma) as f32;
        }
        self.sigma = 1.0;
        self.wnorm2 = linalg::norm2(&self.v);
        // Cold path by construction (amortized O(D/updates)), so one
        // gated counter bump is free relative to the O(D) fold above.
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::SIGMA_FOLDS.inc();
        }
    }

    /// `‖c_a − c_b‖²` of the explicit parts, computed without
    /// materializing either weight vector (two-ball merge geometry).
    pub fn center_diff_norm2(&self, other: &BallState) -> f64 {
        assert_eq!(self.v.len(), other.v.len());
        let mut acc = 0.0f64;
        for i in 0..self.v.len() {
            let d = self.sigma * self.v[i] as f64 - other.sigma * other.v[i] as f64;
            acc += d * d;
        }
        acc
    }

    /// `||c||²` in the augmented space.
    pub fn center_norm2(&self) -> f64 {
        self.wnorm2 + self.xi2
    }

    pub fn dim(&self) -> usize {
        self.v.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Features;
    use crate::prop::{check_default, gen};
    use crate::svm::SlackMode;

    fn opts() -> TrainOptions {
        TrainOptions::default()
    }

    #[test]
    fn init_state() {
        let b = BallState::init(&[2.0, -1.0], -1.0, &opts());
        assert_eq!(b.weights(), vec![-2.0, 1.0]);
        assert_eq!(b.r, 0.0);
        assert_eq!(b.xi2, 1.0); // consistent mode at C=1 → 1/C = 1
        assert_eq!(b.m, 1);
        assert_eq!(b.wnorm2(), 5.0);
    }

    #[test]
    fn first_update_moves_halfway() {
        // From a zero-radius ball, beta = 1/2: center lands midway, radius
        // at half the distance.
        let o = opts();
        let mut b = BallState::init(&[0.0, 0.0], 1.0, &o);
        let d0 = b.distance(&[2.0, 0.0], 1.0, &o);
        assert!(b.try_update(&[2.0, 0.0], 1.0, &o));
        assert_eq!(b.weights(), vec![1.0, 0.0]);
        assert!((b.r - 0.5 * d0).abs() < 1e-12);
        assert_eq!(b.m, 2);
    }

    #[test]
    fn enclosed_point_is_discarded() {
        let o = opts();
        let mut b = BallState::init(&[0.0], 1.0, &o);
        b.try_update(&[10.0], 1.0, &o);
        let r_before = b.r;
        // A point between the two: must be enclosed after the first grow.
        assert!(!b.try_update(&[5.0], 1.0, &o));
        assert_eq!(b.r, r_before);
        assert_eq!(b.m, 2);
    }

    #[test]
    fn non_finite_distance_is_skipped_in_release() {
        // Satellite guard: a NaN feature must not update the ball (in
        // release; debug builds assert). `d < r` is false for NaN, so the
        // unguarded update would poison w forever.
        if cfg!(debug_assertions) {
            let o = opts();
            let mut b = BallState::init(&[1.0], 1.0, &o);
            let r = std::panic::catch_unwind(move || {
                b.try_update(&[f32::NAN], 1.0, &o);
            });
            assert!(r.is_err(), "debug build should assert on NaN distance");
        } else {
            let o = opts();
            let mut b = BallState::init(&[1.0], 1.0, &o);
            let before = b.clone();
            assert!(!b.try_update(&[f32::NAN], 1.0, &o));
            assert_eq!(b, before, "NaN example must leave the ball untouched");
            assert!(b.weights()[0].is_finite());
        }
    }

    #[test]
    fn sparse_and_dense_updates_agree() {
        let o = opts();
        let xs: Vec<Vec<f32>> = vec![
            vec![0.0, 2.0, 0.0, 0.0],
            vec![1.0, 0.0, 0.0, -1.0],
            vec![0.0, 0.0, 3.0, 0.0],
            vec![0.5, 0.5, 0.0, 0.0],
        ];
        let ys = [1.0f32, -1.0, 1.0, -1.0];
        let mut dense = BallState::init(&xs[0], ys[0], &o);
        let sp = |x: &[f32]| Features::Dense(x.to_vec()).to_sparse();
        let f0 = sp(&xs[0]);
        let mut sparse = BallState::init_view(f0.view(), ys[0], &o);
        for (x, y) in xs[1..].iter().zip(&ys[1..]) {
            let f = sp(x);
            let ud = dense.try_update(x, *y, &o);
            let us = sparse.try_update_view(f.view(), *y, &o);
            assert_eq!(ud, us);
        }
        assert_eq!(dense.m, sparse.m);
        assert!((dense.r - sparse.r).abs() < 1e-9);
        assert!((dense.xi2 - sparse.xi2).abs() < 1e-9);
        for (a, b) in dense.weights().iter().zip(sparse.weights()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn sigma_folds_without_changing_geometry() {
        // An adversarial stream (every point escapes) drives many β≈½
        // updates; σ must fold back into v without disturbing w.
        let o = opts();
        let mut b = BallState::init(&[1.0], 1.0, &o);
        for i in 1..=200 {
            b.try_update(&[1.2f32.powi(i)], 1.0, &o);
        }
        // every point escapes (geometric growth), so ~200 β≈0.07 updates
        // shrink σ past the fold threshold at least once
        assert_eq!(b.m, 201, "geometric stream must always escape");
        assert!(b.sigma().abs() >= SIGMA_FOLD / 2.0, "sigma = {}", b.sigma());
        let w = b.weights();
        assert!(w[0].is_finite());
        let rel = (b.wnorm2() - (w[0] as f64).powi(2)).abs() / b.wnorm2().max(1e-12);
        assert!(rel < 1e-4, "cached norm drifted: {rel}");
    }

    #[test]
    fn radius_never_shrinks_property() {
        check_default("ball-radius-monotone", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 64, d, 2.0, 0.5);
            let o = TrainOptions::default().with_c(0.5 + rng.uniform() * 4.0);
            let mut b = BallState::init(&xs[0], ys[0], &o);
            let mut prev = 0.0;
            for (x, y) in xs[1..].iter().zip(&ys[1..]) {
                b.try_update(x, *y, &o);
                if b.r < prev - 1e-9 {
                    return Err(format!("radius shrank: {prev} -> {}", b.r));
                }
                prev = b.r;
            }
            Ok(())
        });
    }

    #[test]
    fn old_ball_always_enclosed_property() {
        // After an update, the new ball must contain the old ball:
        // ||c' - c|| + r <= r' (within float tolerance). This is the
        // invariant that makes the coordinator's block filter exact.
        check_default("ball-grows", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 48, d, 1.5, 0.3);
            let o = TrainOptions::default();
            let mut b = BallState::init(&xs[0], ys[0], &o);
            for (x, y) in xs[1..].iter().zip(&ys[1..]) {
                let before = b.clone();
                if b.try_update(x, *y, &o) {
                    // ||c' - c||² in augmented space: explicit diff plus
                    // slack-mass displacement. With beta the blend weight,
                    // slack displacement² = beta²(ξ²_old + s²).
                    let diff2 = b.center_diff_norm2(&before);
                    // recover beta from the radius update: r' = r + (d-r)/2
                    // and beta = (1 - r/d)/2 → d = 2 r' - r ... use defs:
                    let dist = 2.0 * b.r - before.r;
                    let beta = 0.5 * (1.0 - before.r / dist);
                    let slack_disp2 = beta * beta * (before.xi2 + o.s2());
                    let move_len = (diff2 + slack_disp2).sqrt();
                    if move_len + before.r > b.r + 1e-6 {
                        return Err(format!(
                            "old ball sticks out: move {move_len} + r {} > r' {}",
                            before.r, b.r
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn paper_mode_xi2_init() {
        let o = TrainOptions::default().with_c(10.0).with_slack_mode(SlackMode::Paper);
        let b = BallState::init(&[1.0], 1.0, &o);
        assert_eq!(b.xi2, 1.0);
        let oc = o.with_slack_mode(SlackMode::Consistent);
        let bc = BallState::init(&[1.0], 1.0, &oc);
        assert!((bc.xi2 - 0.1).abs() < 1e-12);
    }

    #[test]
    fn from_parts_roundtrip() {
        let b = BallState::from_parts(vec![1.5, -2.0], 3.0, 0.25, 7);
        assert_eq!(b.weights(), vec![1.5, -2.0]);
        assert_eq!(b.sigma(), 1.0);
        assert!((b.wnorm2() - 6.25).abs() < 1e-12);
        assert_eq!(b.dim(), 2);
        let mut out = [0.0f32; 2];
        b.write_weights(&mut out);
        assert_eq!(out, [1.5, -2.0]);
        assert_eq!(b.score(&[2.0, 1.0]), 1.0);
    }
}
