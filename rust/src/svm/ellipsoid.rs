//! Streaming ellipsoid prototype — the paper's §6.2 extension.
//!
//! Instead of a ball that "expands equally in all dimensions", maintain a
//! center and per-axis semi-axes (a diagonal minimum-volume-ellipsoid
//! surrogate). A point escapes when its *Mahalanobis* distance exceeds 1;
//! the update then runs the one-dimensional Zarrabi-Zadeh–Chan ball
//! update independently on every axis where the point sticks out, so the
//! ellipsoid "expands only along those directions where needed" (§6.2).
//!
//! Scoring is confidence-weighted (the CW analogy the paper draws):
//! `score(x) = Σ_j w_j x_j / (a_j² + ε)` — axes with large learned spread
//! (low confidence) are down-weighted.
//!
//! Status per the paper: streaming MVE approximation guarantees are an
//! *open problem* ("very conservative" known bounds); this module is the
//! exploratory prototype the paper calls for, not a guaranteed-ratio
//! algorithm. Tests cover per-axis monotonicity, box enclosure and the
//! anisotropic-data win over the isotropic ball.

use crate::data::Example;
use crate::eval::Classifier;
use crate::svm::TrainOptions;

/// Streaming diagonal-ellipsoid learner.
#[derive(Clone, Debug)]
pub struct EllipsoidSvm {
    /// Center (the weight vector analogue).
    pub w: Vec<f32>,
    /// Per-axis semi-axes.
    pub a: Vec<f64>,
    opts: TrainOptions,
    seen: usize,
    updates: usize,
    init: bool,
}

/// Initial semi-axis (a tiny but non-zero extent keeps the Mahalanobis
/// test well-defined from the first point).
const A0: f64 = 1e-3;

impl EllipsoidSvm {
    pub fn new(dim: usize, opts: TrainOptions) -> Self {
        EllipsoidSvm {
            w: vec![0.0; dim],
            a: vec![A0; dim],
            opts,
            seen: 0,
            updates: 0,
            init: false,
        }
    }

    /// Squared Mahalanobis distance of `φ(z) = y x` to the center (the
    /// slack/regularization term enters as a constant floor, like the
    /// ball's `ξ² + 1/C`, normalized by the mean axis).
    pub fn mahalanobis2(&self, x: &[f32], y: f32) -> f64 {
        let mut m2 = 0.0;
        for j in 0..self.w.len() {
            let d = y as f64 * x[j] as f64 - self.w[j] as f64;
            m2 += (d * d) / (self.a[j] * self.a[j]);
        }
        let mean_a2 = self.a.iter().map(|v| v * v).sum::<f64>() / self.a.len() as f64;
        m2 + self.opts.invc() / (mean_a2 + self.opts.invc())
    }

    /// Stream one example; returns whether an update happened.
    pub fn observe(&mut self, x: &[f32], y: f32) -> bool {
        self.seen += 1;
        if !self.init {
            for (wj, &xj) in self.w.iter_mut().zip(x) {
                *wj = y * xj;
            }
            self.init = true;
            self.updates += 1;
            return true;
        }
        if self.mahalanobis2(x, y) <= 1.0 {
            return false;
        }
        // per-axis 1-D ball update where the point escapes its interval
        let mut any = false;
        for j in 0..self.w.len() {
            let p = y as f64 * x[j] as f64;
            let c = self.w[j] as f64;
            let gap = (p - c).abs() - self.a[j];
            if gap > 0.0 {
                // 1-D Zarrabi-Zadeh–Chan: move center half the gap toward
                // the point, grow the semi-axis by the other half.
                let dir = (p - c).signum();
                self.w[j] = (c + dir * 0.5 * gap) as f32;
                self.a[j] += 0.5 * gap;
                any = true;
            }
        }
        if any {
            self.updates += 1;
        }
        any
    }

    pub fn fit<'a, I: IntoIterator<Item = &'a Example>>(
        stream: I,
        dim: usize,
        opts: &TrainOptions,
    ) -> Self {
        let mut m = EllipsoidSvm::new(dim, *opts);
        for e in stream {
            m.observe(&e.x.dense(), e.y);
        }
        m
    }

    pub fn num_updates(&self) -> usize {
        self.updates
    }

    pub fn examples_seen(&self) -> usize {
        self.seen
    }

    /// Geometric-mean semi-axis (volume surrogate).
    pub fn mean_axis(&self) -> f64 {
        let s: f64 = self.a.iter().map(|v| v.ln()).sum();
        (s / self.a.len() as f64).exp()
    }
}

impl Classifier for EllipsoidSvm {
    fn score(&self, x: &[f32]) -> f64 {
        let mut s = 0.0;
        for j in 0..self.w.len() {
            s += self.w[j] as f64 * x[j] as f64 / (self.a[j] * self.a[j] + 1e-9);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::accuracy;
    use crate::prop::{check_default, gen};
    use crate::rng::Pcg32;
    use crate::svm::streamsvm::StreamSvm;

    #[test]
    fn axes_grow_where_variance_is() {
        // dim 0 has 10x the spread of dim 1: the learned semi-axes must
        // reflect that anisotropy.
        let mut rng = Pcg32::seeded(1);
        let mut m = EllipsoidSvm::new(2, TrainOptions::default());
        for _ in 0..2000 {
            let x = vec![(rng.normal() * 10.0) as f32, rng.normal() as f32];
            m.observe(&x, 1.0);
        }
        assert!(m.a[0] > 4.0 * m.a[1], "a = {:?}", m.a);
    }

    #[test]
    fn axes_monotone_property() {
        check_default("ellipsoid-axes-monotone", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 60, d, 1.5, 0.4);
            let mut m = EllipsoidSvm::new(d, TrainOptions::default());
            let mut prev = m.a.clone();
            for (x, y) in xs.iter().zip(&ys) {
                m.observe(x, *y);
                for j in 0..d {
                    if m.a[j] + 1e-12 < prev[j] {
                        return Err(format!("axis {j} shrank"));
                    }
                }
                prev = m.a.clone();
            }
            Ok(())
        });
    }

    #[test]
    fn box_enclosure_property() {
        // Every absorbed point ends inside the axis-aligned box
        // [w_j ± a_j] (the per-axis interval invariant).
        check_default("ellipsoid-box-enclosure", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 80, d, 1.5, 0.4);
            let mut m = EllipsoidSvm::new(d, TrainOptions::default());
            for (x, y) in xs.iter().zip(&ys) {
                m.observe(x, *y);
            }
            for (i, (x, y)) in xs.iter().zip(&ys).enumerate() {
                for j in 0..d {
                    let p = *y as f64 * x[j] as f64;
                    let lo = m.w[j] as f64 - m.a[j] * (1.0 + 1e-6) - 1e-9;
                    let hi = m.w[j] as f64 + m.a[j] * (1.0 + 1e-6) + 1e-9;
                    if p < lo || p > hi {
                        return Err(format!("point {i} axis {j} escapes the box"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn beats_ball_on_anisotropic_data() {
        // synthC-like geometry: signal on axis 0, large distractor
        // variance elsewhere. The ellipsoid's whitened scoring should
        // recover the signal that drags the isotropic ball.
        let mut rng = Pcg32::seeded(3);
        let mut exs = Vec::new();
        for _ in 0..4000 {
            let y = rng.label(0.5);
            let mut x = vec![(y as f64 * 1.2 + rng.normal() * 0.8) as f32];
            for _ in 0..4 {
                x.push((rng.normal() * 6.0) as f32);
            }
            exs.push(Example::new(x, y));
        }
        let opts = TrainOptions::default();
        let ball = StreamSvm::fit(exs.iter(), 5, &opts);
        let ell = EllipsoidSvm::fit(exs.iter(), 5, &opts);
        let (ab, ae) = (accuracy(&ball, &exs), accuracy(&ell, &exs));
        assert!(ae > ab + 0.05, "ellipsoid {ae:.3} vs ball {ab:.3}");
        assert!(ae > 0.8, "ellipsoid {ae:.3}");
    }

    #[test]
    fn update_count_sublinear_on_benign_stream() {
        let mut rng = Pcg32::seeded(4);
        let (xs, ys) = gen::labeled_points(&mut rng, 5000, 6, 1.0, 0.5);
        let mut m = EllipsoidSvm::new(6, TrainOptions::default());
        for (x, y) in xs.iter().zip(&ys) {
            m.observe(x, *y);
        }
        assert!(m.num_updates() < 1000, "updates {}", m.num_updates());
    }
}
