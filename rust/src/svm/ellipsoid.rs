//! Streaming ellipsoid variant — the paper's §6.2 extension.
//!
//! Runs the Algorithm-1 ball update in a **diagonal metric**: the
//! enclosing region is `{z : Σⱼ (zⱼ − wⱼ)²/sⱼ² ≤ R²}` for per-axis
//! scales `sⱼ`, so the ball "expands only along those directions where
//! needed" (§6.2) by *growing the metric scale* of an axis instead of
//! inflating the shared radius. With the isotropic metric (`s ≡ 1`,
//! fixed) every formula degenerates bit-for-bit to
//! [`BallState`](crate::svm::ball::BallState) — the
//! conformance anchor the cross-variant suite checks.
//!
//! # Lazily-scaled center, O(nnz) updates
//!
//! The center mirrors `BallState`'s factoring `w = σ·v` with a cached
//! metric norm `‖w‖²_S = Σ wⱼ²/sⱼ²`: the reject test is the expansion
//! `‖w − yx‖²_S = ‖w‖²_S − 2y⟨w,x⟩_S + ‖x‖²_S` (two O(nnz) scaled
//! reductions), and an update is one scalar multiply on `σ` plus a
//! sparse scatter-add into `v` — never an O(D) pass.
//!
//! # Metric adaptation (the CW analogy)
//!
//! In the adaptive mode, an update also grows `sⱼ` to the raw residual
//! `|y·xⱼ − wⱼ|` on the axes the example actually touches (its stored
//! non-zeros), monotonically: axes with large observed spread get a
//! large scale, which (a) down-weights them in every future distance and
//! (b) down-weights them in the confidence-weighted score
//! `Σⱼ wⱼ xⱼ/sⱼ²` — the confidence-weighted-learning analogy the paper
//! draws. Each scale change patches the cached `‖w‖²_S` in O(1), so
//! adaptation stays O(nnz) per update too. Streaming minimum-volume
//! -ellipsoid guarantees remain an open problem per the paper; this is
//! the exploratory prototype it calls for, with the isotropic mode as
//! the exactness anchor.

use crate::data::{Example, FeaturesView};
use crate::eval::Classifier;
use crate::linalg;
use crate::svm::learner::{StreamLearner, Variant};
// The fold/renorm schedule is shared with BallState (one source of
// truth): the isotropic mode's bit-parity with the ball depends on both
// learners folding σ and re-anchoring the cached norm at the same
// stream positions.
use crate::svm::ball::{RENORM_EVERY, SIGMA_FOLD};
use crate::svm::TrainOptions;

/// Streaming diagonal-metric MEB learner.
#[derive(Clone, Debug)]
pub struct EllipsoidSvm {
    /// Unscaled center direction; the true center is `w = σ·v`.
    v: Vec<f32>,
    /// Lazy scale on `v`.
    sigma: f64,
    /// Per-axis metric scales `sⱼ` (≥ 1; grow-only in adaptive mode).
    s: Vec<f64>,
    /// Cached `1/sⱼ²` (what the O(nnz) scaled reductions consume).
    inv_s2: Vec<f64>,
    /// Cached metric norm `‖w‖²_S`, maintained incrementally.
    wnorm2s: f64,
    r: f64,
    xi2: f64,
    /// Core-set points absorbed (init counts as 1, like the ball's `m`).
    m: usize,
    /// Adapt the metric on updates (false = fixed isotropic metric).
    adapt: bool,
    opts: TrainOptions,
    dim: usize,
    seen: usize,
}

impl EllipsoidSvm {
    /// Adaptive-metric learner (the §6.2 prototype proper).
    pub fn new(dim: usize, opts: TrainOptions) -> Self {
        Self::with_adapt(dim, opts, true)
    }

    /// Fixed isotropic metric: every formula reduces to
    /// [`BallState`](crate::svm::ball::BallState)'s
    /// (multiplying by a cached `1/s² = 1.0` is exact), so this variant
    /// matches Algorithm 1 on `(w, R, ξ²)` bit-for-bit.
    pub fn isotropic(dim: usize, opts: TrainOptions) -> Self {
        Self::with_adapt(dim, opts, false)
    }

    fn with_adapt(dim: usize, opts: TrainOptions, adapt: bool) -> Self {
        EllipsoidSvm {
            v: vec![0.0; dim],
            sigma: 1.0,
            s: vec![1.0; dim],
            inv_s2: vec![1.0; dim],
            wnorm2s: 0.0,
            r: 0.0,
            xi2: opts.s2(),
            m: 0,
            adapt,
            opts,
            dim,
            seen: 0,
        }
    }

    /// `(⟨w,x⟩_S, ‖x‖²_S)` — the two O(nnz) scaled reductions every
    /// distance and norm refresh is assembled from.
    fn metric_dots(&self, x: FeaturesView<'_>) -> (f64, f64) {
        debug_assert_eq!(x.dim(), self.dim);
        match x {
            FeaturesView::Dense(xs) => (
                self.sigma * linalg::dot_scaled(&self.v, xs, &self.inv_s2),
                linalg::norm2_scaled(xs, &self.inv_s2),
            ),
            FeaturesView::Sparse { idx, val, .. } => (
                self.sigma * linalg::sparse_dot_scaled(&self.v, &self.inv_s2, idx, val),
                linalg::sparse_norm2_scaled(&self.inv_s2, idx, val),
            ),
        }
    }

    /// Metric distance of `φ̃((x, y))` to the center:
    /// `d = sqrt(‖w − yx‖²_S + ξ² + 1/C)`.
    pub fn distance_view(&self, x: FeaturesView<'_>, y: f32) -> f64 {
        let (wx, xn2) = self.metric_dots(x);
        let feat2 = (self.wnorm2s - 2.0 * y as f64 * wx + xn2).max(0.0);
        (feat2 + self.xi2 + self.opts.invc()).sqrt()
    }

    /// Stream one example; returns whether an update happened.
    pub fn observe(&mut self, x: &[f32], y: f32) -> bool {
        self.observe_view(FeaturesView::Dense(x), y)
    }

    /// [`Self::observe`] for a dense-or-sparse feature view — O(nnz):
    /// scaled-reduction reject test, one scalar multiply on `σ`, a
    /// sparse scatter-add into `v`, closed-form `‖w‖²_S`/`ξ²`/`R`
    /// refreshes, and (adaptive mode) per-touched-axis metric growth.
    pub fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        debug_assert_eq!(x.dim(), self.dim);
        self.seen += 1;
        if self.m == 0 {
            if !x.is_finite() {
                // keep NaN out of the seed center
                debug_assert!(false, "non-finite features in EllipsoidSvm::observe");
                return false;
            }
            // w = y x: σ = y, v = x (scattered into the zero direction)
            x.axpy_into(&mut self.v, 1.0);
            self.sigma = y as f64;
            let (_, xn2) = self.metric_dots(x);
            self.wnorm2s = xn2;
            self.r = 0.0;
            self.xi2 = self.opts.s2();
            self.m = 1;
            self.tap_telemetry(true);
            return true;
        }
        let (wx, xn2) = self.metric_dots(x);
        let feat2 = (self.wnorm2s - 2.0 * y as f64 * wx + xn2).max(0.0);
        let d = (feat2 + self.xi2 + self.opts.invc()).sqrt();
        if !d.is_finite() {
            // Same skip-and-surface path as BallState::try_update_view: a
            // NaN distance must not reach the blend (`d < r` is false for
            // NaN, so the center would be poisoned forever).
            debug_assert!(false, "non-finite distance in EllipsoidSvm::observe (d = {d})");
            return false;
        }
        if d < self.r {
            self.tap_telemetry(false);
            return false;
        }
        let beta = 0.5 * (1.0 - self.r / d);
        let omb = 1.0 - beta;
        self.sigma *= omb;
        // w' = (1−β)w + βyx  ⇔  v += (βy/σ')x with σ' already scaled.
        x.axpy_into(&mut self.v, (beta * y as f64 / self.sigma) as f32);
        self.wnorm2s = (omb * omb * self.wnorm2s
            + 2.0 * omb * beta * y as f64 * wx
            + beta * beta * xn2)
            .max(0.0);
        self.r += 0.5 * (d - self.r);
        self.xi2 = self.xi2 * omb * omb + beta * beta * self.opts.s2();
        self.m += 1;
        if self.sigma.abs() < SIGMA_FOLD || self.m % RENORM_EVERY == 0 {
            self.renormalize();
        }
        if self.adapt {
            self.adapt_axes(x, y);
        }
        self.tap_telemetry(true);
        true
    }

    /// Training-dynamics tap: one relaxed load when telemetry is off.
    /// `‖w‖` is reported in the learner's own (diagonal) metric.
    #[inline]
    fn tap_telemetry(&self, updated: bool) {
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::record_example(updated);
            crate::obs::telemetry::RADIUS.set(self.r);
            crate::obs::telemetry::WNORM.set(self.wnorm2s.max(0.0).sqrt());
        }
    }

    /// Grow the metric scale of every axis the example touches (its
    /// stored non-zeros — identical for a sparse row and its densified
    /// twin, since `SparseVec::from_dense` drops zeros) to the post-blend
    /// residual `|y·xⱼ − wⱼ|`, patching the cached `‖w‖²_S` in O(1) per
    /// changed axis. Scales are grow-only, so the metric is monotone.
    fn adapt_axes(&mut self, x: FeaturesView<'_>, y: f32) {
        match x {
            FeaturesView::Dense(xs) => {
                for (j, &xj) in xs.iter().enumerate() {
                    if xj != 0.0 {
                        self.adapt_axis(j, xj, y);
                    }
                }
            }
            FeaturesView::Sparse { idx, val, .. } => {
                for (&i, &xj) in idx.iter().zip(val) {
                    if xj != 0.0 {
                        self.adapt_axis(i as usize, xj, y);
                    }
                }
            }
        }
        self.wnorm2s = self.wnorm2s.max(0.0);
    }

    fn adapt_axis(&mut self, j: usize, xj: f32, y: f32) {
        let wj = self.sigma * self.v[j] as f64;
        let rho = (y as f64 * xj as f64 - wj).abs();
        if rho > self.s[j] {
            let new_inv = 1.0 / (rho * rho);
            // ‖w‖²_S correction for the one changed axis
            self.wnorm2s += wj * wj * (new_inv - self.inv_s2[j]);
            self.s[j] = rho;
            self.inv_s2[j] = new_inv;
        }
    }

    /// Fold `σ` into `v` and refresh the cached metric norm (amortized).
    fn renormalize(&mut self) {
        for vi in self.v.iter_mut() {
            *vi = (*vi as f64 * self.sigma) as f32;
        }
        self.sigma = 1.0;
        self.wnorm2s = linalg::norm2_scaled(&self.v, &self.inv_s2);
    }

    pub fn fit<'a, I: IntoIterator<Item = &'a Example>>(
        stream: I,
        dim: usize,
        opts: &TrainOptions,
    ) -> Self {
        let mut m = EllipsoidSvm::new(dim, *opts);
        for e in stream {
            m.observe_view(e.x.view(), e.y);
        }
        m
    }

    /// Materialize the center `w = σ·v`.
    pub fn weights(&self) -> Vec<f32> {
        self.v.iter().map(|&vi| (vi as f64 * self.sigma) as f32).collect()
    }

    /// Per-axis metric scales (the learned semi-axis directions).
    pub fn axes(&self) -> &[f64] {
        &self.s
    }

    pub fn radius(&self) -> f64 {
        self.r
    }

    /// Slack mass of the center.
    pub fn xi2(&self) -> f64 {
        self.xi2
    }

    /// Core-set size (= update count; init counts as 1, like the ball).
    pub fn num_support(&self) -> usize {
        self.m
    }

    /// Updates performed (kept as an alias of [`Self::num_support`] for
    /// the ablation harnesses).
    pub fn num_updates(&self) -> usize {
        self.m
    }

    pub fn examples_seen(&self) -> usize {
        self.seen
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Geometric-mean metric scale (volume surrogate).
    pub fn mean_axis(&self) -> f64 {
        if self.s.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.s.iter().map(|v| v.ln()).sum();
        (sum / self.s.len() as f64).exp()
    }

    /// The lazy scale `σ` on the stored direction (`w = σ·v`).
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// The unscaled center direction `v`.
    pub fn direction(&self) -> &[f32] {
        &self.v
    }

    /// The cached metric norm `‖w‖²_S`.
    pub fn wnorm2_scaled(&self) -> f64 {
        self.wnorm2s
    }

    /// Whether the metric adapts on updates (false = isotropic anchor).
    pub fn is_adaptive(&self) -> bool {
        self.adapt
    }

    /// Rebuild from exact serialized state (the `.meb` v4 decode path).
    /// `inv_s2` is recomputed as `1/(sⱼ·sⱼ)` — the identical expression
    /// [`Self::adapt_axis`] caches, so the restored model scores and
    /// continues training bit-for-bit like the one that was encoded.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        dim: usize,
        opts: TrainOptions,
        adapt: bool,
        v: Vec<f32>,
        sigma: f64,
        s: Vec<f64>,
        wnorm2s: f64,
        r: f64,
        xi2: f64,
        m: usize,
        seen: usize,
    ) -> Self {
        assert_eq!(v.len(), dim, "direction length mismatch");
        assert_eq!(s.len(), dim, "axis-scale length mismatch");
        let inv_s2 = s.iter().map(|&sj| 1.0 / (sj * sj)).collect();
        EllipsoidSvm { v, sigma, s, inv_s2, wnorm2s, r, xi2, m, adapt, opts, dim, seen }
    }
}

impl Classifier for EllipsoidSvm {
    /// Confidence-weighted margin `Σⱼ wⱼ xⱼ / sⱼ²` — axes with large
    /// learned spread (low confidence) are down-weighted. With the
    /// isotropic metric this is exactly the ball's raw margin.
    fn score(&self, x: &[f32]) -> f64 {
        self.sigma * linalg::dot_scaled(&self.v, x, &self.inv_s2)
    }

    fn score_view(&self, x: FeaturesView<'_>) -> f64 {
        match x {
            FeaturesView::Dense(xs) => self.score(xs),
            FeaturesView::Sparse { idx, val, .. } => {
                self.sigma * linalg::sparse_dot_scaled(&self.v, &self.inv_s2, idx, val)
            }
        }
    }
}

/// Validated observation (`try_observe`) comes from the trait's default
/// body — the guard logic lives once, in [`crate::svm::learner`].
impl StreamLearner for EllipsoidSvm {
    fn variant(&self) -> Variant {
        Variant::Ellipsoid
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn options(&self) -> &TrainOptions {
        &self.opts
    }

    #[inline]
    fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        EllipsoidSvm::observe_view(self, x, y)
    }

    fn radius(&self) -> f64 {
        self.r
    }

    fn xi2(&self) -> f64 {
        self.xi2
    }

    fn examples_seen(&self) -> usize {
        self.seen
    }

    fn num_support(&self) -> usize {
        self.m
    }

    /// A ball over the materialized center. Exact for the isotropic
    /// metric; for the adaptive metric it is the Euclidean summary the
    /// cross-shard merge tree aggregates (the learned axes are a
    /// per-shard refinement the ball summary deliberately flattens).
    fn summary_ball(&self) -> Option<crate::svm::ball::BallState> {
        if self.m == 0 {
            return None;
        }
        Some(crate::svm::ball::BallState::from_parts(self.weights(), self.r, self.xi2, self.m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::eval::accuracy;
    use crate::prop::{check_default, gen};
    use crate::rng::Pcg32;
    use crate::svm::streamsvm::StreamSvm;

    #[test]
    fn isotropic_matches_ball_exactly() {
        // The fixed-metric variant is Algorithm 1 in disguise: identical
        // update decisions and identical (w, R, ξ², M).
        check_default("ellipsoid-isotropic-equals-ball", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 60, d, 1.5, 0.4);
            let opts = TrainOptions::default().with_c(0.5 + rng.uniform() * 4.0);
            let mut ball = StreamSvm::new(d, opts);
            let mut ell = EllipsoidSvm::isotropic(d, opts);
            for (x, y) in xs.iter().zip(&ys) {
                let u1 = ball.observe(x, *y);
                let u2 = ell.observe(x, *y);
                if u1 != u2 {
                    return Err("update decisions diverged".into());
                }
            }
            if ball.num_support() != ell.num_support() {
                return Err("M diverged".into());
            }
            if (ball.radius() - ell.radius()).abs() > 1e-12 * ball.radius().max(1.0) {
                return Err(format!("R {} vs {}", ball.radius(), ell.radius()));
            }
            let bxi2 = ball.ball().map(|b| b.xi2).unwrap_or(0.0);
            if (bxi2 - ell.xi2()).abs() > 1e-12 {
                return Err(format!("ξ² {} vs {}", bxi2, ell.xi2()));
            }
            if ball.weights() != ell.weights() {
                return Err("w diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn axes_grow_where_variance_is() {
        // dim 0 has 10x the spread of dim 1: the learned metric scales
        // must reflect that anisotropy.
        let mut rng = Pcg32::seeded(1);
        let mut m = EllipsoidSvm::new(2, TrainOptions::default());
        for _ in 0..2000 {
            let x = vec![(rng.normal() * 10.0) as f32, rng.normal() as f32];
            m.observe(&x, 1.0);
        }
        assert!(m.axes()[0] > 4.0 * m.axes()[1], "s = {:?}", m.axes());
    }

    #[test]
    fn axes_and_radius_monotone_property() {
        check_default("ellipsoid-monotone", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 60, d, 1.5, 0.4);
            let mut m = EllipsoidSvm::new(d, TrainOptions::default());
            let mut prev_s = m.axes().to_vec();
            let mut prev_r = 0.0;
            for (x, y) in xs.iter().zip(&ys) {
                m.observe(x, *y);
                if m.radius() < prev_r - 1e-9 {
                    return Err(format!("radius shrank {prev_r} -> {}", m.radius()));
                }
                prev_r = m.radius();
                for j in 0..d {
                    if m.axes()[j] + 1e-12 < prev_s[j] {
                        return Err(format!("axis {j} shrank"));
                    }
                }
                prev_s = m.axes().to_vec();
            }
            Ok(())
        });
    }

    #[test]
    fn sparse_observe_matches_dense() {
        // The O(nnz) view path (including metric adaptation, which keys
        // off stored non-zeros) must follow the dense trajectory.
        check_default("ellipsoid-sparse-dense", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 60, d, 1.5, 0.4);
            let opts = TrainOptions::default();
            let mut dense = EllipsoidSvm::new(d, opts);
            let mut sparse = EllipsoidSvm::new(d, opts);
            for (x, y) in xs.iter().zip(&ys) {
                let f = crate::data::Features::Dense(x.clone()).to_sparse();
                let ud = dense.observe(x, *y);
                let us = sparse.observe_view(f.view(), *y);
                if ud != us {
                    return Err("update decisions diverged".into());
                }
            }
            if dense.num_support() != sparse.num_support() {
                return Err("M diverged".into());
            }
            if (dense.radius() - sparse.radius()).abs() > 1e-9 * dense.radius().max(1.0) {
                return Err(format!("R {} vs {}", dense.radius(), sparse.radius()));
            }
            for (a, b) in dense.axes().iter().zip(sparse.axes()) {
                if (a - b).abs() > 1e-9 * a.max(1.0) {
                    return Err(format!("axes diverged {a} vs {b}"));
                }
            }
            for (a, b) in dense.weights().iter().zip(sparse.weights()) {
                if (a - b).abs() > 1e-5 {
                    return Err(format!("w diverged {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn beats_ball_on_anisotropic_data() {
        // synthC-like geometry: signal on axis 0, large distractor
        // variance elsewhere. The whitened scoring should recover the
        // signal that drags the isotropic ball.
        let mut rng = Pcg32::seeded(3);
        // a clean first example pins both learners' seed center on the
        // signal axis (both get it — the comparison stays fair)
        let mut exs = vec![Example::new(vec![1.2, 0.0, 0.0, 0.0, 0.0], 1.0)];
        for _ in 0..4000 {
            let y = rng.label(0.5);
            let mut x = vec![(y as f64 * 1.2 + rng.normal() * 0.8) as f32];
            for _ in 0..4 {
                x.push((rng.normal() * 6.0) as f32);
            }
            exs.push(Example::new(x, y));
        }
        let opts = TrainOptions::default();
        let ball = StreamSvm::fit(exs.iter(), 5, &opts);
        let ell = EllipsoidSvm::fit(exs.iter(), 5, &opts);
        let (ab, ae) = (accuracy(&ball, &exs), accuracy(&ell, &exs));
        assert!(ae > ab + 0.04, "ellipsoid {ae:.3} vs ball {ab:.3}");
        assert!(ae > 0.8, "ellipsoid {ae:.3}");
    }

    #[test]
    fn update_count_sublinear_on_benign_stream() {
        let mut rng = Pcg32::seeded(4);
        let (xs, ys) = gen::labeled_points(&mut rng, 5000, 6, 1.0, 0.5);
        let mut m = EllipsoidSvm::new(6, TrainOptions::default());
        for (x, y) in xs.iter().zip(&ys) {
            m.observe(x, *y);
        }
        assert!(m.num_updates() < 1000, "updates {}", m.num_updates());
    }

    #[test]
    fn nan_features_never_poison_the_center() {
        // Regression (mirrors the PR-4 multiball/lookahead fixes): a NaN
        // distance must be skipped, never blended into (w, R, ξ²).
        let mk = || {
            let mut m = EllipsoidSvm::new(2, TrainOptions::default());
            m.observe(&[1.0, 0.0], 1.0);
            m.observe(&[0.0, 4.0], -1.0);
            m
        };
        if cfg!(debug_assertions) {
            let r = std::panic::catch_unwind(|| {
                let mut m = mk();
                m.observe(&[f32::NAN, 0.0], 1.0);
            });
            let payload = r.expect_err("debug build should assert");
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            assert!(msg.contains("non-finite"), "unexpected panic: {msg}");
        } else {
            let mut m = mk();
            let supports = m.num_support();
            assert!(!m.observe(&[f32::NAN, 0.0], 1.0));
            assert_eq!(m.num_support(), supports);
            assert!(m.radius().is_finite());
            assert!(m.weights().iter().all(|w| w.is_finite()), "NaN poisoned the center");
            // a NaN first example must not seed the center either
            let mut m = EllipsoidSvm::new(1, TrainOptions::default());
            assert!(!m.observe(&[f32::NAN], 1.0));
            assert_eq!(m.num_support(), 0);
        }
        // the validated entry point surfaces the defect as an error
        let mut m = mk();
        let err = m.try_observe(FeaturesView::Dense(&[f32::NAN, 0.0]), 1.0).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        let err = m.try_observe(FeaturesView::Dense(&[1.0]), 1.0).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        let err = m.try_observe(FeaturesView::Dense(&[1.0, 2.0]), 0.0).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        // rejects consumed no stream position; valid input still flows
        assert_eq!(m.examples_seen(), 2);
        assert!(m.try_observe(FeaturesView::Dense(&[9.0, 9.0]), 1.0).is_ok());
        assert_eq!(m.examples_seen(), 3);
    }
}
