//! Kernelized StreamSVM (paper §4.2).
//!
//! Instead of the explicit weight vector, stores the signed Lagrange
//! coefficients α over the absorbed core set (α includes the label sign:
//! init `α = [y₁]`). Distance to a new candidate (paper's d² formula):
//!
//!   d² = Σ αₙαₘ K(xₙ,xₘ) + K(x,x) − 2 y Σ αₘ K(xₘ,x) + ξ² + 1/C
//!
//! The quadratic term (the center's feature-space norm) is maintained
//! incrementally across updates, so each example costs O(M·cost(K))
//! rather than O(M²).

use crate::data::Example;
use crate::eval::Classifier;
use crate::svm::kernelfn::Kernel;
use crate::svm::TrainOptions;

/// Kernelized Algorithm 1.
#[derive(Clone, Debug)]
pub struct KernelStreamSvm {
    kernel: Kernel,
    /// Stored core vectors.
    svs: Vec<(Vec<f32>, f32)>,
    /// Signed coefficients (include the label factor).
    alpha: Vec<f64>,
    /// `||feature part of center||²`, maintained incrementally.
    feat_norm2: f64,
    r: f64,
    xi2: f64,
    opts: TrainOptions,
    seen: usize,
}

impl KernelStreamSvm {
    pub fn new(kernel: Kernel, opts: TrainOptions) -> Self {
        KernelStreamSvm {
            kernel,
            svs: Vec::new(),
            alpha: Vec::new(),
            feat_norm2: 0.0,
            r: 0.0,
            xi2: opts.s2(),
            opts,
            seen: 0,
        }
    }

    /// `f(x) = Σ αₘ K(xₘ, x)` — the raw decision value.
    fn f(&self, x: &[f32]) -> f64 {
        self.svs
            .iter()
            .zip(&self.alpha)
            .map(|((sx, _), &a)| a * self.kernel.eval(sx, x))
            .sum()
    }

    /// Distance of `φ̃((x, y))` to the current center.
    pub fn distance(&self, x: &[f32], y: f32) -> f64 {
        let kxx = self.kernel.self_eval(x);
        let d2 = self.feat_norm2 + kxx - 2.0 * y as f64 * self.f(x) + self.xi2 + self.opts.invc();
        d2.max(0.0).sqrt()
    }

    /// Stream one example.
    pub fn observe(&mut self, x: &[f32], y: f32) -> bool {
        self.seen += 1;
        if self.svs.is_empty() {
            self.feat_norm2 = self.kernel.self_eval(x);
            self.svs.push((x.to_vec(), y));
            self.alpha.push(y as f64);
            return true;
        }
        let d = self.distance(x, y);
        if d < self.r {
            return false;
        }
        let beta = 0.5 * (1.0 - self.r / d);
        let fx = self.f(x);
        let kxx = self.kernel.self_eval(x);
        // α ← (1−β) α ; α_new = β y   (paper §4.2)
        for a in self.alpha.iter_mut() {
            *a *= 1.0 - beta;
        }
        self.alpha.push(beta * y as f64);
        self.svs.push((x.to_vec(), y));
        // ||c'||² = (1−β)²||c||² + 2(1−β)β y f(x) + β² K(x,x)
        let omb = 1.0 - beta;
        self.feat_norm2 =
            omb * omb * self.feat_norm2 + 2.0 * omb * beta * y as f64 * fx + beta * beta * kxx;
        self.r += 0.5 * (d - self.r);
        self.xi2 = self.xi2 * omb * omb + beta * beta * self.opts.s2();
        true
    }

    pub fn fit<'a, I: IntoIterator<Item = &'a Example>>(
        stream: I,
        kernel: Kernel,
        opts: &TrainOptions,
    ) -> Self {
        let mut m = KernelStreamSvm::new(kernel, *opts);
        for e in stream {
            m.observe(&e.x.dense(), e.y);
        }
        m
    }

    pub fn num_support(&self) -> usize {
        self.svs.len()
    }

    pub fn radius(&self) -> f64 {
        self.r
    }

    pub fn examples_seen(&self) -> usize {
        self.seen
    }
}

impl Classifier for KernelStreamSvm {
    fn score(&self, x: &[f32]) -> f64 {
        self.f(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::accuracy;
    use crate::prop::{check_default, gen};
    use crate::rng::Pcg32;
    use crate::svm::streamsvm::StreamSvm;

    #[test]
    fn linear_kernel_matches_explicit_streamsvm() {
        // The kernelized path with a linear kernel must reproduce the
        // explicit-w Algorithm 1 exactly (same updates, same radius).
        check_default("kernelized-linear-equiv", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 48, d, 1.0, 0.4);
            let opts = TrainOptions::default().with_c(2.0);
            let mut lin = StreamSvm::new(d, opts);
            let mut ker = KernelStreamSvm::new(Kernel::Linear, opts);
            for (x, y) in xs.iter().zip(&ys) {
                let u1 = lin.observe(x, *y);
                let u2 = ker.observe(x, *y);
                if u1 != u2 {
                    return Err("update decisions diverged".into());
                }
            }
            if (lin.radius() - ker.radius()).abs() > 1e-6 * lin.radius().max(1.0) {
                return Err(format!("radius {} vs {}", lin.radius(), ker.radius()));
            }
            // scores agree on random probes
            for _ in 0..8 {
                let probe: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let s1 = lin.score(&probe);
                let s2 = ker.score(&probe);
                if (s1 - s2).abs() > 1e-4 * s1.abs().max(1.0) {
                    return Err(format!("scores {s1} vs {s2}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rbf_solves_xor() {
        // XOR: linearly inseparable, RBF-separable — the point of §4.2.
        let mut rng = Pcg32::seeded(5);
        let mut train = Vec::new();
        for _ in 0..400 {
            let a = rng.bernoulli(0.5);
            let b = rng.bernoulli(0.5);
            let y = if a ^ b { 1.0 } else { -1.0 };
            let x = vec![
                (if a { 1.0 } else { -1.0 }) + rng.normal() as f32 * 0.15,
                (if b { 1.0 } else { -1.0 }) + rng.normal() as f32 * 0.15,
            ];
            train.push(Example::new(x, y));
        }
        let opts = TrainOptions::default().with_c(100.0);
        let ker = KernelStreamSvm::fit(train.iter(), Kernel::Rbf { gamma: 1.0 }, &opts);
        let lin = StreamSvm::fit(train.iter(), 2, &opts);
        let acc_k = accuracy(&ker, &train);
        let acc_l = accuracy(&lin, &train);
        assert!(acc_k > 0.9, "rbf acc {acc_k}");
        assert!(acc_l < 0.7, "linear should fail on xor, got {acc_l}");
    }

    #[test]
    fn radius_monotone() {
        let mut rng = Pcg32::seeded(6);
        let (xs, ys) = gen::labeled_points(&mut rng, 100, 4, 1.0, 0.2);
        let mut m = KernelStreamSvm::new(Kernel::Rbf { gamma: 0.3 }, TrainOptions::default());
        let mut prev = 0.0;
        for (x, y) in xs.iter().zip(&ys) {
            m.observe(x, *y);
            assert!(m.radius() >= prev - 1e-9);
            prev = m.radius();
        }
    }
}
