//! Kernelized StreamSVM (paper §4.2).
//!
//! Instead of the explicit weight vector, stores the signed Lagrange
//! coefficients α over the absorbed core set (α includes the label sign:
//! init `α = [y₁]`). Distance to a new candidate (paper's d² formula):
//!
//!   d² = Σ αₙαₘ K(xₙ,xₘ) + K(x,x) − 2 y Σ αₘ K(xₘ,x) + ξ² + 1/C
//!
//! The quadratic term (the center's feature-space norm) is maintained
//! incrementally across updates, so each example costs O(M·cost(K))
//! rather than O(M²).
//!
//! Core-set points keep their arriving representation (sparse rows stay
//! sparse) and cache their squared norm, so every kernel evaluation goes
//! through the norm expansion `‖x‖² + ‖z‖² − 2⟨x,z⟩` and `cost(K)` is
//! O(nnz) per stored point — the observe path never densifies.

use crate::data::{Example, Features, FeaturesView};
use crate::error::Result;
use crate::eval::Classifier;
use crate::svm::kernelfn::Kernel;
use crate::svm::learner::{StreamLearner, Variant};
use crate::svm::TrainOptions;

/// One absorbed core-set point: features in their arriving
/// representation plus the cached `‖x‖²` the norm-expansion kernel
/// evaluations need.
#[derive(Clone, Debug)]
struct CorePoint {
    x: Features,
    norm2: f64,
}

/// Kernelized Algorithm 1.
#[derive(Clone, Debug)]
pub struct KernelStreamSvm {
    kernel: Kernel,
    /// Stored core vectors (sparse rows stay sparse; `‖x‖²` cached).
    svs: Vec<CorePoint>,
    /// Signed coefficients (include the label factor).
    alpha: Vec<f64>,
    /// `||feature part of center||²`, maintained incrementally.
    feat_norm2: f64,
    r: f64,
    xi2: f64,
    opts: TrainOptions,
    /// Dimension pinned by the first observed example.
    dim: Option<usize>,
    seen: usize,
}

impl KernelStreamSvm {
    pub fn new(kernel: Kernel, opts: TrainOptions) -> Self {
        KernelStreamSvm {
            kernel,
            svs: Vec::new(),
            alpha: Vec::new(),
            feat_norm2: 0.0,
            r: 0.0,
            xi2: opts.s2(),
            opts,
            dim: None,
            seen: 0,
        }
    }

    /// [`Self::new`] with the dimension pinned up front (the serving /
    /// pipeline layers know the stream's declared dimension before the
    /// first example arrives, so wrong-dimension inputs can be rejected
    /// immediately instead of seeding a mis-sized core set). Observing
    /// behaves identically to the lazily-pinned path.
    pub fn with_dim(kernel: Kernel, dim: usize, opts: TrainOptions) -> Self {
        let mut m = KernelStreamSvm::new(kernel, opts);
        m.dim = Some(dim);
        m
    }

    /// Rebuild from exact serialized state (the `.meb` v4 decode path).
    /// Fields are bit-copied, so a restored model scores and continues
    /// training identically to the one that was encoded.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        kernel: Kernel,
        dim: Option<usize>,
        svs: Vec<(Features, f64)>,
        alpha: Vec<f64>,
        feat_norm2: f64,
        r: f64,
        xi2: f64,
        opts: TrainOptions,
        seen: usize,
    ) -> Self {
        assert_eq!(svs.len(), alpha.len(), "core set / coefficient length mismatch");
        KernelStreamSvm {
            kernel,
            svs: svs.into_iter().map(|(x, norm2)| CorePoint { x, norm2 }).collect(),
            alpha,
            feat_norm2,
            r,
            xi2,
            opts,
            dim,
            seen,
        }
    }

    /// `f(x) = Σ αₘ K(xₘ, x)` — the raw decision value, O(Σ nnz) over
    /// the core set given the example's cached `‖x‖²`.
    fn f_view(&self, x: FeaturesView<'_>, xn2: f64) -> f64 {
        self.svs
            .iter()
            .zip(&self.alpha)
            .map(|(sv, &a)| a * self.kernel.eval_view(sv.x.view(), sv.norm2, x, xn2))
            .sum()
    }

    /// Distance of `φ̃((x, y))` to the current center.
    pub fn distance(&self, x: &[f32], y: f32) -> f64 {
        self.distance_view(FeaturesView::Dense(x), y)
    }

    /// [`Self::distance`] for a dense-or-sparse feature view — O(M·nnz).
    pub fn distance_view(&self, x: FeaturesView<'_>, y: f32) -> f64 {
        let xn2 = x.norm2();
        let kxx = self.kernel.self_eval_n2(xn2);
        let d2 =
            self.feat_norm2 + kxx - 2.0 * y as f64 * self.f_view(x, xn2) + self.xi2
                + self.opts.invc();
        d2.max(0.0).sqrt()
    }

    /// Stream one example.
    pub fn observe(&mut self, x: &[f32], y: f32) -> bool {
        self.observe_view(FeaturesView::Dense(x), y)
    }

    /// [`Self::observe`] for a dense-or-sparse feature view: the distance
    /// test and the coefficient update cost O(M·nnz) kernel work, and the
    /// absorbed point is stored in its arriving representation (sparse
    /// stays sparse — no densify anywhere on this path).
    pub fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        debug_assert!(
            self.dim.map_or(true, |d| d == x.dim()),
            "example dimension {} but the model saw {:?}",
            x.dim(),
            self.dim
        );
        self.seen += 1;
        let xn2 = x.norm2();
        if self.svs.is_empty() {
            if !xn2.is_finite() {
                // keep NaN/Inf out of the seed core point (mirrors
                // BallState::init guards; see try_observe for the
                // surfaced-error entry point)
                debug_assert!(false, "non-finite features in KernelStreamSvm::observe");
                return false;
            }
            self.dim = Some(x.dim());
            self.feat_norm2 = self.kernel.self_eval_n2(xn2);
            self.svs.push(CorePoint { x: x.to_features(), norm2: xn2 });
            self.alpha.push(y as f64);
            self.tap_telemetry(true);
            return true;
        }
        let fx = self.f_view(x, xn2);
        let kxx = self.kernel.self_eval_n2(xn2);
        let d2 = self.feat_norm2 + kxx - 2.0 * y as f64 * fx + self.xi2 + self.opts.invc();
        let d = d2.max(0.0).sqrt();
        if !d.is_finite() {
            // A non-finite distance (NaN features smuggled past the
            // ingestion guards) must not poison the core set: `d < r` is
            // false for NaN, so the unguarded blend below would corrupt
            // α and the cached norm forever.
            debug_assert!(false, "non-finite distance in KernelStreamSvm::observe (d = {d})");
            return false;
        }
        if d < self.r {
            self.tap_telemetry(false);
            return false;
        }
        let beta = 0.5 * (1.0 - self.r / d);
        // α ← (1−β) α ; α_new = β y   (paper §4.2)
        for a in self.alpha.iter_mut() {
            *a *= 1.0 - beta;
        }
        self.alpha.push(beta * y as f64);
        self.svs.push(CorePoint { x: x.to_features(), norm2: xn2 });
        // ||c'||² = (1−β)²||c||² + 2(1−β)β y f(x) + β² K(x,x)
        let omb = 1.0 - beta;
        self.feat_norm2 =
            omb * omb * self.feat_norm2 + 2.0 * omb * beta * y as f64 * fx + beta * beta * kxx;
        self.r += 0.5 * (d - self.r);
        self.xi2 = self.xi2 * omb * omb + beta * beta * self.opts.s2();
        self.tap_telemetry(true);
        true
    }

    /// Training-dynamics tap: one relaxed load when telemetry is off.
    #[inline]
    fn tap_telemetry(&self, updated: bool) {
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::record_example(updated);
            crate::obs::telemetry::RADIUS.set(self.r);
            crate::obs::telemetry::CORESET.set(self.svs.len() as f64);
        }
    }

    pub fn fit<'a, I: IntoIterator<Item = &'a Example>>(
        stream: I,
        kernel: Kernel,
        opts: &TrainOptions,
    ) -> Self {
        let mut m = KernelStreamSvm::new(kernel, *opts);
        for e in stream {
            m.observe_view(e.x.view(), e.y);
        }
        m
    }

    pub fn num_support(&self) -> usize {
        self.svs.len()
    }

    pub fn radius(&self) -> f64 {
        self.r
    }

    /// Slack mass of the center (the ξ² bookkeeping term).
    pub fn xi2(&self) -> f64 {
        self.xi2
    }

    /// The signed coefficients over the core set. Invariant of the
    /// Algorithm-1 blend: `α_m = c_m · y_m` with `c_m ≥ 0` and
    /// `Σ c_m = 1`, i.e. `Σ |α_m| = 1` (the convex-combination law the
    /// conformance suite checks).
    pub fn coefficients(&self) -> &[f64] {
        &self.alpha
    }

    /// Dimension pinned by the first observed example (`None` before).
    pub fn dim(&self) -> Option<usize> {
        self.dim
    }

    pub fn examples_seen(&self) -> usize {
        self.seen
    }

    /// The kernel this model evaluates.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The cached `‖center‖²` in feature space.
    pub fn feat_norm2(&self) -> f64 {
        self.feat_norm2
    }

    /// Core-set points with their cached squared norms, in absorption
    /// order (what the `.meb` v4 encoder walks).
    pub fn support_points(&self) -> impl Iterator<Item = (&Features, f64)> {
        self.svs.iter().map(|sv| (&sv.x, sv.norm2))
    }

    /// The explicit primal weights `w = Σ αₘ xₘ` — defined only for the
    /// linear kernel, where the feature map is the identity. `None` for
    /// non-linear kernels (and before any data for an unpinned model).
    pub fn linear_weights(&self) -> Option<Vec<f32>> {
        if self.kernel != Kernel::Linear {
            return None;
        }
        let dim = self.dim?;
        let mut w = vec![0.0f32; dim];
        for (sv, &a) in self.svs.iter().zip(&self.alpha) {
            sv.x.view().axpy_into(&mut w, a as f32);
        }
        Some(w)
    }
}

/// The trait's default `try_observe` is overridden here: the expected
/// dimension is pinned lazily by the first example, so until then the
/// guard validates against the example's own dimension (the guard logic
/// itself still lives once, in [`crate::svm::validate_example`]).
impl StreamLearner for KernelStreamSvm {
    fn variant(&self) -> Variant {
        Variant::Kernelized
    }

    /// 0 while the dimension is still unpinned.
    fn dim(&self) -> usize {
        self.dim.unwrap_or(0)
    }

    fn options(&self) -> &TrainOptions {
        &self.opts
    }

    #[inline]
    fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        KernelStreamSvm::observe_view(self, x, y)
    }

    fn try_observe(&mut self, x: FeaturesView<'_>, y: f32) -> Result<bool> {
        let dim = self.dim.unwrap_or(x.dim());
        crate::svm::validate_example(x, y, dim)?;
        Ok(self.observe_view(x, y))
    }

    fn radius(&self) -> f64 {
        self.r
    }

    fn xi2(&self) -> f64 {
        self.xi2
    }

    fn examples_seen(&self) -> usize {
        self.seen
    }

    fn num_support(&self) -> usize {
        self.svs.len()
    }

    /// A primal ball exists only under the linear kernel.
    fn summary_ball(&self) -> Option<crate::svm::ball::BallState> {
        let w = self.linear_weights()?;
        if self.svs.is_empty() {
            return None;
        }
        Some(crate::svm::ball::BallState::from_parts(w, self.r, self.xi2, self.svs.len()))
    }
}

impl Classifier for KernelStreamSvm {
    fn score(&self, x: &[f32]) -> f64 {
        Classifier::score_view(self, FeaturesView::Dense(x))
    }

    fn score_view(&self, x: FeaturesView<'_>) -> f64 {
        self.f_view(x, x.norm2())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::eval::accuracy;
    use crate::prop::{check_default, gen};
    use crate::rng::Pcg32;
    use crate::svm::streamsvm::StreamSvm;

    #[test]
    fn linear_kernel_matches_explicit_streamsvm() {
        // The kernelized path with a linear kernel must reproduce the
        // explicit-w Algorithm 1 exactly (same updates, same radius).
        check_default("kernelized-linear-equiv", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 48, d, 1.0, 0.4);
            let opts = TrainOptions::default().with_c(2.0);
            let mut lin = StreamSvm::new(d, opts);
            let mut ker = KernelStreamSvm::new(Kernel::Linear, opts);
            for (x, y) in xs.iter().zip(&ys) {
                let u1 = lin.observe(x, *y);
                let u2 = ker.observe(x, *y);
                if u1 != u2 {
                    return Err("update decisions diverged".into());
                }
            }
            if (lin.radius() - ker.radius()).abs() > 1e-6 * lin.radius().max(1.0) {
                return Err(format!("radius {} vs {}", lin.radius(), ker.radius()));
            }
            // scores agree on random probes
            for _ in 0..8 {
                let probe: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let s1 = lin.score(&probe);
                let s2 = ker.score(&probe);
                if (s1 - s2).abs() > 1e-4 * s1.abs().max(1.0) {
                    return Err(format!("scores {s1} vs {s2}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sparse_observe_matches_dense() {
        // The O(nnz) view path must follow the identical trajectory as
        // densified input, for every kernel.
        check_default("kernelized-sparse-dense", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 40, d, 1.0, 0.4);
            for kernel in [
                Kernel::Linear,
                Kernel::Rbf { gamma: 0.4 },
                Kernel::Poly { degree: 2, coef: 1.0 },
            ] {
                let opts = TrainOptions::default();
                let mut dense = KernelStreamSvm::new(kernel, opts);
                let mut sparse = KernelStreamSvm::new(kernel, opts);
                for (x, y) in xs.iter().zip(&ys) {
                    let f = crate::data::Features::Dense(x.clone()).to_sparse();
                    let ud = dense.observe(x, *y);
                    let us = sparse.observe_view(f.view(), *y);
                    if ud != us {
                        return Err(format!("{kernel:?}: update decisions diverged"));
                    }
                }
                if dense.num_support() != sparse.num_support() {
                    return Err(format!("{kernel:?}: support counts diverged"));
                }
                let rel = (dense.radius() - sparse.radius()).abs() / dense.radius().max(1.0);
                if rel > 1e-9 {
                    return Err(format!("{kernel:?}: radius diverged ({rel})"));
                }
                // sparse storage actually survived (no densify): probe scores agree
                let probe: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let (s1, s2) = (dense.score(&probe), sparse.score(&probe));
                if (s1 - s2).abs() > 1e-6 * s1.abs().max(1.0) {
                    return Err(format!("{kernel:?}: scores {s1} vs {s2}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rbf_solves_xor() {
        // XOR: linearly inseparable, RBF-separable — the point of §4.2.
        let mut rng = Pcg32::seeded(5);
        let mut train = Vec::new();
        for _ in 0..400 {
            let a = rng.bernoulli(0.5);
            let b = rng.bernoulli(0.5);
            let y = if a ^ b { 1.0 } else { -1.0 };
            let x = vec![
                (if a { 1.0 } else { -1.0 }) + rng.normal() as f32 * 0.15,
                (if b { 1.0 } else { -1.0 }) + rng.normal() as f32 * 0.15,
            ];
            train.push(Example::new(x, y));
        }
        let opts = TrainOptions::default().with_c(100.0);
        let ker = KernelStreamSvm::fit(train.iter(), Kernel::Rbf { gamma: 1.0 }, &opts);
        let lin = StreamSvm::fit(train.iter(), 2, &opts);
        let acc_k = accuracy(&ker, &train);
        let acc_l = accuracy(&lin, &train);
        assert!(acc_k > 0.9, "rbf acc {acc_k}");
        assert!(acc_l < 0.7, "linear should fail on xor, got {acc_l}");
    }

    #[test]
    fn radius_monotone() {
        let mut rng = Pcg32::seeded(6);
        let (xs, ys) = gen::labeled_points(&mut rng, 100, 4, 1.0, 0.2);
        let mut m = KernelStreamSvm::new(Kernel::Rbf { gamma: 0.3 }, TrainOptions::default());
        let mut prev = 0.0;
        for (x, y) in xs.iter().zip(&ys) {
            m.observe(x, *y);
            assert!(m.radius() >= prev - 1e-9);
            prev = m.radius();
        }
    }

    #[test]
    fn coefficients_stay_a_signed_convex_combination() {
        let mut rng = Pcg32::seeded(7);
        let (xs, ys) = gen::labeled_points(&mut rng, 80, 5, 1.2, 0.3);
        let mut m = KernelStreamSvm::new(Kernel::Rbf { gamma: 0.5 }, TrainOptions::default());
        for (x, y) in xs.iter().zip(&ys) {
            m.observe(x, *y);
            let sum_abs: f64 = m.coefficients().iter().map(|a| a.abs()).sum();
            assert!((sum_abs - 1.0).abs() < 1e-9, "Σ|α| = {sum_abs}");
            assert!(m.coefficients().iter().all(|a| a.abs() <= 1.0 + 1e-12));
        }
    }

    #[test]
    fn nan_features_never_poison_the_core_set() {
        // Regression (mirrors the PR-4 multiball/lookahead fixes): a NaN
        // feature's distance is NaN, `d < r` is false, and the unguarded
        // blend used to corrupt α and the cached norm forever.
        let mk = || {
            let mut m = KernelStreamSvm::new(Kernel::Rbf { gamma: 0.5 }, TrainOptions::default());
            m.observe(&[1.0, 0.0], 1.0);
            m.observe(&[0.0, 4.0], -1.0);
            m
        };
        if cfg!(debug_assertions) {
            let r = std::panic::catch_unwind(|| {
                let mut m = mk();
                m.observe(&[f32::NAN, 0.0], 1.0);
            });
            let payload = r.expect_err("debug build should assert");
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            assert!(msg.contains("non-finite"), "unexpected panic: {msg}");
        } else {
            let mut m = mk();
            let supports = m.num_support();
            assert!(!m.observe(&[f32::NAN, 0.0], 1.0));
            assert_eq!(m.num_support(), supports, "NaN example reached the core set");
            assert!(m.radius().is_finite());
            assert!(m.score(&[1.0, 1.0]).is_finite(), "NaN poisoned the coefficients");
            // a NaN first example must not seed the core set either
            let mut m = KernelStreamSvm::new(Kernel::Linear, TrainOptions::default());
            assert!(!m.observe(&[f32::NAN], 1.0));
            assert_eq!(m.num_support(), 0);
        }
        // the validated entry point surfaces the defect as an error
        let mut m = mk();
        let err = m.try_observe(FeaturesView::Dense(&[f32::NAN, 0.0]), 1.0).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        // wrong dimension (vs the pinned first-example dim) → Config
        let err = m.try_observe(FeaturesView::Dense(&[1.0, 2.0, 3.0]), 1.0).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // bad label → Data
        let err = m.try_observe(FeaturesView::Dense(&[1.0, 2.0]), 0.5).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        // none of the rejects consumed a stream position or grew the set
        assert_eq!(m.examples_seen(), 2);
        assert_eq!(m.num_support(), 2);
        // a valid example still flows through
        assert!(m.try_observe(FeaturesView::Dense(&[9.0, -9.0]), 1.0).is_ok());
        assert_eq!(m.examples_seen(), 3);
    }
}
