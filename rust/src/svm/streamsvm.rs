//! Algorithm 1: StreamSVM — the one-pass, O(D)-memory ℓ₂-SVM learner.
//!
//! The per-example hot path accepts a [`FeaturesView`], so sparse
//! examples cost O(nnz) per update (see [`crate::svm::ball`]); the
//! `&[f32]` entry points remain for dense callers.

use crate::data::{Example, FeaturesView};
use crate::eval::Classifier;
use crate::svm::ball::BallState;
use crate::svm::learner::{StreamLearner, Variant};
use crate::svm::TrainOptions;

/// A trained (or in-training) StreamSVM model.
///
/// `fit` consumes the stream exactly once; `observe` exposes the same
/// update for the coordinator's incremental pipeline.
#[derive(Clone, Debug)]
pub struct StreamSvm {
    ball: Option<BallState>,
    opts: TrainOptions,
    dim: usize,
    seen: usize,
}

impl StreamSvm {
    pub fn new(dim: usize, opts: TrainOptions) -> Self {
        StreamSvm { ball: None, opts, dim, seen: 0 }
    }

    /// One streamed example (Algorithm 1 lines 4–11; line 3 on the first).
    pub fn observe(&mut self, x: &[f32], y: f32) -> bool {
        self.observe_view(FeaturesView::Dense(x), y)
    }

    /// [`Self::observe`] for a dense-or-sparse feature view — O(nnz).
    pub fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        debug_assert_eq!(x.dim(), self.dim);
        self.seen += 1;
        let updated = match &mut self.ball {
            None => {
                self.ball = Some(BallState::init_view(x, y, &self.opts));
                true
            }
            Some(b) => b.try_update_view(x, y, &self.opts),
        };
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::record_example(updated);
            if let Some(b) = &self.ball {
                crate::obs::telemetry::RADIUS.set(b.r);
                crate::obs::telemetry::WNORM.set(b.wnorm());
            }
        }
        updated
    }

    /// Train on a full stream in one pass.
    pub fn fit<'a, I: IntoIterator<Item = &'a Example>>(
        stream: I,
        dim: usize,
        opts: &TrainOptions,
    ) -> Self {
        let mut model = StreamSvm::new(dim, *opts);
        for e in stream {
            model.observe_view(e.x.view(), e.y);
        }
        model
    }

    /// The learned weight vector, materialized (zeros-length before any
    /// data; the ball stores the center factored as `σ·v`).
    pub fn weights(&self) -> Vec<f32> {
        self.ball.as_ref().map(|b| b.weights()).unwrap_or_default()
    }

    /// Current ball radius (the margin surrogate `R`).
    pub fn radius(&self) -> f64 {
        self.ball.as_ref().map(|b| b.r).unwrap_or(0.0)
    }

    /// Core-set size = number of updates = SV-count upper bound.
    pub fn num_support(&self) -> usize {
        self.ball.as_ref().map(|b| b.m).unwrap_or(0)
    }

    pub fn examples_seen(&self) -> usize {
        self.seen
    }

    /// Feature dimension this model was constructed for (valid before
    /// any data arrives, unlike `weights().len()`).
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn options(&self) -> &TrainOptions {
        &self.opts
    }

    /// Borrow the raw ball state (used by the coordinator and benches).
    pub fn ball(&self) -> Option<&BallState> {
        self.ball.as_ref()
    }

    /// Replace the ball state (used by the PJRT pipeline, which advances
    /// the state on-device and writes it back).
    pub fn set_ball(&mut self, ball: BallState, seen: usize) {
        self.ball = Some(ball);
        self.seen = seen;
    }
}

impl Classifier for StreamSvm {
    fn score(&self, x: &[f32]) -> f64 {
        match &self.ball {
            Some(b) => b.score(x),
            None => 0.0,
        }
    }

    fn score_view(&self, x: FeaturesView<'_>) -> f64 {
        match &self.ball {
            Some(b) => b.score_view(x),
            None => 0.0,
        }
    }
}

/// Validated observation (`try_observe`) comes from the trait's default
/// body — the guard logic lives once, in [`crate::svm::learner`].
impl StreamLearner for StreamSvm {
    fn variant(&self) -> Variant {
        Variant::Ball
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn options(&self) -> &TrainOptions {
        &self.opts
    }

    #[inline]
    fn observe_view(&mut self, x: FeaturesView<'_>, y: f32) -> bool {
        StreamSvm::observe_view(self, x, y)
    }

    fn radius(&self) -> f64 {
        StreamSvm::radius(self)
    }

    fn xi2(&self) -> f64 {
        self.ball.as_ref().map(|b| b.xi2).unwrap_or_else(|| self.opts.s2())
    }

    fn examples_seen(&self) -> usize {
        self.seen
    }

    fn num_support(&self) -> usize {
        StreamSvm::num_support(self)
    }

    fn summary_ball(&self) -> Option<BallState> {
        self.ball.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::eval::accuracy;
    use crate::prop::{check_default, gen};
    use crate::rng::Pcg32;

    fn toy_stream(n: usize, d: usize, sep: f64, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, sep);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    #[test]
    fn learns_separable_data() {
        let train = toy_stream(2000, 10, 1.5, 1);
        let test = toy_stream(500, 10, 1.5, 2);
        let model = StreamSvm::fit(train.iter(), 10, &TrainOptions::default());
        // seed 2 shares the generator's mean direction only in
        // distribution; re-train/test on the same draw for the check:
        let acc_train = accuracy(&model, &train);
        assert!(acc_train > 0.9, "train acc {acc_train}");
        assert!(model.num_support() >= 1);
        let _ = test;
    }

    #[test]
    fn single_example_model() {
        let e = Example::new(vec![1.0, -2.0], -1.0);
        let model = StreamSvm::fit([&e].into_iter().map(|x| &*x), 2, &TrainOptions::default());
        assert_eq!(model.weights(), &[-1.0, 2.0]);
        assert_eq!(model.radius(), 0.0);
        assert_eq!(model.num_support(), 1);
        assert_eq!(model.predict(&[1.0, -2.0]), -1.0);
    }

    #[test]
    fn empty_model_scores_zero() {
        let model = StreamSvm::new(3, TrainOptions::default());
        assert_eq!(model.score(&[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(model.num_support(), 0);
    }

    #[test]
    fn try_observe_validates_inputs() {
        let mut m = StreamSvm::new(3, TrainOptions::default());
        // wrong dimension → Error::Config with context, not a panic
        let err = m.try_observe(FeaturesView::Dense(&[1.0, 2.0]), 1.0).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("dimension 2"), "{err}");
        // non-finite features → Error::Data
        let err = m.try_observe(FeaturesView::Dense(&[1.0, f32::NAN, 0.0]), 1.0).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        // bad label → Error::Data
        let err = m.try_observe(FeaturesView::Dense(&[1.0, 2.0, 3.0]), 0.5).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        // none of the rejects consumed a stream position
        assert_eq!(m.examples_seen(), 0);
        // a valid example passes through to the ordinary update
        assert!(m.try_observe(FeaturesView::Dense(&[1.0, 2.0, 3.0]), 1.0).unwrap());
        assert_eq!(m.examples_seen(), 1);
    }

    #[test]
    fn sparse_observe_matches_dense() {
        let train = toy_stream(400, 8, 0.5, 11);
        let opts = TrainOptions::default();
        let dense = StreamSvm::fit(train.iter(), 8, &opts);
        let mut sparse = StreamSvm::new(8, opts);
        for e in &train {
            let s = e.x.to_sparse();
            sparse.observe_view(s.view(), e.y);
        }
        assert_eq!(dense.num_support(), sparse.num_support());
        assert!((dense.radius() - sparse.radius()).abs() < 1e-6 * dense.radius().max(1.0));
        for (a, b) in dense.weights().iter().zip(sparse.weights()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn support_count_at_most_stream_length() {
        check_default("sv-count-bound", |rng, _| {
            let d = gen::dim(rng);
            let n = 8 + rng.below(100);
            let (xs, ys) = gen::labeled_points(rng, n, d, 1.0, 0.2);
            let mut model = StreamSvm::new(d, TrainOptions::default());
            for (x, y) in xs.iter().zip(&ys) {
                model.observe(x, *y);
            }
            if model.num_support() > n || model.examples_seen() != n {
                return Err(format!(
                    "m = {} for n = {n}, seen = {}",
                    model.num_support(),
                    model.examples_seen()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn far_fewer_updates_than_examples_on_benign_data() {
        // The paper's observation: the number of MEB updates is much
        // smaller than e.g. Perceptron mistakes on benign streams.
        let train = toy_stream(10_000, 5, 1.0, 3);
        let model = StreamSvm::fit(train.iter(), 5, &TrainOptions::default());
        assert!(
            model.num_support() < train.len() / 10,
            "m = {} of {}",
            model.num_support(),
            train.len()
        );
    }
}
