//! Minimum-enclosing-ball solvers shared by Algorithm 2 and CVM.
//!
//! Two solvers, both Badoiu-Clarkson (farthest-point) style:
//!
//! * [`solve_merge`] — MEB of *(existing ball ∪ L buffered points)* in the
//!   augmented feature space, operating entirely in the coefficient space
//!   of the Gram matrix of `v_i = p_i − c0` (mirrors the AOT
//!   `merge_graph`; the PJRT path and this pure-Rust path are
//!   cross-checked in integration tests). The returned radius is the
//!   exact max-distance at the final center, so enclosure of the old ball
//!   and all buffered points holds unconditionally.
//!
//! * [`solve_meb_points`] — MEB of a set of augmented points, center kept
//!   as an explicit convex combination (used by the CVM baseline where
//!   the point set is the growing core set).

use crate::data::FeaturesView;
use crate::linalg;
use crate::svm::ball::BallState;
use crate::svm::TrainOptions;

const EPS: f64 = 1e-12;

/// Result of a ball∪points merge.
#[derive(Clone, Debug)]
pub struct MergeResult {
    pub ball: BallState,
    /// Convex coefficients over the buffered points (c = c0 + Σ μᵢ (pᵢ−c0)).
    pub mu: Vec<f64>,
}

/// Gram of `v_i = p_i − c0` plus the cross terms `cp_i = y_i⟨w, x_i⟩`
/// (needed again for the closed-form `‖w'‖²` of the merged center).
fn gram_with_cp(
    ball: &BallState,
    xs: &[FeaturesView<'_>],
    ys: &[f32],
    s2: f64,
) -> (Vec<f64>, Vec<f64>) {
    let l = ys.len();
    let cc = ball.center_norm2();
    let cp: Vec<f64> = (0..l).map(|i| ys[i] as f64 * ball.score_view(xs[i])).collect();
    let mut g = vec![0.0f64; l * l];
    for i in 0..l {
        for j in 0..=i {
            let mut v = ys[i] as f64 * ys[j] as f64 * xs[i].dot_view(&xs[j]);
            if i == j {
                v += s2;
            }
            v += cc - cp[i] - cp[j];
            g[i * l + j] = v;
            g[j * l + i] = v;
        }
    }
    (g, cp)
}

/// Gram matrix of `v_i = p_i − c0` in the augmented space (row-major L×L),
/// computed with the O(nnz) view kernels — O(L²·nnz) for sparse buffers
/// instead of O(L²·D).
///
/// `<p_i,p_j> = y_i y_j <x_i,x_j> + [i==j]·s²` (fresh orthogonal slacks),
/// `<c0,p_i> = y_i <w,x_i>` (the old center's slack mass is supported on
/// earlier stream indices, orthogonal to the buffer's), and
/// `<c0,c0> = ||w||² + ξ²`.
pub fn merge_gram(ball: &BallState, xs: &[FeaturesView<'_>], ys: &[f32], s2: f64) -> Vec<f64> {
    gram_with_cp(ball, xs, ys, s2).0
}

/// `max(||Vμ|| + r0, maxᵢ ||Vμ − vᵢ||)` evaluated from the Gram.
pub fn merge_objective(mu: &[f64], g: &[f64], r0: f64) -> f64 {
    let l = mu.len();
    let q: Vec<f64> = (0..l)
        .map(|i| (0..l).map(|j| g[i * l + j] * mu[j]).sum())
        .collect();
    let mgm: f64 = mu.iter().zip(&q).map(|(m, qi)| m * qi).sum::<f64>().max(0.0);
    let mut best = mgm.sqrt() + r0;
    for i in 0..l {
        let d2 = (mgm - 2.0 * q[i] + g[i * l + i]).max(0.0);
        best = best.max(d2.sqrt());
    }
    best
}

/// MEB of (ball ∪ points) via Badoiu-Clarkson in μ-space — the
/// non-mutating wrapper around [`solve_merge_into`] (tests, the PJRT
/// cross-checks). Hot paths call the in-place form to skip the O(D)
/// center copy.
pub fn solve_merge(
    ball: &BallState,
    xs: &[FeaturesView<'_>],
    ys: &[f32],
    opts: &TrainOptions,
) -> MergeResult {
    let mut out = ball.clone();
    let mu = solve_merge_into(&mut out, xs, ys, opts);
    MergeResult { ball: out, mu }
}

/// [`solve_merge`], updating `ball` in place: the Algorithm-2 flush
/// then costs O(L²·nnz) for the Gram plus O(Σ nnz) scatter-adds, with
/// no O(D) copy. Returns the convex coefficients μ.
///
/// Exactly mirrors the AOT `merge_graph`: at each step move 1/(t+2) of the
/// way toward the farthest entity — a buffered point, or the far pole of
/// the old ball (`q_μ = −μ·r0/||Vμ||`).
pub fn solve_merge_into(
    ball: &mut BallState,
    xs: &[FeaturesView<'_>],
    ys: &[f32],
    opts: &TrainOptions,
) -> Vec<f64> {
    let l = ys.len();
    assert_eq!(xs.len(), l);
    let s2 = opts.s2();
    let (g, cp) = gram_with_cp(ball, xs, ys, s2);
    let r0 = ball.r;
    let mut mu = vec![0.0f64; l];
    let mut q = vec![0.0f64; l];

    for t in 0..opts.merge_iters {
        // q = G μ, mgm = μᵀ G μ
        for i in 0..l {
            q[i] = (0..l).map(|j| g[i * l + j] * mu[j]).sum();
        }
        let mgm: f64 = mu.iter().zip(&q).map(|(m, qi)| m * qi).sum::<f64>().max(0.0);
        let dball = mgm.sqrt() + r0;
        let (mut far_i, mut far_d) = (0usize, f64::NEG_INFINITY);
        for i in 0..l {
            let d = (mgm - 2.0 * q[i] + g[i * l + i]).max(0.0).sqrt();
            if d > far_d {
                far_d = d;
                far_i = i;
            }
        }
        let step = 1.0 / (t as f64 + 2.0);
        if dball > far_d {
            if mgm <= EPS {
                continue; // center == c0 and the ball is farthest: stay
            }
            // Step toward the ball's far pole `q_μ = −μ·r0/||Vμ||`. When
            // `r0 ≫ ||Vμ||` the pole overshoots the origin and the raw
            // scale goes negative, which would push μ outside the simplex
            // and silently break the convex-coefficient invariant the
            // enclosure check and the ξ² bookkeeping assume — clamp the
            // scaled μ at 0 (the μ-space projection of that step back
            // onto the simplex).
            let scale = ((1.0 - step) - step * r0 / mgm.sqrt()).max(0.0);
            for m in mu.iter_mut() {
                *m *= scale;
            }
        } else {
            for (i, m) in mu.iter_mut().enumerate() {
                *m += step * ((i == far_i) as u8 as f64 - *m);
            }
        }
    }

    let r1 = merge_objective(&mu, &g, r0);
    let tot: f64 = mu.iter().sum();
    let mcp: f64 = mu.iter().zip(&cp).map(|(m, c)| m * c).sum();
    let mu2: f64 = mu.iter().map(|m| m * m).sum();
    // μᵀGμ at the final μ (one more O(L²) pass; the loop's value is stale
    // after the last update).
    let mgm: f64 = (0..l)
        .map(|i| mu[i] * (0..l).map(|j| g[i * l + j] * mu[j]).sum::<f64>())
        .sum();
    // Closed-form ‖w'‖² of w' = (1−Σμ)·w + Σ μᵢyᵢxᵢ, recovered from the
    // Gram (G folds in s², ⟨c0,c0⟩ and the cp cross terms):
    //   μᵀKμ = μᵀGμ − s²·Σμ² − ⟨c0,c0⟩·(Σμ)² + 2·Σμ·Σμᵢcpᵢ
    //   ‖w'‖² = (1−Σμ)²‖w‖² + 2(1−Σμ)·Σμᵢcpᵢ + μᵀKμ
    // which simplifies (the cp terms combine) to the expression below.
    let cc = ball.center_norm2();
    let wnorm2 = (1.0 - tot) * (1.0 - tot) * ball.wnorm2() + 2.0 * mcp + mgm
        - s2 * mu2
        - cc * tot * tot;
    // The expression differences O(cc)-sized terms: when the result is
    // tiny relative to them (the new center nearly cancels), its f64
    // error is amplified and the cached norm would poison every later
    // distance test — flag it so the ball recomputes the norm exactly
    // from the stored center instead (O(D), what the pre-factored code
    // always paid).
    let magnitude = (1.0 - tot) * (1.0 - tot) * ball.wnorm2()
        + 2.0 * mcp.abs()
        + mgm
        + s2 * mu2
        + cc * tot * tot;
    let wnorm2 = (wnorm2 > 1e-7 * magnitude).then_some(wnorm2);
    let xi1 = (1.0 - tot) * (1.0 - tot) * ball.xi2 + mu2 * s2;
    let coefs: Vec<f64> = mu.iter().zip(ys).map(|(m, &y)| m * y as f64).collect();
    ball.merge_into(1.0 - tot, xs, &coefs, wnorm2, r1, xi1, l);
    mu
}

/// MEB of a set of augmented points `φ̃(zᵢ)` via Badoiu-Clarkson with an
/// explicit convex-combination center. Returns the final state plus the
/// coefficients α (center = Σ αᵢ φ̃(zᵢ), Σα = 1, α ≥ 0).
///
/// Distances use the orthogonal-slack identity:
/// `d²(c, pᵢ) = ||w − yᵢxᵢ||² + ξ² − 2 s² αᵢ + s²` with `ξ² = s²·Σα²`.
pub struct PointsMeb {
    pub w: Vec<f32>,
    pub alpha: Vec<f64>,
    pub xi2: f64,
    pub r: f64,
}

pub fn solve_meb_points(
    xs: &[&[f32]],
    ys: &[f32],
    s2: f64,
    iters: usize,
) -> PointsMeb {
    let n = ys.len();
    assert!(n > 0);
    let dim = xs[0].len();
    let mut alpha = vec![0.0f64; n];
    alpha[0] = 1.0;
    let mut w = vec![0.0f32; dim];
    linalg::blend_into(&mut w, xs[0], ys[0], 1.0);
    let mut a2: f64 = 1.0; // Σ α²

    let sqdist = |w: &[f32], a2: f64, ai: f64, i: usize| -> f64 {
        linalg::sqdist_scaled(w, xs[i], ys[i]) + s2 * (a2 - 2.0 * ai + 1.0)
    };

    for t in 0..iters {
        let (mut far_i, mut far_d2) = (0usize, f64::NEG_INFINITY);
        for i in 0..n {
            let d2 = sqdist(&w, a2, alpha[i], i);
            if d2 > far_d2 {
                far_d2 = d2;
                far_i = i;
            }
        }
        let eta = 1.0 / (t as f64 + 2.0);
        // α ← (1−η) α + η e_far
        a2 = 0.0;
        for (i, a) in alpha.iter_mut().enumerate() {
            *a *= 1.0 - eta;
            if i == far_i {
                *a += eta;
            }
            a2 += *a * *a;
        }
        linalg::scale(&mut w, (1.0 - eta) as f32);
        linalg::axpy(&mut w, (eta * ys[far_i] as f64) as f32, xs[far_i]);
    }

    let xi2 = s2 * a2;
    let mut r2: f64 = 0.0;
    for i in 0..n {
        r2 = r2.max(sqdist(&w, a2, alpha[i], i));
    }
    PointsMeb { w, alpha, xi2, r: r2.sqrt() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check_default, gen};
    use crate::rng::Pcg32;

    fn mk_ball(dim: usize, rng: &mut Pcg32) -> BallState {
        let w: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        BallState::from_parts(w, 1.0 + rng.uniform(), 0.5, 3)
    }

    /// Explicit-space verification of the merge: materialize c0 and the
    /// points in (D + L + 1) dims (one slack dim per point + one for the
    /// old center's aggregated mass) and check enclosure.
    fn verify_enclosure(
        ball: &BallState,
        xs: &[&[f32]],
        ys: &[f32],
        s2: f64,
        res: &MergeResult,
        tol: f64,
    ) -> Result<(), String> {
        let d = ball.dim();
        let l = ys.len();
        let bw = ball.weights();
        let mut c0 = vec![0.0f64; d + l + 1];
        for i in 0..d {
            c0[i] = bw[i] as f64;
        }
        c0[d + l] = ball.xi2.sqrt();
        let mut pts = Vec::new();
        for i in 0..l {
            let mut p = vec![0.0f64; d + l + 1];
            for j in 0..d {
                p[j] = ys[i] as f64 * xs[i][j] as f64;
            }
            p[d + i] = s2.sqrt();
            pts.push(p);
        }
        let tot: f64 = res.mu.iter().sum();
        let mut c1: Vec<f64> = c0.iter().map(|v| v * (1.0 - tot)).collect();
        for (i, p) in pts.iter().enumerate() {
            for (c, pv) in c1.iter_mut().zip(p) {
                *c += res.mu[i] * pv;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
        };
        if dist(&c1, &c0) + ball.r > res.ball.r + tol {
            return Err(format!(
                "old ball not enclosed: {} + {} > {}",
                dist(&c1, &c0),
                ball.r,
                res.ball.r
            ));
        }
        for (i, p) in pts.iter().enumerate() {
            if dist(&c1, p) > res.ball.r + tol {
                return Err(format!("point {i} outside: {} > {}", dist(&c1, p), res.ball.r));
            }
        }
        // explicit-part & slack bookkeeping agree
        let rw = res.ball.weights();
        for j in 0..d {
            if (c1[j] - rw[j] as f64).abs() > 1e-3 {
                return Err(format!("w mismatch at {j}"));
            }
        }
        let slack2: f64 = c1[d..].iter().map(|v| v * v).sum();
        if (slack2 - res.ball.xi2).abs() > 1e-3 * slack2.max(1.0) {
            return Err(format!("xi2 mismatch: {slack2} vs {}", res.ball.xi2));
        }
        Ok(())
    }

    fn dense_views(xs: &[Vec<f32>]) -> Vec<FeaturesView<'_>> {
        xs.iter().map(|v| FeaturesView::Dense(v.as_slice())).collect()
    }

    #[test]
    fn merge_encloses_ball_and_points_property() {
        check_default("merge-enclosure", |rng, _| {
            let d = gen::dim(rng);
            let l = 1 + rng.below(12);
            let (xs, ys) = gen::labeled_points(rng, l, d, 1.5, 0.4);
            let ball = mk_ball(d, rng);
            let opts = TrainOptions::default().with_c(2.0);
            let xrefs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let res = solve_merge(&ball, &dense_views(&xs), &ys, &opts);
            verify_enclosure(&ball, &xrefs, &ys, opts.s2(), &res, 1e-3 * res.ball.r.max(1.0))
        });
    }

    #[test]
    fn merge_radius_at_least_r0() {
        check_default("merge-monotone", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 4, d, 1.0, 0.0);
            let ball = mk_ball(d, rng);
            let res = solve_merge(&ball, &dense_views(&xs), &ys, &TrainOptions::default());
            if res.ball.r + 1e-9 < ball.r {
                return Err(format!("radius shrank {} -> {}", ball.r, res.ball.r));
            }
            Ok(())
        });
    }

    #[test]
    fn merge_l1_close_to_closed_form() {
        // Algorithm 2 with L=1 should be near the closed-form Algorithm-1
        // update (BC approximates the same two-entity MEB).
        check_default("merge-l1-vs-algo1", |rng, _| {
            let d = gen::dim(rng);
            let (xs, ys) = gen::labeled_points(rng, 1, d, 1.0, 0.0);
            let ball = mk_ball(d, rng);
            let opts = TrainOptions { merge_iters: 512, ..TrainOptions::default() };
            let res = solve_merge(&ball, &dense_views(&xs), &ys, &opts);
            let mut closed = ball.clone();
            closed.try_update(&xs[0], ys[0], &opts);
            let rel = (res.ball.r - closed.r).abs() / closed.r.max(1e-9);
            if rel > 0.05 {
                return Err(format!("BC r {} vs closed-form {}", res.ball.r, closed.r));
            }
            Ok(())
        });
    }

    #[test]
    fn sparse_and_dense_merge_agree() {
        // The O(L²·nnz) sparse Gram + scatter-add reconstruction must
        // match the dense-view path on the same buffer.
        check_default("merge-sparse-dense", |rng, _| {
            let d = gen::dim(rng);
            let l = 1 + rng.below(10);
            let (xs, ys) = gen::labeled_points(rng, l, d, 1.5, 0.4);
            let ball = mk_ball(d, rng);
            let opts = TrainOptions::default().with_c(2.0);
            let sparse: Vec<crate::data::Features> =
                xs.iter().map(|x| crate::data::Features::Dense(x.clone()).to_sparse()).collect();
            let sviews: Vec<FeaturesView> = sparse.iter().map(|f| f.view()).collect();
            let rd = solve_merge(&ball, &dense_views(&xs), &ys, &opts);
            let rs = solve_merge(&ball, &sviews, &ys, &opts);
            if (rd.ball.r - rs.ball.r).abs() > 1e-9 * rd.ball.r.max(1.0) {
                return Err(format!("R diverged: {} vs {}", rd.ball.r, rs.ball.r));
            }
            if (rd.ball.xi2 - rs.ball.xi2).abs() > 1e-9 * rd.ball.xi2.max(1.0) {
                return Err(format!("xi2 diverged: {} vs {}", rd.ball.xi2, rs.ball.xi2));
            }
            let (wd, ws) = (rd.ball.weights(), rs.ball.weights());
            let scale = wd.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for (i, (a, b)) in wd.iter().zip(&ws).enumerate() {
                if (a - b).abs() > 1e-5 * scale {
                    return Err(format!("w[{i}] diverged: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ball_pole_step_keeps_mu_on_the_simplex() {
        // Regression (pre-fix the scaled μ went negative): with a large
        // C (tiny s²) two antipodal survivors barely outside a big ball
        // nearly cancel, so after two far-point steps ‖Vμ‖ ≈ √(2s²)/3
        // while r0 = 10 — the pole step scale `(1−η) − η·r0/‖Vμ‖` is
        // hugely negative (≈ −52) and the unclamped solver pushed μ to
        // ≈ −17 mid-run and ended at μ ≈ [−0.161, −0.168], off the
        // simplex — breaking the convex-coefficient invariant that
        // `verify_enclosure` and the ξ² bookkeeping assume.
        let opts = TrainOptions::default().with_c(100.0);
        let ball = BallState::from_parts(vec![0.0], 10.0, 0.0, 3);
        let xs = vec![vec![10.05f32], vec![10.05f32]];
        let ys = [1.0f32, -1.0];
        let res = solve_merge(&ball, &dense_views(&xs), &ys, &opts);
        let tot: f64 = res.mu.iter().sum();
        for (i, &m) in res.mu.iter().enumerate() {
            assert!(m >= 0.0, "mu[{i}] = {m} left the simplex");
        }
        assert!(tot <= 1.0 + 1e-12, "sum mu = {tot} > 1");
        assert!(res.ball.r + 1e-9 >= ball.r, "radius shrank");
        assert!(res.ball.xi2 >= 0.0 && res.ball.xi2.is_finite());
        assert!(res.ball.weights().iter().all(|w| w.is_finite()));
        // enclosure still holds with the clamped step
        let xrefs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        verify_enclosure(&ball, &xrefs, &ys, opts.s2(), &res, 1e-3 * res.ball.r.max(1.0))
            .unwrap();
    }

    #[test]
    fn points_meb_encloses_all() {
        check_default("points-meb-enclosure", |rng, _| {
            let d = gen::dim(rng);
            let n = 2 + rng.below(30);
            let (xs, ys) = gen::labeled_points(rng, n, d, 2.0, 0.3);
            let s2 = 0.5;
            let xrefs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let meb = solve_meb_points(&xrefs, &ys, s2, 256);
            let a2: f64 = meb.alpha.iter().map(|a| a * a).sum();
            for i in 0..n {
                let d2 = linalg::sqdist_scaled(&meb.w, &xs[i], ys[i])
                    + s2 * (a2 - 2.0 * meb.alpha[i] + 1.0);
                if d2.sqrt() > meb.r + 1e-6 {
                    return Err(format!("point {i} outside: {} > {}", d2.sqrt(), meb.r));
                }
            }
            // convexity of alpha
            let tot: f64 = meb.alpha.iter().sum();
            if (tot - 1.0).abs() > 1e-9 || meb.alpha.iter().any(|&a| a < -1e-12) {
                return Err(format!("alpha not convex: sum {tot}"));
            }
            Ok(())
        });
    }

    #[test]
    fn points_meb_two_points_midpoint() {
        // MEB of two antipodal points (slack off): center at midpoint.
        let xs: Vec<&[f32]> = vec![&[1.0, 0.0], &[-1.0, 0.0]];
        let ys = [1.0f32, 1.0];
        let meb = solve_meb_points(&xs, &ys, 0.0, 2048);
        assert!((meb.w[0]).abs() < 0.02, "w = {:?}", meb.w);
        assert!((meb.r - 1.0).abs() < 0.02, "r = {}", meb.r);
    }
}
