//! Kernel functions for the kernelized StreamSVM (paper §4.2).
//!
//! The MEB↔SVM duality requires `K(x, x) = κ` constant; linear kernels on
//! unnormalized inputs violate this mildly (the paper still uses them for
//! all experiments), RBF satisfies it exactly with κ = 1.
//!
//! The view entry points ([`Kernel::eval_view`], [`Kernel::self_eval_n2`])
//! compute `K(x, z)` from the norm expansion
//! `‖x − z‖² = ‖x‖² + ‖z‖² − 2⟨x, z⟩` with the squared norms supplied by
//! the caller — the kernelized learner caches `‖x‖²` per core-set point,
//! so every evaluation against a sparse example is a single O(nnz)
//! (or merge-join) dot instead of an O(D) densified pass.

use crate::data::FeaturesView;
use crate::linalg;

/// Supported kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    Linear,
    /// `exp(-gamma ||a - b||²)`; κ = 1.
    Rbf { gamma: f64 },
    /// `(<a, b> + coef)^degree`.
    Poly { degree: u32, coef: f64 },
}

impl Kernel {
    pub fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        match *self {
            Kernel::Linear => linalg::dot(a, b),
            Kernel::Rbf { gamma } => {
                let d2 = linalg::norm2(a) + linalg::norm2(b) - 2.0 * linalg::dot(a, b);
                (-gamma * d2.max(0.0)).exp()
            }
            Kernel::Poly { degree, coef } => (linalg::dot(a, b) + coef).powi(degree as i32),
        }
    }

    /// `K(x, x)` without the cross-term cancellation issues.
    pub fn self_eval(&self, a: &[f32]) -> f64 {
        match *self {
            Kernel::Linear => linalg::norm2(a),
            Kernel::Rbf { .. } => 1.0,
            Kernel::Poly { degree, coef } => (linalg::norm2(a) + coef).powi(degree as i32),
        }
    }

    /// `K(a, b)` for dense-or-sparse views with the squared norms `‖a‖²`,
    /// `‖b‖²` supplied (cached by the caller) — cost is one
    /// [`FeaturesView::dot_view`], i.e. O(nnz) against a sparse operand
    /// and a merge-join for two sparse operands.
    pub fn eval_view(&self, a: FeaturesView<'_>, an2: f64, b: FeaturesView<'_>, bn2: f64) -> f64 {
        match *self {
            Kernel::Linear => a.dot_view(&b),
            Kernel::Rbf { gamma } => {
                let d2 = an2 + bn2 - 2.0 * a.dot_view(&b);
                (-gamma * d2.max(0.0)).exp()
            }
            Kernel::Poly { degree, coef } => (a.dot_view(&b) + coef).powi(degree as i32),
        }
    }

    /// `K(x, x)` from the cached squared norm alone — O(1).
    pub fn self_eval_n2(&self, n2: f64) -> f64 {
        match *self {
            Kernel::Linear => n2,
            Kernel::Rbf { .. } => 1.0,
            Kernel::Poly { degree, coef } => (n2 + coef).powi(degree as i32),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_is_dot() {
        assert_eq!(Kernel::Linear.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(Kernel::Linear.self_eval(&[3.0, 4.0]), 25.0);
    }

    #[test]
    fn rbf_unit_diagonal_and_symmetry() {
        let k = Kernel::Rbf { gamma: 0.5 };
        assert!((k.eval(&[1.0, -1.0], &[1.0, -1.0]) - 1.0).abs() < 1e-12);
        assert_eq!(k.self_eval(&[9.0, 9.0]), 1.0);
        let a = [0.3f32, -1.2];
        let b = [2.0f32, 0.7];
        assert!((k.eval(&a, &b) - k.eval(&b, &a)).abs() < 1e-12);
        assert!(k.eval(&a, &b) < 1.0);
    }

    #[test]
    fn poly_matches_formula() {
        let k = Kernel::Poly { degree: 2, coef: 1.0 };
        // (<(1,1),(2,0)> + 1)^2 = 9
        assert_eq!(k.eval(&[1.0, 1.0], &[2.0, 0.0]), 9.0);
    }

    #[test]
    fn view_evals_match_dense_evals() {
        use crate::data::Features;
        let a = Features::sparse(6, vec![0, 3, 5], vec![1.0, -2.0, 0.5]);
        let b = Features::sparse(6, vec![1, 3, 4], vec![2.0, 3.0, 1.0]);
        let (ad, bd) = (a.dense().into_owned(), b.dense().into_owned());
        let (an2, bn2) = (a.view().norm2(), b.view().norm2());
        for k in [
            Kernel::Linear,
            Kernel::Rbf { gamma: 0.7 },
            Kernel::Poly { degree: 3, coef: 0.5 },
        ] {
            let want = k.eval(&ad, &bd);
            // all four representation pairs agree with the dense eval
            let got_ss = k.eval_view(a.view(), an2, b.view(), bn2);
            let got_sd = k.eval_view(a.view(), an2, FeaturesView::Dense(&bd), bn2);
            let got_ds = k.eval_view(FeaturesView::Dense(&ad), an2, b.view(), bn2);
            for got in [got_ss, got_sd, got_ds] {
                assert!((got - want).abs() <= 1e-12 * want.abs().max(1.0), "{got} vs {want}");
            }
            // cached-norm self-eval matches the slice self-eval
            assert!((k.self_eval_n2(an2) - k.self_eval(&ad)).abs() < 1e-12);
        }
    }
}
