//! Kernel functions for the kernelized StreamSVM (paper §4.2).
//!
//! The MEB↔SVM duality requires `K(x, x) = κ` constant; linear kernels on
//! unnormalized inputs violate this mildly (the paper still uses them for
//! all experiments), RBF satisfies it exactly with κ = 1.

use crate::linalg;

/// Supported kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    Linear,
    /// `exp(-gamma ||a - b||²)`; κ = 1.
    Rbf { gamma: f64 },
    /// `(<a, b> + coef)^degree`.
    Poly { degree: u32, coef: f64 },
}

impl Kernel {
    pub fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        match *self {
            Kernel::Linear => linalg::dot(a, b),
            Kernel::Rbf { gamma } => {
                let d2 = linalg::norm2(a) + linalg::norm2(b) - 2.0 * linalg::dot(a, b);
                (-gamma * d2.max(0.0)).exp()
            }
            Kernel::Poly { degree, coef } => (linalg::dot(a, b) + coef).powi(degree as i32),
        }
    }

    /// `K(x, x)` without the cross-term cancellation issues.
    pub fn self_eval(&self, a: &[f32]) -> f64 {
        match *self {
            Kernel::Linear => linalg::norm2(a),
            Kernel::Rbf { .. } => 1.0,
            Kernel::Poly { degree, coef } => (linalg::norm2(a) + coef).powi(degree as i32),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_is_dot() {
        assert_eq!(Kernel::Linear.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(Kernel::Linear.self_eval(&[3.0, 4.0]), 25.0);
    }

    #[test]
    fn rbf_unit_diagonal_and_symmetry() {
        let k = Kernel::Rbf { gamma: 0.5 };
        assert!((k.eval(&[1.0, -1.0], &[1.0, -1.0]) - 1.0).abs() < 1e-12);
        assert_eq!(k.self_eval(&[9.0, 9.0]), 1.0);
        let a = [0.3f32, -1.2];
        let b = [2.0f32, 0.7];
        assert!((k.eval(&a, &b) - k.eval(&b, &a)).abs() < 1e-12);
        assert!(k.eval(&a, &b) < 1.0);
    }

    #[test]
    fn poly_matches_formula() {
        let k = Kernel::Poly { degree: 2, coef: 1.0 };
        // (<(1,1),(2,0)> + 1)^2 = 9
        assert_eq!(k.eval(&[1.0, 1.0], &[2.0, 0.0]), 9.0);
    }
}
