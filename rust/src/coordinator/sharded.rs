//! Sharded one-pass training: S worker threads each consume a disjoint
//! sub-stream with Algorithm 1, and the final balls merge pairwise into
//! one model (closed-form two-ball MEB) — the natural distributed
//! extension of the streaming coordinator.
//!
//! Slack masses of distinct shards live on disjoint stream indices, so
//! the two-ball merge geometry of `svm::multiball` applies exactly. The
//! merged ball encloses every shard ball, hence (transitively) every
//! streamed point in the augmented space; the price is the same kind of
//! radius slack the lookahead analysis bounds.

use std::sync::mpsc::sync_channel;

use crate::data::Example;
use crate::error::{Error, Result};
use crate::svm::ball::BallState;
use crate::svm::multiball::merge_balls;
use crate::svm::streamsvm::StreamSvm;
use crate::svm::TrainOptions;

/// Report of a sharded run.
#[derive(Debug)]
pub struct ShardedReport {
    pub model: StreamSvm,
    /// Final per-shard balls (pre-merge), for diagnostics.
    pub shard_radii: Vec<f64>,
    pub examples: usize,
}

/// Train over `source` with `shards` parallel one-pass learners
/// (round-robin dispatch, bounded per-shard queues for backpressure).
pub fn train_sharded<I>(
    source: I,
    dim: usize,
    shards: usize,
    opts: TrainOptions,
    queue: usize,
) -> Result<ShardedReport>
where
    I: Iterator<Item = Example>,
{
    assert!(shards >= 1);
    let mut senders = Vec::with_capacity(shards);
    let mut workers = Vec::with_capacity(shards);
    for _ in 0..shards {
        let (tx, rx) = sync_channel::<Example>(queue.max(1));
        senders.push(tx);
        workers.push(std::thread::spawn(move || {
            let mut model: Option<StreamSvm> = None;
            for e in rx.iter() {
                let m = model.get_or_insert_with(|| StreamSvm::new(e.x.len(), opts));
                m.observe(&e.x, e.y);
            }
            model
        }));
    }
    let mut n = 0usize;
    for (i, e) in source.enumerate() {
        n += 1;
        senders[i % shards]
            .send(e)
            .map_err(|_| Error::Pipeline("shard worker hung up".into()))?;
    }
    drop(senders);
    let mut balls: Vec<BallState> = Vec::new();
    for w in workers {
        let model = w.join().map_err(|_| Error::Pipeline("shard worker panicked".into()))?;
        if let Some(m) = model {
            if let Some(b) = m.ball() {
                balls.push(b.clone());
            }
        }
    }
    if balls.is_empty() {
        return Err(Error::Pipeline("empty stream".into()));
    }
    let shard_radii: Vec<f64> = balls.iter().map(|b| b.r).collect();
    let merged = merge_balls(&balls).expect("non-empty");
    let mut model = StreamSvm::new(dim, opts);
    model.set_ball(merged, n);
    Ok(ShardedReport { model, shard_radii, examples: n })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::accuracy;
    use crate::prop::gen;
    use crate::rng::Pcg32;

    fn toy(n: usize, d: usize, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, 1.0);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    #[test]
    fn sharded_matches_single_shard_semantics() {
        let exs = toy(500, 6, 1);
        let opts = TrainOptions::default();
        let one = train_sharded(exs.clone().into_iter(), 6, 1, opts, 8).unwrap();
        let direct = StreamSvm::fit(exs.iter(), 6, &opts);
        assert_eq!(one.model.weights(), direct.weights());
        assert_eq!(one.examples, 500);
    }

    #[test]
    fn sharded_accuracy_close_to_single() {
        let exs = toy(4000, 8, 2);
        let opts = TrainOptions::default();
        let single = train_sharded(exs.clone().into_iter(), 8, 1, opts, 8).unwrap();
        let four = train_sharded(exs.clone().into_iter(), 8, 4, opts, 8).unwrap();
        let (a1, a4) = (accuracy(&single.model, &exs), accuracy(&four.model, &exs));
        assert_eq!(four.shard_radii.len(), 4);
        assert!(a4 > a1 - 0.08, "sharded {a4:.3} vs single {a1:.3}");
    }

    #[test]
    fn merged_radius_dominates_shards() {
        let exs = toy(1000, 4, 3);
        let rep = train_sharded(exs.into_iter(), 4, 3, TrainOptions::default(), 4).unwrap();
        let max_shard = rep.shard_radii.iter().cloned().fold(0.0f64, f64::max);
        assert!(rep.model.radius() + 1e-9 >= max_shard);
    }

    #[test]
    fn empty_stream_errors() {
        let err = train_sharded(std::iter::empty(), 3, 2, TrainOptions::default(), 2);
        assert!(err.is_err());
    }
}
