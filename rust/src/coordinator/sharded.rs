//! Sharded one-pass training: S worker threads each consume a disjoint
//! sub-stream, and the final balls merge through the sketch layer's
//! balanced merge-and-reduce tree ([`crate::sketch::merge`]) into one
//! model — the natural distributed extension of the streaming
//! coordinator.
//!
//! Workers train any learner variant through
//! [`crate::svm::learner::AnyLearner`]; aggregation goes through
//! [`crate::svm::learner::StreamLearner::summary_ball`], so every
//! variant with a primal summary ball shards (a non-linear kernelized
//! learner has none and is rejected as a configuration error). Slack
//! masses of distinct shards live on disjoint stream indices, so the
//! two-ball merge geometry of `svm::multiball` applies exactly at every
//! tree level. The merged ball encloses every shard ball, hence
//! (transitively) every streamed point in the augmented space; the price
//! is the same kind of radius slack the lookahead analysis bounds, and
//! the balanced tree keeps it order-robust (⌈log₂ S⌉ merges deep instead
//! of S−1).

use std::sync::mpsc::sync_channel;
use std::time::Instant;

use crate::coordinator::metrics::PipelineMetrics;
use crate::data::Example;
use crate::error::{Error, Result};
use crate::sketch::codec::MebSketch;
use crate::sketch::merge::{merge_ball_tree, merge_sketches};
use crate::svm::ball::BallState;
use crate::svm::learner::{AnyLearner, Variant};
use crate::svm::lookahead::LookaheadSvm;
use crate::svm::streamsvm::StreamSvm;
use crate::svm::TrainOptions;

/// Report of a sharded run.
#[derive(Debug)]
pub struct ShardedReport {
    pub model: AnyLearner,
    /// Final per-shard balls (pre-merge), for diagnostics.
    pub shard_radii: Vec<f64>,
    pub examples: usize,
    /// Aggregate over all shards ([`PipelineMetrics::merge`]): counters
    /// sum, wall time is the slowest shard, so `metrics.throughput()` is
    /// the aggregate rate. Shard workers run the sequential updater with
    /// no block filter, so `survivors == examples` and `filter_rate` is 0.
    pub metrics: PipelineMetrics,
}

impl ShardedReport {
    /// The merged model as a durable sketch (for `streamsvm train
    /// --shards N --out model.meb` and checkpoint hand-off).
    pub fn sketch(&self, tag: &str) -> MebSketch {
        MebSketch::from_learner(&self.model, tag)
    }
}

/// [`train_sharded_variant`] with the ball learner (Algorithm 1 per
/// shard) — the classic sharded configuration.
pub fn train_sharded<I>(
    source: I,
    dim: usize,
    shards: usize,
    opts: TrainOptions,
    queue: usize,
) -> Result<ShardedReport>
where
    I: Iterator<Item = Example>,
{
    train_sharded_variant(source, dim, shards, Variant::Ball, opts, queue)
}

/// Train over `source` with `shards` parallel one-pass learners of the
/// chosen `variant` (round-robin dispatch, bounded per-shard queues for
/// backpressure), then merge the shards' summary balls through the
/// balanced tree.
///
/// Every dispatched example is validated against the caller-supplied
/// `dim`; a mismatch aborts with [`Error::Config`] instead of silently
/// training shards on inconsistent dimensions. The merged model is the
/// variant's own type for ball and lookahead (the merge output *is* a
/// single ball); for the other variants the per-shard structure beyond
/// the summary ball is not mergeable, so the aggregate is reported as a
/// ball model over the merged geometry.
pub fn train_sharded_variant<I>(
    source: I,
    dim: usize,
    shards: usize,
    variant: Variant,
    opts: TrainOptions,
    queue: usize,
) -> Result<ShardedReport>
where
    I: Iterator<Item = Example>,
{
    assert!(shards >= 1);
    let opts = lookahead_defaulted(variant, opts);
    let mut senders = Vec::with_capacity(shards);
    let mut workers = Vec::with_capacity(shards);
    for _ in 0..shards {
        let (tx, rx) = sync_channel::<Example>(queue.max(1));
        senders.push(tx);
        workers.push(std::thread::spawn(move || {
            // Workers are told the stream dimension up front — they no
            // longer infer it from their first example.
            let mut model = AnyLearner::new(variant, dim, opts);
            let mut metrics = PipelineMetrics::default();
            let wall = Instant::now();
            for e in rx.iter() {
                metrics.examples += 1;
                metrics.survivors += 1; // sequential path: every row checked
                if model.observe_view(e.x.view(), e.y) {
                    metrics.updates += 1;
                }
            }
            model.finish();
            metrics.wall_ns = wall.elapsed().as_nanos() as u64;
            (model, metrics)
        }));
    }
    let mut n = 0usize;
    for (i, e) in source.enumerate() {
        if e.dim() != dim {
            drop(senders); // release workers before bailing out
            return Err(Error::config(format!(
                "shard dispatch: example {i} has dimension {} but the stream \
                 was declared as {dim}",
                e.dim()
            )));
        }
        n += 1;
        senders[i % shards]
            .send(e)
            .map_err(|_| Error::Pipeline("shard worker hung up".into()))?;
    }
    drop(senders);
    let mut models = Vec::with_capacity(shards);
    let mut agg = PipelineMetrics::default();
    for w in workers {
        let (model, m) =
            w.join().map_err(|_| Error::Pipeline("shard worker panicked".into()))?;
        agg.merge(&m);
        models.push(model);
    }
    let (model, shard_radii) = merge_worker_models(models, dim, variant, opts, n)?;
    Ok(ShardedReport { model, shard_radii, examples: n, metrics: agg })
}

/// Mirror `AnyLearner::new`'s lookahead depth default so worker options
/// and the merged lookahead model agree. Shared by the sharded and
/// parallel-ingest coordinators.
pub(crate) fn lookahead_defaulted(variant: Variant, opts: TrainOptions) -> TrainOptions {
    if variant == Variant::Lookahead && opts.lookahead <= 1 {
        opts.with_lookahead(8)
    } else {
        opts
    }
}

/// Fold finished worker models into one aggregate: collect each model's
/// summary ball (workers that saw zero examples are tolerated — the
/// stream may be shorter than the worker count), merge through the
/// balanced tree, and wrap the merged geometry in the variant's
/// aggregate type. Returns the model and the per-worker radii
/// (pre-merge, for diagnostics). Shared by the sharded and
/// parallel-ingest coordinators.
pub(crate) fn merge_worker_models(
    models: Vec<AnyLearner>,
    dim: usize,
    variant: Variant,
    opts: TrainOptions,
    n: usize,
) -> Result<(AnyLearner, Vec<f64>)> {
    let mut balls: Vec<BallState> = Vec::new();
    for model in &models {
        match model.summary_ball() {
            Some(b) => balls.push(b),
            None if model.examples_seen() == 0 => {} // idle worker
            None => {
                return Err(Error::config(format!(
                    "variant {variant} has no summary ball to shard-merge \
                     (non-linear kernels cannot be aggregated in primal space)"
                )))
            }
        }
    }
    if balls.is_empty() {
        return Err(Error::Pipeline("empty stream".into()));
    }
    let radii: Vec<f64> = balls.iter().map(|b| b.r).collect();
    let merged = merge_ball_tree(balls).expect("non-empty");
    let model = match variant {
        Variant::Lookahead => {
            AnyLearner::Lookahead(LookaheadSvm::from_ball(dim, opts, merged, n, 0))
        }
        _ => {
            let mut m = StreamSvm::new(dim, opts);
            m.set_ball(merged, n);
            AnyLearner::Ball(m)
        }
    };
    Ok((model, radii))
}

/// Merge independently-trained shard sketches into one model — the
/// cross-machine half of merge-and-reduce, where each shard arrives as a
/// `MebSketch` file rather than a live thread. Variant-generic through
/// [`merge_sketches`]' gates: mixed-variant inputs are rejected, and the
/// aggregate of summary balls is a ball model.
pub fn merge_shard_sketches(sketches: &[MebSketch]) -> Result<ShardedReport> {
    let shard_radii: Vec<f64> = sketches.iter().map(|s| s.radius()).collect();
    let merged = merge_sketches(sketches)?;
    let examples = merged.seen;
    // Offline merge: the shards' wall clocks are unknown, so only the
    // work counters recoverable from the sketches are populated.
    let metrics = PipelineMetrics {
        examples,
        survivors: examples,
        updates: sketches.iter().map(|s| s.num_support()).sum(),
        ..Default::default()
    };
    Ok(ShardedReport {
        model: AnyLearner::from(merged.to_model()),
        shard_radii,
        examples,
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::accuracy;
    use crate::prop::gen;
    use crate::rng::Pcg32;

    fn toy(n: usize, d: usize, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, 1.0);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    #[test]
    fn sharded_matches_single_shard_semantics() {
        let exs = toy(500, 6, 1);
        let opts = TrainOptions::default();
        let one = train_sharded(exs.clone().into_iter(), 6, 1, opts, 8).unwrap();
        let direct = StreamSvm::fit(exs.iter(), 6, &opts);
        assert_eq!(one.model.weights(), Some(direct.weights()));
        assert_eq!(one.examples, 500);
    }

    #[test]
    fn sharded_accuracy_close_to_single() {
        let exs = toy(4000, 8, 2);
        let opts = TrainOptions::default();
        let single = train_sharded(exs.clone().into_iter(), 8, 1, opts, 8).unwrap();
        let four = train_sharded(exs.clone().into_iter(), 8, 4, opts, 8).unwrap();
        let (a1, a4) = (accuracy(&single.model, &exs), accuracy(&four.model, &exs));
        assert_eq!(four.shard_radii.len(), 4);
        assert!(a4 > a1 - 0.08, "sharded {a4:.3} vs single {a1:.3}");
    }

    #[test]
    fn many_shards_through_the_tree_stay_in_tolerance() {
        // The merge tree must keep accuracy when S is large enough that
        // the old sequential fold would be S−1 merges deep.
        let exs = toy(6000, 8, 5);
        let opts = TrainOptions::default();
        let single = train_sharded(exs.clone().into_iter(), 8, 1, opts, 8).unwrap();
        let wide = train_sharded(exs.clone().into_iter(), 8, 16, opts, 8).unwrap();
        let (a1, aw) = (accuracy(&single.model, &exs), accuracy(&wide.model, &exs));
        assert_eq!(wide.shard_radii.len(), 16);
        assert!(aw > a1 - 0.08, "16-shard {aw:.3} vs single {a1:.3}");
    }

    #[test]
    fn merged_radius_dominates_shards() {
        let exs = toy(1000, 4, 3);
        let rep = train_sharded(exs.into_iter(), 4, 3, TrainOptions::default(), 4).unwrap();
        let max_shard = rep.shard_radii.iter().cloned().fold(0.0f64, f64::max);
        assert!(rep.model.radius() + 1e-9 >= max_shard);
    }

    #[test]
    fn sharded_metrics_aggregate_across_shards() {
        let exs = toy(1200, 6, 11);
        let rep = train_sharded(exs.into_iter(), 6, 4, TrainOptions::default(), 8).unwrap();
        // per-shard counters merged into one aggregate
        assert_eq!(rep.metrics.examples, 1200);
        assert_eq!(rep.metrics.survivors, 1200, "no block filter in shard workers");
        assert!(rep.metrics.updates >= 4, "each shard updates at least once");
        assert!(rep.metrics.wall_ns > 0);
        assert!(rep.metrics.throughput() > 0.0);
        assert!((rep.metrics.filter_rate()).abs() < 1e-12);
    }

    #[test]
    fn every_summarizable_variant_shards() {
        let exs = toy(1500, 6, 13);
        let opts = TrainOptions::default();
        let single = StreamSvm::fit(exs.iter(), 6, &opts);
        let a1 = accuracy(&single, &exs);
        for v in Variant::ALL {
            let rep =
                train_sharded_variant(exs.clone().into_iter(), 6, 3, v, opts, 8).unwrap();
            assert_eq!(rep.examples, 1500, "{v}");
            assert_eq!(rep.shard_radii.len(), 3, "{v}");
            // lookahead aggregates to a lookahead model; the rest report
            // the merged ball geometry
            let want = if v == Variant::Lookahead { v } else { Variant::Ball };
            assert_eq!(rep.model.variant(), want, "{v}");
            let a = accuracy(&rep.model, &exs);
            assert!(a > a1 - 0.15, "{v}: sharded {a:.3} vs single-ball {a1:.3}");
            // and the report sketches with its model's provenance
            assert_eq!(rep.sketch("t").variant, want, "{v}");
        }
    }

    #[test]
    fn nonlinear_kernel_sharding_rejected() {
        // A variant whose learner has no summary ball cannot shard-merge.
        // `AnyLearner::new` kernelized is linear (has a ball), so force
        // the issue through a one-shard run over an RBF learner's options
        // path: the gate lives on summary_ball(), exercised via a direct
        // worker-equivalent check.
        use crate::svm::kernelfn::Kernel;
        use crate::svm::learner::StreamLearner;
        let exs = toy(50, 4, 17);
        let mut rbf = AnyLearner::with_kernel(
            Variant::Kernelized,
            4,
            TrainOptions::default(),
            Kernel::Rbf { gamma: 0.5 },
        );
        for e in &exs {
            rbf.observe_view(e.x.view(), e.y);
        }
        assert!(rbf.examples_seen() > 0);
        assert!(StreamLearner::summary_ball(&rbf).is_none());
    }

    #[test]
    fn empty_stream_errors() {
        let err = train_sharded(std::iter::empty(), 3, 2, TrainOptions::default(), 2);
        assert!(err.is_err());
    }

    #[test]
    fn dimension_mismatch_rejected_at_dispatch() {
        let mut exs = toy(20, 4, 7);
        exs.insert(10, Example::new(vec![1.0, -1.0], 1.0)); // rogue dim-2 row
        let err = train_sharded(exs.into_iter(), 4, 3, TrainOptions::default(), 2).unwrap_err();
        let msg = err.to_string();
        assert!(matches!(err, Error::Config(_)), "{msg}");
        assert!(msg.contains("example 10") && msg.contains("dimension 2"), "{msg}");
    }

    #[test]
    fn shard_sketches_merge_like_live_shards() {
        let exs = toy(1200, 5, 9);
        let opts = TrainOptions::default();
        let sketches: Vec<MebSketch> = exs
            .chunks(400)
            .enumerate()
            .map(|(i, c)| {
                MebSketch::from_model(
                    &StreamSvm::fit(c.iter(), 5, &opts),
                    format!("shard{i}"),
                )
            })
            .collect();
        let rep = merge_shard_sketches(&sketches).unwrap();
        assert_eq!(rep.examples, 1200);
        assert_eq!(rep.shard_radii.len(), 3);
        // same tolerance sharded training gets vs the single pass
        let single = StreamSvm::fit(exs.iter(), 5, &opts);
        let (a, a1) = (accuracy(&rep.model, &exs), accuracy(&single, &exs));
        assert!(a > a1 - 0.08, "sketch-merged {a:.3} vs single {a1:.3}");
    }
}
