//! Pipeline and service metrics: counters, nanosecond timers, and a
//! log-bucketed latency histogram (p50/p90/p99 without storing samples).

use std::time::{Duration, Instant};

/// Training-pipeline counters.
#[derive(Clone, Debug, Default)]
pub struct PipelineMetrics {
    pub examples: usize,
    pub blocks: usize,
    /// Rows that escaped the block filter and needed sequential checks.
    pub survivors: usize,
    /// Actual ball updates (core-set growth).
    pub updates: usize,
    /// Lookahead merge solves.
    pub merges: usize,
    /// Time inside PJRT execute calls.
    pub xla_ns: u64,
    /// Time in the sequential Rust updater.
    pub rust_ns: u64,
    /// End-to-end wall time of the training loop.
    pub wall_ns: u64,
}

impl PipelineMetrics {
    pub fn throughput(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.examples as f64 / (self.wall_ns as f64 * 1e-9)
        }
    }

    /// Fraction of rows discarded by the block filter alone.
    pub fn filter_rate(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            1.0 - self.survivors as f64 / self.examples as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "examples={} blocks={} survivors={} updates={} merges={} \
             filter={:.1}% throughput={:.0}/s xla={:.1}ms rust={:.1}ms wall={:.1}ms",
            self.examples,
            self.blocks,
            self.survivors,
            self.updates,
            self.merges,
            self.filter_rate() * 100.0,
            self.throughput(),
            self.xla_ns as f64 * 1e-6,
            self.rust_ns as f64 * 1e-6,
            self.wall_ns as f64 * 1e-6,
        )
    }
}

/// Scope timer adding elapsed nanos to a counter on drop.
pub struct ScopeTimer<'a> {
    start: Instant,
    sink: &'a mut u64,
}

impl<'a> ScopeTimer<'a> {
    pub fn new(sink: &'a mut u64) -> Self {
        ScopeTimer { start: Instant::now(), sink }
    }
}

impl Drop for ScopeTimer<'_> {
    fn drop(&mut self) {
        *self.sink += self.start.elapsed().as_nanos() as u64;
    }
}

/// Log₂-bucketed latency histogram: buckets at [1µs·2ⁱ).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; 32],
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; 32], count: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos() as u64;
        let us = (ns / 1000).max(1);
        let idx = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }

    /// Upper edge of the bucket holding quantile `q` (0..1).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:?} p50≤{:?} p90≤{:?} p99≤{:?} max={:?}",
            self.count,
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.90),
            self.quantile(0.99),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_rate_and_throughput() {
        let m = PipelineMetrics {
            examples: 1000,
            survivors: 100,
            wall_ns: 1_000_000_000,
            ..Default::default()
        };
        assert!((m.filter_rate() - 0.9).abs() < 1e-12);
        assert!((m.throughput() - 1000.0).abs() < 1e-9);
        assert_eq!(PipelineMetrics::default().throughput(), 0.0);
    }

    #[test]
    fn scope_timer_accumulates() {
        let mut sink = 0u64;
        {
            let _t = ScopeTimer::new(&mut sink);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(sink >= 1_000_000, "sink = {sink}");
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= Duration::from_micros(256) && p50 <= Duration::from_micros(1024));
        assert!(h.mean() > Duration::from_micros(400));
        assert!(h.max() >= Duration::from_micros(1000));
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }
}
