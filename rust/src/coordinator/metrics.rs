//! Pipeline and service metrics: counters, nanosecond timers, and a
//! log-bucketed latency histogram (p50/p90/p99 without storing samples).

use std::time::{Duration, Instant};

/// Training-pipeline counters.
#[derive(Clone, Debug, Default)]
pub struct PipelineMetrics {
    pub examples: usize,
    pub blocks: usize,
    /// Rows that escaped the block filter and needed sequential checks.
    pub survivors: usize,
    /// Actual ball updates (core-set growth).
    pub updates: usize,
    /// Lookahead merge solves.
    pub merges: usize,
    /// Time inside PJRT execute calls.
    pub xla_ns: u64,
    /// Time in the sequential Rust updater.
    pub rust_ns: u64,
    /// End-to-end wall time of the training loop.
    pub wall_ns: u64,
}

impl PipelineMetrics {
    pub fn throughput(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.examples as f64 / (self.wall_ns as f64 * 1e-9)
        }
    }

    /// Fraction of rows discarded by the block filter alone.
    pub fn filter_rate(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            1.0 - self.survivors as f64 / self.examples as f64
        }
    }

    /// Fold another run's counters into this one, so sharded training can
    /// report one aggregate instead of per-shard metrics only.
    ///
    /// Work counters (`examples`, `updates`, time inside the engines, ...)
    /// add; `wall_ns` takes the maximum because shards run concurrently —
    /// the aggregate wall clock is the slowest shard, which makes
    /// [`Self::throughput`] report the true aggregate rate.
    pub fn merge(&mut self, other: &PipelineMetrics) {
        self.examples += other.examples;
        self.blocks += other.blocks;
        self.survivors += other.survivors;
        self.updates += other.updates;
        self.merges += other.merges;
        self.xla_ns += other.xla_ns;
        self.rust_ns += other.rust_ns;
        self.wall_ns = self.wall_ns.max(other.wall_ns);
    }

    pub fn summary(&self) -> String {
        format!(
            "examples={} blocks={} survivors={} updates={} merges={} \
             filter={:.1}% throughput={:.0}/s xla={:.1}ms rust={:.1}ms wall={:.1}ms",
            self.examples,
            self.blocks,
            self.survivors,
            self.updates,
            self.merges,
            self.filter_rate() * 100.0,
            self.throughput(),
            self.xla_ns as f64 * 1e-6,
            self.rust_ns as f64 * 1e-6,
            self.wall_ns as f64 * 1e-6,
        )
    }
}

/// Scope timer adding elapsed nanos to a counter on drop.
pub struct ScopeTimer<'a> {
    start: Instant,
    sink: &'a mut u64,
}

impl<'a> ScopeTimer<'a> {
    pub fn new(sink: &'a mut u64) -> Self {
        ScopeTimer { start: Instant::now(), sink }
    }
}

impl Drop for ScopeTimer<'_> {
    fn drop(&mut self) {
        *self.sink += self.start.elapsed().as_nanos() as u64;
    }
}

/// Log₂-bucketed latency histogram: buckets at [1µs·2ⁱ).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; 32],
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; 32], count: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos() as u64;
        let us = (ns / 1000).max(1);
        let idx = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }

    /// Upper edge of the bucket holding quantile `q` (0..1).
    ///
    /// Edge behavior (pinned by tests, relied on by `/metrics`):
    /// * **empty histogram** → `Duration::ZERO` for every `q` — never a
    ///   misleading max.
    /// * **`q = 0.0`** → the upper edge of the *first non-empty* bucket
    ///   (the minimum recorded latency's bucket). The rank target is
    ///   clamped to `[1, count]`, so `q ≤ 0` can't fall through to the
    ///   max and `q ≥ 1` reports the last non-empty bucket.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }

    /// Raw per-bucket counts; bucket `i` holds samples in
    /// `[2^i µs, 2^(i+1) µs)`. Exposed for Prometheus histogram
    /// rendering (`GET /metrics`).
    pub fn bucket_counts(&self) -> &[u64; 32] {
        &self.buckets
    }

    /// Total recorded nanoseconds (the Prometheus `_sum`).
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    /// Upper edge of bucket `i`, in microseconds.
    pub const fn bucket_edge_us(i: usize) -> u64 {
        1u64 << (i + 1)
    }

    /// Fold another histogram into this one (bucket-wise). Used to
    /// aggregate per-thread histograms (server handler threads, loadgen
    /// worker threads) into one distribution for quantile reporting.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:?} p50≤{:?} p90≤{:?} p99≤{:?} max={:?}",
            self.count,
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.90),
            self.quantile(0.99),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_rate_and_throughput() {
        let m = PipelineMetrics {
            examples: 1000,
            survivors: 100,
            wall_ns: 1_000_000_000,
            ..Default::default()
        };
        assert!((m.filter_rate() - 0.9).abs() < 1e-12);
        assert!((m.throughput() - 1000.0).abs() < 1e-9);
        assert_eq!(PipelineMetrics::default().throughput(), 0.0);
    }

    #[test]
    fn scope_timer_accumulates() {
        let mut sink = 0u64;
        {
            let _t = ScopeTimer::new(&mut sink);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(sink >= 1_000_000, "sink = {sink}");
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= Duration::from_micros(256) && p50 <= Duration::from_micros(1024));
        assert!(h.mean() > Duration::from_micros(400));
        assert!(h.max() >= Duration::from_micros(1000));
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::default();
        // pinned: an empty histogram is ZERO at every quantile, never a
        // misleading max
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), Duration::ZERO, "q={q}");
        }
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn quantile_zero_reports_the_min_bucket() {
        // One slow outlier plus a cluster of fast samples: q=0 must land
        // in the fast cluster's bucket, not bucket 0 and not the max.
        let mut h = LatencyHistogram::default();
        for _ in 0..100 {
            h.record(Duration::from_micros(300)); // bucket [256µs, 512µs)
        }
        h.record(Duration::from_millis(80)); // bucket [65ms, 131ms)
        assert_eq!(h.quantile(0.0), Duration::from_micros(512));
        assert_eq!(h.quantile(1.0), Duration::from_micros(131_072));
        // out-of-range q clamps rather than falling off either end
        assert_eq!(h.quantile(-0.5), h.quantile(0.0));
        assert_eq!(h.quantile(2.0), h.quantile(1.0));
    }

    #[test]
    fn pipeline_metrics_merge_aggregates_shards() {
        let mut a = PipelineMetrics {
            examples: 1000,
            blocks: 10,
            survivors: 100,
            updates: 40,
            merges: 4,
            xla_ns: 5_000,
            rust_ns: 7_000,
            wall_ns: 2_000_000_000,
        };
        let b = PipelineMetrics {
            examples: 3000,
            blocks: 30,
            survivors: 300,
            updates: 60,
            merges: 6,
            xla_ns: 1_000,
            rust_ns: 3_000,
            wall_ns: 1_000_000_000,
        };
        a.merge(&b);
        assert_eq!(a.examples, 4000);
        assert_eq!(a.blocks, 40);
        assert_eq!(a.survivors, 400);
        assert_eq!(a.updates, 100);
        assert_eq!(a.merges, 10);
        assert_eq!(a.xla_ns, 6_000);
        assert_eq!(a.rust_ns, 10_000);
        // concurrent shards: wall is the slowest shard, so throughput is
        // the aggregate rate (4000 examples / 2 s)
        assert_eq!(a.wall_ns, 2_000_000_000);
        assert!((a.throughput() - 2000.0).abs() < 1e-9);
        // merging into a default is identity
        let mut z = PipelineMetrics::default();
        z.merge(&b);
        assert_eq!(z.examples, b.examples);
        assert_eq!(z.wall_ns, b.wall_ns);
    }

    #[test]
    fn histogram_quantiles_are_ordered_property() {
        crate::prop::check_default("hist-quantile-order", |rng, _| {
            let mut h = LatencyHistogram::default();
            let n = 1 + rng.below(500);
            for _ in 0..n {
                // span the bucket range: 1µs .. ~100ms
                let us = 1 + rng.below(100_000);
                h.record(Duration::from_micros(us as u64));
            }
            let (p50, p90, p99) = (h.quantile(0.50), h.quantile(0.90), h.quantile(0.99));
            if !(p50 <= p90 && p90 <= p99) {
                return Err(format!("quantiles out of order: {p50:?} {p90:?} {p99:?}"));
            }
            if h.count() != n as u64 {
                return Err(format!("count {} != {n}", h.count()));
            }
            Ok(())
        });
    }

    #[test]
    fn known_distribution_lands_in_the_right_log_bucket() {
        crate::prop::check_default("hist-bucket-placement", |rng, _| {
            // All samples inside one log₂ bucket [2^i µs, 2^(i+1) µs):
            // every quantile must report exactly that bucket's upper edge.
            let i = 1 + rng.below(20) as u32;
            let lo = 1u64 << i;
            let mut h = LatencyHistogram::default();
            for _ in 0..200 {
                let us = lo + rng.below(lo as usize) as u64; // [2^i, 2^(i+1))
                h.record(Duration::from_micros(us));
            }
            let edge = Duration::from_micros(1u64 << (i + 1));
            for q in [0.01, 0.5, 0.9, 0.99] {
                let got = h.quantile(q);
                if got != edge {
                    return Err(format!("q={q}: got {got:?}, want bucket edge {edge:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn merge_then_quantile_equals_single_histogram_property() {
        crate::prop::check_default("hist-merge-quantile", |rng, _| {
            // Scatter random samples across k shard histograms; merging
            // the shards must reproduce the single-histogram quantiles
            // exactly (including the q=0 / q=1 edges).
            let k = 2 + rng.below(6);
            let mut shards: Vec<LatencyHistogram> =
                (0..k).map(|_| LatencyHistogram::default()).collect();
            let mut all = LatencyHistogram::default();
            let n = 1 + rng.below(400);
            for _ in 0..n {
                let us = 1 + rng.below(1_000_000) as u64;
                let d = Duration::from_micros(us);
                all.record(d);
                let s = rng.below(k);
                shards[s].record(d);
            }
            let mut merged = LatencyHistogram::default();
            for s in &shards {
                merged.merge(s);
            }
            if merged.count() != all.count() {
                return Err(format!("count {} != {}", merged.count(), all.count()));
            }
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let (m, a) = (merged.quantile(q), all.quantile(q));
                if m != a {
                    return Err(format!("q={q}: merged {m:?} != single {a:?}"));
                }
            }
            if merged.mean() != all.mean() || merged.max() != all.max() {
                return Err("mean/max diverged after merge".into());
            }
            Ok(())
        });
    }

    #[test]
    fn histogram_merge_matches_single_recording() {
        let mut all = LatencyHistogram::default();
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        for i in 1..=1000u64 {
            let d = Duration::from_micros(i * 3);
            all.record(d);
            if i % 2 == 0 {
                a.record(d);
            } else {
                b.record(d);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.mean(), all.mean());
        assert_eq!(a.max(), all.max());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), all.quantile(q), "q={q}");
        }
    }
}
