//! Shape-bucketed batching: turns an example stream into padded blocks
//! matching the AOT artifact buckets, with a bounded-channel reader
//! thread for backpressure.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::data::Example;

/// One padded block, laid out exactly as the AOT entry points expect:
/// row-major `(b, d_pad)` features, `y`/`valid` of length `b`. Padding
/// rows have `valid = 0` and zero features; padding columns are zero.
#[derive(Clone, Debug)]
pub struct Block {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub valid: Vec<f32>,
    /// Real rows in this block (≤ b; the final block may be partial).
    pub n_real: usize,
    pub b: usize,
    pub d_pad: usize,
    /// Logical feature dimension (≤ d_pad).
    pub d: usize,
}

impl Block {
    /// Row `i`'s logical features (un-padded view).
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d_pad..i * self.d_pad + self.d]
    }
}

/// Assemble blocks of `b` rows padded to `d_pad` columns.
pub struct Batcher<I: Iterator<Item = Example>> {
    source: I,
    b: usize,
    d: usize,
    d_pad: usize,
    done: bool,
}

impl<I: Iterator<Item = Example>> Batcher<I> {
    pub fn new(source: I, b: usize, d: usize, d_pad: usize) -> Self {
        assert!(d_pad >= d && b > 0);
        Batcher { source, b, d, d_pad, done: false }
    }
}

impl<I: Iterator<Item = Example>> Iterator for Batcher<I> {
    type Item = Block;

    fn next(&mut self) -> Option<Block> {
        if self.done {
            return None;
        }
        let mut block = Block {
            x: vec![0.0; self.b * self.d_pad],
            y: vec![0.0; self.b],
            valid: vec![0.0; self.b],
            n_real: 0,
            b: self.b,
            d_pad: self.d_pad,
            d: self.d,
        };
        for i in 0..self.b {
            match self.source.next() {
                Some(e) => {
                    debug_assert_eq!(e.x.len(), self.d);
                    e.x.view()
                        .write_into(&mut block.x[i * self.d_pad..i * self.d_pad + self.d]);
                    block.y[i] = e.y;
                    block.valid[i] = 1.0;
                    block.n_real += 1;
                }
                None => {
                    self.done = true;
                    break;
                }
            }
        }
        if block.n_real == 0 {
            None
        } else {
            Some(block)
        }
    }
}

/// Run the batcher on a reader thread, returning a bounded receiver —
/// the backpressure boundary: at most `queue` blocks are in flight, so a
/// slow trainer throttles a fast source instead of buffering the stream
/// (the streaming model's storage constraint).
pub fn spawn_reader<I>(
    source: I,
    b: usize,
    d: usize,
    d_pad: usize,
    queue: usize,
) -> (Receiver<Block>, JoinHandle<usize>)
where
    I: Iterator<Item = Example> + Send + 'static,
{
    let (tx, rx) = sync_channel(queue.max(1));
    let handle = std::thread::spawn(move || {
        let mut sent = 0usize;
        for block in Batcher::new(source, b, d, d_pad) {
            sent += block.n_real;
            if tx.send(block).is_err() {
                break; // trainer hung up (early stop)
            }
        }
        sent
    });
    (rx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check_default;

    fn exs(n: usize, d: usize) -> Vec<Example> {
        (0..n)
            .map(|i| {
                Example::new(
                    (0..d).map(|j| (i * d + j) as f32).collect::<Vec<f32>>(),
                    if i % 2 == 0 { 1.0 } else { -1.0 },
                )
            })
            .collect()
    }

    #[test]
    fn blocks_cover_stream_exactly() {
        let blocks: Vec<Block> = Batcher::new(exs(10, 3).into_iter(), 4, 3, 5).collect();
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks.iter().map(|b| b.n_real).sum::<usize>(), 10);
        assert_eq!(blocks[2].n_real, 2);
        // padding rows are invalid and zeroed
        assert_eq!(blocks[2].valid[2..], [0.0, 0.0]);
        assert!(blocks[2].x[2 * 5..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn column_padding_zeroed_row_content_preserved() {
        let blocks: Vec<Block> = Batcher::new(exs(2, 3).into_iter(), 2, 3, 8).collect();
        let b = &blocks[0];
        assert_eq!(b.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(b.row(1), &[3.0, 4.0, 5.0]);
        assert!(b.x[3..8].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn empty_stream_yields_nothing() {
        let blocks: Vec<Block> = Batcher::new(exs(0, 2).into_iter(), 4, 2, 2).collect();
        assert!(blocks.is_empty());
    }

    #[test]
    fn batcher_never_drops_or_duplicates_property() {
        check_default("batcher-conservation", |rng, _| {
            let n = rng.below(200);
            let d = 1 + rng.below(8);
            let b = 1 + rng.below(16);
            let src = exs(n, d);
            let blocks: Vec<Block> = Batcher::new(src.clone().into_iter(), b, d, d + rng.below(4)).collect();
            let mut recon = Vec::new();
            for blk in &blocks {
                for i in 0..blk.n_real {
                    recon.push((blk.row(i).to_vec(), blk.y[i]));
                }
                // trailing rows must be invalid
                for i in blk.n_real..blk.b {
                    if blk.valid[i] != 0.0 {
                        return Err("padding row marked valid".into());
                    }
                }
            }
            if recon.len() != n {
                return Err(format!("{} rows reconstructed of {n}", recon.len()));
            }
            for (e, (x, y)) in src.iter().zip(&recon) {
                if e.x.dense().as_ref() != x.as_slice() || e.y != *y {
                    return Err("row mismatch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn reader_thread_backpressure_and_total() {
        let (rx, handle) = spawn_reader(exs(100, 2).into_iter(), 8, 2, 2, 2);
        std::thread::sleep(std::time::Duration::from_millis(20));
        // with queue=2 the reader can be at most ~3 blocks ahead
        let mut total = 0;
        for blk in rx.iter() {
            total += blk.n_real;
        }
        assert_eq!(total, 100);
        assert_eq!(handle.join().unwrap(), 100);
    }

    #[test]
    fn reader_handles_early_hangup() {
        let (rx, handle) = spawn_reader(exs(1000, 2).into_iter(), 8, 2, 2, 1);
        let first = rx.recv().unwrap();
        assert_eq!(first.n_real, 8);
        drop(rx); // trainer aborts
        let sent = handle.join().unwrap();
        assert!(sent < 1000, "reader should stop early, sent {sent}");
    }
}
