//! Shape-bucketed batching: turns an example stream into blocks of up
//! to `b` rows, with a bounded-channel reader thread for backpressure.
//!
//! Rows keep their arriving representation — sparse rows stay sparse —
//! so the pure-Rust pipeline modes run O(nnz) end-to-end. The dense
//! padded `(b, d_pad)` layout the AOT PJRT entry points expect is
//! materialized on demand via [`Block::pad`], so only the device paths
//! pay for padding (the old block dense-padded every row up front,
//! taxing every mode with the device layout).

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::data::{Example, Features, FeaturesView};

/// One block of up to `b` rows, un-padded.
#[derive(Clone, Debug)]
pub struct Block {
    /// Rows in arrival order, in their arriving representation.
    pub xs: Vec<Features>,
    pub y: Vec<f32>,
    /// Logical feature dimension.
    pub d: usize,
}

impl Block {
    /// Real rows in this block (the final block may be partial).
    pub fn n_real(&self) -> usize {
        self.xs.len()
    }

    /// Row `i`'s features (O(1); sparse rows stay sparse).
    pub fn row(&self, i: usize) -> FeaturesView<'_> {
        self.xs[i].view()
    }

    /// Materialize the dense padded layout the AOT entry points expect:
    /// row-major `(b, d_pad)` features, `y`/`valid` of length `b`.
    /// Padding rows have `valid = 0` and zero features; padding columns
    /// are zero.
    pub fn pad(&self, b: usize, d_pad: usize) -> PaddedBlock {
        assert!(b >= self.xs.len() && d_pad >= self.d, "pad target smaller than block");
        let mut p = PaddedBlock {
            x: vec![0.0; b * d_pad],
            y: vec![0.0; b],
            valid: vec![0.0; b],
            b,
            d_pad,
        };
        for (i, row) in self.xs.iter().enumerate() {
            row.view().write_into(&mut p.x[i * d_pad..i * d_pad + self.d]);
            p.y[i] = self.y[i];
            p.valid[i] = 1.0;
        }
        p
    }
}

/// The dense padded device layout (see [`Block::pad`]).
#[derive(Clone, Debug)]
pub struct PaddedBlock {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub valid: Vec<f32>,
    pub b: usize,
    pub d_pad: usize,
}

/// Assemble blocks of up to `b` rows.
pub struct Batcher<I: Iterator<Item = Example>> {
    source: I,
    b: usize,
    d: usize,
    done: bool,
}

impl<I: Iterator<Item = Example>> Batcher<I> {
    pub fn new(source: I, b: usize, d: usize) -> Self {
        assert!(b > 0);
        Batcher { source, b, d, done: false }
    }
}

impl<I: Iterator<Item = Example>> Iterator for Batcher<I> {
    type Item = Block;

    fn next(&mut self) -> Option<Block> {
        if self.done {
            return None;
        }
        let mut xs = Vec::with_capacity(self.b);
        let mut y = Vec::with_capacity(self.b);
        while xs.len() < self.b {
            match self.source.next() {
                Some(e) => {
                    debug_assert_eq!(e.x.len(), self.d);
                    xs.push(e.x);
                    y.push(e.y);
                }
                None => {
                    self.done = true;
                    break;
                }
            }
        }
        if xs.is_empty() {
            None
        } else {
            Some(Block { xs, y, d: self.d })
        }
    }
}

/// Run the batcher on a reader thread, returning a bounded receiver —
/// the backpressure boundary: at most `queue` blocks are in flight, so a
/// slow trainer throttles a fast source instead of buffering the stream
/// (the streaming model's storage constraint).
pub fn spawn_reader<I>(
    source: I,
    b: usize,
    d: usize,
    queue: usize,
) -> (Receiver<Block>, JoinHandle<usize>)
where
    I: Iterator<Item = Example> + Send + 'static,
{
    let (tx, rx) = sync_channel(queue.max(1));
    let handle = std::thread::spawn(move || {
        let mut sent = 0usize;
        for block in Batcher::new(source, b, d) {
            sent += block.n_real();
            if tx.send(block).is_err() {
                break; // trainer hung up (early stop)
            }
        }
        sent
    });
    (rx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check_default;

    fn exs(n: usize, d: usize) -> Vec<Example> {
        (0..n)
            .map(|i| {
                Example::new(
                    (0..d).map(|j| (i * d + j) as f32).collect::<Vec<f32>>(),
                    if i % 2 == 0 { 1.0 } else { -1.0 },
                )
            })
            .collect()
    }

    #[test]
    fn blocks_cover_stream_exactly() {
        let blocks: Vec<Block> = Batcher::new(exs(10, 3).into_iter(), 4, 3).collect();
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks.iter().map(|b| b.n_real()).sum::<usize>(), 10);
        assert_eq!(blocks[2].n_real(), 2);
        // padding appears only in the on-demand device layout
        let p = blocks[2].pad(4, 5);
        assert_eq!(p.valid, [1.0, 1.0, 0.0, 0.0]);
        assert!(p.x[2 * 5..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn column_padding_zeroed_row_content_preserved() {
        let blocks: Vec<Block> = Batcher::new(exs(2, 3).into_iter(), 2, 3).collect();
        let b = &blocks[0];
        assert_eq!(b.xs[0].dense().as_ref(), &[0.0, 1.0, 2.0]);
        assert_eq!(b.xs[1].dense().as_ref(), &[3.0, 4.0, 5.0]);
        let p = b.pad(2, 8);
        assert_eq!(&p.x[0..3], &[0.0, 1.0, 2.0]);
        assert!(p.x[3..8].iter().all(|&v| v == 0.0));
        assert_eq!(&p.x[8..11], &[3.0, 4.0, 5.0]);
        assert!(p.x[11..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sparse_rows_keep_their_representation() {
        let rows = vec![
            Example::new(Features::sparse(6, vec![1, 4], vec![2.0, -1.0]), 1.0),
            Example::new(vec![1.0; 6], -1.0),
        ];
        let blocks: Vec<Block> = Batcher::new(rows.into_iter(), 4, 6).collect();
        let b = &blocks[0];
        assert_eq!(b.n_real(), 2);
        // the sparse row was not densified by batching
        assert!(matches!(b.row(0), FeaturesView::Sparse { .. }));
        assert_eq!(b.xs[0].nnz(), 2);
        // ... but the device layout densifies it correctly
        let p = b.pad(4, 8);
        assert_eq!(&p.x[0..6], &[0.0, 2.0, 0.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn empty_stream_yields_nothing() {
        let blocks: Vec<Block> = Batcher::new(exs(0, 2).into_iter(), 4, 2).collect();
        assert!(blocks.is_empty());
    }

    #[test]
    fn batcher_never_drops_or_duplicates_property() {
        check_default("batcher-conservation", |rng, _| {
            let n = rng.below(200);
            let d = 1 + rng.below(8);
            let b = 1 + rng.below(16);
            let src = exs(n, d);
            let blocks: Vec<Block> = Batcher::new(src.clone().into_iter(), b, d).collect();
            let mut recon = Vec::new();
            for blk in &blocks {
                for i in 0..blk.n_real() {
                    recon.push((blk.xs[i].clone(), blk.y[i]));
                }
            }
            if recon.len() != n {
                return Err(format!("{} rows reconstructed of {n}", recon.len()));
            }
            for (e, (x, y)) in src.iter().zip(&recon) {
                if e.x != *x || e.y != *y {
                    return Err("row mismatch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn reader_thread_backpressure_and_total() {
        let (rx, handle) = spawn_reader(exs(100, 2).into_iter(), 8, 2, 2);
        std::thread::sleep(std::time::Duration::from_millis(20));
        // with queue=2 the reader can be at most ~3 blocks ahead
        let mut total = 0;
        for blk in rx.iter() {
            total += blk.n_real();
        }
        assert_eq!(total, 100);
        assert_eq!(handle.join().unwrap(), 100);
    }

    #[test]
    fn reader_handles_early_hangup() {
        let (rx, handle) = spawn_reader(exs(1000, 2).into_iter(), 8, 2, 1);
        let first = rx.recv().unwrap();
        assert_eq!(first.n_real(), 8);
        drop(rx); // trainer aborts
        let sent = handle.join().unwrap();
        assert!(sent < 1000, "reader should stop early, sent {sent}");
    }
}
