//! Batched prediction service: the post-training serving path.
//!
//! Requests arrive on a channel from any number of client threads; the
//! service loop drains up to one artifact block per iteration (dynamic
//! batching with a fill timeout), scores the batch with a single PJRT
//! `predict` call (the L1 Pallas matvec kernel), and replies through
//! per-request channels. Latency is tracked per request admission →
//! reply in a log-bucketed histogram.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::LatencyHistogram;
use crate::error::{Error, Result};
use crate::linalg;
use crate::runtime::{pad_dim, Runtime};
use crate::sketch::codec::MebSketch;
use crate::svm::streamsvm::StreamSvm;

/// One scoring request.
pub struct Request {
    pub x: Vec<f32>,
    pub reply: Sender<Reply>,
    admitted: Instant,
}

/// Scoring response: raw margin (sign = predicted label).
#[derive(Clone, Copy, Debug)]
pub struct Reply {
    pub score: f32,
}

/// Client handle for submitting requests.
#[derive(Clone)]
pub struct ServiceClient {
    tx: Sender<Request>,
    /// Set by the service loop's drop guard the moment [`PredictService::run`]
    /// returns — normally *or by panic* — so a waiting client can tell a
    /// dead loop from a slow one.
    stopped: Arc<AtomicBool>,
}

impl ServiceClient {
    /// Submit and wait for the score.
    ///
    /// Never blocks forever: if the service loop thread exits (including a
    /// panic mid-batch, which may strand this request without dropping its
    /// reply channel), the call returns [`Error::Pipeline`] instead of
    /// hanging on `recv()`.
    pub fn score(&self, x: Vec<f32>) -> Result<f32> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request { x, reply: reply_tx, admitted: Instant::now() })
            .map_err(|_| Error::Pipeline("service stopped".into()))?;
        loop {
            match reply_rx.recv_timeout(Duration::from_millis(20)) {
                Ok(r) => return Ok(r.score),
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Pipeline("service dropped request".into()))
                }
                Err(RecvTimeoutError::Timeout) => {
                    if self.stopped.load(Ordering::Acquire) {
                        // The loop is gone. Drain a reply that may have
                        // raced the flag before giving up.
                        return match reply_rx.try_recv() {
                            Ok(r) => Ok(r.score),
                            Err(_) => Err(Error::Pipeline(
                                "service loop terminated before replying \
                                 (panicked mid-batch?)"
                                    .into(),
                            )),
                        };
                    }
                }
            }
        }
    }
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Max rows per PJRT call (must match a compiled predict bucket).
    pub batch: usize,
    /// How long to wait to fill a batch before flushing a partial one.
    pub fill_timeout: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { batch: 64, fill_timeout: Duration::from_micros(200) }
    }
}

/// Serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub batches: u64,
    /// Live model snapshots written while serving.
    pub snapshots: u64,
    pub latency: LatencyHistogram,
}

impl ServiceStats {
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// The service: owns the model weights and the PJRT runtime reference.
pub struct PredictService {
    w: Vec<f32>,
    dim: usize,
    d_pad: usize,
    cfg: ServiceConfig,
    rx: Receiver<Request>,
    tx: Sender<Request>,
    stats: ServiceStats,
    /// Full model sketch for live snapshots (None when constructed from
    /// bare weights).
    sketch: Option<MebSketch>,
    /// `(path, every_batches)` — persist the sketch to `path`, checked
    /// every N batches while serving.
    snapshot: Option<(PathBuf, u64)>,
    /// Has the (immutable) sketch been written this run? Serving never
    /// mutates the model, so after the first successful write the hook
    /// only re-writes if the file disappears out from under it.
    snapshot_fresh: bool,
    /// Shared with every [`ServiceClient`]; flipped when `run` exits.
    stopped: Arc<AtomicBool>,
}

impl PredictService {
    pub fn new(w: Vec<f32>, cfg: ServiceConfig) -> Self {
        let dim = w.len();
        let d_pad = pad_dim(dim);
        let mut w_pad = w;
        w_pad.resize(d_pad, 0.0);
        let (tx, rx) = channel();
        PredictService {
            w: w_pad,
            dim,
            d_pad,
            cfg,
            rx,
            tx,
            stats: ServiceStats::default(),
            sketch: None,
            snapshot: None,
            snapshot_fresh: false,
            stopped: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Build a service around a trained model, retaining its full sketch
    /// (ball + provenance) so live snapshots capture the whole state,
    /// not just the serving weights.
    pub fn from_model(model: &StreamSvm, tag: &str, cfg: ServiceConfig) -> Self {
        let mut svc = Self::new(model.weights().to_vec(), cfg);
        svc.sketch = Some(MebSketch::from_model(model, tag));
        svc
    }

    /// Live-snapshot hook: while serving, persist the model sketch to
    /// `path` (atomic tmp+rename) without ever blocking a reply on a
    /// failure. The serving model is immutable, so the sketch is
    /// written once on the first eligible batch; every `every_batches`
    /// batches thereafter the hook re-checks the file and rewrites it
    /// only if it vanished (rotated away, volume wiped). Requires
    /// [`Self::from_model`]; failures are reported on stderr and never
    /// interrupt serving.
    pub fn snapshot_to(mut self, path: PathBuf, every_batches: u64) -> Self {
        self.snapshot = Some((path, every_batches.max(1)));
        self
    }

    /// The retained model sketch, if constructed with [`Self::from_model`].
    pub fn sketch(&self) -> Option<&MebSketch> {
        self.sketch.as_ref()
    }

    pub fn client(&self) -> ServiceClient {
        ServiceClient { tx: self.tx.clone(), stopped: self.stopped.clone() }
    }

    /// Run until all clients hang up. `runtime = None` falls back to the
    /// pure-Rust matvec (used for the ablation and artifact-less runs).
    pub fn run(mut self, mut runtime: Option<&mut Runtime>) -> Result<ServiceStats> {
        // Tell waiting clients when this loop is gone — even by panic —
        // so `ServiceClient::score` fails fast instead of blocking.
        struct StopGuard(Arc<AtomicBool>);
        impl Drop for StopGuard {
            fn drop(&mut self) {
                self.0.store(true, Ordering::Release);
            }
        }
        let _stop = StopGuard(self.stopped.clone());
        // Drop our own sender so the loop ends when clients do.
        let rx = self.rx;
        drop(self.tx);
        let mut batch: Vec<Request> = Vec::with_capacity(self.cfg.batch);
        let mut x = vec![0.0f32; self.cfg.batch * self.d_pad];
        loop {
            batch.clear();
            // block for the first request
            match rx.recv() {
                Ok(r) => batch.push(r),
                Err(_) => break, // all clients gone
            }
            // fill the batch up to the timeout
            let deadline = Instant::now() + self.cfg.fill_timeout;
            while batch.len() < self.cfg.batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => batch.push(r),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            // score
            x[..batch.len() * self.d_pad].fill(0.0);
            for (i, r) in batch.iter().enumerate() {
                debug_assert_eq!(r.x.len(), self.dim);
                x[i * self.d_pad..i * self.d_pad + self.dim].copy_from_slice(&r.x);
            }
            let scores: Vec<f32> = match runtime.as_deref_mut() {
                Some(rt) => rt.predict(&self.w, &x, self.cfg.batch, self.d_pad)?,
                None => {
                    let mut out = vec![0.0f32; self.cfg.batch];
                    linalg::matvec(&x, self.cfg.batch, self.d_pad, &self.w, &mut out);
                    out
                }
            };
            self.stats.batches += 1;
            for (i, r) in batch.drain(..).enumerate() {
                self.stats.requests += 1;
                self.stats.latency.record(r.admitted.elapsed());
                let _ = r.reply.send(Reply { score: scores[i] });
            }
            if let (Some(sk), Some((path, every))) = (&self.sketch, &self.snapshot) {
                if self.stats.batches % every == 0 && (!self.snapshot_fresh || !path.exists()) {
                    match sk.write_to(path) {
                        Ok(()) => {
                            self.stats.snapshots += 1;
                            self.snapshot_fresh = true;
                        }
                        Err(e) => crate::obs_warn!("coordinator", "live snapshot failed: {e}"),
                    }
                }
            }
        }
        Ok(self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_pure_rust_batches() {
        let svc = PredictService::new(vec![1.0, -2.0], ServiceConfig::default());
        let client = svc.client();
        let workers: Vec<_> = (0..4)
            .map(|k| {
                let c = client.clone();
                std::thread::spawn(move || {
                    let mut ok = 0;
                    for i in 0..50 {
                        let v = (k * 50 + i) as f32;
                        let s = c.score(vec![v, 1.0]).unwrap();
                        if (s - (v - 2.0)).abs() < 1e-5 {
                            ok += 1;
                        }
                    }
                    ok
                })
            })
            .collect();
        drop(client);
        let stats = svc.run(None).unwrap();
        let total: usize = workers.into_iter().map(|w| w.join().unwrap()).sum();
        assert_eq!(total, 200);
        assert_eq!(stats.requests, 200);
        assert!(stats.batches <= 200);
        assert!(stats.latency.count() == 200);
    }

    #[test]
    fn live_snapshot_writes_decodable_sketch() {
        use crate::svm::TrainOptions;
        let dir = std::env::temp_dir().join(format!("ssvm_svc_snap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("live.meb");
        let mut model = StreamSvm::new(2, TrainOptions::default());
        model.observe(&[1.0, -2.0], 1.0);
        model.observe(&[3.0, 0.5], -1.0);
        let svc = PredictService::from_model(&model, "serving", ServiceConfig::default())
            .snapshot_to(path.clone(), 1);
        let client = svc.client();
        let worker = std::thread::spawn(move || {
            for i in 0..40 {
                let _ = client.score(vec![i as f32, 1.0]).unwrap();
            }
        });
        let stats = svc.run(None).unwrap();
        worker.join().unwrap();
        assert!(stats.snapshots >= 1, "no snapshots written");
        let sk = MebSketch::read_from(&path).unwrap();
        assert_eq!(sk.tag, "serving");
        assert_eq!(sk.to_model().weights(), model.weights());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn score_errors_instead_of_hanging_when_service_panics() {
        let svc = PredictService::new(vec![1.0, -2.0], ServiceConfig::default());
        let client = svc.client();
        let loop_thread = std::thread::spawn(move || {
            // The wrong-dim request below panics the loop mid-batch; keep
            // the panic inside this thread.
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| svc.run(None)));
        });
        // Poison pill: a wrong-dimension request makes the batch copy panic.
        let bad = client.score(vec![1.0, 2.0, 3.0]);
        assert!(bad.is_err(), "wrong-dim request must error, got {bad:?}");
        loop_thread.join().unwrap();
        // After the loop died, every call must fail fast — never block.
        for _ in 0..4 {
            let r = client.score(vec![1.0, 1.0]);
            assert!(r.is_err(), "score must fail once the loop is dead");
        }
    }

    #[test]
    fn batch_fill_metric() {
        let mut s = ServiceStats { requests: 100, batches: 10, ..Default::default() };
        assert_eq!(s.mean_batch_fill(), 10.0);
        s.batches = 0;
        assert_eq!(s.mean_batch_fill(), 0.0);
    }
}
