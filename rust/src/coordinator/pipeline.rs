//! The one-pass training pipeline: reader → batcher → block filter →
//! sequential updater, with the PJRT artifacts on the hot path.
//!
//! Three execution modes (ablated in `benches/throughput.rs`):
//!
//! * [`ExecMode::Filter`] — the default hot path: one PJRT `distance`
//!   call per block (the L1 Pallas kernel), then Rust-side sequential
//!   updates for the (rare) survivors. Exact: the ball only grows, so a
//!   row enclosed at block entry stays enclosed forever; survivors are
//!   re-checked against the live ball.
//! * [`ExecMode::Scan`] — pushes the whole Algorithm-1 block scan into
//!   the AOT `update` graph (an XLA `While`), proving all three layers
//!   compose; slower on CPU PJRT but the faithful all-XLA path.
//! * [`ExecMode::Pure`] — no PJRT at all (pure Rust); the fallback when
//!   artifacts are absent and the baseline for the ablation.
//!
//! Lookahead (Algorithm 2) composes with all modes: survivors go to a
//! buffer that merges through the AOT `merge` graph (Filter/Scan) or the
//! Rust solver (Pure).
//!
//! Any learner variant trains through the pipeline
//! ([`PipelineConfig::variant`]): ball and lookahead run the block
//! machinery above on every mode, while the kernelized / ellipsoid /
//! multiball learners — whose updates are not the single-ball recurrence
//! the device graphs encode — stream block-by-block through
//! [`crate::svm::learner::AnyLearner`] in [`ExecMode::Pure`] only.
//! Blocks carry un-padded rows (sparse rows stay sparse); the dense
//! padded device layout is materialized per block on the PJRT paths
//! only.

use std::time::Instant;

use crate::coordinator::batcher::{spawn_reader, Block};
use crate::coordinator::metrics::{PipelineMetrics, ScopeTimer};
use crate::data::{Example, FeaturesView};
use crate::error::{Error, Result};
use crate::runtime::{pad_dim, Runtime};
use crate::sketch::checkpoint::Checkpointer;
use crate::svm::ball::BallState;
use crate::svm::learner::{AnyLearner, Variant};
use crate::svm::lookahead::LookaheadSvm;
use crate::svm::meb::solve_merge_into;
use crate::svm::streamsvm::StreamSvm;
use crate::svm::TrainOptions;

/// Which engine advances the ball.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Filter,
    Scan,
    Pure,
}

/// Pipeline configuration.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    pub train: TrainOptions,
    pub mode: ExecMode,
    /// Which learner trains (`train --variant`). Ball/lookahead run on
    /// every mode; the other variants require [`ExecMode::Pure`].
    pub variant: Variant,
    /// Rows per block; `None` → the artifact's compiled train block.
    pub block: Option<usize>,
    /// Bounded channel capacity (blocks in flight).
    pub queue: usize,
    /// Parallel workers (`train --workers N`). With `workers > 1` the
    /// stream routes through [`crate::coordinator::parallel`]: N
    /// Pure-mode learners train concurrently and their summary balls
    /// merge through the balanced tree. Requires [`ExecMode::Pure`] and
    /// no checkpointer.
    pub workers: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            train: TrainOptions::default(),
            mode: ExecMode::Filter,
            variant: Variant::Ball,
            block: None,
            queue: 4,
            workers: 1,
        }
    }
}

/// Result of a pipeline run.
#[derive(Debug)]
pub struct PipelineReport {
    pub model: AnyLearner,
    pub metrics: PipelineMetrics,
}

/// Internal mutable trainer state (ball/lookahead variants).
struct Trainer<'rt> {
    rt: Option<&'rt mut Runtime>,
    cfg: PipelineConfig,
    ball: Option<BallState>,
    /// Lookahead buffer (logical-dim dense rows).
    buf_x: Vec<Vec<f32>>,
    buf_y: Vec<f32>,
    /// Padded scratch for the current center.
    w_pad: Vec<f32>,
    dim: usize,
    d_pad: usize,
    /// Rows per block (the device bucket the Filter/Scan pads to).
    block_rows: usize,
    metrics: PipelineMetrics,
}

impl<'rt> Trainer<'rt> {
    fn new(
        rt: Option<&'rt mut Runtime>,
        cfg: PipelineConfig,
        dim: usize,
        block_rows: usize,
    ) -> Self {
        let d_pad = pad_dim(dim);
        Trainer {
            rt,
            cfg,
            ball: None,
            buf_x: Vec::new(),
            buf_y: Vec::new(),
            w_pad: vec![0.0; d_pad],
            dim,
            d_pad,
            block_rows,
            metrics: PipelineMetrics::default(),
        }
    }

    fn sync_w_pad(&mut self) {
        if let Some(b) = &self.ball {
            b.write_weights(&mut self.w_pad[..self.dim]);
        }
    }

    /// Sequentially check-and-absorb one row.
    fn absorb(&mut self, x: FeaturesView<'_>, y: f32) {
        let opts = self.cfg.train;
        match &mut self.ball {
            None => {
                self.ball = Some(BallState::init_view(x, y, &opts));
                self.metrics.updates += 1;
            }
            Some(ball) => {
                if opts.lookahead <= 1 {
                    if ball.try_update_view(x, y, &opts) {
                        self.metrics.updates += 1;
                    }
                } else {
                    let d = ball.distance_view(x, y, &opts);
                    if d >= ball.r {
                        let mut row = vec![0.0f32; self.dim];
                        x.write_into(&mut row);
                        self.buf_x.push(row);
                        self.buf_y.push(y);
                        if self.buf_x.len() >= opts.lookahead {
                            self.flush_buffer();
                        }
                    }
                }
            }
        }
    }

    /// Merge the lookahead buffer into the ball.
    fn flush_buffer(&mut self) {
        if self.buf_x.is_empty() {
            return;
        }
        let opts = self.cfg.train;
        let ball = self.ball.as_mut().expect("buffer implies ball");
        let l = self.buf_x.len();
        // Prefer the AOT merge graph when a bucket fits (Filter/Scan).
        let mut merged_on_device = false;
        if self.cfg.mode != ExecMode::Pure {
            if let Some(rt) = self.rt.as_deref_mut() {
                // smallest merge bucket >= l
                let bucket = rt
                    .available()
                    .into_iter()
                    .filter(|(e, b, d)| e == "merge" && *d == self.d_pad && *b >= l)
                    .map(|(_, b, _)| b)
                    .min();
                if let Some(lb) = bucket {
                    let mut xs = vec![0.0f32; lb * self.d_pad];
                    let mut ys = vec![0.0f32; lb];
                    let mut valid = vec![0.0f32; lb];
                    for i in 0..l {
                        xs[i * self.d_pad..i * self.d_pad + self.dim]
                            .copy_from_slice(&self.buf_x[i]);
                        ys[i] = self.buf_y[i];
                        valid[i] = 1.0;
                    }
                    ball.write_weights(&mut self.w_pad[..self.dim]);
                    let t = ScopeTimer::new(&mut self.metrics.xla_ns);
                    let out = rt.merge(
                        &self.w_pad,
                        ball.r as f32,
                        ball.xi2 as f32,
                        &xs,
                        &ys,
                        &valid,
                        opts.s2() as f32,
                        lb,
                        self.d_pad,
                    );
                    drop(t);
                    if let Ok(out) = out {
                        *ball = BallState::from_parts(
                            out.w[..self.dim].to_vec(),
                            out.r,
                            out.xi2,
                            ball.m + l,
                        );
                        merged_on_device = true;
                    }
                }
            }
        }
        if !merged_on_device {
            let t = ScopeTimer::new(&mut self.metrics.rust_ns);
            let views: Vec<FeaturesView> =
                self.buf_x.iter().map(|v| FeaturesView::Dense(v.as_slice())).collect();
            solve_merge_into(ball, &views, &self.buf_y, &opts);
            drop(t);
        }
        self.metrics.updates += l;
        self.metrics.merges += 1;
        self.buf_x.clear();
        self.buf_y.clear();
    }

    /// Process one block through the configured engine.
    fn process_block(&mut self, block: &Block) -> Result<()> {
        self.metrics.blocks += 1;
        self.metrics.examples += block.n_real();
        let opts = self.cfg.train;

        let mut start_row = 0usize;
        if self.ball.is_none() {
            // Initialize from the first real row, then continue in-block.
            self.absorb(block.row(0), block.y[0]);
            start_row = 1;
        }

        match self.cfg.mode {
            ExecMode::Pure => {
                let t = Instant::now();
                for i in start_row..block.n_real() {
                    self.metrics.survivors += 1; // no filter: all rows sequential
                    self.absorb(block.row(i), block.y[i]);
                }
                self.metrics.rust_ns += t.elapsed().as_nanos() as u64;
            }
            ExecMode::Filter => {
                let ball = self.ball.as_ref().expect("initialized above");
                let (r, xi2) = (ball.r, ball.xi2);
                self.sync_w_pad();
                let p = block.pad(self.block_rows, self.d_pad);
                let rt = self
                    .rt
                    .as_deref_mut()
                    .ok_or_else(|| Error::config("Filter mode requires a Runtime"))?;
                let t = ScopeTimer::new(&mut self.metrics.xla_ns);
                let d0 = rt.distance(
                    &self.w_pad,
                    &p.x,
                    &p.y,
                    xi2 as f32,
                    opts.invc() as f32,
                    p.b,
                    p.d_pad,
                )?;
                drop(t);
                let t = Instant::now();
                for i in start_row..block.n_real() {
                    // exact filter: enclosed at block entry => enclosed forever
                    if (d0[i] as f64) < r {
                        continue;
                    }
                    self.metrics.survivors += 1;
                    self.absorb(block.row(i), block.y[i]);
                }
                self.metrics.rust_ns += t.elapsed().as_nanos() as u64;
            }
            ExecMode::Scan => {
                if opts.lookahead > 1 {
                    return Err(Error::config(
                        "Scan mode supports lookahead=1 only (the scan graph \
                         encodes Algorithm 1); use Filter for Algorithm 2",
                    ));
                }
                let ball = self.ball.as_mut().expect("initialized above");
                let r_before = ball.r;
                ball.write_weights(&mut self.w_pad[..self.dim]);
                let mut p = block.pad(self.block_rows, self.d_pad);
                for v in p.valid.iter_mut().take(start_row) {
                    *v = 0.0;
                }
                let rt = self
                    .rt
                    .as_deref_mut()
                    .ok_or_else(|| Error::config("Scan mode requires a Runtime"))?;
                let t = ScopeTimer::new(&mut self.metrics.xla_ns);
                let out = rt.update(
                    &self.w_pad,
                    ball.r as f32,
                    ball.xi2 as f32,
                    &p.x,
                    &p.y,
                    &p.valid,
                    opts.invc() as f32,
                    opts.s2() as f32,
                    p.b,
                    p.d_pad,
                )?;
                drop(t);
                *ball = BallState::from_parts(
                    out.w[..self.dim].to_vec(),
                    out.r,
                    out.xi2,
                    ball.m + out.m_added,
                );
                self.metrics.updates += out.m_added;
                // survivors := rows whose distance at block entry cleared
                // the entry radius (informational in Scan mode)
                self.metrics.survivors += (start_row..block.n_real())
                    .filter(|&i| out.d0[i] as f64 >= r_before)
                    .count();
            }
        }
        Ok(())
    }
}

/// Train one pass over `source` with the streaming pipeline.
///
/// `runtime` may be `None` only in [`ExecMode::Pure`].
pub fn train_stream<I>(
    runtime: Option<&mut Runtime>,
    source: I,
    dim: usize,
    cfg: PipelineConfig,
) -> Result<PipelineReport>
where
    I: Iterator<Item = Example> + Send + 'static,
{
    train_stream_ckpt(runtime, source, dim, cfg, None)
}

/// [`train_stream`] with periodic checkpoints: the `Checkpointer`
/// snapshots the live learner at block boundaries whenever its interval
/// elapsed, so a crashed run resumes from the last sketch via
/// [`crate::sketch::checkpoint::resume_fit`] /
/// [`crate::sketch::checkpoint::resume_learner`] — bit-identically for
/// the pure-Rust paths (resume replays with the algorithm the sketch's
/// provenance selects); runs whose merges executed on-device resume
/// within float tolerance.
///
/// With lookahead > 1, snapshots only happen while the merge buffer is
/// empty — buffered-but-unmerged survivors are not part of the ball, so
/// a mid-buffer sketch would drop them on resume (and the resume merge
/// cadence relies on the buffer-empty cut).
pub fn train_stream_ckpt<I>(
    runtime: Option<&mut Runtime>,
    source: I,
    dim: usize,
    cfg: PipelineConfig,
    ckpt: Option<&mut Checkpointer>,
) -> Result<PipelineReport>
where
    I: Iterator<Item = Example> + Send + 'static,
{
    if cfg.workers > 1 {
        if cfg.mode != ExecMode::Pure {
            return Err(Error::config(
                "--workers > 1 trains in ExecMode::Pure only (each worker runs \
                 the sequential updater; the PJRT block filter is single-stream)",
            ));
        }
        if ckpt.is_some() {
            return Err(Error::config(
                "checkpointing is not supported with --workers > 1 (worker state \
                 exists only at merge time; use --workers 1, or --out to persist \
                 the merged model)",
            ));
        }
        let rep = crate::coordinator::parallel::ingest_stream(
            source,
            dim,
            crate::coordinator::parallel::IngestConfig {
                train: cfg.train,
                variant: cfg.variant,
                workers: cfg.workers,
                chunk_bytes: crate::data::chunked::DEFAULT_CHUNK_BYTES,
                queue: cfg.queue,
            },
            cfg.block.unwrap_or(256),
        )?;
        return Ok(PipelineReport { model: rep.model, metrics: rep.metrics });
    }
    match cfg.variant {
        Variant::Ball | Variant::Lookahead => {
            train_ball_pipeline(runtime, source, dim, cfg, ckpt)
        }
        v => {
            if cfg.mode != ExecMode::Pure {
                return Err(Error::config(format!(
                    "variant {v} trains in ExecMode::Pure only (the PJRT \
                     filter/scan graphs encode the single-ball recurrence)"
                )));
            }
            train_generic_pure(source, dim, cfg, ckpt)
        }
    }
}

/// The block-filter pipeline for the ball and lookahead variants (the
/// device-capable path).
fn train_ball_pipeline<I>(
    runtime: Option<&mut Runtime>,
    source: I,
    dim: usize,
    mut cfg: PipelineConfig,
    mut ckpt: Option<&mut Checkpointer>,
) -> Result<PipelineReport>
where
    I: Iterator<Item = Example> + Send + 'static,
{
    // `--variant lookahead` with an unset depth gets the same default
    // the other layers use (AnyLearner::new); an explicit lookahead > 1
    // in the options is Algorithm 2 whichever way it was selected.
    if cfg.variant == Variant::Lookahead && cfg.train.lookahead <= 1 {
        cfg.train = cfg.train.with_lookahead(8);
    }
    let d_pad = pad_dim(dim);
    let block = cfg
        .block
        .or_else(|| runtime.as_ref().and_then(|rt| rt.train_block(d_pad)))
        .unwrap_or(256);
    let wall = Instant::now();
    let (rx, reader) = spawn_reader(source, block, dim, cfg.queue);
    let mut trainer = Trainer::new(runtime, cfg, dim, block);
    for blk in rx.iter() {
        trainer.process_block(&blk)?;
        if let Some(ck) = ckpt.as_deref_mut() {
            if trainer.buf_x.is_empty() {
                ck.maybe_save(
                    trainer.ball.as_ref(),
                    dim,
                    trainer.metrics.examples,
                    trainer.metrics.merges,
                    &trainer.cfg.train,
                )?;
            }
        }
    }
    trainer.flush_buffer();
    reader
        .join()
        .map_err(|_| Error::Pipeline("reader thread panicked".into()))?;
    trainer.metrics.wall_ns = wall.elapsed().as_nanos() as u64;

    let seen = trainer.metrics.examples;
    let model = match cfg.variant {
        Variant::Lookahead => AnyLearner::Lookahead(match trainer.ball {
            Some(ball) => {
                LookaheadSvm::from_ball(dim, cfg.train, ball, seen, trainer.metrics.merges)
            }
            None => LookaheadSvm::new(dim, cfg.train),
        }),
        _ => {
            let mut m = StreamSvm::new(dim, cfg.train);
            if let Some(ball) = trainer.ball {
                m.set_ball(ball, seen);
            }
            AnyLearner::Ball(m)
        }
    };
    Ok(PipelineReport { model, metrics: trainer.metrics })
}

/// The generic streaming loop for the variants whose update is not the
/// single-ball recurrence: block-batched for the same backpressure
/// boundary, every row through [`AnyLearner::observe_view`] (O(nnz) —
/// blocks are un-padded), checkpoints at block boundaries.
fn train_generic_pure<I>(
    source: I,
    dim: usize,
    cfg: PipelineConfig,
    mut ckpt: Option<&mut Checkpointer>,
) -> Result<PipelineReport>
where
    I: Iterator<Item = Example> + Send + 'static,
{
    let block = cfg.block.unwrap_or(256);
    let wall = Instant::now();
    let (rx, reader) = spawn_reader(source, block, dim, cfg.queue);
    let mut model = AnyLearner::new(cfg.variant, dim, cfg.train);
    let mut metrics = PipelineMetrics::default();
    for blk in rx.iter() {
        metrics.blocks += 1;
        metrics.examples += blk.n_real();
        let t = Instant::now();
        for i in 0..blk.n_real() {
            metrics.survivors += 1; // no device filter on this path
            if model.observe_view(blk.row(i), blk.y[i]) {
                metrics.updates += 1;
            }
        }
        metrics.rust_ns += t.elapsed().as_nanos() as u64;
        if let Some(ck) = ckpt.as_deref_mut() {
            ck.maybe_save_learner(&model)?;
        }
    }
    reader
        .join()
        .map_err(|_| Error::Pipeline("reader thread panicked".into()))?;
    model.finish();
    metrics.wall_ns = wall.elapsed().as_nanos() as u64;
    Ok(PipelineReport { model, metrics })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check_default, gen};
    use crate::rng::Pcg32;

    fn toy(n: usize, d: usize, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, 0.8);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    #[test]
    fn pure_mode_equals_direct_algorithm1() {
        check_default("pipeline-pure-equiv", |rng, _| {
            let d = gen::dim(rng);
            let n = 1 + rng.below(300);
            let (xs, ys) = gen::labeled_points(rng, n, d, 1.0, 0.5);
            let exs: Vec<Example> =
                xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
            let cfg = PipelineConfig {
                mode: ExecMode::Pure,
                block: Some(1 + rng.below(64)),
                ..Default::default()
            };
            let report = train_stream(None, exs.clone().into_iter(), d, cfg).unwrap();
            let direct = StreamSvm::fit(exs.iter(), d, &cfg.train);
            if report.model.weights().as_deref() != Some(direct.weights().as_slice())
                || report.model.radius() != direct.radius()
                || report.model.num_support() != direct.num_support()
            {
                return Err("pipeline diverged from direct Algorithm 1".into());
            }
            Ok(())
        });
    }

    #[test]
    fn pure_mode_lookahead_equals_direct_algorithm2() {
        check_default("pipeline-pure-algo2-equiv", |rng, _| {
            let d = gen::dim(rng);
            let n = 1 + rng.below(200);
            let l = 2 + rng.below(8);
            let (xs, ys) = gen::labeled_points(rng, n, d, 1.0, 0.5);
            let exs: Vec<Example> =
                xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
            let train = TrainOptions::default().with_lookahead(l);
            let cfg = PipelineConfig {
                mode: ExecMode::Pure,
                train,
                block: Some(1 + rng.below(32)),
                ..Default::default()
            };
            let report = train_stream(None, exs.clone().into_iter(), d, cfg).unwrap();
            let direct = crate::svm::lookahead::LookaheadSvm::fit(exs.iter(), d, &train);
            let (a, b) = (report.model.radius(), direct.radius());
            if (a - b).abs() > 1e-9 * b.max(1.0) {
                return Err(format!("algo2 pipeline radius {a} vs direct {b}"));
            }
            if report.model.weights().as_deref() != Some(direct.weights().as_slice()) {
                return Err("algo2 pipeline weights diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn generic_variants_match_direct_fit_bit_identical() {
        let exs = toy(250, 5, 9);
        let opts = TrainOptions::default();
        let probes = toy(6, 5, 10);
        for v in [Variant::Kernelized, Variant::Ellipsoid, Variant::Multiball] {
            let cfg = PipelineConfig {
                mode: ExecMode::Pure,
                variant: v,
                block: Some(17),
                ..Default::default()
            };
            let report = train_stream(None, exs.clone().into_iter(), 5, cfg).unwrap();
            let direct = AnyLearner::fit(exs.iter(), v, 5, opts);
            assert_eq!(report.model.variant(), v);
            assert_eq!(report.metrics.examples, 250);
            assert_eq!(report.model.radius().to_bits(), direct.radius().to_bits(), "{v}");
            for p in &probes {
                assert_eq!(
                    report.model.score_view(p.x.view()).to_bits(),
                    direct.score_view(p.x.view()).to_bits(),
                    "{v} score diverged"
                );
            }
            // non-pure modes reject the generic variants explicitly
            let err = train_stream(
                None,
                exs.clone().into_iter(),
                5,
                PipelineConfig { mode: ExecMode::Filter, variant: v, ..Default::default() },
            )
            .unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{v}: {err}");
        }
    }

    #[test]
    fn lookahead_variant_defaults_depth_and_reports_lookahead_model() {
        let exs = toy(120, 4, 12);
        let cfg = PipelineConfig {
            mode: ExecMode::Pure,
            variant: Variant::Lookahead,
            block: Some(16),
            ..Default::default()
        };
        let report = train_stream(None, exs.clone().into_iter(), 4, cfg).unwrap();
        assert_eq!(report.model.variant(), Variant::Lookahead);
        // the same default depth AnyLearner::new applies
        let direct = crate::svm::lookahead::LookaheadSvm::fit(
            exs.iter(),
            4,
            &TrainOptions::default().with_lookahead(8),
        );
        assert_eq!(report.model.radius().to_bits(), direct.radius().to_bits());
        assert_eq!(
            report.model.weights().as_deref(),
            Some(direct.weights().as_slice())
        );
    }

    #[test]
    fn filter_mode_without_runtime_errors() {
        let exs = toy(10, 3, 1);
        let err = train_stream(
            None,
            exs.into_iter(),
            3,
            PipelineConfig { mode: ExecMode::Filter, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("requires a Runtime"));
    }

    #[test]
    fn metrics_count_examples() {
        let exs = toy(100, 4, 2);
        let cfg = PipelineConfig { mode: ExecMode::Pure, block: Some(16), ..Default::default() };
        let report = train_stream(None, exs.into_iter(), 4, cfg).unwrap();
        assert_eq!(report.metrics.examples, 100);
        assert_eq!(report.metrics.blocks, 7);
        assert!(report.metrics.updates >= 1);
        assert!(report.metrics.wall_ns > 0);
    }

    #[test]
    fn multiworker_pipeline_merges_within_tolerance() {
        use crate::eval::accuracy;
        let exs = toy(3000, 6, 27);
        let one = train_stream(
            None,
            exs.clone().into_iter(),
            6,
            PipelineConfig { mode: ExecMode::Pure, block: Some(64), ..Default::default() },
        )
        .unwrap();
        let four = train_stream(
            None,
            exs.clone().into_iter(),
            6,
            PipelineConfig {
                mode: ExecMode::Pure,
                block: Some(64),
                workers: 4,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(four.metrics.examples, 3000);
        let (a1, a4) = (accuracy(&one.model, &exs), accuracy(&four.model, &exs));
        assert!(a4 > a1 - 0.08, "4 workers {a4:.3} vs 1 worker {a1:.3}");
    }

    #[test]
    fn multiworker_rejects_nonpure_and_checkpoints() {
        use crate::sketch::checkpoint::{CheckpointConfig, Checkpointer};
        let exs = toy(50, 3, 28);
        let err = train_stream(
            None,
            exs.clone().into_iter(),
            3,
            PipelineConfig { mode: ExecMode::Filter, workers: 2, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("ExecMode::Pure"), "{err}");
        let mut ck = Checkpointer::new(CheckpointConfig {
            every: 10,
            path: std::env::temp_dir().join("ssvm_workers_ckpt.meb"),
            tag: "w".into(),
        });
        let err = train_stream_ckpt(
            None,
            exs.into_iter(),
            3,
            PipelineConfig { mode: ExecMode::Pure, workers: 2, ..Default::default() },
            Some(&mut ck),
        )
        .unwrap_err();
        assert!(err.to_string().contains("not supported with --workers"), "{err}");
    }

    #[test]
    fn checkpointed_pipeline_resumes_bit_identical() {
        use crate::sketch::checkpoint::{resume_fit, CheckpointConfig};
        use crate::sketch::codec::MebSketch;
        let dir = std::env::temp_dir().join(format!("ssvm_pipe_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pipe.meb");
        let exs = toy(200, 5, 4);
        let cfg = PipelineConfig { mode: ExecMode::Pure, block: Some(16), ..Default::default() };
        let mut ck = Checkpointer::new(CheckpointConfig {
            every: 48,
            path: path.clone(),
            tag: "pipe".into(),
        });
        let report =
            train_stream_ckpt(None, exs.clone().into_iter(), 5, cfg, Some(&mut ck)).unwrap();
        // intervals elapse at block boundaries 48, 96, 144, 192
        assert!(ck.saves() >= 3, "saves = {}", ck.saves());
        let sk = MebSketch::read_from(&path).unwrap();
        assert!(sk.seen > 0 && sk.seen < 200, "seen = {}", sk.seen);
        // simulate the crash: resume from the last checkpoint and replay
        let resumed = resume_fit(&sk, exs.clone());
        assert_eq!(Some(resumed.weights().as_slice()), report.model.weights().as_deref());
        assert_eq!(resumed.radius().to_bits(), report.model.radius().to_bits());
        assert_eq!(resumed.num_support(), report.model.num_support());
        assert_eq!(resumed.examples_seen(), 200);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpointed_generic_variant_resumes_bit_identical() {
        use crate::sketch::checkpoint::{resume_learner, CheckpointConfig};
        use crate::sketch::codec::MebSketch;
        let dir = std::env::temp_dir().join(format!("ssvm_pipe_ckpt_gen_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let exs = toy(200, 4, 15);
        for v in [Variant::Kernelized, Variant::Ellipsoid, Variant::Multiball] {
            let path = dir.join(format!("{v}.meb"));
            let cfg = PipelineConfig {
                mode: ExecMode::Pure,
                variant: v,
                block: Some(16),
                ..Default::default()
            };
            let mut ck = Checkpointer::new(CheckpointConfig {
                every: 48,
                path: path.clone(),
                tag: "gen".into(),
            });
            let report =
                train_stream_ckpt(None, exs.clone().into_iter(), 4, cfg, Some(&mut ck)).unwrap();
            assert!(ck.saves() >= 3, "{v}: saves = {}", ck.saves());
            let sk = MebSketch::read_from(&path).unwrap();
            assert_eq!(sk.variant, v);
            assert!(sk.seen > 0 && sk.seen < 200, "{v}: seen = {}", sk.seen);
            let resumed = resume_learner(&sk, exs.clone()).unwrap();
            assert_eq!(resumed.radius().to_bits(), report.model.radius().to_bits(), "{v}");
            for p in exs.iter().take(5) {
                assert_eq!(
                    resumed.score_view(p.x.view()).to_bits(),
                    report.model.score_view(p.x.view()).to_bits(),
                    "{v} resumed score diverged"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpointed_lookahead_skips_mid_buffer_saves() {
        use crate::sketch::checkpoint::CheckpointConfig;
        use crate::sketch::codec::MebSketch;
        let dir = std::env::temp_dir().join(format!("ssvm_pipe_ckpt_la_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("la.meb");
        let exs = toy(300, 4, 6);
        let cfg = PipelineConfig {
            mode: ExecMode::Pure,
            block: Some(32),
            train: TrainOptions::default().with_lookahead(7),
            ..Default::default()
        };
        let mut ck =
            Checkpointer::new(CheckpointConfig { every: 64, path: path.clone(), tag: "la".into() });
        train_stream_ckpt(None, exs.clone().into_iter(), 4, cfg, Some(&mut ck)).unwrap();
        if ck.saves() > 0 {
            // every saved sketch must be at a fully-absorbed prefix: the
            // resumed prefix model equals a direct prefix-trained model
            let sk = MebSketch::read_from(&path).unwrap();
            let mut direct = crate::svm::lookahead::LookaheadSvm::new(4, cfg.train);
            for e in exs.iter().take(sk.seen) {
                direct.observe_view(e.x.view(), e.y);
            }
            assert_eq!(direct.buffered(), 0, "checkpoint taken mid-buffer");
            assert_eq!(sk.ball.as_ref().unwrap().weights(), direct.weights());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
