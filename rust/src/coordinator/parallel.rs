//! Parallel ingest: multicore one-pass training straight from bytes.
//!
//! The third layer of the chunked-ingest pipeline. [`ChunkReader`]
//! (layer 1, `data::chunked`) turns a file into newline-aligned byte
//! chunks; this driver round-robins those chunks over a bounded channel
//! to N worker threads, each of which parses its chunks with the
//! tolerant byte-level row parser and runs Algorithm 1 (any variant,
//! via [`AnyLearner`]) over the rows it sees. The finished workers'
//! summary balls fold through the sketch layer's balanced merge tree —
//! the same aggregation [`super::sharded`] uses, factored into
//! [`super::sharded::merge_worker_models`] — so the result is one model
//! whose ball encloses every streamed point.
//!
//! ```text
//!   feeder (this thread)              N workers
//!   ┌─────────────────┐  bounded     ┌──────────────────────────┐
//!   │ ChunkReader:    │  channels    │ bytes → parse_row_tolerant│
//!   │ read + newline  │ ──chunks───▶ │ → AnyLearner (Algorithm 1)│ ×N
//!   │ alignment       │  round-robin └──────────────────────────┘
//!   └─────────────────┘                      │ summary balls
//!                                            ▼
//!                                   merge_ball_tree → one model
//! ```
//!
//! Contrast with [`super::sharded`]: that coordinator dispatches
//! *parsed* `Example`s one at a time (one channel send per row), so at
//! high row rates the dispatch itself becomes the bottleneck. Here a
//! send moves ~256 KiB of raw bytes and the *parsing* parallelizes too
//! — the whole ingest cost (syscalls excepted) scales with cores.
//! [`ingest_stream`] is the same driver for sources that are already
//! `Example`s (the `train --workers N` pipeline route): rows travel in
//! blocks instead of byte chunks.
//!
//! Accounting: skipped rows bump
//! [`telemetry::PARSE_SKIPPED`] unconditionally (data loss is never
//! invisible); chunk/byte/row counters
//! (`pallas_ingest_chunks/bytes/rows_total`) are gated on
//! [`telemetry::telemetry_on`] like every other hot-path tap.

use std::fs::File;
use std::io::Read;
use std::path::Path;
use std::sync::mpsc::sync_channel;
use std::time::Instant;

use crate::coordinator::metrics::PipelineMetrics;
use crate::coordinator::sharded::{lookahead_defaulted, merge_worker_models};
use crate::data::chunked::{self, ChunkReader, Row, DEFAULT_CHUNK_BYTES};
use crate::data::Example;
use crate::error::{Error, Result};
use crate::obs::telemetry;
use crate::svm::learner::{AnyLearner, Variant};
use crate::svm::TrainOptions;

/// Parallel-ingest configuration.
#[derive(Clone, Copy, Debug)]
pub struct IngestConfig {
    pub train: TrainOptions,
    /// Which learner each worker runs (same gate as sharding: the
    /// variant must expose a summary ball to merge).
    pub variant: Variant,
    /// Worker threads. 1 is a valid (sequential) configuration.
    pub workers: usize,
    /// Target bytes per chunk ([`DEFAULT_CHUNK_BYTES`] unless tuned
    /// with `--chunk-kb`). A line longer than this still parses; the
    /// chunk just grows.
    pub chunk_bytes: usize,
    /// Bounded per-worker channel capacity (chunks in flight), the
    /// backpressure bound on queued memory: at most
    /// `workers * queue * chunk_bytes` buffered bytes.
    pub queue: usize,
}

impl Default for IngestConfig {
    fn default() -> Self {
        IngestConfig {
            train: TrainOptions::default(),
            variant: Variant::Ball,
            workers: 1,
            chunk_bytes: DEFAULT_CHUNK_BYTES,
            queue: 4,
        }
    }
}

/// Result of a parallel ingest run.
#[derive(Debug)]
pub struct IngestReport {
    pub model: AnyLearner,
    /// Rows parsed and trained on (across all workers).
    pub rows: usize,
    /// Malformed rows skipped by the tolerant parser.
    pub skipped: usize,
    /// Newline-aligned chunks (byte path) or row blocks (stream path)
    /// dispatched.
    pub chunks: usize,
    /// Bytes consumed from the reader (0 on the stream path).
    pub bytes: u64,
    /// Per-worker summary-ball radii (pre-merge, for diagnostics).
    pub worker_radii: Vec<f64>,
    /// Aggregate over all workers (counters sum); `wall_ns` is the
    /// end-to-end driver wall clock, dispatch and merge included, so
    /// [`PipelineMetrics::throughput`] is the true ingest rate.
    pub metrics: PipelineMetrics,
}

impl IngestReport {
    /// End-to-end rows per second.
    pub fn rows_per_s(&self) -> f64 {
        self.metrics.throughput()
    }

    /// End-to-end parse throughput in MB/s (byte path only).
    pub fn mb_per_s(&self) -> f64 {
        if self.metrics.wall_ns == 0 {
            0.0
        } else {
            self.bytes as f64 / (1024.0 * 1024.0) / (self.metrics.wall_ns as f64 * 1e-9)
        }
    }
}

/// One worker's loop on the byte path: parse every line of every chunk
/// received, feed the learner, count skips.
fn byte_worker(
    rx: std::sync::mpsc::Receiver<Vec<u8>>,
    variant: Variant,
    dim: usize,
    opts: TrainOptions,
) -> (AnyLearner, PipelineMetrics, usize) {
    let mut model = AnyLearner::new(variant, dim, opts);
    let mut metrics = PipelineMetrics::default();
    let mut skipped = 0usize;
    let wall = Instant::now();
    for chunk in rx.iter() {
        metrics.blocks += 1;
        let mut rows = 0u64;
        for line in chunked::lines(&chunk) {
            match chunked::parse_row_tolerant(line, dim) {
                Row::Ok(e) => {
                    rows += 1;
                    metrics.examples += 1;
                    metrics.survivors += 1; // sequential path: every row checked
                    if model.observe_view(e.x.view(), e.y) {
                        metrics.updates += 1;
                    }
                }
                Row::Blank => {}
                Row::Bad => {
                    skipped += 1;
                    // unconditional, like every tolerant-parse skip site
                    telemetry::PARSE_SKIPPED.inc();
                }
            }
        }
        if telemetry::telemetry_on() {
            telemetry::INGEST_ROWS.add(rows);
        }
    }
    model.finish();
    metrics.wall_ns = wall.elapsed().as_nanos() as u64;
    (model, metrics, skipped)
}

/// Train one pass over a LIBSVM byte stream with `cfg.workers` parallel
/// learners. The feeder (calling thread) only reads and realigns bytes;
/// parsing and training both happen in the workers.
pub fn ingest_reader<R: Read>(r: R, dim: usize, cfg: IngestConfig) -> Result<IngestReport> {
    let workers = cfg.workers.max(1);
    let opts = lookahead_defaulted(cfg.variant, cfg.train);
    let variant = cfg.variant;
    let wall = Instant::now();
    let mut senders = Vec::with_capacity(workers);
    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (tx, rx) = sync_channel::<Vec<u8>>(cfg.queue.max(1));
        senders.push(tx);
        handles.push(std::thread::spawn(move || byte_worker(rx, variant, dim, opts)));
    }
    let mut reader = ChunkReader::new(r, cfg.chunk_bytes);
    let mut chunks = 0usize;
    while let Some(chunk) = reader.next_chunk()? {
        senders[chunks % workers]
            .send(chunk)
            .map_err(|_| Error::Pipeline("ingest worker hung up".into()))?;
        chunks += 1;
    }
    let bytes = reader.bytes_read();
    drop(senders);

    let mut models = Vec::with_capacity(workers);
    let mut agg = PipelineMetrics::default();
    let mut skipped = 0usize;
    for h in handles {
        let (model, m, sk) =
            h.join().map_err(|_| Error::Pipeline("ingest worker panicked".into()))?;
        agg.merge(&m);
        skipped += sk;
        models.push(model);
    }
    let rows = agg.examples;
    let (model, worker_radii) = merge_worker_models(models, dim, variant, opts, rows)?;
    agg.wall_ns = wall.elapsed().as_nanos() as u64;
    Ok(IngestReport { model, rows, skipped, chunks, bytes, worker_radii, metrics: agg })
}

/// [`ingest_reader`] over a file. The [`ChunkReader`] issues its own
/// chunk-sized reads, so no `BufReader` layer is wanted in between.
pub fn ingest_file(path: &Path, dim: usize, cfg: IngestConfig) -> Result<IngestReport> {
    let f = File::open(path)
        .map_err(|e| Error::Io(std::io::Error::new(e.kind(), format!("{}: {e}", path.display()))))?;
    ingest_reader(f, dim, cfg)
}

/// The same parallel driver for sources that are already parsed
/// `Example`s (the `train --workers N` pipeline route): rows round-robin
/// to the workers in blocks of `block`, the parallel analog of the byte
/// chunks. Every example is validated against `dim` at dispatch, like
/// [`super::sharded::train_sharded_variant`].
pub fn ingest_stream<I>(
    source: I,
    dim: usize,
    cfg: IngestConfig,
    block: usize,
) -> Result<IngestReport>
where
    I: Iterator<Item = Example>,
{
    let workers = cfg.workers.max(1);
    let opts = lookahead_defaulted(cfg.variant, cfg.train);
    let variant = cfg.variant;
    let block = block.max(1);
    let wall = Instant::now();
    let mut senders = Vec::with_capacity(workers);
    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (tx, rx) = sync_channel::<Vec<Example>>(cfg.queue.max(1));
        senders.push(tx);
        handles.push(std::thread::spawn(move || {
            let mut model = AnyLearner::new(variant, dim, opts);
            let mut metrics = PipelineMetrics::default();
            let wall = Instant::now();
            for blk in rx.iter() {
                metrics.blocks += 1;
                if telemetry::telemetry_on() {
                    telemetry::INGEST_ROWS.add(blk.len() as u64);
                }
                for e in blk {
                    metrics.examples += 1;
                    metrics.survivors += 1;
                    if model.observe_view(e.x.view(), e.y) {
                        metrics.updates += 1;
                    }
                }
            }
            model.finish();
            metrics.wall_ns = wall.elapsed().as_nanos() as u64;
            (model, metrics)
        }));
    }
    let mut buf: Vec<Example> = Vec::with_capacity(block);
    let mut blocks = 0usize;
    let mut n = 0usize;
    for (i, e) in source.enumerate() {
        if e.dim() != dim {
            drop(senders); // release workers before bailing out
            return Err(Error::config(format!(
                "parallel ingest: example {i} has dimension {} but the stream \
                 was declared as {dim}",
                e.dim()
            )));
        }
        n += 1;
        buf.push(e);
        if buf.len() >= block {
            let full = std::mem::replace(&mut buf, Vec::with_capacity(block));
            senders[blocks % workers]
                .send(full)
                .map_err(|_| Error::Pipeline("ingest worker hung up".into()))?;
            blocks += 1;
        }
    }
    if !buf.is_empty() {
        senders[blocks % workers]
            .send(buf)
            .map_err(|_| Error::Pipeline("ingest worker hung up".into()))?;
        blocks += 1;
    }
    drop(senders);

    let mut models = Vec::with_capacity(workers);
    let mut agg = PipelineMetrics::default();
    for h in handles {
        let (model, m) =
            h.join().map_err(|_| Error::Pipeline("ingest worker panicked".into()))?;
        agg.merge(&m);
        models.push(model);
    }
    let (model, worker_radii) = merge_worker_models(models, dim, variant, opts, n)?;
    agg.wall_ns = wall.elapsed().as_nanos() as u64;
    Ok(IngestReport {
        model,
        rows: n,
        skipped: 0,
        chunks: blocks,
        bytes: 0,
        worker_radii,
        metrics: agg,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::accuracy;
    use crate::prop::gen;
    use crate::rng::Pcg32;
    use crate::svm::streamsvm::StreamSvm;

    fn toy(n: usize, d: usize, seed: u64) -> Vec<Example> {
        let mut rng = Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, 1.0);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    /// Render examples as LIBSVM text the way `gen-data` does; `{}` on
    /// f32 round-trips bit-exactly through the byte parser.
    fn libsvm_text(exs: &[Example]) -> String {
        let mut s = String::new();
        for e in exs {
            s.push_str(if e.y > 0.0 { "+1" } else { "-1" });
            for (i, v) in e.x.iter_nonzero() {
                s.push_str(&format!(" {}:{v}", i + 1));
            }
            s.push('\n');
        }
        s
    }

    #[test]
    fn single_worker_ingest_matches_direct_fit() {
        let exs = toy(400, 5, 3);
        let text = libsvm_text(&exs);
        let cfg = IngestConfig { chunk_bytes: 97, ..Default::default() };
        let rep = ingest_reader(text.as_bytes(), 5, cfg).unwrap();
        assert_eq!(rep.rows, 400);
        assert_eq!(rep.skipped, 0);
        assert!(rep.chunks > 1, "chunks = {}", rep.chunks);
        assert_eq!(rep.bytes, text.len() as u64);
        // one worker == the sequential pass: the merged single ball is
        // the worker's own ball, so the model matches a direct fit over
        // the same parsed (sparse) stream exactly
        let parsed: Vec<Example> =
            crate::coordinator::stream::FileStream::from_reader(text.as_bytes(), 5).collect();
        assert_eq!(parsed.len(), 400);
        let direct = StreamSvm::fit(parsed.iter(), 5, &TrainOptions::default());
        assert_eq!(rep.model.weights(), Some(direct.weights()));
        assert_eq!(rep.model.radius().to_bits(), direct.radius().to_bits());
    }

    #[test]
    fn worker_count_invariance_within_merge_tolerance() {
        let exs = toy(4000, 8, 7);
        let text = libsvm_text(&exs);
        let one = ingest_reader(
            text.as_bytes(),
            8,
            IngestConfig { workers: 1, chunk_bytes: 4096, ..Default::default() },
        )
        .unwrap();
        let eight = ingest_reader(
            text.as_bytes(),
            8,
            IngestConfig { workers: 8, chunk_bytes: 4096, ..Default::default() },
        )
        .unwrap();
        assert_eq!(one.rows, 4000);
        assert_eq!(eight.rows, 4000);
        assert_eq!(eight.worker_radii.len(), 8);
        let (a1, a8) = (accuracy(&one.model, &exs), accuracy(&eight.model, &exs));
        assert!(a8 > a1 - 0.08, "8 workers {a8:.3} vs 1 worker {a1:.3}");
        // the merged ball dominates every worker ball
        let max_r = eight.worker_radii.iter().cloned().fold(0.0f64, f64::max);
        assert!(eight.model.radius() + 1e-9 >= max_r);
    }

    #[test]
    fn malformed_rows_skip_and_count_across_workers() {
        let exs = toy(200, 4, 11);
        let mut text = libsvm_text(&exs);
        text.push_str("not-a-label 1:1\n+1 1:bad\n# comment\n\n+1 1:0.5\n");
        let before = telemetry::PARSE_SKIPPED.get();
        let rep = ingest_reader(
            text.as_bytes(),
            4,
            IngestConfig { workers: 3, chunk_bytes: 64, ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.rows, 201);
        assert_eq!(rep.skipped, 2);
        // unconditional counter moved by at least our skips (other tests
        // may bump it concurrently, so >= not ==)
        assert!(telemetry::PARSE_SKIPPED.get() >= before + 2);
    }

    #[test]
    fn empty_and_all_bad_inputs_error() {
        assert!(ingest_reader(&b""[..], 3, IngestConfig::default()).is_err());
        let rep = ingest_reader(&b"garbage\nmore garbage\n"[..], 3, IngestConfig::default());
        assert!(rep.is_err(), "rows never parsed: no model to report");
    }

    #[test]
    fn stream_path_matches_sharded_semantics() {
        let exs = toy(1500, 6, 13);
        let one =
            ingest_stream(exs.clone().into_iter(), 6, IngestConfig::default(), 64).unwrap();
        let four = ingest_stream(
            exs.clone().into_iter(),
            6,
            IngestConfig { workers: 4, ..Default::default() },
            64,
        )
        .unwrap();
        assert_eq!(one.rows, 1500);
        assert_eq!(four.rows, 1500);
        assert_eq!(four.chunks, 1500usize.div_ceil(64));
        let direct = StreamSvm::fit(exs.iter(), 6, &TrainOptions::default());
        assert_eq!(one.model.weights(), Some(direct.weights()));
        let (a1, a4) = (accuracy(&one.model, &exs), accuracy(&four.model, &exs));
        assert!(a4 > a1 - 0.08, "4 workers {a4:.3} vs 1 worker {a1:.3}");
    }

    #[test]
    fn stream_path_rejects_dimension_mismatch() {
        let mut exs = toy(30, 4, 17);
        exs.insert(20, Example::new(vec![1.0, -1.0], 1.0)); // rogue dim-2 row
        let err = ingest_stream(
            exs.into_iter(),
            4,
            IngestConfig { workers: 2, ..Default::default() },
            8,
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(matches!(err, Error::Config(_)), "{msg}");
        assert!(msg.contains("example 20") && msg.contains("dimension 2"), "{msg}");
    }

    #[test]
    fn lookahead_variant_ingests_with_defaulted_depth() {
        let exs = toy(600, 5, 19);
        let text = libsvm_text(&exs);
        let rep = ingest_reader(
            text.as_bytes(),
            5,
            IngestConfig { variant: Variant::Lookahead, workers: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.model.variant(), Variant::Lookahead);
        assert_eq!(rep.rows, 600);
        assert!(accuracy(&rep.model, &exs) > 0.5);
    }

    #[test]
    fn telemetry_counts_chunks_bytes_rows() {
        let _g = crate::obs::recorder::test_lock();
        telemetry::reset_all();
        crate::obs::set_telemetry(true);
        let exs = toy(300, 4, 23);
        let text = libsvm_text(&exs);
        let rep = ingest_reader(
            text.as_bytes(),
            4,
            IngestConfig { workers: 2, chunk_bytes: 512, ..Default::default() },
        )
        .unwrap();
        crate::obs::set_telemetry(false);
        assert!(telemetry::INGEST_CHUNKS.get() >= rep.chunks as u64);
        assert!(telemetry::INGEST_BYTES.get() >= rep.bytes);
        assert!(telemetry::INGEST_ROWS.get() >= rep.rows as u64);
        telemetry::reset_all();
    }
}
