//! Stream sources: in-memory (with deterministic permutation), lazy
//! LIBSVM file streaming, and rate metering hooks.

use std::io::{BufRead, BufReader};
use std::path::Path;

use crate::data::{chunked, Dataset, Example};
use crate::error::Result;
use crate::rng::Pcg32;

/// An owned in-memory stream, optionally order-permuted (the paper
/// averages every experiment over random stream orders).
pub struct VecStream {
    examples: Vec<Example>,
    order: Vec<usize>,
    pos: usize,
}

impl VecStream {
    /// Stream in stored order.
    pub fn new(examples: Vec<Example>) -> Self {
        let order = (0..examples.len()).collect();
        VecStream { examples, order, pos: 0 }
    }

    /// Stream in a seeded random permutation of the stored order.
    pub fn permuted(examples: Vec<Example>, seed: u64) -> Self {
        let order = Pcg32::new(seed, 0x0DE8).permutation(examples.len());
        VecStream { examples, order, pos: 0 }
    }

    /// Borrowing constructor over a dataset's training split.
    pub fn of_train(ds: &Dataset, perm_seed: Option<u64>) -> Self {
        match perm_seed {
            Some(s) => Self::permuted(ds.train.clone(), s),
            None => Self::new(ds.train.clone()),
        }
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }
}

impl Iterator for VecStream {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        let i = *self.order.get(self.pos)?;
        self.pos += 1;
        Some(self.examples[i].clone())
    }
}

/// Lazy one-pass LIBSVM file stream — the genuinely disk-resident case
/// from the paper's motivation (§1). Rides the chunked byte-level
/// reader ([`chunked::ChunkReader`]): the file is pulled in
/// newline-aligned buffers and each row parses on demand as a *sparse*
/// example straight from the bytes (never materialized, densified, or
/// copied into a per-line `String`), so the downstream update cost is
/// O(nnz) per row. Dimension must be known up front (`dim`). This
/// reader is tolerant: out-of-range indices are dropped, and rows with
/// non-finite labels/values *or malformed tokens* (`qid:3` fields,
/// garbage, unparsable numbers) are skipped whole and counted in
/// [`Self::rows_skipped`] — one bad row must never truncate the rest of
/// a long stream (the strict loaders in
/// [`crate::data::libsvm_format`] reject instead). Only EOF or an I/O
/// error ends the stream. [`LineStream`] keeps the old per-line
/// implementation as the reference the parity tests and the ingest
/// bench compare against.
pub struct FileStream<R: std::io::Read> {
    chunks: chunked::ChunkReader<R>,
    /// Current newline-aligned chunk, consumed from `pos`.
    chunk: Vec<u8>,
    pos: usize,
    dim: usize,
    yielded: usize,
    skipped: usize,
}

impl FileStream<std::fs::File> {
    pub fn open(path: &Path, dim: usize) -> Result<Self> {
        Ok(Self::from_reader(std::fs::File::open(path)?, dim))
    }
}

impl<R: std::io::Read> FileStream<R> {
    pub fn from_reader(r: R, dim: usize) -> Self {
        FileStream {
            chunks: chunked::ChunkReader::new(r, chunked::DEFAULT_CHUNK_BYTES),
            chunk: Vec::new(),
            pos: 0,
            dim,
            yielded: 0,
            skipped: 0,
        }
    }

    /// Examples yielded so far (the `serve --train-stream` progress
    /// counter behind `/stats`).
    pub fn rows_yielded(&self) -> usize {
        self.yielded
    }

    /// Rows skipped so far (non-finite labels/values, malformed tokens).
    pub fn rows_skipped(&self) -> usize {
        self.skipped
    }
}

impl<R: std::io::Read> Iterator for FileStream<R> {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        loop {
            if self.pos >= self.chunk.len() {
                // An I/O error ends the stream, like EOF (`.ok()?`) —
                // mirroring the legacy per-line reader.
                self.chunk = self.chunks.next_chunk().ok()??;
                self.pos = 0;
            }
            let rest = &self.chunk[self.pos..];
            let end = rest.iter().position(|&b| b == b'\n').unwrap_or(rest.len());
            let line = &rest[..end];
            self.pos += end + 1;
            // A malformed or poisoned row must not end the stream: with
            // `--train-stream` a `None` here would be reported as a
            // *completed* file while silently dropping every later row.
            match chunked::parse_row_tolerant(line, self.dim) {
                chunked::Row::Ok(e) => {
                    self.yielded += 1;
                    return Some(e);
                }
                chunked::Row::Blank => continue,
                chunked::Row::Bad => {
                    self.skipped += 1;
                    // Unconditional, like OBS_EVENTS_DROPPED: dropped
                    // training data must stay visible.
                    crate::obs::telemetry::PARSE_SKIPPED.inc();
                    continue;
                }
            }
        }
    }
}

/// The legacy per-line reader (`BufRead::read_line` + `str::parse`),
/// semantics-identical to [`FileStream`]. Retained as the comparison
/// baseline: the parity tests assert chunked == per-line `Example`
/// sequences on every fixture, and `benches/ingest.rs` measures the
/// MB/s gap between the two.
pub struct LineStream<R: std::io::Read> {
    reader: BufReader<R>,
    dim: usize,
    line: String,
    yielded: usize,
    skipped: usize,
}

impl LineStream<std::fs::File> {
    pub fn open(path: &Path, dim: usize) -> Result<Self> {
        Ok(Self::from_reader(std::fs::File::open(path)?, dim))
    }
}

impl<R: std::io::Read> LineStream<R> {
    pub fn from_reader(r: R, dim: usize) -> Self {
        LineStream {
            reader: BufReader::new(r),
            dim,
            line: String::new(),
            yielded: 0,
            skipped: 0,
        }
    }

    pub fn rows_yielded(&self) -> usize {
        self.yielded
    }

    pub fn rows_skipped(&self) -> usize {
        self.skipped
    }

    /// Parse one non-empty, non-comment line; `None` = skip this row
    /// (malformed or poisoned), never end the stream.
    fn parse_row(&self, t: &str) -> Option<Example> {
        let mut it = t.split_whitespace();
        let label: f64 = it.next()?.parse().ok()?;
        if !label.is_finite() {
            return None;
        }
        let mut pairs: Vec<(u32, f32)> = Vec::new();
        for tok in it {
            let (i, v) = tok.split_once(':')?;
            let idx: usize = i.parse().ok()?;
            if idx == 0 || idx > self.dim {
                continue;
            }
            let val: f32 = v.parse().ok()?;
            if !val.is_finite() {
                return None;
            }
            pairs.push((idx as u32 - 1, val));
        }
        pairs.sort_unstable_by_key(|&(i, _)| i);
        pairs.dedup_by_key(|&mut (i, _)| i);
        let (idx, val): (Vec<u32>, Vec<f32>) = pairs.into_iter().unzip();
        Some(Example::sparse(
            self.dim,
            idx,
            val,
            if label > 0.0 { 1.0 } else { -1.0 },
        ))
    }
}

impl<R: std::io::Read> Iterator for LineStream<R> {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        loop {
            self.line.clear();
            if self.reader.read_line(&mut self.line).ok()? == 0 {
                return None;
            }
            let t = self.line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            match self.parse_row(t) {
                Some(e) => {
                    self.yielded += 1;
                    return Some(e);
                }
                None => {
                    self.skipped += 1;
                    continue;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exs(n: usize) -> Vec<Example> {
        (0..n)
            .map(|i| Example::new(vec![i as f32], if i % 2 == 0 { 1.0 } else { -1.0 }))
            .collect()
    }

    #[test]
    fn vec_stream_preserves_order() {
        let got: Vec<f32> = VecStream::new(exs(5)).map(|e| e.x[0]).collect();
        assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn permuted_stream_is_permutation() {
        let mut got: Vec<f32> = VecStream::permuted(exs(50), 3).map(|e| e.x[0]).collect();
        assert_ne!(got, (0..50).map(|i| i as f32).collect::<Vec<_>>());
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, (0..50).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn permutation_deterministic_per_seed() {
        let a: Vec<f32> = VecStream::permuted(exs(20), 7).map(|e| e.x[0]).collect();
        let b: Vec<f32> = VecStream::permuted(exs(20), 7).map(|e| e.x[0]).collect();
        let c: Vec<f32> = VecStream::permuted(exs(20), 8).map(|e| e.x[0]).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn file_stream_parses_lazily_as_sparse() {
        let text = "+1 1:0.5 3:1.5\n# comment\n-1 2:2.0\n";
        let got: Vec<Example> = FileStream::from_reader(text.as_bytes(), 3).collect();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].x.nnz(), 2);
        assert_eq!(got[0].x.dense().as_ref(), &[0.5, 0.0, 1.5]);
        assert_eq!(got[1].y, -1.0);
    }

    #[test]
    fn file_stream_ignores_out_of_range_indices() {
        let got: Vec<Example> = FileStream::from_reader("+1 99:1.0 1:2.0\n".as_bytes(), 2).collect();
        assert_eq!(got[0].x.dense().as_ref(), &[2.0, 0.0]);
    }

    #[test]
    fn file_stream_skips_non_finite_rows_without_truncating() {
        let text = "+1 1:nan\nnan 1:1\n+1 1:inf\n-1 1:1\n";
        let mut fs = FileStream::from_reader(text.as_bytes(), 2);
        let got: Vec<Example> = (&mut fs).collect();
        assert_eq!(got.len(), 1, "good rows after a poisoned row must survive");
        assert_eq!(got[0].y, -1.0);
        assert_eq!(got[0].x.dense().as_ref(), &[1.0, 0.0]);
        assert_eq!(fs.rows_yielded(), 1);
        assert_eq!(fs.rows_skipped(), 3);
    }

    #[test]
    fn file_stream_skips_malformed_rows_without_truncating() {
        // qid fields, garbage labels, unparsable values: each bad row is
        // skipped and counted; rows after it must still stream (before
        // this guard, the first malformed token silently ended the
        // iterator — fatal for `serve --train-stream`, which would then
        // report the file as fully consumed).
        let text = "+1 qid:3 1:0.5\nnot-a-label 1:1\n+1 1:bad\n+1 1:0.5\n-1 2:2.0\n";
        let mut fs = FileStream::from_reader(text.as_bytes(), 2);
        let got: Vec<Example> = (&mut fs).collect();
        assert_eq!(got.len(), 2, "good rows after malformed rows must survive");
        assert_eq!(got[0].x.dense().as_ref(), &[0.5, 0.0]);
        assert_eq!(got[1].y, -1.0);
        assert_eq!(fs.rows_yielded(), 2);
        assert_eq!(fs.rows_skipped(), 3);
    }

    #[test]
    fn chunked_file_stream_matches_line_stream() {
        // same examples, same counters, across good/bad/blank/comment
        // rows and both number-grammar paths (fast path + fallback)
        let text = "+1 1:0.5 3:1.5\n# comment\n-1 2:2.0\n+1 qid:3 1:0.5\nnan 1:1\n\
                    +1 99:1 1:2\n\n-1 1:1e-3 2:2.5E1\n+1 3:3 1:1 3:9";
        let mut a = FileStream::from_reader(text.as_bytes(), 3);
        let mut b = LineStream::from_reader(text.as_bytes(), 3);
        let ea: Vec<Example> = (&mut a).collect();
        let eb: Vec<Example> = (&mut b).collect();
        assert_eq!(ea, eb);
        assert_eq!(a.rows_yielded(), b.rows_yielded());
        assert_eq!(a.rows_skipped(), b.rows_skipped());
        assert_eq!(a.rows_yielded(), 5);
        assert_eq!(a.rows_skipped(), 2);
    }

    #[test]
    fn file_stream_counts_progress() {
        let text = "# header\n+1 1:0.5\n\n-1 2:2.0\n";
        let mut fs = FileStream::from_reader(text.as_bytes(), 2);
        assert_eq!(fs.rows_yielded(), 0);
        assert!(fs.next().is_some());
        assert_eq!(fs.rows_yielded(), 1);
        assert!(fs.next().is_some());
        assert!(fs.next().is_none());
        assert_eq!(fs.rows_yielded(), 2);
        assert_eq!(fs.rows_skipped(), 0, "comments/blanks are not skipped rows");
    }
}
