//! Stream sources: in-memory (with deterministic permutation), lazy
//! LIBSVM file streaming, and rate metering hooks.

use std::io::{BufRead, BufReader};
use std::path::Path;

use crate::data::{Dataset, Example};
use crate::error::Result;
use crate::rng::Pcg32;

/// An owned in-memory stream, optionally order-permuted (the paper
/// averages every experiment over random stream orders).
pub struct VecStream {
    examples: Vec<Example>,
    order: Vec<usize>,
    pos: usize,
}

impl VecStream {
    /// Stream in stored order.
    pub fn new(examples: Vec<Example>) -> Self {
        let order = (0..examples.len()).collect();
        VecStream { examples, order, pos: 0 }
    }

    /// Stream in a seeded random permutation of the stored order.
    pub fn permuted(examples: Vec<Example>, seed: u64) -> Self {
        let order = Pcg32::new(seed, 0x0DE8).permutation(examples.len());
        VecStream { examples, order, pos: 0 }
    }

    /// Borrowing constructor over a dataset's training split.
    pub fn of_train(ds: &Dataset, perm_seed: Option<u64>) -> Self {
        match perm_seed {
            Some(s) => Self::permuted(ds.train.clone(), s),
            None => Self::new(ds.train.clone()),
        }
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }
}

impl Iterator for VecStream {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        let i = *self.order.get(self.pos)?;
        self.pos += 1;
        Some(self.examples[i].clone())
    }
}

/// Lazy one-pass LIBSVM file stream — the genuinely disk-resident case
/// from the paper's motivation (§1). Lines parse on demand as *sparse*
/// examples (the file is never materialized or densified), so the
/// downstream update cost is O(nnz) per row. Dimension must be known up
/// front (`dim`). This reader is tolerant: out-of-range indices are
/// dropped and rows with non-finite labels/values are skipped whole —
/// one poisoned row must not truncate the rest of a long stream (the
/// strict loaders in [`crate::data::libsvm_format`] reject instead).
pub struct FileStream<R: std::io::Read> {
    reader: BufReader<R>,
    dim: usize,
    line: String,
    lineno: usize,
}

impl FileStream<std::fs::File> {
    pub fn open(path: &Path, dim: usize) -> Result<Self> {
        Ok(FileStream {
            reader: BufReader::new(std::fs::File::open(path)?),
            dim,
            line: String::new(),
            lineno: 0,
        })
    }
}

impl<R: std::io::Read> FileStream<R> {
    pub fn from_reader(r: R, dim: usize) -> Self {
        FileStream { reader: BufReader::new(r), dim, line: String::new(), lineno: 0 }
    }
}

impl<R: std::io::Read> Iterator for FileStream<R> {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        loop {
            self.line.clear();
            self.lineno += 1;
            if self.reader.read_line(&mut self.line).ok()? == 0 {
                return None;
            }
            let t = self.line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let mut it = t.split_whitespace();
            let label: f64 = it.next()?.parse().ok()?;
            if !label.is_finite() {
                continue; // skip the poisoned row, keep streaming
            }
            let mut pairs: Vec<(u32, f32)> = Vec::new();
            let mut poisoned = false;
            for tok in it {
                let (i, v) = tok.split_once(':')?;
                let idx: usize = i.parse().ok()?;
                if idx == 0 || idx > self.dim {
                    continue;
                }
                let val: f32 = v.parse().ok()?;
                if !val.is_finite() {
                    poisoned = true;
                    break;
                }
                pairs.push((idx as u32 - 1, val));
            }
            if poisoned {
                continue; // skip the poisoned row, keep streaming
            }
            pairs.sort_unstable_by_key(|&(i, _)| i);
            pairs.dedup_by_key(|&mut (i, _)| i);
            let (idx, val): (Vec<u32>, Vec<f32>) = pairs.into_iter().unzip();
            return Some(Example::sparse(
                self.dim,
                idx,
                val,
                if label > 0.0 { 1.0 } else { -1.0 },
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exs(n: usize) -> Vec<Example> {
        (0..n)
            .map(|i| Example::new(vec![i as f32], if i % 2 == 0 { 1.0 } else { -1.0 }))
            .collect()
    }

    #[test]
    fn vec_stream_preserves_order() {
        let got: Vec<f32> = VecStream::new(exs(5)).map(|e| e.x[0]).collect();
        assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn permuted_stream_is_permutation() {
        let mut got: Vec<f32> = VecStream::permuted(exs(50), 3).map(|e| e.x[0]).collect();
        assert_ne!(got, (0..50).map(|i| i as f32).collect::<Vec<_>>());
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, (0..50).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn permutation_deterministic_per_seed() {
        let a: Vec<f32> = VecStream::permuted(exs(20), 7).map(|e| e.x[0]).collect();
        let b: Vec<f32> = VecStream::permuted(exs(20), 7).map(|e| e.x[0]).collect();
        let c: Vec<f32> = VecStream::permuted(exs(20), 8).map(|e| e.x[0]).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn file_stream_parses_lazily_as_sparse() {
        let text = "+1 1:0.5 3:1.5\n# comment\n-1 2:2.0\n";
        let got: Vec<Example> = FileStream::from_reader(text.as_bytes(), 3).collect();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].x.nnz(), 2);
        assert_eq!(got[0].x.dense().as_ref(), &[0.5, 0.0, 1.5]);
        assert_eq!(got[1].y, -1.0);
    }

    #[test]
    fn file_stream_ignores_out_of_range_indices() {
        let got: Vec<Example> = FileStream::from_reader("+1 99:1.0 1:2.0\n".as_bytes(), 2).collect();
        assert_eq!(got[0].x.dense().as_ref(), &[2.0, 0.0]);
    }

    #[test]
    fn file_stream_skips_non_finite_rows_without_truncating() {
        let text = "+1 1:nan\nnan 1:1\n+1 1:inf\n-1 1:1\n";
        let got: Vec<Example> = FileStream::from_reader(text.as_bytes(), 2).collect();
        assert_eq!(got.len(), 1, "good rows after a poisoned row must survive");
        assert_eq!(got[0].y, -1.0);
        assert_eq!(got[0].x.dense().as_ref(), &[1.0, 0.0]);
    }
}
