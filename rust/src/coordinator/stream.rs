//! Stream sources: in-memory (with deterministic permutation), lazy
//! LIBSVM file streaming, and rate metering hooks.

use std::io::{BufRead, BufReader};
use std::path::Path;

use crate::data::{Dataset, Example};
use crate::error::Result;
use crate::rng::Pcg32;

/// An owned in-memory stream, optionally order-permuted (the paper
/// averages every experiment over random stream orders).
pub struct VecStream {
    examples: Vec<Example>,
    order: Vec<usize>,
    pos: usize,
}

impl VecStream {
    /// Stream in stored order.
    pub fn new(examples: Vec<Example>) -> Self {
        let order = (0..examples.len()).collect();
        VecStream { examples, order, pos: 0 }
    }

    /// Stream in a seeded random permutation of the stored order.
    pub fn permuted(examples: Vec<Example>, seed: u64) -> Self {
        let order = Pcg32::new(seed, 0x0DE8).permutation(examples.len());
        VecStream { examples, order, pos: 0 }
    }

    /// Borrowing constructor over a dataset's training split.
    pub fn of_train(ds: &Dataset, perm_seed: Option<u64>) -> Self {
        match perm_seed {
            Some(s) => Self::permuted(ds.train.clone(), s),
            None => Self::new(ds.train.clone()),
        }
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }
}

impl Iterator for VecStream {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        let i = *self.order.get(self.pos)?;
        self.pos += 1;
        Some(self.examples[i].clone())
    }
}

/// Lazy one-pass LIBSVM file stream — the genuinely disk-resident case
/// from the paper's motivation (§1). Lines parse on demand as *sparse*
/// examples (the file is never materialized or densified), so the
/// downstream update cost is O(nnz) per row. Dimension must be known up
/// front (`dim`). This reader is tolerant: out-of-range indices are
/// dropped, and rows with non-finite labels/values *or malformed tokens*
/// (`qid:3` fields, garbage, unparsable numbers) are skipped whole and
/// counted in [`Self::rows_skipped`] — one bad row must never truncate
/// the rest of a long stream (the strict loaders in
/// [`crate::data::libsvm_format`] reject instead). Only EOF or an I/O
/// error ends the stream.
pub struct FileStream<R: std::io::Read> {
    reader: BufReader<R>,
    dim: usize,
    line: String,
    lineno: usize,
    yielded: usize,
    skipped: usize,
}

impl FileStream<std::fs::File> {
    pub fn open(path: &Path, dim: usize) -> Result<Self> {
        Ok(FileStream {
            reader: BufReader::new(std::fs::File::open(path)?),
            dim,
            line: String::new(),
            lineno: 0,
            yielded: 0,
            skipped: 0,
        })
    }
}

impl<R: std::io::Read> FileStream<R> {
    pub fn from_reader(r: R, dim: usize) -> Self {
        FileStream {
            reader: BufReader::new(r),
            dim,
            line: String::new(),
            lineno: 0,
            yielded: 0,
            skipped: 0,
        }
    }

    /// Examples yielded so far (the `serve --train-stream` progress
    /// counter behind `/stats`).
    pub fn rows_yielded(&self) -> usize {
        self.yielded
    }

    /// Rows skipped so far (non-finite labels/values, malformed tokens).
    pub fn rows_skipped(&self) -> usize {
        self.skipped
    }

    /// Parse one non-empty, non-comment line; `None` = skip this row
    /// (malformed or poisoned), never end the stream.
    fn parse_row(&self, t: &str) -> Option<Example> {
        let mut it = t.split_whitespace();
        let label: f64 = it.next()?.parse().ok()?;
        if !label.is_finite() {
            return None;
        }
        let mut pairs: Vec<(u32, f32)> = Vec::new();
        for tok in it {
            let (i, v) = tok.split_once(':')?;
            let idx: usize = i.parse().ok()?;
            if idx == 0 || idx > self.dim {
                continue;
            }
            let val: f32 = v.parse().ok()?;
            if !val.is_finite() {
                return None;
            }
            pairs.push((idx as u32 - 1, val));
        }
        pairs.sort_unstable_by_key(|&(i, _)| i);
        pairs.dedup_by_key(|&mut (i, _)| i);
        let (idx, val): (Vec<u32>, Vec<f32>) = pairs.into_iter().unzip();
        Some(Example::sparse(
            self.dim,
            idx,
            val,
            if label > 0.0 { 1.0 } else { -1.0 },
        ))
    }
}

impl<R: std::io::Read> Iterator for FileStream<R> {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        loop {
            self.line.clear();
            self.lineno += 1;
            if self.reader.read_line(&mut self.line).ok()? == 0 {
                return None;
            }
            let t = self.line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            // A malformed or poisoned row must not end the stream: with
            // `--train-stream` a `None` here would be reported as a
            // *completed* file while silently dropping every later row.
            match self.parse_row(t) {
                Some(e) => {
                    self.yielded += 1;
                    return Some(e);
                }
                None => {
                    self.skipped += 1;
                    continue;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exs(n: usize) -> Vec<Example> {
        (0..n)
            .map(|i| Example::new(vec![i as f32], if i % 2 == 0 { 1.0 } else { -1.0 }))
            .collect()
    }

    #[test]
    fn vec_stream_preserves_order() {
        let got: Vec<f32> = VecStream::new(exs(5)).map(|e| e.x[0]).collect();
        assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn permuted_stream_is_permutation() {
        let mut got: Vec<f32> = VecStream::permuted(exs(50), 3).map(|e| e.x[0]).collect();
        assert_ne!(got, (0..50).map(|i| i as f32).collect::<Vec<_>>());
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, (0..50).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn permutation_deterministic_per_seed() {
        let a: Vec<f32> = VecStream::permuted(exs(20), 7).map(|e| e.x[0]).collect();
        let b: Vec<f32> = VecStream::permuted(exs(20), 7).map(|e| e.x[0]).collect();
        let c: Vec<f32> = VecStream::permuted(exs(20), 8).map(|e| e.x[0]).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn file_stream_parses_lazily_as_sparse() {
        let text = "+1 1:0.5 3:1.5\n# comment\n-1 2:2.0\n";
        let got: Vec<Example> = FileStream::from_reader(text.as_bytes(), 3).collect();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].x.nnz(), 2);
        assert_eq!(got[0].x.dense().as_ref(), &[0.5, 0.0, 1.5]);
        assert_eq!(got[1].y, -1.0);
    }

    #[test]
    fn file_stream_ignores_out_of_range_indices() {
        let got: Vec<Example> = FileStream::from_reader("+1 99:1.0 1:2.0\n".as_bytes(), 2).collect();
        assert_eq!(got[0].x.dense().as_ref(), &[2.0, 0.0]);
    }

    #[test]
    fn file_stream_skips_non_finite_rows_without_truncating() {
        let text = "+1 1:nan\nnan 1:1\n+1 1:inf\n-1 1:1\n";
        let mut fs = FileStream::from_reader(text.as_bytes(), 2);
        let got: Vec<Example> = (&mut fs).collect();
        assert_eq!(got.len(), 1, "good rows after a poisoned row must survive");
        assert_eq!(got[0].y, -1.0);
        assert_eq!(got[0].x.dense().as_ref(), &[1.0, 0.0]);
        assert_eq!(fs.rows_yielded(), 1);
        assert_eq!(fs.rows_skipped(), 3);
    }

    #[test]
    fn file_stream_skips_malformed_rows_without_truncating() {
        // qid fields, garbage labels, unparsable values: each bad row is
        // skipped and counted; rows after it must still stream (before
        // this guard, the first malformed token silently ended the
        // iterator — fatal for `serve --train-stream`, which would then
        // report the file as fully consumed).
        let text = "+1 qid:3 1:0.5\nnot-a-label 1:1\n+1 1:bad\n+1 1:0.5\n-1 2:2.0\n";
        let mut fs = FileStream::from_reader(text.as_bytes(), 2);
        let got: Vec<Example> = (&mut fs).collect();
        assert_eq!(got.len(), 2, "good rows after malformed rows must survive");
        assert_eq!(got[0].x.dense().as_ref(), &[0.5, 0.0]);
        assert_eq!(got[1].y, -1.0);
        assert_eq!(fs.rows_yielded(), 2);
        assert_eq!(fs.rows_skipped(), 3);
    }

    #[test]
    fn file_stream_counts_progress() {
        let text = "# header\n+1 1:0.5\n\n-1 2:2.0\n";
        let mut fs = FileStream::from_reader(text.as_bytes(), 2);
        assert_eq!(fs.rows_yielded(), 0);
        assert!(fs.next().is_some());
        assert_eq!(fs.rows_yielded(), 1);
        assert!(fs.next().is_some());
        assert!(fs.next().is_none());
        assert_eq!(fs.rows_yielded(), 2);
        assert_eq!(fs.rows_skipped(), 0, "comments/blanks are not skipped rows");
    }
}
