//! Layer-3 streaming coordinator.
//!
//! The paper's system is a *one-pass streaming learner*, so the
//! coordinator is a streaming orchestrator:
//!
//! ```text
//!   reader thread                     trainer thread (owns PJRT)
//!   ┌───────────┐   bounded channel   ┌──────────────────────────────┐
//!   │ source →  │ ──── Blocks ──────▶ │ block filter (L1 distance    │
//!   │ batcher   │   (backpressure)    │ kernel, 1 PJRT call/block) → │
//!   └───────────┘                     │ sequential updater (rare)    │
//!                                     └──────────────────────────────┘
//! ```
//!
//! The block filter is **exact**: every Algorithm-1 update grows the ball
//! (old ball ⊆ new ball — property-tested in `svm::ball`), so a point
//! inside the ball at block entry can never escape later; points outside
//! are re-checked sequentially against the live ball. Discard decisions
//! batch into one MXU-friendly PJRT call while update semantics stay
//! bit-equivalent to the paper's sequential algorithm.

pub mod batcher;
pub mod metrics;
pub mod parallel;
pub mod pipeline;
pub mod service;
pub mod sharded;
pub mod stream;
