//! Artifact manifest parsing, shared by the PJRT executor and the
//! feature-off stub runtime so both agree on `manifest.txt` semantics.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Key into the artifact manifest: `(entry, block, dim)`.
#[derive(Clone, Debug, Hash, PartialEq, Eq)]
pub struct Key {
    pub entry: String,
    pub b: usize,
    pub d: usize,
}

/// Read and parse `<dir>/manifest.txt` (`entry b d file` per line).
pub fn parse(dir: &Path) -> Result<HashMap<Key, PathBuf>> {
    let manifest_path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
        Error::artifact(format!(
            "cannot read {} — run `make artifacts` first ({e})",
            manifest_path.display()
        ))
    })?;
    let mut manifest = HashMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 4 {
            return Err(Error::artifact(format!(
                "manifest line {}: expected `entry b d file`, got `{line}`",
                lineno + 1
            )));
        }
        let key = Key {
            entry: parts[0].to_string(),
            b: parts[1].parse().map_err(|e| Error::artifact(format!("bad b: {e}")))?,
            d: parts[2].parse().map_err(|e| Error::artifact(format!("bad d: {e}")))?,
        };
        manifest.insert(key, dir.join(parts[3]));
    }
    Ok(manifest)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_missing_dir_is_artifact_error() {
        let err = parse(Path::new("/nonexistent/artifacts")).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)));
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn parse_rejects_malformed() {
        let dir = std::env::temp_dir().join(format!("ssvm_manifest_mod_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "distance 256\n").unwrap();
        let err = parse(&dir).unwrap_err();
        assert!(err.to_string().contains("expected `entry b d file`"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parse_accepts_well_formed() {
        let dir = std::env::temp_dir().join(format!("ssvm_manifest_ok_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "distance 256 21 d.hlo.txt\n\nupdate 256 21 u.hlo.txt\n",
        )
        .unwrap();
        let m = parse(&dir).unwrap();
        assert_eq!(m.len(), 2);
        let k = Key { entry: "distance".into(), b: 256, d: 21 };
        assert_eq!(m[&k], dir.join("d.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
