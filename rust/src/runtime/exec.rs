//! The executor: manifest parsing, compile cache, typed entry points.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::runtime::manifest::{self, Key};

/// Outputs of the `update` entry point (Algorithm-1 semantics over one
/// block).
#[derive(Clone, Debug)]
pub struct UpdateOut {
    pub w: Vec<f32>,
    pub r: f64,
    pub xi2: f64,
    /// Updates applied within the block.
    pub m_added: usize,
    /// Per-row update mask.
    pub upd_mask: Vec<f32>,
    /// Per-row distance to the *entry* ball (the L1 kernel's output).
    pub d0: Vec<f32>,
}

/// Outputs of the `merge` entry point (Algorithm-2 lookahead merge).
#[derive(Clone, Debug)]
pub struct MergeOut {
    pub w: Vec<f32>,
    pub r: f64,
    pub xi2: f64,
    pub mu: Vec<f32>,
}


/// Build a `(rows, cols)` f32 literal from a row-major slice with a single
/// host copy (`vec1().reshape()` copies twice — measurable at 1 MB/block
/// on the training hot path).
fn matrix_literal(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &[rows, cols],
        bytes,
    )
    .map_err(Into::into)
}

/// PJRT runtime with artifact registry and compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<Key, PathBuf>,
    cache: HashMap<Key, xla::PjRtLoadedExecutable>,
    /// Prefer the CPU-optimized native-jnp artifact variants (`*f`) when
    /// the manifest carries them. The Pallas-kernel artifacts stay
    /// available for the TPU-structured path and the backend ablation.
    prefer_fast: bool,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.txt`; artifacts
    /// compile lazily on first use).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = manifest::parse(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: HashMap::new(),
            prefer_fast: true,
        })
    }

    /// Open the default artifact directory.
    pub fn open_default() -> Result<Self> {
        Self::open(&super::default_artifact_dir())
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// All `(entry, b, d)` triples in the manifest.
    pub fn available(&self) -> Vec<(String, usize, usize)> {
        let mut v: Vec<_> = self.manifest.keys().map(|k| (k.entry.clone(), k.b, k.d)).collect();
        v.sort();
        v
    }

    /// Does the manifest have this bucket?
    pub fn has(&self, entry: &str, b: usize, d: usize) -> bool {
        self.manifest.contains_key(&Key { entry: entry.into(), b, d })
    }

    /// The default training block size compiled for dimension `d` (the
    /// batcher asks this before shaping blocks). Returns the *smallest*
    /// compiled bucket: small blocks keep the filter radius fresh on
    /// short streams; the larger buckets are reachable via
    /// [`Self::train_blocks`] for the amortization ablation.
    pub fn train_block(&self, d: usize) -> Option<usize> {
        self.train_blocks(d).first().copied()
    }

    /// All compiled training block sizes for dimension `d`, ascending.
    pub fn train_blocks(&self, d: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .manifest
            .keys()
            .filter(|k| k.entry == "update" && k.d == d)
            .map(|k| k.b)
            .collect();
        v.sort_unstable();
        v
    }

    /// Toggle backend kernel selection (see `prefer_fast`); returns the
    /// previous value. Used by the throughput ablation.
    pub fn set_prefer_fast(&mut self, on: bool) -> bool {
        std::mem::replace(&mut self.prefer_fast, on)
    }

    /// Resolve `entry` to the backend-preferred variant present in the
    /// manifest (`<entry>f` when prefer_fast and compiled, else `entry`).
    fn resolve_entry(&self, entry: &str, b: usize, d: usize) -> String {
        if self.prefer_fast {
            let fast = format!("{entry}f");
            if self.manifest.contains_key(&Key { entry: fast.clone(), b, d }) {
                return fast;
            }
        }
        entry.to_string()
    }

    fn exe(&mut self, entry: &str, b: usize, d: usize) -> Result<&xla::PjRtLoadedExecutable> {
        let entry = self.resolve_entry(entry, b, d);
        let key = Key { entry, b, d };
        if !self.cache.contains_key(&key) {
            let path = self.manifest.get(&key).ok_or_else(|| {
                Error::artifact(format!(
                    "no artifact for {} b={b} d={d}; run `make artifacts` \
                     with --dims covering this dataset",
                    key.entry
                ))
            })?;
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }

    /// Pre-compile a set of entries (pipeline warmup; keeps first-block
    /// latency out of the steady-state measurements).
    pub fn warmup(&mut self, entries: &[(&str, usize, usize)]) -> Result<()> {
        for &(e, b, d) in entries {
            self.exe(e, b, d)?;
        }
        Ok(())
    }

    /// `distance` entry: d_b for a padded block.
    ///
    /// `x` is row-major `(b, d)`, `w`/`y` match the bucket; returns `d[b]`.
    pub fn distance(
        &mut self,
        w: &[f32],
        x: &[f32],
        y: &[f32],
        xi2: f32,
        invc: f32,
        b: usize,
        d: usize,
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(x.len(), b * d);
        debug_assert_eq!(w.len(), d);
        debug_assert_eq!(y.len(), b);
        let exe = self.exe("distance", b, d)?;
        let lw = xla::Literal::vec1(w);
        let lx = matrix_literal(x, b, d)?;
        let ly = xla::Literal::vec1(y);
        let lxi = xla::Literal::from(xi2);
        let lc = xla::Literal::from(invc);
        let res = exe.execute::<xla::Literal>(&[lw, lx, ly, lxi, lc])?[0][0]
            .to_literal_sync()?;
        let mut parts = res.to_tuple()?;
        parts.remove(0).to_vec::<f32>().map_err(Into::into)
    }

    /// `predict` entry: raw margins for a padded block.
    pub fn predict(&mut self, w: &[f32], x: &[f32], b: usize, d: usize) -> Result<Vec<f32>> {
        debug_assert_eq!(x.len(), b * d);
        let exe = self.exe("predict", b, d)?;
        let lw = xla::Literal::vec1(w);
        let lx = matrix_literal(x, b, d)?;
        let res = exe.execute::<xla::Literal>(&[lw, lx])?[0][0].to_literal_sync()?;
        let mut parts = res.to_tuple()?;
        parts.remove(0).to_vec::<f32>().map_err(Into::into)
    }

    /// `update` entry: Algorithm-1 scan over a padded block.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        w: &[f32],
        r: f32,
        xi2: f32,
        x: &[f32],
        y: &[f32],
        valid: &[f32],
        invc: f32,
        s2: f32,
        b: usize,
        d: usize,
    ) -> Result<UpdateOut> {
        debug_assert_eq!(x.len(), b * d);
        let exe = self.exe("update", b, d)?;
        let args = [
            xla::Literal::vec1(w),
            xla::Literal::from(r),
            xla::Literal::from(xi2),
            matrix_literal(x, b, d)?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(valid),
            xla::Literal::from(invc),
            xla::Literal::from(s2),
        ];
        let res = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = res.to_tuple()?;
        let [w1, r1, xi1, m, upd, d0]: [xla::Literal; 6] = parts
            .try_into()
            .map_err(|_| Error::artifact("update: expected 6 outputs"))?;
        Ok(UpdateOut {
            w: w1.to_vec::<f32>()?,
            r: r1.get_first_element::<f32>()? as f64,
            xi2: xi1.get_first_element::<f32>()? as f64,
            m_added: m.get_first_element::<f32>()? as usize,
            upd_mask: upd.to_vec::<f32>()?,
            d0: d0.to_vec::<f32>()?,
        })
    }

    /// `merge` entry: Algorithm-2 lookahead merge over a padded buffer.
    ///
    /// No `invc` argument: the consistent slack convention folds 1/C into
    /// `s2`, and the AOT graph has no (dead) invc parameter.
    #[allow(clippy::too_many_arguments)]
    pub fn merge(
        &mut self,
        w: &[f32],
        r: f32,
        xi2: f32,
        xs: &[f32],
        ys: &[f32],
        valid: &[f32],
        s2: f32,
        l: usize,
        d: usize,
    ) -> Result<MergeOut> {
        debug_assert_eq!(xs.len(), l * d);
        let exe = self.exe("merge", l, d)?;
        let args = [
            xla::Literal::vec1(w),
            xla::Literal::from(r),
            xla::Literal::from(xi2),
            matrix_literal(xs, l, d)?,
            xla::Literal::vec1(ys),
            xla::Literal::vec1(valid),
            xla::Literal::from(s2),
        ];
        let res = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = res.to_tuple()?;
        let [w1, r1, xi1, mu]: [xla::Literal; 4] = parts
            .try_into()
            .map_err(|_| Error::artifact("merge: expected 4 outputs"))?;
        Ok(MergeOut {
            w: w1.to_vec::<f32>()?,
            r: r1.get_first_element::<f32>()? as f64,
            xi2: xi1.get_first_element::<f32>()? as f64,
            mu: mu.to_vec::<f32>()?,
        })
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("artifacts", &self.manifest.len())
            .field("compiled", &self.cache.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_dir_is_artifact_error() {
        let err = Runtime::open(Path::new("/nonexistent/artifacts")).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)));
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn manifest_parse_rejects_malformed() {
        let dir = std::env::temp_dir().join(format!("ssvm_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "distance 256\n").unwrap();
        let err = Runtime::open(&dir).unwrap_err();
        assert!(err.to_string().contains("expected `entry b d file`"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
