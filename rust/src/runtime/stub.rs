//! Stub runtime compiled when the `pjrt` feature is off (the offline
//! default — the `xla` crate is unavailable in the image).
//!
//! It mirrors the executor's public API exactly: manifests parse with
//! identical semantics and errors, bucket queries (`has`, `train_block`,
//! `available`) answer from the manifest, but every execute entry point
//! returns an artifact error. Callers already handle execute-time
//! artifact failures (corrupt HLO, missing bucket) by falling back to
//! the pure-Rust paths, so a feature-off build degrades exactly like a
//! build whose artifacts are absent or broken.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::runtime::manifest::{self, Key};

/// Outputs of the `update` entry point (Algorithm-1 semantics over one
/// block).
#[derive(Clone, Debug)]
pub struct UpdateOut {
    pub w: Vec<f32>,
    pub r: f64,
    pub xi2: f64,
    /// Updates applied within the block.
    pub m_added: usize,
    /// Per-row update mask.
    pub upd_mask: Vec<f32>,
    /// Per-row distance to the *entry* ball (the L1 kernel's output).
    pub d0: Vec<f32>,
}

/// Outputs of the `merge` entry point (Algorithm-2 lookahead merge).
#[derive(Clone, Debug)]
pub struct MergeOut {
    pub w: Vec<f32>,
    pub r: f64,
    pub xi2: f64,
    pub mu: Vec<f32>,
}

/// Manifest-only runtime: resolves buckets, never executes.
pub struct Runtime {
    dir: PathBuf,
    manifest: HashMap<Key, PathBuf>,
    prefer_fast: bool,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.txt`).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = manifest::parse(dir)?;
        Ok(Runtime { dir: dir.to_path_buf(), manifest, prefer_fast: true })
    }

    /// Open the default artifact directory.
    pub fn open_default() -> Result<Self> {
        Self::open(&super::default_artifact_dir())
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// All `(entry, b, d)` triples in the manifest.
    pub fn available(&self) -> Vec<(String, usize, usize)> {
        let mut v: Vec<_> = self.manifest.keys().map(|k| (k.entry.clone(), k.b, k.d)).collect();
        v.sort();
        v
    }

    /// Does the manifest have this bucket?
    pub fn has(&self, entry: &str, b: usize, d: usize) -> bool {
        self.manifest.contains_key(&Key { entry: entry.into(), b, d })
    }

    /// The default training block size compiled for dimension `d`
    /// (smallest compiled bucket, matching the executor's choice).
    pub fn train_block(&self, d: usize) -> Option<usize> {
        self.train_blocks(d).first().copied()
    }

    /// All compiled training block sizes for dimension `d`, ascending.
    pub fn train_blocks(&self, d: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .manifest
            .keys()
            .filter(|k| k.entry == "update" && k.d == d)
            .map(|k| k.b)
            .collect();
        v.sort_unstable();
        v
    }

    /// Toggle backend kernel selection; returns the previous value.
    pub fn set_prefer_fast(&mut self, on: bool) -> bool {
        std::mem::replace(&mut self.prefer_fast, on)
    }

    fn resolve_entry(&self, entry: &str, b: usize, d: usize) -> String {
        if self.prefer_fast {
            let fast = format!("{entry}f");
            if self.manifest.contains_key(&Key { entry: fast.clone(), b, d }) {
                return fast;
            }
        }
        entry.to_string()
    }

    /// Execute-time error for `entry`: missing bucket reports the same
    /// message as the executor; a present bucket reports the missing
    /// `pjrt` feature.
    fn exec_err(&self, entry: &str, b: usize, d: usize) -> Error {
        let entry = self.resolve_entry(entry, b, d);
        if self.manifest.contains_key(&Key { entry: entry.clone(), b, d }) {
            Error::artifact(format!(
                "artifact {entry} b={b} d={d} exists but this build lacks the \
                 `pjrt` feature; rebuild with `--features pjrt` (see Cargo.toml)"
            ))
        } else {
            Error::artifact(format!(
                "no artifact for {entry} b={b} d={d}; run `make artifacts` \
                 with --dims covering this dataset"
            ))
        }
    }

    /// Pre-compile a set of entries — always fails in the stub.
    pub fn warmup(&mut self, entries: &[(&str, usize, usize)]) -> Result<()> {
        match entries.first() {
            Some(&(e, b, d)) => Err(self.exec_err(e, b, d)),
            None => Ok(()),
        }
    }

    /// `distance` entry — always fails in the stub.
    pub fn distance(
        &mut self,
        _w: &[f32],
        _x: &[f32],
        _y: &[f32],
        _xi2: f32,
        _invc: f32,
        b: usize,
        d: usize,
    ) -> Result<Vec<f32>> {
        Err(self.exec_err("distance", b, d))
    }

    /// `predict` entry — always fails in the stub.
    pub fn predict(&mut self, _w: &[f32], _x: &[f32], b: usize, d: usize) -> Result<Vec<f32>> {
        Err(self.exec_err("predict", b, d))
    }

    /// `update` entry — always fails in the stub.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        _w: &[f32],
        _r: f32,
        _xi2: f32,
        _x: &[f32],
        _y: &[f32],
        _valid: &[f32],
        _invc: f32,
        _s2: f32,
        b: usize,
        d: usize,
    ) -> Result<UpdateOut> {
        Err(self.exec_err("update", b, d))
    }

    /// `merge` entry — always fails in the stub.
    #[allow(clippy::too_many_arguments)]
    pub fn merge(
        &mut self,
        _w: &[f32],
        _r: f32,
        _xi2: f32,
        _xs: &[f32],
        _ys: &[f32],
        _valid: &[f32],
        _s2: f32,
        l: usize,
        d: usize,
    ) -> Result<MergeOut> {
        Err(self.exec_err("merge", l, d))
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("artifacts", &self.manifest.len())
            .field("pjrt", &false)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_manifest(lines: &str, tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ssvm_stub_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), lines).unwrap();
        dir
    }

    #[test]
    fn open_missing_dir_is_artifact_error() {
        let err = Runtime::open(Path::new("/nonexistent/artifacts")).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)));
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn bucket_queries_answer_from_manifest() {
        let dir = tmp_manifest("update 64 21 u.hlo.txt\nupdate 256 21 u2.hlo.txt\n", "q");
        let rt = Runtime::open(&dir).unwrap();
        assert!(rt.has("update", 64, 21));
        assert!(!rt.has("update", 64, 22));
        assert_eq!(rt.train_block(21), Some(64));
        assert_eq!(rt.train_blocks(21), vec![64, 256]);
        assert_eq!(rt.available().len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn execute_reports_missing_bucket_or_feature() {
        let dir = tmp_manifest("distance 64 4 d.hlo.txt\n", "x");
        let mut rt = Runtime::open(&dir).unwrap();
        // present bucket: feature error
        let e = rt.distance(&[0.0; 4], &[0.0; 256], &[1.0; 64], 1.0, 1.0, 64, 4).unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
        // absent bucket: the executor's missing-artifact message
        let e = rt.predict(&[0.0; 4], &[0.0; 256], 64, 4).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("predict") && msg.contains("make artifacts"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
