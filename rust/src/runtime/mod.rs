//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! One [`Runtime`] owns the PJRT CPU client, the parsed artifact
//! manifest, and a compile cache (each artifact compiles once, on first
//! use). The typed wrappers ([`Runtime::distance`], [`Runtime::update`],
//! [`Runtime::predict`], [`Runtime::merge`]) mirror the four AOT entry
//! points; shapes must match the compiled (B, D) bucket exactly — the
//! coordinator's batcher owns padding (see `coordinator::batcher`).

//! Feature gating: the real executor needs the `xla` crate, which the
//! offline image does not carry. Without the `pjrt` feature a stub
//! runtime with the identical API parses manifests but fails at execute
//! time, and every caller falls back to the pure-Rust paths.

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod exec;
#[cfg(feature = "pjrt")]
pub use exec::{MergeOut, Runtime, UpdateOut};

#[cfg(not(feature = "pjrt"))]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{MergeOut, Runtime, UpdateOut};

/// Feature-dim padding rule — must mirror `aot.pad_dim` on the Python
/// side: exact below 128, then the next multiple of 128.
pub fn pad_dim(d: usize) -> usize {
    if d <= 128 {
        d
    } else {
        d.div_ceil(128) * 128
    }
}

/// Default artifact directory, overridable with `STREAMSVM_ARTIFACTS`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var_os("STREAMSVM_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_dim_mirrors_python() {
        assert_eq!(pad_dim(2), 2);
        assert_eq!(pad_dim(128), 128);
        assert_eq!(pad_dim(129), 256);
        assert_eq!(pad_dim(300), 384);
        assert_eq!(pad_dim(784), 896);
    }
}
