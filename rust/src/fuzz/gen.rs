//! Structure-aware case generators: grammar-driven *valid* seeds for
//! each fuzz target, so coverage reaches past the first reject.
//!
//! A purely random byte string dies at `read_line` / `MAGIC` / the
//! first `{`; these generators produce well-formed HTTP messages, JSON
//! documents and `.meb` sketch frames (every codec version), which the
//! mutator then corrupts. The `.meb` seeds include one trained v4
//! sketch per variant — each exercises its own exact-state section —
//! plus hand-assembled v1/v2/v3 legacy frames, mirroring what the codec
//! corruption suite (PR 9) used before it migrated into this harness.

use std::sync::OnceLock;

use crate::data::FeaturesView;
use crate::rng::Pcg32;
use crate::sketch::codec::{fnv1a64, MebSketch, CHECKSUM_LEN, HEADER_LEN};
use crate::svm::learner::{AnyLearner, Variant};
use crate::svm::TrainOptions;

/// A grammar-valid HTTP/1.1 message: mostly requests against the
/// serving endpoints (correct `Content-Length`, occasional duplicates —
/// same and conflicting — `Expect: 100-continue`, traceparent headers),
/// sometimes a response, so both parser halves see structured input.
pub fn http_message(rng: &mut Pcg32) -> Vec<u8> {
    let mut out = Vec::with_capacity(256);
    let body = http_body(rng);
    if rng.below(4) == 0 {
        // response shape
        let status = [200u16, 204, 400, 404, 429, 500][rng.below(6)];
        out.extend_from_slice(format!("HTTP/1.1 {status} X\r\n").as_bytes());
    } else {
        let method = ["GET", "POST", "PUT", "DELETE", "HEAD", "PATCH"][rng.below(6)];
        let path = [
            "/predict",
            "/predict_batch",
            "/train",
            "/stats",
            "/metrics",
            "/snapshot",
            "/trace",
            "/debug/trace/4bf92f3577b34da6a3ce929d0e0e4736",
            "/a/b%20c?x=1&y=2",
        ][rng.below(9)];
        out.extend_from_slice(format!("{method} {path} HTTP/1.1\r\n").as_bytes());
    }
    out.extend_from_slice(b"Host: 127.0.0.1:7878\r\n");
    if rng.below(3) == 0 {
        out.extend_from_slice(b"Content-Type: application/json\r\n");
    }
    if rng.below(4) == 0 {
        out.extend_from_slice(
            b"traceparent: 00-4bf92f3577b34da6a3ce929d0e0e4736-00f067aa0ba902b7-01\r\n",
        );
    }
    if rng.below(6) == 0 {
        out.extend_from_slice(b"Expect: 100-continue\r\n");
    }
    if rng.below(5) == 0 {
        out.extend_from_slice(format!("X-Junk: {}\r\n", rng.next_u32()).as_bytes());
    }
    // content-length: usually correct, sometimes wrong, sometimes
    // duplicated (same value, or the conflicting request-smuggling shape)
    let declared = match rng.below(8) {
        0 => body.len() + 1 + rng.below(64),
        _ => body.len(),
    };
    out.extend_from_slice(format!("Content-Length: {declared}\r\n").as_bytes());
    match rng.below(6) {
        0 => out.extend_from_slice(format!("content-length: {declared}\r\n").as_bytes()),
        1 => out
            .extend_from_slice(format!("Content-Length: {}\r\n", declared + 1).as_bytes()),
        _ => {}
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(&body);
    out
}

fn http_body(rng: &mut Pcg32) -> Vec<u8> {
    match rng.below(4) {
        0 => Vec::new(),
        1 => json_doc(rng),
        2 => (0..rng.below(64)).map(|_| rng.next_u32() as u8).collect(),
        _ => br#"{"x":[0.5,-1.2]}"#.to_vec(),
    }
}

/// A grammar-valid JSON document (objects, arrays, strings with escapes,
/// numbers including the overflow-exponent forms the parser must reject
/// gracefully, literals), with occasional pathological nesting that
/// crosses the parser's depth cap.
pub fn json_doc(rng: &mut Pcg32) -> Vec<u8> {
    let mut s = String::with_capacity(128);
    if rng.below(12) == 0 {
        // deep nesting: crosses MAX_DEPTH, must error (never overflow)
        let depth = 40 + rng.below(80);
        s.push_str(&"[".repeat(depth));
        s.push('1');
        s.push_str(&"]".repeat(depth));
    } else {
        json_value(rng, 0, &mut s);
    }
    s.into_bytes()
}

fn json_value(rng: &mut Pcg32, depth: usize, out: &mut String) {
    let pick = if depth >= 5 { rng.below(4) } else { rng.below(6) };
    match pick {
        0 => out.push_str(["null", "true", "false"][rng.below(3)]),
        1 => out.push_str(&json_number(rng)),
        2 | 3 => {
            out.push('"');
            out.push_str(&json_string_body(rng));
            out.push('"');
        }
        4 => {
            out.push('[');
            let n = rng.below(5);
            for i in 0..n {
                if i > 0 {
                    out.push(',');
                }
                json_value(rng, depth + 1, out);
            }
            out.push(']');
        }
        _ => {
            out.push('{');
            let n = rng.below(4);
            for i in 0..n {
                if i > 0 {
                    out.push(',');
                }
                out.push('"');
                out.push_str(&json_string_body(rng));
                out.push_str("\":");
                json_value(rng, depth + 1, out);
            }
            out.push('}');
        }
    }
}

fn json_number(rng: &mut Pcg32) -> String {
    match rng.below(6) {
        0 => format!("{}", rng.next_u32() as i64 - (u32::MAX / 2) as i64),
        1 => crate::server::json::fmt_num(rng.normal() * 100.0),
        2 => "0".into(),
        3 => ["3.5e-2", "2E4", "-0.0", "1e308", "123456789.125"][rng.below(5)].into(),
        // the overflow / boundary forms the satellite fix must reject
        // or normalize without panicking
        _ => ["1e999", "-1e999", "1e-999", "9e18", "-9007199254740993"][rng.below(5)].into(),
    }
}

fn json_string_body(rng: &mut Pcg32) -> String {
    let mut s = String::new();
    for _ in 0..rng.below(8) {
        match rng.below(8) {
            0 => s.push_str("\\n"),
            1 => s.push_str("\\\""),
            2 => s.push_str("\\\\"),
            3 => s.push_str("\\u00e9"),
            4 => s.push('é'),
            5 => s.push('字'),
            _ => s.push((b'a' + rng.below(26) as u8) as char),
        }
    }
    s
}

/// Frame a payload as sketch version `v` (the envelope every version
/// shares: magic, version, flags, length, payload, FNV-1a checksum).
pub fn frame_meb(version: u16, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + CHECKSUM_LEN);
    out.extend_from_slice(b"MEBS");
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&fnv1a64(payload).to_le_bytes());
    out
}

/// Hand-assemble a v1/v2/v3 payload (the legacy layouts `decode` still
/// reads; v2+ adds the factored center, v3 merges + hash provenance).
pub fn legacy_meb(version: u16) -> Vec<u8> {
    let w = [1.5f32, -2.0, 0.5];
    let mut p: Vec<u8> = Vec::new();
    p.extend_from_slice(&(2u32).to_le_bytes());
    p.extend_from_slice(b"vx");
    p.extend_from_slice(&1.0f64.to_bits().to_le_bytes()); // c
    p.push(1); // SlackMode::Consistent
    p.extend_from_slice(&1u64.to_le_bytes()); // lookahead
    p.extend_from_slice(&60u64.to_le_bytes()); // merge_iters
    if version >= 3 {
        p.extend_from_slice(&4u64.to_le_bytes()); // merges
        p.push(0); // no hash
    }
    p.extend_from_slice(&17u64.to_le_bytes()); // seen
    p.extend_from_slice(&(w.len() as u64).to_le_bytes()); // dim
    p.push(1); // has_ball
    p.extend_from_slice(&5u64.to_le_bytes()); // m
    p.extend_from_slice(&2.5f64.to_bits().to_le_bytes()); // r
    p.extend_from_slice(&0.25f64.to_bits().to_le_bytes()); // xi2
    if version >= 2 {
        p.extend_from_slice(&0.5f64.to_bits().to_le_bytes()); // sigma
        p.extend_from_slice(&1.5625f64.to_bits().to_le_bytes()); // wnorm2
    }
    for &v in &w {
        p.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    frame_meb(version, &p)
}

/// The valid `.meb` seed pool: one trained v4 sketch per variant (each
/// exercises its own exact-state section) plus the three legacy
/// layouts. Built once — training is deterministic, so the pool is
/// identical across runs.
pub fn meb_bases() -> &'static [Vec<u8>] {
    static BASES: OnceLock<Vec<Vec<u8>>> = OnceLock::new();
    BASES.get_or_init(|| {
        let mut rng = Pcg32::seeded(0xC0_22);
        let d = 4;
        let mut bases: Vec<Vec<u8>> = Variant::ALL
            .into_iter()
            .map(|variant| {
                let mut m = AnyLearner::new(variant, d, TrainOptions::default());
                for _ in 0..60 {
                    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                    let y = if x[0] + x[1] >= 0.0 { 1.0 } else { -1.0 };
                    m.observe_view(FeaturesView::Dense(&x), y);
                }
                m.finish();
                MebSketch::from_learner(&m, variant.name()).encode()
            })
            .collect();
        bases.extend([legacy_meb(1), legacy_meb(2), legacy_meb(3)]);
        bases
    })
}

/// One valid `.meb` frame drawn from the seed pool.
pub fn meb_frame(rng: &mut Pcg32) -> Vec<u8> {
    let bases = meb_bases();
    bases[rng.below(bases.len())].clone()
}

/// Recompute the FNV-1a checksum over the (possibly corrupted) payload
/// so the mutation survives the integrity gate and `decode` reaches its
/// structural checks. Uses the buffer's *actual* geometry, not the
/// header's promise — a mutated length field keeps disagreeing, which
/// is the point of those mutations.
pub fn fix_meb_checksum(bytes: &mut [u8]) {
    if bytes.len() < HEADER_LEN + CHECKSUM_LEN {
        return;
    }
    let payload_end = bytes.len() - CHECKSUM_LEN;
    let sum = fnv1a64(&bytes[HEADER_LEN..payload_end]);
    bytes[payload_end..].copy_from_slice(&sum.to_le_bytes());
}

/// A raw entropy tape for the invariants target: decoded by
/// [`crate::fuzz::laws::stream_case_from_tape`] into a runnable stream,
/// so chunk-removal minimization maps to dropping examples.
pub fn invariants_tape(rng: &mut Pcg32) -> Vec<u8> {
    let n = 4 + rng.below(400);
    (0..n).map(|_| rng.next_u32() as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::json::Json;

    #[test]
    fn json_seeds_parse_or_reject_gracefully() {
        let mut rng = Pcg32::seeded(11);
        for _ in 0..200 {
            let doc = json_doc(&mut rng);
            let s = String::from_utf8(doc).expect("generator emits UTF-8");
            // overflow numbers and deep nesting are rejected with an
            // error; everything else parses
            let _ = Json::parse(&s);
        }
    }

    #[test]
    fn meb_seed_pool_is_valid_and_stable() {
        let bases = meb_bases();
        assert_eq!(bases.len(), Variant::ALL.len() + 3);
        for (i, b) in bases.iter().enumerate() {
            assert!(MebSketch::decode(b).is_ok(), "base {i} must decode");
        }
        // deterministic across calls (OnceLock) and across processes
        // (seeded training): spot-check a stable prefix
        assert_eq!(&bases[0][..4], b"MEBS");
    }

    #[test]
    fn checksum_fixup_revalidates_a_corrupted_frame() {
        let mut f = legacy_meb(3);
        assert!(MebSketch::decode(&f).is_ok());
        // corrupt one payload byte: checksum now rejects it
        let at = HEADER_LEN + 5;
        f[at] ^= 0xFF;
        let before = MebSketch::decode(&f).unwrap_err().to_string();
        assert!(before.contains("checksum"), "{before}");
        // recompute: decode proceeds to the structural layer (Ok or a
        // structural error, but no longer a checksum mismatch)
        fix_meb_checksum(&mut f);
        if let Err(e) = MebSketch::decode(&f) {
            assert!(!e.to_string().contains("checksum mismatch"), "{e}");
        }
    }
}
