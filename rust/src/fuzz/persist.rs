//! Failure persistence with greedy minimization, in the style of the
//! edr fuzz harness's `failurePersistDir`.
//!
//! Semantics:
//!
//! * The failures directory is created **lazily, only when a failure
//!   exists** — a clean run leaves no `fuzz/failures/` behind.
//! * A failing case is first greedily minimized (chunk removal at
//!   halving granularities, down to single bytes) while it still
//!   reproduces the failure, then written under
//!   `<root>/<target>/case-<fnv1a64 hex>.bin`. Content-hash naming
//!   dedupes the same minimized case across runs.
//! * On the next run, every persisted case is **replayed first**,
//!   before any generated case — a regression stays loud until its
//!   file is deleted (or the run is pointed at a fresh `--persist-dir`).

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::sketch::codec::fnv1a64;

/// Cap on property evaluations during one minimization, so a slow
/// property on a large case cannot stall the run.
const MINIMIZE_BUDGET: usize = 2000;

/// Greedily shrink `bytes` while `still_fails` keeps reproducing the
/// failure: repeated passes of aligned chunk removal, halving the chunk
/// size down to one byte (ddmin-lite). Returns the smallest failing
/// case found; `bytes` itself is returned untouched if nothing smaller
/// still fails.
pub fn minimize(bytes: &[u8], mut still_fails: impl FnMut(&[u8]) -> bool) -> Vec<u8> {
    let mut best = bytes.to_vec();
    let mut evals = 0usize;
    let mut chunk = (best.len() / 2).max(1);
    loop {
        let mut progressed = false;
        let mut start = 0usize;
        while start < best.len() {
            if evals >= MINIMIZE_BUDGET {
                return best;
            }
            let end = (start + chunk).min(best.len());
            let mut candidate = Vec::with_capacity(best.len() - (end - start));
            candidate.extend_from_slice(&best[..start]);
            candidate.extend_from_slice(&best[end..]);
            evals += 1;
            if still_fails(&candidate) {
                best = candidate;
                progressed = true;
                // same `start` now addresses the next chunk
            } else {
                start = end;
            }
        }
        if !progressed {
            if chunk == 1 {
                return best;
            }
            chunk = (chunk / 2).max(1);
        }
    }
}

/// Write a (minimized) failing case under `<root>/<target>/`, creating
/// the directory only now — the lazy-creation contract. Returns the
/// written path.
pub fn persist(root: &Path, target: &str, bytes: &[u8]) -> Result<PathBuf> {
    let dir = root.join(target);
    std::fs::create_dir_all(&dir)
        .map_err(|e| Error::Pipeline(format!("fuzz: cannot create {}: {e}", dir.display())))?;
    let path = dir.join(format!("case-{:016x}.bin", fnv1a64(bytes)));
    std::fs::write(&path, bytes)
        .map_err(|e| Error::Pipeline(format!("fuzz: cannot write {}: {e}", path.display())))?;
    Ok(path)
}

/// Load every persisted case for `target`, sorted by file name for a
/// deterministic replay order. An absent directory is an empty list,
/// not an error (nothing has ever failed).
pub fn load_cases(root: &Path, target: &str) -> Vec<(PathBuf, Vec<u8>)> {
    let dir = root.join(target);
    let Ok(entries) = std::fs::read_dir(&dir) else {
        return Vec::new();
    };
    let mut cases: Vec<(PathBuf, Vec<u8>)> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.is_file())
        .filter_map(|p| std::fs::read(&p).ok().map(|b| (p, b)))
        .collect();
    cases.sort_by(|a, b| a.0.cmp(&b.0));
    cases
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimize_finds_the_single_failing_byte() {
        let case: Vec<u8> = (0..200u8).collect();
        let min = minimize(&case, |b| b.contains(&7));
        assert_eq!(min, vec![7]);
    }

    #[test]
    fn minimize_keeps_order_dependent_pairs() {
        // failure requires the subsequence [3, 9]
        let case: Vec<u8> = (0..64u8).collect();
        let fails = |b: &[u8]| {
            let i3 = b.iter().position(|&x| x == 3);
            let i9 = b.iter().position(|&x| x == 9);
            matches!((i3, i9), (Some(a), Some(b)) if a < b)
        };
        let min = minimize(&case, fails);
        assert_eq!(min, vec![3, 9]);
    }

    #[test]
    fn minimize_returns_input_when_nothing_smaller_fails() {
        let case = vec![1u8, 2, 3];
        let min = minimize(&case, |b| b == [1, 2, 3]);
        assert_eq!(min, case);
    }

    #[test]
    fn persist_creates_dir_lazily_and_load_replays_sorted() {
        let root =
            std::env::temp_dir().join(format!("ssvm_fuzz_persist_{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        // nothing persisted: no directory, empty load
        assert!(load_cases(&root, "json").is_empty());
        assert!(!root.exists(), "load must not create the directory");

        let p1 = persist(&root, "json", b"bb").unwrap();
        let p2 = persist(&root, "json", b"aa").unwrap();
        assert!(root.join("json").is_dir());
        let cases = load_cases(&root, "json");
        assert_eq!(cases.len(), 2);
        assert!(cases.windows(2).all(|w| w[0].0 < w[1].0));
        let loaded: Vec<&[u8]> = cases.iter().map(|(_, b)| b.as_slice()).collect();
        assert!(loaded.contains(&&b"aa"[..]) && loaded.contains(&&b"bb"[..]));

        // same bytes, same name: re-persisting dedupes
        let p1b = persist(&root, "json", b"bb").unwrap();
        assert_eq!(p1, p1b);
        assert_ne!(p1, p2);
        assert_eq!(load_cases(&root, "json").len(), 2);

        // other targets stay isolated
        assert!(load_cases(&root, "http").is_empty());
        std::fs::remove_dir_all(&root).ok();
    }
}
