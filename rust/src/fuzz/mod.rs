//! Structure-aware fuzzing with persisted failing cases (std-only).
//!
//! The subsystem has four layers:
//!
//! * [`gen`] — structure-aware generators that start from *valid*
//!   inputs: HTTP/1.1 requests and responses, JSON documents, framed
//!   `.meb` sketches (every supported wire version), and entropy tapes
//!   that decode to labeled example streams.
//! * [`mutate`] — a seeded deterministic mutator (truncation, bit
//!   flips, splices, little-endian length-field and integer-boundary
//!   overwrites). A fixed `--seed` reproduces the whole case stream
//!   bit-for-bit.
//! * [`harness`] — runs N cases per target against its property
//!   (never-panics, `Error`-not-abort, codec fixpoint, JSON round
//!   trip, and the variant-conformance laws of [`laws`]).
//! * [`persist`] — on failure, greedy chunk-then-byte minimization and
//!   persistence under `fuzz/failures/<target>/`, created lazily only
//!   when a failure exists; persisted cases replay first on the next
//!   run so regressions stay loud.
//!
//! Driven by the `fuzz` CLI subcommand:
//!
//! ```text
//! streamsvm fuzz --target json --cases 2000 --seed 7 --persist-dir fuzz/failures
//! ```

pub mod gen;
pub mod harness;
pub mod laws;
pub mod mutate;
pub mod persist;

pub use harness::{case_bytes, run, run_with, FuzzConfig, FuzzReport, Target};
pub use mutate::Mutator;
