//! The fuzz harness: N seeded cases per target, each checked against
//! the target's property under `catch_unwind`.
//!
//! Targets and their properties:
//!
//! * `http` — the hand-rolled HTTP/1.1 reader parses the bytes as a
//!   request and as a response. `Ok` and `Err` are both acceptable;
//!   a panic is a failure (`Error`-not-abort).
//! * `json` — the minimal JSON parser parses the (lossily decoded)
//!   bytes. On `Ok`, every number must be finite and the value must
//!   survive a serialize → re-parse round trip unchanged; a reject is
//!   fine, a panic is a failure.
//! * `codec` — `.meb` `decode` over mutated frames of every version
//!   (the PR-9 corruption suite, generalized): `Err` is fine; on `Ok`
//!   the sketch must re-encode/re-decode to a byte-identical frame.
//! * `invariants` — the conformance laws of [`crate::fuzz::laws`] run
//!   over a stream decoded from the case bytes, for all five variants
//!   through `AnyLearner`; any law violation is a failure.
//!
//! On failure the case is greedily minimized and persisted under
//! `<persist_dir>/<target>/` ([`crate::fuzz::persist`]); persisted
//! cases replay **first** on the next run.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::str::FromStr;

use crate::error::{Error, Result};
use crate::fuzz::mutate::Mutator;
use crate::fuzz::{gen, laws, persist};
use crate::rng::Pcg32;
use crate::server::http;
use crate::server::json::{escape, fmt_num, Json};
use crate::sketch::codec::MebSketch;

/// Stop minimizing/persisting after this many failures in one run (the
/// run keeps counting, but a systemically broken property should not
/// pay the minimization cost thousands of times).
const MAX_PERSISTED: usize = 8;

/// A fuzzable subsystem.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Target {
    Http,
    Json,
    Codec,
    Invariants,
}

impl Target {
    pub const ALL: [Target; 4] = [Target::Http, Target::Json, Target::Codec, Target::Invariants];

    pub fn name(self) -> &'static str {
        match self {
            Target::Http => "http",
            Target::Json => "json",
            Target::Codec => "codec",
            Target::Invariants => "invariants",
        }
    }
}

impl std::fmt::Display for Target {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Target {
    type Err = Error;

    fn from_str(s: &str) -> Result<Target> {
        Target::ALL.into_iter().find(|t| t.name() == s).ok_or_else(|| {
            Error::config(format!("unknown fuzz target `{s}` (expected http|json|codec|invariants)"))
        })
    }
}

/// One fuzz run's configuration.
#[derive(Clone, Debug)]
pub struct FuzzConfig {
    /// Generated cases to run (after replaying persisted ones).
    pub cases: usize,
    /// Master seed: the whole case stream is a deterministic function
    /// of `(seed, case index)`.
    pub seed: u64,
    /// Failure-persistence root (`<dir>/<target>/case-*.bin`). `None`
    /// counts failures without persisting.
    pub persist_dir: Option<PathBuf>,
}

impl Default for FuzzConfig {
    fn default() -> Self {
        FuzzConfig { cases: 500, seed: 1, persist_dir: None }
    }
}

/// What one run did.
#[derive(Debug)]
pub struct FuzzReport {
    pub target: String,
    /// Persisted cases replayed (before any generated case).
    pub replayed: usize,
    /// Persisted cases that still fail.
    pub replay_failures: Vec<PathBuf>,
    /// Generated cases executed.
    pub executed: usize,
    /// Generated cases that failed the property.
    pub failures: usize,
    /// Newly persisted (minimized) failing cases.
    pub persisted: Vec<PathBuf>,
    /// First failure message, for diagnostics.
    pub sample_failure: Option<String>,
}

impl FuzzReport {
    /// No failures, replayed or fresh.
    pub fn clean(&self) -> bool {
        self.replay_failures.is_empty() && self.failures == 0
    }
}

/// Run one target.
pub fn run(target: Target, cfg: &FuzzConfig) -> Result<FuzzReport> {
    match target {
        Target::Http => run_with(target.name(), cfg, gen::http_message, no_fixup, http_property),
        Target::Json => run_with(target.name(), cfg, gen::json_doc, no_fixup, json_property),
        Target::Codec => run_with(target.name(), cfg, gen::meb_frame, codec_fixup, codec_property),
        Target::Invariants => {
            run_with(target.name(), cfg, gen::invariants_tape, no_fixup, invariants_property)
        }
    }
}

/// The exact case bytes `run` executes at `index` — exposed so the
/// determinism tests can pin the stream bit-for-bit.
pub fn case_bytes(target: Target, seed: u64, index: u64) -> Vec<u8> {
    match target {
        Target::Http => build_case(gen::http_message, no_fixup, seed, index),
        Target::Json => build_case(gen::json_doc, no_fixup, seed, index),
        Target::Codec => build_case(gen::meb_frame, codec_fixup, seed, index),
        Target::Invariants => build_case(gen::invariants_tape, no_fixup, seed, index),
    }
}

fn build_case(
    generate: impl Fn(&mut Pcg32) -> Vec<u8>,
    fixup: impl Fn(&mut Pcg32, &mut Vec<u8>),
    seed: u64,
    index: u64,
) -> Vec<u8> {
    let mut m = Mutator::for_case(seed, index);
    let mut case = generate(m.rng());
    let donor = generate(m.rng());
    // keep ~1/8 of cases pristine: valid inputs must keep passing too
    if m.rng().below(8) != 0 {
        m.mutate(&mut case, &donor);
    }
    fixup(m.rng(), &mut case);
    case
}

/// The generic engine behind [`run`]: replay persisted cases first,
/// then generate/mutate/execute `cfg.cases` fresh ones, minimizing and
/// persisting failures. Public as the test seam — the replay-order and
/// panic-capture tests drive it with synthetic properties.
pub fn run_with(
    name: &str,
    cfg: &FuzzConfig,
    generate: impl Fn(&mut Pcg32) -> Vec<u8>,
    fixup: impl Fn(&mut Pcg32, &mut Vec<u8>),
    property: impl Fn(&[u8]) -> Result<(), String>,
) -> Result<FuzzReport> {
    // silence the default panic hook while the harness probes for
    // panics; restored before returning
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let report = run_inner(name, cfg, generate, fixup, property);
    std::panic::set_hook(prev_hook);
    report
}

fn run_inner(
    name: &str,
    cfg: &FuzzConfig,
    generate: impl Fn(&mut Pcg32) -> Vec<u8>,
    fixup: impl Fn(&mut Pcg32, &mut Vec<u8>),
    property: impl Fn(&[u8]) -> Result<(), String>,
) -> Result<FuzzReport> {
    let mut report = FuzzReport {
        target: name.to_string(),
        replayed: 0,
        replay_failures: Vec::new(),
        executed: 0,
        failures: 0,
        persisted: Vec::new(),
        sample_failure: None,
    };

    // replay-first: every persisted case runs before any generated one
    if let Some(root) = &cfg.persist_dir {
        for (path, bytes) in persist::load_cases(root, name) {
            report.replayed += 1;
            if let Err(msg) = check(&property, &bytes) {
                crate::obs_warn!(
                    "fuzz";
                    target = name,
                    case = path.display().to_string();
                    "persisted case still fails: {msg}"
                );
                report.sample_failure.get_or_insert(msg);
                report.replay_failures.push(path);
            }
        }
    }

    let mut minimized = 0usize;
    for index in 0..cfg.cases as u64 {
        let case = build_case(&generate, &fixup, cfg.seed, index);
        report.executed += 1;
        let msg = match check(&property, &case) {
            Ok(()) => continue,
            Err(msg) => msg,
        };
        report.failures += 1;
        report.sample_failure.get_or_insert(msg.clone());
        if minimized >= MAX_PERSISTED {
            // past the cap a systemically broken property would pay the
            // minimization cost for every remaining case — stop early
            break;
        }
        minimized += 1;
        let min = persist::minimize(&case, |b| check(&property, b).is_err());
        if let Some(root) = &cfg.persist_dir {
            let path = persist::persist(root, name, &min)?;
            crate::obs_warn!(
                "fuzz";
                target = name,
                case_index = index,
                minimized_bytes = min.len();
                "case {index} failed ({msg}); minimized {} -> {} bytes, persisted {}",
                case.len(),
                min.len(),
                path.display()
            );
            // content-hash naming dedupes equal minimized cases
            if !report.persisted.contains(&path) {
                report.persisted.push(path);
            }
        }
    }
    Ok(report)
}

/// Run the property under `catch_unwind`: a panic is a failure with the
/// panic payload as the message (`Error`-not-abort is the contract).
fn check(property: &impl Fn(&[u8]) -> Result<(), String>, bytes: &[u8]) -> Result<(), String> {
    match catch_unwind(AssertUnwindSafe(|| property(bytes))) {
        Ok(r) => r,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            Err(format!("panic: {msg}"))
        }
    }
}

fn no_fixup(_rng: &mut Pcg32, _case: &mut Vec<u8>) {}

/// Half the corrupted `.meb` frames get their checksum recomputed so
/// the mutation reaches the structural validation layer instead of
/// dying at the integrity gate.
fn codec_fixup(rng: &mut Pcg32, case: &mut Vec<u8>) {
    if rng.below(2) == 0 {
        gen::fix_meb_checksum(case);
    }
}

/// Parser limits for fuzzing: the production shape, with a small body
/// cap so length-field mutations cannot turn into large allocations.
fn fuzz_limits() -> http::Limits {
    http::Limits { max_line: 4096, max_headers: 64, max_body: 1 << 16 }
}

fn http_property(bytes: &[u8]) -> Result<(), String> {
    let limits = fuzz_limits();
    let mut r = std::io::Cursor::new(bytes);
    let _ = http::read_request(&mut r, &limits);
    let mut r = std::io::Cursor::new(bytes);
    let _ = http::read_response(&mut r, &limits);
    Ok(())
}

fn json_property(bytes: &[u8]) -> Result<(), String> {
    let s = String::from_utf8_lossy(bytes);
    let v = match Json::parse(&s) {
        Err(_) => return Ok(()), // a clean reject is the expected path
        Ok(v) => v,
    };
    all_numbers_finite(&v)?;
    let ser = to_json_string(&v);
    let back = Json::parse(&ser)
        .map_err(|e| format!("re-parse of serialized accepted value failed: {e} (`{ser}`)"))?;
    if back != v {
        return Err(format!("serialize/re-parse round trip changed the value (`{ser}`)"));
    }
    Ok(())
}

/// The parser must never hand a non-finite number to the protocol layer
/// (the trap `1e999` used to spring).
fn all_numbers_finite(v: &Json) -> Result<(), String> {
    match v {
        Json::Num(n) if !n.is_finite() => Err(format!("parser accepted non-finite number {n}")),
        Json::Arr(items) => items.iter().try_for_each(all_numbers_finite),
        Json::Obj(kv) => kv.iter().try_for_each(|(_, v)| all_numbers_finite(v)),
        _ => Ok(()),
    }
}

/// Serialize a parsed value back to text (the round-trip half the
/// protocol writers don't need, so it lives with the fuzzer).
fn to_json_string(v: &Json) -> String {
    match v {
        Json::Null => "null".into(),
        Json::Bool(b) => b.to_string(),
        Json::Num(n) => fmt_num(*n),
        Json::Str(s) => format!("\"{}\"", escape(s)),
        Json::Arr(items) => {
            let inner: Vec<String> = items.iter().map(to_json_string).collect();
            format!("[{}]", inner.join(","))
        }
        Json::Obj(kv) => {
            let inner: Vec<String> =
                kv.iter().map(|(k, v)| format!("\"{}\":{}", escape(k), to_json_string(v))).collect();
            format!("{{{}}}", inner.join(","))
        }
    }
}

fn codec_property(bytes: &[u8]) -> Result<(), String> {
    let sk = match MebSketch::decode(bytes) {
        Err(_) => return Ok(()), // a clean reject is the expected path
        Ok(sk) => sk,
    };
    // whatever decode accepted must re-encode/re-decode as a fixpoint
    let re = sk.encode();
    let back = MebSketch::decode(&re)
        .map_err(|e| format!("re-decode of a re-encoded accepted sketch failed: {e}"))?;
    let re2 = back.encode();
    if re2 != re {
        return Err("encode/decode is not a byte-identical fixpoint".into());
    }
    Ok(())
}

fn invariants_property(bytes: &[u8]) -> Result<(), String> {
    laws::check_tape(bytes)
}
