//! Seeded deterministic byte/structure mutation.
//!
//! Every mutation stream derives from a `(seed, case index)` pair
//! through [`Pcg32`], so a fixed seed reproduces the identical case
//! stream bit-for-bit across runs and machines — the property the
//! replay/determinism tests pin. The operator menu is the classic
//! wire-fuzz set: truncation, bit flips, byte overwrites, inserts,
//! deletes, splices from a donor case, little-endian length-field and
//! integer-boundary overwrites. Checksum-gated formats additionally
//! recompute their digest after corruption (see
//! [`crate::fuzz::gen::fix_meb_checksum`]) so mutations survive the CRC
//! gate and reach the structural validation layer.

use crate::rng::Pcg32;

/// Boundary integers that historically break length/count fields.
pub const BOUNDARY_U64: [u64; 8] =
    [0, 1, 2, u32::MAX as u64, u32::MAX as u64 + 1, u64::MAX, u64::MAX - 7, 1 << 60];

/// A deterministic mutator for one fuzz case.
pub struct Mutator {
    rng: Pcg32,
}

impl Mutator {
    /// Mutator for case `index` of a run seeded with `seed`. Cases are
    /// independent: case `i` of two runs with the same seed is
    /// bit-identical regardless of what ran before it.
    pub fn for_case(seed: u64, index: u64) -> Self {
        Mutator { rng: Pcg32::new(seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15), 2 * index + 1) }
    }

    /// The mutator's RNG, for callers that need case-local randomness
    /// (e.g. deciding whether to recompute a checksum).
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    /// Apply 1..=4 random operators to `case` in place. `donor` feeds
    /// the splice operator (typically another freshly generated valid
    /// case of the same grammar).
    pub fn mutate(&mut self, case: &mut Vec<u8>, donor: &[u8]) {
        let ops = 1 + self.rng.below(4);
        for _ in 0..ops {
            self.mutate_once(case, donor);
        }
    }

    fn mutate_once(&mut self, case: &mut Vec<u8>, donor: &[u8]) {
        if case.is_empty() {
            case.extend_from_slice(&[0u8; 4]);
        }
        let len = case.len();
        match self.rng.below(8) {
            // truncate to a random prefix (possibly empty)
            0 => case.truncate(self.rng.below(len + 1)),
            // single bit flip
            1 => {
                let pos = self.rng.below(len);
                case[pos] ^= 1 << self.rng.below(8);
            }
            // byte overwrite with an interesting value
            2 => {
                let pos = self.rng.below(len);
                const INTERESTING: [u8; 9] = [0x00, 0x01, 0x7F, 0x80, 0xFF, b'\n', b'\r', b'"', b':'];
                case[pos] = INTERESTING[self.rng.below(INTERESTING.len())];
            }
            // insert 1..=8 random bytes
            3 => {
                let at = self.rng.below(len + 1);
                let k = 1 + self.rng.below(8);
                let ins: Vec<u8> = (0..k).map(|_| self.rng.next_u32() as u8).collect();
                case.splice(at..at, ins);
            }
            // delete a short run
            4 => {
                let at = self.rng.below(len);
                let k = (1 + self.rng.below(8)).min(len - at);
                case.drain(at..at + k);
            }
            // splice a donor slice over a random position
            5 => {
                if !donor.is_empty() {
                    let from = self.rng.below(donor.len());
                    let k = (1 + self.rng.below(16)).min(donor.len() - from);
                    let at = self.rng.below(len + 1);
                    let end = (at + k).min(case.len());
                    case.splice(at..end, donor[from..from + k].iter().copied());
                }
            }
            // little-endian u64 length-field / integer-boundary overwrite
            6 => {
                if len >= 8 {
                    let at = self.rng.below(len - 7);
                    let v = BOUNDARY_U64[self.rng.below(BOUNDARY_U64.len())];
                    case[at..at + 8].copy_from_slice(&v.to_le_bytes());
                }
            }
            // little-endian u16 boundary overwrite (version/flags fields)
            _ => {
                if len >= 2 {
                    let at = self.rng.below(len - 1);
                    let v = [0u16, 1, 5, 0x00FF, 0x7FFF, 0x8000, u16::MAX][self.rng.below(7)];
                    case[at..at + 2].copy_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_seed_reproduces_identical_mutations() {
        let donor = b"donor bytes for splicing".to_vec();
        for index in 0..64u64 {
            let mut a = Mutator::for_case(42, index);
            let mut b = Mutator::for_case(42, index);
            let mut ca = (0..40u8).collect::<Vec<u8>>();
            let mut cb = ca.clone();
            a.mutate(&mut ca, &donor);
            b.mutate(&mut cb, &donor);
            assert_eq!(ca, cb, "case {index} diverged under the same seed");
        }
    }

    #[test]
    fn different_cases_diverge() {
        let donor = Vec::new();
        let base = (0..64u8).collect::<Vec<u8>>();
        let mut outs = std::collections::HashSet::new();
        for index in 0..32u64 {
            let mut c = base.clone();
            Mutator::for_case(7, index).mutate(&mut c, &donor);
            outs.insert(c);
        }
        // mutation is not a constant function of the input
        assert!(outs.len() > 1);
    }

    #[test]
    fn mutation_never_panics_on_tiny_inputs() {
        for index in 0..256u64 {
            let mut c = Vec::new();
            let mut m = Mutator::for_case(3, index);
            for _ in 0..8 {
                m.mutate(&mut c, b"xy");
            }
        }
    }
}
