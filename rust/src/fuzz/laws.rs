//! The conformance laws as reusable property functions.
//!
//! These are the invariants that make the paper's (1+ε)-MEB guarantee
//! meaningful — radius monotonicity, convex-coefficient laws, the
//! reduction anchors tying the kernelized/ellipsoid variants back to
//! [`BallState`], sparse/dense agreement, codec round-trips, and the
//! `try_observe` rejection contract. They used to live inline in
//! `tests/variant_conformance.rs`; factored here so the randomized
//! fuzz harness (`fuzz --target invariants`) and the conformance test
//! suite run the *same* code over different case distributions.
//!
//! Every law takes a [`StreamCase`] (one logical stream, dense rows plus
//! their sparse twins) and returns `Err(description)` on violation —
//! the shape [`crate::prop::check`] and the fuzz harness both consume.

use crate::data::Features;
use crate::error::Error;
use crate::eval::Classifier;
use crate::prop::gen;
use crate::rng::Pcg32;
use crate::sketch::codec::MebSketch;
use crate::svm::ellipsoid::EllipsoidSvm;
use crate::svm::kernelfn::Kernel;
use crate::svm::kernelized::KernelStreamSvm;
use crate::svm::learner::{AnyLearner, StreamLearner, Variant};
use crate::svm::lookahead::LookaheadSvm;
use crate::svm::multiball::{MergePolicy, MultiBallSvm};
use crate::svm::streamsvm::StreamSvm;
use crate::svm::TrainOptions;

/// One generated conformance stream: dense rows plus their sparse twins.
pub struct StreamCase {
    pub dense: Vec<Vec<f32>>,
    pub sparse: Vec<Features>,
    pub ys: Vec<f32>,
    pub dim: usize,
}

impl StreamCase {
    /// Build from dense rows + labels (sparse twins derived).
    pub fn new(dense: Vec<Vec<f32>>, ys: Vec<f32>, dim: usize) -> Self {
        let sparse = dense.iter().map(|x| Features::Dense(x.clone()).to_sparse()).collect();
        StreamCase { dense, sparse, ys, dim }
    }

    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }
}

/// Sample a conformance stream from the standard two-Gaussian generator.
pub fn gen_stream(rng: &mut Pcg32, n: usize) -> StreamCase {
    let dim = gen::dim(rng);
    let (dense, ys) = gen::labeled_points(rng, n, dim, 1.2, 0.4);
    StreamCase::new(dense, ys, dim)
}

/// Decode a fuzzer byte tape into a stream case plus options. Total:
/// every byte string decodes to *some* valid case (values are finite by
/// construction), so byte-level mutation and chunk-removal minimization
/// always land on runnable streams. Layout: `[dim sel, c sel, lookahead
/// sel, reserved]` then rows of `1 + 2·dim` bytes (label byte + per-axis
/// i16/1024 values); a trailing partial row zero-pads.
pub fn stream_case_from_tape(tape: &[u8]) -> (StreamCase, TrainOptions, usize) {
    let b = |i: usize| tape.get(i).copied().unwrap_or(0);
    let dim = 1 + (b(0) as usize) % 12;
    let c = 0.5 + (b(1) % 16) as f64 * 0.25;
    let lookahead = 1 + (b(2) as usize) % 6;
    let opts = TrainOptions::default().with_c(c);
    let row_bytes = 1 + 2 * dim;
    let body = if tape.len() > 4 { &tape[4..] } else { &[][..] };
    let n = body.len().div_ceil(row_bytes).min(96);
    let mut dense = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for r in 0..n {
        let at = |k: usize| body.get(r * row_bytes + k).copied().unwrap_or(0);
        ys.push(if at(0) % 2 == 0 { 1.0 } else { -1.0 });
        let mut x = Vec::with_capacity(dim);
        for j in 0..dim {
            let raw = i16::from_le_bytes([at(1 + 2 * j), at(2 + 2 * j)]);
            x.push(raw as f32 / 1024.0);
        }
        dense.push(x);
    }
    (StreamCase::new(dense, ys, dim), opts, lookahead)
}

/// Drive `step(i)` (observe example `i`, return the current radius) over
/// the stream, checking radius monotonicity after every example.
pub fn radius_monotone(
    name: &str,
    n: usize,
    mut step: impl FnMut(usize) -> f64,
) -> Result<(), String> {
    let mut prev = 0.0;
    for i in 0..n {
        let r = step(i);
        if !r.is_finite() {
            return Err(format!("{name}: radius went non-finite at example {i}"));
        }
        if r < prev - 1e-9 {
            return Err(format!("{name}: radius shrank {prev} -> {r} at example {i}"));
        }
        prev = r;
    }
    Ok(())
}

/// Radius monotonicity + convex-coefficient laws over every variant,
/// driven sparse or dense: Algorithm 1 and the lookahead/kernelized/
/// ellipsoid/multiball variants never shrink the enclosing radius, the
/// kernelized α stay a signed convex combination (`Σ|α| = 1`, every
/// `|α| ≤ 1`), the ellipsoid ξ² stays in `(0, s²]`, and the multiball
/// ball count respects its budget.
pub fn monotone_and_convex(
    st: &StreamCase,
    opts: TrainOptions,
    use_sparse: bool,
    lookahead: usize,
) -> Result<(), String> {
    let n = st.len();
    let feed = |i: usize| st.sparse[i].view();

    // Algorithm 1
    let mut a1 = StreamSvm::new(st.dim, opts);
    radius_monotone("stream", n, |i| {
        if use_sparse {
            a1.observe_view(feed(i), st.ys[i]);
        } else {
            a1.observe(&st.dense[i], st.ys[i]);
        }
        a1.radius()
    })?;

    // Algorithm 2 (lookahead): monotone through the merge solves
    let l = lookahead.max(2);
    let mut a2 = LookaheadSvm::new(st.dim, opts.with_lookahead(l));
    radius_monotone("lookahead", n, |i| {
        if use_sparse {
            a2.observe_view(feed(i), st.ys[i]);
        } else {
            a2.observe(&st.dense[i], st.ys[i]);
        }
        a2.radius()
    })?;
    let before_finish = a2.radius();
    a2.finish();
    if a2.radius() < before_finish - 1e-9 {
        return Err("lookahead finish shrank the radius".into());
    }

    // Kernelized (linear): radius + convex coefficients
    let mut ker = KernelStreamSvm::new(Kernel::Linear, opts);
    radius_monotone("kernelized", n, |i| {
        if use_sparse {
            ker.observe_view(feed(i), st.ys[i]);
        } else {
            ker.observe(&st.dense[i], st.ys[i]);
        }
        ker.radius()
    })?;
    if n > 0 && !ker.coefficients().is_empty() {
        let sum_abs: f64 = ker.coefficients().iter().map(|a| a.abs()).sum();
        if (sum_abs - 1.0).abs() > 1e-9 {
            return Err(format!("kernelized Σ|α| = {sum_abs}"));
        }
        if !ker.coefficients().iter().all(|a| a.abs() <= 1.0 + 1e-12) {
            return Err("kernelized |α| > 1".into());
        }
    }

    // Ellipsoid (isotropic metric)
    let mut ell = EllipsoidSvm::isotropic(st.dim, opts);
    radius_monotone("ellipsoid", n, |i| {
        if use_sparse {
            ell.observe_view(feed(i), st.ys[i]);
        } else {
            ell.observe(&st.dense[i], st.ys[i]);
        }
        ell.radius()
    })?;
    if n > 0 && !(ell.xi2() > 0.0 && ell.xi2() <= opts.s2() + 1e-12) {
        return Err(format!("ellipsoid ξ² = {} outside (0, s²]", ell.xi2()));
    }

    // Multiball: bounded ball count, finite merged final ball
    let budget = 3usize;
    let mut mb = MultiBallSvm::new(st.dim, budget, MergePolicy::NewBallMergeClosest, opts);
    for i in 0..n {
        if use_sparse {
            mb.observe_view(feed(i), st.ys[i]);
        } else {
            mb.observe(&st.dense[i], st.ys[i]);
        }
        if mb.num_balls() > budget {
            return Err(format!("multiball exceeded L: {}", mb.num_balls()));
        }
    }
    if n > 0 {
        let fb = mb.final_ball().ok_or("multiball produced no final ball")?;
        if !fb.r.is_finite() || fb.r < 0.0 {
            return Err(format!("multiball final radius {}", fb.r));
        }
        if !fb.weights().iter().all(|w| w.is_finite()) {
            return Err("multiball final center non-finite".into());
        }
    }
    Ok(())
}

/// The reduction anchors: linear-kernelized and isotropic-ellipsoid are
/// Algorithm 1 in different clothes. Same update decisions, same
/// `(w, R, ξ², M)` to tolerance — sparse and dense inputs both.
pub fn reduction_anchors(
    st: &StreamCase,
    opts: TrainOptions,
    use_sparse: bool,
) -> Result<(), String> {
    let mut ball = StreamSvm::new(st.dim, opts);
    let mut ker = KernelStreamSvm::new(Kernel::Linear, opts);
    let mut ell = EllipsoidSvm::isotropic(st.dim, opts);
    for i in 0..st.len() {
        let (ub, uk, ue) = if use_sparse {
            let v = st.sparse[i].view();
            (
                ball.observe_view(v, st.ys[i]),
                ker.observe_view(v, st.ys[i]),
                ell.observe_view(v, st.ys[i]),
            )
        } else {
            (
                ball.observe(&st.dense[i], st.ys[i]),
                ker.observe(&st.dense[i], st.ys[i]),
                ell.observe(&st.dense[i], st.ys[i]),
            )
        };
        if ub != uk || ub != ue {
            return Err(format!(
                "update decisions diverged at example {i}: ball {ub}, kernel {uk}, ellipsoid {ue}"
            ));
        }
    }
    let b = match ball.ball() {
        Some(b) => b,
        None => return Ok(()), // empty / all-skipped stream: nothing to anchor
    };

    // R
    let rtol = 1e-6 * b.r.max(1.0);
    if (ker.radius() - b.r).abs() > rtol {
        return Err(format!("kernelized R {} vs ball {}", ker.radius(), b.r));
    }
    if (ell.radius() - b.r).abs() > 1e-12 * b.r.max(1.0) {
        return Err(format!("ellipsoid R {} vs ball {}", ell.radius(), b.r));
    }
    // ξ² (the kernelized recurrence compounds β through its own float
    // path — the bound matches R's rather than demanding bit-parity)
    if (ker.xi2() - b.xi2).abs() > 1e-6 * b.xi2.max(1.0) {
        return Err(format!("kernelized ξ² {} vs ball {}", ker.xi2(), b.xi2));
    }
    if (ell.xi2() - b.xi2).abs() > 1e-12 * b.xi2.max(1.0) {
        return Err(format!("ellipsoid ξ² {} vs ball {}", ell.xi2(), b.xi2));
    }
    // w: the ellipsoid materializes its center; the kernelized center is
    // probed on the basis vectors (linear kernel ⇒ f(e_j) = w_j exactly).
    let w = ball.weights();
    let we = ell.weights();
    for j in 0..st.dim {
        if (w[j] - we[j]).abs() > 1e-5 * w[j].abs().max(1.0) {
            return Err(format!("ellipsoid w[{j}] {} vs ball {}", we[j], w[j]));
        }
        let mut e = vec![0.0f32; st.dim];
        e[j] = 1.0;
        let wk = ker.score(&e);
        if (w[j] as f64 - wk).abs() > 1e-4 * (w[j].abs() as f64).max(1.0) {
            return Err(format!("kernelized w[{j}] {wk} vs ball {}", w[j]));
        }
    }
    // M (support counts agree: decisions were identical)
    if ball.num_support() != ker.num_support() || ball.num_support() != ell.num_support() {
        return Err(format!(
            "M diverged: ball {}, kernel {}, ellipsoid {}",
            ball.num_support(),
            ker.num_support(),
            ell.num_support()
        ));
    }
    Ok(())
}

/// Sparse and dense physical representations of the same logical stream
/// must produce tolerance-identical state in every variant, driven
/// through the unified [`AnyLearner`] surface.
pub fn sparse_dense_agree(st: &StreamCase, opts: TrainOptions) -> Result<(), String> {
    for variant in Variant::ALL {
        let mut md = AnyLearner::new(variant, st.dim, opts);
        let mut ms = AnyLearner::new(variant, st.dim, opts);
        for i in 0..st.len() {
            md.observe_view(crate::data::FeaturesView::Dense(&st.dense[i]), st.ys[i]);
            ms.observe_view(st.sparse[i].view(), st.ys[i]);
        }
        md.finish();
        ms.finish();
        let (rd, rs) = (md.radius(), ms.radius());
        if (rd - rs).abs() > 1e-6 * rd.max(1.0) {
            return Err(format!("{variant}: R diverged {rd} vs {rs}"));
        }
        if md.num_support() != ms.num_support() {
            return Err(format!(
                "{variant}: support counts diverged {} vs {}",
                md.num_support(),
                ms.num_support()
            ));
        }
        if md.examples_seen() != ms.examples_seen() {
            return Err(format!("{variant}: examples_seen diverged"));
        }
    }
    Ok(())
}

/// Generic-drive radius law + finish contract for one variant through
/// [`AnyLearner`], returning the finished learner for further probing.
pub fn any_learner_monotone(
    variant: Variant,
    st: &StreamCase,
    opts: TrainOptions,
) -> Result<AnyLearner, String> {
    let mut any = AnyLearner::new(variant, st.dim, opts);
    radius_monotone(variant.name(), st.len(), |i| {
        any.observe_view(st.sparse[i].view(), st.ys[i]);
        any.radius()
    })?;
    let before = any.radius();
    any.finish();
    if any.radius() < before - 1e-9 {
        return Err(format!("{variant}: finish shrank the radius"));
    }
    Ok(any)
}

/// Serialization is part of the conformance surface: a finished learner
/// must survive the v4 `.meb` codec — encode, decode,
/// [`MebSketch::to_learner`] — with its variant tag intact and
/// *bit-identical* radius and probe scores.
pub fn meb_round_trip(m: &AnyLearner, st: &StreamCase) -> Result<(), String> {
    let v = m.variant();
    let sk = MebSketch::from_learner(m, "conformance");
    let bytes = sk.encode();
    let back = MebSketch::decode(&bytes).map_err(|e| format!("{v}: decode: {e}"))?;
    if back.variant != v {
        return Err(format!("{v}: round-trip variant tag became {}", back.variant));
    }
    let restored = back.to_learner().map_err(|e| format!("{v}: to_learner: {e}"))?;
    if restored.variant() != v {
        return Err(format!("{v}: restored as {}", restored.variant()));
    }
    if restored.examples_seen() != m.examples_seen() {
        return Err(format!(
            "{v}: seen {} != {}",
            restored.examples_seen(),
            m.examples_seen()
        ));
    }
    if restored.radius().to_bits() != m.radius().to_bits() {
        return Err(format!(
            "{v}: restored R {} != {} (not bit-identical)",
            restored.radius(),
            m.radius()
        ));
    }
    for (j, x) in st.dense.iter().take(8).enumerate() {
        if restored.score(x).to_bits() != m.score(x).to_bits() {
            return Err(format!("{v}: probe {j} score diverged after round-trip"));
        }
    }
    Ok(())
}

/// The `try_observe` rejection contract through the unified surface:
/// wrong dimension is [`Error::Config`], NaN features and non-±1 labels
/// are [`Error::Data`], and rejected examples consume no stream
/// position.
pub fn try_observe_contract(variant: Variant, opts: TrainOptions) -> Result<(), String> {
    use crate::data::FeaturesView;
    let good = [1.0f32, -2.0, 0.5];
    let nan = [1.0f32, f32::NAN, 0.5];
    let short = [1.0f32, 2.0];
    let mut any = AnyLearner::new(variant, 3, opts);
    any.try_observe(FeaturesView::Dense(&good), 1.0)
        .map_err(|e| format!("{variant}: valid example rejected: {e}"))?;
    match any.try_observe(FeaturesView::Dense(&short), 1.0) {
        Err(Error::Config(_)) => {}
        Err(e) => return Err(format!("{variant}: wrong-dim gave {e}")),
        Ok(_) => return Err(format!("{variant}: wrong-dim accepted")),
    }
    match any.try_observe(FeaturesView::Dense(&nan), 1.0) {
        Err(Error::Data(_)) => {}
        Err(e) => return Err(format!("{variant}: NaN gave {e}")),
        Ok(_) => return Err(format!("{variant}: NaN accepted")),
    }
    match any.try_observe(FeaturesView::Dense(&good), 0.5) {
        Err(Error::Data(_)) => {}
        Err(e) => return Err(format!("{variant}: bad label gave {e}")),
        Ok(_) => return Err(format!("{variant}: bad label accepted")),
    }
    if any.examples_seen() != 1 {
        return Err(format!("{variant}: rejections consumed stream positions"));
    }
    Ok(())
}

/// All laws over one decoded fuzz tape: the per-case body of
/// `fuzz --target invariants`.
pub fn check_tape(tape: &[u8]) -> Result<(), String> {
    let (st, opts, lookahead) = stream_case_from_tape(tape);
    let use_sparse = lookahead % 2 == 0;
    monotone_and_convex(&st, opts, use_sparse, lookahead)?;
    reduction_anchors(&st, opts, use_sparse)?;
    sparse_dense_agree(&st, opts)?;
    for variant in Variant::ALL {
        let m = any_learner_monotone(variant, &st, opts.with_lookahead(lookahead))?;
        meb_round_trip(&m, &st)?;
        try_observe_contract(variant, opts)?;
    }
    Ok(())
}
