//! Deterministic random number generation (PCG32 + distributions).
//!
//! The offline image has no `rand` crate; this is a minimal, fully
//! deterministic replacement. Every dataset generator, stream permutation
//! and property test derives from a `Pcg32` seeded explicitly, so all
//! experiments are reproducible bit-for-bit.

/// PCG-XSH-RR 64/32 (O'Neill 2014). 64-bit state, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// Cached second Box-Muller variate.
    spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with an arbitrary `(seed, stream)` pair.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1, spare: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 54 (an arbitrary fixed default).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, bias-free enough for
    /// our purposes via rejection).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let v = self.next_u64();
            let r = v % n;
            // rejection zone keeps the distribution exactly uniform
            if v.wrapping_sub(r) <= u64::MAX - (u64::MAX % n) {
                return r as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Bernoulli with probability `p` of `true`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// ±1 label with probability `p_pos` of +1.
    pub fn label(&mut self, p_pos: f64) -> f32 {
        if self.bernoulli(p_pos) {
            1.0
        } else {
            -1.0
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Pcg32::seeded(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Pcg32::seeded(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(6);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn permutation_distinct_seeds() {
        let p1 = Pcg32::seeded(10).permutation(50);
        let p2 = Pcg32::seeded(11).permutation(50);
        assert_ne!(p1, p2);
    }
}
