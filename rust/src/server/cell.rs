//! Hot-swap model cell: lock-held-for-nanoseconds snapshot publishing.
//!
//! The serving path must never observe a torn model while the trainer
//! thread keeps learning. The cell holds an `Arc<ModelSnapshot>` behind
//! an `RwLock`; readers clone the `Arc` (a refcount bump under the read
//! lock), the trainer builds a complete new snapshot off-lock and swaps
//! the pointer under the write lock. Every request therefore scores
//! against exactly one published snapshot — old or new, never a mix —
//! and publishing never blocks on in-flight scoring work, because
//! scoring happens after the guard is released.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::data::FeaturesView;
use crate::sketch::codec::MebSketch;
use crate::svm::learner::{AnyLearner, Variant};

/// One immutable published model: a frozen copy of the learner (so
/// scoring runs the variant's own decision rule — kernel expansions and
/// ellipsoid metrics included, not just a dense weight vector) plus the
/// full durable sketch (so `/snapshot` serves the same bytes a `.meb`
/// file would hold) and provenance for `/stats` and response metadata.
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// Frozen copy of the learner at publish time; all scoring goes
    /// through it so every variant serves its exact training-time scores.
    pub model: AnyLearner,
    pub dim: usize,
    /// Which algorithm produced this snapshot.
    pub variant: Variant,
    /// Monotone publish counter; 1 is the snapshot the server started with.
    pub version: u64,
    /// Stream position of the learner when this snapshot was taken.
    pub seen: usize,
    pub radius: f64,
    pub supports: usize,
    /// Full durable state ([`MebSketch`]), the `/snapshot` payload.
    pub sketch: MebSketch,
}

impl ModelSnapshot {
    fn build(model: &AnyLearner, tag: &str, version: u64) -> Self {
        ModelSnapshot {
            dim: model.dim(),
            variant: model.variant(),
            version,
            seen: model.examples_seen(),
            radius: model.radius(),
            supports: model.num_support(),
            sketch: MebSketch::from_learner(model, tag),
            model: model.clone(),
        }
    }

    /// Raw margin of `x` against this snapshot's model. Callers
    /// validate dimensions at the protocol boundary; a mismatch here is
    /// a bug, handled as an error response upstream.
    pub fn score(&self, x: &[f32]) -> f64 {
        debug_assert_eq!(x.len(), self.dim);
        self.model.score(x)
    }

    /// O(nnz) margin for a sparse request payload (`idx`/`val` pairs,
    /// validated in-range at the protocol boundary).
    pub fn score_sparse(&self, idx: &[u32], val: &[f32]) -> f64 {
        self.model.score_view(FeaturesView::Sparse { dim: self.dim, idx, val })
    }

    /// Margin for either payload shape.
    pub fn score_view(&self, x: FeaturesView<'_>) -> f64 {
        match x {
            FeaturesView::Dense(d) => self.score(d),
            FeaturesView::Sparse { idx, val, .. } => self.score_sparse(idx, val),
        }
    }
}

/// The swap cell shared by acceptor/handler threads and the trainer.
pub struct ModelCell {
    slot: RwLock<Arc<ModelSnapshot>>,
    version: AtomicU64,
    /// Republishes performed after construction (`version - 1` for a
    /// single-publisher cell; kept separate so `/stats` can report
    /// swap activity even if versioning semantics ever change).
    publishes: AtomicU64,
}

impl ModelCell {
    /// Publish `model` as version 1.
    pub fn new(model: &AnyLearner, tag: &str) -> Self {
        ModelCell {
            slot: RwLock::new(Arc::new(ModelSnapshot::build(model, tag, 1))),
            version: AtomicU64::new(1),
            publishes: AtomicU64::new(0),
        }
    }

    /// The latest published snapshot. Lock-poisoning (a reader panicking
    /// with the guard held) cannot corrupt an `Arc` swap, so a poisoned
    /// lock is recovered rather than propagated — serving must not die
    /// because one handler thread did.
    pub fn load(&self) -> Arc<ModelSnapshot> {
        match self.slot.read() {
            Ok(g) => g.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        }
    }

    /// Atomically replace the published snapshot with the trainer's
    /// current state. Returns the new version.
    ///
    /// Single-publisher: only the trainer thread calls this, so the
    /// version counter advances *after* the swap — [`Self::version`]
    /// never reports a version that is not yet loadable.
    pub fn publish(&self, model: &AnyLearner, tag: &str) -> u64 {
        let version = self.version.load(Ordering::Acquire) + 1;
        let next = Arc::new(ModelSnapshot::build(model, tag, version));
        match self.slot.write() {
            Ok(mut g) => *g = next,
            Err(poisoned) => *poisoned.into_inner() = next,
        }
        self.version.store(version, Ordering::Release);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        crate::obs_debug!("server"; version = version, variant = model.variant().name(), seen = model.examples_seen(), radius = model.radius(); "published model snapshot");
        version
    }

    /// The latest published version (monotone, starts at 1).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Hot-swaps performed since construction (the republish count
    /// behind `/stats` and `pallas_model_publishes_total`).
    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::kernelfn::Kernel;
    use crate::svm::streamsvm::StreamSvm;
    use crate::svm::TrainOptions;

    fn toy_model(n: usize) -> AnyLearner {
        let mut m = StreamSvm::new(2, TrainOptions::default());
        for i in 0..n {
            let v = 1.0 + i as f32;
            m.observe(&[v, -v], if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        m.into()
    }

    #[test]
    fn publish_bumps_version_and_swaps_weights() {
        let m1 = toy_model(1);
        let cell = ModelCell::new(&m1, "t");
        let s1 = cell.load();
        assert_eq!(s1.version, 1);
        assert_eq!(s1.dim, 2);
        assert_eq!(s1.variant, Variant::Ball);
        assert_eq!(s1.seen, 1);

        assert_eq!(cell.publishes(), 0, "construction is not a republish");
        let m2 = toy_model(20);
        let v = cell.publish(&m2, "t");
        assert_eq!(v, 2);
        assert_eq!(cell.version(), 2);
        assert_eq!(cell.publishes(), 1);
        let s2 = cell.load();
        assert_eq!(s2.version, 2);
        assert_eq!(s2.seen, 20);
        let probe = [0.7f32, 0.3];
        assert_eq!(s2.score(&probe).to_bits(), m2.score(&probe).to_bits());
        // the old Arc is still intact for readers that grabbed it
        assert_eq!(s1.version, 1);
        assert_eq!(s1.seen, 1);
    }

    #[test]
    fn empty_model_serves_zero_scores() {
        let m: AnyLearner = StreamSvm::new(3, TrainOptions::default()).into();
        let cell = ModelCell::new(&m, "empty");
        let s = cell.load();
        assert_eq!(s.score(&[1.0, 2.0, 3.0]), 0.0);
        assert!(s.sketch.ball.is_none());
    }

    #[test]
    fn snapshot_sketch_is_decodable_and_equal() {
        let m = toy_model(40);
        let cell = ModelCell::new(&m, "tag");
        let s = cell.load();
        let bytes = s.sketch.encode();
        let back = MebSketch::decode(&bytes).unwrap();
        assert_eq!(back, s.sketch);
        let restored = back.to_learner().unwrap();
        let probe = [0.5f32, -0.25];
        assert_eq!(restored.score(&probe).to_bits(), m.score(&probe).to_bits());
    }

    #[test]
    fn nonlinear_snapshot_scores_with_the_kernel_expansion() {
        let opts = TrainOptions::default();
        let mut m = AnyLearner::with_kernel(
            Variant::Kernelized,
            2,
            opts,
            Kernel::Rbf { gamma: 0.5 },
        );
        for i in 0..30 {
            let v = 0.1 * (1.0 + i as f32);
            m.try_observe(FeaturesView::Dense(&[v, -v]), if i % 2 == 0 { 1.0 } else { -1.0 })
                .unwrap();
        }
        let cell = ModelCell::new(&m, "rbf");
        let s = cell.load();
        assert_eq!(s.variant, Variant::Kernelized);
        let probe = [0.3f32, 0.6];
        // dense, sparse, and direct-learner scores all agree bit-for-bit
        let direct = m.score(&probe);
        assert_eq!(s.score(&probe).to_bits(), direct.to_bits());
        assert_eq!(
            s.score_sparse(&[0, 1], &[0.3, 0.6]).to_bits(),
            direct.to_bits(),
            "sparse request path diverged from the kernel expansion"
        );
        // the RBF sketch round-trips through the v4 exact-state section
        let back = MebSketch::decode(&s.sketch.encode()).unwrap();
        assert_eq!(back.to_learner().unwrap().score(&probe).to_bits(), direct.to_bits());
    }

    #[test]
    fn concurrent_readers_never_see_a_torn_model() {
        // Publish models whose weights satisfy an invariant
        // (score(e0) == -score(e1)); a torn read would break it.
        let cell = std::sync::Arc::new(ModelCell::new(&toy_model(1), "t"));
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let cell = cell.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut last_version = 0;
                    let mut reads = 0usize;
                    while !stop.load(Ordering::Acquire) {
                        let s = cell.load();
                        assert!(s.version >= last_version, "version went backwards");
                        last_version = s.version;
                        let sc = s.score(&[1.0, 1.0]);
                        assert!(sc.is_finite());
                        // invariant of every published model below
                        assert_eq!(
                            s.score(&[1.0, 0.0]),
                            -s.score(&[0.0, 1.0]),
                            "torn snapshot"
                        );
                        reads += 1;
                    }
                    reads
                })
            })
            .collect();
        for n in 2..200 {
            cell.publish(&toy_model(n), "t");
        }
        stop.store(true, Ordering::Release);
        let total: usize = readers.into_iter().map(|r| r.join().unwrap()).sum();
        assert!(total > 0);
        assert_eq!(cell.version(), 199);
    }
}
