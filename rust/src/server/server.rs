//! The network serving loop: accept → admit → route, with a background
//! trainer republishing hot-swap snapshots.
//!
//! Thread layout (all `std::net` + `std::thread`, zero dependencies):
//!
//! ```text
//!   acceptor ──(bounded conn queue, shed ⇒ 429)──▶ handler pool (N threads)
//!                                                     │ /predict, /predict_batch:
//!                                                     │    score vs cell.load()
//!   trainer ◀─(bounded train queue, shed ⇒ 429)────── │ /train: enqueue example
//!      │  ◀─(FileStream --train-stream, interleaved)  │ /snapshot: sketch bytes
//!      └── observe → republish every k ──▶ ModelCell  │ /stats: counters+quantiles
//! ```
//!
//! Consistency story: handlers never touch the learner — they score
//! against the latest *published* [`ModelCell`] snapshot, so a request
//! can never observe a half-updated model. The trainer owns the
//! [`AnyLearner`] exclusively (any of the five variants; `serve
//! --variant` on the CLI) and republishes a complete snapshot every
//! `republish_every` absorbed examples (and once more at shutdown), so
//! accepted `/train` examples are never lost.
//!
//! With [`ServerConfig::train_stream`] set, the trainer also feeds from a
//! local LIBSVM file through the lazy [`FileStream`] reader, strictly
//! interleaved with the `/train` queue (one queued example, one stream
//! row per iteration — neither source starves the other), sharing the
//! same republish/snapshot machinery. Stream progress is live in
//! `/stats` under `"stream"`, and the `.meb` snapshot is rewritten once
//! more when the file is consumed to EOF.

use std::io::{BufReader, BufWriter, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::stream::FileStream;
use crate::data::hashing::FeatureHasher;
use crate::data::Features;
use crate::error::{Error, Result};
use crate::obs::prom::{render_histogram_samples, PromWriter};
use crate::obs::recorder::Value;
use crate::obs::span_tree;
use crate::obs::Trace;
use crate::svm::HashSpec;
use crate::server::admission::{bounded, Bounded, Endpoint, ServerStats};
use crate::server::cell::ModelCell;
use crate::server::http::{self, HttpRequest, Limits};
use crate::server::json::{self, Json};
use crate::svm::learner::{AnyLearner, Variant};

const JSON_CT: &str = "application/json";
/// Upper bound on `/predict_batch` rows per request.
pub const MAX_BATCH_ROWS: usize = 4096;

/// A `/train` queue item: the validated example plus the admitting
/// request's trace (when traced), so the trainer's absorb span lands in
/// the same tree the client can fetch back at `/debug/trace/<id>`.
type TrainItem = (Features, f32, Option<Trace>);

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`ServerHandle::addr`]).
    pub addr: String,
    /// Handler threads = maximum concurrent connections being served.
    pub threads: usize,
    /// Accepted connections queued beyond busy handlers before the
    /// acceptor sheds with 429. 0 = rendezvous (admit only when a
    /// handler is free).
    pub conn_queue: usize,
    /// `/train` examples buffered ahead of the trainer before the
    /// handler sheds with 429.
    pub train_queue: usize,
    /// Republish the serving snapshot every N absorbed `/train`
    /// examples (the hot-swap interval; `--republish-every` on the CLI).
    pub republish_every: usize,
    /// Persist the published sketch to this `.meb` path on every
    /// republish (atomic tmp+rename via [`crate::sketch::codec::MebSketch`]).
    pub snapshot: Option<PathBuf>,
    /// Per-connection socket read timeout (idle keep-alive cutoff).
    pub read_timeout: Duration,
    /// Provenance tag stamped into published sketches.
    pub tag: String,
    /// HTTP parse limits.
    pub limits: Limits,
    /// Feature-hashing front-end: when set, `/predict*` and `/train`
    /// payloads are hashed on ingest, so wire features may carry
    /// *arbitrary* indices (unbounded vocabularies) and any dense
    /// length; the model itself lives in the hashed dim-`D` space. Must
    /// match the served model's hash spec.
    pub hash: Option<HashSpec>,
    /// Train from this local LIBSVM file in the background, interleaved
    /// with the `/train` queue (`serve --train-stream` on the CLI). The
    /// tolerant [`FileStream`] reader is used: rows stream lazily as
    /// sparse examples, poisoned rows are skipped and counted. With
    /// [`Self::hash`] set the file's indices are unbounded and hashed on
    /// ingest; otherwise out-of-range indices are dropped per row.
    pub train_stream: Option<PathBuf>,
    /// Tail-sampling threshold: a request slower than this many
    /// microseconds has its span tree retained for `GET
    /// /debug/trace/<id>`. Requests carrying a `traceparent` header are
    /// always retained, whatever their latency.
    pub trace_slow_us: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            threads: 8,
            conn_queue: 64,
            train_queue: 1024,
            republish_every: 32,
            snapshot: None,
            read_timeout: Duration::from_secs(5),
            tag: "serve".into(),
            limits: Limits::default(),
            hash: None,
            train_stream: None,
            trace_slow_us: 10_000,
        }
    }
}

/// State shared by every server thread.
struct Shared {
    cell: ModelCell,
    stats: ServerStats,
    train: Bounded<TrainItem>,
    /// Stops the acceptor and the handler pool (checked between requests).
    shutdown: AtomicBool,
    /// Stops the trainer — set only after the handler pool has joined,
    /// so the final drain sees every admitted example.
    trainer_stop: AtomicBool,
    /// Examples absorbed by the trainer.
    trained: AtomicU64,
    started: Instant,
    dim: usize,
    /// Which algorithm the trainer runs (`serve --variant`); labels the
    /// `/stats` payload and the `pallas_serve_variant` info gauge.
    variant: Variant,
    tag: String,
    limits: Limits,
    /// Hash-on-ingest front-end (see [`ServerConfig::hash`]).
    hasher: Option<FeatureHasher>,
    /// A `--train-stream` file feed is configured (drives the `/stats`
    /// `"stream"` object; progress lives in `stats.stream`).
    stream_configured: bool,
    /// Tail-sampling latency threshold (see [`ServerConfig::trace_slow_us`]).
    trace_slow_us: u64,
}

/// A running server; dropping it without [`ServerHandle::shutdown`]
/// leaves the threads serving until the process exits.
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    acceptor: Option<JoinHandle<()>>,
    handlers: Vec<JoinHandle<()>>,
    trainer: Option<JoinHandle<AnyLearner>>,
}

/// Final accounting returned by [`ServerHandle::shutdown`].
#[derive(Debug)]
pub struct ServerReport {
    /// The trainer's final model (every accepted `/train` example absorbed).
    pub model: AnyLearner,
    pub trained: u64,
    /// Last published snapshot version.
    pub version: u64,
    pub requests_ok: u64,
    pub requests_shed: u64,
    pub conns_accepted: u64,
    pub conns_shed: u64,
    /// `--train-stream` rows absorbed by the trainer (0 without one).
    pub stream_rows: u64,
    /// The `--train-stream` file was consumed to EOF before shutdown.
    pub stream_done: bool,
}

/// Start serving `model` according to `cfg`. Any learner variant can be
/// served — pass a concrete learner (the `From` impls convert) or an
/// [`AnyLearner`] built from `serve --variant`. Returns once the
/// listener is bound and all threads are up; serving continues until
/// [`ServerHandle::shutdown`] (or process exit).
pub fn serve(model: impl Into<AnyLearner>, cfg: ServerConfig) -> Result<ServerHandle> {
    let model: AnyLearner = model.into();
    if cfg.threads == 0 {
        return Err(Error::config("server threads must be >= 1"));
    }
    if let Some(spec) = cfg.hash {
        if spec.dim != model.dim() {
            return Err(Error::config(format!(
                "hash dimension {} does not match the served model dimension {}",
                spec.dim,
                model.dim()
            )));
        }
        if model.options().hash != Some(spec) {
            return Err(Error::config(
                "the served model was not trained in the configured hash space \
                 (train it with TrainOptions.hash = the server's spec so snapshot \
                 provenance and ingest hashing agree)",
            ));
        }
    }
    // Open the background train stream up front so a bad path is a
    // synchronous config/io error, not a silent dead trainer feed. When
    // hashing is on, file indices are unbounded (they hash down to D);
    // otherwise the tolerant reader drops out-of-range indices per row.
    let stream = match &cfg.train_stream {
        Some(path) => {
            let raw_dim = if cfg.hash.is_some() { u32::MAX as usize } else { model.dim() };
            Some(FileStream::open(path, raw_dim)?)
        }
        None => None,
    };
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    // Serving turns the training-dynamics telemetry on: `/metrics` must
    // expose live radius/violation-rate gauges while the trainer runs.
    crate::obs::set_telemetry(true);
    // ... and span-tree tracing, so slow requests tail-sample into the
    // retained ring behind `GET /debug/trace/<id>`.
    crate::obs::set_tracing(true);
    crate::obs_info!("server"; addr = addr.to_string(), variant = model.variant().name(), threads = cfg.threads, republish_every = cfg.republish_every; "listening");
    let (train_tx, train_rx) = bounded::<TrainItem>(cfg.train_queue.max(1));
    let shared = Arc::new(Shared {
        cell: ModelCell::new(&model, &cfg.tag),
        stats: ServerStats::default(),
        train: train_tx,
        shutdown: AtomicBool::new(false),
        trainer_stop: AtomicBool::new(false),
        trained: AtomicU64::new(0),
        started: Instant::now(),
        dim: model.dim(),
        variant: model.variant(),
        tag: cfg.tag.clone(),
        limits: cfg.limits,
        hasher: cfg.hash.map(FeatureHasher::from_spec),
        stream_configured: stream.is_some(),
        trace_slow_us: cfg.trace_slow_us,
    });

    let (conn_tx, conn_rx) = bounded::<TcpStream>(cfg.conn_queue);
    let conn_rx = Arc::new(Mutex::new(conn_rx));

    let mut handlers = Vec::with_capacity(cfg.threads);
    for _ in 0..cfg.threads {
        let sh = shared.clone();
        let rx = conn_rx.clone();
        let read_timeout = cfg.read_timeout;
        handlers.push(std::thread::spawn(move || loop {
            // Hold the mutex only while waiting for a hand-off; serving
            // happens with the lock released so the pool stays parallel.
            let next = {
                let guard = match rx.lock() {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
                guard.recv()
            };
            match next {
                Ok(stream) => handle_conn(&sh, read_timeout, stream),
                Err(_) => return, // acceptor gone: shutdown
            }
        }));
    }

    let acceptor = {
        let sh = shared.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                if sh.shutdown.load(Ordering::Acquire) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                match conn_tx.try_admit(stream) {
                    Ok(()) => {
                        sh.stats.conns_accepted.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(stream) => {
                        sh.stats.conns_shed.fetch_add(1, Ordering::Relaxed);
                        shed_connection(stream, sh.limits);
                    }
                }
            }
            // dropping conn_tx here ends the handler pool
        })
    };

    let trainer = {
        let sh = shared.clone();
        let republish_every = cfg.republish_every.max(1);
        let snapshot = cfg.snapshot.clone();
        std::thread::spawn(move || {
            trainer_loop(sh, model, train_rx, republish_every, snapshot, stream)
        })
    };

    Ok(ServerHandle {
        addr,
        shared,
        acceptor: Some(acceptor),
        handlers,
        trainer: Some(trainer),
    })
}

impl ServerHandle {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live stats registry (what `/stats` reports).
    pub fn stats(&self) -> &ServerStats {
        &self.shared.stats
    }

    /// Latest published snapshot version.
    pub fn version(&self) -> u64 {
        self.shared.cell.version()
    }

    /// Examples absorbed by the trainer so far.
    pub fn trained(&self) -> u64 {
        self.shared.trained.load(Ordering::Relaxed)
    }

    /// Block on the acceptor thread forever (the CLI `serve` mode; the
    /// process is expected to be killed externally).
    pub fn run_forever(mut self) -> Result<()> {
        if let Some(a) = self.acceptor.take() {
            a.join().map_err(|_| Error::Pipeline("acceptor thread panicked".into()))?;
        }
        Ok(())
    }

    /// Graceful stop: acceptor first, then the handler pool (each stops
    /// at its next request boundary), then the trainer — which drains
    /// every admitted `/train` example and publishes a final snapshot, so
    /// the returned model reflects all accepted training traffic.
    pub fn shutdown(mut self) -> Result<ServerReport> {
        self.shared.shutdown.store(true, Ordering::Release);
        // Wake the acceptor out of `accept()`.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            a.join().map_err(|_| Error::Pipeline("acceptor thread panicked".into()))?;
        }
        for h in self.handlers.drain(..) {
            h.join().map_err(|_| Error::Pipeline("handler thread panicked".into()))?;
        }
        // Handlers are gone: no new /train admissions can race the drain.
        self.shared.trainer_stop.store(true, Ordering::Release);
        let model = self
            .trainer
            .take()
            .expect("trainer joined once")
            .join()
            .map_err(|_| Error::Pipeline("trainer thread panicked".into()))?;
        let sh = &self.shared;
        Ok(ServerReport {
            model,
            trained: sh.trained.load(Ordering::Relaxed),
            version: sh.cell.version(),
            requests_ok: sh.stats.total_ok(),
            requests_shed: sh.stats.total_shed(),
            conns_accepted: sh.stats.conns_accepted.load(Ordering::Relaxed),
            conns_shed: sh.stats.conns_shed.load(Ordering::Relaxed),
            stream_rows: sh.stats.stream.rows(),
            stream_done: sh.stats.stream.is_done(),
        })
    }
}

/// Cap on concurrent shed-handling threads: beyond it a flood gets a
/// best-effort inline 429 and an immediate close instead of a polite
/// drain, so overload can never translate into unbounded thread spawn.
const MAX_SHED_THREADS: usize = 32;
static SHED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Explicit reject for a connection the pool cannot absorb. Runs on a
/// short-lived thread (bounded by [`MAX_SHED_THREADS`]) so the acceptor
/// never blocks on a slow peer: the pending request is read with the
/// server's own parse limits (draining it avoids a TCP reset racing the
/// reply) and answered 429, never hung.
fn shed_connection(stream: TcpStream, limits: Limits) {
    // A peer that never reads must not block either shed path.
    let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
    if SHED_THREADS.fetch_add(1, Ordering::AcqRel) >= MAX_SHED_THREADS {
        // Flood regime: don't drain, just answer and close. The reply
        // may race a reset if the peer already sent its request, but the
        // rejection stays immediate and the thread count stays bounded.
        SHED_THREADS.fetch_sub(1, Ordering::AcqRel);
        let mut writer = BufWriter::new(stream);
        let _ = http::write_response(
            &mut writer,
            429,
            JSON_CT,
            br#"{"error":"server at capacity"}"#,
            false,
        );
        let _ = writer.flush();
        return;
    }
    std::thread::spawn(move || {
        struct Slot;
        impl Drop for Slot {
            fn drop(&mut self) {
                SHED_THREADS.fetch_sub(1, Ordering::AcqRel);
            }
        }
        let _slot = Slot;
        let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
        let reader = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let mut reader = BufReader::new(reader);
        let _ = http::read_request(&mut reader, &limits);
        let mut writer = BufWriter::new(stream);
        let _ = http::write_response(
            &mut writer,
            429,
            JSON_CT,
            br#"{"error":"server at capacity"}"#,
            false,
        );
        let _ = writer.flush();
    });
}

/// Serve one (keep-alive) connection.
fn handle_conn(sh: &Arc<Shared>, read_timeout: Duration, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(read_timeout));
    let peer = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(peer);
    let mut writer = BufWriter::new(stream);
    loop {
        if sh.shutdown.load(Ordering::Acquire) {
            return;
        }
        let req = match http::read_request_expect(&mut reader, Some(&mut writer), &sh.limits) {
            Ok(Some(r)) => r,
            Ok(None) => return,      // peer closed between requests
            Err(Error::Io(_)) => return, // idle timeout / reset
            Err(_) => {
                // malformed request: explicit 400, then close
                let _ = http::write_response(
                    &mut writer,
                    400,
                    JSON_CT,
                    &err_body("malformed HTTP request"),
                    false,
                );
                let _ = writer.flush();
                return;
            }
        };
        let t0 = Instant::now();
        let start_us = crate::obs::recorder::now_us();
        let keep = !req.wants_close() && !sh.shutdown.load(Ordering::Acquire);
        // Trace when the gate is on, or when the client asked with a
        // `traceparent` header (an explicit ask is honored regardless —
        // and adopts the client's trace id, so both sides of the wire
        // agree on what to look up later).
        let tp = req.header("traceparent").and_then(http::parse_traceparent);
        let trace = if crate::obs::tracing_on() || tp.is_some() {
            let id = tp.map(|t| t.trace_id).unwrap_or_else(span_tree::gen_trace_id);
            Some(Trace::start(id, span_tree::REQUEST_SPAN_CAP))
        } else {
            None
        };
        let (status, ctype, body, ep) = match &trace {
            Some(t) => {
                let _bound = t.bind();
                route(sh, &req)
            }
            None => route(sh, &req),
        };
        let dur_us = t0.elapsed().as_micros() as u64;
        // Server-side duration rides back on every response so clients
        // (the loadgen) can split wire time from handling time.
        let mut extra: Vec<(&str, String)> = vec![("x-pallas-dur-us", dur_us.to_string())];
        if let Some(t) = &trace {
            extra.push(("traceparent", http::format_traceparent(t.id(), t.root_span())));
        }
        if http::write_response_ext(&mut writer, status, ctype, &body, keep, &extra).is_err() {
            return;
        }
        if writer.flush().is_err() {
            return;
        }
        if let Some(t) = trace {
            t.finish_root(
                "server",
                ep.map_or("request", Endpoint::name),
                start_us,
                dur_us,
                vec![
                    ("path", Value::Str(req.path.clone())),
                    ("status", Value::U64(status as u64)),
                ],
            );
            // Tail sampling: explicit traceparent requests are always
            // retained, slow ones besides.
            if tp.is_some() || dur_us >= sh.trace_slow_us {
                span_tree::retain(&t);
            }
        }
        if let Some(ep) = ep {
            if (200..300).contains(&status) {
                sh.stats.record_ok(ep, t0.elapsed());
            } else if status == 429 {
                sh.stats.record_shed(ep);
            } else {
                sh.stats.record_error(ep);
            }
        }
        if !keep {
            return;
        }
    }
}

fn err_body(msg: &str) -> Vec<u8> {
    format!(r#"{{"error":"{}"}}"#, json::escape(msg)).into_bytes()
}

/// Dispatch one request. Returns `(status, content-type, body, endpoint)`;
/// `endpoint = None` for unrouted paths (they are not part of any
/// endpoint's stats).
fn route(sh: &Shared, req: &HttpRequest) -> (u16, &'static str, Vec<u8>, Option<Endpoint>) {
    // `/debug/trace` carries the trace id in the path, so it cannot be
    // an exact-match arm below.
    if req.path == "/debug/trace" || req.path.starts_with("/debug/trace/") {
        if req.method != "GET" {
            return (405, JSON_CT, err_body("method not allowed for this endpoint"), None);
        }
        return match req.path.strip_prefix("/debug/trace/") {
            Some(id) => debug_trace_get(id),
            None => (200, JSON_CT, debug_trace_list().into_bytes(), Some(Endpoint::DebugTrace)),
        };
    }
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/predict") => {
            let (status, body) = handle_predict(sh, &req.body);
            (status, JSON_CT, body, Some(Endpoint::Predict))
        }
        ("POST", "/predict_batch") => {
            let (status, body) = handle_predict_batch(sh, &req.body);
            (status, JSON_CT, body, Some(Endpoint::PredictBatch))
        }
        ("POST", "/train") => {
            let (status, body) = handle_train(sh, &req.body);
            (status, JSON_CT, body, Some(Endpoint::Train))
        }
        ("GET", "/snapshot") => (
            200,
            "application/octet-stream",
            sh.cell.load().sketch.encode(),
            Some(Endpoint::Snapshot),
        ),
        ("GET", "/stats") => (200, JSON_CT, stats_json(sh).into_bytes(), Some(Endpoint::Stats)),
        ("GET", "/metrics") => (
            200,
            "text/plain; version=0.0.4",
            metrics_text(sh).into_bytes(),
            Some(Endpoint::Metrics),
        ),
        ("GET", "/trace") => (200, JSON_CT, trace_json().into_bytes(), Some(Endpoint::Trace)),
        // any other method on a real endpoint is 405, unknown paths 404
        (
            _,
            "/predict" | "/predict_batch" | "/train" | "/snapshot" | "/stats" | "/metrics"
            | "/trace",
        ) => (405, JSON_CT, err_body("method not allowed for this endpoint"), None),
        _ => (404, JSON_CT, err_body("no such endpoint"), None),
    }
}

/// `GET /debug/trace/<id>`: one retained span tree, as rendered by
/// [`TraceShared::to_json`](crate::obs::span_tree::TraceShared::to_json).
fn debug_trace_get(id_hex: &str) -> (u16, &'static str, Vec<u8>, Option<Endpoint>) {
    let ep = Some(Endpoint::DebugTrace);
    let Some(id) = span_tree::parse_trace_id(id_hex) else {
        return (400, JSON_CT, err_body("trace id must be 32 hex chars"), ep);
    };
    match span_tree::find(id) {
        Some(t) => (200, JSON_CT, t.to_json().into_bytes(), ep),
        None => (404, JSON_CT, err_body("no retained trace with that id"), ep),
    }
}

/// `GET /debug/trace`: the retained-trace listing, oldest first.
fn debug_trace_list() -> String {
    let traces = span_tree::retained_summaries();
    let mut out = String::with_capacity(32 + traces.len() * 72);
    out.push_str("{\"traces\":[");
    for (i, (id, spans, root_dur)) in traces.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"trace_id\":\"");
        out.push_str(&span_tree::fmt_trace_id(*id));
        out.push_str("\",\"spans\":");
        out.push_str(&spans.to_string());
        out.push_str(",\"root_dur_us\":");
        match root_dur {
            Some(d) => out.push_str(&d.to_string()),
            None => out.push_str("null"),
        }
        out.push('}');
    }
    out.push_str("]}");
    out
}

fn parse_body(body: &[u8]) -> Option<Json> {
    std::str::from_utf8(body).ok().and_then(|s| Json::parse(s).ok())
}

const BODY_SHAPE: &str = r#"body must carry features as "x":[...] or "idx":[...],"val":[...]"#;
const BATCH_SHAPE: &str = r#"body must be {"xs":[[...],...]} or {"rows":[{"x":[...]} | {"idx":[...],"val":[...]}, ...]}"#;

/// Validate a dense feature vector at the protocol boundary and (when a
/// hasher is configured) fold it into the model's hash space. Non-finite
/// features would poison the ball geometry on `/train` (inf radius
/// forever, then persisted to the snapshot) and produce meaningless
/// scores on `/predict` — both are client errors, rejected with the
/// returned message. Without a hasher the length must equal the model
/// dimension; with one, any length hashes down to `D`.
fn dense_features(
    x: Vec<f32>,
    dim: usize,
    hasher: Option<&FeatureHasher>,
) -> std::result::Result<Features, String> {
    if let Some(i) = x.iter().position(|v| !v.is_finite()) {
        return Err(format!("x[{i}] is not finite"));
    }
    match hasher {
        Some(h) => Ok(h.hash_features(&Features::Dense(x))),
        None => {
            if x.len() != dim {
                return Err(format!("x has dimension {}, model expects {dim}", x.len()));
            }
            Ok(Features::Dense(x))
        }
    }
}

/// Validate a sparse `idx`/`val` payload (parallel arrays, finite
/// values). Without a hasher the indices must be 0-based, strictly
/// increasing and in the model's range; with one they may be *arbitrary*
/// u32 in *any* order, duplicates included (the hasher sorts and
/// accumulates) — the hash front-end is exactly what makes unbounded
/// wire vocabularies legal.
fn sparse_features(
    idx: Vec<u32>,
    val: Vec<f32>,
    dim: usize,
    hasher: Option<&FeatureHasher>,
) -> std::result::Result<Features, String> {
    if idx.len() != val.len() {
        return Err(format!("idx has {} entries but val has {}", idx.len(), val.len()));
    }
    if let Some(i) = val.iter().position(|v| !v.is_finite()) {
        return Err(format!("val[{i}] is not finite"));
    }
    match hasher {
        Some(h) => Ok(h.hash_pairs(&idx, &val)),
        None => {
            if !idx.windows(2).all(|w| w[0] < w[1]) {
                return Err("idx must be strictly increasing".into());
            }
            if let Some(&last) = idx.last() {
                if last as usize >= dim {
                    return Err(format!(
                        "idx {last} is out of range for model dimension {dim}"
                    ));
                }
            }
            Ok(Features::sparse(dim, idx, val))
        }
    }
}

/// Extract the feature payload from a parsed body: dense `{"x":[...]}`
/// or sparse `{"idx":[...],"val":[...]}`. `Err` is the 400 message.
fn parse_features(
    parsed: Option<&Json>,
    dim: usize,
    hasher: Option<&FeatureHasher>,
) -> std::result::Result<Features, String> {
    let body = parsed.ok_or_else(|| BODY_SHAPE.to_string())?;
    if let Some(xv) = body.get("x") {
        let x = xv.f32_vec().ok_or_else(|| BODY_SHAPE.to_string())?;
        return dense_features(x, dim, hasher);
    }
    let idx = body.get("idx").and_then(|v| v.u32_vec());
    let val = body.get("val").and_then(|v| v.f32_vec());
    match (idx, val) {
        (Some(idx), Some(val)) => sparse_features(idx, val, dim, hasher),
        _ => Err(BODY_SHAPE.to_string()),
    }
}

fn handle_predict(sh: &Shared, body: &[u8]) -> (u16, Vec<u8>) {
    let parsed = parse_body(body);
    let x = match parse_features(parsed.as_ref(), sh.dim, sh.hasher.as_ref()) {
        Ok(x) => x,
        Err(e) => return (400, err_body(&e)),
    };
    let snap = sh.cell.load();
    let score = snap.score_view(x.view());
    (
        200,
        format!(
            r#"{{"score":{},"version":{},"seen":{}}}"#,
            json::fmt_num(score),
            snap.version,
            snap.seen
        )
        .into_bytes(),
    )
}

fn handle_predict_batch(sh: &Shared, body: &[u8]) -> (u16, Vec<u8>) {
    let parsed = parse_body(body);
    let obj = match parsed.as_ref() {
        Some(v) => v,
        None => return (400, err_body(BATCH_SHAPE)),
    };
    // Two shapes: legacy `"xs"` (dense rows as bare arrays) and `"rows"`
    // (row objects in the same dense-or-sparse shape `/predict` takes,
    // freely mixed within one request).
    let (rows, shaped) = match (
        obj.get("xs").and_then(|v| v.as_array()),
        obj.get("rows").and_then(|v| v.as_array()),
    ) {
        (Some(xs), None) => (xs, false),
        (None, Some(rows)) => (rows, true),
        _ => return (400, err_body(BATCH_SHAPE)),
    };
    if rows.len() > MAX_BATCH_ROWS {
        return (
            413,
            err_body(&format!("{} rows exceeds the {MAX_BATCH_ROWS} row limit", rows.len())),
        );
    }
    // One snapshot for the whole batch: every row scores against the
    // same published version.
    let snap = sh.cell.load();
    let hasher = sh.hasher.as_ref();
    let mut scores = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let feats = if shaped {
            parse_features(Some(row), sh.dim, hasher)
        } else {
            row.f32_vec()
                .ok_or_else(|| "not a numeric vector".to_string())
                .and_then(|x| dense_features(x, sh.dim, hasher))
        };
        match feats {
            Ok(f) => scores.push(json::fmt_num(snap.score_view(f.view()))),
            Err(e) => return (400, err_body(&format!("row {i}: {e}"))),
        }
    }
    (
        200,
        format!(
            r#"{{"scores":[{}],"version":{},"seen":{}}}"#,
            scores.join(","),
            snap.version,
            snap.seen
        )
        .into_bytes(),
    )
}

fn handle_train(sh: &Shared, body: &[u8]) -> (u16, Vec<u8>) {
    let parsed = parse_body(body);
    let y = match parsed.as_ref().and_then(|v| v.get("y")).and_then(|v| v.as_f64()) {
        Some(y) => y as f32,
        None => return (400, err_body(r#"body must be {"x":[...]|"idx"/"val",  "y":±1}"#)),
    };
    if y != 1.0 && y != -1.0 {
        return (400, err_body("y must be 1 or -1"));
    }
    let x = match parse_features(parsed.as_ref(), sh.dim, sh.hasher.as_ref()) {
        Ok(x) => x,
        Err(e) => return (400, err_body(&e)),
    };
    match sh.train.try_admit((x, y, span_tree::current_trace())) {
        Ok(()) => (
            202,
            format!(r#"{{"accepted":true,"version":{}}}"#, sh.cell.version()).into_bytes(),
        ),
        Err(_) => (429, err_body("train queue full")),
    }
}

fn stats_json(sh: &Shared) -> String {
    let snap = sh.cell.load();
    let stream = if sh.stream_configured {
        format!(
            r#"{{"rows":{},"skipped":{},"done":{}}}"#,
            sh.stats.stream.rows(),
            sh.stats.stream.skipped_rows(),
            sh.stats.stream.is_done(),
        )
    } else {
        "null".into()
    };
    let mut out = String::with_capacity(1024);
    out.push_str(&format!(
        r#"{{"version":{},"variant":"{}","generation":{},"republishes":{},"seen":{},"radius":{},"supports":{},"trained":{},"stream":{},"hash_dim":{},"uptime_s":{},"conns":{{"accepted":{},"shed":{}}},"endpoints":{{"#,
        snap.version,
        sh.variant.name(),
        sh.cell.version(),
        sh.cell.publishes(),
        snap.seen,
        json::fmt_num(snap.radius),
        snap.supports,
        sh.trained.load(Ordering::Relaxed),
        stream,
        sh.hasher.as_ref().map(|h| h.dim().to_string()).unwrap_or_else(|| "null".into()),
        json::fmt_num(sh.started.elapsed().as_secs_f64()),
        sh.stats.conns_accepted.load(Ordering::Relaxed),
        sh.stats.conns_shed.load(Ordering::Relaxed),
    ));
    for (i, ep) in Endpoint::ALL.iter().enumerate() {
        let s = sh.stats.snapshot(*ep);
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            r#""{}":{{"ok":{},"shed":{},"errors":{},"mean_us":{},"p50_us":{},"p90_us":{},"p99_us":{},"max_us":{}}}"#,
            ep.name(),
            s.ok,
            s.shed,
            s.errors,
            s.latency.mean().as_micros(),
            s.latency.quantile(0.50).as_micros(),
            s.latency.quantile(0.90).as_micros(),
            s.latency.quantile(0.99).as_micros(),
            s.latency.max().as_micros(),
        ));
    }
    out.push_str("}}");
    out
}

/// The `GET /metrics` body: full Prometheus text exposition — server
/// request/connection counters, per-endpoint latency histograms mapped
/// from the log₂-bucket layout, hot-swap bookkeeping, `--train-stream`
/// progress, and every registered training-dynamics counter/gauge (the
/// live radius / violation-rate / merge signals). Validated end-to-end
/// by [`crate::obs::prom::check_exposition`] in `serve_http.rs` and the
/// CI smoke.
fn metrics_text(sh: &Shared) -> String {
    let mut w = PromWriter::new();

    w.header("pallas_build_info", "Constant 1; build metadata rides on the labels.", "gauge");
    w.sample(
        "pallas_build_info",
        &[
            ("version", env!("CARGO_PKG_VERSION")),
            ("features", if cfg!(feature = "pjrt") { "pjrt" } else { "default" }),
        ],
        1.0,
    );
    w.header("pallas_uptime_seconds", "Seconds since the server started.", "gauge");
    w.sample("pallas_uptime_seconds", &[], sh.started.elapsed().as_secs_f64());
    w.header(
        "pallas_serve_variant",
        "Constant 1; the served learner variant rides on the label.",
        "gauge",
    );
    w.sample("pallas_serve_variant", &[("variant", sh.variant.name())], 1.0);
    w.header(
        "pallas_model_generation",
        "Version of the currently published model snapshot.",
        "gauge",
    );
    w.sample("pallas_model_generation", &[], sh.cell.version() as f64);
    w.header(
        "pallas_model_publishes_total",
        "Hot-swap republishes since the server started.",
        "counter",
    );
    w.sample("pallas_model_publishes_total", &[], sh.cell.publishes() as f64);
    w.header(
        "pallas_trained_examples_total",
        "Examples absorbed by the background trainer.",
        "counter",
    );
    w.sample("pallas_trained_examples_total", &[], sh.trained.load(Ordering::Relaxed) as f64);

    w.header("pallas_connections_total", "Connections by admission outcome.", "counter");
    w.sample(
        "pallas_connections_total",
        &[("outcome", "accepted")],
        sh.stats.conns_accepted.load(Ordering::Relaxed) as f64,
    );
    w.sample(
        "pallas_connections_total",
        &[("outcome", "shed")],
        sh.stats.conns_shed.load(Ordering::Relaxed) as f64,
    );

    w.header("pallas_requests_total", "2xx-answered requests by endpoint.", "counter");
    let snaps: Vec<_> = Endpoint::ALL.iter().map(|&ep| (ep, sh.stats.snapshot(ep))).collect();
    for (ep, s) in &snaps {
        w.sample("pallas_requests_total", &[("endpoint", ep.name())], s.ok as f64);
    }
    w.header(
        "pallas_requests_shed_total",
        "Requests rejected by admission control (429), by endpoint.",
        "counter",
    );
    for (ep, s) in &snaps {
        w.sample("pallas_requests_shed_total", &[("endpoint", ep.name())], s.shed as f64);
    }
    w.header(
        "pallas_request_errors_total",
        "Malformed or failed requests (non-429 4xx/5xx), by endpoint.",
        "counter",
    );
    for (ep, s) in &snaps {
        w.sample("pallas_request_errors_total", &[("endpoint", ep.name())], s.errors as f64);
    }
    w.header(
        "pallas_request_latency_seconds",
        "Admission-to-response latency of 2xx requests, by endpoint.",
        "histogram",
    );
    for (ep, s) in &snaps {
        render_histogram_samples(
            &mut w,
            "pallas_request_latency_seconds",
            &[("endpoint", ep.name())],
            &s.latency,
        );
    }

    if sh.stream_configured {
        w.header(
            "pallas_stream_rows_total",
            "Rows absorbed from the --train-stream file.",
            "counter",
        );
        w.sample("pallas_stream_rows_total", &[], sh.stats.stream.rows() as f64);
        w.header(
            "pallas_stream_skipped_total",
            "Stream rows skipped or rejected.",
            "counter",
        );
        w.sample("pallas_stream_skipped_total", &[], sh.stats.stream.skipped_rows() as f64);
        w.header(
            "pallas_stream_done",
            "1 once the --train-stream file is consumed to EOF.",
            "gauge",
        );
        w.sample("pallas_stream_done", &[], if sh.stats.stream.is_done() { 1.0 } else { 0.0 });
    }

    crate::obs::prom::render_registry(&mut w);
    w.finish()
}

/// The `GET /trace` body: the recorder's ring buffer of recent events
/// as a JSON array, oldest first, plus how many events the bounded ring
/// has dropped since startup (so a gap in the log is never silent).
fn trace_json() -> String {
    let events = crate::obs::recent_events();
    let mut out = String::with_capacity(96 + events.len() * 96);
    out.push_str("{\"dropped\":");
    out.push_str(&crate::obs::telemetry::OBS_EVENTS_DROPPED.get().to_string());
    out.push_str(",\"events\":[");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&ev.to_json());
    }
    out.push_str("]}");
    out
}

/// The background trainer: consume admitted examples (and, when
/// configured, a local `--train-stream` file, strictly interleaved so
/// neither source starves the other), republish the hot-swap snapshot
/// every `republish_every` absorbed examples across both sources,
/// persist the sketch if configured, and drain exactly once at
/// shutdown. Stream EOF triggers one extra republish + snapshot so the
/// persisted `.meb` reflects the fully-streamed model.
fn trainer_loop(
    sh: Arc<Shared>,
    mut model: AnyLearner,
    rx: Receiver<TrainItem>,
    republish_every: usize,
    snapshot: Option<PathBuf>,
    mut stream: Option<FileStream<std::fs::File>>,
) -> AnyLearner {
    let mut since_publish = 0usize;
    // Stream rows the trainer's validated entry point rejected (counted
    // into the live `skipped` stat so `rows + skipped` always accounts
    // for every row the reader produced or dropped).
    let mut stream_rejected = 0u64;
    // Admitted examples were validated at the protocol boundary, but the
    // fallible entry point keeps a defective example (e.g. a dim change
    // across hot-swap experiments) from panicking the trainer thread.
    // Queue items carry the admitting request's trace: binding it here
    // parents the absorb span (and the ball-geometry spans under it)
    // into the tree the client fetches at `/debug/trace/<id>`.
    fn absorb(model: &mut AnyLearner, x: Features, y: f32, trace: Option<&Trace>) -> bool {
        let _bound = trace.map(Trace::bind);
        let _span = crate::obs::span("server", "train_absorb");
        match model.try_observe(x.view(), y) {
            Ok(_) => true,
            Err(e) => {
                crate::obs_warn!("server", "trainer rejected an admitted example: {e}");
                false
            }
        }
    }
    loop {
        if sh.trainer_stop.load(Ordering::Acquire) {
            // The handler pool has joined: this drain is exact. The file
            // stream is left wherever it is — its progress (and that it
            // did not finish) stays visible in the stats.
            while let Ok((x, y, t)) = rx.try_recv() {
                if absorb(&mut model, x, y, t.as_ref()) {
                    sh.trained.fetch_add(1, Ordering::Relaxed);
                    since_publish += 1;
                }
            }
            break;
        }
        let mut progressed = false;
        // one queued /train example (non-blocking: wire traffic never
        // waits behind the file stream)
        if let Ok((x, y, t)) = rx.try_recv() {
            if absorb(&mut model, x, y, t.as_ref()) {
                sh.trained.fetch_add(1, Ordering::Relaxed);
                since_publish += 1;
            }
            progressed = true;
        }
        // one file-stream row
        let mut stream_finished = false;
        if let Some(s) = stream.as_mut() {
            match s.next() {
                Some(e) => {
                    let e = match &sh.hasher {
                        Some(h) => h.hash_example(&e),
                        None => e,
                    };
                    if absorb(&mut model, e.x, e.y, None) {
                        sh.stats.stream.record_row();
                        since_publish += 1;
                    } else {
                        stream_rejected += 1;
                    }
                    sh.stats.stream.set_skipped(s.rows_skipped() as u64 + stream_rejected);
                    progressed = true;
                }
                None => {
                    sh.stats.stream.set_skipped(s.rows_skipped() as u64 + stream_rejected);
                    sh.stats.stream.finish();
                    stream_finished = true;
                }
            }
        }
        if stream_finished {
            stream = None;
            // EOF republish: the published snapshot (and the persisted
            // .meb) must include the whole stream.
            since_publish = 0;
            publish(&sh, &model, &snapshot);
        }
        if progressed {
            if since_publish >= republish_every {
                since_publish = 0;
                publish(&sh, &model, &snapshot);
            }
            continue;
        }
        // both sources idle: block briefly on the queue, then re-check
        // the stop flag at the top of the loop
        match rx.recv_timeout(Duration::from_millis(20)) {
            Ok((x, y, t)) => {
                if absorb(&mut model, x, y, t.as_ref()) {
                    sh.trained.fetch_add(1, Ordering::Relaxed);
                    since_publish += 1;
                }
                if since_publish >= republish_every {
                    since_publish = 0;
                    publish(&sh, &model, &snapshot);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    if since_publish > 0 {
        publish(&sh, &model, &snapshot);
    }
    model
}

fn publish(sh: &Shared, model: &AnyLearner, snapshot: &Option<PathBuf>) {
    sh.cell.publish(model, &sh.tag);
    if let Some(path) = snapshot {
        if let Err(e) = sh.cell.load().sketch.write_to(path) {
            crate::obs_warn!("server", "serving snapshot write failed: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::streamsvm::StreamSvm;
    use crate::svm::TrainOptions;

    fn toy_model() -> StreamSvm {
        let mut m = StreamSvm::new(2, TrainOptions::default());
        m.observe(&[1.0, -2.0], 1.0);
        m.observe(&[-1.0, 2.0], -1.0);
        m
    }

    fn route_raw(sh: &Shared, method: &str, path: &str, body: &[u8]) -> (u16, Vec<u8>) {
        let req = HttpRequest {
            method: method.into(),
            path: path.into(),
            headers: vec![],
            body: body.to_vec(),
        };
        let (status, _ct, body, _ep) = route(sh, &req);
        (status, body)
    }

    fn test_shared(train_queue: usize) -> (Arc<Shared>, Receiver<TrainItem>) {
        test_shared_hashed(train_queue, None)
    }

    fn test_shared_hashed(
        train_queue: usize,
        hash: Option<HashSpec>,
    ) -> (Arc<Shared>, Receiver<TrainItem>) {
        let model = AnyLearner::from(toy_model());
        let (train_tx, train_rx) = bounded(train_queue);
        let sh = Arc::new(Shared {
            cell: ModelCell::new(&model, "t"),
            stats: ServerStats::default(),
            train: train_tx,
            shutdown: AtomicBool::new(false),
            trainer_stop: AtomicBool::new(false),
            trained: AtomicU64::new(0),
            started: Instant::now(),
            dim: 2,
            variant: model.variant(),
            tag: "t".into(),
            limits: Limits::default(),
            hasher: hash.map(FeatureHasher::from_spec),
            stream_configured: false,
            trace_slow_us: 10_000,
        });
        (sh, train_rx)
    }

    #[test]
    fn predict_routes_and_scores() {
        let (sh, _rx) = test_shared(4);
        let (status, body) = route_raw(&sh, "POST", "/predict", br#"{"x":[1.0,0.0]}"#);
        assert_eq!(status, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let score = v.get("score").unwrap().as_f64().unwrap();
        assert!(score.is_finite());
        assert_eq!(v.get("version").unwrap().as_f64(), Some(1.0));

        // wrong dim and malformed bodies are explicit 400s
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"x":[1,2,3]}"#).0, 400);
        assert_eq!(route_raw(&sh, "POST", "/predict", b"not json").0, 400);
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"y":1}"#).0, 400);
        // non-finite features are rejected, not scored (1e999 → inf, and
        // 3.5e38 overflows the f32 cast)
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"x":[1e999,0]}"#).0, 400);
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"x":[3.5e38,0]}"#).0, 400);
    }

    #[test]
    fn predict_batch_scores_rows_against_one_version() {
        let (sh, _rx) = test_shared(4);
        let (status, body) =
            route_raw(&sh, "POST", "/predict_batch", br#"{"xs":[[1,0],[0,1],[2,2]]}"#);
        assert_eq!(status, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let scores = v.get("scores").unwrap().as_array().unwrap();
        assert_eq!(scores.len(), 3);
        assert_eq!(route_raw(&sh, "POST", "/predict_batch", br#"{"xs":[[1,2,3]]}"#).0, 400);
    }

    #[test]
    fn train_admits_then_sheds_when_full() {
        let (sh, rx) = test_shared(2);
        assert_eq!(route_raw(&sh, "POST", "/train", br#"{"x":[1,0],"y":1}"#).0, 202);
        assert_eq!(route_raw(&sh, "POST", "/train", br#"{"x":[0,1],"y":-1}"#).0, 202);
        // queue depth 2, trainer not draining → explicit 429
        let (status, body) = route_raw(&sh, "POST", "/train", br#"{"x":[1,1],"y":1}"#);
        assert_eq!(status, 429);
        assert!(String::from_utf8(body).unwrap().contains("train queue full"));
        // bad label / bad dim / non-finite features never reach the queue
        assert_eq!(route_raw(&sh, "POST", "/train", br#"{"x":[1,0],"y":0.5}"#).0, 400);
        assert_eq!(route_raw(&sh, "POST", "/train", br#"{"x":[1],"y":1}"#).0, 400);
        assert_eq!(route_raw(&sh, "POST", "/train", br#"{"x":[1e999,0],"y":1}"#).0, 400);
        drop(rx);
    }

    #[test]
    fn sparse_predict_and_train_payloads() {
        let (sh, rx) = test_shared(4);
        // sparse predict scores identically to the equivalent dense body
        let (s1, b1) = route_raw(&sh, "POST", "/predict", br#"{"x":[1.0,0.0]}"#);
        let (s2, b2) = route_raw(&sh, "POST", "/predict", br#"{"idx":[0],"val":[1.0]}"#);
        assert_eq!((s1, s2), (200, 200));
        let score = |b: &[u8]| {
            Json::parse(std::str::from_utf8(b).unwrap())
                .unwrap()
                .get("score")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert_eq!(score(&b1), score(&b2));
        // the all-zeros sparse vector is valid
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"idx":[],"val":[]}"#).0, 200);
        // malformed sparse payloads are explicit 400s, never 500s
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"idx":[0,1],"val":[1.0]}"#).0, 400);
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"idx":[1,0],"val":[1,2]}"#).0, 400);
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"idx":[2],"val":[1.0]}"#).0, 400);
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"idx":[0],"val":[1e999]}"#).0, 400);
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"idx":[0]}"#).0, 400);
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"idx":[-1],"val":[1.0]}"#).0, 400);
        // sparse /train admits the example to the queue *as sparse*
        assert_eq!(
            route_raw(&sh, "POST", "/train", br#"{"idx":[1],"val":[2.0],"y":-1}"#).0,
            202
        );
        let (x, y, _) = rx.try_recv().unwrap();
        assert_eq!(y, -1.0);
        assert_eq!(x.nnz(), 1);
        assert_eq!(x.dense().as_ref(), &[0.0, 2.0]);
    }

    #[test]
    fn predict_batch_rows_mixes_dense_and_sparse() {
        let (sh, _rx) = test_shared(4);
        // the sparse row is the same vector as the dense one: equal scores
        let (status, body) = route_raw(
            &sh,
            "POST",
            "/predict_batch",
            br#"{"rows":[{"x":[1.0,0.0]},{"idx":[0],"val":[1.0]},{"idx":[],"val":[]}]}"#,
        );
        assert_eq!(status, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let scores = v.get("scores").unwrap().as_array().unwrap();
        assert_eq!(scores.len(), 3);
        assert_eq!(scores[0].as_f64(), scores[1].as_f64());
        assert_eq!(scores[2].as_f64(), Some(0.0));
        // same idx/val validation as /predict: bad rows are explicit 400s
        for bad in [
            br#"{"rows":[{"idx":[0,1],"val":[1.0]}]}"#.as_slice(),
            br#"{"rows":[{"idx":[1,0],"val":[1,2]}]}"#.as_slice(),
            br#"{"rows":[{"idx":[2],"val":[1.0]}]}"#.as_slice(),
            br#"{"rows":[{"idx":[0],"val":[1e999]}]}"#.as_slice(),
            br#"{"rows":[{"y":1}]}"#.as_slice(),
            br#"{"rows":[[1,0]],"xs":[[1,0]]}"#.as_slice(),
        ] {
            let (status, body) = route_raw(&sh, "POST", "/predict_batch", bad);
            assert_eq!(status, 400, "{}", String::from_utf8_lossy(bad));
            assert!(!body.is_empty());
        }
        // error messages carry the failing row index
        let (_, body) = route_raw(
            &sh,
            "POST",
            "/predict_batch",
            br#"{"rows":[{"x":[1.0,0.0]},{"idx":[9],"val":[1.0]}]}"#,
        );
        assert!(String::from_utf8(body).unwrap().contains("row 1"));
    }

    #[test]
    fn hashed_ingest_accepts_arbitrary_indices() {
        let spec = HashSpec { dim: 2, seed: 42 };
        let (sh, rx) = test_shared_hashed(4, Some(spec));
        let h = FeatureHasher::from_spec(spec);
        // out-of-range indices are legal now: they hash into [0, D)
        let (status, body) =
            route_raw(&sh, "POST", "/predict", br#"{"idx":[123456789],"val":[2.0]}"#);
        assert_eq!(status, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let got = v.get("score").unwrap().as_f64().unwrap();
        let want = {
            let snap = sh.cell.load();
            snap.score_view(h.hash_pairs(&[123456789], &[2.0]).view())
        };
        assert_eq!(got, want, "served score must equal hashing then scoring");
        // dense payloads of any length hash down to D
        assert_eq!(
            route_raw(&sh, "POST", "/predict", br#"{"x":[1,2,3,4,5,6,7]}"#).0,
            200
        );
        // /train admits the hashed example (dim D on the queue)
        assert_eq!(
            route_raw(&sh, "POST", "/train", br#"{"idx":[7,900000],"val":[1.0,1.0],"y":1}"#).0,
            202
        );
        let (x, _y, _) = rx.try_recv().unwrap();
        assert_eq!(x.len(), 2);
        assert_eq!(x, h.hash_pairs(&[7, 900000], &[1.0, 1.0]));
        // batch rows hash too
        let (status, _) = route_raw(
            &sh,
            "POST",
            "/predict_batch",
            br#"{"rows":[{"idx":[31337],"val":[1.0]},{"x":[1,2,3]}]}"#,
        );
        assert_eq!(status, 200);
        // hashed ingest accepts any index order and duplicates (the
        // hasher sorts and accumulates) — equal score either way
        let (s_sorted, b_sorted) =
            route_raw(&sh, "POST", "/predict", br#"{"idx":[2,5],"val":[2.0,1.0]}"#);
        let (s_unsorted, b_unsorted) =
            route_raw(&sh, "POST", "/predict", br#"{"idx":[5,2],"val":[1.0,2.0]}"#);
        assert_eq!((s_sorted, s_unsorted), (200, 200));
        let score = |b: &[u8]| {
            Json::parse(std::str::from_utf8(b).unwrap())
                .unwrap()
                .get("score")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert_eq!(score(&b_sorted), score(&b_unsorted));
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"idx":[7,7],"val":[1,1]}"#).0, 200);
        // still-invalid payloads stay rejected: NaN values, length mismatch
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"idx":[0],"val":[1e999]}"#).0, 400);
        assert_eq!(route_raw(&sh, "POST", "/predict", br#"{"idx":[5,2],"val":[1.0]}"#).0, 400);
        // ... and the unhashed server still requires sorted indices
        let (plain, _rx2) = test_shared(4);
        assert_eq!(route_raw(&plain, "POST", "/predict", br#"{"idx":[1,0],"val":[1,2]}"#).0, 400);
    }

    #[test]
    fn serve_rejects_mismatched_hash_config() {
        // model not trained in the hash space → explicit config error
        let model = toy_model();
        let cfg = ServerConfig {
            hash: Some(HashSpec { dim: 2, seed: 1 }),
            ..Default::default()
        };
        let err = serve(model, cfg).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // hash dim disagreeing with the model dim → config error
        let mut m = StreamSvm::new(4, TrainOptions::default().with_hash(Some(HashSpec { dim: 2, seed: 1 })));
        m.observe(&[1.0, 0.0, 0.0, 0.0], 1.0);
        let cfg = ServerConfig {
            hash: Some(HashSpec { dim: 2, seed: 1 }),
            ..Default::default()
        };
        let err = serve(m, cfg).unwrap_err();
        assert!(err.to_string().contains("dimension"), "{err}");
    }

    #[test]
    fn snapshot_returns_decodable_sketch_bytes() {
        use crate::sketch::codec::MebSketch;
        let (sh, _rx) = test_shared(4);
        let (status, body) = route_raw(&sh, "GET", "/snapshot", b"");
        assert_eq!(status, 200);
        let sk = MebSketch::decode(&body).unwrap();
        assert_eq!(sk.dim, 2);
        assert_eq!(sk.to_model().weights(), toy_model().weights());
    }

    #[test]
    fn stats_is_valid_json_with_all_endpoints() {
        let (sh, _rx) = test_shared(4);
        sh.stats.record_ok(Endpoint::Predict, Duration::from_micros(120));
        let (status, body) = route_raw(&sh, "GET", "/stats", b"");
        assert_eq!(status, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("version").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("variant").and_then(|x| x.as_str()), Some("ball"));
        // no --train-stream configured → explicit null, not a stale object
        assert_eq!(v.get("stream"), Some(&Json::Null));
        let eps = v.get("endpoints").unwrap();
        for ep in Endpoint::ALL {
            assert!(eps.get(ep.name()).is_some(), "missing endpoint {}", ep.name());
        }
        assert_eq!(
            eps.get("predict").unwrap().get("ok").unwrap().as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn stats_reports_generation_and_republishes() {
        let (sh, _rx) = test_shared(4);
        sh.cell.publish(&AnyLearner::from(toy_model()), "t");
        sh.cell.publish(&AnyLearner::from(toy_model()), "t");
        let (status, body) = route_raw(&sh, "GET", "/stats", b"");
        assert_eq!(status, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("generation").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("republishes").unwrap().as_f64(), Some(2.0));
        assert!(v.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn metrics_is_valid_prometheus_exposition() {
        let (sh, _rx) = test_shared(4);
        sh.stats.record_ok(Endpoint::Predict, Duration::from_micros(120));
        sh.stats.record_ok(Endpoint::Predict, Duration::from_micros(480));
        sh.stats.record_shed(Endpoint::Train);
        let (status, ctype, body, ep) = {
            let req = HttpRequest {
                method: "GET".into(),
                path: "/metrics".into(),
                headers: vec![],
                body: vec![],
            };
            route(&sh, &req)
        };
        assert_eq!(status, 200);
        assert!(ctype.starts_with("text/plain"), "{ctype}");
        assert_eq!(ep, Some(Endpoint::Metrics));
        let text = String::from_utf8(body).unwrap();
        let families = crate::obs::prom::check_exposition(&text)
            .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{text}"));
        assert!(families >= 20, "only {families} families");
        // request counters present with endpoint labels
        assert!(text.contains("pallas_requests_total{endpoint=\"predict\"} 2\n"), "{text}");
        assert!(text.contains("pallas_requests_shed_total{endpoint=\"train\"} 1\n"));
        // latency histogram buckets from the log₂ layout, +Inf included
        assert!(text.contains("pallas_request_latency_seconds_bucket{endpoint=\"predict\",le=\"+Inf\"} 2\n"));
        assert!(text.contains("pallas_request_latency_seconds_count{endpoint=\"predict\"} 2\n"));
        // build metadata and the served variant ride info-style gauges
        assert!(text.contains("pallas_build_info{version=\""), "{text}");
        assert!(text.contains("pallas_serve_variant{variant=\"ball\"} 1\n"), "{text}");
        assert!(text.contains(concat!("version=\"", env!("CARGO_PKG_VERSION"), "\"")));
        // hot-swap bookkeeping and the training gauges are exposed
        assert!(text.contains("pallas_model_generation 1\n"));
        assert!(text.contains("pallas_model_publishes_total 0\n"));
        assert!(text.contains("pallas_train_radius"));
        assert!(text.contains("pallas_train_violation_rate"));
        assert!(text.contains("pallas_train_merges_total"));
        // no --train-stream → no stream families
        assert!(!text.contains("pallas_stream_rows_total"));
    }

    #[test]
    fn trace_returns_ring_buffer_json() {
        let _g = crate::obs::recorder::test_lock();
        crate::obs::configure(None, Some(crate::obs::Level::Info));
        crate::obs::recorder::clear_ring();
        let (sh, _rx) = test_shared(4);
        crate::obs_info!("server"; version = 7u64; "trace test event");
        let (status, body) = route_raw(&sh, "GET", "/trace", b"");
        assert_eq!(status, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert!(v.get("dropped").and_then(|d| d.as_f64()).is_some(), "drop accounting");
        let events = v.get("events").unwrap().as_array().unwrap();
        let ev = events
            .iter()
            .find(|e| e.get("msg").and_then(|m| m.as_str()) == Some("trace test event"))
            .expect("emitted event present in /trace");
        assert_eq!(ev.get("level").and_then(|l| l.as_str()), Some("info"));
        assert_eq!(
            ev.get("fields").and_then(|f| f.get("version")).and_then(|x| x.as_f64()),
            Some(7.0)
        );
        crate::obs::configure(Some(crate::obs::Level::Warn), Some(crate::obs::Level::Info));
        crate::obs::recorder::clear_ring();
    }

    #[test]
    fn debug_trace_serves_retained_traces() {
        let _g = crate::obs::recorder::test_lock();
        span_tree::clear_retained();
        let (sh, _rx) = test_shared(4);
        let t = Trace::start(span_tree::gen_trace_id(), 16);
        t.finish_root("test", "req", 0, 42, vec![]);
        span_tree::retain(&t);
        let hex = span_tree::fmt_trace_id(t.id());
        let (status, body) = route_raw(&sh, "GET", &format!("/debug/trace/{hex}"), b"");
        assert_eq!(status, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("trace_id").and_then(|x| x.as_str()), Some(hex.as_str()));
        assert_eq!(v.get("root_dur_us").and_then(|x| x.as_f64()), Some(42.0));
        // the listing carries the same id
        let (status, body) = route_raw(&sh, "GET", "/debug/trace", b"");
        assert_eq!(status, 200);
        assert!(String::from_utf8(body).unwrap().contains(&hex));
        // unknown id → 404, malformed id → 400, wrong method → 405
        let missing = span_tree::fmt_trace_id(span_tree::gen_trace_id());
        assert_eq!(route_raw(&sh, "GET", &format!("/debug/trace/{missing}"), b"").0, 404);
        assert_eq!(route_raw(&sh, "GET", "/debug/trace/xyz", b"").0, 400);
        assert_eq!(route_raw(&sh, "POST", "/debug/trace", b"").0, 405);
        span_tree::clear_retained();
    }

    #[test]
    fn traced_train_ships_the_trace_down_the_queue() {
        let (sh, rx) = test_shared(4);
        let t = Trace::start(span_tree::gen_trace_id(), 16);
        let (status, _) = {
            let _bound = t.bind();
            route_raw(&sh, "POST", "/train", br#"{"x":[1,0],"y":1}"#)
        };
        assert_eq!(status, 202);
        let (_x, _y, queued) = rx.try_recv().unwrap();
        assert_eq!(queued.expect("trace rode the queue").id(), t.id());
        // an untraced request enqueues None
        assert_eq!(route_raw(&sh, "POST", "/train", br#"{"x":[0,1],"y":-1}"#).0, 202);
        let (_x, _y, queued) = rx.try_recv().unwrap();
        assert!(queued.is_none());
    }

    #[test]
    fn serve_rejects_missing_train_stream_file() {
        // a bad --train-stream path must fail serve() synchronously, not
        // leave a silently dead trainer feed behind a running listener
        let cfg = ServerConfig {
            train_stream: Some(PathBuf::from("/definitely/not/here.libsvm")),
            ..Default::default()
        };
        let err = serve(toy_model(), cfg).unwrap_err();
        assert!(matches!(err, Error::Io(_)), "{err}");
    }

    #[test]
    fn unknown_paths_and_methods_are_rejected() {
        let (sh, _rx) = test_shared(4);
        assert_eq!(route_raw(&sh, "GET", "/nope", b"").0, 404);
        assert_eq!(route_raw(&sh, "GET", "/predict", b"").0, 405);
        assert_eq!(route_raw(&sh, "POST", "/stats", b"").0, 405);
        // other verbs on real endpoints are 405 too, not 404
        assert_eq!(route_raw(&sh, "PUT", "/train", b"").0, 405);
        assert_eq!(route_raw(&sh, "HEAD", "/stats", b"").0, 405);
    }
}
