//! Network serving subsystem: train-while-serving over plain TCP, with
//! zero dependencies.
//!
//! The paper's one-pass learner keeps constant storage and cheap
//! per-example updates — exactly the profile of a model that can be
//! *trained and served simultaneously* behind live traffic. This
//! subsystem is that deployment shape:
//!
//! * [`http`] — hand-rolled minimal HTTP/1.1 (request/response framing,
//!   keep-alive, strict limits) shared by server and client.
//! * [`json`] — a tiny JSON parser/writer for the protocol bodies.
//! * [`cell`] — the hot-swap [`cell::ModelCell`]: acceptor threads score
//!   against an immutable published snapshot (`Arc` swap under an
//!   `RwLock`) while the background trainer keeps learning; no request
//!   can observe a torn model.
//! * [`admission`] — bounded queues with explicit 429 shedding, plus the
//!   per-endpoint latency/shed accounting behind `/stats`.
//! * [`server`] — the listener: `/predict`, `/predict_batch`, `/train`,
//!   `/snapshot` (live `.meb` bytes), `/stats`, `/metrics` (Prometheus
//!   text exposition: request counters, latency histograms, live
//!   training gauges) and `/trace` (the [`crate::obs`] ring buffer as
//!   JSON); a background training thread consumes `/train` examples
//!   Algorithm-1 style and republishes every k examples via the sketch
//!   machinery.
//! * [`loadgen`] — the protocol client and a paced open-loop driver
//!   that emits `BENCH_serve.json` (throughput, p50/p90/p99, shed rate).
//!
//! CLI: `streamsvm serve` / `streamsvm loadgen` (see README "Serving").

pub mod admission;
pub mod cell;
pub mod http;
pub mod json;
pub mod loadgen;
pub mod server;

pub use admission::{Endpoint, ServerStats};
pub use cell::{ModelCell, ModelSnapshot};
pub use loadgen::{run_loadgen, LoadClient, LoadReport, LoadgenConfig};
pub use server::{serve, ServerConfig, ServerHandle, ServerReport};
