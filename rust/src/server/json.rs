//! Dependency-free minimal JSON: a recursive-descent parser for the
//! request/response bodies the serving protocol exchanges, plus the few
//! formatting helpers the writers need.
//!
//! Coverage is deliberately small but standard: objects, arrays,
//! strings with the common escapes (`\" \\ \/ \b \f \n \r \t \uXXXX`),
//! numbers via `f64`, `true`/`false`/`null`. Depth is bounded so a
//! hostile body cannot blow the stack.

use crate::error::{Error, Result};

/// Maximum nesting depth accepted by the parser.
const MAX_DEPTH: usize = 64;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(Error::data(format!("json: trailing bytes at offset {}", p.pos)));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// An array of numbers as an `f32` vector (the `x` payload shape).
    pub fn f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_array()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    /// An array of non-negative integers as `u32` (the sparse `idx`
    /// payload shape). Rejects negatives, fractions and out-of-range
    /// values rather than truncating them.
    pub fn u32_vec(&self) -> Option<Vec<u32>> {
        let arr = self.as_array()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            let f = v.as_f64()?;
            if !(0.0..=u32::MAX as f64).contains(&f) || f.fract() != 0.0 {
                return None;
            }
            out.push(f as u32);
        }
        Some(out)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::data(format!(
                "json: expected `{}` at offset {}",
                c as char, self.pos
            )))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::data(format!("json: bad literal at offset {}", self.pos)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth >= MAX_DEPTH {
            return Err(Error::data("json: nesting too deep"));
        }
        match self.peek() {
            None => Err(Error::data("json: unexpected end of input")),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.skip_ws();
                    items.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(Error::data(format!(
                            "json: expected `,` or `]` at offset {}",
                            self.pos
                        ))),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut kv = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(kv));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    self.skip_ws();
                    let v = self.value(depth + 1)?;
                    kv.push((k, v));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(kv));
                        }
                        _ => return Err(Error::data(format!(
                            "json: expected `,` or `}}` at offset {}",
                            self.pos
                        ))),
                    }
                }
            }
            Some(_) => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::data("json: unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| Error::data("json: unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let end = self.pos.checked_add(4).filter(|&e| e <= self.b.len());
                            let hex = end
                                .map(|e| &self.b[self.pos..e])
                                .ok_or_else(|| Error::data("json: truncated \\u escape"))?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|_| Error::data("json: bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::data("json: bad \\u escape"))?;
                            self.pos += 4;
                            // surrogates map to the replacement char; the
                            // protocol never emits them
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => {
                            return Err(Error::data(format!(
                                "json: unknown escape `\\{}`",
                                other as char
                            )))
                        }
                    }
                }
                Some(c) if c < 0x20 => {
                    return Err(Error::data("json: raw control byte in string"))
                }
                Some(_) => {
                    // copy one UTF-8 scalar (input is already valid UTF-8)
                    let s = &self.b[self.pos..];
                    let ch_len = match s[0] {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (self.pos + ch_len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[self.pos..end])
                            .map_err(|_| Error::data("json: bad UTF-8 in string"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        if start == self.pos {
            return Err(Error::data(format!("json: expected a value at offset {start}")));
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).expect("ascii");
        // Rust's f64 parser is laxer than RFC 8259: it accepts a leading
        // `+` ("+1" → 1.0), which JSON forbids.
        if s.starts_with('+') {
            return Err(Error::data(format!("json: bad number `{s}` (leading `+`)")));
        }
        let v: f64 = s
            .parse()
            .map_err(|_| Error::data(format!("json: bad number `{s}`")))?;
        // Overflowing exponents ("1e999") parse to ±inf; a non-finite
        // number must never reach the protocol layer, where it would
        // serialize as `null` or poison a distance computation.
        if !v.is_finite() {
            return Err(Error::data(format!("json: number `{s}` overflows f64")));
        }
        Ok(Json::Num(v))
    }
}

/// Format a float as a JSON value: finite numbers verbatim, NaN/±inf as
/// `null` (raw `NaN` would make the document unparseable).
pub fn fmt_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

/// Escape a string for embedding in a JSON document.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format a `u32` slice as a JSON array (the sparse `idx` payload).
pub fn fmt_u32_array(xs: &[u32]) -> String {
    let mut out = String::with_capacity(2 + 4 * xs.len());
    out.push('[');
    for (i, &v) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&v.to_string());
    }
    out.push(']');
    out
}

/// Format an `f32` slice as a JSON array of numbers.
pub fn fmt_f32_array(xs: &[f32]) -> String {
    let mut out = String::with_capacity(2 + 8 * xs.len());
    out.push('[');
    for (i, &v) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&fmt_num(v as f64));
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_predict_body() {
        let v = Json::parse(r#"{"x":[1.5,-2,3e-1],"y":1}"#).unwrap();
        assert_eq!(v.get("x").unwrap().f32_vec().unwrap(), vec![1.5, -2.0, 0.3]);
        assert_eq!(v.get("y").unwrap().as_f64(), Some(1.0));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn parses_nested_batch() {
        let v = Json::parse(r#"{"xs":[[1,2],[3,4]]}"#).unwrap();
        let xs = v.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs.len(), 2);
        assert_eq!(xs[1].f32_vec().unwrap(), vec![3.0, 4.0]);
    }

    #[test]
    fn parses_scalars_strings_bools() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::parse("\"héllo\"").unwrap().as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":}", "tru", "1 2", "[1 2]",
            "\"unterminated", "{\"a\":1,}x", "nanx", "\u{1}",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject `{bad}`");
        }
    }

    #[test]
    fn number_audit_rejects_lax_forms() {
        // overflow exponents: Rust's f64 parser yields ±inf, which must
        // not cross the wire boundary (fuzz target `json` found this)
        for bad in ["1e999", "-1e999", "1e309", "-1e309"] {
            let err = Json::parse(bad).unwrap_err();
            assert!(err.to_string().contains("overflows"), "`{bad}`: {err}");
        }
        // leading plus is valid to Rust's parser but not to JSON
        for bad in ["+1", "+0.5", "[+2]", "{\"a\":+3}"] {
            assert!(Json::parse(bad).is_err(), "should reject `{bad}`");
        }
        // a lone minus (and minus-dot) must not parse
        for bad in ["-", "[-]", "-.", "{\"a\":-}"] {
            assert!(Json::parse(bad).is_err(), "should reject `{bad}`");
        }
        // tiny exponents underflow to zero, which is finite and fine
        assert_eq!(Json::parse("1e-999").unwrap(), Json::Num(0.0));
        // boundary cases stay accepted
        assert_eq!(Json::parse("1e308").unwrap(), Json::Num(1e308));
        assert_eq!(Json::parse("-0.0").unwrap(), Json::Num(-0.0));
    }

    #[test]
    fn depth_bounded() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
        // the cap is exact: MAX_DEPTH nested arrays parse, one more errors
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(Json::parse(&ok).is_ok());
        let over = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        assert!(Json::parse(&over).is_err());
    }

    #[test]
    fn f32_vec_rejects_non_numbers() {
        let v = Json::parse(r#"[1,"two"]"#).unwrap();
        assert!(v.f32_vec().is_none());
    }

    #[test]
    fn u32_vec_accepts_indices_rejects_junk() {
        assert_eq!(
            Json::parse("[0,3,4294967295]").unwrap().u32_vec(),
            Some(vec![0, 3, u32::MAX])
        );
        for bad in ["[-1]", "[1.5]", "[4294967296]", r#"["x"]"#, "1"] {
            assert!(Json::parse(bad).unwrap().u32_vec().is_none(), "{bad}");
        }
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_num(1.5), "1.5");
        assert_eq!(fmt_num(f64::NAN), "null");
        assert_eq!(fmt_num(f64::INFINITY), "null");
        assert_eq!(fmt_f32_array(&[1.0, -0.5]), "[1,-0.5]");
        assert_eq!(fmt_u32_array(&[0, 7, 42]), "[0,7,42]");
        assert_eq!(fmt_u32_array(&[]), "[]");
        assert_eq!(escape("a\"b\n"), "a\\\"b\\n");
        // round-trip through the parser
        let doc = format!(r#"{{"s":"{}","v":{}}}"#, escape("x\"y"), fmt_num(2.25));
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\"y"));
        assert_eq!(v.get("v").unwrap().as_f64(), Some(2.25));
    }
}
