//! Admission control: bounded hand-off queues with explicit shedding,
//! and the per-endpoint latency/shed bookkeeping behind `/stats`.
//!
//! The server has two admission points, both built on [`bounded`]:
//! connections (acceptor → handler pool) and training examples
//! (`/train` handler → trainer thread). Either queue being full is an
//! *explicit, immediate* 429-style reject — never a silent drop, never
//! an unbounded backlog, never a hang.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::metrics::LatencyHistogram;

/// Producer side of a bounded hand-off queue.
pub struct Bounded<T> {
    tx: SyncSender<T>,
    depth: usize,
}

impl<T> Clone for Bounded<T> {
    fn clone(&self) -> Self {
        Bounded { tx: self.tx.clone(), depth: self.depth }
    }
}

/// A bounded queue of capacity `depth` (0 = rendezvous: admit only when
/// a consumer is actively waiting).
pub fn bounded<T>(depth: usize) -> (Bounded<T>, Receiver<T>) {
    let (tx, rx) = sync_channel(depth);
    (Bounded { tx, depth }, rx)
}

impl<T> Bounded<T> {
    /// Non-blocking admit. `Err(item)` hands the item back when the
    /// queue is full (shed it) or the consumer is gone (shutdown).
    pub fn try_admit(&self, item: T) -> std::result::Result<(), T> {
        match self.tx.try_send(item) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(it)) | Err(TrySendError::Disconnected(it)) => Err(it),
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }
}

/// The serving endpoints, in display order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Endpoint {
    Predict,
    PredictBatch,
    Train,
    Snapshot,
    Stats,
    Metrics,
    Trace,
    DebugTrace,
}

impl Endpoint {
    pub const ALL: [Endpoint; 8] = [
        Endpoint::Predict,
        Endpoint::PredictBatch,
        Endpoint::Train,
        Endpoint::Snapshot,
        Endpoint::Stats,
        Endpoint::Metrics,
        Endpoint::Trace,
        Endpoint::DebugTrace,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Endpoint::Predict => "predict",
            Endpoint::PredictBatch => "predict_batch",
            Endpoint::Train => "train",
            Endpoint::Snapshot => "snapshot",
            Endpoint::Stats => "stats",
            Endpoint::Metrics => "metrics",
            Endpoint::Trace => "trace",
            Endpoint::DebugTrace => "debug_trace",
        }
    }

    fn idx(self) -> usize {
        match self {
            Endpoint::Predict => 0,
            Endpoint::PredictBatch => 1,
            Endpoint::Train => 2,
            Endpoint::Snapshot => 3,
            Endpoint::Stats => 4,
            Endpoint::Metrics => 5,
            Endpoint::Trace => 6,
            Endpoint::DebugTrace => 7,
        }
    }
}

/// Counters + latency distribution for one endpoint.
#[derive(Clone, Debug, Default)]
pub struct EndpointStats {
    /// Requests answered 2xx.
    pub ok: u64,
    /// Requests rejected by admission control (429).
    pub shed: u64,
    /// Malformed / failed requests (4xx other than 429, 5xx).
    pub errors: u64,
    /// Admission → response-written latency of 2xx requests.
    pub latency: LatencyHistogram,
}

/// Progress of the background `--train-stream` file feed: how many rows
/// the trainer has absorbed from the local stream (interleaved with the
/// `/train` queue) and whether the file has been fully consumed.
/// Reported in `/stats` next to the admission counters.
#[derive(Default)]
pub struct StreamProgress {
    /// Stream rows absorbed by the trainer so far.
    pub rows: AtomicU64,
    /// Rows not absorbed: poisoned/malformed rows the tolerant reader
    /// skipped plus rows the trainer's validated entry point rejected.
    /// Updated live (per row), not just at EOF.
    pub skipped: AtomicU64,
    /// The stream file has been consumed to EOF.
    pub done: AtomicBool,
}

impl StreamProgress {
    pub fn record_row(&self) {
        self.rows.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish the current not-absorbed count (reader skips + trainer
    /// rejects); called every iteration so `/stats` is live.
    pub fn set_skipped(&self, skipped: u64) {
        self.skipped.store(skipped, Ordering::Relaxed);
    }

    pub fn finish(&self) {
        self.done.store(true, Ordering::Release);
    }

    pub fn rows(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    pub fn skipped_rows(&self) -> u64 {
        self.skipped.load(Ordering::Relaxed)
    }

    pub fn is_done(&self) -> bool {
        self.done.load(Ordering::Acquire)
    }
}

/// Shared, thread-safe stats registry for the whole server.
#[derive(Default)]
pub struct ServerStats {
    per: [Mutex<EndpointStats>; 8],
    /// Connections handed to the handler pool.
    pub conns_accepted: AtomicU64,
    /// Connections shed at the acceptor (handler pool + queue full).
    pub conns_shed: AtomicU64,
    /// `--train-stream` progress (zero/false when no stream configured).
    pub stream: StreamProgress,
}

impl ServerStats {
    fn lock(&self, ep: Endpoint) -> std::sync::MutexGuard<'_, EndpointStats> {
        match self.per[ep.idx()].lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    pub fn record_ok(&self, ep: Endpoint, latency: Duration) {
        let mut g = self.lock(ep);
        g.ok += 1;
        g.latency.record(latency);
    }

    pub fn record_shed(&self, ep: Endpoint) {
        self.lock(ep).shed += 1;
    }

    pub fn record_error(&self, ep: Endpoint) {
        self.lock(ep).errors += 1;
    }

    /// A point-in-time copy of one endpoint's stats.
    pub fn snapshot(&self, ep: Endpoint) -> EndpointStats {
        self.lock(ep).clone()
    }

    /// Total 2xx-answered requests across endpoints.
    pub fn total_ok(&self) -> u64 {
        Endpoint::ALL.iter().map(|&e| self.lock(e).ok).sum()
    }

    /// Total requests shed across endpoints (excluding connection sheds).
    pub fn total_shed(&self) -> u64 {
        Endpoint::ALL.iter().map(|&e| self.lock(e).shed).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_admits_until_full_then_hands_back() {
        let (q, rx) = bounded::<u32>(2);
        assert!(q.try_admit(1).is_ok());
        assert!(q.try_admit(2).is_ok());
        assert_eq!(q.try_admit(3), Err(3), "full queue must hand the item back");
        assert_eq!(rx.recv().unwrap(), 1);
        assert!(q.try_admit(3).is_ok(), "space freed after a pop");
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn bounded_rejects_after_consumer_gone() {
        let (q, rx) = bounded::<u32>(4);
        drop(rx);
        assert_eq!(q.try_admit(7), Err(7));
    }

    #[test]
    fn rendezvous_queue_sheds_without_waiting_consumer() {
        let (q, rx) = bounded::<u32>(0);
        assert_eq!(q.try_admit(1), Err(1), "no consumer waiting → shed");
        let waiter = std::thread::spawn(move || rx.recv().unwrap());
        // spin until the consumer blocks in recv
        let mut admitted = false;
        for _ in 0..500 {
            if q.try_admit(9).is_ok() {
                admitted = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(admitted, "rendezvous admit must succeed once a consumer waits");
        assert_eq!(waiter.join().unwrap(), 9);
    }

    #[test]
    fn stream_progress_records_and_finishes() {
        let p = StreamProgress::default();
        assert_eq!(p.rows(), 0);
        assert!(!p.is_done());
        p.record_row();
        p.record_row();
        p.set_skipped(1); // live, before EOF
        assert_eq!(p.rows(), 2);
        assert_eq!(p.skipped_rows(), 1);
        assert!(!p.is_done());
        p.finish();
        assert!(p.is_done());
        assert_eq!(p.skipped_rows(), 1);
    }

    #[test]
    fn stats_record_and_snapshot() {
        let s = ServerStats::default();
        s.record_ok(Endpoint::Predict, Duration::from_micros(100));
        s.record_ok(Endpoint::Predict, Duration::from_micros(200));
        s.record_shed(Endpoint::Train);
        s.record_error(Endpoint::PredictBatch);
        let p = s.snapshot(Endpoint::Predict);
        assert_eq!(p.ok, 2);
        assert_eq!(p.latency.count(), 2);
        assert_eq!(s.snapshot(Endpoint::Train).shed, 1);
        assert_eq!(s.snapshot(Endpoint::PredictBatch).errors, 1);
        assert_eq!(s.total_ok(), 2);
        assert_eq!(s.total_shed(), 1);
        assert_eq!(s.snapshot(Endpoint::Stats).ok, 0);
        for ep in Endpoint::ALL {
            assert!(!ep.name().is_empty());
        }
    }
}
