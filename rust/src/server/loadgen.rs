//! Load generator: a dependency-free HTTP client for the serving
//! subsystem plus a paced multi-thread driver that reports throughput,
//! latency quantiles and shed rate (`BENCH_serve.json`).
//!
//! [`LoadClient`] is the protocol client (keep-alive connection, one
//! in-flight request): it powers the paced driver, the CI smoke test
//! and the integration suite. [`run_loadgen`] drives N client threads
//! at a target aggregate QPS with open-loop pacing (each thread sends
//! on a fixed schedule rather than as-fast-as-replies-arrive, so
//! server slowdowns surface as latency, not as a lower offered rate).

use std::io::{BufReader, BufWriter, Write as _};
use std::net::{TcpStream, ToSocketAddrs};
use std::path::Path;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::LatencyHistogram;
use crate::data::{Example, Features, FeaturesView};
use crate::error::{Error, Result};
use crate::rng::Pcg32;
use crate::server::http::{self, HttpResponse, Limits};
use crate::server::json::{self, Json};

/// One keep-alive connection to a serving endpoint.
pub struct LoadClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    host: String,
    limits: Limits,
}

/// Outcome of one round-trip.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub status: u16,
    /// Parsed `score` for `/predict` 2xx replies.
    pub score: Option<f64>,
    /// Parsed snapshot `version`, when the reply carries one.
    pub version: Option<u64>,
    /// Server announced it will close the connection (reconnect before
    /// the next request).
    pub closed: bool,
    /// Server-reported handling time from the `x-pallas-dur-us` response
    /// header — wire time excluded, the cross-check against client-side
    /// latency.
    pub server_dur_us: Option<u64>,
}

impl LoadClient {
    /// Connect with `read_timeout` on replies.
    pub fn connect<A: ToSocketAddrs + std::fmt::Display>(
        addr: A,
        read_timeout: Duration,
    ) -> Result<Self> {
        let host = addr.to_string();
        let stream = TcpStream::connect(&addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(read_timeout))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(LoadClient { reader, writer: BufWriter::new(stream), host, limits: Limits::default() })
    }

    /// Send an arbitrary request with extra headers and return the raw
    /// parsed response (status + headers + body) — the integration
    /// tests' hook for header-level assertions like `traceparent`
    /// propagation.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
        extra: &[(&str, String)],
    ) -> Result<HttpResponse> {
        http::write_request_ext(&mut self.writer, method, path, &self.host, body, extra)?;
        self.writer.flush()?;
        http::read_response(&mut self.reader, &self.limits)?
            .ok_or_else(|| Error::Pipeline("server closed the connection before replying".into()))
    }

    fn round_trip(&mut self, method: &str, path: &str, body: &[u8]) -> Result<HttpResponse> {
        self.request(method, path, body, &[])
    }

    fn outcome_of(resp: HttpResponse) -> Outcome {
        let parsed = std::str::from_utf8(&resp.body).ok().and_then(|s| Json::parse(s).ok());
        let field = |k: &str| parsed.as_ref().and_then(|v| v.get(k)).and_then(|v| v.as_f64());
        Outcome {
            status: resp.status,
            score: field("score"),
            version: field("version").map(|v| v as u64),
            closed: resp.connection_close(),
            server_dur_us: resp.header("x-pallas-dur-us").and_then(|v| v.trim().parse().ok()),
        }
    }

    /// `POST /predict` with one dense feature vector.
    pub fn predict(&mut self, x: &[f32]) -> Result<Outcome> {
        let body = format!(r#"{{"x":{}}}"#, json::fmt_f32_array(x));
        Ok(Self::outcome_of(self.round_trip("POST", "/predict", body.as_bytes())?))
    }

    /// `POST /train` with one dense labeled example.
    pub fn train(&mut self, x: &[f32], y: f32) -> Result<Outcome> {
        let body = format!(r#"{{"x":{},"y":{}}}"#, json::fmt_f32_array(x), json::fmt_num(y as f64));
        Ok(Self::outcome_of(self.round_trip("POST", "/train", body.as_bytes())?))
    }

    /// Encode features in their natural payload shape: dense `"x"` or
    /// sparse `"idx"`/`"val"`.
    fn features_body(x: &Features) -> String {
        match x.view() {
            FeaturesView::Dense(d) => format!(r#""x":{}"#, json::fmt_f32_array(d)),
            FeaturesView::Sparse { idx, val, .. } => format!(
                r#""idx":{},"val":{}"#,
                json::fmt_u32_array(idx),
                json::fmt_f32_array(val)
            ),
        }
    }

    /// `POST /predict` in the features' natural shape (sparse examples
    /// send the O(nnz) sparse payload).
    pub fn predict_features(&mut self, x: &Features) -> Result<Outcome> {
        let body = format!("{{{}}}", Self::features_body(x));
        Ok(Self::outcome_of(self.round_trip("POST", "/predict", body.as_bytes())?))
    }

    /// `POST /train` in the features' natural shape.
    pub fn train_features(&mut self, x: &Features, y: f32) -> Result<Outcome> {
        let body = format!(
            "{{{},\"y\":{}}}",
            Self::features_body(x),
            json::fmt_num(y as f64)
        );
        Ok(Self::outcome_of(self.round_trip("POST", "/train", body.as_bytes())?))
    }

    /// `POST /predict_batch` with the `{"rows":[...]}` shape: each row
    /// is sent in its natural dense-or-sparse payload form (rows may
    /// mix representations freely). Returns the status and parsed body.
    pub fn predict_batch_features(&mut self, rows: &[Features]) -> Result<(u16, Json)> {
        let mut body = String::from(r#"{"rows":["#);
        for (i, x) in rows.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            body.push('{');
            body.push_str(&Self::features_body(x));
            body.push('}');
        }
        body.push_str("]}");
        let resp = self.round_trip("POST", "/predict_batch", body.as_bytes())?;
        let text = std::str::from_utf8(&resp.body)
            .map_err(|_| Error::Pipeline("predict_batch body is not UTF-8".into()))?;
        Ok((resp.status, Json::parse(text)?))
    }

    /// `GET /stats`, parsed.
    pub fn stats(&mut self) -> Result<Json> {
        let resp = self.round_trip("GET", "/stats", b"")?;
        let text = std::str::from_utf8(&resp.body)
            .map_err(|_| Error::Pipeline("stats body is not UTF-8".into()))?;
        Json::parse(text)
    }

    /// `GET` any text endpoint (`/metrics`, `/trace`) as a UTF-8 body.
    pub fn get_text(&mut self, path: &str) -> Result<String> {
        let resp = self.round_trip("GET", path, b"")?;
        if !resp.is_2xx() {
            return Err(Error::Pipeline(format!("{path} returned {}", resp.status)));
        }
        String::from_utf8(resp.body)
            .map_err(|_| Error::Pipeline(format!("{path} body is not UTF-8")))
    }

    /// `GET /snapshot`: the raw `.meb` bytes.
    pub fn snapshot(&mut self) -> Result<Vec<u8>> {
        let resp = self.round_trip("GET", "/snapshot", b"")?;
        if !resp.is_2xx() {
            return Err(Error::Pipeline(format!("snapshot returned {}", resp.status)));
        }
        Ok(resp.body)
    }
}

/// Load-generation parameters.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Client threads (each holds one keep-alive connection).
    pub threads: usize,
    /// Total requests across all threads.
    pub requests: usize,
    /// Aggregate target rate; `<= 0` runs unthrottled (closed loop).
    pub qps: f64,
    /// Fraction of requests that hit `/train` instead of `/predict`.
    pub train_share: f64,
    pub read_timeout: Duration,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7878".into(),
            threads: 4,
            requests: 2000,
            qps: 500.0,
            train_share: 0.1,
            read_timeout: Duration::from_secs(5),
            seed: 42,
        }
    }
}

/// Aggregate results of one load-generation run.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub sent: usize,
    /// 2xx replies with a well-formed body (finite score for predicts).
    pub ok: usize,
    /// Explicit 429 rejects (request- or connection-level shedding).
    pub shed: usize,
    /// Transport failures and non-2xx/non-429 statuses.
    pub errors: usize,
    pub predicts: usize,
    pub trains: usize,
    pub wall: Duration,
    pub qps_target: f64,
    /// Send → parsed-reply latency of *ok* (2xx) replies across all
    /// threads — shed fast-path replies are excluded, matching the
    /// server's own `/stats` accounting.
    pub latency: LatencyHistogram,
    /// Server-reported handling time (`x-pallas-dur-us`) of the same ok
    /// replies. `latency` minus this is time spent on the wire and in
    /// the accept/admission path — the split that tells an overloaded
    /// network apart from a slow handler.
    pub server_latency: LatencyHistogram,
}

impl LoadReport {
    pub fn qps_achieved(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s <= 0.0 {
            0.0
        } else {
            // completed round-trips per second
            (self.ok + self.shed) as f64 / s
        }
    }

    pub fn shed_rate(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.shed as f64 / self.sent as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "sent={} ok={} shed={} errors={} ({} predict / {} train) in {:.2?} | \
             {:.0} rps achieved (target {}) shed_rate={:.2}% | latency {}",
            self.sent,
            self.ok,
            self.shed,
            self.errors,
            self.predicts,
            self.trains,
            self.wall,
            self.qps_achieved(),
            if self.qps_target > 0.0 { format!("{:.0}", self.qps_target) } else { "∞".into() },
            self.shed_rate() * 100.0,
            self.latency.summary(),
        )
    }

    /// The `BENCH_serve.json` document.
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                r#"{{"requests":{},"ok":{},"shed":{},"errors":{},"#,
                r#""predicts":{},"trains":{},"shed_rate":{},"#,
                r#""wall_s":{},"qps_target":{},"qps_achieved":{},"#,
                r#""latency_us":{{"mean":{},"p50":{},"p90":{},"p99":{},"max":{}}},"#,
                r#""server_latency_us":{{"mean":{},"p50":{},"p90":{},"p99":{},"max":{}}}}}"#
            ),
            self.sent,
            self.ok,
            self.shed,
            self.errors,
            self.predicts,
            self.trains,
            json::fmt_num(self.shed_rate()),
            json::fmt_num(self.wall.as_secs_f64()),
            json::fmt_num(self.qps_target.max(0.0)),
            json::fmt_num(self.qps_achieved()),
            self.latency.mean().as_micros(),
            self.latency.quantile(0.50).as_micros(),
            self.latency.quantile(0.90).as_micros(),
            self.latency.quantile(0.99).as_micros(),
            self.latency.max().as_micros(),
            self.server_latency.mean().as_micros(),
            self.server_latency.quantile(0.50).as_micros(),
            self.server_latency.quantile(0.90).as_micros(),
            self.server_latency.quantile(0.99).as_micros(),
            self.server_latency.max().as_micros(),
        )
    }

    /// Write [`Self::to_json`] to `path`.
    pub fn write_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())?;
        Ok(())
    }
}

/// Per-thread slice of the run.
struct ThreadReport {
    sent: usize,
    ok: usize,
    shed: usize,
    errors: usize,
    predicts: usize,
    trains: usize,
    latency: LatencyHistogram,
    server_latency: LatencyHistogram,
}

/// Drive `cfg.addr` with a mixed `/predict` + `/train` workload drawn
/// from `examples` (cycled). Returns the aggregate report; transport
/// errors reconnect and count, they never abort the run.
pub fn run_loadgen(cfg: &LoadgenConfig, examples: &[Example]) -> Result<LoadReport> {
    if examples.is_empty() {
        return Err(Error::config("loadgen needs at least one example"));
    }
    if cfg.threads == 0 || cfg.requests == 0 {
        return Err(Error::config("loadgen needs threads >= 1 and requests >= 1"));
    }
    let interval = if cfg.qps > 0.0 {
        Some(Duration::from_secs_f64(cfg.threads as f64 / cfg.qps))
    } else {
        None
    };
    let wall = Instant::now();
    let reports: Vec<ThreadReport> = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(cfg.threads);
        for k in 0..cfg.threads {
            let n = cfg.requests / cfg.threads + usize::from(k < cfg.requests % cfg.threads);
            joins.push(scope.spawn(move || drive_one(cfg, examples, k, n, interval)));
        }
        joins.into_iter().map(|j| j.join().expect("loadgen thread panicked")).collect()
    });
    let mut agg = LoadReport { qps_target: cfg.qps, ..Default::default() };
    for r in reports {
        agg.sent += r.sent;
        agg.ok += r.ok;
        agg.shed += r.shed;
        agg.errors += r.errors;
        agg.predicts += r.predicts;
        agg.trains += r.trains;
        agg.latency.merge(&r.latency);
        agg.server_latency.merge(&r.server_latency);
    }
    agg.wall = wall.elapsed();
    Ok(agg)
}

fn drive_one(
    cfg: &LoadgenConfig,
    examples: &[Example],
    thread_idx: usize,
    n: usize,
    interval: Option<Duration>,
) -> ThreadReport {
    let mut rep = ThreadReport {
        sent: 0,
        ok: 0,
        shed: 0,
        errors: 0,
        predicts: 0,
        trains: 0,
        latency: LatencyHistogram::default(),
        server_latency: LatencyHistogram::default(),
    };
    let mut rng = Pcg32::new(cfg.seed, 7000 + thread_idx as u64);
    let mut client = LoadClient::connect(cfg.addr.as_str(), cfg.read_timeout).ok();
    // Stagger thread k by k/threads of a slot so the aggregate offered
    // load is a smooth cfg.qps, not synchronized bursts of `threads`
    // requests every interval.
    let phase = interval
        .map(|iv| iv.mul_f64(thread_idx as f64 / cfg.threads.max(1) as f64))
        .unwrap_or(Duration::ZERO);
    let t0 = Instant::now();
    for j in 0..n {
        if let Some(iv) = interval {
            // open-loop pacing: sleep to this thread's j-th slot
            let target = phase + iv.mul_f64(j as f64);
            let elapsed = t0.elapsed();
            if elapsed < target {
                std::thread::sleep(target - elapsed);
            }
        }
        if client.is_none() {
            match LoadClient::connect(cfg.addr.as_str(), cfg.read_timeout) {
                Ok(c) => client = Some(c),
                Err(_) => {
                    rep.sent += 1;
                    rep.errors += 1;
                    continue;
                }
            }
        }
        let c = client.as_mut().expect("connected above");
        let e = &examples[(thread_idx * 31 + j * 7) % examples.len()];
        let is_train = rng.bernoulli(cfg.train_share);
        rep.sent += 1;
        if is_train {
            rep.trains += 1;
        } else {
            rep.predicts += 1;
        }
        let sent_at = Instant::now();
        let outcome =
            if is_train { c.train_features(&e.x, e.y) } else { c.predict_features(&e.x) };
        match outcome {
            Ok(o) => {
                // a 2xx predict only counts as ok with a finite score
                let body_ok = is_train || matches!(o.score, Some(s) if s.is_finite());
                if (200..300).contains(&o.status) && body_ok {
                    rep.ok += 1;
                    rep.latency.record(sent_at.elapsed());
                    if let Some(us) = o.server_dur_us {
                        rep.server_latency.record(Duration::from_micros(us));
                    }
                } else if o.status == 429 {
                    // counted, but kept out of the latency histogram: the
                    // reject fast-path would make an overloaded server
                    // look like it meets latency targets
                    rep.shed += 1;
                } else {
                    rep.errors += 1;
                }
                if o.closed {
                    client = None;
                }
            }
            Err(_) => {
                rep.errors += 1;
                client = None; // reconnect on the next iteration
            }
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_rates_and_json() {
        let mut r = LoadReport {
            sent: 100,
            ok: 90,
            shed: 10,
            errors: 0,
            predicts: 80,
            trains: 20,
            wall: Duration::from_secs(2),
            qps_target: 100.0,
            ..Default::default()
        };
        r.latency.record(Duration::from_micros(300));
        assert!((r.qps_achieved() - 50.0).abs() < 1e-9);
        assert!((r.shed_rate() - 0.1).abs() < 1e-12);
        let v = Json::parse(&r.to_json()).unwrap();
        assert_eq!(v.get("requests").unwrap().as_f64(), Some(100.0));
        assert_eq!(v.get("shed").unwrap().as_f64(), Some(10.0));
        assert!(v.get("qps_achieved").unwrap().as_f64().unwrap() > 0.0);
        let lat = v.get("latency_us").unwrap();
        let srv = v.get("server_latency_us").unwrap();
        for k in ["mean", "p50", "p90", "p99", "max"] {
            assert!(lat.get(k).unwrap().as_f64().is_some(), "missing latency key {k}");
            assert!(srv.get(k).unwrap().as_f64().is_some(), "missing server latency key {k}");
        }
        assert!(!r.summary().is_empty());
    }

    #[test]
    fn empty_report_is_safe() {
        let r = LoadReport::default();
        assert_eq!(r.qps_achieved(), 0.0);
        assert_eq!(r.shed_rate(), 0.0);
        assert!(Json::parse(&r.to_json()).is_ok());
    }

    #[test]
    fn loadgen_config_validation() {
        let cfg = LoadgenConfig { requests: 0, ..Default::default() };
        assert!(run_loadgen(&cfg, &[Example::new(vec![1.0], 1.0)]).is_err());
        let cfg = LoadgenConfig::default();
        assert!(run_loadgen(&cfg, &[]).is_err());
    }
}
