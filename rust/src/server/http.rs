//! Hand-rolled minimal HTTP/1.1 — just enough protocol for the serving
//! subsystem, with zero dependencies.
//!
//! Supported: request line + headers + `Content-Length` bodies,
//! keep-alive connections, `Expect: 100-continue` (see
//! [`read_request_expect`]), and the response writer the server and the
//! loadgen client share. Not supported (rejected or ignored): chunked
//! transfer encoding, multi-line headers, HTTP/2. Limits guard every
//! read so a malformed or hostile peer can cost at most
//! [`Limits::max_body`] bytes of memory.

use std::io::{BufRead, Read, Write};

use crate::error::{Error, Result};

/// Parse limits for one request/response.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Longest accepted request/status/header line, in bytes.
    pub max_line: usize,
    /// Maximum number of headers.
    pub max_headers: usize,
    /// Maximum `Content-Length`.
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { max_line: 8192, max_headers: 64, max_body: 16 << 20 }
    }
}

/// One parsed HTTP request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        header_of(&self.headers, name)
    }

    /// Did the client ask to drop the connection after this exchange?
    pub fn wants_close(&self) -> bool {
        self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// One parsed HTTP response (the loadgen-client half).
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        header_of(&self.headers, name)
    }

    /// Will the server drop the connection after this response?
    pub fn connection_close(&self) -> bool {
        self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }

    pub fn is_2xx(&self) -> bool {
        (200..300).contains(&self.status)
    }
}

fn header_of<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case(name))
        .map(|(_, v)| v.as_str())
}

/// Reason phrases for the status codes the subsystem emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Read one `\r\n`- (or `\n`-) terminated line, enforcing `max_line`.
/// Returns `None` on clean EOF before the first byte.
fn read_line<R: BufRead>(r: &mut R, max_line: usize) -> Result<Option<String>> {
    let mut buf: Vec<u8> = Vec::with_capacity(64);
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Err(Error::Pipeline("http: connection closed mid-line".into()));
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    let s = String::from_utf8(buf)
                        .map_err(|_| Error::Pipeline("http: non-UTF-8 header line".into()))?;
                    return Ok(Some(s));
                }
                buf.push(byte[0]);
                if buf.len() > max_line {
                    return Err(Error::Pipeline("http: header line too long".into()));
                }
            }
            Err(e) => return Err(Error::Io(e)),
        }
    }
}

/// Read the header block (up to and including the blank line).
fn read_headers<R: BufRead>(r: &mut R, limits: &Limits) -> Result<Vec<(String, String)>> {
    let mut headers = Vec::new();
    loop {
        let line = read_line(r, limits.max_line)?
            .ok_or_else(|| Error::Pipeline("http: connection closed in headers".into()))?;
        if line.is_empty() {
            return Ok(headers);
        }
        if headers.len() >= limits.max_headers {
            return Err(Error::Pipeline("http: too many headers".into()));
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| Error::Pipeline(format!("http: malformed header `{line}`")))?;
        headers.push((k.trim().to_string(), v.trim().to_string()));
    }
}

/// Declared body length, validated against `max_body`.
///
/// Duplicate `Content-Length` headers with *differing* values are the
/// classic request-smuggling shape (a front proxy framing on one value,
/// this parser on the other), so they are rejected outright; duplicates
/// that agree are tolerated per RFC 9110 §8.6.
fn body_len(headers: &[(String, String)], limits: &Limits) -> Result<usize> {
    let mut declared = headers
        .iter()
        .filter(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.as_str());
    let len: usize = match declared.next() {
        None => 0,
        Some(v) => {
            if declared.any(|other| other != v) {
                return Err(Error::Pipeline(
                    "http: conflicting duplicate content-length headers".into(),
                ));
            }
            v.parse()
                .map_err(|_| Error::Pipeline(format!("http: bad content-length `{v}`")))?
        }
    };
    if len > limits.max_body {
        return Err(Error::Pipeline(format!(
            "http: body of {len} bytes exceeds the {} byte limit",
            limits.max_body
        )));
    }
    Ok(len)
}

/// Read the shared `headers … blank line … body` tail of a message.
fn read_headers_and_body<R: BufRead>(
    r: &mut R,
    limits: &Limits,
) -> Result<(Vec<(String, String)>, Vec<u8>)> {
    let headers = read_headers(r, limits)?;
    let len = body_len(&headers, limits)?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(Error::Io)?;
    Ok((headers, body))
}

/// Read one request from a keep-alive connection. `Ok(None)` means the
/// peer closed cleanly between requests (the normal end of a
/// connection); errors mean a malformed request or a mid-message close.
pub fn read_request<R: BufRead>(r: &mut R, limits: &Limits) -> Result<Option<HttpRequest>> {
    read_request_expect(r, None, limits)
}

/// [`read_request`] with `Expect: 100-continue` support: when the client
/// announced a body with that header (curl does for bodies over ~1KB)
/// and `cont` is given, an interim `HTTP/1.1 100 Continue` is written
/// before the body read — otherwise such clients stall ~1s per request
/// waiting for the go-ahead.
pub fn read_request_expect<R: BufRead>(
    r: &mut R,
    cont: Option<&mut dyn Write>,
    limits: &Limits,
) -> Result<Option<HttpRequest>> {
    let line = match read_line(r, limits.max_line)? {
        None => return Ok(None),
        Some(l) => l,
    };
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m, p, v),
        _ => return Err(Error::Pipeline(format!("http: malformed request line `{line}`"))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(Error::Pipeline(format!("http: unsupported version `{version}`")));
    }
    let headers = read_headers(r, limits)?;
    let len = body_len(&headers, limits)?;
    if len > 0 {
        let expects_continue = header_of(&headers, "expect")
            .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"));
        if expects_continue {
            if let Some(w) = cont {
                w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
                w.flush()?;
            }
        }
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(Error::Io)?;
    Ok(Some(HttpRequest {
        method: method.to_ascii_uppercase(),
        path: path.to_string(),
        headers,
        body,
    }))
}

/// Read one response (the client half). `Ok(None)` on clean EOF before
/// the status line — e.g. a server that shed the connection after its
/// final response.
pub fn read_response<R: BufRead>(r: &mut R, limits: &Limits) -> Result<Option<HttpResponse>> {
    let line = match read_line(r, limits.max_line)? {
        None => return Ok(None),
        Some(l) => l,
    };
    let mut parts = line.split_whitespace();
    let status: u16 = match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => code
            .parse()
            .map_err(|_| Error::Pipeline(format!("http: bad status code in `{line}`")))?,
        _ => return Err(Error::Pipeline(format!("http: malformed status line `{line}`"))),
    };
    let (headers, body) = read_headers_and_body(r, limits)?;
    Ok(Some(HttpResponse { status, headers, body }))
}

/// Write one response. The caller flushes (so a handler can batch the
/// write with its latency bookkeeping).
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write_response_ext(w, status, content_type, body, keep_alive, &[])
}

/// [`write_response`] plus arbitrary extra headers (`traceparent`,
/// `x-pallas-dur-us`). Extra names/values must be pre-sanitized — this
/// writer does not reject CR/LF (all call sites pass literals or
/// formatted numerics).
pub fn write_response_ext<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
    extra: &[(&str, String)],
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        status_text(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    )?;
    for (k, v) in extra {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)
}

/// Write one request (the client half).
pub fn write_request<W: Write>(
    w: &mut W,
    method: &str,
    path: &str,
    host: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write_request_ext(w, method, path, host, body, &[])
}

/// [`write_request`] plus arbitrary extra headers (the loadgen client
/// and the integration tests use it to send `traceparent`).
pub fn write_request_ext<W: Write>(
    w: &mut W,
    method: &str,
    path: &str,
    host: &str,
    body: &[u8],
    extra: &[(&str, String)],
) -> std::io::Result<()> {
    write!(
        w,
        "{method} {path} HTTP/1.1\r\nHost: {host}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n",
        body.len(),
    )?;
    for (k, v) in extra {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)
}

// ---- W3C trace context ------------------------------------------------

/// A parsed `traceparent` header (W3C Trace Context, version 00):
/// `00-<32 hex trace-id>-<16 hex parent-id>-<2 hex flags>`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceParent {
    pub trace_id: u128,
    pub parent_id: u64,
    pub flags: u8,
}

/// Strict parse of a `traceparent` value. Rejects the all-zero
/// trace-id/parent-id and the reserved version `ff`, accepts future
/// versions with the 00 layout (per spec §4.3).
pub fn parse_traceparent(s: &str) -> Option<TraceParent> {
    let s = s.trim();
    let mut parts = s.splitn(4, '-');
    let (ver, tid, pid, flags) = (parts.next()?, parts.next()?, parts.next()?, parts.next()?);
    let all_hex = |p: &str| p.bytes().all(|b| b.is_ascii_hexdigit());
    if ver.len() != 2 || !all_hex(ver) || ver.eq_ignore_ascii_case("ff") {
        return None;
    }
    if tid.len() != 32 || pid.len() != 16 || flags.len() != 2 || !all_hex(flags) {
        return None;
    }
    let trace_id = crate::obs::span_tree::parse_trace_id(tid)?;
    if !all_hex(pid) {
        return None;
    }
    let parent_id = u64::from_str_radix(pid, 16).ok()?;
    if parent_id == 0 {
        return None;
    }
    let flags = u8::from_str_radix(flags, 16).ok()?;
    Some(TraceParent { trace_id, parent_id, flags })
}

/// Render a version-00 `traceparent` with the sampled flag set.
pub fn format_traceparent(trace_id: u128, span_id: u64) -> String {
    format!("00-{trace_id:032x}-{span_id:016x}-01")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse_req(raw: &[u8]) -> Result<Option<HttpRequest>> {
        read_request(&mut BufReader::new(raw), &Limits::default())
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\n{\"x\":[1,2]}";
        let req = parse_req(raw).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert_eq!(req.body, b"{\"x\":[1,2]}");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(!req.wants_close());
    }

    #[test]
    fn parses_get_without_body_and_close() {
        let raw = b"GET /stats HTTP/1.1\r\nConnection: close\r\n\r\n";
        let req = parse_req(raw).unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert!(req.wants_close());
    }

    #[test]
    fn keep_alive_reads_two_requests_then_eof() {
        let raw: Vec<u8> = [
            &b"GET /a HTTP/1.1\r\n\r\n"[..],
            &b"GET /b HTTP/1.1\r\n\r\n"[..],
        ]
        .concat();
        let mut r = BufReader::new(&raw[..]);
        let lim = Limits::default();
        assert_eq!(read_request(&mut r, &lim).unwrap().unwrap().path, "/a");
        assert_eq!(read_request(&mut r, &lim).unwrap().unwrap().path, "/b");
        assert!(read_request(&mut r, &lim).unwrap().is_none());
    }

    #[test]
    fn malformed_inputs_error() {
        assert!(parse_req(b"garbage\r\n\r\n").is_err());
        assert!(parse_req(b"GET /x SPDY/3\r\n\r\n").is_err());
        assert!(parse_req(b"GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n").is_err());
        assert!(parse_req(b"GET /x HTTP/1.1\r\nContent-Length: zap\r\n\r\n").is_err());
        // body shorter than content-length → mid-message close
        assert!(parse_req(b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").is_err());
    }

    #[test]
    fn conflicting_duplicate_content_length_rejected() {
        // two differing Content-Length lines: the request-smuggling
        // shape — a proxy framing on one value, us on the other
        let raw = b"POST /train HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\
                    Content-Length: 11\r\n\r\n{\"x\":[1,2]}";
        let err = parse_req(raw).unwrap_err();
        assert!(err.to_string().contains("conflicting duplicate content-length"), "{err}");

        // case-mixed duplicates still conflict
        let raw = b"POST /train HTTP/1.1\r\ncontent-length: 11\r\n\
                    CONTENT-LENGTH: 4\r\n\r\n{\"x\":[1,2]}";
        assert!(parse_req(raw).is_err());

        // duplicates that agree are tolerated (RFC 9110 §8.6)
        let raw = b"POST /train HTTP/1.1\r\nContent-Length: 11\r\n\
                    Content-Length: 11\r\n\r\n{\"x\":[1,2]}";
        let req = parse_req(raw).unwrap().unwrap();
        assert_eq!(req.body, b"{\"x\":[1,2]}");
    }

    #[test]
    fn limits_enforced() {
        let lim = Limits { max_line: 16, max_headers: 1, max_body: 4 };
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(64));
        assert!(read_request(&mut BufReader::new(long.as_bytes()), &lim).is_err());
        let many = b"GET /x HTTP/1.1\r\na: 1\r\nb: 2\r\n\r\n";
        assert!(read_request(&mut BufReader::new(&many[..]), &lim).is_err());
        let big = b"POST /x HTTP/1.1\r\nContent-Length: 99\r\n\r\n";
        assert!(read_request(&mut BufReader::new(&big[..]), &lim).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{\"ok\":true}", true).unwrap();
        let resp = read_response(&mut BufReader::new(&out[..]), &Limits::default())
            .unwrap()
            .unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.is_2xx());
        assert!(!resp.connection_close());
        assert_eq!(resp.body, b"{\"ok\":true}");

        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", b"{}", false).unwrap();
        let resp = read_response(&mut BufReader::new(&out[..]), &Limits::default())
            .unwrap()
            .unwrap();
        assert_eq!(resp.status, 429);
        assert!(resp.connection_close());
        assert!(!resp.is_2xx());
    }

    #[test]
    fn request_roundtrip() {
        let mut out = Vec::new();
        write_request(&mut out, "POST", "/train", "127.0.0.1:7878", b"{\"y\":1}").unwrap();
        let req = parse_req(&out).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/train");
        assert_eq!(req.body, b"{\"y\":1}");
    }

    #[test]
    fn ext_writers_carry_extra_headers() {
        let mut out = Vec::new();
        let extra = [("x-pallas-dur-us", "42".to_string()), ("traceparent", "t".to_string())];
        write_response_ext(&mut out, 200, "application/json", b"{}", true, &extra).unwrap();
        let resp = read_response(&mut BufReader::new(&out[..]), &Limits::default())
            .unwrap()
            .unwrap();
        assert_eq!(resp.header("X-Pallas-Dur-Us"), Some("42"));
        assert_eq!(resp.header("traceparent"), Some("t"));
        assert_eq!(resp.body, b"{}");

        let mut out = Vec::new();
        write_request_ext(&mut out, "GET", "/x", "h", b"", &extra[..1]).unwrap();
        let req = parse_req(&out).unwrap().unwrap();
        assert_eq!(req.header("x-pallas-dur-us"), Some("42"));
    }

    #[test]
    fn traceparent_parses_strictly_and_roundtrips() {
        let tp = parse_traceparent("00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01")
            .expect("spec example parses");
        assert_eq!(tp.trace_id, 0x0af7651916cd43dd8448eb211c80319c);
        assert_eq!(tp.parent_id, 0xb7ad6b7169203331);
        assert_eq!(tp.flags, 1);
        let rendered = format_traceparent(tp.trace_id, tp.parent_id);
        assert_eq!(parse_traceparent(&rendered), Some(tp));

        // Rejections: zero ids, reserved version, wrong lengths, non-hex.
        let zeros = format!("00-{}-{}-01", "0".repeat(32), "1".repeat(16));
        assert!(parse_traceparent(&zeros.replace('1', "0")).is_none());
        assert!(parse_traceparent(&format!("00-{}-{}-01", "a".repeat(32), "0".repeat(16)))
            .is_none());
        assert!(parse_traceparent(&format!("ff-{}-{}-01", "a".repeat(32), "b".repeat(16)))
            .is_none());
        assert!(parse_traceparent("00-abc-b7ad6b7169203331-01").is_none());
        assert!(parse_traceparent(&format!("00-{}-{}-zz", "a".repeat(32), "b".repeat(16)))
            .is_none());
        assert!(parse_traceparent("").is_none());
    }

    #[test]
    fn bare_lf_line_endings_accepted() {
        let req = parse_req(b"GET /x HTTP/1.1\nA: b\n\n").unwrap().unwrap();
        assert_eq!(req.path, "/x");
        assert_eq!(req.header("a"), Some("b"));
    }

    #[test]
    fn expect_100_continue_gets_interim_reply() {
        let raw =
            b"POST /train HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 7\r\n\r\n{\"y\":1}";
        let mut interim: Vec<u8> = Vec::new();
        let req = read_request_expect(
            &mut BufReader::new(&raw[..]),
            Some(&mut interim),
            &Limits::default(),
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.body, b"{\"y\":1}");
        assert_eq!(interim, b"HTTP/1.1 100 Continue\r\n\r\n");

        // no Expect header → no interim bytes
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 2\r\n\r\nab";
        let mut interim: Vec<u8> = Vec::new();
        read_request_expect(
            &mut BufReader::new(&raw[..]),
            Some(&mut interim),
            &Limits::default(),
        )
        .unwrap()
        .unwrap();
        assert!(interim.is_empty());

        // plain read_request still parses Expect requests (no writer)
        let raw =
            b"POST /x HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\nab";
        assert_eq!(parse_req(raw).unwrap().unwrap().body, b"ab");
    }
}
