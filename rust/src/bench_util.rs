//! Timing harness for the benches (criterion is unavailable offline).
//!
//! `cargo bench` runs the custom-harness binaries in `benches/`; they use
//! this module for warmup + repeated measurement with mean/p50/p99, and
//! aligned table printing for the paper-shaped outputs.

use std::time::{Duration, Instant};

/// Statistics from a measured run.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub reps: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  p50 {:>10.3?}  p99 {:>10.3?}  (n={}, min {:.3?}, max {:.3?})",
            self.mean, self.p50, self.p99, self.reps, self.min, self.max
        )
    }
}

/// Run `f` for `warmup` unmeasured + `reps` measured iterations.
pub fn bench<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }
    times.sort();
    let total: Duration = times.iter().sum();
    BenchStats {
        reps,
        mean: total / reps.max(1) as u32,
        p50: times[reps / 2],
        p99: times[(reps * 99 / 100).min(reps - 1)],
        min: times[0],
        max: times[reps - 1],
    }
}

/// Time a single closure invocation.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

/// Fixed-width table printer for paper-shaped outputs.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            widths: headers.iter().map(|h| h.len()).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let parts: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers, &self.widths);
        let sep: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &self.widths);
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_orders_percentiles() {
        let mut i = 0u64;
        let stats = bench(2, 50, || {
            i = i.wrapping_add(1);
            std::thread::sleep(Duration::from_micros(100));
        });
        assert!(stats.p50 <= stats.p99);
        assert!(stats.min <= stats.mean && stats.mean <= stats.max.max(stats.mean));
        assert_eq!(stats.reps, 50);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "22".into()]);
        t.print(); // should not panic
    }
}
