//! `MebSketch` — the durable form of a StreamSVM model.
//!
//! The entire learner state is a ball `(w, R, ξ², M)` plus stream
//! provenance (examples seen, training-option fingerprint, dataset tag),
//! a few hundred bytes for typical dimensions. The wire format is
//! versioned, length-prefixed and checksummed:
//!
//! ```text
//!   magic   "MEBS"                     4 bytes
//!   version u16 LE                     2 bytes
//!   flags   u16 LE (reserved, 0)       2 bytes
//!   len     u64 LE (payload bytes)     8 bytes
//!   payload                            len bytes
//!   fnv1a64 u64 LE (over payload)      8 bytes
//! ```
//!
//! Payload, all little-endian:
//! `tag(u32 len + utf8) · c(f64) · slack_mode(u8) · lookahead(u64) ·
//! merge_iters(u64) · merges(u64) · has_hash(u8) · [hash_seed(u64) ·
//! hash_dim(u64)] · seen(u64) · dim(u64) · has_ball(u8) ·
//! [m(u64) · r(f64) · xi2(f64) · sigma(f64) · wnorm2(f64) ·
//! v(dim × f32)] · variant(u8) · has_extra(u8) · [extra]`.
//!
//! Version 2 serializes the ball's *factored* center `w = σ·v` (plus
//! the cached `‖w‖²`) exactly as the live state holds it, so decode →
//! resume → continue training reproduces an uninterrupted run
//! bit-for-bit — including the lazy-scaling fold schedule. Version 3
//! adds two provenance fields: the Algorithm-2 merge count (so a
//! resumed run reports the paper's O(N/L) bound correctly) and the
//! feature-hashing spec `(seed, D)` (so resume and merge can refuse
//! mismatched hash spaces). Version 4 adds *variant* provenance: a tag
//! naming which of the five learners the sketch was taken from, plus —
//! for the variants whose live state is more than one ball — an exact
//! per-variant payload section ([`VariantExtra`]), so `to_learner`
//! restores a kernelized core set, an ellipsoid metric, or a multiball
//! list bit-for-bit. The top-level ball stays the variant's *summary*
//! ball, which is what cross-shard merge aggregates. Version-1 sketches
//! (explicit dense `w`), version-2 and version-3 sketches still decode
//! (`merges = 0` / no hash where absent, and always as the `ball`
//! variant with no extra section).

use std::path::Path;

use crate::data::{Features, FeaturesView};
use crate::error::{Error, Result};
use crate::svm::ball::BallState;
use crate::svm::ellipsoid::EllipsoidSvm;
use crate::svm::kernelfn::Kernel;
use crate::svm::kernelized::KernelStreamSvm;
use crate::svm::learner::{AnyLearner, Variant};
use crate::svm::lookahead::LookaheadSvm;
use crate::svm::multiball::{MergePolicy, MultiBallSvm};
use crate::svm::streamsvm::StreamSvm;
use crate::svm::{HashSpec, SlackMode, TrainOptions};

/// Current wire-format version (4 = variant tag + per-variant payload;
/// 3 = merge-count + hash provenance; 2 = lazily-scaled center;
/// 1 = explicit dense `w`; all readable).
pub const SKETCH_VERSION: u16 = 4;

const MAGIC: &[u8; 4] = b"MEBS";
/// Fixed header bytes before the payload (magic + version + flags + len).
/// Public so the structure-aware fuzzer can frame and re-frame sketches.
pub const HEADER_LEN: usize = 4 + 2 + 2 + 8;
/// Trailing checksum bytes.
pub const CHECKSUM_LEN: usize = 8;

/// A serializable, mergeable snapshot of one StreamSVM learner.
#[derive(Clone, Debug, PartialEq)]
pub struct MebSketch {
    /// Feature dimension (valid even before any data arrived).
    pub dim: usize,
    /// Ball state; `None` for a learner that has seen no examples.
    pub ball: Option<BallState>,
    /// Stream position: examples consumed so far.
    pub seen: usize,
    /// Training-option fingerprint (merge compatibility is checked on
    /// `c`, `slack_mode`, `dim` and the hash spec).
    pub opts: TrainOptions,
    /// Free-form provenance tag (dataset name, shard id, ...).
    pub tag: String,
    /// Algorithm-2 merge solves performed up to `seen` (0 for
    /// Algorithm-1 learners): resuming threads this through
    /// [`crate::svm::lookahead::LookaheadSvm::from_ball`] so the paper's
    /// O(N/L) merge count survives an interruption.
    pub merges: usize,
    /// Which learner the sketch was taken from. Pre-v4 sketches decode
    /// as [`Variant::Ball`]. Resume must agree with this tag; merge
    /// refuses to fold sketches of different variants.
    pub variant: Variant,
    /// Exact live state beyond the summary ball, for the variants that
    /// carry more than one ball's worth ([`Variant::Kernelized`],
    /// [`Variant::Ellipsoid`], [`Variant::Multiball`]). `None` for ball
    /// and lookahead sketches, whose summary ball *is* the whole state.
    pub extra: Option<VariantExtra>,
}

/// Per-variant exact state section of a v4 sketch. Every field is
/// bit-copied from / into the live learner (see each variant's
/// `from_parts`), so a decoded learner scores and continues training
/// identically to the one that was encoded.
#[derive(Clone, Debug, PartialEq)]
pub enum VariantExtra {
    /// [`KernelStreamSvm`]: kernel, core set (arriving representation
    /// preserved — sparse rows stay sparse — with cached `‖x‖²`), signed
    /// coefficients, and the incrementally-maintained center norm.
    Kernelized {
        kernel: Kernel,
        /// Whether the dimension was pinned (by construction or a first
        /// example); a pinned model's dimension is the sketch's `dim`.
        pinned: bool,
        svs: Vec<(Features, f64)>,
        alpha: Vec<f64>,
        feat_norm2: f64,
        r: f64,
        xi2: f64,
    },
    /// [`EllipsoidSvm`]: the factored center `w = σ·v`, the per-axis
    /// metric scales, and the cached metric norm (`inv_s2` is
    /// recomputed bit-identically on decode).
    Ellipsoid {
        adapt: bool,
        v: Vec<f32>,
        sigma: f64,
        s: Vec<f64>,
        wnorm2s: f64,
        r: f64,
        xi2: f64,
        m: usize,
    },
    /// [`MultiBallSvm`]: the live ball list plus the merge cache *when
    /// it was materialized* — scoring switches between the merged ball
    /// and the max-margin vote on exactly that flag, so the cache state
    /// must survive the round-trip for scores to stay bit-identical.
    Multiball {
        max_balls: usize,
        policy: MergePolicy,
        balls: Vec<BallState>,
        merged: Option<BallState>,
    },
}

/// FNV-1a 64-bit — tiny, deterministic, dependency-free integrity check.
/// Public so the fuzzer's checksum-recompute-after-corrupt mutations can
/// carry a corrupted payload past the integrity gate into the structural
/// validation layer (and so persisted failing cases hash stably).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Little-endian payload reader with truncation-checked accessors.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| Error::sketch(format!("truncated payload reading {what}")))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// `n` consecutive `f32` bit patterns.
    fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let b = self.take(
            n.checked_mul(4)
                .ok_or_else(|| Error::sketch(format!("{what} length {n} overflows")))?,
            what,
        )?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    /// `n` consecutive `f64` bit patterns.
    fn f64s(&mut self, n: usize, what: &str) -> Result<Vec<f64>> {
        let b = self.take(
            n.checked_mul(8)
                .ok_or_else(|| Error::sketch(format!("{what} length {n} overflows")))?,
            what,
        )?;
        Ok(b.chunks_exact(8)
            .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

fn usize_of(v: u64, what: &str) -> Result<usize> {
    usize::try_from(v).map_err(|_| Error::sketch(format!("{what} {v} overflows usize")))
}

/// Serialize a full factored ball (unlike the top-level summary ball,
/// these carry their own dimension so the multiball list is
/// self-describing).
fn put_ball(p: &mut Vec<u8>, b: &BallState) {
    p.extend_from_slice(&(b.m as u64).to_le_bytes());
    p.extend_from_slice(&b.r.to_bits().to_le_bytes());
    p.extend_from_slice(&b.xi2.to_bits().to_le_bytes());
    p.extend_from_slice(&b.sigma().to_bits().to_le_bytes());
    p.extend_from_slice(&b.wnorm2().to_bits().to_le_bytes());
    p.extend_from_slice(&(b.dim() as u64).to_le_bytes());
    for &v in b.direction() {
        p.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

fn read_ball(r: &mut Reader<'_>, expect_dim: usize) -> Result<BallState> {
    let m = usize_of(r.u64("ball m")?, "ball m")?;
    let rad = r.f64("ball r")?;
    let xi2 = r.f64("ball xi2")?;
    let sigma = r.f64("ball sigma")?;
    let wnorm2 = r.f64("ball wnorm2")?;
    let dim = usize_of(r.u64("ball dim")?, "ball dim")?;
    if dim != expect_dim {
        return Err(Error::sketch(format!(
            "embedded ball has dimension {dim} but the sketch declares {expect_dim}"
        )));
    }
    let v = r.f32s(dim, "ball weights")?;
    Ok(BallState::from_scaled(v, sigma, wnorm2, rad, xi2, m))
}

/// Serialize features *in their arriving representation* (the
/// kernelized core set keys kernel evaluations off stored non-zeros,
/// so dense-vs-sparse must survive the round-trip bit-for-bit).
fn put_features(p: &mut Vec<u8>, f: &Features) {
    match f.view() {
        FeaturesView::Dense(xs) => {
            p.push(0);
            p.extend_from_slice(&(xs.len() as u64).to_le_bytes());
            for &x in xs {
                p.extend_from_slice(&x.to_bits().to_le_bytes());
            }
        }
        FeaturesView::Sparse { dim, idx, val } => {
            p.push(1);
            p.extend_from_slice(&(dim as u64).to_le_bytes());
            p.extend_from_slice(&(idx.len() as u64).to_le_bytes());
            for &i in idx {
                p.extend_from_slice(&i.to_le_bytes());
            }
            for &x in val {
                p.extend_from_slice(&x.to_bits().to_le_bytes());
            }
        }
    }
}

fn read_features(r: &mut Reader<'_>) -> Result<Features> {
    match r.u8("features repr")? {
        0 => {
            let n = usize_of(r.u64("dense length")?, "dense length")?;
            Ok(Features::Dense(r.f32s(n, "dense values")?))
        }
        1 => {
            let dim = usize_of(r.u64("sparse dim")?, "sparse dim")?;
            let nnz = usize_of(r.u64("sparse nnz")?, "sparse nnz")?;
            if nnz > dim {
                return Err(Error::sketch(format!("sparse nnz {nnz} exceeds dim {dim}")));
            }
            let ib = r.take(
                nnz.checked_mul(4)
                    .ok_or_else(|| Error::sketch(format!("sparse nnz {nnz} overflows")))?,
                "sparse indices",
            )?;
            let idx: Vec<u32> = ib
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            // validate before Features::sparse, whose invariants assert
            for w in idx.windows(2) {
                if w[0] >= w[1] {
                    return Err(Error::sketch("sparse indices are not strictly increasing"));
                }
            }
            if let Some(&last) = idx.last() {
                if last as usize >= dim {
                    return Err(Error::sketch(format!(
                        "sparse index {last} out of range for dim {dim}"
                    )));
                }
            }
            let val = r.f32s(nnz, "sparse values")?;
            Ok(Features::sparse(dim, idx, val))
        }
        other => Err(Error::sketch(format!("unknown features repr byte {other}"))),
    }
}

fn put_kernel(p: &mut Vec<u8>, k: Kernel) {
    match k {
        Kernel::Linear => p.push(0),
        Kernel::Rbf { gamma } => {
            p.push(1);
            p.extend_from_slice(&gamma.to_bits().to_le_bytes());
        }
        Kernel::Poly { degree, coef } => {
            p.push(2);
            p.extend_from_slice(&degree.to_le_bytes());
            p.extend_from_slice(&coef.to_bits().to_le_bytes());
        }
    }
}

fn read_kernel(r: &mut Reader<'_>) -> Result<Kernel> {
    match r.u8("kernel kind")? {
        0 => Ok(Kernel::Linear),
        1 => Ok(Kernel::Rbf { gamma: r.f64("rbf gamma")? }),
        2 => {
            let degree = r.u32("poly degree")?;
            let coef = r.f64("poly coef")?;
            Ok(Kernel::Poly { degree, coef })
        }
        other => Err(Error::sketch(format!("unknown kernel kind byte {other}"))),
    }
}

/// Decode the per-variant exact-state section of a v4 payload.
fn read_extra(r: &mut Reader<'_>, variant: Variant, dim: usize) -> Result<VariantExtra> {
    match variant {
        Variant::Kernelized => {
            let kernel = read_kernel(r)?;
            let pinned = match r.u8("pinned")? {
                0 => false,
                1 => true,
                other => return Err(Error::sketch(format!("bad pinned byte {other}"))),
            };
            let feat_norm2 = r.f64("feat_norm2")?;
            let rad = r.f64("kernelized r")?;
            let xi2 = r.f64("kernelized xi2")?;
            let n = usize_of(r.u64("core-set size")?, "core-set size")?;
            let mut svs = Vec::with_capacity(n.min(1 << 20));
            let mut alpha = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let x = read_features(r)?;
                if pinned && x.len() != dim {
                    return Err(Error::sketch(format!(
                        "core point has dimension {} but the sketch declares {dim}",
                        x.len()
                    )));
                }
                let norm2 = r.f64("core norm2")?;
                svs.push((x, norm2));
                alpha.push(r.f64("alpha")?);
            }
            Ok(VariantExtra::Kernelized { kernel, pinned, svs, alpha, feat_norm2, r: rad, xi2 })
        }
        Variant::Ellipsoid => {
            let adapt = match r.u8("adapt")? {
                0 => false,
                1 => true,
                other => return Err(Error::sketch(format!("bad adapt byte {other}"))),
            };
            let sigma = r.f64("ellipsoid sigma")?;
            let wnorm2s = r.f64("wnorm2s")?;
            let rad = r.f64("ellipsoid r")?;
            let xi2 = r.f64("ellipsoid xi2")?;
            let m = usize_of(r.u64("ellipsoid m")?, "ellipsoid m")?;
            let v = r.f32s(dim, "ellipsoid direction")?;
            let s = r.f64s(dim, "ellipsoid axes")?;
            for (j, &sj) in s.iter().enumerate() {
                if !(sj > 0.0) || !sj.is_finite() {
                    return Err(Error::sketch(format!("axis scale s[{j}] = {sj} is not positive")));
                }
            }
            Ok(VariantExtra::Ellipsoid { adapt, v, sigma, s, wnorm2s, r: rad, xi2, m })
        }
        Variant::Multiball => {
            let max_balls = usize_of(r.u64("max_balls")?, "max_balls")?;
            if max_balls == 0 {
                return Err(Error::sketch("multiball budget L must be >= 1"));
            }
            let policy = match r.u8("merge policy")? {
                0 => MergePolicy::NearestBall,
                1 => MergePolicy::NewBallMergeClosest,
                other => return Err(Error::sketch(format!("unknown merge policy byte {other}"))),
            };
            let n = usize_of(r.u64("ball count")?, "ball count")?;
            if n > max_balls {
                return Err(Error::sketch(format!(
                    "multiball sketch holds {n} balls with budget L={max_balls}"
                )));
            }
            // cap the pre-allocation: `n` is attacker-controlled in a
            // corrupted sketch, and a huge reserve aborts before the
            // truncation check inside `read_ball` can error
            let mut balls = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                balls.push(read_ball(r, dim)?);
            }
            let merged = match r.u8("has_merged")? {
                0 => None,
                1 => Some(read_ball(r, dim)?),
                other => return Err(Error::sketch(format!("bad has_merged byte {other}"))),
            };
            Ok(VariantExtra::Multiball { max_balls, policy, balls, merged })
        }
        v => Err(Error::sketch(format!("{v} sketches carry no exact-state section"))),
    }
}

impl MebSketch {
    /// Build a sketch from raw state (the checkpointer's entry point).
    pub fn new(
        dim: usize,
        ball: Option<BallState>,
        seen: usize,
        opts: TrainOptions,
        tag: impl Into<String>,
    ) -> Self {
        if let Some(b) = &ball {
            debug_assert_eq!(b.dim(), dim, "ball/sketch dim mismatch");
        }
        MebSketch {
            dim,
            ball,
            seen,
            opts,
            tag: tag.into(),
            merges: 0,
            variant: Variant::Ball,
            extra: None,
        }
    }

    /// Record the Algorithm-2 merge count in provenance (builder-style;
    /// Algorithm-1 sketches leave it at 0).
    pub fn with_merges(mut self, merges: usize) -> Self {
        self.merges = merges;
        self
    }

    /// Set the variant tag and its exact-state section (builder-style).
    pub fn with_variant(mut self, variant: Variant, extra: Option<VariantExtra>) -> Self {
        self.variant = variant;
        self.extra = extra;
        self
    }

    /// Snapshot a live model.
    pub fn from_model(model: &StreamSvm, tag: impl Into<String>) -> Self {
        MebSketch::new(
            model.dim(),
            model.ball().cloned(),
            model.examples_seen(),
            *model.options(),
            tag,
        )
    }

    /// Snapshot any live learner: the top-level ball is the variant's
    /// *summary* ball (what cross-shard merge aggregates), the variant
    /// tag + extra section carry the exact state [`Self::to_learner`]
    /// restores. Lookahead learners snapshot their absorbed ball only —
    /// call `finish()` first (or snapshot at a buffer-empty position) so
    /// no buffered survivors are dropped.
    pub fn from_learner(model: &AnyLearner, tag: impl Into<String>) -> Self {
        let base = MebSketch::new(
            model.dim(),
            model.summary_ball(),
            model.examples_seen(),
            *model.options(),
            tag,
        );
        match model {
            AnyLearner::Ball(_) => base,
            AnyLearner::Lookahead(m) => base
                .with_merges(m.num_merges())
                .with_variant(Variant::Lookahead, None),
            AnyLearner::Kernelized(m) => base.with_variant(
                Variant::Kernelized,
                Some(VariantExtra::Kernelized {
                    kernel: m.kernel(),
                    pinned: m.dim().is_some(),
                    svs: m.support_points().map(|(x, n2)| (x.clone(), n2)).collect(),
                    alpha: m.coefficients().to_vec(),
                    feat_norm2: m.feat_norm2(),
                    r: m.radius(),
                    xi2: m.xi2(),
                }),
            ),
            AnyLearner::Ellipsoid(m) => base.with_variant(
                Variant::Ellipsoid,
                Some(VariantExtra::Ellipsoid {
                    adapt: m.is_adaptive(),
                    v: m.direction().to_vec(),
                    sigma: m.sigma(),
                    s: m.axes().to_vec(),
                    wnorm2s: m.wnorm2_scaled(),
                    r: m.radius(),
                    xi2: m.xi2(),
                    m: m.num_support(),
                }),
            ),
            AnyLearner::Multiball(m) => base.with_variant(
                Variant::Multiball,
                Some(VariantExtra::Multiball {
                    max_balls: m.max_balls(),
                    policy: m.policy(),
                    balls: m.balls().to_vec(),
                    merged: m.merged_cached().cloned(),
                }),
            ),
        }
    }

    /// Rebuild the live model. The result is bit-identical to the model
    /// the sketch was taken from: feeding it the remaining stream
    /// reproduces an uninterrupted run exactly.
    ///
    /// This is the *ball* view: for a non-ball variant it rebuilds an
    /// Algorithm-1 learner from the summary ball. Use
    /// [`Self::to_learner`] to restore the exact variant.
    pub fn to_model(&self) -> StreamSvm {
        let mut model = StreamSvm::new(self.dim, self.opts);
        if let Some(b) = &self.ball {
            model.set_ball(b.clone(), self.seen);
        }
        model
    }

    /// Rebuild the exact learner the sketch's variant tag names. The
    /// result scores bit-identically to the learner
    /// [`Self::from_learner`] encoded, and continues training
    /// identically. Errors if a kernelized/ellipsoid/multiball sketch
    /// is missing its exact-state section.
    pub fn to_learner(&self) -> Result<AnyLearner> {
        match (self.variant, &self.extra) {
            (Variant::Ball, _) => Ok(AnyLearner::Ball(self.to_model())),
            (Variant::Lookahead, _) => Ok(AnyLearner::Lookahead(match &self.ball {
                Some(b) => LookaheadSvm::from_ball(
                    self.dim,
                    self.opts,
                    b.clone(),
                    self.seen,
                    self.merges,
                ),
                None => LookaheadSvm::new(self.dim, self.opts),
            })),
            (
                Variant::Kernelized,
                Some(VariantExtra::Kernelized { kernel, pinned, svs, alpha, feat_norm2, r, xi2 }),
            ) => {
                if svs.len() != alpha.len() {
                    return Err(Error::sketch(format!(
                        "kernelized sketch has {} core points but {} coefficients",
                        svs.len(),
                        alpha.len()
                    )));
                }
                Ok(AnyLearner::Kernelized(KernelStreamSvm::from_parts(
                    *kernel,
                    pinned.then_some(self.dim),
                    svs.clone(),
                    alpha.clone(),
                    *feat_norm2,
                    *r,
                    *xi2,
                    self.opts,
                    self.seen,
                )))
            }
            (
                Variant::Ellipsoid,
                Some(VariantExtra::Ellipsoid { adapt, v, sigma, s, wnorm2s, r, xi2, m }),
            ) => {
                if v.len() != self.dim || s.len() != self.dim {
                    return Err(Error::sketch(format!(
                        "ellipsoid sketch state has dimension {}/{} but the sketch declares {}",
                        v.len(),
                        s.len(),
                        self.dim
                    )));
                }
                Ok(AnyLearner::Ellipsoid(EllipsoidSvm::from_parts(
                    self.dim, self.opts, *adapt, v.clone(), *sigma, s.clone(), *wnorm2s, *r,
                    *xi2, *m, self.seen,
                )))
            }
            (
                Variant::Multiball,
                Some(VariantExtra::Multiball { max_balls, policy, balls, merged }),
            ) => {
                if *max_balls == 0 || balls.len() > *max_balls {
                    return Err(Error::sketch(format!(
                        "multiball sketch holds {} balls with budget L={max_balls}",
                        balls.len()
                    )));
                }
                Ok(AnyLearner::Multiball(MultiBallSvm::from_parts(
                    self.dim,
                    *max_balls,
                    *policy,
                    self.opts,
                    balls.clone(),
                    merged.clone(),
                    self.seen,
                )))
            }
            (v, _) => Err(Error::sketch(format!(
                "{v} sketch is missing its exact-state section"
            ))),
        }
    }

    /// Ball radius (0 for an empty sketch) — convenience for reporting.
    pub fn radius(&self) -> f64 {
        self.ball.as_ref().map(|b| b.r).unwrap_or(0.0)
    }

    /// Core-set size (0 for an empty sketch).
    pub fn num_support(&self) -> usize {
        self.ball.as_ref().map(|b| b.m).unwrap_or(0)
    }

    /// Can `self` and `other` be merged into one model? Requires the same
    /// feature dimension, the same `(C, slack_mode)` geometry and the
    /// same feature-hash space — lookahead and merge-iteration budgets
    /// are training-time tuning and may differ between shards.
    pub fn compatible(&self, other: &MebSketch) -> bool {
        self.dim == other.dim
            && self.opts.c.to_bits() == other.opts.c.to_bits()
            && self.opts.slack_mode == other.opts.slack_mode
            && self.opts.hash == other.opts.hash
    }

    /// One-line human summary for CLI output.
    pub fn summary(&self) -> String {
        let hash = match self.opts.hash {
            Some(h) => format!(" hash=D{}@{:#x}", h.dim, h.seed),
            None => String::new(),
        };
        format!(
            "tag={} variant={} dim={} seen={} supports={} R={:.4} C={} slack={:?}{hash}",
            if self.tag.is_empty() { "-" } else { &self.tag },
            self.variant,
            self.dim,
            self.seen,
            self.num_support(),
            self.radius(),
            self.opts.c,
            self.opts.slack_mode,
        )
    }

    /// Serialize to the versioned, checksummed wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut p: Vec<u8> = Vec::with_capacity(64 + self.tag.len() + 4 * self.dim);
        p.extend_from_slice(&(self.tag.len() as u32).to_le_bytes());
        p.extend_from_slice(self.tag.as_bytes());
        p.extend_from_slice(&self.opts.c.to_bits().to_le_bytes());
        p.push(match self.opts.slack_mode {
            SlackMode::Paper => 0,
            SlackMode::Consistent => 1,
        });
        p.extend_from_slice(&(self.opts.lookahead as u64).to_le_bytes());
        p.extend_from_slice(&(self.opts.merge_iters as u64).to_le_bytes());
        p.extend_from_slice(&(self.merges as u64).to_le_bytes());
        match self.opts.hash {
            None => p.push(0),
            Some(h) => {
                p.push(1);
                p.extend_from_slice(&h.seed.to_le_bytes());
                p.extend_from_slice(&(h.dim as u64).to_le_bytes());
            }
        }
        p.extend_from_slice(&(self.seen as u64).to_le_bytes());
        p.extend_from_slice(&(self.dim as u64).to_le_bytes());
        match &self.ball {
            None => p.push(0),
            Some(b) => {
                p.push(1);
                p.extend_from_slice(&(b.m as u64).to_le_bytes());
                p.extend_from_slice(&b.r.to_bits().to_le_bytes());
                p.extend_from_slice(&b.xi2.to_bits().to_le_bytes());
                p.extend_from_slice(&b.sigma().to_bits().to_le_bytes());
                p.extend_from_slice(&b.wnorm2().to_bits().to_le_bytes());
                for &v in b.direction() {
                    p.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
        }
        // v4: variant tag + exact-state section
        p.push(self.variant.tag());
        match &self.extra {
            None => p.push(0),
            Some(extra) => {
                p.push(1);
                match extra {
                    VariantExtra::Kernelized {
                        kernel,
                        pinned,
                        svs,
                        alpha,
                        feat_norm2,
                        r,
                        xi2,
                    } => {
                        put_kernel(&mut p, *kernel);
                        p.push(u8::from(*pinned));
                        p.extend_from_slice(&feat_norm2.to_bits().to_le_bytes());
                        p.extend_from_slice(&r.to_bits().to_le_bytes());
                        p.extend_from_slice(&xi2.to_bits().to_le_bytes());
                        p.extend_from_slice(&(svs.len() as u64).to_le_bytes());
                        for ((x, norm2), a) in svs.iter().zip(alpha) {
                            put_features(&mut p, x);
                            p.extend_from_slice(&norm2.to_bits().to_le_bytes());
                            p.extend_from_slice(&a.to_bits().to_le_bytes());
                        }
                    }
                    VariantExtra::Ellipsoid { adapt, v, sigma, s, wnorm2s, r, xi2, m } => {
                        p.push(u8::from(*adapt));
                        p.extend_from_slice(&sigma.to_bits().to_le_bytes());
                        p.extend_from_slice(&wnorm2s.to_bits().to_le_bytes());
                        p.extend_from_slice(&r.to_bits().to_le_bytes());
                        p.extend_from_slice(&xi2.to_bits().to_le_bytes());
                        p.extend_from_slice(&(*m as u64).to_le_bytes());
                        for &x in v {
                            p.extend_from_slice(&x.to_bits().to_le_bytes());
                        }
                        for &x in s {
                            p.extend_from_slice(&x.to_bits().to_le_bytes());
                        }
                    }
                    VariantExtra::Multiball { max_balls, policy, balls, merged } => {
                        p.extend_from_slice(&(*max_balls as u64).to_le_bytes());
                        p.push(match policy {
                            MergePolicy::NearestBall => 0,
                            MergePolicy::NewBallMergeClosest => 1,
                        });
                        p.extend_from_slice(&(balls.len() as u64).to_le_bytes());
                        for b in balls {
                            put_ball(&mut p, b);
                        }
                        match merged {
                            None => p.push(0),
                            Some(b) => {
                                p.push(1);
                                put_ball(&mut p, b);
                            }
                        }
                    }
                }
            }
        }
        let mut out = Vec::with_capacity(HEADER_LEN + p.len() + CHECKSUM_LEN);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&SKETCH_VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // flags
        out.extend_from_slice(&(p.len() as u64).to_le_bytes());
        let sum = fnv1a64(&p);
        out.extend_from_slice(&p);
        out.extend_from_slice(&sum.to_le_bytes());
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::SKETCH_ENCODES.inc();
            crate::obs::telemetry::SKETCH_BYTES.add(out.len() as u64);
        }
        out
    }

    /// Deserialize, validating magic, version, length and checksum.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < HEADER_LEN + CHECKSUM_LEN {
            return Err(Error::sketch(format!(
                "{} bytes is too short for a sketch header",
                bytes.len()
            )));
        }
        if &bytes[..4] != MAGIC {
            return Err(Error::sketch("bad magic (not a MEBS sketch)"));
        }
        let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
        if version == 0 || version > SKETCH_VERSION {
            return Err(Error::sketch(format!(
                "unsupported sketch version {version} (this build reads <= {SKETCH_VERSION})"
            )));
        }
        let payload_len =
            usize_of(u64::from_le_bytes(bytes[8..16].try_into().unwrap()), "payload length")?;
        let expect = HEADER_LEN
            .checked_add(payload_len)
            .and_then(|v| v.checked_add(CHECKSUM_LEN))
            .ok_or_else(|| {
                Error::sketch(format!("payload length {payload_len} overflows the sketch size"))
            })?;
        if bytes.len() != expect {
            return Err(Error::sketch(format!(
                "length mismatch: header promises {expect} bytes, got {}",
                bytes.len()
            )));
        }
        let payload = &bytes[HEADER_LEN..HEADER_LEN + payload_len];
        let stored = u64::from_le_bytes(bytes[HEADER_LEN + payload_len..].try_into().unwrap());
        let actual = fnv1a64(payload);
        if stored != actual {
            return Err(Error::sketch(format!(
                "checksum mismatch (stored {stored:#018x}, computed {actual:#018x}) — \
                 corrupt or partially-written sketch"
            )));
        }

        let mut r = Reader::new(payload);
        let tag_len = usize_of(r.u32("tag length")? as u64, "tag length")?;
        let tag = std::str::from_utf8(r.take(tag_len, "tag")?)
            .map_err(|_| Error::sketch("tag is not valid UTF-8"))?
            .to_string();
        let c = r.f64("c")?;
        let slack_mode = match r.u8("slack_mode")? {
            0 => SlackMode::Paper,
            1 => SlackMode::Consistent,
            other => return Err(Error::sketch(format!("unknown slack mode byte {other}"))),
        };
        let lookahead = usize_of(r.u64("lookahead")?, "lookahead")?;
        let merge_iters = usize_of(r.u64("merge_iters")?, "merge_iters")?;
        // v3 provenance: merge count + feature-hash spec.
        let (merges, hash) = if version >= 3 {
            let merges = usize_of(r.u64("merges")?, "merges")?;
            let hash = match r.u8("has_hash")? {
                0 => None,
                1 => {
                    let seed = r.u64("hash_seed")?;
                    let dim = usize_of(r.u64("hash_dim")?, "hash_dim")?;
                    if dim == 0 {
                        return Err(Error::sketch("hash_dim must be >= 1"));
                    }
                    Some(HashSpec { dim, seed })
                }
                other => return Err(Error::sketch(format!("bad has_hash byte {other}"))),
            };
            (merges, hash)
        } else {
            (0, None)
        };
        let seen = usize_of(r.u64("seen")?, "seen")?;
        let dim = usize_of(r.u64("dim")?, "dim")?;
        let ball = match r.u8("has_ball")? {
            0 => None,
            1 => {
                let m = usize_of(r.u64("m")?, "m")?;
                let rad = r.f64("r")?;
                let xi2 = r.f64("xi2")?;
                // v2 carries the factored center; v1 stored dense w.
                let (sigma, wnorm2) = if version >= 2 {
                    (Some(r.f64("sigma")?), Some(r.f64("wnorm2")?))
                } else {
                    (None, None)
                };
                let wb = r.take(dim.checked_mul(4).ok_or_else(|| {
                    Error::sketch(format!("dim {dim} overflows the weight size"))
                })?, "weights")?;
                let w: Vec<f32> = wb
                    .chunks_exact(4)
                    .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                    .collect();
                Some(match (sigma, wnorm2) {
                    (Some(sigma), Some(wnorm2)) => {
                        BallState::from_scaled(w, sigma, wnorm2, rad, xi2, m)
                    }
                    _ => BallState::from_parts(w, rad, xi2, m),
                })
            }
            other => return Err(Error::sketch(format!("bad has_ball byte {other}"))),
        };
        // v4: variant tag + exact-state section; older sketches are
        // always Algorithm-1 ball snapshots.
        let (variant, extra) = if version >= 4 {
            let variant = Variant::from_tag(r.u8("variant")?)?;
            let extra = match r.u8("has_extra")? {
                0 => None,
                1 => Some(read_extra(&mut r, variant, dim)?),
                other => return Err(Error::sketch(format!("bad has_extra byte {other}"))),
            };
            (variant, extra)
        } else {
            (Variant::Ball, None)
        };
        if !r.done() {
            return Err(Error::sketch("trailing bytes after sketch payload"));
        }
        let opts = TrainOptions { c, slack_mode, lookahead, merge_iters, hash };
        Ok(MebSketch { dim, ball, seen, opts, tag, merges, variant, extra })
    }

    /// Write atomically: encode to `<path>.tmp`, then rename over `path`,
    /// so a crash mid-write never leaves a truncated sketch behind.
    pub fn write_to(&self, path: &Path) -> Result<()> {
        let t0 = std::time::Instant::now();
        let bytes = self.encode();
        let tmp = path.with_extension("meb.tmp");
        std::fs::write(&tmp, &bytes)?;
        std::fs::rename(&tmp, path)?;
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::SKETCH_WRITE_NS.add(t0.elapsed().as_nanos() as u64);
        }
        crate::obs_debug!(
            "sketch";
            bytes = bytes.len(),
            seen = self.seen,
            radius = self.radius();
            "wrote sketch to {}",
            path.display()
        );
        Ok(())
    }

    /// Read and decode a sketch file.
    pub fn read_from(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::sketch(format!("cannot read {}: {e}", path.display())))?;
        Self::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Example;
    use crate::prop::{check_default, gen};

    fn trained(n: usize, d: usize, seed: u64, opts: &TrainOptions) -> StreamSvm {
        let mut rng = crate::rng::Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, 0.5);
        let exs: Vec<Example> = xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
        StreamSvm::fit(exs.iter(), d, opts)
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        check_default("sketch-roundtrip", |rng, case| {
            let d = gen::dim(rng);
            let n = 1 + rng.below(120);
            let opts = TrainOptions::default()
                .with_c(0.25 + rng.uniform() * 8.0)
                .with_lookahead(1 + rng.below(20));
            let model = trained(n, d, 1000 + case as u64, &opts);
            let sk = MebSketch::from_model(&model, format!("case-{case}"));
            let back = MebSketch::decode(&sk.encode()).map_err(|e| e.to_string())?;
            if back != sk {
                return Err("decoded sketch differs".into());
            }
            let m2 = back.to_model();
            let (a, b) = (model.ball().unwrap(), m2.ball().unwrap());
            if a.direction() != b.direction()
                || a.sigma().to_bits() != b.sigma().to_bits()
                || a.wnorm2().to_bits() != b.wnorm2().to_bits()
                || a.r.to_bits() != b.r.to_bits()
                || a.xi2.to_bits() != b.xi2.to_bits()
                || a.m != b.m
                || m2.examples_seen() != model.examples_seen()
            {
                return Err("rebuilt model is not bit-identical".into());
            }
            Ok(())
        });
    }

    #[test]
    fn empty_model_roundtrips() {
        let model = StreamSvm::new(7, TrainOptions::default());
        let sk = MebSketch::from_model(&model, "empty");
        let back = MebSketch::decode(&sk.encode()).unwrap();
        assert_eq!(back, sk);
        assert!(back.ball.is_none());
        let m2 = back.to_model();
        assert_eq!(m2.dim(), 7);
        assert_eq!(m2.examples_seen(), 0);
    }

    #[test]
    fn corruption_detected() {
        let model = trained(60, 5, 9, &TrainOptions::default());
        let good = MebSketch::from_model(&model, "t").encode();

        // flip one payload byte → checksum error
        let mut bad = good.clone();
        let mid = HEADER_LEN + 10;
        bad[mid] ^= 0xFF;
        let e = MebSketch::decode(&bad).unwrap_err();
        assert!(e.to_string().contains("checksum"), "{e}");

        // truncate → length error
        let e = MebSketch::decode(&good[..good.len() - 3]).unwrap_err();
        assert!(e.to_string().contains("length"), "{e}");

        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(MebSketch::decode(&bad).unwrap_err().to_string().contains("magic"));

        // future version
        let mut bad = good.clone();
        bad[4] = 0xFF;
        bad[5] = 0xFF;
        assert!(MebSketch::decode(&bad).unwrap_err().to_string().contains("version"));

        // too short entirely
        assert!(MebSketch::decode(&good[..8]).is_err());
    }

    #[test]
    fn file_roundtrip_atomic() {
        let dir = std::env::temp_dir().join(format!("ssvm_sketch_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.meb");
        let model = trained(40, 3, 11, &TrainOptions::default().with_c(2.0));
        let sk = MebSketch::from_model(&model, "file");
        sk.write_to(&path).unwrap();
        // the temp file must be gone after the rename
        assert!(!path.with_extension("meb.tmp").exists());
        let back = MebSketch::read_from(&path).unwrap();
        assert_eq!(back, sk);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn decodes_version1_sketches() {
        // Hand-assemble a v1 payload (explicit dense w, no sigma/wnorm2)
        // and check it decodes to the equivalent factored state.
        let w = [1.5f32, -2.0, 0.5];
        let (rad, xi2, m, seen) = (3.25f64, 0.5f64, 4usize, 17usize);
        let opts = TrainOptions::default().with_c(2.0);
        let mut p: Vec<u8> = Vec::new();
        p.extend_from_slice(&(2u32).to_le_bytes()); // tag len
        p.extend_from_slice(b"v1");
        p.extend_from_slice(&opts.c.to_bits().to_le_bytes());
        p.push(1); // Consistent
        p.extend_from_slice(&(opts.lookahead as u64).to_le_bytes());
        p.extend_from_slice(&(opts.merge_iters as u64).to_le_bytes());
        p.extend_from_slice(&(seen as u64).to_le_bytes());
        p.extend_from_slice(&(w.len() as u64).to_le_bytes());
        p.push(1); // has_ball
        p.extend_from_slice(&(m as u64).to_le_bytes());
        p.extend_from_slice(&rad.to_bits().to_le_bytes());
        p.extend_from_slice(&xi2.to_bits().to_le_bytes());
        for &v in &w {
            p.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u16.to_le_bytes()); // version 1
        bytes.extend_from_slice(&0u16.to_le_bytes());
        bytes.extend_from_slice(&(p.len() as u64).to_le_bytes());
        let sum = fnv1a64(&p);
        bytes.extend_from_slice(&p);
        bytes.extend_from_slice(&sum.to_le_bytes());

        let sk = MebSketch::decode(&bytes).unwrap();
        assert_eq!(sk.tag, "v1");
        assert_eq!(sk.dim, 3);
        assert_eq!(sk.seen, seen);
        let b = sk.ball.as_ref().unwrap();
        assert_eq!(b.weights(), w.to_vec());
        assert_eq!(b.sigma(), 1.0);
        assert_eq!(b.r, rad);
        assert_eq!(b.xi2, xi2);
        assert_eq!(b.m, m);
        // and re-encoding writes the current (v2) format
        let back = MebSketch::decode(&sk.encode()).unwrap();
        assert_eq!(back, sk);
    }

    #[test]
    fn merges_and_hash_provenance_roundtrip() {
        let model = trained(80, 6, 13, &TrainOptions::default().with_lookahead(4));
        let mut sk = MebSketch::from_model(&model, "prov").with_merges(7);
        sk.opts.hash = Some(HashSpec { dim: 4096, seed: 0xDEAD_BEEF });
        let back = MebSketch::decode(&sk.encode()).unwrap();
        assert_eq!(back, sk);
        assert_eq!(back.merges, 7);
        assert_eq!(back.opts.hash, Some(HashSpec { dim: 4096, seed: 0xDEAD_BEEF }));
        // no-hash sketches roundtrip too
        let sk2 = MebSketch::from_model(&model, "prov2").with_merges(3);
        let back2 = MebSketch::decode(&sk2.encode()).unwrap();
        assert_eq!(back2.merges, 3);
        assert_eq!(back2.opts.hash, None);
    }

    #[test]
    fn decodes_version2_sketches() {
        // Hand-assemble a v2 payload (factored center, no merges/hash
        // fields) and check it decodes with merges = 0 and no hash spec.
        let v = [1.5f32, -2.0];
        let (sigma, wnorm2) = (0.5f64, 1.5625f64);
        let (rad, xi2, m, seen) = (2.0f64, 0.25f64, 3usize, 9usize);
        let opts = TrainOptions::default();
        let mut p: Vec<u8> = Vec::new();
        p.extend_from_slice(&(2u32).to_le_bytes());
        p.extend_from_slice(b"v2");
        p.extend_from_slice(&opts.c.to_bits().to_le_bytes());
        p.push(1); // Consistent
        p.extend_from_slice(&(opts.lookahead as u64).to_le_bytes());
        p.extend_from_slice(&(opts.merge_iters as u64).to_le_bytes());
        p.extend_from_slice(&(seen as u64).to_le_bytes());
        p.extend_from_slice(&(v.len() as u64).to_le_bytes());
        p.push(1); // has_ball
        p.extend_from_slice(&(m as u64).to_le_bytes());
        p.extend_from_slice(&rad.to_bits().to_le_bytes());
        p.extend_from_slice(&xi2.to_bits().to_le_bytes());
        p.extend_from_slice(&sigma.to_bits().to_le_bytes());
        p.extend_from_slice(&wnorm2.to_bits().to_le_bytes());
        for &x in &v {
            p.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&2u16.to_le_bytes()); // version 2
        bytes.extend_from_slice(&0u16.to_le_bytes());
        bytes.extend_from_slice(&(p.len() as u64).to_le_bytes());
        let sum = fnv1a64(&p);
        bytes.extend_from_slice(&p);
        bytes.extend_from_slice(&sum.to_le_bytes());

        let sk = MebSketch::decode(&bytes).unwrap();
        assert_eq!(sk.tag, "v2");
        assert_eq!(sk.merges, 0);
        assert_eq!(sk.opts.hash, None);
        let b = sk.ball.as_ref().unwrap();
        assert_eq!(b.sigma(), sigma);
        assert_eq!(b.direction(), &v);
        assert_eq!(b.wnorm2(), wnorm2);
    }

    #[test]
    fn compatibility_fingerprint() {
        let a = MebSketch::new(4, None, 0, TrainOptions::default(), "a");
        let b = MebSketch::new(4, None, 0, TrainOptions::default().with_lookahead(10), "b");
        assert!(a.compatible(&b), "lookahead must not affect compatibility");
        let c = MebSketch::new(4, None, 0, TrainOptions::default().with_c(2.0), "c");
        assert!(!a.compatible(&c));
        let d = MebSketch::new(5, None, 0, TrainOptions::default(), "d");
        assert!(!a.compatible(&d));
        let e = MebSketch::new(
            4,
            None,
            0,
            TrainOptions::default().with_slack_mode(SlackMode::Paper),
            "e",
        );
        assert!(!a.compatible(&e));
        // mismatched hash spaces are incompatible (dim, seed, presence)
        let h = |dim, seed| {
            MebSketch::new(
                4,
                None,
                0,
                TrainOptions::default().with_hash(Some(HashSpec { dim, seed })),
                "h",
            )
        };
        assert!(!a.compatible(&h(4, 1)), "hashed vs unhashed must differ");
        assert!(!h(4, 1).compatible(&h(4, 2)), "seeds must match");
        assert!(h(4, 1).compatible(&h(4, 1)));
        // merge count is provenance, not compatibility
        assert!(a.compatible(&MebSketch::new(4, None, 0, TrainOptions::default(), "m").with_merges(9)));
    }

    #[test]
    fn v4_learner_roundtrip_is_bit_exact_per_variant() {
        let mut rng = crate::rng::Pcg32::seeded(31);
        let d = 5;
        let (xs, ys) = gen::labeled_points(&mut rng, 120, d, 1.2, 0.5);
        let exs: Vec<Example> =
            xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
        let probes: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        let opts = TrainOptions::default().with_c(2.0);
        for variant in Variant::ALL {
            let mut m = AnyLearner::new(variant, d, opts);
            for e in &exs {
                m.observe_view(e.x.view(), e.y);
            }
            // mid-stream snapshot: multiball's merge cache is cold here
            // (max-vote scoring), lookahead may hold buffered survivors
            // that the sketch deliberately excludes — scoring must still
            // agree bit-for-bit because scoring never sees the buffer.
            let sk = MebSketch::from_learner(&m, variant.name());
            let back = MebSketch::decode(&sk.encode()).unwrap();
            assert_eq!(back, sk, "{variant}: decoded sketch differs");
            assert_eq!(back.variant, variant);
            let restored = back.to_learner().unwrap();
            assert_eq!(restored.variant(), variant);
            assert_eq!(restored.examples_seen(), m.examples_seen(), "{variant}");
            assert_eq!(restored.radius().to_bits(), m.radius().to_bits(), "{variant}");
            for p in &probes {
                assert_eq!(
                    restored.score(p).to_bits(),
                    m.score(p).to_bits(),
                    "{variant}: scores diverged after round-trip"
                );
            }
            // after finish() (multiball materializes its merge cache,
            // lookahead flushes) a fresh snapshot still round-trips
            m.finish();
            let sk2 = MebSketch::from_learner(&m, "finished");
            let restored = MebSketch::decode(&sk2.encode()).unwrap().to_learner().unwrap();
            assert_eq!(restored.radius().to_bits(), m.radius().to_bits(), "{variant} finished");
            for p in &probes {
                assert_eq!(
                    restored.score(p).to_bits(),
                    m.score(p).to_bits(),
                    "{variant}: finished scores diverged"
                );
            }
        }
    }

    #[test]
    fn v4_nonlinear_kernel_roundtrip_preserves_sparse_core_points() {
        use crate::svm::kernelfn::Kernel;
        let mut rng = crate::rng::Pcg32::seeded(33);
        let d = 6;
        let (xs, ys) = gen::labeled_points(&mut rng, 90, d, 1.0, 0.4);
        let opts = TrainOptions::default();
        let mut m = AnyLearner::with_kernel(
            Variant::Kernelized,
            d,
            opts,
            Kernel::Rbf { gamma: 0.7 },
        );
        for (i, (x, y)) in xs.iter().zip(&ys).enumerate() {
            // alternate representations so the core set holds both
            if i % 2 == 0 {
                let f = crate::data::Features::Dense(x.clone()).to_sparse();
                m.observe_view(f.view(), *y);
            } else {
                m.observe_view(crate::data::FeaturesView::Dense(x), *y);
            }
        }
        let sk = MebSketch::from_learner(&m, "rbf");
        assert!(sk.ball.is_none(), "non-linear kernels have no primal summary ball");
        assert_eq!(sk.variant, Variant::Kernelized);
        let back = MebSketch::decode(&sk.encode()).unwrap();
        assert_eq!(back, sk);
        let restored = back.to_learner().unwrap();
        assert_eq!(restored.num_support(), m.num_support());
        assert_eq!(restored.radius().to_bits(), m.radius().to_bits());
        for _ in 0..6 {
            let p: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            assert_eq!(restored.score(&p).to_bits(), m.score(&p).to_bits());
        }
    }

    #[test]
    fn decodes_version3_sketches_as_ball_variant() {
        // Hand-assemble a v3 payload (merges + hash provenance, no
        // variant tag) and check it decodes as the ball variant.
        let v = [0.5f32, 1.0, -0.25];
        let (sigma, wnorm2) = (1.0f64, 1.3125f64);
        let (rad, xi2, m, seen, merges) = (1.5f64, 0.125f64, 2usize, 11usize, 4usize);
        let opts = TrainOptions::default();
        let mut p: Vec<u8> = Vec::new();
        p.extend_from_slice(&(2u32).to_le_bytes());
        p.extend_from_slice(b"v3");
        p.extend_from_slice(&opts.c.to_bits().to_le_bytes());
        p.push(1); // Consistent
        p.extend_from_slice(&(opts.lookahead as u64).to_le_bytes());
        p.extend_from_slice(&(opts.merge_iters as u64).to_le_bytes());
        p.extend_from_slice(&(merges as u64).to_le_bytes());
        p.push(0); // no hash
        p.extend_from_slice(&(seen as u64).to_le_bytes());
        p.extend_from_slice(&(v.len() as u64).to_le_bytes());
        p.push(1); // has_ball
        p.extend_from_slice(&(m as u64).to_le_bytes());
        p.extend_from_slice(&rad.to_bits().to_le_bytes());
        p.extend_from_slice(&xi2.to_bits().to_le_bytes());
        p.extend_from_slice(&sigma.to_bits().to_le_bytes());
        p.extend_from_slice(&wnorm2.to_bits().to_le_bytes());
        for &x in &v {
            p.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&3u16.to_le_bytes()); // version 3
        bytes.extend_from_slice(&0u16.to_le_bytes());
        bytes.extend_from_slice(&(p.len() as u64).to_le_bytes());
        let sum = fnv1a64(&p);
        bytes.extend_from_slice(&p);
        bytes.extend_from_slice(&sum.to_le_bytes());

        let sk = MebSketch::decode(&bytes).unwrap();
        assert_eq!(sk.tag, "v3");
        assert_eq!(sk.variant, Variant::Ball);
        assert!(sk.extra.is_none());
        assert_eq!(sk.merges, merges);
        assert_eq!(sk.seen, seen);
        // re-encoding writes v4 and round-trips
        let back = MebSketch::decode(&sk.encode()).unwrap();
        assert_eq!(back, sk);
        // and the exact learner it restores is the Algorithm-1 model
        let learner = sk.to_learner().unwrap();
        assert_eq!(learner.variant(), Variant::Ball);
        assert_eq!(learner.examples_seen(), seen);
    }

    #[test]
    fn variant_sketch_without_extra_is_rejected_by_to_learner() {
        let sk = MebSketch::new(3, None, 0, TrainOptions::default(), "hollow")
            .with_variant(Variant::Kernelized, None);
        let err = sk.to_learner().unwrap_err();
        assert!(err.to_string().contains("exact-state"), "{err}");
        // ...but ball and lookahead never need one
        for v in [Variant::Ball, Variant::Lookahead] {
            let sk = MebSketch::new(3, None, 0, TrainOptions::default(), "ok")
                .with_variant(v, None);
            assert_eq!(sk.to_learner().unwrap().variant(), v);
        }
    }
}
