//! `MebSketch` — the durable form of a StreamSVM model.
//!
//! The entire learner state is a ball `(w, R, ξ², M)` plus stream
//! provenance (examples seen, training-option fingerprint, dataset tag),
//! a few hundred bytes for typical dimensions. The wire format is
//! versioned, length-prefixed and checksummed:
//!
//! ```text
//!   magic   "MEBS"                     4 bytes
//!   version u16 LE                     2 bytes
//!   flags   u16 LE (reserved, 0)       2 bytes
//!   len     u64 LE (payload bytes)     8 bytes
//!   payload                            len bytes
//!   fnv1a64 u64 LE (over payload)      8 bytes
//! ```
//!
//! Payload, all little-endian:
//! `tag(u32 len + utf8) · c(f64) · slack_mode(u8) · lookahead(u64) ·
//! merge_iters(u64) · merges(u64) · has_hash(u8) · [hash_seed(u64) ·
//! hash_dim(u64)] · seen(u64) · dim(u64) · has_ball(u8) ·
//! [m(u64) · r(f64) · xi2(f64) · sigma(f64) · wnorm2(f64) ·
//! v(dim × f32)]`.
//!
//! Version 2 serializes the ball's *factored* center `w = σ·v` (plus
//! the cached `‖w‖²`) exactly as the live state holds it, so decode →
//! resume → continue training reproduces an uninterrupted run
//! bit-for-bit — including the lazy-scaling fold schedule. Version 3
//! adds two provenance fields: the Algorithm-2 merge count (so a
//! resumed run reports the paper's O(N/L) bound correctly) and the
//! feature-hashing spec `(seed, D)` (so resume and merge can refuse
//! mismatched hash spaces). Version-1 sketches (explicit dense `w`)
//! and version-2 sketches still decode (`merges = 0`, no hash).

use std::path::Path;

use crate::error::{Error, Result};
use crate::svm::ball::BallState;
use crate::svm::streamsvm::StreamSvm;
use crate::svm::{HashSpec, SlackMode, TrainOptions};

/// Current wire-format version (3 = merge-count + hash provenance;
/// 2 = lazily-scaled center; 1 = explicit dense `w`; all readable).
pub const SKETCH_VERSION: u16 = 3;

const MAGIC: &[u8; 4] = b"MEBS";
/// Fixed header bytes before the payload.
const HEADER_LEN: usize = 4 + 2 + 2 + 8;
/// Trailing checksum bytes.
const CHECKSUM_LEN: usize = 8;

/// A serializable, mergeable snapshot of one StreamSVM learner.
#[derive(Clone, Debug, PartialEq)]
pub struct MebSketch {
    /// Feature dimension (valid even before any data arrived).
    pub dim: usize,
    /// Ball state; `None` for a learner that has seen no examples.
    pub ball: Option<BallState>,
    /// Stream position: examples consumed so far.
    pub seen: usize,
    /// Training-option fingerprint (merge compatibility is checked on
    /// `c`, `slack_mode`, `dim` and the hash spec).
    pub opts: TrainOptions,
    /// Free-form provenance tag (dataset name, shard id, ...).
    pub tag: String,
    /// Algorithm-2 merge solves performed up to `seen` (0 for
    /// Algorithm-1 learners): resuming threads this through
    /// [`crate::svm::lookahead::LookaheadSvm::from_ball`] so the paper's
    /// O(N/L) merge count survives an interruption.
    pub merges: usize,
}

/// FNV-1a 64-bit — tiny, deterministic, dependency-free integrity check.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Little-endian payload reader with truncation-checked accessors.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| Error::sketch(format!("truncated payload reading {what}")))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

fn usize_of(v: u64, what: &str) -> Result<usize> {
    usize::try_from(v).map_err(|_| Error::sketch(format!("{what} {v} overflows usize")))
}

impl MebSketch {
    /// Build a sketch from raw state (the checkpointer's entry point).
    pub fn new(
        dim: usize,
        ball: Option<BallState>,
        seen: usize,
        opts: TrainOptions,
        tag: impl Into<String>,
    ) -> Self {
        if let Some(b) = &ball {
            debug_assert_eq!(b.dim(), dim, "ball/sketch dim mismatch");
        }
        MebSketch { dim, ball, seen, opts, tag: tag.into(), merges: 0 }
    }

    /// Record the Algorithm-2 merge count in provenance (builder-style;
    /// Algorithm-1 sketches leave it at 0).
    pub fn with_merges(mut self, merges: usize) -> Self {
        self.merges = merges;
        self
    }

    /// Snapshot a live model.
    pub fn from_model(model: &StreamSvm, tag: impl Into<String>) -> Self {
        MebSketch::new(
            model.dim(),
            model.ball().cloned(),
            model.examples_seen(),
            *model.options(),
            tag,
        )
    }

    /// Rebuild the live model. The result is bit-identical to the model
    /// the sketch was taken from: feeding it the remaining stream
    /// reproduces an uninterrupted run exactly.
    pub fn to_model(&self) -> StreamSvm {
        let mut model = StreamSvm::new(self.dim, self.opts);
        if let Some(b) = &self.ball {
            model.set_ball(b.clone(), self.seen);
        }
        model
    }

    /// Ball radius (0 for an empty sketch) — convenience for reporting.
    pub fn radius(&self) -> f64 {
        self.ball.as_ref().map(|b| b.r).unwrap_or(0.0)
    }

    /// Core-set size (0 for an empty sketch).
    pub fn num_support(&self) -> usize {
        self.ball.as_ref().map(|b| b.m).unwrap_or(0)
    }

    /// Can `self` and `other` be merged into one model? Requires the same
    /// feature dimension, the same `(C, slack_mode)` geometry and the
    /// same feature-hash space — lookahead and merge-iteration budgets
    /// are training-time tuning and may differ between shards.
    pub fn compatible(&self, other: &MebSketch) -> bool {
        self.dim == other.dim
            && self.opts.c.to_bits() == other.opts.c.to_bits()
            && self.opts.slack_mode == other.opts.slack_mode
            && self.opts.hash == other.opts.hash
    }

    /// One-line human summary for CLI output.
    pub fn summary(&self) -> String {
        let hash = match self.opts.hash {
            Some(h) => format!(" hash=D{}@{:#x}", h.dim, h.seed),
            None => String::new(),
        };
        format!(
            "tag={} dim={} seen={} supports={} R={:.4} C={} slack={:?}{hash}",
            if self.tag.is_empty() { "-" } else { &self.tag },
            self.dim,
            self.seen,
            self.num_support(),
            self.radius(),
            self.opts.c,
            self.opts.slack_mode,
        )
    }

    /// Serialize to the versioned, checksummed wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut p: Vec<u8> = Vec::with_capacity(64 + self.tag.len() + 4 * self.dim);
        p.extend_from_slice(&(self.tag.len() as u32).to_le_bytes());
        p.extend_from_slice(self.tag.as_bytes());
        p.extend_from_slice(&self.opts.c.to_bits().to_le_bytes());
        p.push(match self.opts.slack_mode {
            SlackMode::Paper => 0,
            SlackMode::Consistent => 1,
        });
        p.extend_from_slice(&(self.opts.lookahead as u64).to_le_bytes());
        p.extend_from_slice(&(self.opts.merge_iters as u64).to_le_bytes());
        p.extend_from_slice(&(self.merges as u64).to_le_bytes());
        match self.opts.hash {
            None => p.push(0),
            Some(h) => {
                p.push(1);
                p.extend_from_slice(&h.seed.to_le_bytes());
                p.extend_from_slice(&(h.dim as u64).to_le_bytes());
            }
        }
        p.extend_from_slice(&(self.seen as u64).to_le_bytes());
        p.extend_from_slice(&(self.dim as u64).to_le_bytes());
        match &self.ball {
            None => p.push(0),
            Some(b) => {
                p.push(1);
                p.extend_from_slice(&(b.m as u64).to_le_bytes());
                p.extend_from_slice(&b.r.to_bits().to_le_bytes());
                p.extend_from_slice(&b.xi2.to_bits().to_le_bytes());
                p.extend_from_slice(&b.sigma().to_bits().to_le_bytes());
                p.extend_from_slice(&b.wnorm2().to_bits().to_le_bytes());
                for &v in b.direction() {
                    p.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
        }
        let mut out = Vec::with_capacity(HEADER_LEN + p.len() + CHECKSUM_LEN);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&SKETCH_VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // flags
        out.extend_from_slice(&(p.len() as u64).to_le_bytes());
        let sum = fnv1a64(&p);
        out.extend_from_slice(&p);
        out.extend_from_slice(&sum.to_le_bytes());
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::SKETCH_ENCODES.inc();
            crate::obs::telemetry::SKETCH_BYTES.add(out.len() as u64);
        }
        out
    }

    /// Deserialize, validating magic, version, length and checksum.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < HEADER_LEN + CHECKSUM_LEN {
            return Err(Error::sketch(format!(
                "{} bytes is too short for a sketch header",
                bytes.len()
            )));
        }
        if &bytes[..4] != MAGIC {
            return Err(Error::sketch("bad magic (not a MEBS sketch)"));
        }
        let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
        if version == 0 || version > SKETCH_VERSION {
            return Err(Error::sketch(format!(
                "unsupported sketch version {version} (this build reads <= {SKETCH_VERSION})"
            )));
        }
        let payload_len =
            usize_of(u64::from_le_bytes(bytes[8..16].try_into().unwrap()), "payload length")?;
        let expect = HEADER_LEN + payload_len + CHECKSUM_LEN;
        if bytes.len() != expect {
            return Err(Error::sketch(format!(
                "length mismatch: header promises {expect} bytes, got {}",
                bytes.len()
            )));
        }
        let payload = &bytes[HEADER_LEN..HEADER_LEN + payload_len];
        let stored = u64::from_le_bytes(bytes[HEADER_LEN + payload_len..].try_into().unwrap());
        let actual = fnv1a64(payload);
        if stored != actual {
            return Err(Error::sketch(format!(
                "checksum mismatch (stored {stored:#018x}, computed {actual:#018x}) — \
                 corrupt or partially-written sketch"
            )));
        }

        let mut r = Reader::new(payload);
        let tag_len = usize_of(r.u32("tag length")? as u64, "tag length")?;
        let tag = std::str::from_utf8(r.take(tag_len, "tag")?)
            .map_err(|_| Error::sketch("tag is not valid UTF-8"))?
            .to_string();
        let c = r.f64("c")?;
        let slack_mode = match r.u8("slack_mode")? {
            0 => SlackMode::Paper,
            1 => SlackMode::Consistent,
            other => return Err(Error::sketch(format!("unknown slack mode byte {other}"))),
        };
        let lookahead = usize_of(r.u64("lookahead")?, "lookahead")?;
        let merge_iters = usize_of(r.u64("merge_iters")?, "merge_iters")?;
        // v3 provenance: merge count + feature-hash spec.
        let (merges, hash) = if version >= 3 {
            let merges = usize_of(r.u64("merges")?, "merges")?;
            let hash = match r.u8("has_hash")? {
                0 => None,
                1 => {
                    let seed = r.u64("hash_seed")?;
                    let dim = usize_of(r.u64("hash_dim")?, "hash_dim")?;
                    if dim == 0 {
                        return Err(Error::sketch("hash_dim must be >= 1"));
                    }
                    Some(HashSpec { dim, seed })
                }
                other => return Err(Error::sketch(format!("bad has_hash byte {other}"))),
            };
            (merges, hash)
        } else {
            (0, None)
        };
        let seen = usize_of(r.u64("seen")?, "seen")?;
        let dim = usize_of(r.u64("dim")?, "dim")?;
        let ball = match r.u8("has_ball")? {
            0 => None,
            1 => {
                let m = usize_of(r.u64("m")?, "m")?;
                let rad = r.f64("r")?;
                let xi2 = r.f64("xi2")?;
                // v2 carries the factored center; v1 stored dense w.
                let (sigma, wnorm2) = if version >= 2 {
                    (Some(r.f64("sigma")?), Some(r.f64("wnorm2")?))
                } else {
                    (None, None)
                };
                let wb = r.take(dim.checked_mul(4).ok_or_else(|| {
                    Error::sketch(format!("dim {dim} overflows the weight size"))
                })?, "weights")?;
                let w: Vec<f32> = wb
                    .chunks_exact(4)
                    .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                    .collect();
                Some(match (sigma, wnorm2) {
                    (Some(sigma), Some(wnorm2)) => {
                        BallState::from_scaled(w, sigma, wnorm2, rad, xi2, m)
                    }
                    _ => BallState::from_parts(w, rad, xi2, m),
                })
            }
            other => return Err(Error::sketch(format!("bad has_ball byte {other}"))),
        };
        if !r.done() {
            return Err(Error::sketch("trailing bytes after sketch payload"));
        }
        let opts = TrainOptions { c, slack_mode, lookahead, merge_iters, hash };
        Ok(MebSketch { dim, ball, seen, opts, tag, merges })
    }

    /// Write atomically: encode to `<path>.tmp`, then rename over `path`,
    /// so a crash mid-write never leaves a truncated sketch behind.
    pub fn write_to(&self, path: &Path) -> Result<()> {
        let t0 = std::time::Instant::now();
        let bytes = self.encode();
        let tmp = path.with_extension("meb.tmp");
        std::fs::write(&tmp, &bytes)?;
        std::fs::rename(&tmp, path)?;
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::SKETCH_WRITE_NS.add(t0.elapsed().as_nanos() as u64);
        }
        crate::obs_debug!(
            "sketch";
            bytes = bytes.len(),
            seen = self.seen,
            radius = self.radius();
            "wrote sketch to {}",
            path.display()
        );
        Ok(())
    }

    /// Read and decode a sketch file.
    pub fn read_from(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::sketch(format!("cannot read {}: {e}", path.display())))?;
        Self::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Example;
    use crate::prop::{check_default, gen};

    fn trained(n: usize, d: usize, seed: u64, opts: &TrainOptions) -> StreamSvm {
        let mut rng = crate::rng::Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, 0.5);
        let exs: Vec<Example> = xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
        StreamSvm::fit(exs.iter(), d, opts)
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        check_default("sketch-roundtrip", |rng, case| {
            let d = gen::dim(rng);
            let n = 1 + rng.below(120);
            let opts = TrainOptions::default()
                .with_c(0.25 + rng.uniform() * 8.0)
                .with_lookahead(1 + rng.below(20));
            let model = trained(n, d, 1000 + case as u64, &opts);
            let sk = MebSketch::from_model(&model, format!("case-{case}"));
            let back = MebSketch::decode(&sk.encode()).map_err(|e| e.to_string())?;
            if back != sk {
                return Err("decoded sketch differs".into());
            }
            let m2 = back.to_model();
            let (a, b) = (model.ball().unwrap(), m2.ball().unwrap());
            if a.direction() != b.direction()
                || a.sigma().to_bits() != b.sigma().to_bits()
                || a.wnorm2().to_bits() != b.wnorm2().to_bits()
                || a.r.to_bits() != b.r.to_bits()
                || a.xi2.to_bits() != b.xi2.to_bits()
                || a.m != b.m
                || m2.examples_seen() != model.examples_seen()
            {
                return Err("rebuilt model is not bit-identical".into());
            }
            Ok(())
        });
    }

    #[test]
    fn empty_model_roundtrips() {
        let model = StreamSvm::new(7, TrainOptions::default());
        let sk = MebSketch::from_model(&model, "empty");
        let back = MebSketch::decode(&sk.encode()).unwrap();
        assert_eq!(back, sk);
        assert!(back.ball.is_none());
        let m2 = back.to_model();
        assert_eq!(m2.dim(), 7);
        assert_eq!(m2.examples_seen(), 0);
    }

    #[test]
    fn corruption_detected() {
        let model = trained(60, 5, 9, &TrainOptions::default());
        let good = MebSketch::from_model(&model, "t").encode();

        // flip one payload byte → checksum error
        let mut bad = good.clone();
        let mid = HEADER_LEN + 10;
        bad[mid] ^= 0xFF;
        let e = MebSketch::decode(&bad).unwrap_err();
        assert!(e.to_string().contains("checksum"), "{e}");

        // truncate → length error
        let e = MebSketch::decode(&good[..good.len() - 3]).unwrap_err();
        assert!(e.to_string().contains("length"), "{e}");

        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(MebSketch::decode(&bad).unwrap_err().to_string().contains("magic"));

        // future version
        let mut bad = good.clone();
        bad[4] = 0xFF;
        bad[5] = 0xFF;
        assert!(MebSketch::decode(&bad).unwrap_err().to_string().contains("version"));

        // too short entirely
        assert!(MebSketch::decode(&good[..8]).is_err());
    }

    #[test]
    fn file_roundtrip_atomic() {
        let dir = std::env::temp_dir().join(format!("ssvm_sketch_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.meb");
        let model = trained(40, 3, 11, &TrainOptions::default().with_c(2.0));
        let sk = MebSketch::from_model(&model, "file");
        sk.write_to(&path).unwrap();
        // the temp file must be gone after the rename
        assert!(!path.with_extension("meb.tmp").exists());
        let back = MebSketch::read_from(&path).unwrap();
        assert_eq!(back, sk);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn decodes_version1_sketches() {
        // Hand-assemble a v1 payload (explicit dense w, no sigma/wnorm2)
        // and check it decodes to the equivalent factored state.
        let w = [1.5f32, -2.0, 0.5];
        let (rad, xi2, m, seen) = (3.25f64, 0.5f64, 4usize, 17usize);
        let opts = TrainOptions::default().with_c(2.0);
        let mut p: Vec<u8> = Vec::new();
        p.extend_from_slice(&(2u32).to_le_bytes()); // tag len
        p.extend_from_slice(b"v1");
        p.extend_from_slice(&opts.c.to_bits().to_le_bytes());
        p.push(1); // Consistent
        p.extend_from_slice(&(opts.lookahead as u64).to_le_bytes());
        p.extend_from_slice(&(opts.merge_iters as u64).to_le_bytes());
        p.extend_from_slice(&(seen as u64).to_le_bytes());
        p.extend_from_slice(&(w.len() as u64).to_le_bytes());
        p.push(1); // has_ball
        p.extend_from_slice(&(m as u64).to_le_bytes());
        p.extend_from_slice(&rad.to_bits().to_le_bytes());
        p.extend_from_slice(&xi2.to_bits().to_le_bytes());
        for &v in &w {
            p.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u16.to_le_bytes()); // version 1
        bytes.extend_from_slice(&0u16.to_le_bytes());
        bytes.extend_from_slice(&(p.len() as u64).to_le_bytes());
        let sum = fnv1a64(&p);
        bytes.extend_from_slice(&p);
        bytes.extend_from_slice(&sum.to_le_bytes());

        let sk = MebSketch::decode(&bytes).unwrap();
        assert_eq!(sk.tag, "v1");
        assert_eq!(sk.dim, 3);
        assert_eq!(sk.seen, seen);
        let b = sk.ball.as_ref().unwrap();
        assert_eq!(b.weights(), w.to_vec());
        assert_eq!(b.sigma(), 1.0);
        assert_eq!(b.r, rad);
        assert_eq!(b.xi2, xi2);
        assert_eq!(b.m, m);
        // and re-encoding writes the current (v2) format
        let back = MebSketch::decode(&sk.encode()).unwrap();
        assert_eq!(back, sk);
    }

    #[test]
    fn merges_and_hash_provenance_roundtrip() {
        let model = trained(80, 6, 13, &TrainOptions::default().with_lookahead(4));
        let mut sk = MebSketch::from_model(&model, "prov").with_merges(7);
        sk.opts.hash = Some(HashSpec { dim: 4096, seed: 0xDEAD_BEEF });
        let back = MebSketch::decode(&sk.encode()).unwrap();
        assert_eq!(back, sk);
        assert_eq!(back.merges, 7);
        assert_eq!(back.opts.hash, Some(HashSpec { dim: 4096, seed: 0xDEAD_BEEF }));
        // no-hash sketches roundtrip too
        let sk2 = MebSketch::from_model(&model, "prov2").with_merges(3);
        let back2 = MebSketch::decode(&sk2.encode()).unwrap();
        assert_eq!(back2.merges, 3);
        assert_eq!(back2.opts.hash, None);
    }

    #[test]
    fn decodes_version2_sketches() {
        // Hand-assemble a v2 payload (factored center, no merges/hash
        // fields) and check it decodes with merges = 0 and no hash spec.
        let v = [1.5f32, -2.0];
        let (sigma, wnorm2) = (0.5f64, 1.5625f64);
        let (rad, xi2, m, seen) = (2.0f64, 0.25f64, 3usize, 9usize);
        let opts = TrainOptions::default();
        let mut p: Vec<u8> = Vec::new();
        p.extend_from_slice(&(2u32).to_le_bytes());
        p.extend_from_slice(b"v2");
        p.extend_from_slice(&opts.c.to_bits().to_le_bytes());
        p.push(1); // Consistent
        p.extend_from_slice(&(opts.lookahead as u64).to_le_bytes());
        p.extend_from_slice(&(opts.merge_iters as u64).to_le_bytes());
        p.extend_from_slice(&(seen as u64).to_le_bytes());
        p.extend_from_slice(&(v.len() as u64).to_le_bytes());
        p.push(1); // has_ball
        p.extend_from_slice(&(m as u64).to_le_bytes());
        p.extend_from_slice(&rad.to_bits().to_le_bytes());
        p.extend_from_slice(&xi2.to_bits().to_le_bytes());
        p.extend_from_slice(&sigma.to_bits().to_le_bytes());
        p.extend_from_slice(&wnorm2.to_bits().to_le_bytes());
        for &x in &v {
            p.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&2u16.to_le_bytes()); // version 2
        bytes.extend_from_slice(&0u16.to_le_bytes());
        bytes.extend_from_slice(&(p.len() as u64).to_le_bytes());
        let sum = fnv1a64(&p);
        bytes.extend_from_slice(&p);
        bytes.extend_from_slice(&sum.to_le_bytes());

        let sk = MebSketch::decode(&bytes).unwrap();
        assert_eq!(sk.tag, "v2");
        assert_eq!(sk.merges, 0);
        assert_eq!(sk.opts.hash, None);
        let b = sk.ball.as_ref().unwrap();
        assert_eq!(b.sigma(), sigma);
        assert_eq!(b.direction(), &v);
        assert_eq!(b.wnorm2(), wnorm2);
    }

    #[test]
    fn compatibility_fingerprint() {
        let a = MebSketch::new(4, None, 0, TrainOptions::default(), "a");
        let b = MebSketch::new(4, None, 0, TrainOptions::default().with_lookahead(10), "b");
        assert!(a.compatible(&b), "lookahead must not affect compatibility");
        let c = MebSketch::new(4, None, 0, TrainOptions::default().with_c(2.0), "c");
        assert!(!a.compatible(&c));
        let d = MebSketch::new(5, None, 0, TrainOptions::default(), "d");
        assert!(!a.compatible(&d));
        let e = MebSketch::new(
            4,
            None,
            0,
            TrainOptions::default().with_slack_mode(SlackMode::Paper),
            "e",
        );
        assert!(!a.compatible(&e));
        // mismatched hash spaces are incompatible (dim, seed, presence)
        let h = |dim, seed| {
            MebSketch::new(
                4,
                None,
                0,
                TrainOptions::default().with_hash(Some(HashSpec { dim, seed })),
                "h",
            )
        };
        assert!(!a.compatible(&h(4, 1)), "hashed vs unhashed must differ");
        assert!(!h(4, 1).compatible(&h(4, 2)), "seeds must match");
        assert!(h(4, 1).compatible(&h(4, 1)));
        // merge count is provenance, not compatibility
        assert!(a.compatible(&MebSketch::new(4, None, 0, TrainOptions::default(), "m").with_merges(9)));
    }
}
