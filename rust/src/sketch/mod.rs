//! Mergeable MEB sketches: durable, composable model state.
//!
//! The paper's central object — the ball `(w, R, ξ², M)` — is tiny, and
//! this module exploits the production consequence of that: the whole
//! learner state *serializes* (a few hundred bytes) and *merges* (the
//! closed-form two-ball MEB composes). Three pieces:
//!
//! * [`codec`] — [`MebSketch`](codec::MebSketch): a versioned,
//!   checksummed binary encoding of ball + stream provenance (examples
//!   seen, training-option fingerprint, dataset tag), with bit-exact
//!   round-tripping through bytes and files.
//! * [`merge`] — order-robust merge-and-reduce: N shard sketches fold
//!   through a balanced binary tree of exact two-ball merges into one
//!   model whose ball encloses every streamed point of every shard. The
//!   sharded coordinator trains through this tree.
//! * [`checkpoint`] — periodic snapshot + *exact* resume: interrupt a
//!   one-pass run at example `k`, resume from the sketch, and the final
//!   weights are bit-identical to an uninterrupted run (the update is
//!   deterministic and the sketch is lossless).
//!
//! This is the substrate for every distributed-scale roadmap item:
//! durable deployable model files (`streamsvm snapshot` / `resume` /
//! `merge`), crash-safe long streams (pipeline checkpoint intervals),
//! and shard-then-merge training (`coordinator::sharded`).

pub mod checkpoint;
pub mod codec;
pub mod merge;

pub use checkpoint::{resume_fit, resume_model, save_model, CheckpointConfig, Checkpointer};
pub use codec::{MebSketch, SKETCH_VERSION};
pub use merge::{merge_ball_tree, merge_sketches, merge_tree_with};
