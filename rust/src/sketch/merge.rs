//! Merge-and-reduce composition of MEB sketches.
//!
//! N shard balls fold into one enclosing ball through a *balanced binary
//! tree* of closed-form two-ball MEB merges (the exact geometry of
//! [`crate::svm::multiball::merge_two`]). Compared with the left-to-right
//! fold the sharded coordinator used before, the tree
//!
//! * is order-robust: every leaf sits at depth ⌈log₂ N⌉, so no shard's
//!   slack compounds through N−1 sequential merges, and permuting the
//!   shards perturbs the result only within the pairing tolerance;
//! * is the composition rule of merge-and-reduce coreset schemes
//!   (Tukan et al., "On Coresets for Support Vector Machines"), which is
//!   what makes sketches the right currency for distributed training:
//!   merging is associative *enough* — every merge output encloses both
//!   inputs, so the root encloses every streamed point of every shard.
//!
//! Slack masses of distinct shards live on disjoint stream indices, so
//! the two-ball distance `t² = ||w₁−w₂||² + ξ₁² + ξ₂²` is exact at every
//! tree level (the merged ξ² bookkeeping keeps the invariant inductively;
//! see the lifted-space property test below).

use crate::error::{Error, Result};
use crate::sketch::codec::MebSketch;
use crate::svm::ball::BallState;
use crate::svm::multiball::merge_two;

/// Fold `items` with `f` along a balanced binary tree: pair adjacent
/// items level by level until one remains. `None` on empty input.
///
/// Generic so tests can thread auxiliary state (e.g. lifted-space
/// centers) through the exact same tree structure.
pub fn merge_tree_with<T>(mut items: Vec<T>, mut f: impl FnMut(&T, &T) -> T) -> Option<T> {
    if items.is_empty() {
        return None;
    }
    while items.len() > 1 {
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(f(&a, &b)),
                None => next.push(a), // odd item promotes unchanged
            }
        }
        items = next;
    }
    items.pop()
}

/// Balanced merge-and-reduce of shard balls into one enclosing ball.
pub fn merge_ball_tree(balls: Vec<BallState>) -> Option<BallState> {
    merge_tree_with(balls, merge_two)
}

/// Merge N sketches into one.
///
/// Validates that every sketch was taken from the *same variant*
/// (folding, say, an ellipsoid summary into a multiball summary would
/// silently discard what makes each variant itself — an operator error,
/// rejected as [`Error::Config`] before any geometry is touched), then
/// pairwise compatibility (same dimension and `(C, slack_mode)`
/// geometry — see [`MebSketch::compatible`]); empty sketches act as
/// merge identities. `seen` counts add; the merged tag records the
/// lineage. The aggregate is the merge of the inputs' *summary balls*,
/// so it is always a ball-variant sketch; a non-linear kernelized
/// sketch has no summary ball and cannot participate.
pub fn merge_sketches(sketches: &[MebSketch]) -> Result<MebSketch> {
    let first = sketches
        .first()
        .ok_or_else(|| Error::sketch("cannot merge zero sketches"))?;
    for (i, s) in sketches.iter().enumerate().skip(1) {
        if s.variant != first.variant {
            // Like the hash-space gate below this is an operator
            // configuration error (mixed --variant runs), not a corrupt
            // sketch — and it must fire before any ball is folded.
            return Err(Error::config(format!(
                "sketch {i} (tag={}) is a {} sketch but sketch 0 (tag={}) is {}; \
                 models of different variants cannot be merged",
                s.tag, s.variant, first.tag, first.variant,
            )));
        }
        if s.opts.hash != first.opts.hash {
            // A hash-space mismatch is an operator configuration error
            // (wrong --hash-dim/--hash-seed), not a corrupt sketch:
            // buckets from different (seed, D) pairs are unrelated
            // coordinates and must never be folded together.
            let fmt = |h: Option<crate::svm::HashSpec>| match h {
                Some(h) => format!("D{}@{:#x}", h.dim, h.seed),
                None => "unhashed".into(),
            };
            return Err(Error::config(format!(
                "sketch {i} (tag={}) lives in hash space {} but sketch 0 (tag={}) in {}; \
                 models from different hash spaces cannot be merged",
                s.tag,
                fmt(s.opts.hash),
                first.tag,
                fmt(first.opts.hash),
            )));
        }
        if !first.compatible(s) {
            return Err(Error::sketch(format!(
                "sketch {i} (tag={}, dim={}, C={}, slack={:?}) is incompatible with \
                 sketch 0 (tag={}, dim={}, C={}, slack={:?})",
                s.tag, s.dim, s.opts.c, s.opts.slack_mode,
                first.tag, first.dim, first.opts.c, first.opts.slack_mode,
            )));
        }
    }
    if let Some((i, s)) =
        sketches.iter().enumerate().find(|(_, s)| s.ball.is_none() && s.seen > 0)
    {
        // Only a non-linear kernelized learner trains without a primal
        // summary ball; its core set lives in feature space and has no
        // closed-form two-ball merge.
        return Err(Error::sketch(format!(
            "sketch {i} (tag={}, variant={}) has no summary ball to merge \
             (non-linear kernels cannot be aggregated in primal space)",
            s.tag, s.variant,
        )));
    }
    let seen: usize = sketches.iter().map(|s| s.seen).sum();
    let balls: Vec<BallState> = sketches.iter().filter_map(|s| s.ball.clone()).collect();
    let ball = merge_ball_tree(balls);
    let tag = match sketches.len() {
        1 => first.tag.clone(),
        n => format!("merge({n}:{})", first.tag),
    };
    Ok(MebSketch::new(first.dim, ball, seen, first.opts, tag))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check_default, gen};
    use crate::rng::Pcg32;
    use crate::svm::multiball::merge_two_lambda;
    use crate::svm::TrainOptions;

    fn random_ball(d: usize, rng: &mut Pcg32) -> BallState {
        BallState::from_parts(
            (0..d).map(|_| (rng.normal() * 2.0) as f32).collect(),
            rng.uniform() * 3.0,
            rng.uniform(),
            1 + rng.below(10),
        )
    }

    /// A ball paired with its center materialized in the lifted space
    /// `R^(d+n)` where shard `i`'s slack mass sits alone on axis `d+i`.
    #[derive(Clone)]
    struct Lifted {
        ball: BallState,
        center: Vec<f64>,
    }

    fn lift(balls: &[BallState], d: usize) -> Vec<Lifted> {
        let n = balls.len();
        balls
            .iter()
            .enumerate()
            .map(|(i, b)| {
                let bw = b.weights();
                let mut c = vec![0.0f64; d + n];
                for j in 0..d {
                    c[j] = bw[j] as f64;
                }
                c[d + i] = b.xi2.sqrt();
                Lifted { ball: b.clone(), center: c }
            })
            .collect()
    }

    fn dist(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
    }

    #[test]
    fn tree_root_encloses_every_input_ball() {
        // Run the tree twice in lockstep: once on the BallState geometry,
        // once on explicit lifted-space centers blended with the same λ.
        // The root must contain every leaf: ||c_root − c_i|| + r_i ≤ R.
        check_default("merge-tree-enclosure", |rng, _| {
            let d = gen::dim(rng);
            let n = 2 + rng.below(15);
            let balls: Vec<BallState> = (0..n).map(|_| random_ball(d, rng)).collect();
            let leaves = lift(&balls, d);
            let root = merge_tree_with(leaves.clone(), |a, b| {
                let (m, lam) = merge_two_lambda(&a.ball, &b.ball);
                let center: Vec<f64> = a
                    .center
                    .iter()
                    .zip(&b.center)
                    .map(|(x, y)| (1.0 - lam) * x + lam * y)
                    .collect();
                Lifted { ball: m, center }
            })
            .unwrap();
            // ξ² bookkeeping matches the explicit lift
            let slack2: f64 = root.center[d..].iter().map(|v| v * v).sum();
            if (slack2 - root.ball.xi2).abs() > 1e-6 * slack2.max(1.0) {
                return Err(format!("xi2 {} vs lifted {slack2}", root.ball.xi2));
            }
            // explicit part matches w
            let rw = root.ball.weights();
            for j in 0..d {
                if (root.center[j] - rw[j] as f64).abs() > 1e-3 {
                    return Err(format!("w[{j}] diverged from lifted center"));
                }
            }
            for (i, leaf) in leaves.iter().enumerate() {
                let gap = dist(&root.center, &leaf.center) + leaf.ball.r - root.ball.r;
                if gap > 1e-6 * root.ball.r.max(1.0) {
                    return Err(format!(
                        "ball {i} sticks out of the root by {gap} (R={})",
                        root.ball.r
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tree_permutation_invariant_within_tolerance() {
        // Pairings differ between shard orders, so roots differ — but
        // every root encloses all inputs (checked above), so radii stay
        // within the streaming-MEB style constant band of each other.
        check_default("merge-tree-permutation", |rng, _| {
            let d = gen::dim(rng);
            let n = 3 + rng.below(13);
            let balls: Vec<BallState> = (0..n).map(|_| random_ball(d, rng)).collect();
            let base = merge_ball_tree(balls.clone()).unwrap();
            for _ in 0..4 {
                let mut shuffled = balls.clone();
                rng.shuffle(&mut shuffled);
                let alt = merge_ball_tree(shuffled).unwrap();
                let ratio = alt.r.max(base.r) / alt.r.min(base.r).max(1e-12);
                if ratio > 1.5 + 1e-9 {
                    return Err(format!(
                        "permutation changed radius beyond tolerance: {} vs {}",
                        base.r, alt.r
                    ));
                }
                if alt.m != base.m {
                    return Err("core-set count is permutation-dependent".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tree_radius_dominates_inputs_and_single_input_is_identity() {
        let mut rng = Pcg32::seeded(77);
        let balls: Vec<BallState> = (0..9).map(|_| random_ball(6, &mut rng)).collect();
        let root = merge_ball_tree(balls.clone()).unwrap();
        let max_r = balls.iter().map(|b| b.r).fold(0.0f64, f64::max);
        assert!(root.r + 1e-9 >= max_r);
        assert_eq!(root.m, balls.iter().map(|b| b.m).sum::<usize>());

        let one = merge_ball_tree(vec![balls[0].clone()]).unwrap();
        assert_eq!(one, balls[0]);
        assert!(merge_ball_tree(Vec::new()).is_none());
    }

    #[test]
    fn sketch_merge_validates_and_sums_provenance() {
        let mut rng = Pcg32::seeded(5);
        let opts = TrainOptions::default().with_c(2.0);
        let sk = |seen: usize, rng: &mut Pcg32| {
            MebSketch::new(4, Some(random_ball(4, rng)), seen, opts, format!("shard{seen}"))
        };
        let parts = [sk(10, &mut rng), sk(20, &mut rng), sk(30, &mut rng)];
        let merged = merge_sketches(&parts).unwrap();
        assert_eq!(merged.seen, 60);
        assert_eq!(merged.dim, 4);
        assert!(merged.tag.starts_with("merge(3:"));
        assert!(merged.radius() >= parts.iter().map(|s| s.radius()).fold(0.0, f64::max));

        // empty sketches are identities
        let with_empty =
            [parts[0].clone(), MebSketch::new(4, None, 0, opts, "idle"), parts[1].clone()];
        let m2 = merge_sketches(&with_empty).unwrap();
        assert_eq!(m2.seen, 30);
        assert!(m2.ball.is_some());

        // incompatible C rejected
        let odd = MebSketch::new(4, None, 0, TrainOptions::default().with_c(9.0), "odd");
        let err = merge_sketches(&[parts[0].clone(), odd]).unwrap_err();
        assert!(err.to_string().contains("incompatible"), "{err}");

        // dimension mismatch rejected
        let wrong_dim = MebSketch::new(5, None, 0, opts, "d5");
        assert!(merge_sketches(&[parts[0].clone(), wrong_dim]).is_err());

        // zero sketches rejected
        assert!(merge_sketches(&[]).is_err());

        // mismatched hash spaces rejected with Error::Config
        use crate::svm::HashSpec;
        let hashed = |seed| {
            MebSketch::new(
                4,
                None,
                0,
                opts.with_hash(Some(HashSpec { dim: 4, seed })),
                "hashed",
            )
        };
        let err = merge_sketches(&[parts[0].clone(), hashed(1)]).unwrap_err();
        assert!(matches!(err, crate::error::Error::Config(_)), "{err}");
        assert!(err.to_string().contains("hash space"), "{err}");
        let err = merge_sketches(&[hashed(1), hashed(2)]).unwrap_err();
        assert!(matches!(err, crate::error::Error::Config(_)), "{err}");
        // same hash space merges fine
        assert!(merge_sketches(&[hashed(1), hashed(1)]).is_ok());
    }

    #[test]
    fn cross_variant_merges_rejected_pairwise() {
        // Satellite of the StreamLearner refactor: folding sketches of
        // different variants must fail loudly as a config error (like
        // the hash-space gate), never emit a garbled model.
        use crate::data::Example;
        use crate::svm::learner::{AnyLearner, Variant};
        let mut rng = Pcg32::seeded(9);
        let (xs, ys) = gen::labeled_points(&mut rng, 40, 4, 1.0, 0.5);
        let exs: Vec<Example> =
            xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
        let opts = TrainOptions::default();
        let sketches: Vec<MebSketch> = Variant::ALL
            .into_iter()
            .map(|v| {
                let m = AnyLearner::fit(exs.iter(), v, 4, opts);
                MebSketch::from_learner(&m, v.name())
            })
            .collect();
        for a in &sketches {
            for b in &sketches {
                let out = merge_sketches(&[a.clone(), b.clone()]);
                if a.variant == b.variant {
                    let merged = out.unwrap();
                    assert_eq!(merged.seen, 80);
                    assert_eq!(
                        merged.variant,
                        Variant::Ball,
                        "summary-ball aggregates are ball sketches"
                    );
                } else {
                    let err = out.unwrap_err();
                    assert!(
                        matches!(err, Error::Config(_)),
                        "{} + {}: expected Config, got {err}",
                        a.variant,
                        b.variant
                    );
                    assert!(err.to_string().contains("variant"), "{err}");
                }
            }
        }
        // a non-linear kernelized sketch has no summary ball: even a
        // same-variant merge refuses rather than emit a hollow model
        use crate::svm::kernelfn::Kernel;
        let mut rbf =
            AnyLearner::with_kernel(Variant::Kernelized, 4, opts, Kernel::Rbf { gamma: 0.5 });
        for e in &exs {
            rbf.observe_view(e.x.view(), e.y);
        }
        let rsk = MebSketch::from_learner(&rbf, "rbf");
        let err = merge_sketches(&[rsk.clone(), rsk]).unwrap_err();
        assert!(matches!(err, Error::Sketch(_)), "{err}");
        assert!(err.to_string().contains("summary ball"), "{err}");
    }

    #[test]
    fn merged_model_classifies_like_its_shards() {
        // End-to-end: train three shards on slices of one stream, merge
        // the sketches, and require the merged model to stay within the
        // sharded-training tolerance of the single-pass model.
        use crate::data::Example;
        use crate::eval::accuracy;
        use crate::svm::streamsvm::StreamSvm;
        let mut rng = Pcg32::seeded(42);
        let (xs, ys) = gen::labeled_points(&mut rng, 1800, 6, 1.0, 1.0);
        let exs: Vec<Example> =
            xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
        let opts = TrainOptions::default();
        let single = StreamSvm::fit(exs.iter(), 6, &opts);

        let sketches: Vec<MebSketch> = exs
            .chunks(600)
            .enumerate()
            .map(|(i, chunk)| {
                let m = StreamSvm::fit(chunk.iter(), 6, &opts);
                MebSketch::from_model(&m, format!("shard{i}"))
            })
            .collect();
        let merged = merge_sketches(&sketches).unwrap().to_model();
        let (a1, am) = (accuracy(&single, &exs), accuracy(&merged, &exs));
        assert!(am > a1 - 0.08, "merged {am:.3} vs single {a1:.3}");
        assert_eq!(merged.examples_seen(), 1800);
    }
}
