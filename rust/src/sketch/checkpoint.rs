//! Periodic snapshot + exact resume for one-pass training.
//!
//! Because the learner state is a tiny closed-form ball and the update
//! is deterministic, checkpointing is *exact*: resume from the sketch
//! taken at example `k`, replay examples `k+1..n`, and the final weights
//! are bit-identical to an uninterrupted run. The [`Checkpointer`]
//! provides interval-based snapshots for the streaming pipeline (it
//! writes atomically via [`MebSketch::write_to`], so a crash mid-write
//! leaves the previous checkpoint intact); [`resume_fit`] is the other
//! half — skip what the sketch already consumed and continue.
//!
//! Against *torn* checkpoints — a live file truncated or corrupted
//! outside the atomic-rename window (full disk, external copy, crash
//! inside a non-atomic filesystem) — each snapshot first rotates the
//! previous good file to `<path>.prev`, and
//! [`read_sketch_with_fallback`] resumes from it with a surfaced
//! warning when the primary no longer decodes.
//!
//! With lookahead (Algorithm 2) the buffered-but-unmerged points are not
//! part of the ball, so the pipeline only snapshots at buffer-empty
//! boundaries — the sketch's `seen` is always a stream position whose
//! prefix is fully absorbed.

use std::path::{Path, PathBuf};

use crate::data::Example;
use crate::error::Result;
use crate::sketch::codec::MebSketch;
use crate::svm::ball::BallState;
use crate::svm::learner::{AnyLearner, Variant};
use crate::svm::streamsvm::StreamSvm;
use crate::svm::TrainOptions;

/// Checkpoint policy for a training run.
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Snapshot whenever at least this many new examples were absorbed
    /// since the last snapshot (checked at block boundaries).
    pub every: usize,
    /// Destination file, overwritten atomically on each snapshot.
    pub path: PathBuf,
    /// Provenance tag stored in every sketch (dataset name, run id...).
    pub tag: String,
}

/// Interval-based snapshot writer driven by the training loop.
#[derive(Debug)]
pub struct Checkpointer {
    cfg: CheckpointConfig,
    last_saved: usize,
    saves: usize,
}

/// Where a checkpoint's previous good snapshot rotates to
/// (`run.meb` → `run.meb.prev`).
pub fn prev_snapshot_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".prev");
    PathBuf::from(os)
}

/// Rotate the current on-disk snapshot (if any) to its `.prev` twin.
/// Best-effort: rotation failing must not block the new snapshot.
fn rotate_prev(path: &Path) {
    if path.exists() && std::fs::rename(path, prev_snapshot_path(path)).is_err() {
        crate::obs_warn!("checkpoint", "could not rotate {} to .prev", path.display());
    }
}

/// Read the sketch at `path`, falling back to the rotated `.prev`
/// snapshot when the primary is torn or corrupt (truncated mid-write by
/// a crash, a full disk, an external copy...). The fallback is
/// surfaced as a warning, never silent; with no readable `.prev` the
/// primary's error propagates.
pub fn read_sketch_with_fallback(path: &Path) -> Result<MebSketch> {
    let primary_err = match MebSketch::read_from(path) {
        Ok(sk) => return Ok(sk),
        Err(e) => e,
    };
    let prev = prev_snapshot_path(path);
    match MebSketch::read_from(&prev) {
        Ok(sk) => {
            crate::obs_warn!(
                "checkpoint";
                seen = sk.seen,
                prev = prev.display().to_string();
                "checkpoint {} is unreadable ({primary_err}); resuming from previous snapshot",
                path.display()
            );
            Ok(sk)
        }
        Err(_) => Err(primary_err),
    }
}

impl Checkpointer {
    pub fn new(cfg: CheckpointConfig) -> Self {
        assert!(cfg.every >= 1, "checkpoint interval must be >= 1");
        Checkpointer { cfg, last_saved: 0, saves: 0 }
    }

    /// Observe the training position; snapshot if the interval elapsed.
    /// Returns whether a snapshot was written. `dim` is the stream's
    /// feature dimension (recorded even when no ball exists yet, so an
    /// empty sketch still resumes at the right dimension); `merges` is
    /// the Algorithm-2 merge count at this position (0 for Algorithm 1),
    /// recorded so a resumed run keeps reporting the paper's O(N/L)
    /// bound correctly.
    pub fn maybe_save(
        &mut self,
        ball: Option<&BallState>,
        dim: usize,
        seen: usize,
        merges: usize,
        opts: &TrainOptions,
    ) -> Result<bool> {
        if seen < self.last_saved + self.cfg.every {
            return Ok(false);
        }
        self.save(ball, dim, seen, merges, opts)?;
        Ok(true)
    }

    /// Unconditional snapshot at the current position.
    pub fn save(
        &mut self,
        ball: Option<&BallState>,
        dim: usize,
        seen: usize,
        merges: usize,
        opts: &TrainOptions,
    ) -> Result<()> {
        debug_assert!(ball.map(|b| b.dim() == dim).unwrap_or(true), "ball/stream dim mismatch");
        let sk = MebSketch::new(dim, ball.cloned(), seen, *opts, self.cfg.tag.clone())
            .with_merges(merges);
        rotate_prev(&self.cfg.path);
        sk.write_to(&self.cfg.path)?;
        self.last_saved = seen;
        self.saves += 1;
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::CHECKPOINT_SAVES.inc();
        }
        crate::obs_info!(
            "checkpoint";
            seen = seen,
            merges = merges,
            saves = self.saves;
            "checkpoint saved to {}",
            self.cfg.path.display()
        );
        Ok(())
    }

    /// [`Self::maybe_save`] for any learner: snapshot the variant's
    /// exact state (via [`MebSketch::from_learner`]) if the interval
    /// elapsed. Lookahead callers must only invoke this at buffer-empty
    /// positions — the sketch excludes buffered survivors.
    pub fn maybe_save_learner(&mut self, model: &AnyLearner) -> Result<bool> {
        if model.examples_seen() < self.last_saved + self.cfg.every {
            return Ok(false);
        }
        self.save_learner(model)?;
        Ok(true)
    }

    /// Unconditional exact-state snapshot of any learner.
    pub fn save_learner(&mut self, model: &AnyLearner) -> Result<()> {
        let seen = model.examples_seen();
        let sk = MebSketch::from_learner(model, self.cfg.tag.clone());
        rotate_prev(&self.cfg.path);
        sk.write_to(&self.cfg.path)?;
        self.last_saved = seen;
        self.saves += 1;
        if crate::obs::telemetry_on() {
            crate::obs::telemetry::CHECKPOINT_SAVES.inc();
        }
        crate::obs_info!(
            "checkpoint";
            seen = seen,
            variant = model.variant().name(),
            saves = self.saves;
            "checkpoint saved to {}",
            self.cfg.path.display()
        );
        Ok(())
    }

    /// Number of snapshots written so far.
    pub fn saves(&self) -> usize {
        self.saves
    }

    /// Stream position of the last snapshot (0 if none yet).
    pub fn last_saved(&self) -> usize {
        self.last_saved
    }

    pub fn path(&self) -> &Path {
        &self.cfg.path
    }
}

/// Snapshot a model to `path` (one-shot convenience over the interval
/// machinery; used by the CLI `snapshot` subcommand).
pub fn save_model(model: &StreamSvm, tag: &str, path: &Path) -> Result<()> {
    MebSketch::from_model(model, tag).write_to(path)
}

/// Load the model a sketch file describes, tolerating a torn primary
/// snapshot via [`read_sketch_with_fallback`].
pub fn resume_model(path: &Path) -> Result<StreamSvm> {
    Ok(read_sketch_with_fallback(path)?.to_model())
}

/// Snapshot any learner to `path` (the variant-generic twin of
/// [`save_model`]; used by the CLI `snapshot` subcommand and the
/// server's serving-snapshot writer). Lookahead learners must be
/// finished (or at a buffer-empty position) first.
pub fn save_learner(model: &AnyLearner, tag: &str, path: &Path) -> Result<()> {
    MebSketch::from_learner(model, tag).write_to(path)
}

/// Exact variant-generic resume: rebuild the learner the sketch's
/// variant tag names, skip the `sketch.seen` stream prefix it already
/// absorbed, consume the rest one-pass, and finish. Pre-v4 sketches are
/// always tagged `ball`, so their options still select the algorithm —
/// an Algorithm-2 run resumes through the lookahead path exactly as
/// [`resume_fit`] always has.
pub fn resume_learner<I: IntoIterator<Item = Example>>(
    sketch: &MebSketch,
    stream: I,
) -> Result<AnyLearner> {
    if sketch.variant == Variant::Ball && sketch.opts.lookahead > 1 {
        return Ok(AnyLearner::Lookahead(resume_lookahead(sketch, stream)));
    }
    let mut m = sketch.to_learner()?;
    for e in stream.into_iter().skip(sketch.seen) {
        m.observe_view(e.x.view(), e.y);
    }
    m.finish();
    Ok(m)
}

/// Exact resume: rebuild the learner from `sketch`, skip the
/// `sketch.seen` stream prefix it already absorbed, and consume the
/// rest one-pass with the algorithm the sketch's options select —
/// Algorithm 1 for `lookahead == 1`, Algorithm 2 otherwise (sketches
/// are only ever taken at buffer-empty positions, so the replayed merge
/// cadence matches the uninterrupted run).
///
/// Feeding the same stream that produced the sketch yields weights
/// bit-identical to an uninterrupted pure-Rust run. A run whose
/// lookahead merges executed on-device (PJRT) resumes within float
/// tolerance instead — the replay uses the Rust reference solver.
pub fn resume_fit<I: IntoIterator<Item = Example>>(sketch: &MebSketch, stream: I) -> StreamSvm {
    if sketch.opts.lookahead > 1 {
        return resume_lookahead(sketch, stream).to_stream_svm();
    }
    let rest = stream.into_iter().skip(sketch.seen);
    let mut model = sketch.to_model();
    for e in rest {
        model.observe_view(e.x.view(), e.y);
    }
    model
}

/// [`resume_fit`] for Algorithm 2, returning the live lookahead learner
/// so callers can inspect merge counts and buffer state. The sketch's
/// stored merge count seeds the resumed counter, so `num_merges()` after
/// the replay equals an uninterrupted run's.
pub fn resume_lookahead<I: IntoIterator<Item = Example>>(
    sketch: &MebSketch,
    stream: I,
) -> crate::svm::lookahead::LookaheadSvm {
    let rest = stream.into_iter().skip(sketch.seen);
    let mut m = match &sketch.ball {
        Some(b) => crate::svm::lookahead::LookaheadSvm::from_ball(
            sketch.dim,
            sketch.opts,
            b.clone(),
            sketch.seen,
            sketch.merges,
        ),
        None => crate::svm::lookahead::LookaheadSvm::new(sketch.dim, sketch.opts),
    };
    for e in rest {
        m.observe_view(e.x.view(), e.y);
    }
    m.finish();
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check_default, gen};

    fn toy(n: usize, d: usize, seed: u64) -> Vec<Example> {
        let mut rng = crate::rng::Pcg32::seeded(seed);
        let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, 0.5);
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
    }

    fn bit_equal(a: &StreamSvm, b: &StreamSvm) -> bool {
        a.weights() == b.weights()
            && a.radius().to_bits() == b.radius().to_bits()
            && a.num_support() == b.num_support()
            && a.examples_seen() == b.examples_seen()
    }

    #[test]
    fn interrupt_anywhere_resume_is_bit_identical() {
        check_default("checkpoint-exact-resume", |rng, case| {
            let d = gen::dim(rng);
            let n = 2 + rng.below(200);
            let k = rng.below(n + 1); // interrupt point, 0..=n
            let opts = TrainOptions::default().with_c(0.5 + rng.uniform() * 4.0);
            let exs = toy(n, d, 7000 + case as u64);

            let full = StreamSvm::fit(exs.iter(), d, &opts);

            let mut partial = StreamSvm::new(d, opts);
            for e in exs.iter().take(k) {
                partial.observe_view(e.x.view(), e.y);
            }
            let sk = MebSketch::from_model(&partial, "resume-test");
            // round-trip through bytes, as a real interruption would
            let sk = MebSketch::decode(&sk.encode()).map_err(|e| e.to_string())?;
            let resumed = resume_fit(&sk, exs.clone());

            if !bit_equal(&full, &resumed) {
                return Err(format!(
                    "resume at k={k}/{n} diverged: R {} vs {}",
                    full.radius(),
                    resumed.radius()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn checkpointer_interval_and_overwrite() {
        let dir = std::env::temp_dir().join(format!("ssvm_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.meb");
        let opts = TrainOptions::default();
        let mut ck = Checkpointer::new(CheckpointConfig {
            every: 32,
            path: path.clone(),
            tag: "interval".into(),
        });
        let exs = toy(100, 4, 3);
        let mut model = StreamSvm::new(4, opts);
        let mut saves = 0usize;
        for (i, e) in exs.iter().enumerate() {
            model.observe_view(e.x.view(), e.y);
            // simulate block boundaries of 10 examples
            if (i + 1) % 10 == 0
                && ck.maybe_save(model.ball(), 4, model.examples_seen(), 0, &opts).unwrap()
            {
                saves += 1;
            }
        }
        // intervals elapse at 40, 80 (block-boundary multiples of 10
        // crossing 32-example gaps): 40, 80 → at least 2 saves
        assert!(saves >= 2, "saves = {saves}");
        assert_eq!(ck.saves(), saves);
        let sk = MebSketch::read_from(&path).unwrap();
        assert_eq!(sk.seen, ck.last_saved());
        assert_eq!(sk.tag, "interval");
        // resume from the overwritten (latest) checkpoint
        let resumed = resume_fit(&sk, exs.clone());
        let full = StreamSvm::fit(exs.iter(), 4, &opts);
        assert_eq!(resumed.weights(), full.weights());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lookahead_resume_is_bit_identical_at_buffer_empty_cuts() {
        use crate::svm::lookahead::LookaheadSvm;
        check_default("checkpoint-lookahead-resume", |rng, case| {
            let d = gen::dim(rng);
            let n = 30 + rng.below(150);
            let l = 2 + rng.below(8);
            let opts = TrainOptions::default().with_lookahead(l);
            let exs = toy(n, d, 9000 + case as u64);
            let full = LookaheadSvm::fit(exs.iter(), d, &opts);

            // walk the stream; sketch at the first buffer-empty position
            // past the midpoint (the checkpointer's save precondition)
            let mut m = LookaheadSvm::new(d, opts);
            let mut sk: Option<MebSketch> = None;
            for (i, e) in exs.iter().enumerate() {
                m.observe_view(e.x.view(), e.y);
                if sk.is_none() && i + 1 >= n / 2 && i + 1 < n && m.buffered() == 0 {
                    sk = Some(
                        MebSketch::new(d, m.ball().cloned(), i + 1, opts, "la")
                            .with_merges(m.num_merges()),
                    );
                }
            }
            let Some(sk) = sk else {
                return Ok(()); // no buffer-empty cut in range: vacuous case
            };
            let sk = MebSketch::decode(&sk.encode()).map_err(|e| e.to_string())?;
            let resumed = resume_fit(&sk, exs.clone());
            let fb = full.ball().expect("trained");
            if resumed.weights() != fb.weights()
                || resumed.radius().to_bits() != fb.r.to_bits()
                || resumed.num_support() != fb.m
                || resumed.examples_seen() != n
            {
                return Err(format!(
                    "lookahead L={l} resume at {} diverged: R {} vs {}",
                    sk.seen,
                    resumed.radius(),
                    fb.r
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn empty_sketch_resume_respects_lookahead() {
        // An empty sketch (seen = 0) with lookahead options must replay
        // the whole stream as Algorithm 2, not Algorithm 1.
        let exs = toy(120, 4, 21);
        let opts = TrainOptions::default().with_lookahead(5);
        let sk = MebSketch::new(4, None, 0, opts, "empty-la");
        let resumed = resume_fit(&sk, exs.clone());
        let direct = crate::svm::lookahead::LookaheadSvm::fit(exs.iter(), 4, &opts);
        assert_eq!(resumed.weights(), direct.weights());
        assert_eq!(resumed.radius().to_bits(), direct.radius().to_bits());
        assert_eq!(resumed.examples_seen(), 120);
    }

    #[test]
    fn learner_resume_is_bit_identical_per_variant() {
        let exs = toy(160, 4, 55);
        let opts = TrainOptions::default().with_c(1.5);
        for variant in Variant::ALL {
            let mut full = AnyLearner::new(variant, 4, opts);
            for e in &exs {
                full.observe_view(e.x.view(), e.y);
            }
            full.finish();
            // interrupt at a snapshot-legal position: lookahead only at
            // buffer-empty cuts, every other variant anywhere.
            let mut partial = AnyLearner::new(variant, 4, opts);
            let mut cut = None;
            for (i, e) in exs.iter().enumerate() {
                partial.observe_view(e.x.view(), e.y);
                if cut.is_none() && i + 1 >= 80 && i + 1 < 160 {
                    let legal = match &partial {
                        AnyLearner::Lookahead(m) => m.buffered() == 0,
                        _ => true,
                    };
                    if legal {
                        cut = Some(MebSketch::from_learner(&partial, "cut"));
                    }
                }
            }
            let Some(sk) = cut else {
                continue; // no buffer-empty cut in range: vacuous case
            };
            // round-trip through bytes, as a real interruption would
            let sk = MebSketch::decode(&sk.encode()).unwrap();
            assert_eq!(sk.variant, variant);
            let resumed = resume_learner(&sk, exs.clone()).unwrap();
            assert_eq!(resumed.variant(), variant);
            assert_eq!(resumed.examples_seen(), 160, "{variant}");
            assert_eq!(
                resumed.radius().to_bits(),
                full.radius().to_bits(),
                "{variant}: radius diverged after resume"
            );
            for e in exs.iter().take(8) {
                assert_eq!(
                    resumed.score_view(e.x.view()).to_bits(),
                    full.score_view(e.x.view()).to_bits(),
                    "{variant}: scores diverged after resume"
                );
            }
        }
    }

    #[test]
    fn torn_checkpoint_resumes_from_previous_snapshot() {
        let dir = std::env::temp_dir().join(format!("ssvm_ckpt_torn_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.meb");
        let opts = TrainOptions::default();
        let exs = toy(80, 4, 17);
        let mut ck = Checkpointer::new(CheckpointConfig {
            every: 1,
            path: path.clone(),
            tag: "torn".into(),
        });

        // first snapshot at seen=40: no prev exists yet
        let mut model = StreamSvm::new(4, opts);
        for e in exs.iter().take(40) {
            model.observe_view(e.x.view(), e.y);
        }
        ck.save(model.ball(), 4, 40, 0, &opts).unwrap();
        assert!(!prev_snapshot_path(&path).exists());

        // second snapshot at seen=80 rotates the first to .prev
        for e in exs.iter().skip(40) {
            model.observe_view(e.x.view(), e.y);
        }
        ck.save(model.ball(), 4, 80, 0, &opts).unwrap();
        assert!(prev_snapshot_path(&path).exists());

        // tear the live checkpoint mid-file (partial write / full disk)
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(MebSketch::read_from(&path).is_err(), "torn file must not decode");

        // tolerant read falls back to the previous good snapshot...
        let sk = read_sketch_with_fallback(&path).unwrap();
        assert_eq!(sk.seen, 40);
        // ...and resuming from it replays to the uninterrupted result
        let resumed = resume_fit(&sk, exs.clone());
        let direct = StreamSvm::fit(exs.iter(), 4, &opts);
        assert!(bit_equal(&resumed, &direct));

        // with the .prev also unreadable, the primary's error surfaces
        std::fs::write(prev_snapshot_path(&path), b"junk").unwrap();
        assert!(read_sketch_with_fallback(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_and_resume_model_helpers() {
        let dir = std::env::temp_dir().join(format!("ssvm_ckpt_h_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.meb");
        let exs = toy(64, 3, 9);
        let model = StreamSvm::fit(exs.iter(), 3, &TrainOptions::default());
        save_model(&model, "helper", &path).unwrap();
        let back = resume_model(&path).unwrap();
        assert!(bit_equal(&model, &back));
        std::fs::remove_dir_all(&dir).ok();
    }
}
