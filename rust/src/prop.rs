//! Minimal seeded property-testing driver.
//!
//! The offline image has no `proptest`; this module provides the subset we
//! need: run a property over `n` generated cases from a deterministic
//! seed, and on failure report the case index and seed so the exact case
//! replays. Invariant suites across the crate (ball growth, enclosure,
//! batcher conservation, pipeline equivalence, ...) are built on this.

use crate::rng::Pcg32;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop(case_rng, case_index)` for `cfg.cases` cases. The property
/// returns `Err(msg)` to signal failure. Panics with a replayable report.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Pcg32, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        // Each case gets an independent, replayable stream.
        let mut rng = Pcg32::new(cfg.seed.wrapping_add(case as u64), 1000 + case as u64);
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property `{name}` failed at case {case}/{} (seed {:#x}): {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Shorthand: `check` with default config.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Pcg32, usize) -> Result<(), String>,
{
    check(name, PropConfig::default(), prop)
}

/// Generator helpers used by the invariant suites.
pub mod gen {
    use crate::rng::Pcg32;

    /// A random dense example matrix: `n` rows of dimension `d`, entries
    /// N(0, scale²), optional per-class mean shift `sep` on labels.
    pub fn labeled_points(
        rng: &mut Pcg32,
        n: usize,
        d: usize,
        scale: f64,
        sep: f64,
    ) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mu: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let y = rng.label(0.5);
            let x: Vec<f32> = (0..d)
                .map(|j| (rng.normal() * scale + y as f64 * sep * mu[j]) as f32)
                .collect();
            xs.push(x);
            ys.push(y);
        }
        (xs, ys)
    }

    /// Random dimension from a menu of tile-edge cases.
    pub fn dim(rng: &mut Pcg32) -> usize {
        const MENU: [usize; 7] = [1, 2, 3, 5, 21, 64, 130];
        MENU[rng.below(MENU.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check_default("trivial", |rng, _| {
            let v = rng.uniform();
            if (0.0..1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("uniform out of range: {v}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn check_reports_failure() {
        check(
            "always-fails",
            PropConfig { cases: 3, seed: 1 },
            |_, _| Err("nope".into()),
        );
    }

    #[test]
    fn labeled_points_shapes() {
        let mut rng = Pcg32::seeded(1);
        let (xs, ys) = gen::labeled_points(&mut rng, 10, 4, 1.0, 0.5);
        assert_eq!(xs.len(), 10);
        assert_eq!(ys.len(), 10);
        assert!(xs.iter().all(|x| x.len() == 4));
        assert!(ys.iter().all(|&y| y == 1.0 || y == -1.0));
    }
}
