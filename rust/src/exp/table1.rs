//! Table 1: single-pass classification accuracies of every algorithm on
//! every dataset, averaged over random stream orders.
//!
//! Columns mirror the paper: libSVM(batch) [our dual-CD batch ℓ₂-SVM],
//! Perceptron, Pegasos k=1, Pegasos k=20, LASVM, StreamSVM Algo-1,
//! StreamSVM Algo-2 (L≈10).

use crate::baselines::batch_l2svm::{BatchL2Svm, BatchL2SvmOptions};
use crate::baselines::lasvm::{Lasvm, LasvmOptions};
use crate::baselines::pegasos::{Pegasos, PegasosOptions};
use crate::baselines::perceptron::Perceptron;
use crate::bench_util::Table;
use crate::data::registry::{load_dataset_sized, TABLE1_NAMES};
use crate::data::{Dataset, Example};
use crate::error::Result;
use crate::eval::{accuracy, mean_std};
use crate::exp::ExpScale;
use crate::rng::Pcg32;
use crate::svm::lookahead::LookaheadSvm;
use crate::svm::streamsvm::StreamSvm;
use crate::svm::TrainOptions;

/// All Table-1 columns.
pub const ALGOS: [&str; 7] =
    ["libSVM(b)", "Perceptron", "Pegasos k=1", "Pegasos k=20", "LASVM", "Algo-1", "Algo-2"];

/// One dataset row: mean accuracy (and std over stream orders) per algo.
#[derive(Clone, Debug)]
pub struct Row {
    pub dataset: String,
    pub dim: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub acc: Vec<(f64, f64)>, // (mean, std) per ALGOS entry
}

fn permuted(train: &[Example], seed: u64) -> Vec<Example> {
    let mut order: Vec<usize> = (0..train.len()).collect();
    Pcg32::new(seed, 0x7AB1).shuffle(&mut order);
    order.iter().map(|&i| train[i].clone()).collect()
}

/// Per-dataset C for the streaming algorithms (the paper tunes C per
/// dataset; these were selected once on seed-0 training data only).
pub fn c_for(name: &str) -> f64 {
    match name {
        "mnist01" | "mnist89" => 0.1,
        "w3a" => 10.0,
        _ => 1.0,
    }
}

/// Run one dataset row.
pub fn run_dataset(ds: &Dataset, scale: &ExpScale) -> Row {
    let dim = ds.dim;
    let c = c_for(&ds.name);
    let opts1 = TrainOptions::default().with_c(c);
    let opts2 = opts1.with_lookahead(10);

    // batch solver sees the data once, in memory (order-insensitive).
    let batch = BatchL2Svm::fit(
        &ds.train,
        dim,
        &BatchL2SvmOptions { c, max_epochs: 200, tol: 1e-3, ..Default::default() },
    );
    let batch_acc = accuracy(&batch, &ds.test);

    let mut per_algo: Vec<Vec<f64>> = vec![Vec::new(); ALGOS.len()];
    per_algo[0].push(batch_acc);
    for run in 0..scale.runs {
        let stream = permuted(&ds.train, scale.seed + run as u64);
        per_algo[1].push(accuracy(&Perceptron::fit(stream.iter(), dim), &ds.test));
        // Pegasos regularization tied to the same per-dataset C the
        // SVM solvers use: lambda = 1/(C N).
        let lambda = Some(1.0 / (c * stream.len() as f64));
        per_algo[2].push(accuracy(
            &Pegasos::fit(&stream, dim, &PegasosOptions { k: 1, lambda }),
            &ds.test,
        ));
        per_algo[3].push(accuracy(
            &Pegasos::fit(&stream, dim, &PegasosOptions { k: 20, lambda }),
            &ds.test,
        ));
        per_algo[4].push(accuracy(
            &Lasvm::fit(stream.iter(), dim, &LasvmOptions { c, ..Default::default() }),
            &ds.test,
        ));
        per_algo[5].push(accuracy(&StreamSvm::fit(stream.iter(), dim, &opts1), &ds.test));
        per_algo[6].push(accuracy(&LookaheadSvm::fit(stream.iter(), dim, &opts2), &ds.test));
    }
    Row {
        dataset: ds.name.clone(),
        dim,
        n_train: ds.train.len(),
        n_test: ds.test.len(),
        acc: per_algo.iter().map(|v| mean_std(v)).collect(),
    }
}

/// Run the full table (all eight datasets).
pub fn run(scale: &ExpScale) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for name in TABLE1_NAMES {
        let ds = load_dataset_sized(name, scale.seed, scale.train_frac)?;
        rows.push(run_dataset(&ds, scale));
    }
    Ok(rows)
}

/// Print rows in the paper's format.
pub fn print(rows: &[Row]) {
    let mut headers = vec!["Data Set", "Dim", "Train", "Test"];
    headers.extend(ALGOS);
    let mut t = Table::new(&headers);
    for r in rows {
        let mut cells = vec![
            r.dataset.clone(),
            r.dim.to_string(),
            r.n_train.to_string(),
            r.n_test.to_string(),
        ];
        cells.extend(r.acc.iter().map(|(m, _)| format!("{:.2}", m * 100.0)));
        t.row(&cells);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::load_dataset_sized;

    #[test]
    fn smoke_row_has_expected_shape_and_regime() {
        let ds = load_dataset_sized("synthA", 1, 0.02).unwrap();
        let row = run_dataset(&ds, &ExpScale { train_frac: 0.02, runs: 2, seed: 1 });
        assert_eq!(row.acc.len(), ALGOS.len());
        // On easy synthA even smoke-scale runs should separate well for
        // the batch solver and StreamSVM.
        assert!(row.acc[0].0 > 0.85, "batch acc {}", row.acc[0].0);
        assert!(row.acc[5].0 > 0.80, "algo1 acc {}", row.acc[5].0);
        for (m, s) in &row.acc {
            assert!((0.0..=1.0).contains(m));
            assert!(*s >= 0.0);
        }
    }
}
