//! §6.1 / Figure 4: the adversarial and random-stream constructions
//! showing that lookahead cannot beat the (1+√2)/2 lower bound or the
//! 3/2 upper bound of the streaming MEB.
//!
//! Construction (Figure 4): (N−1)/2 points near (0, 1), (N−1)/2 near
//! (0, −1), one singleton at (1+√2, 0). The streaming algorithm only
//! beats the (1+√2)/2 ratio if the singleton arrives among the first L
//! points — vanishingly unlikely as N grows with polylog L. We run the
//! pure MEB case (slack disabled: C → ∞ so the augmented geometry
//! degenerates to the plain ball) and report achieved-radius / optimal-
//! radius ratios.

use crate::bench_util::Table;
use crate::eval::mean_std;
use crate::rng::Pcg32;
use crate::svm::lookahead::LookaheadSvm;
use crate::svm::{SlackMode, TrainOptions};

/// Ratio statistics for one (algo, L) configuration.
#[derive(Clone, Debug)]
pub struct BoundsPoint {
    pub l: usize,
    pub order: &'static str,
    pub mean_ratio: f64,
    pub std_ratio: f64,
    pub max_ratio: f64,
}

pub const LOWER_BOUND: f64 = 1.2071067811865475; // (1+√2)/2
pub const UPPER_BOUND: f64 = 1.5;

/// Near-slackless options: C huge ⇒ 1/C and s² ≈ 0, so the augmented MEB
/// is the plain geometric MEB of the points.
fn meb_opts(l: usize) -> TrainOptions {
    TrainOptions::default()
        .with_c(1e9)
        .with_slack_mode(SlackMode::Consistent)
        .with_lookahead(l)
}

/// The Figure-4 instance, all labels +1 (pure MEB).
fn adversarial_instance(n: usize, jitter: f64, rng: &mut Pcg32) -> Vec<Vec<f32>> {
    let mut pts = Vec::with_capacity(n);
    let half = (n - 1) / 2;
    for _ in 0..half {
        pts.push(vec![rng.normal_ms(0.0, jitter) as f32, (1.0 + rng.normal_ms(0.0, jitter)) as f32]);
    }
    for _ in 0..half {
        pts.push(vec![rng.normal_ms(0.0, jitter) as f32, (-1.0 + rng.normal_ms(0.0, jitter)) as f32]);
    }
    pts.push(vec![(1.0 + std::f64::consts::SQRT_2) as f32, 0.0]);
    pts
}

/// Exact optimal MEB radius of a small 2-d point set (dense search on the
/// x-axis exploiting the construction's symmetry is NOT valid once points
/// are jittered, so use Welzl-style exact solve via three-point
/// circumscribed circles — n here is small).
fn optimal_radius_2d(pts: &[Vec<f32>]) -> f64 {
    // Badoiu-Clarkson with many iterations on raw points (s2 = 0) is
    // accurate to ~1e-3 relative; sufficient for the ratio study.
    let ys = vec![1.0f32; pts.len()];
    let xrefs: Vec<&[f32]> = pts.iter().map(|p| p.as_slice()).collect();
    let meb = crate::svm::meb::solve_meb_points(&xrefs, &ys, 0.0, 4000);
    meb.r
}

/// Run the bounds study: adversarial order (singleton last) vs random
/// order, for each lookahead L.
pub fn run(n: usize, ls: &[usize], trials: usize, seed: u64) -> Vec<BoundsPoint> {
    let mut out = Vec::new();
    for &l in ls {
        for order in ["adversarial", "random"] {
            let mut ratios = Vec::with_capacity(trials);
            for t in 0..trials {
                let mut rng = Pcg32::new(seed + t as u64, 0xB0);
                let mut pts = adversarial_instance(n, 0.01, &mut rng);
                let opt = optimal_radius_2d(&pts);
                match order {
                    // singleton already last in construction; shuffle the
                    // cloud only
                    "adversarial" => {
                        let last = pts.len() - 1;
                        // shuffle all but the singleton
                        for i in (1..last).rev() {
                            let j = rng.below(i + 1);
                            pts.swap(i, j);
                        }
                    }
                    _ => rng.shuffle(&mut pts),
                }
                let opts = meb_opts(l);
                let mut m = LookaheadSvm::new(2, opts);
                for p in &pts {
                    m.observe(p, 1.0);
                }
                m.finish();
                ratios.push(m.radius() / opt);
            }
            let (mean, std) = mean_std(&ratios);
            let max = ratios.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            out.push(BoundsPoint { l, order, mean_ratio: mean, std_ratio: std, max_ratio: max });
        }
    }
    out
}

/// Print with the theoretical lines.
pub fn print(points: &[BoundsPoint]) {
    println!(
        "theory: lower bound (1+√2)/2 = {LOWER_BOUND:.4}, upper bound 3/2 = {UPPER_BOUND}"
    );
    let mut t = Table::new(&["L", "order", "mean ratio", "std", "max ratio"]);
    for p in points {
        t.row(&[
            p.l.to_string(),
            p.order.to_string(),
            format!("{:.4}", p.mean_ratio),
            format!("{:.4}", p.std_ratio),
            format!("{:.4}", p.max_ratio),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adversarial_ratio_in_theory_band() {
        let pts = run(201, &[1, 8], 4, 7);
        for p in &pts {
            // BC-approximate optimum + float noise: generous band around
            // [1, 3/2]. The adversarial singleton-last order should sit
            // near or above the lower bound.
            assert!(
                p.mean_ratio > 0.95 && p.mean_ratio < UPPER_BOUND + 0.08,
                "{p:?}"
            );
            if p.order == "adversarial" {
                assert!(p.mean_ratio > LOWER_BOUND - 0.12, "{p:?}");
            }
        }
    }

    #[test]
    fn instance_shape() {
        let mut rng = Pcg32::seeded(1);
        let pts = adversarial_instance(101, 0.0, &mut rng);
        assert_eq!(pts.len(), 101);
        let last = pts.last().unwrap();
        assert!((last[0] as f64 - (1.0 + std::f64::consts::SQRT_2)).abs() < 1e-6);
        // optimal radius: MEB of {(0,±1), (1+√2, 0)} — all three on the
        // boundary; radius ≈ 1.414 (circumradius), sanity check > 1.2
        let opt = optimal_radius_2d(&pts);
        assert!(opt > 1.2 && opt < 1.7, "opt {opt}");
    }
}
