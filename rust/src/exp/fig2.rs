//! Figure 2: how many passes over MNIST 8vs9 the batch CVM needs before
//! it beats one StreamSVM pass.
//!
//! X axis: CVM passes (one pass per core vector). Y axis: test accuracy.
//! Horizontal reference lines: single-pass StreamSVM Algo-1 and Algo-2.

use crate::baselines::cvm::{Cvm, CvmOptions};
use crate::bench_util::Table;
use crate::data::registry::load_dataset_sized;
use crate::error::Result;
use crate::eval::{accuracy, Classifier};
use crate::exp::ExpScale;
use crate::linalg;
use crate::svm::lookahead::LookaheadSvm;
use crate::svm::streamsvm::StreamSvm;
use crate::svm::TrainOptions;

/// Accuracy of a raw weight vector.
struct W<'a>(&'a [f32]);
impl Classifier for W<'_> {
    fn score(&self, x: &[f32]) -> f64 {
        linalg::dot(self.0, x)
    }
}

/// The figure's data: CVM accuracy per pass + the StreamSVM lines.
#[derive(Clone, Debug)]
pub struct Fig2 {
    pub dataset: String,
    pub algo1_acc: f64,
    pub algo2_acc: f64,
    /// (pass, test accuracy, core-set size)
    pub cvm_curve: Vec<(usize, f64, usize)>,
    /// First pass where CVM ≥ Algo-2's single-pass accuracy (None if never).
    pub passes_to_beat: Option<usize>,
}

/// Run Figure 2 on `dataset` (paper: mnist89) with a pass budget.
pub fn run(dataset: &str, max_passes: usize, scale: &ExpScale) -> Result<Fig2> {
    let ds = load_dataset_sized(dataset, scale.seed, scale.train_frac)?;
    let c = crate::exp::table1::c_for(dataset);
    let opts = TrainOptions::default().with_c(c);

    let algo1 = StreamSvm::fit(ds.train.iter(), ds.dim, &opts);
    let algo2 = LookaheadSvm::fit(ds.train.iter(), ds.dim, &opts.with_lookahead(10));
    let algo1_acc = accuracy(&algo1, &ds.test);
    let algo2_acc = accuracy(&algo2, &ds.test);

    let mut curve = Vec::new();
    let _ = Cvm::fit_tracked(
        &ds.train,
        ds.dim,
        &CvmOptions {
            train: opts,
            eps: 1e-6,
            max_passes,
            ..Default::default()
        },
        |snap| {
            let acc = accuracy(&W(&snap.w), &ds.test);
            curve.push((snap.pass, acc, snap.coreset));
        },
    );
    // CVM's accuracy oscillates while the core set grows; the paper's
    // question is when it *sustainably* matches one StreamSVM pass, so we
    // report the first pass after which it never drops below the target.
    let target = algo2_acc;
    let passes_to_beat = curve
        .iter()
        .rev()
        .take_while(|(_, a, _)| *a >= target)
        .last()
        .map(|(p, _, _)| *p)
        .filter(|&p| p < curve.last().map(|(q, _, _)| *q).unwrap_or(0) || curve.len() == 1);
    Ok(Fig2 { dataset: ds.name, algo1_acc, algo2_acc, cvm_curve: curve, passes_to_beat })
}

/// Print the figure as a table (plus the headline number).
pub fn print(f: &Fig2) {
    println!(
        "single-pass StreamSVM on {}: Algo-1 {:.2}%, Algo-2(L=10) {:.2}%",
        f.dataset,
        f.algo1_acc * 100.0,
        f.algo2_acc * 100.0
    );
    let mut t = Table::new(&["CVM passes", "coreset", "accuracy %"]);
    // thin the curve for printing: powers-of-two-ish passes + the last
    let mut printed = 0usize;
    for (p, a, cs) in &f.cvm_curve {
        let show = p.is_power_of_two() || *p == f.cvm_curve.len() || *p <= 4;
        if show {
            t.row(&[p.to_string(), cs.to_string(), format!("{:.2}", a * 100.0)]);
            printed += 1;
        }
    }
    let _ = printed;
    t.print();
    match f.passes_to_beat {
        Some(p) => println!(
            "CVM needs {p} passes to reach StreamSVM's single-pass accuracy \
             ({:.2}%)",
            f.algo2_acc * 100.0
        ),
        None => println!(
            "CVM did NOT reach StreamSVM's single-pass accuracy ({:.2}%) within \
             the {}-pass budget",
            f.algo2_acc * 100.0,
            f.cvm_curve.len()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_curve_shape() {
        let f = run("mnist89", 8, &ExpScale { train_frac: 0.02, runs: 1, seed: 3 }).unwrap();
        assert!(!f.cvm_curve.is_empty());
        assert!(f.cvm_curve.len() <= 8);
        for w in f.cvm_curve.windows(2) {
            assert_eq!(w[1].0, w[0].0 + 1); // consecutive passes
            assert!(w[1].2 >= w[0].2); // core set grows
        }
        assert!((0.0..=1.0).contains(&f.algo1_acc));
        assert!((0.0..=1.0).contains(&f.algo2_acc));
    }
}
