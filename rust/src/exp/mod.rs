//! Experiment harnesses regenerating every table and figure of the
//! paper's evaluation (§5) plus the §6.1 bounds study. Shared between
//! the CLI (`streamsvm table1` etc.) and the benches.

pub mod bounds;
pub mod fig2;
pub mod fig3;
pub mod table1;

/// Global scale knobs so experiments run at paper size from the CLI and
/// at smoke size from tests/benches.
#[derive(Clone, Copy, Debug)]
pub struct ExpScale {
    /// Fraction of each training split to use (1.0 = paper size).
    pub train_frac: f64,
    /// Stream-order repetitions to average over.
    pub runs: usize,
    /// Base seed.
    pub seed: u64,
}

impl Default for ExpScale {
    fn default() -> Self {
        ExpScale { train_frac: 1.0, runs: 20, seed: 42 }
    }
}

impl ExpScale {
    pub fn smoke() -> Self {
        ExpScale { train_frac: 0.05, runs: 3, seed: 42 }
    }
}
